"""Test configuration.

8 host devices (NOT the dry-run's 512 — that flag stays local to
launch/dryrun.py): the partitioning-equivalence and elastic-scaling tests
need a real multi-device mesh to exercise shard_map collectives, and 8 keeps
CPU compiles fast.  Must run before the first jax import in the process.
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)
