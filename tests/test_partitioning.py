"""Distributed-equivalence suite: snapshot partitioning (plain + overlapped),
vertex partitioning, hybrid SpMM — all against the single-device reference,
on an 8-host-device mesh.  This is the paper's Fig. 6 claim (identical
convergence) made exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import checkpoint as ckpt_exec
from repro.core import dtdg, models, partition
from repro.dist import overlap
from repro.graph import generate
from repro.launch.mesh import make_host_mesh

T, N = 16, 32


def _setup(model, nb=2):
    snaps = generate.evolving_dynamic_graph(N, T, density=2.0, churn=0.1,
                                            seed=0)
    frames = np.stack([generate.degree_features(s, N) for s in snaps])
    batch = dtdg.build_batch(snaps, frames, N)
    cfg = models.DynGNNConfig(model=model, num_nodes=N, num_steps=T,
                              window=3, checkpoint_blocks=nb)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    labels = jnp.asarray(
        np.random.default_rng(0).integers(0, 2, size=(T, N)))
    return cfg, params, batch, labels


@pytest.mark.parametrize("model", ["cdgcn", "evolvegcn", "tmgcn"])
def test_snapshot_partition_matches_reference(model):
    mesh = make_host_mesh(data=4, model=1)
    cfg, params, batch, labels = _setup(model)
    z_ref = ckpt_exec.blocked_forward(cfg, params, batch, nb=2)
    fwd = partition.snapshot_partition_forward(cfg, mesh)
    fr, ed, ew = partition.blockify_batch(batch, 2)
    z_sp = np.asarray(jax.jit(fwd)(params, fr, ed, ew)).reshape(z_ref.shape)
    np.testing.assert_allclose(np.asarray(z_ref), z_sp, atol=1e-5)


@pytest.mark.parametrize("model", ["cdgcn", "evolvegcn", "tmgcn"])
def test_snapshot_partition_gradients_match(model):
    mesh = make_host_mesh(data=4, model=1)
    cfg, params, batch, labels = _setup(model)
    lossfn = partition.snapshot_partition_loss(cfg, mesh)
    fr, ed, ew = partition.blockify_batch(batch, 2)
    lab_b = labels.reshape(2, T // 2, N)
    l_sp, g_sp = jax.jit(jax.value_and_grad(
        lambda p: lossfn(p, fr, ed, ew, lab_b)))(params)
    l_ref, g_ref = jax.value_and_grad(
        lambda p: ckpt_exec.blocked_node_loss(cfg, p, batch, labels, nb=2))(
        params)
    assert np.allclose(float(l_sp), float(l_ref), atol=1e-6)
    for a, b in zip(jax.tree.leaves(g_sp), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("model", ["cdgcn", "tmgcn"])
def test_overlapped_variant_matches_plain(model):
    """§6.5 compute/comm overlap restructures the schedule, not the math."""
    mesh = make_host_mesh(data=4, model=1)
    cfg, params, batch, labels = _setup(model)
    fr, ed, ew = partition.blockify_batch(batch, 2)
    plain = partition.snapshot_partition_forward(cfg, mesh)
    z1 = np.asarray(jax.jit(plain)(params, fr, ed, ew))
    over = overlap.snapshot_partition_forward_overlapped(cfg, mesh,
                                                         num_chunks=2)
    z2 = np.asarray(jax.jit(over)(params, fr, ed, ew))
    np.testing.assert_allclose(z1, z2, atol=1e-5)


def test_overlapped_hlo_has_multiple_all_to_alls():
    """Structural check: C chunks -> C independent all-to-all chains per
    redistribution (what the TPU latency-hiding scheduler overlaps)."""
    mesh = make_host_mesh(data=4, model=1)
    cfg, params, batch, _ = _setup("tmgcn")
    fr, ed, ew = partition.blockify_batch(batch, 2)
    plain = jax.jit(partition.snapshot_partition_forward(cfg, mesh))
    over = jax.jit(overlap.snapshot_partition_forward_overlapped(
        cfg, mesh, num_chunks=2))
    t_plain = plain.lower(params, fr, ed, ew).compile().as_text()
    t_over = over.lower(params, fr, ed, ew).compile().as_text()
    assert t_over.count("all-to-all") > t_plain.count("all-to-all")


@pytest.mark.parametrize("model", ["cdgcn", "tmgcn", "evolvegcn"])
def test_vertex_partition_matches_reference(model):
    mesh = make_host_mesh(data=4, model=1)
    cfg, params, batch, labels = _setup(model, nb=1)
    z_ref = models.forward(cfg, params, batch)
    fwd = partition.vertex_partition_forward(cfg, mesh)
    edges_p, w_p = partition.partition_edges_by_dst(
        batch.edges, batch.edge_mask, N, 4,
        max_local_edges=batch.edges.shape[1])
    # recompute laplacian-normalized weights per partitioned edge layout
    import numpy as onp
    w_full = onp.asarray(batch.edge_weights)
    # map weights: for each t, each partition p, edges were filtered in order
    ew_p = onp.zeros_like(w_p)
    for t in range(T):
        e = onp.asarray(batch.edges[t])
        m = onp.asarray(batch.edge_mask[t]) > 0
        ew_t = w_full[t][m]
        own = e[m][:, 1] // (N // 4)
        for p in range(4):
            sel = ew_t[own == p]
            ew_p[t, p, :sel.shape[0]] = sel
    # vertex_partition_forward expects edges (T, E_total, 2) with the edge
    # axis sharded P(None, 'data'): concatenate the per-partition slices so
    # shard p receives exactly its dst-local edges.
    e_stack = jnp.asarray(edges_p).reshape(T, 4 * edges_p.shape[2], 2)
    w_stack = jnp.asarray(ew_p).reshape(T, 4 * ew_p.shape[2])
    z_vp = jax.jit(fwd)(params, batch.frames, e_stack, w_stack)
    np.testing.assert_allclose(np.asarray(z_ref), np.asarray(z_vp),
                               atol=1e-4)


def test_hybrid_spmm_matches_dense():
    """§6.5 hybrid partitioning: intra-snapshot edge sharding + psum."""
    from functools import partial as fpartial

    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    mesh = make_host_mesh(data=1, model=4)
    rng = np.random.default_rng(0)
    n, e, f = 64, 512, 8
    edges = rng.integers(0, n, size=(e, 2)).astype(np.int32)
    w = rng.normal(size=(e,)).astype(np.float32)
    x = rng.normal(size=(n, f)).astype(np.float32)

    fn = shard_map(
        fpartial(partition.hybrid_spmm, num_nodes=n, model_axis="model"),
        mesh=mesh, in_specs=(P(), P("model", None), P("model")),
        out_specs=P(), check_vma=False)
    got = jax.jit(fn)(jnp.asarray(x), jnp.asarray(edges), jnp.asarray(w))
    from repro.graph import segment
    want = segment.spmm(jnp.asarray(x), jnp.asarray(edges), jnp.asarray(w),
                        n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_comm_volume_law():
    """O(T*N) invariance: snapshot-partition volume is constant in P; the
    all-gather vertex baseline grows ~P (Table 2's qualitative behavior)."""
    from repro.dist import comm_volume as cv
    vols = [cv.snapshot_partition_volume(64, 1024, 6, 2, p) for p in
            (4, 16, 64)]
    assert max(vols) / min(vols) < 1.35     # (P-1)/P factor only
    ag = [cv.allgather_vertex_volume(64, 1024, 6, 2, p) for p in
          (4, 16, 64)]
    assert ag[2] > ag[1] > ag[0]
    assert ag[2] / ag[0] > 10


def test_bfs_vertex_partition_volume_between_bounds():
    from repro.dist import comm_volume as cv
    snaps = generate.evolving_dynamic_graph(256, 8, density=4.0, churn=0.2,
                                            seed=0)
    p = 8
    owner = cv.bfs_partition(np.concatenate(snaps), 256, p)
    v_hyper = cv.vertex_partition_volume(snaps, 256, 6, 2, p, owner)
    v_allgather = cv.allgather_vertex_volume(len(snaps), 256, 6, 2, p)
    assert 0 < v_hyper < v_allgather
