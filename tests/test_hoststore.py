"""Out-of-core sampled training (``repro.hoststore``).

The contracts PR 8 exists for:

* the host ``TemporalCSRStore`` ingests the SAME ``IncrementalEncoder``
  delta items as the device path and reconstructs every snapshot
  exactly (delta ingest == full-sync ingest == the raw edge lists);
* host-resident carries round-trip through gather/scatter losslessly;
* with every vertex a seed and full fanout, ``schedule="sampled"``
  reproduces the full-graph distributed streamed run (<= 1e-5 losses,
  <= 1e-6 params) on the 8-device host mesh — and on 4;
* truncated fanout still trains (bounded final-loss drift vs the
  full-graph reference);
* ``plan.device_budget_bytes`` makes full-graph schedules refuse a
  graph whose per-round tensors do not fit while the sampled schedule
  trains it, staging strictly fewer bytes.
"""

import jax
import numpy as np
import pytest

from repro import hoststore as hs
from repro.core.models import DynGNNConfig
from repro.data.dyngnn import DTDGPipeline, synthetic_dataset
from repro.launch.mesh import make_host_mesh
from repro.stream import distributed as dist
from repro.stream import encoder as enc

N, T, NB = 48, 16, 2
WIN = T // NB


def _ds(model, seed=0):
    smooth = {"tmgcn": "mproduct", "evolvegcn": "edgelife",
              "cdgcn": "none"}[model]
    ds = synthetic_dataset(N, T, density=2.0, churn=0.1,
                           smoothing_mode=smooth, window=3, seed=seed)
    cfg = DynGNNConfig(model=model, num_nodes=N, num_steps=T, window=3,
                       checkpoint_blocks=NB)
    return cfg, ds, np.asarray(ds.frames), np.asarray(ds.labels)


def _canon(edges, values):
    """(src, dst, value) rows in a canonical order for set comparison."""
    rows = np.stack([edges[:, 0].astype(np.int64),
                     edges[:, 1].astype(np.int64)], axis=1)
    order = np.lexsort((values, rows[:, 0], rows[:, 1]))
    return rows[order], values[order]


# ============================================================ store =========

@pytest.mark.parametrize("model", ["tmgcn", "cdgcn"])
def test_store_matches_snapshots(model):
    """Delta-stream ingest reconstructs every snapshot's edge list and
    edge values exactly (order-independent)."""
    _, ds, _, _ = _ds(model)
    store = hs.TemporalCSRStore.from_snapshots(
        ds.snapshots, ds.values, N, block_size=WIN)
    assert store.num_steps == T
    for t in range(T):
        ref_v = (np.asarray(ds.values[t], dtype=np.float32)
                 if ds.values is not None
                 else np.ones(ds.snapshots[t].shape[0], np.float32))
        got_e, got_v = _canon(store.edges(t), store.values_csr(t))
        ref_e, ref_v = _canon(np.asarray(ds.snapshots[t]), ref_v)
        assert np.array_equal(got_e, ref_e)
        np.testing.assert_allclose(got_v, ref_v, rtol=0, atol=0)


def test_store_delta_ingest_equals_full_sync():
    """block_size=WIN (delta-heavy) and block_size=1 (every item a full
    sync) build the same per-step graphs: identical indptr, identical
    (src, value) multisets per dst bucket.  (Entry ORDER within a bucket
    may differ — deltas mirror device order, survivors then adds — and
    aggregation is order-invariant.)"""
    _, ds, _, _ = _ds("cdgcn")
    a = hs.TemporalCSRStore.from_snapshots(ds.snapshots, ds.values, N,
                                           block_size=WIN)
    b = hs.TemporalCSRStore.from_snapshots(ds.snapshots, ds.values, N,
                                           block_size=1)
    for t in range(T):
        assert np.array_equal(a.csr(t).indptr, b.csr(t).indptr)
        ea, va = _canon(a.edges(t), a.values_csr(t))
        eb, vb = _canon(b.edges(t), b.values_csr(t))
        assert np.array_equal(ea, eb)
        assert np.array_equal(va, vb)


def test_store_shares_encoder_items():
    """The store consumes the pipeline's own host stream (one encode,
    no second decode) — same result as encoding itself."""
    _, ds, _, _ = _ds("cdgcn")
    pipe = DTDGPipeline(ds, nb=NB)
    via_pipe = hs.TemporalCSRStore.from_stream(pipe.host_stream(), N)
    direct = hs.TemporalCSRStore.from_snapshots(ds.snapshots, ds.values,
                                                N, block_size=WIN)
    for t in range(T):
        assert np.array_equal(via_pipe.csr(t).indices,
                              direct.csr(t).indices)
    assert via_pipe.nbytes == direct.nbytes
    assert via_pipe.max_in_degree() == direct.max_in_degree()


def test_store_rejects_delta_first():
    _, ds, _, _ = _ds("cdgcn")
    items = list(enc.iter_encode_stream(
        ds.snapshots, ds.values, N, enc.padded_max_edges(ds.snapshots),
        WIN, None))
    store = hs.TemporalCSRStore(N)
    with pytest.raises(ValueError, match="full sync"):
        store.ingest(items[1])      # a delta, mid-block


# ============================================================ carry =========

@pytest.mark.parametrize("model", ["tmgcn", "cdgcn", "evolvegcn"])
def test_carry_gather_scatter_roundtrip(model):
    """scatter(gather(...)) is the identity, touched rows update, and
    rows outside the table keep their previous state."""
    from repro.core import models as mdl

    cfg, _, _, _ = _ds(model)
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    cs = hs.HostCarryStore(cfg, params)
    ids = np.array([1, 5, 7, 40], dtype=np.int64)
    pad = 8
    g0 = cs.gather(ids, pad)
    cs.scatter(ids, g0)
    g1 = cs.gather(ids, pad)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        assert np.array_equal(a, b)
    # perturb the gathered rows, scatter, re-gather: rows moved
    bumped = jax.tree.map(lambda x: x + 1.0, g0)
    cs.scatter(ids, bumped)
    g2 = cs.gather(ids, pad)
    for a, b in zip(jax.tree.leaves(bumped), jax.tree.leaves(g2)):
        if cs.axis is None:
            assert np.array_equal(a, b)
        else:
            k = ids.shape[0]
            sl = (slice(0, k) if cs.axis == 0
                  else (slice(None), slice(0, k)))
            assert np.array_equal(np.asarray(a)[sl], np.asarray(b)[sl])
    if cs.axis is not None:
        # untouched node keeps its (zero-init) state
        other = cs.gather(np.array([2], dtype=np.int64), pad)
        for leaf in jax.tree.leaves(other):
            assert np.all(np.asarray(leaf) == 0.0)


# ================================================= sampling pipeline ========

def test_sample_round_deterministic_across_workers():
    """The same (seed, epoch, round) samples identically no matter how
    many worker threads run the per-step expansions."""
    from concurrent.futures import ThreadPoolExecutor

    _, ds, frames, labels = _ds("cdgcn")
    store = hs.TemporalCSRStore.from_snapshots(ds.snapshots, ds.values, N,
                                               block_size=WIN)
    spec = hs.SamplingSpec(batch_nodes=12, fanouts=(3, 3), seed=5)
    resolved = spec.resolve(N, WIN, 4)
    outs = []
    for workers in (1, 4):
        with ThreadPoolExecutor(max_workers=workers) as pool:
            outs.append(hs.sample_round(store, frames, labels, spec,
                                        resolved, WIN, r=1, epoch=0,
                                        pool=pool))
    a, b = outs
    assert np.array_equal(a.node_ids, b.node_ids)
    for f in ("frames", "labels", "edges", "mask", "values"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f


def test_sample_round_budget_overflow_degrades():
    """Tiny static budgets drop lanes (counted) but never change
    shapes."""
    from concurrent.futures import ThreadPoolExecutor

    _, ds, frames, labels = _ds("cdgcn")
    store = hs.TemporalCSRStore.from_snapshots(ds.snapshots, ds.values, N,
                                               block_size=WIN)
    spec = hs.SamplingSpec(batch_nodes=8, fanouts=(8, 8), seed=0,
                           table_pad=12, max_edges=16)
    resolved = spec.resolve(N, WIN, 4)
    assert resolved.table_pad == 12 and resolved.edge_pad == 128
    with ThreadPoolExecutor(max_workers=2) as pool:
        rnd = hs.sample_round(store, frames, labels, spec, resolved, WIN,
                              r=0, epoch=0, pool=pool)
    assert rnd.dropped_nodes > 0
    assert rnd.edges.shape == (WIN, 128, 2)
    assert rnd.frames.shape == (WIN, 12, frames.shape[-1])
    # surviving edges reference only in-table lanes
    assert rnd.edges.max() < 12


def test_draw_seeds_identity_and_random():
    assert np.array_equal(hs.draw_seeds(10, 10, 0, 0, 0), np.arange(10))
    assert np.array_equal(hs.draw_seeds(10, 99, 0, 0, 0), np.arange(10))
    s = hs.draw_seeds(100, 10, seed=1, epoch=0, r=0)
    assert s.shape == (10,) and np.unique(s).shape == (10,)
    assert np.array_equal(s, hs.draw_seeds(100, 10, 1, 0, 0))
    assert not np.array_equal(s, hs.draw_seeds(100, 10, 1, 0, 1))


def test_sampling_spec_resolve():
    spec = hs.SamplingSpec(batch_nodes=16, fanouts=(4, 4))
    r = spec.resolve(num_nodes=1000, win=8, num_shards=8)
    assert r.num_seeds == 16
    assert r.table_pad % 8 == 0
    assert r.edge_pad % 128 == 0
    # table bounded by N
    r2 = spec.resolve(num_nodes=48, win=8, num_shards=8)
    assert r2.table_pad == 48
    with pytest.raises(ValueError):
        hs.SamplingSpec(batch_nodes=0).validate()
    with pytest.raises(ValueError):
        hs.SamplingSpec(batch_nodes=4, fanouts=()).validate()


# ===================================================== equivalence ==========

@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
@pytest.mark.parametrize("model", ["tmgcn", "cdgcn", "evolvegcn"])
def test_full_fanout_matches_full_graph_reference(model):
    """Every vertex a seed + fanout >= max in-degree: the sampled
    schedule IS the full-graph distributed streamed run (<= 1e-5
    losses, <= 1e-6 params) on the 8-device mesh."""
    cfg, ds, frames, labels = _ds(model)
    pipe = DTDGPipeline(ds, nb=NB)
    mesh = make_host_mesh(data=8, model=1)
    ref = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        block_size=WIN, num_epochs=2, stats=pipe.stream_stats,
        max_edges=pipe.max_edges, log_fn=None)
    store = hs.TemporalCSRStore.from_stream(pipe.host_stream(), N)
    deg = store.max_in_degree()
    spec = hs.SamplingSpec(batch_nodes=N, fanouts=(deg, deg), seed=0)
    got = hs.train_sampled(cfg, store, frames, labels, spec=spec,
                           mesh=mesh, block_size=WIN, num_epochs=2,
                           log_fn=None)
    assert len(got.losses) == len(ref.losses) == 2 * NB
    np.testing.assert_allclose(got.losses, ref.losses, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(got.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)
    assert got.report.dropped_nodes == 0
    assert got.report.dropped_edges == 0


def test_full_fanout_matches_reference_p4():
    """Same equivalence on a 4-shard mesh (different table tiling)."""
    cfg, ds, frames, labels = _ds("cdgcn")
    pipe = DTDGPipeline(ds, nb=NB)
    mesh = make_host_mesh(data=4, model=1)
    ref = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        block_size=WIN, num_epochs=1, stats=pipe.stream_stats,
        max_edges=pipe.max_edges, log_fn=None)
    store = hs.TemporalCSRStore.from_stream(pipe.host_stream(), N)
    deg = store.max_in_degree()
    spec = hs.SamplingSpec(batch_nodes=N, fanouts=(deg, deg), seed=0)
    got = hs.train_sampled(cfg, store, frames, labels, spec=spec,
                           mesh=mesh, block_size=WIN, num_epochs=1,
                           log_fn=None)
    np.testing.assert_allclose(got.losses, ref.losses, rtol=1e-5)


def test_truncated_fanout_converges():
    """GraphSAGE-regime sanity: truncated fanout still trains, and its
    final loss drifts a bounded amount from the full-graph reference."""
    cfg, ds, frames, labels = _ds("cdgcn")
    pipe = DTDGPipeline(ds, nb=NB)
    mesh = make_host_mesh(data=4, model=1)
    epochs = 4
    ref = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        block_size=WIN, num_epochs=epochs, stats=pipe.stream_stats,
        max_edges=pipe.max_edges, log_fn=None)
    store = hs.TemporalCSRStore.from_stream(pipe.host_stream(), N)
    spec = hs.SamplingSpec(batch_nodes=24, fanouts=(4, 4), seed=0)
    got = hs.train_sampled(cfg, store, frames, labels, spec=spec,
                           mesh=mesh, block_size=WIN, num_epochs=epochs,
                           log_fn=None)
    assert len(got.losses) == epochs * NB
    # it trains (first-epoch mean -> last-epoch mean goes down) ...
    first = np.mean(got.losses[:NB])
    last = np.mean(got.losses[-NB:])
    assert last < first
    # ... and lands within a bounded drift of the full-graph final loss
    assert abs(last - np.mean(ref.losses[-NB:])) < 0.1


# ========================================================= budget ===========

def test_budget_gate_numbers():
    kw = dict(num_steps=T, win=WIN, num_shards=4, max_edges=256,
              num_nodes=N, feat_dim=2)
    full = hs.full_graph_round_bytes("streamed_mesh", **kw)
    assert full == (WIN // 4) * (256 * 16 + N * 2 * 4 + N * 4)
    assert hs.check_budget("streamed_mesh", None, **kw) is None
    ok = hs.check_budget("streamed_mesh", full, **kw)
    assert ok == {"required": full, "budget": full}
    with pytest.raises(hs.DeviceBudgetError) as ei:
        hs.check_budget("streamed_mesh", full - 1, **kw)
    assert "sampled" in str(ei.value)


def test_budget_refusal_and_sampled_fit():
    """The win condition, engine-level: a budget the full-graph
    schedules refuse is enough for the sampled schedule, which stages
    strictly fewer graph bytes than the full round would."""
    from repro.run import (Engine, ExecutionPlan, RunConfig, SamplingSpec,
                           SyntheticTrace)

    data = SyntheticTrace(num_nodes=N, num_steps=T, density=2.0, seed=3)
    model = DynGNNConfig(model="cdgcn", num_nodes=N, num_steps=T,
                         checkpoint_blocks=NB)
    spec = SamplingSpec(batch_nodes=12, fanouts=(3, 3), seed=0,
                        table_pad=24, max_edges=128)
    # budget: exactly one sampled round — below every full-graph round
    budget = hs.sampled_round_bytes(
        spec.resolve(N, WIN, 4), win=WIN, num_shards=4, feat_dim=2)
    for mode, shards in (("eager", 1), ("streamed", 1),
                         ("streamed_mesh", 4)):
        plan = ExecutionPlan(mode=mode, shards=shards,
                             device_budget_bytes=budget)
        with pytest.raises(hs.DeviceBudgetError):
            Engine(RunConfig(model=model, data=data, plan=plan,
                             log_fn=lambda s: None)).fit()
    plan = ExecutionPlan(mode="sampled", shards=4, sampling=spec,
                        device_budget_bytes=budget)
    res = Engine(RunConfig(model=model, data=data, plan=plan,
                           log_fn=lambda s: None)).fit()
    assert res.budget_report is not None
    # the full schedules raised above with THIS budget, so transitively
    # sampled_required <= budget < every full-graph requirement
    assert res.budget_report["required"] <= budget
    assert len(res.losses) == NB
    assert res.sample_report.rounds == NB
    assert res.sample_report.staged_bytes > 0


# ========================================================= engine ===========

def test_engine_sampled_mode():
    """mode='sampled' end-to-end through the Engine: losses, sample
    report, and N not padded (the table axis is what tiles)."""
    from repro.run import (Engine, ExecutionPlan, RunConfig, SamplingSpec,
                           SyntheticTrace)

    n_odd = 50                       # NOT a multiple of 4: sampled mode
    data = SyntheticTrace(num_nodes=n_odd, num_steps=T, density=2.0,
                          seed=1)
    model = DynGNNConfig(model="cdgcn", num_nodes=n_odd, num_steps=T,
                         checkpoint_blocks=NB)
    plan = ExecutionPlan(mode="sampled", shards=4, num_epochs=2,
                         sampling=SamplingSpec(batch_nodes=16,
                                               fanouts=(4, 4), seed=2))
    res = Engine(RunConfig(model=model, data=data, plan=plan,
                           log_fn=lambda s: None)).fit()
    assert len(res.losses) == 2 * NB
    assert res.sample_report.rounds == 2 * NB
    assert res.sample_report.table_fill_max <= 50
    assert res.budget_report is None
    assert all(np.isfinite(res.losses))


def test_plan_validation_sampled():
    from repro.run import ExecutionPlan, SamplingSpec

    with pytest.raises(ValueError, match="needs plan.sampling"):
        ExecutionPlan(mode="sampled").validate()
    with pytest.raises(ValueError, match="requires mode='sampled'"):
        ExecutionPlan(mode="eager",
                      sampling=SamplingSpec(batch_nodes=4)).validate()
    with pytest.raises(ValueError, match="device_budget_bytes"):
        ExecutionPlan(device_budget_bytes=0).validate()
    ExecutionPlan(mode="sampled", shards=4,
                  sampling=SamplingSpec(batch_nodes=4)).validate()
