"""Paper-core behaviour: DTDG models, blocked checkpointing, graph-diff,
smoothing — the single-device faithfulness suite."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import checkpoint as ckpt_exec
from repro.core import dtdg, graphdiff, models, smoothing, temporal
from repro.graph import generate


def _small_batch(t=8, n=32, seed=0, churn=0.1):
    snaps = generate.evolving_dynamic_graph(n, t, density=3.0, churn=churn,
                                            seed=seed)
    frames = np.stack([generate.degree_features(s, n) for s in snaps])
    return snaps, dtdg.build_batch(snaps, frames, n)


@pytest.mark.parametrize("model", ["cdgcn", "evolvegcn", "tmgcn"])
def test_forward_shapes_and_finite(model):
    _, batch = _small_batch()
    cfg = models.DynGNNConfig(model=model, num_nodes=32, num_steps=8,
                              window=3)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    z = models.forward(cfg, params, batch)
    assert z.shape == (8, 32, cfg.out_dim)
    assert not bool(jnp.isnan(z).any())


@pytest.mark.parametrize("model", ["cdgcn", "evolvegcn", "tmgcn"])
@pytest.mark.parametrize("nb", [2, 4])
def test_blocked_checkpoint_exactness(model, nb):
    """Gradient checkpointing must not change values OR gradients (§3.1)."""
    _, batch = _small_batch(t=8)
    cfg = models.DynGNNConfig(model=model, num_nodes=32, num_steps=8,
                              window=3)
    params = models.init_params(jax.random.PRNGKey(1), cfg)
    labels = jnp.asarray(
        np.random.default_rng(0).integers(0, 2, size=(8, 32)))
    z_full = models.forward(cfg, params, batch)
    z_blocked = ckpt_exec.blocked_forward(cfg, params, batch, nb=nb)
    np.testing.assert_allclose(np.asarray(z_full), np.asarray(z_blocked),
                               atol=1e-5)
    g_full = jax.grad(lambda p: models.node_loss(cfg, p, batch, labels))(
        params)
    g_blk = jax.grad(lambda p: ckpt_exec.blocked_node_loss(cfg, p, batch,
                                                           labels, nb=nb))(
        params)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_blk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_mproduct_equals_matrix_definition():
    """Eq. in §5.3: Y = M x_1 X with the explicit banded M."""
    rng = np.random.default_rng(0)
    t, n, f, w = 10, 5, 3, 4
    x = jnp.asarray(rng.normal(size=(t, n, f)).astype(np.float32))
    m = jnp.asarray(smoothing.m_transform_matrix(t, w))
    want = jnp.einsum("tk,knf->tnf", m, x)
    got = temporal.m_product(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_edge_life_smoothing_increases_density():
    snaps = generate.evolving_dynamic_graph(64, 10, density=2.0, churn=0.5,
                                            seed=2)
    sm_e, sm_v = smoothing.edge_life(snaps, life=4)
    assert all(s.shape[0] <= e.shape[0]
               for s, e in zip(snaps[3:], sm_e[3:]))
    # weights accumulate duplicates
    assert all(v.max() >= 1.0 for v in sm_v)


def test_smoothing_increases_graphdiff_overlap():
    """§5.4: smoothing magnifies consecutive-snapshot overlap, which the GD
    transfer exploits (the mechanism behind Fig. 4's higher gains)."""
    n = 128
    snaps = generate.evolving_dynamic_graph(n, 12, density=3.0, churn=0.4,
                                            seed=3)
    raw = graphdiff.encode_stream(snaps, None, n, 4096, block_size=12)
    sm_e, sm_v = smoothing.edge_life(snaps, life=5)
    sm = graphdiff.encode_stream(sm_e, sm_v, n, 8192, block_size=12)
    raw_ratio = graphdiff.stream_bytes(raw) / graphdiff.naive_bytes(snaps)
    sm_ratio = graphdiff.stream_bytes(sm) / graphdiff.naive_bytes(sm_e)
    assert sm_ratio < raw_ratio


@settings(max_examples=10, deadline=None)
@given(n=st.integers(16, 128), t=st.integers(2, 12),
       churn=st.floats(0.0, 0.9), seed=st.integers(0, 1000))
def test_graphdiff_roundtrip_property(n, t, churn, seed):
    """decode(encode(stream)) reproduces every snapshot's edge set exactly."""
    snaps = generate.evolving_dynamic_graph(n, t, density=2.0, churn=churn,
                                            seed=seed)
    max_edges = max(s.shape[0] for s in snaps) * 2 + 16
    stream = graphdiff.encode_stream(snaps, None, n, max_edges,
                                     block_size=max(t // 2, 1))
    dec = graphdiff.decode_stream(stream, max_edges)
    for snap, (e, m) in zip(snaps, dec):
        got = set(map(tuple, e[m > 0].tolist()))
        want = set(map(tuple, snap.tolist()))
        assert got == want


def test_graphdiff_bytes_decrease_with_overlap():
    n = 256
    ratios = []
    for churn in (0.05, 0.3, 0.8):
        snaps = generate.evolving_dynamic_graph(n, 10, density=3.0,
                                                churn=churn, seed=1)
        st_ = graphdiff.encode_stream(snaps, None, n, 8192, block_size=10)
        ratios.append(graphdiff.stream_bytes(st_)
                      / graphdiff.naive_bytes(snaps))
    assert ratios[0] < ratios[1] < ratios[2]


def test_checkpoint_memory_model_tradeoff():
    """§3.1: intra-block memory falls with nb, checkpoint data grows."""
    cfg = models.DynGNNConfig(model="cdgcn", num_nodes=1024, num_steps=64,
                              window=3)
    est = [ckpt_exec.activation_memory_estimate(cfg, num_edges=4096, nb=nb)
           for nb in (1, 4, 16)]
    assert est[0]["intra_block"] > est[1]["intra_block"] \
        > est[2]["intra_block"]
    assert est[0]["checkpoint"] < est[1]["checkpoint"] \
        < est[2]["checkpoint"]


def test_evolvegcn_weights_evolve():
    cfg = models.DynGNNConfig(model="evolvegcn", num_nodes=16, num_steps=6)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    ws = temporal.evolve_weights(params["layers"][0]["evolve"], 6)
    assert ws.shape[0] == 6
    # weights differ across time (they evolve)
    assert not bool(jnp.allclose(ws[0], ws[-1]))
