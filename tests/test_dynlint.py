"""dynlint fixture suite: every pass has known-bad snippets it must
flag and known-good snippets it must not, plus pragma semantics and the
repo-wide green-run gate (``python -m tools.dynlint src/`` exits 0 —
the same invocation CI runs)."""

import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.dynlint import core  # noqa: E402
from tools.dynlint.passes import (donation, interpret_mode, locks,  # noqa: E402
                                  prng, shard_axes, static_shapes, timing)


def run_pass(pass_mod, code, path="src/repro/fixture.py"):
    src = core.Source.from_text(textwrap.dedent(code), path)
    return [f for f in pass_mod.check(src)
            if not src.allowed(f.pass_id, f.line)]


# ------------------------------------------------------------ donation ------

def test_donation_flags_read_after_donate():
    bad = """
    import jax
    _step = jax.jit(apply, donate_argnums=(0,))

    def run(buf, y):
        out = _step(buf, y)
        return buf + 1
    """
    fs = run_pass(donation, bad)
    assert len(fs) == 1 and "'buf'" in fs[0].message


def test_donation_clean_when_rebound():
    good = """
    import jax
    _step = jax.jit(apply, donate_argnums=(0,))

    def run(buf, y):
        buf = _step(buf, y)
        return buf + 1
    """
    assert run_pass(donation, good) == []


def test_donation_branches_fork_and_merge():
    good = """
    _step = jax.jit(apply, donate_argnums=(0,))

    def run(buf, y, flag):
        if flag:
            buf = _step(buf, y)
        else:
            buf = buf + 1
        return buf
    """
    assert run_pass(donation, good) == []
    bad = """
    _step = jax.jit(apply, donate_argnums=(0,))

    def run(buf, y, flag):
        if flag:
            _step(buf, y)
        return buf
    """
    assert len(run_pass(donation, bad)) == 1


def test_donation_factory_and_self_attr():
    bad = """
    class Engine:
        def __init__(self, cfg):
            self._advance = make_advance_step(cfg)

        def step(self, frame):
            z = self._advance(self.params, self.carries, frame)
            return self.carries
    """
    fs = run_pass(donation, bad)
    assert any("self.carries" in f.message for f in fs)
    good = """
    class Engine:
        def __init__(self, cfg):
            self._advance = make_advance_step(cfg)

        def step(self, frame):
            z, self.carries = self._advance(self.params, self.carries,
                                            frame)
            return z
    """
    assert run_pass(donation, good) == []


def test_donation_return_alias_of_ring_buffer():
    bad = """
    import jax

    class Ring:
        def __init__(self):
            self._apply = jax.jit(apply, donate_argnums=(0,))

        def consume(self, x):
            self.buf = self._apply(self.buf, x)
            return self.buf
    """
    fs = run_pass(donation, bad)
    assert len(fs) == 1 and "alias" in fs[0].message
    allowed = bad.replace("return self.buf",
                          "return self.buf  # dynlint: allow[donation]")
    assert run_pass(donation, allowed) == []


def test_donation_loop_carried_read():
    bad = """
    _step = jax.jit(apply, donate_argnums=(0,))

    def run(buf, xs):
        for x in xs:
            y = buf * 2
            _step(buf, x)
        return y
    """
    fs = run_pass(donation, bad)
    assert len(fs) == 1


# ----------------------------------------------------------- interpret ------

def test_interpret_literal_flagged():
    bad = """
    out = pl.pallas_call(kernel, out_shape=shape, interpret=True)(x)
    """
    fs = run_pass(interpret_mode, bad,
                  path="src/repro/kernels/seg/seg.py")
    assert len(fs) == 1 and "interpret=True" in fs[0].message
    assert run_pass(interpret_mode, bad.replace("True", "False"),
                    path="src/repro/kernels/seg/seg.py")


def test_interpret_threaded_flag_and_exempt_file_clean():
    good = """
    def f(x, interpret=None):
        mode = resolve_interpret(interpret)
        return pl.pallas_call(kernel, out_shape=s, interpret=mode)(x)
    """
    assert run_pass(interpret_mode, good,
                    path="src/repro/kernels/seg/seg.py") == []
    literal = "out = pl.pallas_call(k, interpret=False)(x)"
    assert run_pass(interpret_mode, literal,
                    path="src/repro/kernels/common.py") == []


# ---------------------------------------------------------------- prng ------

def test_prng_literal_key_flagged_outside_tests():
    bad = "params = init(jax.random.PRNGKey(0), cfg)"
    fs = run_pass(prng, bad)
    assert len(fs) == 1 and "PRNGKey(0)" in fs[0].message
    assert run_pass(prng, bad, path="tests/test_x.py") == []
    assert run_pass(prng, bad, path="examples/quickstart.py") == []
    good = "params = init(jax.random.PRNGKey(seed), cfg)"
    assert run_pass(prng, good) == []


def test_prng_key_reuse_flagged():
    bad = """
    def init(key):
        a = jax.random.normal(key, (2,))
        b = jax.random.normal(key, (2,))
        return a, b
    """
    fs = run_pass(prng, bad)
    assert len(fs) == 1 and "second consumer" in fs[0].message


def test_prng_split_between_consumers_clean():
    good = """
    def init(key):
        k1, k2 = jax.random.split(key)
        a = jax.random.normal(k1, (2,))
        b = jax.random.normal(k2, (2,))
        return a, b
    """
    assert run_pass(prng, good) == []


def test_prng_subscripted_subkeys_and_loop_resplit_clean():
    good = """
    def init(key, n):
        ks = jax.random.split(key, n)
        a = jax.random.normal(ks[0], (2,))
        b = jax.random.normal(ks[1], (2,))
        layers = []
        for _ in range(n):
            key, k = jax.random.split(key)
            layers.append(jax.random.normal(k, (2,)))
        return a, b, layers
    """
    assert run_pass(prng, good) == []


def test_prng_reuse_inside_loop_flagged():
    bad = """
    def init(key, n):
        out = []
        for _ in range(n):
            out.append(jax.random.normal(key, (2,)))
        return out
    """
    assert len(run_pass(prng, bad)) == 1


def test_prng_exclusive_branches_clean():
    good = """
    def build(key, kind):
        if kind == "a":
            return init_a(key)
        elif kind == "b":
            return init_b(key)
        return init_c(key)
    """
    assert run_pass(prng, good) == []


def test_prng_array_split_is_not_a_key():
    good = """
    def rotate(x):
        x1, x2 = jnp.split(x, 2, axis=-1)
        return f(x1, x2), g(x1, x2)
    """
    assert run_pass(prng, good) == []


# ---------------------------------------------------------- shard_axes ------

def test_shard_axes_literal_flagged():
    bad = 'spec = P("data", None)'
    fs = run_pass(shard_axes, bad)
    assert len(fs) == 1 and "'data'" in fs[0].message
    bad2 = 'total = jax.lax.psum(x, "model")'
    assert len(run_pass(shard_axes, bad2)) == 1


def test_shard_axes_constants_and_params_clean():
    good = """
    from repro.dist.sharding import DATA_AXIS, MODEL_AXIS
    spec = P(DATA_AXIS, None)
    table = P(MODEL_AXIS, None)
    def reduce(x, axis):
        return jax.lax.psum(x, axis)
    def specs(axis="data"):
        return P(axis, None)
    """
    assert run_pass(shard_axes, good) == []


# ------------------------------------------------------- static_shapes ------

def test_static_shapes_host_syncs_flagged():
    bad = """
    import jax, numpy as np

    @jax.jit
    def step(x):
        n = int(x.sum())
        h = np.asarray(x)
        jax.block_until_ready(x)
        return x.item()
    """
    fs = run_pass(static_shapes, bad)
    kinds = sorted(f.message.split(" ")[0] for f in fs)
    assert len(fs) == 4, kinds


def test_static_shapes_if_on_traced_param_flagged():
    bad = """
    @jax.jit
    def step(x):
        if x:
            return x + 1
        return x
    """
    fs = run_pass(static_shapes, bad)
    assert len(fs) == 1 and "lax.cond" in fs[0].message


def test_static_shapes_static_argnames_clean():
    good = """
    import functools, jax

    @functools.partial(jax.jit, static_argnames=("block",))
    def step(x, block):
        if block > 8:
            return x + 1
        return x
    """
    assert run_pass(static_shapes, good) == []


def test_static_shapes_device_ops_clean():
    good = """
    @jax.jit
    def step(x):
        y = jnp.asarray(x)
        return y.astype(jnp.float32)
    """
    assert run_pass(static_shapes, good) == []


def test_static_shapes_traced_helper_and_shard_map():
    bad = """
    def advance_slice(cfg, params, carries, frames):
        return np.asarray(frames)
    """
    assert len(run_pass(static_shapes, bad)) == 1
    bad2 = """
    def body(x):
        return x.item()
    stepped = shard_map(body, mesh=mesh, in_specs=s, out_specs=s)
    """
    assert len(run_pass(static_shapes, bad2)) == 1


# --------------------------------------------------------------- locks ------

def test_locks_unguarded_write_from_thread_target_flagged():
    bad = """
    import threading

    class Worker:
        def __init__(self):
            self._t = threading.Thread(target=self._work)

        def _work(self):
            self._val = 1
    """
    fs = run_pass(locks, bad)
    assert len(fs) == 1 and "self._val" in fs[0].message


def test_locks_held_lock_clean():
    good = """
    import threading

    class Worker:
        def __init__(self):
            self._mu = threading.Lock()
            self._t = threading.Thread(target=self._work)

        def _work(self):
            with self._mu:
                self._val = 1
    """
    assert run_pass(locks, good) == []


def test_locks_thread_owned_allowlist_clean():
    good = """
    import threading

    class Worker:
        _thread_owned = ("_err",)

        def __init__(self):
            self._t = threading.Thread(target=self._work)

        def _work(self):
            self._err = ValueError("x")
    """
    assert run_pass(locks, good) == []


def test_locks_closure_target_checked():
    bad = """
    import threading

    class Saver:
        def save(self):
            def write():
                self._busy = True
            threading.Thread(target=write).start()
    """
    assert len(run_pass(locks, bad)) == 1
    good = """
    import threading

    class Saver:
        def save(self):
            def write():
                data = pack()
                emit(data)
            threading.Thread(target=write).start()
    """
    assert run_pass(locks, good) == []


# -------------------------------------------------------------- pragmas -----

def test_pragma_same_line_and_comment_above():
    code = """
    a = init(jax.random.PRNGKey(0), cfg)  # dynlint: allow[prng]
    # deliberate registry fallback
    # dynlint: allow[prng]
    b = init(jax.random.PRNGKey(1), cfg)
    c = init(jax.random.PRNGKey(2), cfg)
    """
    fs = run_pass(prng, code)
    assert len(fs) == 1 and "PRNGKey(2)" in fs[0].message


def test_pragma_star_and_wrong_pass():
    code = """
    a = init(jax.random.PRNGKey(0), cfg)  # dynlint: allow[*]
    b = init(jax.random.PRNGKey(1), cfg)  # dynlint: allow[donation]
    """
    fs = run_pass(prng, code)
    assert len(fs) == 1 and fs[0].line == 3


# ------------------------------------------------------ CLI / repo gate -----

def test_cli_json_output_and_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text('spec = P("data", None)\n')
    rc = core.main([str(bad), "--format", "json"])
    out = capsys.readouterr().out
    import json
    findings = json.loads(out)
    assert rc == 1 and findings[0]["pass"] == "shard_axes"
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert core.main([str(good)]) == 0


def test_cli_select_subset(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text('spec = P("data", None)\n')
    assert core.main([str(bad), "--select", "prng"]) == 0
    assert core.main([str(bad), "--select", "shard_axes"]) == 1


# -------------------------------------------------------------- timing ------

def test_timing_flags_raw_clock_reads():
    bad = """
    import time

    def measure(fn):
        t0 = time.perf_counter()
        fn()
        return time.monotonic() - t0
    """
    fs = run_pass(timing, bad)
    assert len(fs) == 2
    assert "perf_counter" in fs[0].message and "repro.obs" in fs[0].message


def test_timing_flags_aliased_and_from_imports():
    bad = """
    import time as clock
    from time import perf_counter_ns as tick

    def f():
        return clock.perf_counter_ns() + tick()
    """
    fs = run_pass(timing, bad)
    assert len(fs) == 2


def test_timing_ignores_wall_clock_and_other_modules():
    good = """
    import time

    def stamp():
        return time.time()          # wall clock: provenance, not perf

    def nap():
        time.sleep(0.1)

    class T:
        def perf_counter(self):     # not the time module
            return 0
    t = T().perf_counter()
    """
    assert run_pass(timing, good) == []


def test_timing_exempts_obs_ft_and_out_of_src():
    code = """
    import time
    t0 = time.perf_counter()
    """
    assert run_pass(timing, code, path="src/repro/obs/trace.py") == []
    assert run_pass(timing, code, path="src/repro/ft/straggler.py") == []
    assert run_pass(timing, code, path="benchmarks/common.py") == []
    assert len(run_pass(timing, code, path="src/repro/stream/x.py")) == 1


def test_timing_pragma_allows():
    code = """
    import time
    t0 = time.perf_counter()  # dynlint: allow[timing]
    """
    assert run_pass(timing, code) == []


def test_repo_src_is_dynlint_clean():
    findings = core.run([str(REPO / "src")])
    assert findings == [], "\n".join(f.render() for f in findings)
