"""LM stack: attention equivalences, decode/prefill consistency, MoE
invariants, optimizer schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.nn import attention as attn
from repro.nn import moe as moelib
from repro.optim import adamw


def _cfg(**kw):
    base = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                head_dim=16, d_ff=128, vocab_size=512, dtype=jnp.float32)
    base.update(kw)
    return lm.LMConfig(**base)


def test_chunked_attention_equals_full():
    rng = np.random.default_rng(0)
    b, s, h, d = 2, 256, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    full = attn.causal_attention(q, k, v)
    chunked = attn.chunked_causal_attention(q, k, v, q_chunk=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5)


def test_gqa_repeat_matches_explicit():
    rng = np.random.default_rng(1)
    b, s, hq, kvh, d = 2, 32, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    got = attn.causal_attention(q, k, v)
    k_rep = jnp.repeat(k, hq // kvh, axis=2)
    v_rep = jnp.repeat(v, hq // kvh, axis=2)
    want = attn.causal_attention(q, k_rep, v_rep)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("moe", [False, True])
def test_decode_matches_training_forward(moe):
    """Greedy decode logits == training-forward logits position by position
    (the KV-cache correctness invariant)."""
    cfg = _cfg(moe_experts=8 if moe else 0, moe_top_k=2 if moe else 0,
               num_kv_heads=4, moe_capacity_factor=8.0)
    params = lm.init_lm_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 512, (2, 12)),
                       jnp.int32)
    logits_f, _ = lm.forward(cfg, params, toks)
    plog, cache = lm.prefill(cfg, params, toks[:, :6], max_len=16)
    np.testing.assert_allclose(np.asarray(plog), np.asarray(logits_f[:, 5]),
                               atol=2e-3)
    for t in range(6, 10):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_f[:, t]), atol=2e-3)


def test_unrolled_forward_matches_scan():
    """layer_unroll (the cost-extraction mode) must not change values."""
    cfg = _cfg()
    params = lm.init_lm_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(2).integers(0, 512, (2, 16)),
                       jnp.int32)
    l1, _ = lm.forward(cfg, params, toks)
    import dataclasses
    cfg_u = dataclasses.replace(cfg, layer_unroll=2, unroll_chunks=True)
    l2, _ = lm.forward(cfg_u, params, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_moe_capacity_and_dispatch():
    rng = np.random.default_rng(0)
    p = moelib.init_moe(jax.random.PRNGKey(0), 32, 64, 8, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)).astype(np.float32))
    out, aux = moelib.moe_apply(p, x, top_k=2, capacity_factor=8.0)
    assert out.shape == x.shape
    # generous capacity -> nothing dropped
    assert float(aux["dropped_frac"]) == 0.0
    assert float(aux["lb_loss"]) > 0
    # tight capacity -> some drops, output still finite
    out2, aux2 = moelib.moe_apply(p, x, top_k=2, capacity_factor=0.25)
    assert float(aux2["dropped_frac"]) > 0
    assert bool(jnp.isfinite(out2).all())


def test_moe_matches_dense_expert_sum():
    """With capacity ample, the sort-based dispatch equals the direct
    per-token expert computation."""
    rng = np.random.default_rng(3)
    e, d, f, topk = 4, 16, 32, 2
    p = moelib.init_moe(jax.random.PRNGKey(1), d, f, e, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 8, d)).astype(np.float32))
    out, _ = moelib.moe_apply(p, x, top_k=topk, capacity_factor=16.0)
    # direct reference
    tokens = x.reshape(-1, d)
    logits = tokens @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, topk)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(tokens)
    for t in range(tokens.shape[0]):
        acc = jnp.zeros((d,))
        for j in range(topk):
            ex = int(ei[t, j])
            h = tokens[t] @ p["wi_gate"][ex]
            u = tokens[t] @ p["wi_up"][ex]
            acc += gv[t, j] * ((jax.nn.silu(h) * u) @ p["wo"][ex])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, d)),
                               np.asarray(ref), atol=1e-4)


def test_wsd_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            schedule="wsd", stable_frac=0.6,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule_lr(cfg, jnp.asarray(s)))
           for s in range(0, 101, 5)]
    assert lrs[0] < lrs[2]                      # warmup
    assert abs(lrs[5] - 1.0) < 1e-6             # stable plateau
    assert lrs[-1] == pytest.approx(0.1, abs=1e-3)   # decayed to min


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, schedule="constant")
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = adamw.apply_updates(cfg, params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_embedding_bag_matches_manual():
    from repro.nn import embedding
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, (4, 6)), jnp.int32)
    mask = jnp.asarray((rng.random((4, 6)) > 0.3).astype(np.float32))
    for mode in ("sum", "mean", "max"):
        got = embedding.embedding_bag(table, ids, mask, mode)
        emb = np.asarray(table)[np.asarray(ids)]
        m = np.asarray(mask)[..., None]
        if mode == "sum":
            want = (emb * m).sum(1)
        elif mode == "mean":
            want = (emb * m).sum(1) / np.maximum(m.sum(1), 1.0)
        else:
            want = np.where(m > 0, emb, -np.inf).max(1)
            want = np.where(np.isinf(want), 0.0, want)
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)
