"""End-to-end behaviour tests for the paper's system: full training runs
with convergence, checkpoint-resume, sampler integration, and
link-prediction evaluation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import models
from repro.data.dyngnn import DTDGPipeline, synthetic_dataset
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.train import trainer


def _pipe(model="tmgcn", n=64, t=16, nb=2):
    smoothing_mode = {"tmgcn": "mproduct", "evolvegcn": "edgelife",
                      "cdgcn": "none"}[model]
    ds = synthetic_dataset(n, t, density=2.0, churn=0.1,
                           smoothing_mode=smoothing_mode, window=3, seed=0)
    return ds, DTDGPipeline(ds, nb=nb)


@pytest.mark.parametrize("model", ["tmgcn", "cdgcn", "evolvegcn"])
def test_training_reduces_loss_single_device(model):
    ds, pipe = _pipe(model)
    cfg = models.DynGNNConfig(model=model, num_nodes=64, num_steps=16,
                              window=3, checkpoint_blocks=2)
    from repro.optim import adamw
    opt = adamw.AdamWConfig(lr=3e-2, warmup_steps=5, total_steps=60,
                            weight_decay=0.0)
    state, losses = trainer.train_dyngnn(cfg, pipe, mesh=None, num_steps=60,
                                         opt_cfg=opt, log_fn=lambda *_: None)
    assert losses[-1] < losses[0] - 0.05, losses[:3] + losses[-3:]


def test_training_distributed_matches_single(tmp_path):
    """Same seed, same data: distributed loss curve == single-device curve
    (paper Fig. 6, as an exact test)."""
    ds, pipe = _pipe("tmgcn")
    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=64, num_steps=16,
                              window=3, checkpoint_blocks=2)
    mesh = make_host_mesh(data=4, model=1)
    _, losses_sp = trainer.train_dyngnn(cfg, pipe, mesh=mesh, num_steps=10,
                                        log_fn=lambda *_: None)
    _, losses_1d = trainer.train_dyngnn(cfg, pipe, mesh=None, num_steps=10,
                                        log_fn=lambda *_: None)
    np.testing.assert_allclose(losses_sp, losses_1d, atol=1e-4)


def test_checkpoint_resume(tmp_path):
    ds, pipe = _pipe("cdgcn")
    cfg = models.DynGNNConfig(model="cdgcn", num_nodes=64, num_steps=16,
                              window=3, checkpoint_blocks=2)
    d = str(tmp_path / "ck")
    state1, _ = trainer.train_dyngnn(cfg, pipe, num_steps=10, ckpt_dir=d,
                                     ckpt_every=5, log_fn=lambda *_: None)
    # "crash" and resume: a fresh call picks up at step 10
    state2, losses2 = trainer.train_dyngnn(cfg, pipe, num_steps=15,
                                           ckpt_dir=d, ckpt_every=5,
                                           log_fn=lambda *_: None)
    assert state2.step == 15
    assert len(losses2) == 5   # only steps 10..14 re-run


def test_link_prediction_evaluation():
    ds, pipe = _pipe("tmgcn", n=64, t=16)
    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=64, num_steps=16,
                              window=3, checkpoint_blocks=2)
    state, _ = trainer.train_dyngnn(cfg, pipe, num_steps=20,
                                    log_fn=lambda *_: None)
    test_snap = ds.snapshots[-1]
    acc = trainer.evaluate_link_prediction(cfg, state.params, pipe,
                                           test_snap)
    assert 0.0 <= acc <= 1.0


def test_neighbor_sampler_produces_valid_subgraphs():
    from repro.graph import generate
    from repro.graph.sampler import CSRGraph, sample_neighbors, flat_edges
    rng = np.random.default_rng(0)
    n = 500
    edges = generate.random_static_graph(n, 5000, seed=0)
    g = CSRGraph.from_edges(edges, n)
    seeds = rng.choice(n, 32, replace=False)
    sub = sample_neighbors(g, seeds, fanouts=[5, 3],
                           rng=np.random.default_rng(1))
    assert sub.num_seeds == 32
    e, m = flat_edges(sub)
    valid = e[m > 0]
    # all local ids within the sampled node table
    n_valid = int(sub.node_mask.sum())
    assert valid.max() < n_valid
    # every sampled edge exists in the original graph (global ids)
    gsrc = sub.node_ids[valid[:, 0]]
    gdst = sub.node_ids[valid[:, 1]]
    edge_set = set(map(tuple, edges.tolist()))
    assert all((int(s), int(d)) in edge_set for s, d in zip(gsrc, gdst))
    # fanout bound respected
    assert valid.shape[0] <= 32 * 5 + 32 * 5 * 3


def test_dtdg_pipeline_transfer_accounting():
    ds, pipe = _pipe("tmgcn")
    rep = pipe.transfer_bytes()
    assert 0 < rep["graph_diff"] < rep["naive"]


def test_grad_compression_trains():
    """int8 error-feedback DP aggregation still converges (EvolveGCN's only
    communication path, §5.5 + compression)."""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.dist import compression
    mesh = make_host_mesh(data=4, model=1)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    w_true = jnp.asarray([[1.0], [-2.0], [0.5], [3.0]])
    y = x @ w_true

    def local_step(w, res, xb, yb):
        g = jax.grad(lambda w_: jnp.mean((xb @ w_ - yb) ** 2))(w)
        red, res = compression.compressed_psum({"w": g}, "data", {"w": res})
        return w - 0.1 * red["w"], res["w"]

    fn = jax.jit(shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P(), P("data", None), P("data", None)),
        out_specs=(P(), P()), check_vma=False))
    w = jnp.zeros((4, 1))
    res = jnp.zeros((4, 1))
    for _ in range(150):
        w, res = fn(w, res, x, y)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_true), atol=0.1)
