"""Golden tests for the declarative ``repro.run`` Engine API.

``Engine.fit()`` must reproduce the legacy entrypoints' loss streams
BIT-FOR-BIT on every schedule (eager, streamed, streamed_mesh): the
Engine is plumbing, never math.  Plus: seed plumbing, the plan's
auto-pad / re-block behavior, the ``EdgeListDTDG`` file round-trip, the
deprecation contract of the shims, and checkpoint resume."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.models import DynGNNConfig
from repro.data.dyngnn import (DTDGPipeline, dataset_from_snapshots,
                               synthetic_dataset)
from repro.graph import generate
from repro.optim import adamw
from repro.run import (CheckpointSpec, Engine, EdgeListDTDG, ExecutionPlan,
                       InMemoryDTDG, RunConfig, SyntheticTrace,
                       read_edgelist, write_edgelist)
from repro.train import trainer

N, T = 48, 16


def _silent(_msg):
    return None


def _cfg(model="tmgcn", n=N, t=T, nb=2):
    return DynGNNConfig(model=model, num_nodes=n, num_steps=t, window=3,
                        checkpoint_blocks=nb)


def _src(model="tmgcn", n=N, t=T):
    smooth = {"tmgcn": "mproduct", "evolvegcn": "edgelife",
              "cdgcn": "none"}[model]
    return SyntheticTrace(num_nodes=n, num_steps=t, density=2.0, churn=0.1,
                          smoothing_mode=smooth, window=3)


def _engine(cfg, data, plan, **kw):
    kw.setdefault("log_fn", _silent)
    return Engine(RunConfig(model=cfg, data=data, plan=plan, **kw))


# ------------------------------------------------ golden equivalence -------

def test_eager_single_device_matches_manual_loop():
    """Engine eager (1 device) == a hand-rolled loop over the legacy step
    factory with the legacy defaults (PRNGKey(0), default AdamW)."""
    cfg = _cfg()
    ds = _src().build()
    num_steps = 12
    got = _engine(cfg, InMemoryDTDG(ds),
                  ExecutionPlan(mode="eager", num_steps=num_steps)).fit()

    pipe = DTDGPipeline(ds, nb=cfg.checkpoint_blocks)
    opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=10,
                                total_steps=num_steps, weight_decay=0.0)
    params = trainer.dyn_models.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init_state(params)
    step_fn = trainer.make_single_device_train_step(cfg, opt_cfg)
    lab = jnp.asarray(ds.labels)
    want = []
    for _ in range(num_steps):
        params, opt_state, loss = step_fn(params, opt_state, pipe.batch,
                                          lab)
        want.append(float(loss))
    assert got.losses == want
    assert got.state.step == num_steps


@pytest.mark.parametrize("model", ["tmgcn", "cdgcn", "evolvegcn"])
def test_streamed_matches_train_streamed(model):
    """Engine streamed == the stream loop called the way the legacy shim
    called it (pipeline-derived block size / stats / max_edges)."""
    from repro.stream import train_loop as stream_train
    cfg = _cfg(model, t=8)
    ds = _src(model, t=8).build()
    pipe = DTDGPipeline(ds, nb=cfg.checkpoint_blocks)
    got = _engine(cfg, InMemoryDTDG(ds, pipeline=pipe),
                  ExecutionPlan(mode="streamed", num_epochs=2)).fit()
    ref = stream_train.train_streamed(
        cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
        np.asarray(ds.labels), block_size=pipe.bsize, num_epochs=2,
        stats=pipe.stream_stats, max_edges=pipe.max_edges)
    assert got.losses == ref.losses
    for a, b in zip(jax.tree.leaves(got.state.params),
                    jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert got.stream_report is not None
    assert got.transfer_report["graph_diff"] > 0


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_streamed_mesh_matches_distributed_loop():
    """Engine streamed_mesh == train_distributed_streamed on the same
    trace, and overlap stays a pure schedule change through the Engine."""
    from repro.launch.mesh import make_host_mesh
    from repro.stream import distributed as dist
    cfg = _cfg()
    ds = _src().build()
    pipe = DTDGPipeline(ds, nb=cfg.checkpoint_blocks)
    got = _engine(cfg, InMemoryDTDG(ds, pipeline=pipe),
                  ExecutionPlan(mode="streamed_mesh", shards=4,
                                num_epochs=2)).fit()
    ref = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
        np.asarray(ds.labels), mesh=make_host_mesh(data=4, model=1),
        num_epochs=2, stats=pipe.stream_stats, max_edges=pipe.max_edges)
    assert got.losses == ref.losses
    assert got.per_shard_bytes == ref.per_shard_bytes
    for a, b in zip(jax.tree.leaves(got.state.params),
                    jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    sync = _engine(cfg, InMemoryDTDG(ds, pipeline=pipe),
                   ExecutionPlan(mode="streamed_mesh", shards=4,
                                 num_epochs=2, overlap=False)).fit()
    assert sync.losses == got.losses


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_eager_mesh_matches_legacy_shim():
    """The deprecated entrypoint and the Engine agree under a mesh (the
    shim IS a RunConfig constructor — this pins its plumbing)."""
    from repro.launch.mesh import make_host_mesh
    cfg = _cfg()
    ds = _src().build()
    pipe = DTDGPipeline(ds, nb=cfg.checkpoint_blocks)
    mesh = make_host_mesh(data=4, model=1)
    with pytest.warns(DeprecationWarning, match="train_dyngnn"):
        state, losses = trainer.train_dyngnn(cfg, pipe, mesh=mesh,
                                             num_steps=6,
                                             log_fn=_silent)
    got = _engine(cfg, InMemoryDTDG(ds, pipeline=pipe),
                  ExecutionPlan(mode="eager", mesh=mesh,
                                num_steps=6)).fit()
    assert got.losses == losses
    assert got.state.step == state.step


def test_legacy_streamed_shim_warns_and_matches():
    cfg = _cfg(t=8)
    ds = _src(t=8).build()
    pipe = DTDGPipeline(ds, nb=cfg.checkpoint_blocks)
    with pytest.warns(DeprecationWarning, match="train_dyngnn_streamed"):
        state, losses = trainer.train_dyngnn_streamed(cfg, pipe,
                                                      log_fn=_silent)
    got = _engine(cfg, InMemoryDTDG(ds, pipeline=pipe),
                  ExecutionPlan(mode="streamed")).fit()
    assert got.losses == losses
    assert isinstance(losses, list) and isinstance(state.step, int)


# ------------------------------------------------------ seed / plan --------

def test_seed_is_plumbed():
    """RunConfig.seed reaches param init (no more hard-coded PRNGKey(0))."""
    cfg = _cfg(t=8)
    ds = _src(t=8).build()
    runs = {}
    for seed in (0, 1):
        runs[seed] = _engine(cfg, InMemoryDTDG(ds),
                             ExecutionPlan(mode="eager", num_steps=4),
                             seed=seed).fit()
    assert runs[0].losses != runs[1].losses
    # seed=0 reproduces the legacy PRNGKey(0) stream
    again = _engine(cfg, InMemoryDTDG(ds),
                    ExecutionPlan(mode="eager", num_steps=4), seed=0).fit()
    assert again.losses == runs[0].losses


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_plan_auto_pads_num_nodes_and_logs():
    """50 nodes over 4 shards: the plan pads to 52 instead of dying."""
    msgs = []
    cfg = _cfg(n=50)
    eng = _engine(cfg, _src(n=50),
                  ExecutionPlan(mode="streamed_mesh", shards=4),
                  log_fn=msgs.append)
    rr = eng.resolve()
    assert rr.cfg.num_nodes == 52
    assert rr.padded_from == 50
    assert any("auto-padding num_nodes 50 -> 52" in m for m in msgs)
    res = eng.fit()
    assert len(res.losses) == T // rr.pipeline.bsize
    assert np.isfinite(res.losses).all()


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_plan_reblocks_timeline_for_mesh():
    """nb=8 gives block size 2, not divisible over 4 shards: the plan
    re-blocks (largest legal block <= requested) instead of raising."""
    msgs = []
    cfg = _cfg(nb=8)
    eng = _engine(cfg, _src(), ExecutionPlan(mode="streamed_mesh",
                                             shards=4),
                  log_fn=msgs.append)
    rr = eng.resolve()
    assert rr.cfg.checkpoint_blocks == 4          # bsize 4 == P
    assert rr.pipeline.bsize % 4 == 0
    assert any("re-blocking" in m for m in msgs)
    res = eng.fit()
    assert len(res.losses) == 4


def test_plan_validation():
    with pytest.raises(ValueError, match="plan.mode"):
        ExecutionPlan(mode="magic").validate()
    with pytest.raises(ValueError, match="single-device"):
        ExecutionPlan(mode="streamed", shards=4).validate()
    with pytest.raises(ValueError, match="a2a_chunks must be"):
        ExecutionPlan(a2a_chunks=0).validate()
    with pytest.raises(ValueError, match="a2a_chunks"):
        ExecutionPlan(mode="streamed", a2a_chunks=2).validate()
    # meshless eager has no all-to-alls either: chunking must fail
    # loudly, not silently no-op (RunResult echoes the knob as executed)
    with pytest.raises(ValueError, match="without a mesh"):
        ExecutionPlan(mode="eager", shards=1, a2a_chunks=2).validate()
    with pytest.raises(ValueError, match="pipeline_rounds"):
        ExecutionPlan(mode="eager", pipeline_rounds=True).validate()
    # mesh schedules accept both knobs
    ExecutionPlan(mode="streamed_mesh", shards=4, a2a_chunks=4,
                  pipeline_rounds=True).validate()
    ExecutionPlan(mode="eager", shards=4, a2a_chunks=2).validate()


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_streamed_mesh_pipelined_matches_serial_through_engine():
    """The acceptance bar of the chunked-round pipeline: a2a_chunks=4 +
    pipeline_rounds=True on the 8-device host mesh reproduces the serial
    plan's loss stream at <= 1e-5 relative, and the RunResult echoes the
    knobs it ran with."""
    cfg = _cfg()
    ds = _src().build()
    pipe = DTDGPipeline(ds, nb=cfg.checkpoint_blocks)
    serial = _engine(cfg, InMemoryDTDG(ds, pipeline=pipe),
                     ExecutionPlan(mode="streamed_mesh", shards=8,
                                   num_epochs=2)).fit()
    piped = _engine(cfg, InMemoryDTDG(ds, pipeline=pipe),
                    ExecutionPlan(mode="streamed_mesh", shards=8,
                                  num_epochs=2, a2a_chunks=4,
                                  pipeline_rounds=True)).fit()
    assert len(piped.losses) == len(serial.losses)
    np.testing.assert_allclose(piped.losses, serial.losses, rtol=1e-5)
    assert piped.a2a_chunks == 4 and piped.pipeline_rounds is True
    assert serial.a2a_chunks == 1 and serial.pipeline_rounds is False
    assert piped.per_shard_bytes == serial.per_shard_bytes


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_eager_mesh_chunked_a2a_matches_serial():
    """a2a_chunks also threads through the eager shard_map schedule
    (snapshot_partition_loss) without changing the loss stream."""
    cfg = _cfg()
    ds = _src().build()
    plain = _engine(cfg, InMemoryDTDG(ds),
                    ExecutionPlan(mode="eager", shards=4,
                                  num_steps=6)).fit()
    chunked = _engine(cfg, InMemoryDTDG(ds),
                      ExecutionPlan(mode="eager", shards=4, num_steps=6,
                                    a2a_chunks=2)).fit()
    np.testing.assert_allclose(chunked.losses, plain.losses, rtol=1e-5)
    assert chunked.a2a_chunks == 2


# ------------------------------------------------ edge-list round-trip -----

@pytest.mark.parametrize("ext", ["tsv", "npz"])
def test_edgelist_roundtrip_matches_in_memory(tmp_path, ext):
    """write trace -> load -> identical dataset AND identical losses."""
    snaps = generate.evolving_dynamic_graph(N, 8, density=2.0, churn=0.2,
                                            seed=3)
    path = tmp_path / f"trace.{ext}"
    write_edgelist(path, snaps)
    loaded_snaps, n_seen = read_edgelist(path)
    assert len(loaded_snaps) == len(snaps)
    for a, b in zip(loaded_snaps, snaps):
        assert np.array_equal(a, b)
    assert n_seen <= N

    mem = dataset_from_snapshots(snaps, N, smoothing_mode="mproduct",
                                 window=3)
    src = EdgeListDTDG(str(path), num_nodes=N, smoothing_mode="mproduct",
                       window=3)
    loaded = src.build()
    assert loaded.num_nodes == mem.num_nodes
    for a, b in zip(loaded.snapshots, mem.snapshots):
        assert np.array_equal(a, b)
    np.testing.assert_array_equal(loaded.frames, mem.frames)
    np.testing.assert_array_equal(loaded.labels, mem.labels)

    cfg = _cfg(t=8)
    plan = ExecutionPlan(mode="streamed")
    from_file = _engine(cfg, src, plan).fit()
    from_mem = _engine(cfg, InMemoryDTDG(mem), plan).fit()
    assert from_file.losses == from_mem.losses


@pytest.mark.parametrize("ext", ["tsv", "npz"])
def test_edgelist_preserves_empty_boundary_snapshots(tmp_path, ext):
    """The num_steps marker keeps empty leading/trailing snapshots, so
    write -> load never silently shortens the trace."""
    core = generate.evolving_dynamic_graph(16, 4, density=2.0, seed=1)
    empty = np.zeros((0, 2), dtype=np.int32)
    snaps = [empty] + core + [empty]
    path = tmp_path / f"trace.{ext}"
    write_edgelist(path, snaps)
    loaded, _ = read_edgelist(path)
    assert len(loaded) == len(snaps) == 6
    for a, b in zip(loaded, snaps):
        assert np.array_equal(a, b)


def test_synthetic_trace_padding_pads_not_regenerates():
    """A num_nodes override appends isolated vertices to the NOMINAL
    trace — same graph, same labels — never a new random graph."""
    src = _src(n=50)
    nominal = src.build()
    padded = src.build(num_nodes=52)
    assert padded.num_nodes == 52
    for a, b in zip(padded.snapshots, nominal.snapshots):
        assert np.array_equal(a, b)
    np.testing.assert_array_equal(padded.frames[:, :50], nominal.frames)
    np.testing.assert_array_equal(padded.labels[:, :50], nominal.labels)
    assert not padded.frames[:, 50:].any()
    with pytest.raises(ValueError, match="shrink"):
        src.build(num_nodes=40)


def test_edgelist_padding_keeps_real_labels(tmp_path):
    """Padding an edge-list source appends isolated vertices AFTER label
    derivation — pad nodes can never shift the real nodes' label median."""
    snaps = generate.evolving_dynamic_graph(30, 4, density=2.0, seed=5)
    p = tmp_path / "t.tsv"
    write_edgelist(p, snaps)
    src = EdgeListDTDG(str(p), num_nodes=30)
    base = src.build()
    padded = src.build(num_nodes=32)
    assert padded.num_nodes == 32
    np.testing.assert_array_equal(padded.labels[:, :30], base.labels)
    np.testing.assert_array_equal(padded.frames[:, :30], base.frames)
    assert not padded.frames[:, 30:].any()


def test_checkpoint_rejected_on_streamed_plans():
    """No silent checkpoint drops: a CheckpointSpec on a streamed plan
    fails loudly at resolve time."""
    cfg = _cfg(t=8)
    eng = _engine(cfg, _src(t=8), ExecutionPlan(mode="streamed"),
                  checkpoint=CheckpointSpec("/tmp/never-used"))
    with pytest.raises(ValueError, match="only wired for plan.mode"):
        eng.resolve()


def test_edgelist_rejects_bad_shapes(tmp_path):
    p = tmp_path / "bad.tsv"
    p.write_text("# src dst\n0\t1\n2\t3\n")
    with pytest.raises(ValueError, match="columns"):
        read_edgelist(p)
    with pytest.raises(ValueError, match="node ids up to"):
        snaps = [np.array([[0, 5]], dtype=np.int32)]
        q = tmp_path / "big.tsv"
        write_edgelist(q, snaps)
        EdgeListDTDG(str(q), num_nodes=3).build()


# --------------------------------------------------- resume / evaluate -----

def test_engine_resume_roundtrip(tmp_path):
    cfg = _cfg(model="cdgcn")
    data = _src("cdgcn")
    ck = CheckpointSpec(str(tmp_path / "ck"), every=5)
    first = _engine(cfg, data, ExecutionPlan(mode="eager", num_steps=10),
                    checkpoint=ck).fit()
    assert first.state.step == 10
    eng2 = _engine(cfg, data, ExecutionPlan(mode="eager", num_steps=15),
                   checkpoint=ck)
    res = eng2.resume()
    assert res.state.step == 15
    assert len(res.losses) == 5               # only steps 10..14 re-run

    with pytest.raises(ValueError, match="RunConfig.checkpoint"):
        _engine(cfg, data, ExecutionPlan(mode="eager", num_steps=5)
                ).resume()
    with pytest.raises(FileNotFoundError):
        _engine(cfg, data, ExecutionPlan(mode="eager", num_steps=5),
                checkpoint=CheckpointSpec(str(tmp_path / "empty"))
                ).resume()


def test_engine_evaluate_needs_fit_or_state():
    cfg = _cfg(t=8)
    eng = _engine(cfg, _src(t=8), ExecutionPlan(mode="eager", num_steps=2))
    with pytest.raises(ValueError, match="before fit"):
        eng.evaluate()
    res = eng.fit()
    acc = eng.evaluate(res)
    assert 0.0 <= acc <= 1.0


# ----------------------------------------- out-of-core edge-list read ------

@pytest.mark.parametrize("ext", ["tsv", "npz"])
def test_edgelist_out_of_core_matches_in_memory(tmp_path, ext):
    """chunk_edges (chunked text scan / zip-member memmap) bins the
    SAME snapshots as the monolithic read, at every chunk size."""
    snaps = generate.evolving_dynamic_graph(N, 8, density=2.0, churn=0.2,
                                            seed=5)
    snaps[2] = np.zeros((0, 2), dtype=np.int32)     # empty mid-trace bin
    path = tmp_path / f"trace.{ext}"
    write_edgelist(path, snaps)
    ref, n_ref = read_edgelist(path)
    for chunk in (1, 13, 10_000):
        got, n = read_edgelist(path, chunk_edges=chunk)
        assert n == n_ref
        assert len(got) == len(ref)
        for a, b in zip(got, ref):
            assert np.array_equal(a, b)


def test_edgelist_out_of_core_npz_is_memmapped(tmp_path):
    """Uncompressed npz members really are mapped, not loaded — and a
    deflated archive falls back to the regular load, same snapshots."""
    from repro.run.data import _npz_memmaps

    snaps = generate.evolving_dynamic_graph(24, 4, density=2.0, seed=2)
    p = tmp_path / "trace.npz"
    write_edgelist(p, snaps)
    mm = _npz_memmaps(p)
    assert mm is not None
    assert isinstance(mm["src"], np.memmap)
    assert np.array_equal(np.asarray(mm["src"]),
                          np.concatenate([s[:, 0] for s in snaps]))
    src = np.concatenate([s[:, 0] for s in snaps]).astype(np.int64)
    dst = np.concatenate([s[:, 1] for s in snaps]).astype(np.int64)
    t = np.concatenate([np.full(s.shape[0], i, np.int64)
                        for i, s in enumerate(snaps)])
    pc = tmp_path / "comp.npz"
    np.savez_compressed(pc, src=src, dst=dst, t=t, num_steps=np.int64(4))
    assert _npz_memmaps(pc) is None         # deflated: nothing to map
    got, _ = read_edgelist(pc, chunk_edges=7)
    ref, _ = read_edgelist(pc)
    for a, b in zip(got, ref):
        assert np.array_equal(a, b)


def test_edgelist_source_out_of_core_trains_identically(tmp_path):
    """EdgeListDTDG(chunk_edges=...) builds the same dataset, so the
    same run produces the same losses."""
    snaps = generate.evolving_dynamic_graph(N, 8, density=2.0, seed=7)
    path = tmp_path / "trace.tsv"
    write_edgelist(path, snaps)
    cfg = _cfg(model="cdgcn", t=8)
    plan = ExecutionPlan(mode="streamed")
    a = _engine(cfg, EdgeListDTDG(str(path), num_nodes=N,
                                  smoothing_mode="none"), plan).fit()
    b = _engine(cfg, EdgeListDTDG(str(path), num_nodes=N,
                                  smoothing_mode="none",
                                  chunk_edges=16), plan).fit()
    assert a.losses == b.losses


# -------------------------------------------------- fetch_data + fixture ---

def test_fetch_data_fixture_pipeline(tmp_path):
    """The committed fixture is byte-reproducible from the tool's
    deterministic sample through the same preprocessing path the real
    fetch uses, and loads through EdgeListDTDG."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from tools import fetch_data as fd

    raw = tmp_path / "out.epinions-sample"
    fd.make_sample(raw)
    out = tmp_path / "epinions_tiny.tsv"
    fd.make_fixture(raw, out, num_nodes=24, num_steps=8)
    committed = Path(__file__).parent / "fixtures" / "epinions_tiny.tsv"
    assert out.read_text() == committed.read_text()
    ds = EdgeListDTDG(str(committed)).build()
    assert ds.num_nodes == 24 and ds.num_steps == 8
    ds_ooc = EdgeListDTDG(str(committed), chunk_edges=8).build()
    for a, b in zip(ds.snapshots, ds_ooc.snapshots):
        assert np.array_equal(a, b)


def test_fetch_data_preprocess_and_checksum(tmp_path):
    """preprocess bins KONECT rows into a loadable trace; the checksum
    layer records on first sight and refuses a tampered file."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from tools import fetch_data as fd

    raw = tmp_path / "out.sample"
    fd.make_sample(raw, num_nodes=40, num_edges=200, seed=11)
    out = tmp_path / "trace.tsv"
    fd.preprocess(raw, out, num_steps=6)
    snaps, n = read_edgelist(out)
    assert len(snaps) == 6
    assert sum(s.shape[0] for s in snaps) == 200
    assert n <= 40

    # trust-on-first-use sidecar, then verification
    digest = fd.verify_checksum(raw, None, None)
    sidecar = raw.with_suffix(raw.suffix + ".sha256")
    assert sidecar.exists() and digest in sidecar.read_text()
    assert fd.verify_checksum(raw, None, None) == digest
    with open(raw, "a") as f:
        f.write("9 9 1 9\n")
    with pytest.raises(SystemExit, match="checksum mismatch"):
        fd.verify_checksum(raw, None, None)
    with pytest.raises(SystemExit, match="checksum mismatch"):
        fd.verify_checksum(raw, digest, None)


def test_fetch_data_sub_slice_deterministic():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from tools import fetch_data as fd

    rng = np.random.default_rng(0)
    src = rng.integers(1, 100, 500)
    dst = rng.integers(1, 100, 500)
    ts = rng.integers(0, 1000, 500)
    a = fd.sub_slice(src, dst, ts, 20)
    b = fd.sub_slice(src, dst, ts, 20)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    kept = np.unique(np.concatenate([a[0], a[1]]))
    assert kept.shape[0] <= 20
    # kept ids are the first 20 distinct ids in file order
    seen = []
    for s, d in zip(src, dst):
        for v in (s, d):
            if v not in seen:
                seen.append(v)
        if len(seen) >= 20:
            break
    assert set(kept) <= set(seen[:21])
