"""Per-architecture smoke tests (deliverable f): instantiate a REDUCED
config of each assigned arch and run one forward/train step on CPU,
asserting output shapes and no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry

registry.load_all()

LM_ARCHS = ["yi-6b", "gemma-7b", "minicpm-2b", "olmoe-1b-7b",
            "moonshot-v1-16b-a3b"]
GNN_ARCHS = ["gatedgcn", "pna", "schnet", "equiformer-v2"]
DYN_ARCHS = ["tmgcn", "cdgcn", "evolvegcn"]


def _finite(tree) -> bool:
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype") and jnp.issubdtype(x.dtype,
                                                         jnp.floating))


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_train_step(arch_id):
    from repro.models import lm
    from repro.optim import adamw
    cfg = registry.get_arch(arch_id).make_smoke_config()
    params = lm.init_lm_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 32)), dtype=jnp.int32)
    loss, grads = jax.value_and_grad(
        lambda p: lm.lm_loss(cfg, p, toks, toks))(params)
    params2, opt2 = adamw.apply_updates(adamw.AdamWConfig(), params, grads,
                                        opt)
    assert jnp.isfinite(loss)
    assert _finite(params2)


@pytest.mark.parametrize("arch_id", LM_ARCHS[:2])
def test_lm_smoke_decode(arch_id):
    from repro.models import lm
    cfg = registry.get_arch(arch_id).make_smoke_config()
    params = lm.init_lm_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)), dtype=jnp.int32)
    logits, cache = lm.prefill(cfg, params, toks, max_len=16)
    assert logits.shape == (2, cfg.padded_vocab)
    lg2, cache = lm.decode_step(cfg, params, cache, toks[:, 0])
    assert lg2.shape == (2, cfg.padded_vocab)
    assert not bool(jnp.isnan(lg2).any())


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_smoke_train_step(arch_id):
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=2, model=1)
    cell = steps.build_cell(
        arch_id, "molecule", mesh, smoke=True,
        shape_override={"n_nodes": 8, "n_edges": 16, "batch": 4,
                        "d_feat": 6, "num_classes": 2})
    rng = np.random.default_rng(0)
    a_p, a_opt, a_e, a_em, a_f, a_pos, a_lab, a_nm, a_gid = \
        cell.abstract_inputs

    def rnd(a, scale=0.2):
        return jnp.asarray(rng.normal(0, scale, a.shape).astype(np.float32))

    args = (
        jax.tree.map(rnd, a_p),
        jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), a_opt),
        jnp.asarray(rng.integers(0, 8, a_e.shape), jnp.int32),  # edges
        jnp.ones(a_em.shape, jnp.float32),                      # edge mask
        rnd(a_f, 1.0),                                          # features
        jnp.asarray(rng.uniform(0, 5, a_pos.shape), jnp.float32),
        jnp.asarray(rng.integers(0, 2, a_lab.shape), jnp.int32),
        jnp.ones(a_nm.shape, jnp.float32),                      # node mask
        jnp.asarray(np.tile(np.repeat(np.arange(a_gid.shape[1] // 8), 8),
                            (a_gid.shape[0], 1)), jnp.int32),
    )
    with mesh:
        out = jax.jit(cell.step, in_shardings=cell.in_shardings,
                      out_shardings=cell.out_shardings)(*args)
    params_new, opt_new, loss = out
    assert jnp.isfinite(loss)
    assert _finite(params_new)


@pytest.mark.parametrize("arch_id", DYN_ARCHS)
def test_dyngnn_smoke_train_step(arch_id):
    from repro.core import checkpoint as ckpt_exec
    from repro.core import models as dyn_models
    from repro.data.dyngnn import synthetic_dataset, DTDGPipeline
    cfg = registry.get_arch(arch_id).make_smoke_config()
    ds = synthetic_dataset(cfg.num_nodes, cfg.num_steps, density=2.0)
    pipe = DTDGPipeline(ds, nb=cfg.checkpoint_blocks)
    params = dyn_models.init_params(jax.random.PRNGKey(0), cfg)
    labels = jnp.asarray(ds.labels)
    loss, grads = jax.value_and_grad(
        lambda p: ckpt_exec.blocked_node_loss(cfg, p, pipe.batch, labels))(
        params)
    assert jnp.isfinite(loss)
    assert _finite(grads)


def test_din_smoke_train_and_retrieval():
    from repro.models import din as din_mod
    cfg = registry.get_arch("din").make_smoke_config()
    params = din_mod.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b = 16
    batch = {
        "user_id": jnp.asarray(rng.integers(0, cfg.user_vocab, (b,)),
                               jnp.int32),
        "hist_items": jnp.asarray(rng.integers(0, cfg.item_vocab,
                                               (b, cfg.seq_len)), jnp.int32),
        "hist_cates": jnp.asarray(rng.integers(0, cfg.cate_vocab,
                                               (b, cfg.seq_len)), jnp.int32),
        "hist_mask": jnp.ones((b, cfg.seq_len), jnp.float32),
        "target_item": jnp.asarray(rng.integers(0, cfg.item_vocab, (b,)),
                                   jnp.int32),
        "target_cate": jnp.asarray(rng.integers(0, cfg.cate_vocab, (b,)),
                                   jnp.int32),
    }
    labels = jnp.asarray(rng.integers(0, 2, (b,)), jnp.int32)
    loss, grads = jax.value_and_grad(
        lambda p: din_mod.ctr_loss(p, batch, labels))(params)
    assert jnp.isfinite(loss)
    # retrieval path: 1 user x N candidates
    one = {k: v[:1] for k, v in batch.items()}
    scores = din_mod.score_candidates(
        params, one,
        jnp.asarray(rng.integers(0, cfg.item_vocab, (64,)), jnp.int32),
        jnp.asarray(rng.integers(0, cfg.cate_vocab, (64,)), jnp.int32))
    assert scores.shape == (64,)
    assert bool(jnp.all((scores >= 0) & (scores <= 1)))


def test_all_archs_registered():
    archs = registry.all_archs()
    for a in LM_ARCHS + GNN_ARCHS + DYN_ARCHS + ["din"]:
        assert a in archs
    # 10 assigned archs x 4 shapes = 40 cells
    assigned = LM_ARCHS + GNN_ARCHS + ["din"]
    cells = [(a, s) for a in assigned for s in archs[a].shapes]
    assert len(cells) == 40


def test_param_counts_match_scale():
    """Config sanity: full configs land near their nameplate sizes."""
    from repro.configs import registry as reg
    yi = reg.get_arch("yi-6b").make_config()
    assert 5.5e9 < yi.param_count() < 6.6e9
    gemma = reg.get_arch("gemma-7b").make_config()
    assert 7.5e9 < gemma.param_count() < 9.8e9   # 8.5B w/ untied head
    minicpm = reg.get_arch("minicpm-2b").make_config()
    assert 2.2e9 < minicpm.param_count() < 3.3e9
    olmoe = reg.get_arch("olmoe-1b-7b").make_config()
    assert 6.0e9 < olmoe.param_count() < 7.5e9
    assert 0.9e9 < olmoe.active_param_count() < 1.6e9
    # assigned config is 48L x 64 experts (larger than the 16B nameplate)
    moon = reg.get_arch("moonshot-v1-16b-a3b").make_config()
    assert 24e9 < moon.param_count() < 30e9
    assert 3.5e9 < moon.active_param_count() < 6e9
