"""Tests for the host-side layered neighbor sampler
(``repro.graph.sampler``): seed determinism, padded-lane mask
invariants, local/global node-table consistency, CSR edge-position
tracking, and a statistical inclusion-probability check for the uniform
fanout draw."""

import numpy as np
import pytest

from repro.graph import sampler as smp


def _random_graph(n=40, e=300, seed=0):
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(e, 2)).astype(np.int32)
    return edges, smp.CSRGraph.from_edges(edges, n)


def _sample(graph, seeds, fanouts, seed):
    return smp.sample_neighbors(graph, seeds,
                                list(fanouts),
                                np.random.default_rng(seed))


# ------------------------------------------------------------ basics -------

def test_csr_from_edges_roundtrip():
    edges, g = _random_graph()
    # row v of the incoming-edge CSR holds exactly the srcs of v's edges
    for v in range(g.num_nodes):
        lo, hi = g.indptr[v], g.indptr[v + 1]
        expect = np.sort(edges[edges[:, 1] == v, 0])
        assert np.array_equal(np.sort(g.indices[lo:hi]), expect)


def test_seed_determinism():
    _, g = _random_graph()
    seeds = np.array([3, 7, 11, 19])
    a = _sample(g, seeds, (3, 2), seed=42)
    b = _sample(g, seeds, (3, 2), seed=42)
    assert np.array_equal(a.node_ids, b.node_ids)
    assert np.array_equal(a.node_mask, b.node_mask)
    for ba, bb in zip(a.blocks, b.blocks, strict=True):
        assert np.array_equal(ba.edges, bb.edges)
        assert np.array_equal(ba.edge_mask, bb.edge_mask)
        assert np.array_equal(ba.edge_pos, bb.edge_pos)
    c = _sample(g, seeds, (3, 2), seed=43)
    diff = any(not np.array_equal(ba.edges, bc.edges)
               for ba, bc in zip(a.blocks, c.blocks, strict=True))
    assert diff, "different PRNG seed should draw a different sample"


# ------------------------------------------------- padded-lane masks -------

def test_padded_lane_invariants():
    _, g = _random_graph()
    seeds = np.array([0, 1, 2])
    sub = _sample(g, seeds, (4, 3), seed=1)
    # static worst-case shapes
    assert sub.node_ids.shape[0] == 3 + 3 * 4 + 3 * 4 * 3
    assert sub.blocks[0].edges.shape == (12, 2)
    assert sub.blocks[1].edges.shape == (36, 2)
    # masks are {0,1} and prefix-shaped (valid lanes first)
    for blk in sub.blocks:
        m = blk.edge_mask
        assert set(np.unique(m)) <= {0.0, 1.0}
        k = int(m.sum())
        assert np.all(m[:k] == 1.0) and np.all(m[k:] == 0.0)
        # padded lanes are zeroed, never stale
        assert np.all(blk.edges[k:] == 0)
        assert np.all(blk.edge_pos[k:] == 0)
    nm = sub.node_mask
    kn = int(nm.sum())
    assert np.all(nm[:kn] == 1.0) and np.all(nm[kn:] == 0.0)
    assert np.all(sub.node_ids[kn:] == 0)


# ------------------------------------- local/global table consistency ------

def test_node_table_consistency():
    edges, g = _random_graph()
    seeds = np.array([5, 9, 21, 33])
    sub = _sample(g, seeds, (3, 3), seed=7)
    kn = int(sub.node_mask.sum())
    table = sub.node_ids[:kn]
    # seeds occupy [0, b) in seed order
    assert np.array_equal(table[:4], seeds)
    # valid table entries are unique
    assert np.unique(table).shape[0] == kn
    eset = {(int(s), int(d)) for s, d in edges}
    for blk in sub.blocks:
        ke = int(blk.edge_mask.sum())
        loc = blk.edges[:ke]
        # every local endpoint indexes a valid table row
        assert loc.size == 0 or int(loc.max()) < kn
        # mapping back through the table lands on real graph edges,
        # and edge_pos points at exactly that (src, dst) CSR slot
        for (ls, ld), pos in zip(loc, blk.edge_pos[:ke], strict=True):
            gs, gd = int(table[ls]), int(table[ld])
            assert (gs, gd) in eset
            assert int(g.indices[pos]) == gs
            lo, hi = g.indptr[gd], g.indptr[gd + 1]
            assert lo <= pos < hi


def test_first_hop_dsts_are_seeds():
    _, g = _random_graph()
    seeds = np.array([2, 17, 30])
    sub = _sample(g, seeds, (5,), seed=3)
    blk = sub.blocks[0]
    ke = int(blk.edge_mask.sum())
    assert ke > 0
    assert np.all(blk.edges[:ke, 1] < 3)    # dst = a seed's local id


def test_full_fanout_covers_in_neighborhood():
    edges, g = _random_graph()
    seeds = np.arange(g.num_nodes)
    deg = np.diff(g.indptr).max()
    sub = _sample(g, seeds, (int(deg),), seed=0)
    blk = sub.blocks[0]
    ke = int(blk.edge_mask.sum())
    assert ke == edges.shape[0]              # every edge sampled once
    table = sub.node_ids[:int(sub.node_mask.sum())]
    got = {(int(table[s]), int(table[d])) for s, d in blk.edges[:ke]}
    assert got == {(int(s), int(d)) for s, d in edges}


# ------------------------------------------- inclusion probabilities -------

def test_uniform_inclusion_probability():
    """Fanout k from a degree-d neighborhood includes each neighbor
    with probability k/d; check the empirical rate over repeats."""
    n, d, k = 12, 10, 3
    # node 0 has exactly d distinct in-neighbors (1..d)
    edges = np.stack([np.arange(1, d + 1),
                      np.zeros(d, dtype=np.int64)], axis=1).astype(np.int32)
    g = smp.CSRGraph.from_edges(edges, n)
    trials = 2000
    counts = np.zeros(d)
    for s in range(trials):
        sub = _sample(g, np.array([0]), (k,), seed=s)
        blk = sub.blocks[0]
        ke = int(blk.edge_mask.sum())
        assert ke == k                       # deg >= fanout: exactly k draws
        table = sub.node_ids
        picked = {int(table[ls]) for ls in blk.edges[:ke, 0]}
        assert len(picked) == k              # without replacement
        for v in picked:
            counts[v - 1] += 1
    rate = counts / trials
    # binomial std ~ sqrt(p(1-p)/trials) ~ 0.01; 5 sigma margin
    assert np.all(np.abs(rate - k / d) < 0.05), rate
