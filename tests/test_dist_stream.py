"""Distributed streamed training vs the single-device streamed reference.

The composition PR 2 exists for: per-shard time-slice delta streams +
per-device edge-buffer rings + the snapshot-parallel shard_map step must
reproduce the single-device slice-granularity streamed loss stream on the
same trace (<= 1e-5 relative), ship only ~1/P of the stream to each
device, and cross shards exclusively through the two all-to-alls per
layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.models import DynGNNConfig
from repro.data.dyngnn import synthetic_dataset
from repro.dist import sharding as shardlib
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.stream import distributed as dist
from repro.stream import train_loop as stream_train

N, T, NB = 48, 16, 2
WIN = T // NB


def _ds(model, seed=0):
    smooth = {"tmgcn": "mproduct", "evolvegcn": "edgelife",
              "cdgcn": "none"}[model]
    ds = synthetic_dataset(N, T, density=2.0, churn=0.1,
                           smoothing_mode=smooth, window=3, seed=seed)
    cfg = DynGNNConfig(model=model, num_nodes=N, num_steps=T, window=3,
                       checkpoint_blocks=NB)
    return cfg, ds, np.asarray(ds.frames), np.asarray(ds.labels)


@pytest.mark.parametrize("model", ["tmgcn", "cdgcn", "evolvegcn"])
def test_distributed_matches_single_device_reference(model):
    """Same trace, same seed: the distributed loss stream equals the
    slice-granularity single-device reference to <= 1e-5 relative, and so
    do the final params."""
    cfg, ds, frames, labels = _ds(model)
    ref = stream_train.train_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, num_epochs=2,
        overlap=False, slice_len=WIN)
    mesh = make_host_mesh(data=4, model=1)
    got = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        num_epochs=2)
    assert len(got.losses) == len(ref.losses) == 2 * NB
    np.testing.assert_allclose(got.losses, ref.losses, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(got.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
@pytest.mark.parametrize("pipeline", [False, True])
@pytest.mark.parametrize("chunks", [1, 2, 4])
def test_pipelined_chunked_round_matches_serial(chunks, pipeline,
                                                _serial_ref_p8):
    """The chunked-round pipelining knobs are pure schedule changes: on
    the 8-device host mesh every (a2a_chunks, pipeline_rounds) combination
    reproduces the serial (C=1, unpipelined) loss stream at <= 1e-5
    relative — and so do the final params."""
    cfg, ds, frames, labels, mesh, ref = _serial_ref_p8
    got = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        num_epochs=2, a2a_chunks=chunks, pipeline_rounds=pipeline)
    assert len(got.losses) == len(ref.losses) == 2 * NB
    np.testing.assert_allclose(got.losses, ref.losses, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(got.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


@pytest.fixture(scope="module")
def _serial_ref_p8():
    """Serial (a2a_chunks=1, pipeline_rounds=False) reference on the
    8-device mesh, computed once for the pipelined-equivalence matrix."""
    cfg, ds, frames, labels = _ds("tmgcn")
    mesh = make_host_mesh(data=8, model=1)
    ref = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        num_epochs=2)
    return cfg, ds, frames, labels, mesh, ref


def test_pipelined_round_rejects_bad_chunks():
    cfg, ds, frames, labels = _ds("tmgcn")
    mesh = make_host_mesh(data=4, model=1)
    with pytest.raises(ValueError, match="a2a_chunks"):
        dist.make_dist_stream_step(
            cfg, mesh, adamw.AdamWConfig(lr=1e-2, total_steps=1),
            a2a_chunks=0)


def test_distributed_overlap_is_pure_schedule_change():
    """Prefetched per-shard staging vs the synchronous schedule: identical
    losses (prefetch moves work between threads, never across the data
    dependency order)."""
    cfg, ds, frames, labels = _ds("tmgcn")
    mesh = make_host_mesh(data=4, model=1)
    kw = dict(mesh=mesh, num_epochs=2)
    sync = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, overlap=False, **kw)
    over = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, overlap=True,
        prefetch_depth=3, **kw)
    assert sync.losses == over.losses
    for a, b in zip(jax.tree.leaves(sync.params),
                    jax.tree.leaves(over.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("p", [2, 4])
def test_per_shard_stream_volume_scales_down(p):
    """Each shard receives only its own time slices: per-shard payload is
    well under the full stream's bytes (down to slice-boundary fulls)."""
    cfg, ds, frames, labels = _ds("tmgcn")
    mesh = make_host_mesh(data=p, model=1)
    got = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        num_epochs=1)
    assert len(got.per_shard_bytes) == p
    from repro.core import graphdiff
    from repro.stream import encoder as enc
    max_edges = enc.padded_max_edges(ds.snapshots)
    full = graphdiff.stream_bytes(enc.encode_stream_fast(
        ds.snapshots, ds.values, N, max_edges, WIN))
    for per_dev in got.per_shard_bytes:
        assert per_dev < full
    assert max(got.per_shard_bytes) < 2 * full / p + max_edges * 12


def test_round_staging_pins_shards_to_their_devices():
    """The prefetch stage function must place shard s's delta items on
    shard s's device and frames/labels with their NamedSharding."""
    cfg, ds, frames, labels = _ds("tmgcn")
    mesh = make_host_mesh(data=4, model=1)
    devices = shardlib.shard_devices(mesh, "data")
    from repro.stream import encoder as enc
    from repro.stream import sharded as stream_sharded
    max_edges = enc.padded_max_edges(ds.snapshots)
    streams = stream_sharded.encode_time_sliced(
        ds.snapshots, ds.values, N, max_edges, WIN, 4)
    stage = dist.make_round_stage_fn(mesh, "data")
    (items, fr_g, lab_g) = stage(next(dist.dist_round_stream(
        streams, frames, labels, WIN, WIN // 4)))
    for s, shard_items in enumerate(items):
        assert len(shard_items) == WIN // 4
        for it in shard_items:
            arr = it.edges if hasattr(it, "edges") else it.add_edges
            assert list(arr.devices()) == [devices[s]]
    assert fr_g.shape == (WIN, N, frames.shape[-1])
    assert fr_g.sharding.spec == shardlib.stream_batch_specs()["frames"]
    assert lab_g.sharding.spec == shardlib.stream_batch_specs()["labels"]


def test_step_crosses_shards_via_all_to_all_only():
    """Structural: the compiled sharded loss contains all-to-alls (the two
    redistributions per GCN layer) and no all-gather on the feature path;
    EvolveGCN compiles with NO feature collectives at all (§5.5); chunking
    multiplies the all-to-all count (the schedule the overlap exploits)."""
    mesh = make_host_mesh(data=4, model=1)

    def hlo_for(model, a2a_chunks=1):
        cfg, ds, frames, labels = _ds(model)
        from repro.core import models as mdl
        step = dist.make_dist_stream_step(
            cfg, mesh, adamw.AdamWConfig(lr=1e-2, total_steps=10),
            a2a_chunks=a2a_chunks)
        params = mdl.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = adamw.init_state(params)
        carries = dist.init_sharded_carries(cfg, params, mesh)
        e = jnp.zeros((WIN, 128, 2), jnp.int32)
        m = jnp.zeros((WIN, 128), jnp.float32)
        v = jnp.zeros((WIN, 128), jnp.float32)
        fr = jnp.zeros((WIN, N, cfg.feat_in), jnp.float32)
        lab = jnp.zeros((WIN, N), jnp.int32)
        return step.lower(params, opt_state, carries, fr, e, m, v, lab,
                          jnp.int32(0)).compile().as_text()

    txt = hlo_for("tmgcn")
    assert txt.count("all-to-all") >= 2     # T->N and N->T redistributions
    chunked = hlo_for("tmgcn", a2a_chunks=2)
    assert chunked.count("all-to-all") > txt.count("all-to-all")
    evolve = hlo_for("evolvegcn")
    assert "all-to-all" not in evolve       # weights evolve locally (§5.5)


def test_sharded_carries_keep_their_placement():
    """Feature-RNN carries stay vertex-sharded across rounds — the step
    must not silently gather them to one device."""
    cfg, ds, frames, labels = _ds("cdgcn")
    mesh = make_host_mesh(data=4, model=1)
    from repro.core import models as mdl
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    carries = dist.init_sharded_carries(cfg, params, mesh)
    for h, _c in carries:
        assert len(h.sharding.device_set) == 4
        assert h.sharding.spec == jax.sharding.PartitionSpec("data", None)


def test_streamed_comm_volume_laws():
    """Analytic invariants the benchmark relies on: per-shard stream
    volume constant under time-axis weak scaling, ~1/P on a fixed trace;
    per-snapshot all-to-all payload monotone in P and bounded by the
    fixed 2*L*N*F total."""
    from repro.dist import comm_volume as cv
    weak = [cv.streamed_shard_volume(8 * p, p, 2 * p, 1000.0, 100.0)
            for p in (1, 2, 4, 8)]
    assert max(weak) == min(weak)               # exactly constant
    fixed = [cv.streamed_shard_volume(64, p, 8, 1000.0, 100.0)
             for p in (1, 2, 4, 8)]
    assert fixed[0] > fixed[1] > fixed[2] > fixed[3]
    n, feat, layers = 128, 6, 2
    bound = 2 * layers * n * feat * 4
    payloads = [cv.alltoall_round_payload(2 * p, n, feat, layers, p) /
                (2 * p) for p in (1, 2, 4, 8)]
    assert payloads[0] == 0.0
    assert payloads[1] < payloads[2] < payloads[3] <= bound


def test_mesh_validation_errors():
    cfg, ds, frames, labels = _ds("tmgcn")
    mesh = make_host_mesh(data=3, model=1)
    with pytest.raises(ValueError, match="must divide"):
        dist.train_distributed_streamed(
            cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh)
