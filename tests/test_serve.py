"""Online serving tests.

The load-bearing guarantees:

* ONLINE == OFFLINE — serving state after window t equals the offline
  blocked forward on the equivalent DTDG (<=1e-5), for every dyngnn
  model, including with params trained by ``Engine.fit`` on the
  8-device mesh;
* the online ingester's delta items are BYTE-IDENTICAL to the offline
  encoder's over the discretized trace (property-style, both policies)
  — one code path, pinned;
* the warm-state cache refreshes on advance (never serves stale
  windows) and micro-batching pads without leaking across requests;
* the declarative surface validates loudly and the legacy launcher is
  a DeprecationWarning shim.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import checkpoint as ckpt
from repro.core import ctdg
from repro.core import models as mdl
from repro.data.dyngnn import DTDGPipeline, dataset_from_snapshots
from repro.serve import (IngestSpec, LateEventError, OnlineIngester,
                         QueryBatcher, ServeConfig, ServeEngine)
from repro.stream import encoder as enc

N, W = 40, 12


def _stream(seed=0, n=N, events=500):
    return ctdg.synthetic_ctdg(n, events, delete_frac=0.25,
                               seed=seed).sorted()


def _spec(stream, pipe, **kw):
    return IngestSpec(num_windows=W,
                      time_range=(float(stream.time.min()),
                                  float(stream.time.max())),
                      block_size=pipe.bsize, max_edges=pipe.max_edges,
                      **kw)


def _offline(stream, n=N):
    snaps = ctdg.snapshot_events(stream, W)
    ds = dataset_from_snapshots(snaps, n, smoothing_mode="none")
    return ds, DTDGPipeline(ds, nb=2)


def _push_chunked(eng, stream, chunk=123):
    for lo in range(0, len(stream), chunk):
        sl = slice(lo, lo + chunk)
        eng.ingest(ctdg.EventStream(stream.src[sl], stream.dst[sl],
                                    stream.time[sl], stream.kind[sl],
                                    stream.num_nodes))


# ------------------------------------------------ online == offline ---------

@pytest.mark.parametrize("model", ["cdgcn", "tmgcn", "evolvegcn"])
def test_online_scores_match_offline(model):
    """Ingest live -> advance all windows -> query == the offline
    blocked forward + heads, for node scoring AND link prediction."""
    stream = _stream(seed=1)
    ds, pipe = _offline(stream)
    cfg = mdl.DynGNNConfig(model=model, num_nodes=N, num_steps=W,
                           window=3, checkpoint_blocks=2)
    params = mdl.init_params(jax.random.PRNGKey(7), cfg)
    z_ref = ckpt.blocked_forward(cfg, params, pipe.batch, 2)

    eng = ServeEngine(ServeConfig(model=cfg, ingest=_spec(stream, pipe)),
                      params=params)
    _push_chunked(eng, stream)
    eng.advance_all()

    got = eng.query_nodes(np.arange(N))
    ref = np.asarray(mdl.classify(params, z_ref[-1]))
    np.testing.assert_allclose(got, ref, atol=1e-5)

    pairs = np.array([[0, 1], [3, 9], [N - 1, 0]])
    got_l = eng.query_links(pairs)
    ref_l = np.asarray(mdl.link_logits(params, z_ref[-1],
                                       jnp.asarray(pairs, jnp.int32)))
    np.testing.assert_allclose(got_l, ref_l, atol=1e-5)

    r = eng.result()
    assert r.events_ingested == len(stream)
    assert r.windows_advanced == W
    assert r.queries == 2 and r.query_batches == 2
    assert np.isfinite(r.p50_ms) and np.isfinite(r.p95_ms)


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_serving_trained_mesh_params_matches_offline_eval():
    """The acceptance path: Engine.fit on the 8-device mesh -> serve the
    trained params online -> scores equal the offline evaluation
    forward on the same DTDG (<=1e-5)."""
    from repro.run import Engine, ExecutionPlan, InMemoryDTDG, RunConfig
    stream = _stream(seed=2, events=600)
    ds, pipe = _offline(stream)
    cfg = mdl.DynGNNConfig(model="tmgcn", num_nodes=N, num_steps=W,
                           window=3, checkpoint_blocks=2)
    fit = Engine(RunConfig(
        model=cfg, data=InMemoryDTDG(ds, pipeline=pipe),
        plan=ExecutionPlan(mode="streamed_mesh", shards=4, num_epochs=1),
        seed=0)).fit()
    params = fit.state.params

    eng = ServeEngine(ServeConfig(model=cfg, ingest=_spec(stream, pipe)),
                      params=params)
    _push_chunked(eng, stream)
    eng.advance_all()
    got = eng.query_nodes(np.arange(N))
    z_ref = ckpt.blocked_forward(cfg, params, pipe.batch, 2)
    ref = np.asarray(mdl.classify(params, z_ref[-1]))
    np.testing.assert_allclose(got, ref, atol=1e-5)


# ------------------------------------- ingester == offline encoder ----------

@pytest.mark.parametrize("policy", ["snapshot", "window"])
@pytest.mark.parametrize("seed", [0, 3, 5])
def test_ingester_items_match_offline_encoder(policy, seed):
    """Property: pushing a random event stream through the online
    ingester yields the SAME delta-stream items (byte for byte) as
    offline-discretizing the trace and encoding it in one pass."""
    stream = _stream(seed=seed, events=400)
    snaps = (ctdg.snapshot_events if policy == "snapshot"
             else ctdg.window_events)(stream, W)
    max_edges = enc.padded_max_edges(snaps)
    spec = IngestSpec(num_windows=W, policy=policy,
                      time_range=(float(stream.time.min()),
                                  float(stream.time.max())),
                      block_size=4, max_edges=max_edges, churn_pad=None)
    ing = OnlineIngester(spec, N)
    for lo in range(0, len(stream), 97):
        sl = slice(lo, lo + 97)
        ing.push(ctdg.EventStream(stream.src[sl], stream.dst[sl],
                                  stream.time[sl], stream.kind[sl], N))
    online = [ing.close_window()[0] for _ in range(W)]

    pad = spec.drop_add_pad
    stats = enc.DeltaStats(max_edges=max_edges, max_drops=pad,
                           max_adds=pad)
    offline = list(enc.iter_encode_stream(snaps, None, N, max_edges, 4,
                                          stats))
    for a, b in zip(online, offline):
        assert type(a) is type(b)
        for f in a.__dataclass_fields__:
            va, vb = getattr(a, f), getattr(b, f)
            if isinstance(va, np.ndarray):
                np.testing.assert_array_equal(va, vb)
            else:
                assert va == vb


def test_ingester_frames_are_degree_features():
    from repro.graph import generate
    stream = _stream(seed=4, events=300)
    snaps = ctdg.snapshot_events(stream, W)
    spec = IngestSpec(num_windows=W,
                      time_range=(float(stream.time.min()),
                                  float(stream.time.max())),
                      max_edges=enc.padded_max_edges(snaps))
    ing = OnlineIngester(spec, N)
    ing.push(stream)
    for t in range(W):
        _, frame = ing.close_window()
        np.testing.assert_array_equal(
            frame, generate.degree_features(snaps[t], N))


# --------------------------------------------------- warm-state cache -------

def test_warm_cache_refreshes_on_advance():
    """The cached z is invalidated by every advance: queries always see
    the CURRENT window, matching the per-window offline reference."""
    stream = _stream(seed=6)
    ds, pipe = _offline(stream)
    cfg = mdl.DynGNNConfig(model="tmgcn", num_nodes=N, num_steps=W,
                           window=3, checkpoint_blocks=2)
    params = mdl.init_params(jax.random.PRNGKey(1), cfg)
    z_ref = ckpt.blocked_forward(cfg, params, pipe.batch, 2)

    eng = ServeEngine(ServeConfig(model=cfg, ingest=_spec(stream, pipe)),
                      params=params)
    eng.ingest(stream)
    ids = np.arange(N)
    seen = []
    for t in range(W):
        eng.advance()
        got = eng.query_nodes(ids)
        np.testing.assert_allclose(
            got, np.asarray(mdl.classify(params, z_ref[t])), atol=1e-5)
        seen.append(got)
    # the state really moved (stale cache would have frozen the scores)
    assert any(np.abs(seen[t] - seen[t + 1]).max() > 0
               for t in range(W - 1))


def test_query_before_first_advance_raises():
    stream = _stream(seed=0)
    ds, pipe = _offline(stream)
    cfg = mdl.DynGNNConfig(model="tmgcn", num_nodes=N, num_steps=W,
                           window=3)
    eng = ServeEngine(ServeConfig(model=cfg, ingest=_spec(stream, pipe)))
    with pytest.raises(ValueError, match="no resident state"):
        eng.query_nodes([0, 1])


# --------------------------------------------------- micro-batching ---------

def test_query_batcher_pads_to_buckets_without_leaking():
    calls = []

    def run_fn(padded):
        calls.append(padded.shape[0])
        return padded * 2.0

    qb = QueryBatcher(run_fn, batch_sizes=(2, 4), queue_depth=8)
    a = qb.submit(np.array([1.0]))
    b = qb.submit(np.array([2.0, 3.0]))
    qb.flush()
    assert a.done and b.done
    np.testing.assert_allclose(a.scores, [2.0])
    np.testing.assert_allclose(b.scores, [4.0, 6.0])
    assert calls == [4]                 # 3 rows -> one padded-4 batch
    assert qb.stats.queries == 2 and qb.stats.rows == 3
    assert len(qb.stats.latencies_ms) == 2


def test_query_batcher_full_queue_flushes_first():
    def run_fn(padded):
        return padded

    qb = QueryBatcher(run_fn, batch_sizes=(1, 2), queue_depth=2)
    p1 = qb.submit(np.array([1.0]))
    p2 = qb.submit(np.array([2.0]))
    p3 = qb.submit(np.array([3.0]))     # full -> flushes p1+p2 first
    assert p1.done and p2.done and not p3.done
    qb.flush()
    assert p3.done


def test_query_batcher_chunks_oversized_requests():
    def run_fn(padded):
        return padded

    qb = QueryBatcher(run_fn, batch_sizes=(2, 4), queue_depth=8)
    out = qb.query(np.arange(10.0))
    np.testing.assert_allclose(out, np.arange(10.0))
    assert qb.stats.batches == 3        # 4 + 4 + 2


# ------------------------------------------------------- validation ---------

def test_serve_config_validation():
    with pytest.raises(ValueError, match="arch id or an explicit"):
        ServeConfig().validate()
    with pytest.raises(ValueError, match="ascending"):
        ServeConfig(arch="x", batch_sizes=(8, 1)).validate()
    with pytest.raises(ValueError, match="queue_depth"):
        ServeConfig(arch="x", queue_depth=0).validate()
    with pytest.raises(ValueError, match="exactly one"):
        IngestSpec().validate()
    with pytest.raises(ValueError, match="exactly one"):
        IngestSpec(time_range=(0, 1), num_windows=4,
                   window_span=0.5).validate()
    with pytest.raises(ValueError, match="num_windows"):
        IngestSpec(time_range=(0, 1)).validate()
    with pytest.raises(ValueError, match="t1 > t0"):
        IngestSpec(time_range=(1, 1), num_windows=2).validate()
    with pytest.raises(ValueError, match="policy"):
        IngestSpec(time_range=(0, 1), num_windows=2,
                   policy="bogus").validate()
    with pytest.raises(ValueError, match="window_span only supports"):
        IngestSpec(window_span=0.5, policy="window").validate()
    with pytest.raises(ValueError, match="churn_pad"):
        IngestSpec(time_range=(0, 1), num_windows=2, max_edges=64,
                   churn_pad=128).validate()
    # valid specs pass
    IngestSpec(time_range=(0, 1), num_windows=2).validate()
    IngestSpec(window_span=0.25).validate()


def test_ingester_rejects_late_and_alien_events():
    stream = _stream(seed=0, events=300)
    spec = IngestSpec(num_windows=W,
                      time_range=(float(stream.time.min()),
                                  float(stream.time.max())),
                      max_edges=2048)
    ing = OnlineIngester(spec, N)
    ing.push(stream)
    ing.close_window()
    late = ctdg.EventStream(np.array([0], np.int32), np.array([1], np.int32),
                            np.array([float(stream.time.min())]),
                            np.array([1], np.int8), N)
    with pytest.raises(LateEventError, match="already.*closed"):
        ing.push(late)
    with pytest.raises(ValueError, match="num_nodes"):
        ing.push(ctdg.EventStream(np.array([0], np.int32),
                                  np.array([1], np.int32),
                                  np.array([1e9]), np.array([1], np.int8),
                                  N + 1))


def test_ingester_bounds_device_memory():
    stream = _stream(seed=0, events=400)
    spec = IngestSpec(num_windows=1,
                      time_range=(float(stream.time.min()),
                                  float(stream.time.max())),
                      max_edges=8)
    ing = OnlineIngester(spec, N)
    ing.push(stream)
    with pytest.raises(ValueError, match="max_edges"):
        ing.close_window()


def test_dyngnn_engine_requires_ingest_spec():
    cfg = mdl.DynGNNConfig(model="tmgcn", num_nodes=N, num_steps=W)
    with pytest.raises(ValueError, match="needs ServeConfig.ingest"):
        ServeEngine(ServeConfig(model=cfg))


# ------------------------------------------- other families + shim ----------

def test_family_guards():
    eng = ServeEngine(ServeConfig(arch="din", batch_sizes=(2,)))
    with pytest.raises(ValueError, match="family"):
        eng.ingest(None)
    with pytest.raises(ValueError, match="family"):
        eng.generate()


def test_recsys_serving():
    eng = ServeEngine(ServeConfig(arch="din", batch_sizes=(4,), seed=3))
    scores = eng.score(batch_size=4)
    assert scores.shape[0] == 4
    r = eng.result()
    assert r.family == "recsys" and r.queries == 4
    assert np.isfinite(r.p50_ms)


def test_lm_serving():
    eng = ServeEngine(ServeConfig(arch="yi-6b", batch_sizes=(2,),
                                  prompt_len=4, max_tokens=3, seed=0))
    toks = eng.generate(batch_size=2)
    assert toks.shape == (2, 3)
    r = eng.result()
    assert r.family == "lm" and r.tokens_generated == 6


def test_run_exports_serve_surface():
    import repro.run as run
    assert run.ServeConfig is ServeConfig
    assert run.ServeEngine is ServeEngine
    assert run.IngestSpec is IngestSpec


def test_launch_serve_is_a_deprecation_shim():
    from repro.launch import serve as legacy
    with pytest.warns(DeprecationWarning, match="repro.serve"):
        legacy.main(["--arch", "din", "--batch", "1", "--requests", "1",
                     "--tokens", "2", "--prompt-len", "2"])
