"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Covers shapes x dtypes for all three Pallas kernels + hypothesis property
tests on the bucketed segment-sum layout.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.mproduct import ops as mp_ops
from repro.kernels.segment_spmm import ops as spmm_ops


# ------------------------------------------------------- segment_spmm ------

@pytest.mark.parametrize("n,e,f", [(200, 1000, 64), (300, 2000, 100),
                                   (128, 500, 128), (64, 64, 32),
                                   (1000, 4000, 256)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_segment_spmm_matches_oracle(n, e, f, dtype):
    rng = np.random.default_rng(n + e)
    edges = rng.integers(0, n, size=(e, 2)).astype(np.int32)
    w = rng.normal(size=(e,)).astype(dtype)
    x = rng.normal(size=(n, f)).astype(dtype)
    got = spmm_ops.segment_spmm(jnp.asarray(x), jnp.asarray(edges),
                                jnp.asarray(w), n)
    want = spmm_ops.segment_spmm_ref(jnp.asarray(x), jnp.asarray(edges),
                                     jnp.asarray(w), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_segment_spmm_masked_edges_ignored():
    n, e, f = 50, 200, 64
    rng = np.random.default_rng(0)
    edges = rng.integers(0, n, size=(e, 2)).astype(np.int32)
    w = rng.normal(size=(e,)).astype(np.float32)
    w[e // 2:] = 0.0   # padded lanes carry zero weight
    x = rng.normal(size=(n, f)).astype(np.float32)
    got = spmm_ops.segment_spmm(jnp.asarray(x), jnp.asarray(edges),
                                jnp.asarray(w), n)
    want = spmm_ops.segment_spmm_ref(
        jnp.asarray(x[:, :f]), jnp.asarray(edges[:e // 2]),
        jnp.asarray(w[:e // 2]), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 300), e=st.integers(1, 800),
       f=st.sampled_from([16, 64, 100]), seed=st.integers(0, 2**31))
def test_segment_spmm_property(n, e, f, seed):
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(e, 2)).astype(np.int32)
    w = rng.normal(size=(e,)).astype(np.float32)
    x = rng.normal(size=(n, f)).astype(np.float32)
    got = spmm_ops.segment_spmm(jnp.asarray(x), jnp.asarray(edges),
                                jnp.asarray(w), n)
    want = spmm_ops.segment_spmm_ref(jnp.asarray(x), jnp.asarray(edges),
                                     jnp.asarray(w), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- mproduct ------

@pytest.mark.parametrize("t,n,f,w", [(16, 8, 4, 3), (32, 16, 6, 5),
                                     (8, 4, 2, 1), (24, 10, 6, 7),
                                     (64, 32, 8, 9)])
def test_mproduct_matches_dense_ttm(t, n, f, w):
    rng = np.random.default_rng(t * w)
    x = jnp.asarray(rng.normal(size=(t, n, f)).astype(np.float32))
    got = mp_ops.m_product(x, w)
    want = mp_ops.banded_ttm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(t=st.integers(2, 40), n=st.integers(1, 12), f=st.integers(1, 8),
       w=st.integers(1, 12), seed=st.integers(0, 2**31))
def test_mproduct_property(t, n, f, w, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, n, f)).astype(np.float32))
    got = mp_ops.m_product(x, w)
    want = mp_ops.banded_ttm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_mproduct_sliced_with_prefix_equals_full():
    from repro.core import temporal
    rng = np.random.default_rng(3)
    t, n, f, w = 12, 6, 4, 4
    x = jnp.asarray(rng.normal(size=(t, n, f)).astype(np.float32))
    full = temporal.m_product(x, w)
    s = 6
    prefix = x[s - (w - 1):s]
    for use_pallas in (False, True):
        sl = temporal.m_product_with_prefix(x[s:], prefix, w, s,
                                            use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(sl), np.asarray(full[s:]),
                                   rtol=1e-5, atol=1e-5)


# -------------------------------------------------------- flash_decode -----

@pytest.mark.parametrize("b,hq,kvh,d,s,blk", [
    (2, 8, 2, 64, 1024, 256), (1, 4, 4, 128, 512, 128),
    (4, 16, 4, 64, 2048, 512), (2, 8, 8, 64, 256, 128)])
def test_flash_decode_matches_oracle(b, hq, kvh, d, s, blk):
    rng = np.random.default_rng(b * s)
    q = jnp.asarray(rng.normal(size=(b, hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    clen = jnp.asarray(rng.integers(1, s, size=(b,)).astype(np.int32))
    got = fd_ops.decode_attention(q, k, v, clen, kv_block=blk)
    want = fd_ops.flash_decode_ref(q, k, v, clen)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_decode_bf16():
    rng = np.random.default_rng(9)
    b, hq, kvh, d, s = 2, 4, 2, 64, 512
    q = jnp.asarray(rng.normal(size=(b, hq, d)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), dtype=jnp.bfloat16)
    clen = jnp.asarray([100, 500], dtype=jnp.int32)
    got = fd_ops.decode_attention(q, k, v, clen, kv_block=128)
    want = fd_ops.flash_decode_ref(q, k, v, clen)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want, dtype=np.float32),
                               rtol=2e-2, atol=2e-2)
