"""Equivalence tests for the §Perf optimization variants — every speedup
must preserve the math (or bound its error, for bf16 comms)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import checkpoint as ckpt_exec
from repro.core import dtdg, models, partition
from repro.graph import generate
from repro.launch.mesh import make_host_mesh
from repro.models import lm

T, N = 16, 32


def _setup(model):
    snaps = generate.evolving_dynamic_graph(N, T, density=2.0, churn=0.1,
                                            seed=0)
    frames = np.stack([generate.degree_features(s, N) for s in snaps])
    batch = dtdg.build_batch(snaps, frames, N)
    cfg = models.DynGNNConfig(model=model, num_nodes=N, num_steps=T,
                              window=3, checkpoint_blocks=2)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    labels = jnp.asarray(
        np.random.default_rng(0).integers(0, 2, size=(T, N)))
    return cfg, params, batch, labels


@pytest.mark.parametrize("model", ["tmgcn", "cdgcn"])
def test_fused_final_loss_matches_plain(model):
    """Eliding the final N->T all-to-all must not change the loss."""
    mesh = make_host_mesh(data=4, model=1)
    cfg, params, batch, labels = _setup(model)
    fr, ed, ew = partition.blockify_batch(batch, 2)
    lab_b = labels.reshape(2, T // 2, N)
    plain = partition.snapshot_partition_loss(cfg, mesh)
    fused = partition.snapshot_partition_loss(cfg, mesh, fuse_final=True)
    l1 = jax.jit(lambda p: plain(p, fr, ed, ew, lab_b))(params)
    l2 = jax.jit(lambda p: fused(p, fr, ed, ew, lab_b))(params)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_bf16_comm_bounded_error():
    mesh = make_host_mesh(data=4, model=1)
    cfg, params, batch, labels = _setup("tmgcn")
    fr, ed, ew = partition.blockify_batch(batch, 2)
    lab_b = labels.reshape(2, T // 2, N)
    plain = partition.snapshot_partition_loss(cfg, mesh)
    bf16 = partition.snapshot_partition_loss(cfg, mesh,
                                             comm_dtype=jnp.bfloat16)
    l1 = float(jax.jit(lambda p: plain(p, fr, ed, ew, lab_b))(params))
    l2 = float(jax.jit(lambda p: bf16(p, fr, ed, ew, lab_b))(params))
    assert abs(l1 - l2) / abs(l1) < 5e-2


def _lm_cfg(**kw):
    base = dict(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                head_dim=16, d_ff=128, vocab_size=512, dtype=jnp.float32)
    base.update(kw)
    return lm.LMConfig(**base)


def test_layer_block_grouping_matches_flat():
    """Two-level (sqrt) layer remat must be a pure storage-schedule change."""
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 512, (2, 16)),
                       jnp.int32)
    cfg_flat = _lm_cfg(layer_block=0)
    cfg_grouped = _lm_cfg(layer_block=2)
    params = lm.init_lm_params(jax.random.PRNGKey(0), cfg_flat)
    l1, g1 = jax.value_and_grad(
        lambda p: lm.lm_loss(cfg_flat, p, toks, toks))(params)
    l2, g2 = jax.value_and_grad(
        lambda p: lm.lm_loss(cfg_grouped, p, toks, toks))(params)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-6)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_chunked_loss_matches_unchunked():
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 512, (2, 64)),
                       jnp.int32)
    cfg_u = _lm_cfg(loss_chunk=0)
    cfg_c = _lm_cfg(loss_chunk=16)
    params = lm.init_lm_params(jax.random.PRNGKey(1), cfg_u)
    l1 = lm.lm_loss(cfg_u, params, toks, toks)
    l2 = lm.lm_loss(cfg_c, params, toks, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_seq_parallel_chunk_attention_matches():
    """chunk_constrain (sequence-parallel attention) is sharding-only."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_host_mesh(data=2, model=4)
    cfg = _lm_cfg(num_heads=6, num_kv_heads=6, d_model=96,
                  q_chunk=8)   # 6 heads don't divide model=4
    toks = jnp.asarray(np.random.default_rng(2).integers(0, 512, (2, 32)),
                       jnp.int32)
    params = lm.init_lm_params(jax.random.PRNGKey(2), cfg)
    inward = NamedSharding(mesh, P("data", "model", None, None))
    outward = NamedSharding(mesh, P("data", None, None, None))

    def chunk_con(x, to_sharded):
        return jax.lax.with_sharding_constraint(
            x, inward if to_sharded else outward)

    with mesh:
        l_plain = jax.jit(lambda p: lm.lm_loss(cfg, p, toks, toks))(params)
        l_sp = jax.jit(lambda p: lm.lm_loss(
            cfg, p, toks, toks, chunk_constrain=chunk_con))(params)
    np.testing.assert_allclose(float(l_plain), float(l_sp), rtol=1e-5)
