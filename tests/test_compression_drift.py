"""The drift-bounded numerics tier for quantized wire formats.

Unlike the overlap/pipelining knobs (pure schedule, bit-exact), the
``compression`` knob CHANGES numerics.  This tier pins what "changes"
means:

* compressed runs (int8 error-feedback all-to-alls, narrow delta wire)
  track the uncompressed loss stream within an absolute drift bound on
  the 8-device mesh, across the a2a_chunks x pipeline_rounds matrix;
* ``compression="none"`` stays BIT-identical to the pre-knob trainer —
  the knob must cost nothing when off;
* EvolveGCN (no feature all-to-alls, §5.5) is bit-exact even under
  ``int8_a2a`` — there is nothing on the wire to quantize;
* byte accounting is structural, not modeled-only: the compiled HLO of
  the round step is parsed (``dist.comm_volume.hlo_collective_bytes``)
  and checked element-for-element against ``alltoall_round_payload``,
  with compressed all-to-all bytes <= 0.3x the f32 lowering;
* the narrow host->device delta wire decodes to the same snapshots
  (edges/mask exact, values within scale/2), narrows indices by range,
  shrinks payload bytes, and leaves resync FullSnapshots lossless;
* the Engine surface validates and echoes the knob.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partition
from repro.core.graphdiff import FullSnapshot, SnapshotDelta
from repro.core.models import DynGNNConfig
from repro.data.dyngnn import synthetic_dataset
from repro.dist import comm_volume as cv
from repro.launch.mesh import make_host_mesh
from repro.stream import distributed as dist
from repro.stream import encoder as enc
from repro.stream import prefetch
from repro.stream import sharded as stream_sharded
from repro.stream import wire as wirelib

N, T, NB = 48, 16, 2
WIN = T // NB
DRIFT_ATOL = 1e-3   # measured ~3e-6 at P=8 over 2 epochs; 1e-3 is the
                    # contract: quantization must never walk the loss


def _ds(model, seed=0):
    smooth = {"tmgcn": "mproduct", "evolvegcn": "edgelife",
              "cdgcn": "none"}[model]
    ds = synthetic_dataset(N, T, density=2.0, churn=0.1,
                           smoothing_mode=smooth, window=3, seed=seed)
    cfg = DynGNNConfig(model=model, num_nodes=N, num_steps=T, window=3,
                      checkpoint_blocks=NB)
    return cfg, ds, np.asarray(ds.frames), np.asarray(ds.labels)


needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 host devices")


@pytest.fixture(scope="module")
def _ref_p8():
    """Uncompressed reference runs on the 8-device mesh, one per model."""
    mesh = make_host_mesh(data=8, model=1)
    out = {}
    for model in ("tmgcn", "cdgcn", "evolvegcn"):
        cfg, ds, frames, labels = _ds(model)
        ref = dist.train_distributed_streamed(
            cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
            num_epochs=2)
        out[model] = (cfg, ds, frames, labels, ref)
    return mesh, out


# ------------------------------------------------------ drift bounds -------

@needs8
@pytest.mark.parametrize("pipeline", [False, True])
@pytest.mark.parametrize("chunks", [1, 2])
def test_int8_a2a_drift_bounded_across_schedule_matrix(chunks, pipeline,
                                                       _ref_p8):
    """int8_a2a tracks the uncompressed loss stream within DRIFT_ATOL on
    every (a2a_chunks, pipeline_rounds) combination — the schedule knobs
    must not compound the quantization drift."""
    mesh, runs = _ref_p8
    cfg, ds, frames, labels, ref = runs["tmgcn"]
    got = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        num_epochs=2, a2a_chunks=chunks, pipeline_rounds=pipeline,
        compression="int8_a2a")
    assert len(got.losses) == len(ref.losses) == 2 * NB
    np.testing.assert_allclose(got.losses, ref.losses, atol=DRIFT_ATOL)


@needs8
@pytest.mark.parametrize("model", ["tmgcn", "cdgcn"])
def test_int8_all_drift_bounded(model, _ref_p8):
    """The full wire stack (quantized a2a + narrow delta wire) stays
    within the same drift bound per model family."""
    mesh, runs = _ref_p8
    cfg, ds, frames, labels, ref = runs[model]
    got = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        num_epochs=2, compression="int8_all")
    np.testing.assert_allclose(got.losses, ref.losses, atol=DRIFT_ATOL)


@needs8
def test_compression_none_is_bit_exact(_ref_p8):
    """compression='none' costs nothing: losses AND final params are
    bitwise identical to the trainer without the knob."""
    mesh, runs = _ref_p8
    cfg, ds, frames, labels, ref = runs["tmgcn"]
    got = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        num_epochs=2, compression="none")
    assert got.losses == ref.losses
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(got.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs8
def test_evolvegcn_int8_a2a_is_bit_exact(_ref_p8):
    """EvolveGCN redistributes nothing (§5.5): quantizing its (absent)
    all-to-alls must be a bitwise no-op, not a small drift."""
    mesh, runs = _ref_p8
    cfg, ds, frames, labels, ref = runs["evolvegcn"]
    got = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
        num_epochs=2, compression="int8_a2a")
    assert got.losses == ref.losses


# ----------------------------------------------- structural byte audit -----

def _hlo_stats(model="tmgcn", chunks=1, compression="none"):
    cfg, ds, frames, labels = _ds(model)
    mesh = make_host_mesh(data=4, model=1)
    hlo = dist.lowered_step_hlo(cfg, mesh, win=WIN, max_edges=128,
                                a2a_chunks=chunks, compression=compression)
    return cfg, cv.hlo_collective_bytes(hlo)


def test_compressed_a2a_bytes_under_point3_of_f32():
    """Acceptance: measured (HLO) all-to-all bytes under int8_a2a are
    <= 0.3x the f32 lowering, scales included."""
    _, f32 = _hlo_stats(compression="none")
    _, q = _hlo_stats(compression="int8_a2a")
    f32_bytes = f32["f32"]["bytes"]
    q_bytes = q["s8"]["bytes"] + q.get("f32", {"bytes": 0})["bytes"]
    assert f32_bytes > 0
    assert q_bytes <= 0.3 * f32_bytes


def test_hlo_matches_payload_model_element_for_element():
    """The analytic model and the lowering agree exactly: per-shard s8
    elements (fwd+bwd) come from partition.a2a_payload_dims, and the
    modeled network-crossing bytes equal per-shard fwd elements x (P-1)
    plus the scale vectors."""
    p, bsl = 4, WIN // 4
    for chunks in (1, 2):
        cfg, q = _hlo_stats(chunks=chunks, compression="int8_a2a")
        dims = partition.a2a_payload_dims(cfg)
        fwd_elems = sum(bsl * N * (f1 + f2) for f1, f2 in dims)
        # one byte per element; backward doubles the op set
        assert q["s8"]["bytes"] == 2 * fwd_elems
        assert q["s8"]["ops"] == 2 * 2 * len(dims) * chunks
        # scale vectors: one (P,) f32 per quantized all-to-all
        assert q["f32"]["bytes"] == 2 * 2 * len(dims) * chunks * p * 4
        feats = {f1 for f1, _ in dims} | {f2 for _, f2 in dims}
        assert len(feats) == 1          # uniform width: the model's feat
        modeled = cv.alltoall_round_payload(
            WIN, N, feats.pop(), len(dims), p, compression="int8_a2a",
            a2a_chunks=chunks)
        assert modeled == fwd_elems * (p - 1) + \
            2 * len(dims) * chunks * p * (p - 1) * 4


def test_chunking_multiplies_ops_not_payload():
    _, q1 = _hlo_stats(chunks=1, compression="int8_a2a")
    _, q2 = _hlo_stats(chunks=2, compression="int8_a2a")
    assert q2["s8"]["ops"] == 2 * q1["s8"]["ops"]
    assert q2["s8"]["bytes"] == q1["s8"]["bytes"]
    # each extra chunk ships its own scale vector
    assert q2["f32"]["bytes"] == 2 * q1["f32"]["bytes"]


def test_evolvegcn_lowers_no_collectives_either_way():
    for compression in ("none", "int8_a2a"):
        _, stats = _hlo_stats("evolvegcn", compression=compression)
        assert stats == {}


# ------------------------------------------------- narrow delta wire -------

def _decode_stream(items, max_edges):
    applier = prefetch.DeltaApplier(max_edges, donate=False)
    return [tuple(np.asarray(a) for a in applier.consume(it))
            for it in items]


def test_quantized_delta_wire_decodes_equivalently():
    """int8 wire vs f32 wire, decoded through the same ring: edges and
    mask identical, values within half a quantization step."""
    cfg, ds, frames, labels = _ds("tmgcn")
    max_edges = enc.padded_max_edges(ds.snapshots)
    f32 = enc.encode_stream_fast(ds.snapshots, ds.values, N, max_edges,
                                 WIN)
    q = enc.encode_stream_fast(ds.snapshots, ds.values, N, max_edges,
                               WIN, wire="int8")
    assert len(f32) == len(q)
    # delta items actually exist (bsl >= 2) and fulls stay lossless f32
    kinds = [type(it) for it in q]
    assert wirelib.QuantizedDelta in kinds and FullSnapshot in kinds
    for it_f, it_q in zip(f32, q):
        if isinstance(it_f, FullSnapshot):
            assert isinstance(it_q, FullSnapshot)
            np.testing.assert_array_equal(it_f.values, it_q.values)
    for (e_f, m_f, v_f), (e_q, m_q, v_q), item in zip(
            _decode_stream(f32, max_edges), _decode_stream(q, max_edges),
            q):
        np.testing.assert_array_equal(e_f, e_q)
        np.testing.assert_array_equal(m_f, m_q)
        if isinstance(item, wirelib.QuantizedDelta):
            step = float(item.values_scale)
            assert np.max(np.abs(v_f - v_q)) <= 0.5 * step * (1 + 1e-5)
        else:
            np.testing.assert_array_equal(v_f, v_q)


def test_index_width_narrows_by_range():
    assert wirelib.index_dtype(32767) == np.int16
    assert wirelib.index_dtype(32768) == np.int32
    assert cv.index_width(32767) == 2.0
    assert cv.index_width(32768) == 4.0
    delta = SnapshotDelta(
        drop_pos=np.asarray([1, 2], np.int32),
        drop_mask=np.asarray([1.0, 1.0], np.float32),
        add_edges=np.zeros((2, 2), np.int32),
        add_mask=np.asarray([1.0, 0.0], np.float32),
        values=np.ones((8,), np.float32), num_edges=5)
    small = wirelib.quantize_delta(delta, num_nodes=100, max_edges=8)
    assert small.drop_pos.dtype == np.int16
    assert small.add_edges.dtype == np.int16
    big = wirelib.quantize_delta(delta, num_nodes=40000, max_edges=8)
    assert big.add_edges.dtype == np.int32
    assert big.drop_pos.dtype == np.int16    # positions index max_edges


def test_narrow_wire_shrinks_shard_payload_bytes():
    """Per-shard stream bytes under wire='int8' < f32 wire (P=4 so each
    shard's slice has real deltas, not just boundary fulls), matching
    the analytic ``delta_wire_bytes`` direction."""
    cfg, ds, frames, labels = _ds("tmgcn")
    max_edges = enc.padded_max_edges(ds.snapshots)
    stats = enc.measure_stats(ds.snapshots, N, WIN, max_edges)
    f32 = stream_sharded.encode_time_sliced(
        ds.snapshots, ds.values, N, max_edges, WIN, 4, stats)
    q = stream_sharded.encode_time_sliced(
        ds.snapshots, ds.values, N, max_edges, WIN, 4, stats, wire="int8")
    for s_f, s_q in zip(f32, q):
        b_f = sum(it.payload_bytes for it in s_f)
        b_q = sum(it.payload_bytes for it in s_q)
        assert b_q < b_f
    assert cv.delta_wire_bytes(4, 4, 100, num_nodes=N, max_edges=128,
                               wire="int8") < \
        cv.delta_wire_bytes(4, 4, 100, num_nodes=N, max_edges=128)


# ------------------------------------------------------- run surface -------

def test_plan_validates_compression():
    from repro.run import ExecutionPlan
    ExecutionPlan(mode="streamed_mesh", shards=4,
                  compression="int8_a2a").validate()
    with pytest.raises(ValueError, match="compression"):
        ExecutionPlan(compression="int8_a2a").validate()       # eager
    with pytest.raises(ValueError, match="compression"):
        ExecutionPlan(mode="streamed_mesh", shards=4,
                      compression="int9").validate()
    with pytest.raises(ValueError, match="elastic"):
        ExecutionPlan(mode="streamed_mesh", shards=4,
                      compression="int8_a2a",
                      rescale=((1, 2),)).validate()


def test_engine_rejects_checkpoint_with_compression(tmp_path):
    from repro.run import (CheckpointSpec, Engine, ExecutionPlan,
                           RunConfig, SyntheticTrace)
    cfg, ds, frames, labels = _ds("tmgcn")
    run = RunConfig(
        model=cfg, data=SyntheticTrace(num_nodes=N, num_steps=T),
        plan=ExecutionPlan(mode="streamed_mesh", shards=4,
                           compression="int8_a2a"),
        checkpoint=CheckpointSpec(str(tmp_path)))
    with pytest.raises(ValueError, match="compression"):
        Engine(run).resolve()


def test_engine_echoes_compression_mode():
    from repro.run import Engine, ExecutionPlan, RunConfig, SyntheticTrace
    cfg, ds, frames, labels = _ds("tmgcn")
    data = SyntheticTrace(num_nodes=N, num_steps=T, density=2.0,
                          smoothing_mode="mproduct", window=3)
    results = {}
    for mode in ("none", "int8_all"):
        plan = ExecutionPlan(mode="streamed_mesh", shards=4,
                             num_epochs=1, compression=mode)
        results[mode] = Engine(RunConfig(
            model=cfg, data=data, plan=plan,
            log_fn=lambda m: None)).fit()
        assert results[mode].compression == mode
    # the narrow wire also shows up in the per-shard byte accounting
    assert (sum(results["int8_all"].per_shard_bytes)
            < sum(results["none"].per_shard_bytes))
    assert abs(results["int8_all"].losses[-1]
               - results["none"].losses[-1]) <= DRIFT_ATOL
