"""The documentation cannot rot: markdown links must resolve and the
``docs/run_api.md`` / ``docs/serve_api.md`` examples must execute (the
same checks CI's docs job runs via ``tools/check_docs.py``)."""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_docs_exist():
    assert (ROOT / "docs" / "architecture.md").exists()
    assert (ROOT / "docs" / "run_api.md").exists()
    assert (ROOT / "docs" / "serve_api.md").exists()


def test_markdown_links_resolve():
    problems = check_docs.check_links()
    assert problems == []


def test_run_api_examples_execute():
    """Every ```python fence in docs/run_api.md runs, in order, in one
    shared namespace (conftest already forces 8 host devices)."""
    check_docs.run_examples(verbose=False)


def test_serve_api_examples_execute():
    """Every ```python fence in docs/serve_api.md runs the same way —
    the serving surface's documentation is executable too."""
    check_docs.run_examples(ROOT / "docs" / "serve_api.md",
                            verbose=False)
