"""Fault-tolerance suite: checkpoint roundtrip + atomicity, elastic
re-mesh + re-blocking, preemption, straggler watchdog, gradient
compression."""

import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import Checkpointer
from repro.dist import compression
from repro.ft import elastic, straggler


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.float32),
                       "c": [jnp.ones((2,)), jnp.zeros((3,))]}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = _tree()
    ck.save(10, tree, extra={"cursor": 123}, blocking=True)
    restored, extra = ck.restore(10, tree)
    assert extra["cursor"] == 123
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_checkpoint_structure_mismatch_detected(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(), blocking=True)
    bad = {"a": jnp.zeros((4, 8))}
    with pytest.raises(ValueError, match="incompatible"):
        ck.restore(1, bad)


def test_checkpoint_restore_onto_mesh(tmp_path):
    """Elastic scaling: save host-gathered, restore sharded on a mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=4, model=2)
    ck = Checkpointer(tmp_path)
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    ck.save(5, tree, blocking=True)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _ = ck.restore(5, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]))


def test_remesh_plan():
    assert elastic.remesh_plan(512, 16) == elastic.MeshPlan(32, 16)
    assert elastic.remesh_plan(256, 16) == elastic.MeshPlan(16, 16)
    # losing a node: 248 chips don't divide by 16 -> fall back to 8
    plan = elastic.remesh_plan(248, 16)
    assert plan.chips == 248 and 248 % plan.model == 0


def test_dyngnn_elastic_blocks():
    nb, bsize = elastic.dyngnn_elastic_blocks(256, 16, target_bsize=64)
    assert 256 % nb == 0 and bsize % 16 == 0 and bsize <= 64
    nb2, bsize2 = elastic.dyngnn_elastic_blocks(256, 32, target_bsize=64)
    assert bsize2 % 32 == 0


@pytest.mark.parametrize("t,p", [(10, 3), (7, 2), (100, 16)])
def test_dyngnn_elastic_blocks_always_tiles_or_raises(t, p):
    """Regression: the old fallback returned (T//P, P) even when P does
    not divide T — an illegal blocking with nb*bsize != T.  Now every
    return tiles the timeline exactly, and the untileable case raises."""
    if t % p:
        with pytest.raises(ValueError, match="cannot be tiled"):
            elastic.dyngnn_elastic_blocks(t, p, target_bsize=4)
    else:
        nb, bsize = elastic.dyngnn_elastic_blocks(t, p, target_bsize=4)
        assert nb * bsize == t and bsize % p == 0
    with pytest.raises(ValueError, match=">= 1"):
        elastic.dyngnn_elastic_blocks(0, 1, target_bsize=4)
    with pytest.raises(ValueError, match=">= 1"):
        elastic.dyngnn_elastic_blocks(8, 0, target_bsize=4)


def test_preemption_guard():
    with elastic.PreemptionGuard() as g:
        assert not g.preempted
        os.kill(os.getpid(), signal.SIGTERM)
        assert g.preempted   # handler flips the flag instead of killing us


def test_preemption_guard_chains_previous_handler():
    """An already-installed SIGTERM handler still runs (the guard chains,
    never clobbers), and __exit__ restores it exactly."""
    calls = []

    def prev(signum, frame):
        calls.append(signum)

    before = signal.signal(signal.SIGTERM, prev)
    try:
        with elastic.PreemptionGuard() as g:
            os.kill(os.getpid(), signal.SIGTERM)
            assert g.preempted
            assert calls == [signal.SIGTERM]      # chained through
        assert signal.getsignal(signal.SIGTERM) is prev
    finally:
        signal.signal(signal.SIGTERM, before)


def test_preemption_guard_nested_guards_restore_in_order():
    """Nested guards: the inner handler chains to the outer one (both
    flags flip on one signal) and each __exit__ restores the handler it
    replaced — LIFO unwind leaves the process handler untouched."""
    base = signal.getsignal(signal.SIGTERM)
    with elastic.PreemptionGuard() as outer:
        mid = signal.getsignal(signal.SIGTERM)
        with elastic.PreemptionGuard() as inner:
            os.kill(os.getpid(), signal.SIGTERM)
            assert inner.preempted and outer.preempted
        assert signal.getsignal(signal.SIGTERM) is mid
    assert signal.getsignal(signal.SIGTERM) is base


def test_preemption_guard_sigint_opt_in():
    """catch_sigint=True converts SIGINT into the flag (no
    KeyboardInterrupt); the default guard leaves SIGINT alone."""
    default_int = signal.getsignal(signal.SIGINT)
    with elastic.PreemptionGuard(catch_sigint=True) as g:
        os.kill(os.getpid(), signal.SIGINT)   # would raise if unhandled
        assert g.preempted
    assert signal.getsignal(signal.SIGINT) is default_int
    with elastic.PreemptionGuard() as g2:
        assert signal.getsignal(signal.SIGINT) is default_int
        assert not g2.preempted


def test_straggler_timer_flags_outliers():
    t = straggler.StepTimer(window=50, threshold_std=3.0)
    for _ in range(30):
        t.observe(0.1 + np.random.default_rng(0).normal() * 1e-4)
    assert t.observe(1.0) is True
    assert t.straggler_rate > 0


def test_straggler_timer_ewma_recurrence():
    """The smoothed moments follow the documented EW update exactly:
    mean += alpha*diff; var = (1-alpha)*(var + alpha*diff^2)."""
    t = straggler.StepTimer(window=9, threshold_std=3.0, min_steps=3)
    assert t.alpha == pytest.approx(2.0 / 10.0)
    seq = [0.10, 0.12, 0.08, 0.11, 0.30, 0.10]
    mean, var = seq[0], 0.0
    t.observe(seq[0])
    for dt in seq[1:]:
        t.observe(dt)
        diff = dt - mean
        mean += t.alpha * diff
        var = (1 - t.alpha) * (var + t.alpha * diff * diff)
    assert t.mean == pytest.approx(mean)
    assert t.var == pytest.approx(var)
    assert t.step_idx == len(seq)
    assert list(t.times) == seq


def test_straggler_timer_outlier_cannot_mask_itself():
    """The flag check runs BEFORE the EWMA update, so a huge step is
    judged against the pre-outlier estimate."""
    t = straggler.StepTimer(window=50, threshold_std=3.0, min_steps=5)
    for _ in range(20):
        t.observe(0.1)
    assert t.observe(5.0) is True
    assert t.flagged_steps == [21]


def test_straggler_timer_reset():
    t = straggler.StepTimer(window=10, min_steps=2)
    for _ in range(8):
        t.observe(0.2)
    t.observe(9.0)
    t.reset()
    assert t.mean == 0.0 and t.var == 0.0 and t.step_idx == 0
    assert not t.times and not t.flagged_steps
    # post-reset the estimate re-seeds from scratch: a step that would
    # have been flagged against the old mean passes quietly
    assert t.observe(9.0) is False
    assert t.mean == pytest.approx(9.0)


def test_straggler_timer_counts_flags_in_obs():
    from repro import obs
    before = obs.metrics_snapshot()
    t = straggler.StepTimer(window=50, threshold_std=3.0, min_steps=5)
    for _ in range(20):
        t.observe(0.1)
    t.observe(5.0)
    d = obs.metrics().delta(before)
    assert d["counters"]["straggler.flags"] == 1


def test_step_timer_wired_through_distributed_stream():
    """An injected StepTimer observes every distributed round."""
    from repro.core.models import DynGNNConfig
    from repro.data.dyngnn import synthetic_dataset
    from repro.launch.mesh import make_host_mesh
    from repro.stream import distributed as dist
    n, t_steps, nb = 48, 16, 2
    ds = synthetic_dataset(n, t_steps, density=2.0, churn=0.1,
                           smoothing_mode="mproduct", window=3, seed=0)
    cfg = DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=t_steps,
                       window=3, checkpoint_blocks=nb)
    timer = straggler.StepTimer(window=8)
    st = dist.train_distributed_streamed(
        cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
        np.asarray(ds.labels), mesh=make_host_mesh(data=4, model=1),
        num_epochs=2, step_timer=timer)
    assert st.step_timer is timer
    assert timer.step_idx == len(st.losses) == 2 * nb
    assert len(timer.times) == 2 * nb


def test_backup_shard_schedule():
    sched = straggler.BackupShardSchedule(num_workers=8, num_backups=2)
    times = [0.1] * 8
    times[3], times[5] = 0.9, 0.8
    plan = sched.plan(times)
    assert set(plan.keys()) == {3, 5}
    # backup shard cursor identical to the primary's (O(1) reassignment)
    assert sched.shard_for(3, 4) == (12, 4)


def test_int8_error_feedback_compression():
    """Compressed psum matches exact psum within quantization error, and
    error feedback drives the residual to track the truncation."""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=4, model=1)
    rng = np.random.default_rng(0)
    g_local = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))

    def fn(g):
        grads = {"w": g[0]}
        res = compression.init_residual(grads)
        red, new_res = compression.compressed_psum(grads, "data", res)
        return red["w"], new_res["w"]

    out, res = jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(P("data", None),),
        out_specs=(P(), P()), check_vma=False))(g_local)
    exact = np.asarray(g_local).mean(axis=0)
    np.testing.assert_allclose(np.asarray(out), exact, atol=0.05)
    # residual bounded by one quantization bucket
    assert float(jnp.max(jnp.abs(res))) < 0.05
