"""CTDG bridge unit tests: window-boundary semantics of both
discretization policies, EventStream validation errors, and the
alive-set bookkeeping the online ingester shares with the offline
discretizers."""

import numpy as np
import pytest

from repro.core import ctdg


def _stream(src, dst, time, kind, n=10):
    return ctdg.EventStream(np.asarray(src, np.int32),
                            np.asarray(dst, np.int32),
                            np.asarray(time, float),
                            np.asarray(kind, np.int8), n)


# ------------------------------------------------- window assignment --------

def test_uniform_bounds_cover_range():
    b = ctdg.uniform_bounds(0.0, 4.0, 4)
    np.testing.assert_allclose(b, [1.0, 2.0, 3.0, 4.0])


def test_snapshot_policy_boundary_event_closes_with_its_window():
    """Snapshot policy: an event AT a window's end bound belongs to that
    window (time <= bound consumption — the reference loop's rule)."""
    b = ctdg.uniform_bounds(0.0, 4.0, 2)          # bounds [2, 4]
    idx = ctdg.snapshot_window_index(np.array([0.0, 2.0, 2.0001, 4.0]), b)
    np.testing.assert_array_equal(idx, [0, 0, 1, 1])

    # end to end (bounds derive from the stream's own [0, 4] range, so
    # W=2 puts the mid bound at t=2): the edge inserted exactly at t=2
    # is alive in snapshot 0
    ev = _stream([1, 2, 3, 4], [4, 5, 6, 7], [0.0, 2.0, 3.0, 4.0],
                 [1, 1, 1, 1])
    snaps = ctdg.snapshot_events(ev, 2)
    assert set(map(tuple, snaps[0].tolist())) == {(1, 4), (2, 5)}
    assert set(map(tuple, snaps[1].tolist())) == \
        {(1, 4), (2, 5), (3, 6), (4, 7)}


def test_snapshot_policy_delete_at_boundary_applies_in_that_window():
    ev = _stream([1, 1], [4, 4], [0.0, 2.0], [1, -1])
    snaps = ctdg.snapshot_events(ev, 2)            # bounds [1, 2]
    assert snaps[0].tolist() == [[1, 4]]
    assert snaps[1].shape[0] == 0                  # deleted AT bound 2


def test_window_policy_boundary_binning_is_the_clip_formula():
    """Interaction policy: boundary times floor into the NEXT window
    (except t1, which clips into the last) — the exact offline rule."""
    idx = ctdg.interaction_window_index(
        np.array([0.0, 1.0, 2.5, 4.0]), 0.0, 4.0, 4)
    np.testing.assert_array_equal(idx, [0, 1, 2, 3])

    ev = _stream([1, 2, 3, 4], [5, 6, 7, 8], [0.0, 1.0, 2.5, 4.0],
                 [1, 1, 1, 1])
    win = ctdg.window_events(ev, 4)
    assert [w.tolist() for w in win] == [[[1, 5]], [[2, 6]], [[3, 7]],
                                         [[4, 8]]]


def test_window_policy_dedups_repeated_interactions():
    ev = _stream([1, 1, 2], [5, 5, 6], [0.0, 0.1, 0.9], [1, 1, 1])
    win = ctdg.window_events(ev, 2)
    assert win[0].tolist() == [[1, 5]]             # observed twice, once out
    assert win[1].tolist() == [[2, 6]]


def test_snapshot_events_match_bruteforce_reference():
    """Property: the AliveSet/searchsorted implementation equals a naive
    consume-loop reference (order included) over random streams."""
    for seed in range(4):
        stream = ctdg.synthetic_ctdg(24, 300, delete_frac=0.25, seed=seed)
        for w in (1, 3, 7):
            got = ctdg.snapshot_events(stream, w)
            ref = _brute_snapshots(stream, w)
            assert len(got) == len(ref) == w
            for g, r in zip(got, ref):
                np.testing.assert_array_equal(g, r)


def _brute_snapshots(stream, num_steps):
    ev = stream.sorted()
    bounds = np.linspace(float(ev.time[0]), float(ev.time[-1]),
                         num_steps + 1)[1:]
    alive, out, i, m = {}, [], 0, len(ev)
    for b in bounds:
        while i < m and ev.time[i] <= b:
            k = (int(ev.src[i]), int(ev.dst[i]))
            if ev.kind[i] > 0:
                alive[k] = alive.get(k, 0) + 1
            else:
                c = alive.get(k, 0) - 1
                if c <= 0:
                    alive.pop(k, None)
                else:
                    alive[k] = c
            i += 1
        out.append(np.array(list(alive.keys()), np.int32).reshape(-1, 2))
    return out


# ------------------------------------------------------- validation ---------

def test_validate_rejects_length_mismatch():
    ev = ctdg.EventStream(np.zeros(3, np.int32), np.zeros(2, np.int32),
                          np.zeros(3), np.ones(3, np.int8), 4)
    with pytest.raises(ValueError, match="must align"):
        ev.validate()


def test_validate_rejects_empty_stream():
    ev = ctdg.EventStream(*(np.zeros(0, np.int32),) * 2,
                          np.zeros(0), np.zeros(0, np.int8), 4)
    with pytest.raises(ValueError, match="empty"):
        ev.validate()


def test_validate_rejects_out_of_range_node_ids():
    with pytest.raises(ValueError, match=r"node id 10 outside"):
        _stream([0], [10], [0.0], [1]).validate()
    with pytest.raises(ValueError, match="num_nodes must be positive"):
        _stream([0], [0], [0.0], [1], n=0).validate()


def test_validate_rejects_bad_kinds_and_times():
    with pytest.raises(ValueError, match=r"\+1 .* or -1"):
        _stream([0, 1], [1, 2], [0.0, 1.0], [1, 2]).validate()
    with pytest.raises(ValueError, match="non-finite"):
        _stream([0], [1], [np.nan], [1]).validate()


def test_validate_require_sorted():
    ev = _stream([0, 1], [1, 2], [1.0, 0.5], [1, 1])
    with pytest.raises(ValueError, match="non-decreasing"):
        ev.validate(require_sorted=True)
    ev.validate()                                  # unsorted ok by default
    ev.sorted().validate(require_sorted=True)


def test_validate_rejects_delete_before_insert():
    with pytest.raises(ValueError, match="delete"):
        _stream([0, 0], [1, 1], [0.0, 1.0], [-1, 1]).validate()
    # double-delete of a once-inserted edge is also a net-negative
    with pytest.raises(ValueError, match="delete"):
        _stream([0, 0, 0], [1, 1, 1], [0.0, 1.0, 2.0],
                [1, -1, -1]).validate()
    # insert-delete-insert-delete is fine
    _stream([0, 0, 0, 0], [1, 1, 1, 1], [0.0, 1.0, 2.0, 3.0],
            [1, -1, 1, -1]).validate()


def test_alive_set_strict_rejects_unmatched_delete():
    alive = ctdg.AliveSet(8)
    alive.apply(np.array([1]), np.array([2]), np.array([1]))
    alive.apply(np.array([1]), np.array([2]), np.array([-1]), strict=True)
    with pytest.raises(ValueError, match="not.*alive"):
        alive.apply(np.array([1]), np.array([2]), np.array([-1]),
                    strict=True)


def test_num_steps_must_be_positive():
    ev = _stream([0], [1], [0.0], [1])
    with pytest.raises(ValueError, match="num_steps"):
        ctdg.snapshot_events(ev, 0)


def test_synthetic_ctdg_is_valid():
    for seed in range(3):
        ctdg.synthetic_ctdg(32, 400, delete_frac=0.3,
                            seed=seed).validate()
