"""repro.obs contract tests: tracer semantics (nesting, ring bounding,
thread safety, the disabled no-op), metrics registry deltas, Perfetto
export round-trip + schema validation, calibration against
``round_time_model``, and the end-to-end traced streamed_mesh fit that
the CI trace-smoke step gates on."""

import json
import threading

import numpy as np
import pytest

import jax

from repro import obs
from repro.core.models import DynGNNConfig
from repro.obs.trace import NULL_SPAN, Tracer
from repro.run import Engine, ExecutionPlan, RunConfig, SyntheticTrace

N, T, NB = 48, 16, 2


# --------------------------------------------------------------- tracer ----

def test_span_records_timing_and_attrs():
    trc = Tracer(enabled=True, fence=False)
    with trc.span("outer", round=3):
        with trc.span("inner", cat="sub"):
            pass
    spans = trc.spans()
    assert [s.name for s in spans] == ["inner", "outer"]  # exit order
    outer = spans[1]
    assert outer.attrs == {"round": 3}
    assert outer.dur_s >= spans[0].dur_s >= 0.0
    # containment on one thread: inner lies inside outer on the clock
    assert outer.start_s <= spans[0].start_s
    assert (outer.start_s + outer.dur_s
            >= spans[0].start_s + spans[0].dur_s)
    assert spans[0].tid == outer.tid == threading.get_ident()


def test_disabled_tracer_is_a_true_noop():
    trc = Tracer(enabled=False)
    sp = trc.span("anything", round=1)
    assert sp is NULL_SPAN              # shared object, no allocation
    with sp as s:
        assert s.fence("x") == "x"      # fence is identity
    assert trc.spans() == [] and trc.recorded == 0
    # the module-level helper takes the same fast path
    assert obs.span("x") is NULL_SPAN or obs.enabled()


def test_stopwatch_measures_even_when_disabled():
    trc = Tracer(enabled=False)
    with trc.stopwatch("work") as sw:
        sum(range(1000))
    assert sw.seconds > 0.0
    assert trc.spans() == []            # measured, but not recorded
    trc2 = Tracer(enabled=True, fence=False)
    with trc2.stopwatch("work", round=7) as sw2:
        pass
    (sp,) = trc2.spans()
    assert sp.name == "work" and sp.attrs == {"round": 7}
    assert sp.dur_s == sw2.seconds


def test_ring_bounds_and_counts_drops():
    trc = Tracer(enabled=True, capacity=8, fence=False)
    for i in range(22):
        with trc.span("s", i=i):
            pass
    assert len(trc.spans()) == 8
    assert trc.recorded == 22 and trc.dropped == 14
    # the ring keeps the newest spans
    assert [s.attrs["i"] for s in trc.spans()] == list(range(14, 22))


def test_tracer_thread_safety():
    trc = Tracer(enabled=True, capacity=10_000, fence=False)

    def worker(k):
        for i in range(100):
            with trc.span("t", k=k, i=i):
                pass

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert trc.recorded == 800 and trc.dropped == 0
    # all 8 workers' spans landed intact (tids may be reused by the OS)
    by_k = {k: 0 for k in range(8)}
    for s in trc.spans():
        by_k[s.attrs["k"]] += 1
    assert all(v == 100 for v in by_k.values())


def test_spans_since_checkpoint():
    trc = Tracer(enabled=True, fence=False)
    with trc.span("before"):
        pass
    mark = trc.recorded
    with trc.span("after"):
        pass
    assert [s.name for s in trc.spans_since(mark)] == ["after"]
    assert trc.summary(trc.spans_since(mark))["after"]["count"] == 1


# -------------------------------------------------------------- metrics ----

def test_metrics_inc_gauge_snapshot_delta():
    reg = obs.MetricsRegistry()
    reg.inc("a.count")
    reg.inc("a.count", 4)
    reg.gauge("b.level", 7.5)
    before = reg.snapshot()
    reg.inc("a.count", 2)
    reg.inc("c.new", 3)
    reg.gauge("b.level", 9.0)
    d = reg.delta(before)
    assert d["counters"] == {"a.count": 2, "c.new": 3}
    assert d["gauges"]["b.level"] == 9.0
    assert reg.get("a.count") == 7


def test_metrics_thread_safe_inc():
    reg = obs.MetricsRegistry()

    def worker():
        for _ in range(1000):
            reg.inc("n")

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.get("n") == 8000


def test_stream_report_mirrors_resync_counter():
    """Ad-hoc report counters and the obs registry stay in lockstep."""
    from repro.stream.encoder import ChurnOverflowError, StreamReport
    before = obs.metrics_snapshot()
    rep = StreamReport()
    rep.note_overflow(3, ChurnOverflowError(9, 2, 4, 4))
    d = obs.metrics().delta(before)
    assert d["counters"]["stream.resyncs"] == 1 == rep.resyncs


# --------------------------------------------------------------- export ----

@pytest.mark.parametrize("suffix", [".json", ".jsonl"])
def test_export_load_validate_roundtrip(tmp_path, suffix):
    trc = Tracer(enabled=True, fence=False)
    with trc.span("round", cat="round", round=0):
        with trc.span("round.transfer", round=0):
            pass
    path = tmp_path / f"trace{suffix}"
    obs.export_trace(path, tracer=trc,
                     metrics={"counters": {"stream.rounds": 1},
                              "gauges": {}})
    events, meta = obs.load_trace(path)
    assert obs.validate_trace(events) == []
    assert meta["format"] == "chrome-trace"
    assert meta["dropped_spans"] == 0
    by_ph = {}
    for ev in events:
        by_ph.setdefault(ev["ph"], []).append(ev)
    names = {ev["name"] for ev in by_ph["X"]}
    assert names == {"round", "round.transfer"}
    assert any(ev["name"] == "stream.rounds" for ev in by_ph["C"])
    assert any(ev["name"] == "thread_name" for ev in by_ph["M"])
    # timestamps are µs and the args carry the span attrs
    rnd = next(ev for ev in by_ph["X"] if ev["name"] == "round")
    assert rnd["args"]["round"] == 0 and rnd["dur"] >= 0


def test_validate_trace_catches_malformed_events(tmp_path):
    assert obs.validate_trace([]) == ["trace contains no events"]
    bad = [
        {"ph": "X", "ts": 0, "pid": 1, "tid": 1, "dur": 1},   # no name
        {"name": "a", "ph": "Z", "ts": 0, "pid": 1, "tid": 1},
        {"name": "b", "ph": "X", "ts": -5, "pid": 1, "tid": 1, "dur": 1},
        {"name": "c", "ph": "X", "ts": 0, "pid": 1, "tid": 1},  # no dur
        {"name": "d", "ph": "X", "ts": 0, "pid": 1, "tid": 1, "dur": 1,
         "args": "nope"},
    ]
    problems = obs.validate_trace(bad)
    assert len(problems) == 5
    # a hand-broken file fails through the same path the CI step runs
    p = tmp_path / "t.json"
    p.write_text(json.dumps({"traceEvents": bad}))
    events, _ = obs.load_trace(p)
    assert obs.validate_trace(events)


# ---------------------------------------------------------- calibration ----

def _synthetic_round_spans(trc, r, transfer, spatial, a2a, temporal,
                           extra=0.0):
    t0 = float(r)
    total = transfer + spatial + a2a + temporal + extra
    trc.add_span("round", t0, total, cat="round", round=r)
    off = 0.0
    for name, dur in (("transfer", transfer), ("spatial", spatial),
                      ("a2a", a2a), ("temporal", temporal)):
        trc.add_span(f"round.{name}", t0 + off, dur, round=r)
        off += dur


def test_calibration_zero_residual_on_model_exact_rounds():
    trc = Tracer(enabled=True, fence=False)
    for r in range(3):
        _synthetic_round_spans(trc, r, 0.010, 0.020, 0.008, 0.030)
    rep = obs.calibration_report(trc.spans())
    assert len(rep.rows) == 3 and rep.extra["skipped"] == 0
    for row in rep.rows:
        assert abs(row.residual_s) < 1e-9        # serial model is the sum
        assert all(abs(v) < 1e-9
                   for v in row.phase_residual_s.values())
    assert rep.baseline_s["spatial"] == pytest.approx(0.020)
    assert "3 rounds" in rep.summary()


def test_calibration_flags_straggler_phase_and_skips_incomplete():
    trc = Tracer(enabled=True, fence=False)
    for r in range(4):
        a2a = 0.008 if r != 2 else 0.020         # round 2 lost time in a2a
        _synthetic_round_spans(trc, r, 0.010, 0.020, a2a, 0.030)
    trc.add_span("round", 9.0, 0.1, cat="round", round=9)  # phases missing
    rep = obs.calibration_report(trc.spans())
    assert rep.extra["skipped"] == 1
    row = next(r_ for r_ in rep.rows if r_.round == 2)
    assert row.phase_residual_s["a2a"] == pytest.approx(0.012)
    assert row.phase_residual_s["temporal"] == pytest.approx(0.0)


def test_calibration_accepts_loaded_trace_events(tmp_path):
    trc = Tracer(enabled=True, fence=False)
    _synthetic_round_spans(trc, 0, 0.010, 0.020, 0.008, 0.030)
    path = tmp_path / "t.json"
    obs.export_trace(path, tracer=trc, metrics={})
    events, _ = obs.load_trace(path)
    rep = obs.calibration_report(events)
    assert len(rep.rows) == 1
    assert rep.rows[0].predicted_s == pytest.approx(0.068, rel=1e-6)


# ------------------------------------------------------------------ e2e ----

@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_traced_streamed_mesh_fit_exports_full_phase_coverage(tmp_path):
    """The acceptance path: a traced 4-shard fit yields all four
    round_time_model phases for every round, prefetch thread spans,
    RunResult.metrics, and a valid exported trace."""
    prev = obs.get_tracer()
    obs.configure(enabled=True)
    try:
        cfg = DynGNNConfig(model="tmgcn", num_nodes=N, num_steps=T,
                           window=3, checkpoint_blocks=NB)
        data = SyntheticTrace(num_nodes=N, num_steps=T, density=2.0,
                              churn=0.1, smoothing_mode="mproduct",
                              window=3)
        plan = ExecutionPlan(mode="streamed_mesh", shards=4, num_epochs=2)
        result = Engine(RunConfig(model=cfg, data=data, plan=plan)).fit()

        trc = obs.get_tracer()
        per_round = obs.phase_durations(trc.spans())
        rounds = sorted(per_round)
        assert len(rounds) == 2 * NB
        for r in rounds:
            missing = [p for p in obs.PHASES if p not in per_round[r]]
            assert not missing, f"round {r} missing phases {missing}"
            assert "round" in per_round[r]
        names = {s.name for s in trc.spans()}
        assert {"prefetch.stage", "prefetch.wait", "round.step"} <= names

        # session-scoped metrics landed on the result
        m = result.metrics
        assert m["counters"]["stream.rounds"] == 2 * NB
        assert m["counters"]["prefetch.items"] >= 2 * NB
        assert m["counters"]["stream.payload_bytes"] > 0
        assert m["spans"]["round"]["count"] == 2 * NB

        # calibration joins every complete round against the model
        rep = obs.calibration_report(trc.spans())
        assert len(rep.rows) == 2 * NB
        assert all(row.predicted_s > 0 for row in rep.rows)

        # and the whole thing survives the CI export -> check path
        path = tmp_path / "trace.json"
        obs.export_trace(path)
        events, _ = obs.load_trace(path)
        assert obs.validate_trace(events) == []
    finally:
        obs.set_tracer(prev)


def test_untraced_fit_records_no_spans_but_still_counts():
    """Tracing off (the default): zero spans, async schedule untouched,
    but counters and RunResult.metrics still work."""
    assert not obs.enabled()
    trc = obs.get_tracer()
    before = trc.recorded
    cfg = DynGNNConfig(model="cdgcn", num_nodes=N, num_steps=T,
                       window=3, checkpoint_blocks=NB)
    data = SyntheticTrace(num_nodes=N, num_steps=T, density=2.0,
                          churn=0.1, smoothing_mode="none", window=3)
    plan = ExecutionPlan(mode="streamed", shards=1, num_epochs=1)
    result = Engine(RunConfig(model=cfg, data=data, plan=plan)).fit()
    assert trc.recorded == before           # no span escaped the no-op
    assert result.metrics is not None
    assert result.metrics["spans"] == {}
    assert np.isfinite(result.losses).all()
