"""Hypothesis property sweeps for the int8 quantizer core
(``repro.dist.compression``) — the randomized side of the numerics tier.

* round-trip error <= scale/2 per element across magnitudes spanning six
  decades, wire dtype always int8;
* the error-feedback identity ``deq == (g + res) - new_res`` telescopes
  over any K steps: the transmitted sum equals the true sum plus the
  residual ledger delta, so truncation is carried, never dropped;
* per-piece quantization (the all-to-all wire layout) round-trips every
  piece within its own scale/2 for any legal piece count.

Deterministic corner cases (all-zero, denormal, ±inf) and the mesh tests
live in ``test_compression.py``, which runs without hypothesis.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.dist import compression

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31), n=st.integers(1, 400),
       scale_pow=st.integers(-3, 3))
def test_quantize_roundtrip_half_step(seed, n, scale_pow):
    rng = np.random.default_rng(seed)
    g = (rng.normal(size=(n,)) * 10.0 ** scale_pow).astype(np.float32)
    q, scale = compression.quantize(jnp.asarray(g))
    assert q.dtype == jnp.int8
    deq = np.asarray(compression.dequantize(q, scale))
    assert np.max(np.abs(deq - g)) <= 0.5 * float(scale) * (1 + 1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), k=st.integers(1, 10))
def test_error_feedback_telescopes(seed, k):
    rng = np.random.default_rng(seed)
    gs = rng.normal(size=(k, 64)).astype(np.float32)
    res = jnp.zeros((64,), jnp.float32)
    total = np.zeros((64,), np.float64)
    for g in gs:
        deq, res = compression.ef_quantize(jnp.asarray(g), res)
        total += np.asarray(deq, np.float64)
    want = gs.astype(np.float64).sum(axis=0) - np.asarray(res, np.float64)
    np.testing.assert_allclose(total, want, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), p=st.sampled_from([1, 2, 4, 8]),
       rows=st.integers(1, 4))
def test_per_piece_quantization_roundtrip(seed, p, rows):
    """Each destination piece round-trips within ITS OWN scale/2 — a
    hot piece must not inflate the error of a quiet one."""
    rng = np.random.default_rng(seed)
    mags = 10.0 ** rng.integers(-2, 3, size=p)
    y = (rng.normal(size=(p * rows, 6)) *
         np.repeat(mags, rows)[:, None]).astype(np.float32)
    q, scales = compression._quantize_pieces(jnp.asarray(y), p, 0)
    deq = np.asarray(compression._dequantize_pieces(q, scales, p, 0))
    for i in range(p):
        piece = slice(i * rows, (i + 1) * rows)
        err = np.max(np.abs(deq[piece] - y[piece]))
        assert err <= 0.5 * float(scales[i]) * (1 + 1e-5)
