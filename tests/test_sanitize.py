"""Runtime sanitizer tests (``repro.sanitize``).

The DonationGuard must make use-after-donation bugs fail loudly on the
host CPU backend — where XLA donation is a no-op and the bug class is
otherwise invisible — and the ThreadAffinityGuard must reject (and
count) concurrent entry into the ServeEngine's resident state.
"""

import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import sanitize
from repro.core import ctdg
from repro.core import models as mdl
from repro.core.graphdiff import FullSnapshot, SnapshotDelta
from repro.serve import IngestSpec, ServeConfig, ServeEngine
from repro.stream.prefetch import DeltaApplier, SlotStacker


# The guard is tested against a NON-donating jit: it must enforce the
# donation contract on the Python references itself, independent of
# whether this backend/jax version invalidates donated args natively.
def _plain_step():
    return jax.jit(lambda buf, y: buf + y)


# ------------------------------------------------------ DonationGuard -------

def test_donation_guard_poisons_donated_input():
    step = sanitize.DonationGuard(_plain_step(), (0,), enabled=True)
    buf = jnp.arange(4.0)
    y = jnp.ones(4)
    out = step(buf, y)
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0) + 1.0)
    assert buf.is_deleted()
    with pytest.raises(RuntimeError):
        np.asarray(buf)          # the stale read raises at the exact line
    assert not y.is_deleted()    # non-donated args untouched


def test_donation_guard_off_is_passthrough():
    step = sanitize.DonationGuard(_plain_step(), (0,), enabled=False)
    buf = jnp.arange(4.0)
    step(buf, jnp.ones(4))
    assert not buf.is_deleted()
    np.testing.assert_allclose(np.asarray(buf), np.arange(4.0))


def test_guard_donated_reads_env(monkeypatch):
    fn = _plain_step()
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert sanitize.guard_donated(fn, (0,)) is fn
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert sanitize.guard_donated(fn, (0,)) is fn
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    guarded = sanitize.guard_donated(fn, (0,))
    assert isinstance(guarded, sanitize.DonationGuard)
    assert guarded.enabled and guarded.donate_argnums == (0,)


def _full(e_max=8, num_edges=3):
    edges = np.zeros((e_max, 2), np.int32)
    edges[:num_edges] = [[0, 1], [1, 2], [2, 3]]
    mask = np.zeros((e_max,), np.float32)
    mask[:num_edges] = 1.0
    values = mask.copy()
    return FullSnapshot(edges, mask, values, num_edges)


def _delta(e_max=8, d_max=2, a_max=2):
    return SnapshotDelta(
        drop_pos=np.zeros((d_max,), np.int32),
        drop_mask=np.zeros((d_max,), np.float32),
        add_edges=np.zeros((a_max, 2), np.int32),
        add_mask=np.zeros((a_max,), np.float32),
        values=np.ones((e_max,), np.float32),
        num_edges=3)


def test_delta_applier_stale_alias_raises_under_sanitize(monkeypatch):
    """The ring contract made executable: aliases returned by ``consume``
    are invalidated by the next delta consume, and under REPRO_SANITIZE=1
    the stale read raises instead of silently returning old memory."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    app = DeltaApplier(max_edges=8)
    e1, m1, _ = app.consume(_full())
    app.consume(_delta())           # donates the previous ring buffers
    assert e1.is_deleted() and m1.is_deleted()
    with pytest.raises(RuntimeError):
        np.asarray(e1)


def test_slot_stacker_copies_survive_sanitized_ring(monkeypatch):
    """SlotStacker copies slots out before the next consume, so its
    blocks stay valid even when the ring is poisoned behind it."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    app = DeltaApplier(max_edges=8)
    stack = SlotStacker(slots=2)
    stack.put(0, *app.consume(_full()))
    stack.put(1, *app.consume(_delta()))
    app.consume(_delta())           # retires the slot-1 ring buffers
    es, ms, vs = stack.arrays()
    assert es.shape == (2, 8, 2) and ms.shape == (2, 8)
    np.testing.assert_allclose(np.asarray(ms[0]),
                               np.asarray(_full().mask))


# -------------------------------------------------- ThreadAffinityGuard -----

def test_affinity_guard_same_thread_reentrant():
    g = sanitize.ThreadAffinityGuard("test")
    with g:
        with g:                      # advance() -> flush() re-entry
            pass
        assert g._depth == 1
    assert g._owner is None and g.trips == 0


def test_affinity_guard_cross_thread_entry_trips():
    g = sanitize.ThreadAffinityGuard("test")
    errs: list[BaseException] = []

    def intrude():
        try:
            with g:
                pass
        except RuntimeError as e:
            errs.append(e)

    with g:
        t = threading.Thread(target=intrude)
        t.start()
        t.join()
    assert len(errs) == 1 and "concurrent entry" in str(errs[0])
    assert g.trips == 1
    # released: re-entry is clean again and the trip count is sticky
    with g:
        pass
    assert g.trips == 1


# ------------------------------------------------ ServeEngine integration ---

def test_serve_engine_rejects_concurrent_entry_and_counts_it():
    n, w = 16, 4
    stream = ctdg.synthetic_ctdg(n, 120, delete_frac=0.25, seed=3).sorted()
    cfg = mdl.DynGNNConfig(model="cdgcn", num_nodes=n, num_steps=w,
                           window=2, checkpoint_blocks=2)
    spec = IngestSpec(num_windows=w,
                      time_range=(float(stream.time.min()),
                                  float(stream.time.max())),
                      block_size=2, max_edges=256)
    eng = ServeEngine(ServeConfig(model=cfg, ingest=spec),
                      params=mdl.init_params(jax.random.PRNGKey(5), cfg))
    eng.ingest(stream)

    errs: list[BaseException] = []

    def intrude():
        try:
            eng.ingest(stream)
        except RuntimeError as e:
            errs.append(e)

    with eng._guard:                 # main thread holds the resident state
        t = threading.Thread(target=intrude)
        t.start()
        t.join()
    assert len(errs) == 1 and "ServeEngine" in str(errs[0])
    assert eng.result().guard_trips == 1

    # single-threaded use is unaffected after the trip
    eng.advance_all()
    scores = eng.query_nodes(np.arange(n))
    assert scores.shape[0] == n
    assert eng.result().guard_trips == 1
