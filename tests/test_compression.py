"""int8 error-feedback compression tests (``repro.dist.compression``).

Pinned claims:

* quantize/dequantize error is bounded by half a quantization step
  (scale = absmax/127) per element, and the wire dtype is int8;
* ``compressed_psum`` satisfies the error-feedback identity exactly —
  reduced mean == mean over shards of (g + residual_in - residual_out) —
  so the truncation error is carried, never dropped;
* with a constant gradient the time-average of the compressed reduction
  converges to the true mean at rate residual/K (no accumulating bias),
  and the residual itself stays bounded by one quantization step.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.dist import compression

PARTS = 4


def _mesh():
    devs = jax.devices()
    if len(devs) < PARTS:
        pytest.skip(f"needs {PARTS} devices")
    return Mesh(np.array(devs[:PARTS]), ("data",))


def _reducer(mesh):
    return shard_map(
        lambda g, r: compression.compressed_psum(g, "data", r),
        mesh=mesh,
        in_specs=(P("data", None), P("data", None)),
        out_specs=(P("data", None), P("data", None)),
        check_vma=False)


# ---------------------------------------------------------- round-trip ------

def test_int8_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (257,), jnp.float32) * 3.0
    q, scale = compression._quantize(g)
    assert q.dtype == jnp.int8
    deq = q.astype(jnp.float32) * scale
    step = float(np.max(np.abs(np.asarray(g)))) / 127.0
    assert np.isclose(float(scale), step, rtol=1e-6)
    err = np.max(np.abs(np.asarray(deq) - np.asarray(g)))
    assert err <= 0.5 * step + 1e-7


def test_quantize_zero_gradient_is_safe():
    q, scale = compression._quantize(jnp.zeros((8,), jnp.float32))
    assert float(scale) > 0.0            # clamped off zero: no NaN divide
    assert np.all(np.asarray(q) == 0)


# ------------------------------------------------- error-feedback psum ------

def test_compressed_psum_error_feedback_identity():
    mesh = _mesh()
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (PARTS, 32), jnp.float32))
    res0 = np.zeros_like(g)
    red, res1 = _reducer(mesh)(jnp.asarray(g), jnp.asarray(res0))
    red, res1 = np.asarray(red), np.asarray(res1)
    # psum output is replicated: every shard row carries the same mean
    np.testing.assert_allclose(red, np.broadcast_to(red[0], red.shape),
                               atol=0)
    # exact identity: what was reduced is what left the residual ledger
    np.testing.assert_allclose(red[0], (g + res0 - res1).mean(axis=0),
                               atol=1e-5)


def test_compressed_psum_accumulation_converges_unbiased():
    mesh = _mesh()
    reduce_ = _reducer(mesh)
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(2),
                                     (PARTS, 32), jnp.float32))
    true_mean = g.mean(axis=0)
    step = np.abs(g).max() / 127.0

    res = jnp.zeros_like(jnp.asarray(g))
    reds = []
    n_steps = 8
    for _ in range(n_steps):
        red, res = reduce_(jnp.asarray(g), res)
        reds.append(np.asarray(red)[0])
        # residual bounded by ~half a quantization step, forever
        assert np.max(np.abs(np.asarray(res))) <= step

    # sum_k red_k = K * true_mean - mean(res_K): averaging over steps
    # kills the truncation at rate 1/K — error feedback carries it all
    avg = np.mean(reds, axis=0)
    np.testing.assert_allclose(avg, true_mean, atol=step / n_steps + 1e-6)
    # and a single step is already within one quantization step
    np.testing.assert_allclose(reds[0], true_mean, atol=step + 1e-6)


def test_compressed_psum_preserves_tree_structure():
    mesh = _mesh()
    tree = {"w": jnp.ones((PARTS, 8), jnp.float32),
            "b": jnp.full((PARTS, 2), 2.0, jnp.float32)}
    res = compression.init_residual(tree)
    assert jax.tree.structure(res) == jax.tree.structure(tree)
    f = shard_map(
        lambda g, r: compression.compressed_psum(g, "data", r),
        mesh=mesh,
        in_specs=(P("data", None), P("data", None)),
        out_specs=(P("data", None), P("data", None)),
        check_vma=False)
    red, new_res = f(tree, res)
    assert jax.tree.structure(red) == jax.tree.structure(tree)
    # identical shards quantize exactly: mean == the common value
    np.testing.assert_allclose(np.asarray(red["w"]), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(red["b"]), 2.0, atol=1e-5)
