"""int8 error-feedback compression tests (``repro.dist.compression``).

Pinned claims:

* quantize/dequantize error is bounded by half a quantization step
  (scale = absmax/127) per element, and the wire dtype is int8
  (randomized property sweeps live in ``test_compression_props.py``
  behind the hypothesis guard);
* absmax edge cases are safe: all-zero tensors quantize to zeros with a
  positive scale, denormal inputs stay finite, ±inf saturates to ±127
  without manufacturing NaN;
* ``compressed_psum`` satisfies the error-feedback identity through a real
  ``shard_map`` psum, converges unbiased under accumulation, and
  preserves pytree structure;
* ``make_quantized_a2a`` ships exactly what the residual ledger says it
  shipped (output == plain all-to-all of ``y + res - new_res``,
  bitwise), preserves the input dtype, and its custom backward tracks
  the plain all-to-all gradient to within quantization error.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.dist import compression

PARTS = 4


def _mesh():
    devs = jax.devices()
    if len(devs) < PARTS:
        pytest.skip(f"needs {PARTS} devices")
    return Mesh(np.array(devs[:PARTS]), ("data",))


def _reducer(mesh):
    return shard_map(
        lambda g, r: compression.compressed_psum(g, "data", r),
        mesh=mesh,
        in_specs=(P("data", None), P("data", None)),
        out_specs=(P("data", None), P("data", None)),
        check_vma=False)


# ---------------------------------------------------------- round-trip ------

def test_int8_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (257,), jnp.float32) * 3.0
    q, scale = compression._quantize(g)
    assert q.dtype == jnp.int8
    deq = q.astype(jnp.float32) * scale
    step = float(np.max(np.abs(np.asarray(g)))) / 127.0
    assert np.isclose(float(scale), step, rtol=1e-6)
    err = np.max(np.abs(np.asarray(deq) - np.asarray(g)))
    assert err <= 0.5 * step + 1e-7


def test_quantize_zero_gradient_is_safe():
    q, scale = compression._quantize(jnp.zeros((8,), jnp.float32))
    assert float(scale) > 0.0            # clamped off zero: no NaN divide
    assert np.all(np.asarray(q) == 0)


# ------------------------------------------------- quantizer corners -------

def test_quantize_absmax_edge_cases():
    # all-zero: positive clamped scale, zero payload (no 0/0 NaN)
    q, scale = compression.quantize(jnp.zeros((4,), jnp.float32))
    assert float(scale) > 0.0 and np.all(np.asarray(q) == 0)
    # denormal absmax: scale clamps to tiny, nothing overflows to inf/NaN
    tiny = np.float32(1e-42)             # subnormal in f32
    q, scale = compression.quantize(jnp.full((4,), tiny))
    deq = np.asarray(compression.dequantize(q, scale))
    assert np.all(np.isfinite(deq))
    # ±inf: inf/clamped-finite-scale clips cleanly to ±127, never NaN
    g = jnp.asarray([np.inf, -np.inf, 1.0, -1.0], jnp.float32)
    q, scale = compression.quantize(g)
    qn = np.asarray(q)
    assert qn[0] == 127 and qn[1] == -127
    assert not np.any(np.isnan(np.asarray(compression.dequantize(q, scale))))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ef_quantize_residual_dtype(dtype):
    """Residuals accumulate in f32 regardless of the payload dtype (a
    bf16 residual would round away exactly the error it must carry)."""
    g = jnp.linspace(-1.0, 1.0, 16).astype(dtype)
    deq, res = compression.ef_quantize(g, jnp.zeros((16,), jnp.float32))
    assert deq.dtype == jnp.float32
    assert res.dtype == jnp.float32


# ------------------------------------------------- error-feedback psum ------

def test_compressed_psum_error_feedback_identity():
    mesh = _mesh()
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (PARTS, 32), jnp.float32))
    res0 = np.zeros_like(g)
    red, res1 = _reducer(mesh)(jnp.asarray(g), jnp.asarray(res0))
    red, res1 = np.asarray(red), np.asarray(res1)
    # psum output is replicated: every shard row carries the same mean
    np.testing.assert_allclose(red, np.broadcast_to(red[0], red.shape),
                               atol=0)
    # exact identity: what was reduced is what left the residual ledger
    np.testing.assert_allclose(red[0], (g + res0 - res1).mean(axis=0),
                               atol=1e-5)


def test_compressed_psum_accumulation_converges_unbiased():
    mesh = _mesh()
    reduce_ = _reducer(mesh)
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(2),
                                     (PARTS, 32), jnp.float32))
    true_mean = g.mean(axis=0)
    step = np.abs(g).max() / 127.0

    res = jnp.zeros_like(jnp.asarray(g))
    reds = []
    n_steps = 8
    for _ in range(n_steps):
        red, res = reduce_(jnp.asarray(g), res)
        reds.append(np.asarray(red)[0])
        # residual bounded by ~half a quantization step, forever
        assert np.max(np.abs(np.asarray(res))) <= step

    # sum_k red_k = K * true_mean - mean(res_K): averaging over steps
    # kills the truncation at rate 1/K — error feedback carries it all
    avg = np.mean(reds, axis=0)
    np.testing.assert_allclose(avg, true_mean, atol=step / n_steps + 1e-6)
    # and a single step is already within one quantization step
    np.testing.assert_allclose(reds[0], true_mean, atol=step + 1e-6)


def test_compressed_psum_preserves_tree_structure():
    mesh = _mesh()
    tree = {"w": jnp.ones((PARTS, 8), jnp.float32),
            "b": jnp.full((PARTS, 2), 2.0, jnp.float32)}
    res = compression.init_residual(tree)
    assert jax.tree.structure(res) == jax.tree.structure(tree)
    f = shard_map(
        lambda g, r: compression.compressed_psum(g, "data", r),
        mesh=mesh,
        in_specs=(P("data", None), P("data", None)),
        out_specs=(P("data", None), P("data", None)),
        check_vma=False)
    red, new_res = f(tree, res)
    assert jax.tree.structure(red) == jax.tree.structure(tree)
    # identical shards quantize exactly: mean == the common value
    np.testing.assert_allclose(np.asarray(red["w"]), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(red["b"]), 2.0, atol=1e-5)


# ------------------------------------------------ quantized all-to-all ------

def _y_res(seed, dtype=jnp.float32):
    """Global (PARTS*2, PARTS*3, 4) activation + f32 residual."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    shape = (PARTS * 2, PARTS * 3, 4)
    y = (jax.random.normal(k1, shape, jnp.float32) * 2.0).astype(dtype)
    res = jax.random.normal(k2, shape, jnp.float32) * 0.01
    return y, res


def test_quantized_a2a_matches_residual_ledger_exactly():
    """The a2a output IS the plain all-to-all of what the ledger says was
    shipped (y + res - new_res), bitwise: scales travel with their
    pieces, so remote dequantization reproduces the local ``sent``."""
    mesh = _mesh()
    qa2a = compression.make_quantized_a2a("data", PARTS, 1, 0)

    def body(y, res):
        out, new_res = qa2a(y, res)
        ref = jax.lax.all_to_all(
            y.astype(jnp.float32) + res - new_res, "data",
            split_axis=1, concat_axis=0, tiled=True)
        return out, new_res, ref

    y, res = _y_res(3)
    out, new_res, ref = shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data"), P("data")),
        check_vma=False)(y, res)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # the residual is bounded by half a per-piece quantization step
    step = float(jnp.max(jnp.abs(y.astype(jnp.float32) + res))) / 127.0
    assert float(jnp.max(jnp.abs(new_res))) <= 0.5 * step * (1 + 1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantized_a2a_preserves_payload_dtype(dtype):
    mesh = _mesh()
    qa2a = compression.make_quantized_a2a("data", PARTS, 1, 0)
    y, res = _y_res(5, dtype)
    out, new_res = shard_map(
        qa2a, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data")), check_vma=False)(y, res)
    assert out.dtype == dtype
    assert new_res.dtype == jnp.float32


def test_quantized_a2a_gradient_tracks_plain_a2a():
    """The custom backward (transposed quantized a2a) agrees with the
    plain all-to-all gradient to within one quantization step."""
    mesh = _mesh()
    qa2a = compression.make_quantized_a2a("data", PARTS, 1, 0)

    def loss_q(y, res):
        out, _ = qa2a(y, res)
        return jax.lax.psum(jnp.sum(jnp.sin(out)), "data")

    def loss_ref(y, res):
        out = jax.lax.all_to_all(y, "data", split_axis=1, concat_axis=0,
                                 tiled=True)
        return jax.lax.psum(jnp.sum(jnp.sin(out)), "data")

    y, res = _y_res(7)
    res = jnp.zeros_like(res)
    grads = {}
    for name, fn in (("q", loss_q), ("ref", loss_ref)):
        g = shard_map(
            jax.grad(fn), mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=P("data"), check_vma=False)(y, res)
        grads[name] = np.asarray(g)
    # two quantization perturbations stack: the forward error moves
    # cos(out) by ~one activation step and the backward quantizes the
    # cotangent itself — both a small multiple of step ~ absmax/127
    # (|cos| <= 1 here, so absolute tolerances are honest)
    diff = np.abs(grads["q"] - grads["ref"])
    assert diff.max() <= 0.2
    assert diff.mean() <= 0.05
