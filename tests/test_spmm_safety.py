"""Regression tests for the segment_spmm safety fixes (PR 2 satellites).

No hypothesis dependency (unlike test_kernels.py) so these always run:
* interpret resolution — the "Pallas" path must never silently interpret on
  a real accelerator backend, and must interpret on CPU;
* bucketing overflow — tight edges_per_block budgets are detected (via
  checkify) and recoverable (dense fallback), never silently wrong.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import checkify

from repro.core import gcn as gcnlib
from repro.kernels.common import resolve_interpret
from repro.kernels.segment_spmm import ops as spmm_ops

N, E, F = 192, 800, 64


def _graph(seed=0, skewed=False):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, N, size=(E,))
    dst = np.zeros((E,), np.int64) if skewed else rng.integers(0, N, (E,))
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    w = rng.normal(size=(E,)).astype(np.float32)
    x = rng.normal(size=(N, F)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(edges), jnp.asarray(w)


# ------------------------------------------------- interpret resolution ----

def test_interpret_resolves_from_backend(monkeypatch):
    """None -> interpret on CPU, compiled kernel everywhere else."""
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert resolve_interpret(None) is True
    for backend in ("tpu", "gpu", "cuda"):
        monkeypatch.setattr(jax, "default_backend", lambda b=backend: b)
        assert resolve_interpret(None) is False, backend


def test_segment_spmm_default_interpret_runs_on_cpu():
    """The default (interpret=None) path must work on the CPU backend and
    match the oracle — i.e. resolution actually reaches pallas_call."""
    assert jax.default_backend() == "cpu"
    x, edges, w = _graph()
    got = spmm_ops.segment_spmm(x, edges, w, N)
    want = spmm_ops.segment_spmm_ref(x, edges, w, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_spatial_aggregate_threads_interpret():
    """core.gcn.spatial_aggregate forwards the flag to the kernel wrapper."""
    x, edges, w = _graph(seed=1)
    got = gcnlib.spatial_aggregate(x, edges, w, N, use_pallas=True,
                                   interpret=True)
    want = gcnlib.spatial_aggregate(x, edges, w, N, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------- bucketing overflow ----

def test_overflow_count_zero_for_default_budget():
    x, edges, w = _graph(seed=2, skewed=True)
    cnt = spmm_ops.bucket_overflow_count(edges, w, N, jnp.int32(E))
    assert int(cnt) == 0


def test_overflow_count_ignores_zero_weight_padding():
    """Padded lanes (weight 0) beyond the budget are a lossless drop."""
    x, edges, w = _graph(seed=3, skewed=True)
    cnt_real = int(spmm_ops.bucket_overflow_count(edges, w, N,
                                                  jnp.int32(128)))
    cnt_pad = int(spmm_ops.bucket_overflow_count(edges, jnp.zeros_like(w),
                                                 N, jnp.int32(128)))
    assert cnt_real > 0
    assert cnt_pad == 0


def test_tight_budget_overflow_surfaces_via_checkify():
    """A skewed destination distribution with a stats-sized budget raises
    under checkify instead of silently dropping edges."""
    x, edges, w = _graph(seed=4, skewed=True)
    fn = checkify.checkify(
        lambda xx, ee, ww: spmm_ops.segment_spmm(xx, ee, ww, N,
                                                 edges_per_block=128),
        errors=checkify.all_checks)
    err, _ = fn(x, edges, w)
    with pytest.raises(checkify.JaxRuntimeError,
                       match="overflow edges_per_block"):
        err.throw()
    # the safe default budget passes the same check
    fn_ok = checkify.checkify(
        lambda xx, ee, ww: spmm_ops.segment_spmm(xx, ee, ww, N),
        errors=checkify.all_checks)
    err_ok, out = fn_ok(x, edges, w)
    err_ok.throw()
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(spmm_ops.segment_spmm_ref(x, edges, w,
                                                              N)),
        rtol=1e-4, atol=1e-4)


def test_checked_wrapper_falls_back_dense_on_overflow():
    x, edges, w = _graph(seed=5, skewed=True)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = spmm_ops.segment_spmm_checked(x, edges, w, N,
                                            edges_per_block=128)
    assert any("falling back" in str(r.message) for r in rec)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(spmm_ops.segment_spmm_ref(x, edges, w, N)),
        rtol=1e-4, atol=1e-4)


def test_checked_wrapper_stays_on_kernel_when_budget_fits():
    x, edges, w = _graph(seed=6, skewed=False)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = spmm_ops.segment_spmm_checked(x, edges, w, N,
                                            edges_per_block=E)
    assert not any("falling back" in str(r.message) for r in rec)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(spmm_ops.segment_spmm_ref(x, edges, w, N)),
        rtol=1e-4, atol=1e-4)
