"""Elastic rescale subsystem (``repro.elastic``) acceptance suite.

The hard invariant: rescaling is SCHEDULE, never math.  A scripted
mid-run rescale (P=4 -> 8 -> 2 on the 8-host-device mesh), a SIGTERM
shrink, and a preempt -> checkpoint -> resume-on-a-different-P sequence
must all reproduce the serial single-device slice reference at block
granularity (<= 1e-5 relative).  Plus: the ``RescaleReport`` byte
accounting matches ``dist.comm_volume.rescale_payload``, the stream
recomposer's from-boundary re-slices equal the tail of a from-zero
encoding, and the plan/controller validation rejects unrealizable
policies loudly.
"""

import os
import signal
import tempfile

import jax
import numpy as np
import pytest

from repro import elastic as el
from repro.core import models as mdl
from repro.core.graphdiff import FullSnapshot
from repro.core.models import DynGNNConfig
from repro.data.dyngnn import DTDGPipeline, synthetic_dataset
from repro.dist import comm_volume as cv
from repro.optim import adamw
from repro.run import (CheckpointSpec, Engine, ExecutionPlan, InMemoryDTDG,
                       RunConfig)
from repro.stream import encoder as enc
from repro.stream import sharded as stream_sharded
from repro.stream import train_loop as stream_train

N, T, NB = 48, 16, 2
WIN = T // NB                      # 8 snapshots per round; rpe = 2


def _silent(_msg):
    return None


@pytest.fixture(scope="module")
def _trace():
    ds = synthetic_dataset(N, T, density=2.0, churn=0.1,
                           smoothing_mode="mproduct", window=3, seed=0)
    cfg = DynGNNConfig(model="tmgcn", num_nodes=N, num_steps=T, window=3,
                       checkpoint_blocks=NB)
    return cfg, ds, DTDGPipeline(ds, nb=NB)


@pytest.fixture(scope="module")
def _serial_ref(_trace):
    """Single-device slice-granularity reference over 2 epochs."""
    cfg, ds, _ = _trace
    st = stream_train.train_streamed(
        cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
        np.asarray(ds.labels), num_epochs=2, overlap=False, slice_len=WIN)
    return st.losses


def _engine(cfg, ds, pipe, plan, **kw):
    kw.setdefault("log_fn", _silent)
    return Engine(RunConfig(model=cfg, data=InMemoryDTDG(ds, pipeline=pipe),
                            plan=plan, **kw))


def _expected_bytes():
    """Carry/state byte totals of the test model, computed independently
    of the run (the report must match comm_volume.rescale_payload on
    exactly these)."""
    cfg = DynGNNConfig(model="tmgcn", num_nodes=N, num_steps=T, window=3,
                       checkpoint_blocks=NB)
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    carry_b = el.tree_bytes(mdl.init_carries(cfg, params))
    state_b = el.tree_bytes(params) + el.tree_bytes(
        adamw.init_state(params))
    return carry_b, state_b


# ------------------------------------------------ acceptance: equivalence --

@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
@pytest.mark.parametrize("pipeline", [False, True])
def test_scripted_rescale_4_8_2_matches_serial_reference(_trace,
                                                         _serial_ref,
                                                         pipeline):
    """The acceptance bar: P=4 -> 8 -> 2 mid-run (boundaries at global
    rounds 1 and 3, both mid-epoch), with and without the chunked-round
    pipeline, reproduces the serial reference loss stream at <= 1e-5
    relative, and the RunResult's RescaleReport records every event with
    re-shard bytes matching dist.comm_volume.rescale_payload."""
    cfg, ds, pipe = _trace
    res = _engine(cfg, ds, pipe, ExecutionPlan(
        mode="streamed_mesh", shards=4, num_epochs=2,
        rescale=((1, 8), (3, 2)),
        a2a_chunks=2 if pipeline else 1,
        pipeline_rounds=pipeline)).fit()
    assert len(res.losses) == len(_serial_ref) == 2 * NB
    np.testing.assert_allclose(res.losses, _serial_ref, rtol=1e-5)

    rep = res.rescale_report
    assert [(e.block, e.old_p, e.new_p) for e in rep.events] == \
        [(1, 4, 8), (3, 8, 2)]
    assert rep.widths == [4, 8, 2]
    carry_b, state_b = _expected_bytes()
    assert rep.events[0].payload_bytes == int(
        cv.rescale_payload(carry_b, state_b, 4, 8))
    assert rep.events[1].payload_bytes == int(
        cv.rescale_payload(carry_b, state_b, 8, 2))
    assert all(e.recompose_s >= 0 for e in rep.events)
    # per-segment stream accounting: one entry per constant-width stretch
    assert [(s[0], s[1]) for s in rep.segments] == \
        [(0, 4), (1, 8), (2, 8), (3, 2)]
    for _start, p, per_shard in rep.segments:
        assert len(per_shard) == p and all(b > 0 for b in per_shard)


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_direct_elastic_loop_matches_reference(_trace, _serial_ref):
    """train_elastic_streamed driven directly (no Engine): same
    invariant, and the final params match a fixed-width run's shapes."""
    cfg, ds, _ = _trace
    ctrl = el.RescaleController(initial_p=2, schedule=((2, 4),))
    st = el.train_elastic_streamed(
        cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
        np.asarray(ds.labels), controller=ctrl, num_epochs=2)
    assert st.completed and st.cursor == 4
    np.testing.assert_allclose(st.losses, _serial_ref, rtol=1e-5)
    assert [(e.old_p, e.new_p) for e in st.report.events] == [(2, 4)]


# ------------------------------------------- preemption: shrink and stop ---

@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_preemption_shrink_continues_at_lower_width(_trace, _serial_ref):
    """SIGTERM with rescale_on_preempt set: the run absorbs the capacity
    loss at the next block boundary and completes — losses unchanged."""
    cfg, ds, pipe = _trace
    sent = []

    def killer(msg):
        if "dist stream round" in msg and not sent:
            sent.append(1)
            os.kill(os.getpid(), signal.SIGTERM)

    res = _engine(cfg, ds, pipe,
                  ExecutionPlan(mode="streamed_mesh", shards=4,
                                num_epochs=2, rescale_on_preempt=2),
                  log_fn=killer, log_every=1).fit()
    np.testing.assert_allclose(res.losses, _serial_ref, rtol=1e-5)
    rep = res.rescale_report
    assert not rep.preempted                  # absorbed, not stopped
    assert len(rep.events) == 1
    ev = rep.events[0]
    assert ev.cause == "preemption" and ev.new_p == 2 and ev.old_p == 4


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_preempt_checkpoint_resume_onto_larger_mesh(_trace, _serial_ref):
    """The end-to-end fault-tolerance path: SIGTERM mid-fit saves a
    checkpoint with the data cursor; Engine.resume restores it onto a
    DIFFERENT width (P=4 checkpoint -> P=8 mesh) and the concatenated
    loss stream equals the uninterrupted run's."""
    cfg, ds, pipe = _trace
    tmp = tempfile.mkdtemp()
    sent = []

    def killer(msg):
        if "dist stream round" in msg and not sent:
            sent.append(1)
            os.kill(os.getpid(), signal.SIGTERM)

    first = _engine(cfg, ds, pipe,
                    ExecutionPlan(mode="streamed_mesh", shards=4,
                                  num_epochs=2),
                    checkpoint=CheckpointSpec(tmp, every=100),
                    log_fn=killer, log_every=1).fit()
    assert first.rescale_report.preempted
    assert 0 < len(first.losses) < 2 * NB
    assert first.state.step == len(first.losses)

    resumed = _engine(cfg, ds, pipe,
                      ExecutionPlan(mode="streamed_mesh", shards=8,
                                    num_epochs=2),
                      checkpoint=CheckpointSpec(tmp, every=100)).resume()
    assert resumed.rescale_report.resumed_from == first.state.step
    assert resumed.state.step == 2 * NB
    np.testing.assert_allclose(first.losses + resumed.losses, _serial_ref,
                               rtol=1e-5)


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_checkpointed_run_matches_plain_and_periodic_saves(_trace):
    """A CheckpointSpec on a fixed-width streamed_mesh plan is pure
    schedule: losses identical to the uncheckpointed run, per-shard byte
    accounting intact, and every round boundary saved (every=1)."""
    from repro.ckpt.checkpoint import Checkpointer
    cfg, ds, pipe = _trace
    plain = _engine(cfg, ds, pipe,
                    ExecutionPlan(mode="streamed_mesh", shards=4,
                                  num_epochs=2)).fit()
    assert plain.rescale_report is None       # legacy path untouched
    tmp = tempfile.mkdtemp()
    ck = _engine(cfg, ds, pipe,
                 ExecutionPlan(mode="streamed_mesh", shards=4,
                               num_epochs=2),
                 checkpoint=CheckpointSpec(tmp, every=1)).fit()
    assert ck.losses == plain.losses
    assert ck.per_shard_bytes is not None
    assert sum(ck.per_shard_bytes) == sum(plain.per_shard_bytes)
    assert Checkpointer(tmp).latest_step() == 2 * NB

    # resuming a COMPLETE run trains zero new rounds (eager semantics)
    done = _engine(cfg, ds, pipe,
                   ExecutionPlan(mode="streamed_mesh", shards=4,
                                 num_epochs=2),
                   checkpoint=CheckpointSpec(tmp, every=1)).resume()
    assert done.losses == [] and done.state.step == 2 * NB


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_resume_rejects_reblocked_cursor(_trace):
    """Regression: a checkpoint cursor counts rounds of the ORIGINAL
    block size; resuming under a plan that re-blocks the timeline must
    raise instead of silently skipping snapshots."""
    cfg, ds, _ = _trace
    import dataclasses
    cfg4 = dataclasses.replace(cfg, checkpoint_blocks=4)   # win=4, rpe=4
    ds4 = InMemoryDTDG(ds, pipeline=DTDGPipeline(ds, nb=4))
    tmp = tempfile.mkdtemp()
    sent = []

    def killer(msg):
        if "dist stream round" in msg and not sent:
            sent.append(1)
            os.kill(os.getpid(), signal.SIGTERM)

    Engine(RunConfig(model=cfg4, data=ds4,
                     plan=ExecutionPlan(mode="streamed_mesh", shards=4,
                                        num_epochs=1),
                     checkpoint=CheckpointSpec(tmp, every=100),
                     log_fn=killer, log_every=1)).fit()
    # shards=8 cannot slice win=4 -> the plan re-blocks to win=8, rpe=2:
    # the saved cursor is meaningless there and must be refused
    with pytest.raises(ValueError, match="rounds per epoch"):
        Engine(RunConfig(model=cfg4, data=ds4,
                         plan=ExecutionPlan(mode="streamed_mesh",
                                            shards=8, num_epochs=1),
                         checkpoint=CheckpointSpec(tmp, every=100),
                         log_fn=_silent)).resume()


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_resume_does_not_replay_realized_rescales(_trace, _serial_ref):
    """Regression: rerunning the SAME elastic command after a preemption
    must not re-record (and re-charge) scripted events the first run
    already realized — only boundaries after the cursor may fire."""
    cfg, ds, pipe = _trace
    tmp = tempfile.mkdtemp()
    killed = []

    def killer(msg):
        # preempt AFTER the block-1 rescale has been realized
        if "dist stream round" in msg and "P=8" in msg and not killed:
            killed.append(1)
            os.kill(os.getpid(), signal.SIGTERM)

    plan = ExecutionPlan(mode="streamed_mesh", shards=4, num_epochs=2,
                         rescale=((1, 8),))
    first = _engine(cfg, ds, pipe, plan,
                    checkpoint=CheckpointSpec(tmp, every=100),
                    log_fn=killer, log_every=1).fit()
    assert first.rescale_report.preempted
    assert [(e.block, e.new_p) for e in first.rescale_report.events] == \
        [(1, 8)]
    cursor = first.state.step
    assert cursor > 1

    resumed = _engine(cfg, ds, pipe, plan,
                      checkpoint=CheckpointSpec(tmp, every=100)).resume()
    # the block-1 event is history: not replayed, not double-counted
    assert resumed.rescale_report.events == []
    np.testing.assert_allclose(first.losses + resumed.losses, _serial_ref,
                               rtol=1e-5)


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_resume_realizes_event_scheduled_at_the_cursor(_trace,
                                                       _serial_ref):
    """Regression: a checkpoint written with cursor == a scripted
    boundary means the event there has NOT been realized yet (events
    fire at the top of their block's iteration) — resume must still
    fire it, not filter it as history."""
    cfg, ds, pipe = _trace
    tmp = tempfile.mkdtemp()
    sent = []

    def killer(msg):
        # SIGTERM during round 1: the segment stops at cursor=2, the
        # exact block the scripted event is scheduled at
        if "dist stream round" in msg and "P=4" in msg \
                and len(sent) == 1:
            os.kill(os.getpid(), signal.SIGTERM)
        sent.append(1)

    plan = ExecutionPlan(mode="streamed_mesh", shards=4, num_epochs=2,
                         rescale=((2, 8),))
    first = _engine(cfg, ds, pipe, plan,
                    checkpoint=CheckpointSpec(tmp, every=100),
                    log_fn=killer, log_every=1).fit()
    assert first.rescale_report.preempted
    assert first.state.step == 2                  # cursor == boundary
    assert first.rescale_report.events == []      # not realized yet
    # preempted run reports no per-shard total (its segment tail never
    # streamed); the planned accounting lives on the report
    assert first.per_shard_bytes is None

    resumed = _engine(cfg, ds, pipe, plan,
                      checkpoint=CheckpointSpec(tmp, every=100)).resume()
    assert [(e.block, e.old_p, e.new_p)
            for e in resumed.rescale_report.events] == [(2, 4, 8)]
    np.testing.assert_allclose(first.losses + resumed.losses, _serial_ref,
                               rtol=1e-5)


# ----------------------------------------------- stream recompose ----------

def test_encode_time_sliced_from_boundary_equals_tail(_trace):
    """Re-slicing the remaining trace from a block boundary produces
    exactly the tail of the from-zero encoding — the property that makes
    block-granular recomposition legal."""
    cfg, ds, pipe = _trace
    p = 4
    stats = pipe.stream_stats
    full = stream_sharded.encode_time_sliced(
        ds.snapshots, ds.values, N, pipe.max_edges, WIN, p, stats)
    tail = stream_sharded.encode_time_sliced(
        ds.snapshots, ds.values, N, pipe.max_edges, WIN, p, stats,
        start_step=WIN)
    bsl = WIN // p
    for s in range(p):
        want = full[s][bsl:]
        got = tail[s]
        assert len(got) == len(want)
        assert isinstance(got[0], FullSnapshot)   # slice boundary full
        for a, b in zip(got, want):
            assert type(a) is type(b)
            assert a.payload_bytes == b.payload_bytes
            for fld in ("edges", "mask", "values", "drop_pos", "drop_mask",
                        "add_edges", "add_mask"):
                if hasattr(a, fld):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(a, fld)),
                        np.asarray(getattr(b, fld)))
    with pytest.raises(ValueError, match="block boundary"):
        stream_sharded.encode_time_sliced(
            ds.snapshots, ds.values, N, pipe.max_edges, WIN, p, stats,
            start_step=3)


# ---------------------------------------------- policy / validation --------

def test_controller_schedule_and_preemption_logic():
    ctrl = el.RescaleController(initial_p=4, schedule=((1, 8), (3, 2)))
    assert ctrl.scripted_width(0) == 4
    assert ctrl.scripted_width(1) == 8
    assert ctrl.scripted_width(2) == 8
    assert ctrl.scripted_width(5) == 2
    assert ctrl.next_boundary(0) == 1
    assert ctrl.next_boundary(1) == 3
    assert ctrl.next_boundary(3) is None
    assert ctrl.widths == (4, 8, 2)
    assert not ctrl.interrupt() and not ctrl.should_stop()

    from repro.ft.elastic import PreemptionGuard
    with PreemptionGuard() as g:
        shrink = el.RescaleController(initial_p=4, guard=g, shrink_to=2)
        stop = el.RescaleController(initial_p=4, guard=g)
        os.kill(os.getpid(), signal.SIGTERM)
        assert shrink.interrupt() and not shrink.should_stop()
        assert stop.interrupt() and stop.should_stop()
        # realizing the shrink absorbs the signal; it then sticks
        assert shrink.width_at(2, 4) == (2, "preemption")
        assert not shrink.interrupt()
        assert shrink.width_at(3, 2) == (2, "preemption")
        # a SECOND SIGTERM re-arms the guard: the one shrink is spent,
        # so the only graceful answer left is checkpoint-and-exit
        os.kill(os.getpid(), signal.SIGTERM)
        assert shrink.interrupt() and shrink.should_stop()

    with PreemptionGuard() as g2:
        # a shrink target at/above the current width can only no-op:
        # the signal must NOT be silently swallowed — it stops the run
        noop = el.RescaleController(initial_p=4, guard=g2, shrink_to=4)
        os.kill(os.getpid(), signal.SIGTERM)
        assert noop.should_stop(4)
        assert noop.width_at(1, 4) == (4, "scheduled")   # no absorb
        assert noop.interrupt()                          # flag kept


def test_controller_rejects_bad_schedules():
    with pytest.raises(ValueError, match="strictly increasing"):
        el.RescaleController(4, schedule=((2, 8), (2, 2)))
    with pytest.raises(ValueError, match="block 1"):
        el.RescaleController(4, schedule=((0, 8),))
    with pytest.raises(ValueError, match="width must be >= 1"):
        el.RescaleController(4, schedule=((1, 0),))
    with pytest.raises(ValueError, match="pairs"):
        el.RescaleController(4, schedule=(8,))


def test_plan_rescale_validation():
    with pytest.raises(ValueError, match="streamed_mesh"):
        ExecutionPlan(mode="eager", rescale=((1, 2),)).validate()
    with pytest.raises(ValueError, match="streamed_mesh"):
        ExecutionPlan(mode="streamed", rescale_on_preempt=2).validate()
    with pytest.raises(ValueError, match="strictly increasing"):
        ExecutionPlan(mode="streamed_mesh", shards=2,
                      rescale=((2, 4), (1, 2))).validate()
    with pytest.raises(ValueError, match="block 0"):
        ExecutionPlan(mode="streamed_mesh", shards=2,
                      rescale=((0, 4),)).validate()
    with pytest.raises(ValueError, match="pairs"):
        ExecutionPlan(mode="streamed_mesh", shards=2,
                      rescale=(4,)).validate()
    ExecutionPlan(mode="streamed_mesh", shards=2, rescale=((1, 4),),
                  rescale_on_preempt=1).validate()
    plan = ExecutionPlan(mode="streamed_mesh", shards=2,
                         rescale=((1, 4), (2, 8)), rescale_on_preempt=1)
    assert plan.rescale_widths == (4, 8, 1)
    assert plan.is_elastic
    assert not ExecutionPlan(mode="streamed_mesh", shards=2).is_elastic


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 host devices")
def test_resolve_rejects_unrealizable_widths(_trace):
    cfg, ds, pipe = _trace
    with pytest.raises(ValueError, match="does not divide the checkpoint"):
        _engine(cfg, ds, pipe,
                ExecutionPlan(mode="streamed_mesh", shards=4,
                              rescale=((1, 3),))).resolve()
    with pytest.raises(ValueError, match="exceeds the"):
        _engine(cfg, ds, pipe,
                ExecutionPlan(mode="streamed_mesh", shards=4,
                              rescale=((1, 512),))).resolve()


def test_plan_pads_vertex_axis_to_lcm_of_widths():
    """An elastic plan pads num_nodes so EVERY width in the policy can
    vertex-shard it — not just the initial one."""
    plan = ExecutionPlan(mode="streamed_mesh", shards=2,
                         rescale=((1, 8),))
    assert plan.padded_num_nodes(50) == 56          # lcm(2, 8) = 8
    assert plan.padded_num_nodes(48) == 48
    fixed = ExecutionPlan(mode="streamed_mesh", shards=2)
    assert fixed.padded_num_nodes(50) == 50         # unchanged behavior


def test_validate_widths_direct():
    el.validate_widths({1, 2, 4}, win=8, num_nodes=N, num_devices=8)
    with pytest.raises(ValueError, match="does not divide the checkpoint"):
        el.validate_widths({3}, win=8, num_nodes=N, num_devices=8)
    with pytest.raises(ValueError, match="exceeds"):
        el.validate_widths({16}, win=16, num_nodes=N, num_devices=8)
    with pytest.raises(ValueError, match="num_nodes"):
        el.validate_widths({5}, win=5, num_nodes=N, num_devices=8)


def test_rescale_payload_model():
    assert cv.rescale_payload(100.0, 10.0, 4, 4) == 0.0
    assert cv.rescale_payload(100.0, 10.0, 4, 8) == 100.0 + 4 * 10.0
    assert cv.rescale_payload(100.0, 10.0, 8, 2) == 100.0   # shrink: carries only
    with pytest.raises(ValueError, match=">= 1"):
        cv.rescale_payload(1.0, 1.0, 0, 4)
