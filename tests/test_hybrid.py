"""§6.5 hybrid partitioning: snapshot groups x intra-snapshot vertex
sharding must match the single-device reference exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dtdg, hybrid, models
from repro.graph import generate
from repro.launch.mesh import make_host_mesh

T, N = 8, 32


@pytest.mark.parametrize("model", ["tmgcn", "cdgcn"])
def test_hybrid_matches_reference(model):
    mesh = make_host_mesh(data=2, model=4)
    snaps = generate.evolving_dynamic_graph(N, T, density=2.0, churn=0.1,
                                            seed=0)
    frames = np.stack([generate.degree_features(s, N) for s in snaps])
    batch = dtdg.build_batch(snaps, frames, N)
    cfg = models.DynGNNConfig(model=model, num_nodes=N, num_steps=T,
                              window=3, checkpoint_blocks=1)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    z_ref = models.forward(cfg, params, batch)

    e_h, w_h = hybrid.partition_edges_for_hybrid(
        batch.edges, batch.edge_weights, batch.edge_mask, N, pm=4,
        max_local_edges=batch.edges.shape[1])
    fwd = hybrid.hybrid_forward(cfg, mesh)
    z_h = jax.jit(fwd)(params, batch.frames, jnp.asarray(e_h),
                       jnp.asarray(w_h))
    np.testing.assert_allclose(np.asarray(z_ref), np.asarray(z_h),
                               atol=1e-5)


def test_ctdg_bridge_roundtrip():
    """CTDG -> DTDG discretization feeds the standard pipeline."""
    from repro.core import ctdg, graphdiff
    stream = ctdg.synthetic_ctdg(64, 2000, delete_frac=0.2, seed=0)
    snaps = ctdg.snapshot_events(stream, num_steps=8)
    assert len(snaps) == 8
    # alive-edge view: edges accumulate then churn -> consecutive overlap
    sizes = [s.shape[0] for s in snaps]
    assert sizes[-1] > 0
    max_edges = max(sizes) * 2 + 16
    st = graphdiff.encode_stream(snaps, None, 64, max_edges, block_size=8)
    dec = graphdiff.decode_stream(st, max_edges)
    for snap, (e, m) in zip(snaps, dec):
        assert set(map(tuple, e[m > 0].tolist())) == \
            set(map(tuple, snap.tolist()))
    # high overlap -> graph-diff wins big on the alive-edge view
    assert graphdiff.stream_bytes(st) < graphdiff.naive_bytes(snaps)
    win = ctdg.window_events(stream, num_steps=8)
    assert len(win) == 8 and all(w.ndim == 2 for w in win)
