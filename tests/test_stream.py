"""Streamed graph-diff transfer subsystem vs the core.graphdiff reference.

The reference encoder/decoder (``core.graphdiff``) is the semantic
ground truth; the vectorized encoder, the stats pad sizing, the prefetch
path, and the shard-aware slicing must all reproduce it exactly."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graphdiff, smoothing
from repro.core.models import DynGNNConfig
from repro.graph import generate
from repro.stream import encoder as stream_encoder
from repro.stream import sharded as stream_sharded
from repro.stream import train_loop as stream_train
from repro.stream.prefetch import DeltaApplier, PrefetchIterator, stage_item

N, T, BS = 96, 16, 4


def _trace(churn=0.15, smooth="mproduct", seed=0):
    snaps = generate.evolving_dynamic_graph(N, T, density=3.0, churn=churn,
                                            seed=seed)
    values = None
    if smooth == "mproduct":
        snaps, values = smoothing.m_transform_sparse(snaps, 3)
    elif smooth == "edgelife":
        snaps, values = smoothing.edge_life(snaps, 3)
    max_edges = stream_encoder.padded_max_edges(snaps)
    return snaps, values, max_edges


@pytest.mark.parametrize("smooth", ["none", "mproduct", "edgelife"])
@pytest.mark.parametrize("churn", [0.05, 0.3])
def test_fast_encoder_decodes_bit_identical(smooth, churn):
    """Vectorized encoder == dict-based reference: decoded (edges, mask)
    and shipped values are exactly equal on a random CTDG trace."""
    snaps, values, max_edges = _trace(churn=churn, smooth=smooth)
    ref = graphdiff.encode_stream(snaps, values, N, max_edges, BS)
    fast = stream_encoder.encode_stream_fast(snaps, values, N, max_edges,
                                             BS)
    dec_ref = graphdiff.decode_stream(ref, max_edges)
    dec_fast = graphdiff.decode_stream(fast, max_edges)
    for (e1, m1), (e2, m2) in zip(dec_ref, dec_fast):
        assert np.array_equal(e1, e2)
        assert np.array_equal(m1, m2)
    for a, b in zip(ref, fast):
        assert np.array_equal(a.values, b.values)
        assert a.num_edges == b.num_edges


def test_stats_pads_bound_churn_and_shrink_buffers():
    snaps, values, max_edges = _trace()
    stats = stream_encoder.measure_stats(snaps, N, BS, max_edges)
    stream = stream_encoder.encode_stream_fast(snaps, values, N, max_edges,
                                               BS, stats)
    deltas = [s for s in stream if isinstance(s, graphdiff.SnapshotDelta)]
    assert deltas, "trace produced no delta steps"
    for d in deltas:
        assert d.drop_pos.shape == (stats.max_drops,)
        assert d.add_edges.shape == (stats.max_adds, 2)
        assert int(d.drop_mask.sum()) <= stats.max_drops
        assert int(d.add_mask.sum()) <= stats.max_adds
    # stats pads genuinely tighter than the E_max pads the reference uses
    assert stats.max_drops < max_edges


def test_payload_bytes_match_reference_and_ratio_bound():
    """Valid-lane byte accounting is pad-independent: fast == reference,
    and the stream beats the naive full-transfer baseline while staying
    above the block-boundary lower bound (full snapshots every BS steps
    must ship >= T/BS full payloads)."""
    snaps, values, max_edges = _trace()
    ref = graphdiff.encode_stream(snaps, values, N, max_edges, BS)
    fast = stream_encoder.encode_stream_fast(snaps, values, N, max_edges,
                                             BS)
    for a, b in zip(ref, fast):
        assert a.payload_bytes == b.payload_bytes
    gd = graphdiff.stream_bytes(fast)
    naive = graphdiff.naive_bytes(snaps)
    assert 0 < gd < naive
    full_bytes = sum(s.payload_bytes for s in fast
                     if isinstance(s, graphdiff.FullSnapshot))
    assert gd >= full_bytes > 0


def test_encoder_churn_overflow_resyncs_instead_of_crashing():
    """When live churn exceeds the stats-sized pads the encoder must not
    raise mid-stream: it ships a FullSnapshot resync for that step, counts
    it, and the stream still decodes to the exact snapshot sequence."""
    snaps, values, max_edges = _trace(churn=0.3)
    tiny = stream_encoder.DeltaStats(max_edges=max_edges, max_drops=1,
                                     max_adds=1)
    report = stream_encoder.StreamReport()
    with pytest.warns(UserWarning, match="resync"):
        stream = stream_encoder.encode_stream_fast(
            snaps, values, N, max_edges, BS, tiny, report=report)
    assert report.resyncs > 0
    assert report.worst_drops > tiny.max_drops \
        or report.worst_adds > tiny.max_adds
    assert len(report.resync_steps) == report.resyncs
    fulls = sum(isinstance(s, graphdiff.FullSnapshot) for s in stream)
    assert fulls == T // BS + report.resyncs
    # degraded, not wrong: every step still reconstructs its snapshot
    for (e, m), snap in zip(graphdiff.decode_stream(stream, max_edges),
                            snaps):
        valid = e[m > 0]
        assert set(map(tuple, valid.tolist())) \
            == set(map(tuple, snap.tolist()))


def test_encoder_churn_overflow_strict_mode_raises():
    snaps, values, max_edges = _trace(churn=0.3)
    tiny = stream_encoder.DeltaStats(max_edges=max_edges, max_drops=1,
                                     max_adds=1)
    with pytest.raises(stream_encoder.ChurnOverflowError,
                       match="exceeds stats pad"):
        stream_encoder.encode_stream_fast(snaps, values, N, max_edges, BS,
                                          tiny, on_overflow="raise")


def test_prefetch_iterator_preserves_order_and_propagates_errors():
    items = list(range(20))
    out = list(PrefetchIterator(iter(items), stage_fn=lambda x: x * 2,
                                depth=3))
    assert out == [x * 2 for x in items]

    def bad():
        yield 1
        raise RuntimeError("encoder blew up")

    it = PrefetchIterator(bad(), stage_fn=lambda x: x, depth=2)
    assert next(it) == 1
    with pytest.raises(RuntimeError, match="encoder blew up"):
        list(it)
    # terminated stays terminated (no deadlock, no re-raise loop)
    with pytest.raises(StopIteration):
        next(it)


def test_prefetch_iterator_close_unblocks_abandoned_worker():
    """Abandoning the stream mid-flight must retire the worker thread
    even while it is blocked on a full queue (infinite producer)."""
    import itertools
    it = PrefetchIterator(itertools.count(), stage_fn=lambda x: x, depth=2)
    assert next(it) == 0
    it.close()
    assert not it._thread.is_alive()
    with pytest.raises(StopIteration):
        next(it)


def test_prefetch_worker_exception_before_first_next():
    """An encoder that dies immediately re-raises on the FIRST __next__
    (not a hang, not a swallowed error)."""
    def dead():
        raise RuntimeError("dead on arrival")
        yield  # pragma: no cover

    it = PrefetchIterator(dead(), stage_fn=lambda x: x, depth=2)
    with pytest.raises(RuntimeError, match="dead on arrival"):
        next(it)
    with pytest.raises(StopIteration):
        next(it)


def test_prefetch_stage_fn_exception_propagates():
    """Errors raised while STAGING (device_put path) surface like encoder
    errors: re-raised at the consumer, then terminated."""
    def boom(x):
        if x == 3:
            raise ValueError("stage failed")
        return x

    it = PrefetchIterator(iter(range(10)), stage_fn=boom, depth=2)
    got = [next(it), next(it), next(it)]
    assert got == [0, 1, 2]
    with pytest.raises(ValueError, match="stage failed"):
        list(it)


def test_prefetch_close_releases_staged_buffers_and_is_idempotent():
    """close() during backpressure drains every staged item (releasing the
    buffers), retires the worker, and is safe to call repeatedly /
    via the context-manager protocol."""
    import itertools
    staged: list[int] = []

    def stage(x):
        staged.append(x)
        return x

    it = PrefetchIterator(itertools.count(), stage_fn=stage, depth=3)
    assert next(it) == 0
    it.close()
    it.close()                      # idempotent
    assert not it._thread.is_alive()
    assert it._q.qsize() == 0       # staged buffers dropped
    assert len(staged) >= 1         # worker really was ahead of us
    with pytest.raises(StopIteration):
        next(it)
    # context-manager form retires the worker on exit too
    with PrefetchIterator(itertools.count(), stage_fn=lambda x: x,
                          depth=2) as cm:
        assert next(cm) == 0
    assert not cm._thread.is_alive()


@pytest.mark.parametrize("donate", [True, False])
def test_delta_applier_multi_shard_ring(donate):
    """One donated edge-buffer ring per device shard, consumed interleaved
    (the distributed trainer's schedule): every shard's ring reproduces
    its own stream's decode exactly — rings never cross-contaminate."""
    from repro.dist import sharding as shardlib
    from repro.launch.mesh import make_host_mesh
    num_shards = 4
    mesh = make_host_mesh(data=num_shards, model=1)
    devices = shardlib.shard_devices(mesh, "data")
    snaps, values, max_edges = _trace()
    shard_streams = stream_sharded.encode_time_sliced(
        snaps, values, N, max_edges, BS, num_shards)
    want = [graphdiff.decode_stream(s, max_edges) for s in shard_streams]
    appliers = [DeltaApplier(max_edges, donate=donate, device=d)
                for d in devices]
    steps = len(shard_streams[0])
    for j in range(steps):
        outs = []
        for s in range(num_shards):
            item = stage_item(shard_streams[s][j], devices[s])
            e, m, _ = appliers[s].consume(item)
            outs.append((e, m))
        for s, (e, m) in enumerate(outs):
            assert list(e.devices()) == [devices[s]]
            we, wm = want[s][j]
            assert np.array_equal(np.asarray(e), we)
            assert np.array_equal(np.asarray(m), wm)


def test_slot_stacker_copies_survive_ring_donation():
    """SlotStacker.put must copy the ring buffers BEFORE the next consume
    donates them: after filling all slots, the block equals the decoded
    per-step sequence."""
    from repro.stream.prefetch import SlotStacker
    snaps, values, max_edges = _trace()
    stream = stream_encoder.encode_stream_fast(snaps, values, N, max_edges,
                                               BS)
    want = graphdiff.decode_stream(stream, max_edges)
    applier = DeltaApplier(max_edges)
    stacker = SlotStacker(len(stream))
    for j, item in enumerate(stream):
        e, m, v = applier.consume(stage_item(item))
        stacker.put(j, e, m, v)
    e_blk, m_blk, _ = stacker.arrays()
    for j, (we, wm) in enumerate(want):
        assert np.array_equal(np.asarray(e_blk[j]), we)
        assert np.array_equal(np.asarray(m_blk[j]), wm)


def test_delta_applier_reconstructs_stream():
    """Prefetched apply path (donated ring buffers) reproduces
    decode_stream's (edges, mask) sequence exactly."""
    snaps, values, max_edges = _trace()
    stream = stream_encoder.encode_stream_fast(snaps, values, N, max_edges,
                                               BS)
    want = graphdiff.decode_stream(stream, max_edges)
    applier = DeltaApplier(max_edges)
    for item, (we, wm) in zip(
            PrefetchIterator(iter(stream), stage_fn=stage_item, depth=2),
            want):
        e, m, _ = applier.consume(item)
        # copy out before the next consume donates these buffers
        assert np.array_equal(np.asarray(e), we)
        assert np.array_equal(np.asarray(m), wm)


@pytest.mark.parametrize("num_shards", [2, 4])
def test_sharded_streams_cover_time_slices(num_shards):
    """Each shard's self-contained stream decodes to exactly the snapshot
    edge sets of its owned steps (values aligned per edge)."""
    snaps, values, max_edges = _trace()
    shard_streams = stream_sharded.encode_time_sliced(
        snaps, values, N, max_edges, BS, num_shards)
    for s, stream in enumerate(shard_streams):
        steps = stream_sharded.shard_slice_steps(T, BS, num_shards, s)
        assert len(stream) == len(steps)
        decoded = graphdiff.decode_stream(stream, max_edges)
        for (e, m), t_global, item in zip(decoded, steps, stream):
            valid = e[m > 0]
            want = snaps[t_global]
            assert valid.shape == want.shape
            assert set(map(tuple, valid.tolist())) \
                == set(map(tuple, want.tolist()))
            # shipped values map to the right edges (valid lanes lead and
            # share the device ordering with the values array)
            key = {tuple(ed): float(v) for ed, v in
                   zip(want.tolist(), values[t_global])}
            for ed, v in zip(valid.tolist(),
                             item.values[:want.shape[0]]):
                assert key[tuple(ed)] == pytest.approx(float(v))
    total = sum(i.payload_bytes for st in shard_streams for i in st)
    assert total < num_shards * graphdiff.stream_bytes(
        stream_encoder.encode_stream_fast(snaps, values, N, max_edges, BS))


@pytest.mark.parametrize("model", ["tmgcn", "cdgcn", "evolvegcn"])
def test_prefetch_training_losses_bit_identical(model):
    """The overlapped transfer loop is a pure schedule change: per-step
    losses equal the synchronous path's exactly."""
    from repro.data.dyngnn import synthetic_dataset
    smooth = {"tmgcn": "mproduct", "evolvegcn": "edgelife",
              "cdgcn": "none"}[model]
    ds = synthetic_dataset(48, 8, density=2.0, churn=0.1,
                           smoothing_mode=smooth, window=3, seed=0)
    cfg = DynGNNConfig(model=model, num_nodes=48, num_steps=8, window=3,
                       checkpoint_blocks=2)
    frames, labels = np.asarray(ds.frames), np.asarray(ds.labels)
    sync = stream_train.train_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, num_epochs=2,
        overlap=False)
    over = stream_train.train_streamed(
        cfg, ds.snapshots, ds.values, frames, labels, num_epochs=2,
        overlap=True, prefetch_depth=3)
    assert sync.losses == over.losses
    assert sync.losses[-1] < sync.losses[0] + 1e-6  # it actually trains
    import jax
    for a, b in zip(jax.tree.leaves(sync.params),
                    jax.tree.leaves(over.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipeline_uses_stream_encoder_and_accounts_bytes():
    from repro.data.dyngnn import DTDGPipeline, synthetic_dataset
    ds = synthetic_dataset(64, 16, density=2.0, churn=0.1,
                           smoothing_mode="mproduct", window=3, seed=0)
    pipe = DTDGPipeline(ds, nb=2)
    rep = pipe.transfer_bytes()
    assert 0 < rep["graph_diff"] < rep["naive"]
    # lazy re-encode equals the eager stream
    lazy = list(pipe.host_stream())
    assert len(lazy) == ds.num_steps
    assert graphdiff.stream_bytes(lazy) == rep["graph_diff"]
    shards = pipe.sharded_streams(2)
    assert len(shards) == 2
