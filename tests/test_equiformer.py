"""SO(3)/eSCN machinery + EquiformerV2 equivariance (the flagship GNN
property test)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gnn import common, equiformer_v2 as eq, so3


def _rot(a, b, g):
    def rz(t):
        return np.array([[np.cos(t), -np.sin(t), 0],
                         [np.sin(t), np.cos(t), 0], [0, 0, 1]])

    def ry(t):
        return np.array([[np.cos(t), 0, np.sin(t)], [0, 1, 0],
                         [-np.sin(t), 0, np.cos(t)]])

    return rz(a) @ ry(b) @ rz(g)


@pytest.mark.parametrize("l", list(range(7)))
def test_wigner_d_orthogonal(l):
    rng = np.random.default_rng(l)
    a, b, g = (jnp.asarray(rng.uniform(-np.pi, np.pi, 4).astype(np.float32))
               for _ in range(3))
    d = so3.wigner_d_real(l, a, b, g)
    eye = jnp.einsum("eij,ekj->eik", d, d)
    np.testing.assert_allclose(np.asarray(eye),
                               np.broadcast_to(np.eye(2 * l + 1),
                                               eye.shape), atol=1e-5)


def test_wigner_l1_equals_rotation_matrix():
    rng = np.random.default_rng(0)
    perm = [1, 2, 0]   # real-SH l=1 ordering (y, z, x)
    for _ in range(5):
        a, b, g = rng.uniform(-np.pi, np.pi, 3)
        r = _rot(a, b, g)[np.ix_(perm, perm)]
        d = np.asarray(so3.wigner_d_real(1, jnp.array([a]), jnp.array([b]),
                                         jnp.array([g])))[0]
        np.testing.assert_allclose(d, r, atol=1e-5)


@pytest.mark.parametrize("l", [2, 4, 6])
def test_wigner_composition_homomorphism(l):
    rng = np.random.default_rng(l)
    a1, b1, g1 = rng.uniform(0.1, np.pi - 0.1, 3)
    a2, b2, g2 = rng.uniform(0.1, np.pi - 0.1, 3)
    r3 = _rot(a1, b1, g1) @ _rot(a2, b2, g2)
    b3 = np.arccos(np.clip(r3[2, 2], -1, 1))
    a3 = np.arctan2(r3[1, 2], r3[0, 2])
    g3 = np.arctan2(r3[2, 1], -r3[2, 0])

    def d(l_, a, b, g):
        return np.asarray(so3.wigner_d_real(
            l_, jnp.array([a]), jnp.array([b]), jnp.array([g])))[0]

    np.testing.assert_allclose(d(l, a1, b1, g1) @ d(l, a2, b2, g2),
                               d(l, a3, b3, g3), atol=1e-4)


def test_edge_alignment_maps_to_z():
    rng = np.random.default_rng(2)
    v = jnp.asarray(rng.normal(size=(8, 3)).astype(np.float32))
    al, be, ga = so3.edge_rotation_angles(v)
    d1 = so3.wigner_d_real(1, al, be, ga)
    vn = v / jnp.linalg.norm(v, axis=-1, keepdims=True)
    rotated = jnp.einsum("eij,ej->ei", d1, vn[:, [1, 2, 0]])
    np.testing.assert_allclose(np.asarray(rotated),
                               np.tile([0, 1, 0], (8, 1)), atol=1e-5)


def test_equiformer_rotation_invariance():
    """Rotate all positions by a random R: invariant (l=0) outputs and the
    classifier logits must be unchanged — the defining property."""
    r = jnp.asarray(_rot(0.7, 1.2, -0.3).astype(np.float32))
    batch = common.batch_molecules(4, 8, 16, feat_dim=5, seed=0)
    batch_rot = dataclasses.replace(batch, positions=batch.positions @ r.T)
    p = eq.init_params(jax.random.PRNGKey(0), 5, channels=16, n_layers=2,
                       l_max=4, m_max=2, n_heads=4, n_rbf=8, num_classes=3)
    kw = dict(l_max=4, m_max=2, n_heads=4, n_rbf=8)
    o1 = eq.logits(p, batch, **kw)
    o2 = eq.logits(p, batch_rot, **kw)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


def test_equiformer_translation_invariance():
    batch = common.batch_molecules(2, 6, 12, feat_dim=5, seed=1)
    shifted = dataclasses.replace(batch, positions=batch.positions + 7.5)
    p = eq.init_params(jax.random.PRNGKey(1), 5, channels=8, n_layers=2,
                       l_max=2, m_max=1, n_heads=2, n_rbf=6, num_classes=2)
    kw = dict(l_max=2, m_max=1, n_heads=2, n_rbf=6)
    np.testing.assert_allclose(np.asarray(eq.logits(p, batch, **kw)),
                               np.asarray(eq.logits(p, shifted, **kw)),
                               atol=1e-4)


def test_equiformer_grads_finite():
    batch = common.batch_molecules(2, 6, 12, feat_dim=5, seed=2)
    p = eq.init_params(jax.random.PRNGKey(2), 5, channels=8, n_layers=2,
                       l_max=3, m_max=2, n_heads=2, n_rbf=6, num_classes=2)
    g = jax.grad(lambda pp: float(0) + jnp.sum(
        eq.logits(pp, batch, l_max=3, m_max=2, n_heads=2, n_rbf=6) ** 2))(p)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_gnn_archs_permutation_equivariance():
    """Node relabeling permutes GNN outputs correspondingly (gatedgcn/pna)."""
    from repro.models.gnn import gatedgcn, pna
    rng = np.random.default_rng(0)
    n, e, f = 20, 60, 5
    edges = rng.integers(0, n, size=(e, 2)).astype(np.int32)
    feat = rng.normal(size=(n, f)).astype(np.float32)
    perm = rng.permutation(n)
    inv = np.argsort(perm)
    batch = common.GraphBatch(
        edges=jnp.asarray(edges), edge_mask=jnp.ones((e,), jnp.float32),
        node_feat=jnp.asarray(feat), node_mask=jnp.ones((n,), jnp.float32))
    batch_p = common.GraphBatch(
        edges=jnp.asarray(perm[edges]),
        edge_mask=jnp.ones((e,), jnp.float32),
        node_feat=jnp.asarray(feat[inv]),
        node_mask=jnp.ones((n,), jnp.float32))
    for mod, init in ((gatedgcn, lambda k: gatedgcn.init_params(
            k, f, 16, 2, 2)),
            (pna, lambda k: pna.init_params(k, f, 12, 2, 2))):
        p = init(jax.random.PRNGKey(0))
        h1 = np.asarray(mod.forward(p, batch))
        h2 = np.asarray(mod.forward(p, batch_p))
        np.testing.assert_allclose(h2, h1[inv], atol=1e-4)
