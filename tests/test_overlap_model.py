"""Unit tests for the overlap/pipelining time models (repro.dist.overlap).

These are the analytic bounds the benchmarks report predictions from
(``benchmarks/overlap_bench.py`` ``pipelined_round``,
``benchmarks/scaling_bench.py`` ``streamed_scaling``): the degenerate
configurations must reproduce the serial schedule EXACTLY and the
chunked bound must be monotone, or predicted-vs-measured rows would lie.
"""

import pytest

from repro.dist.overlap import overlap_time_model, round_time_model


def test_round_model_degenerate_c1_equals_serial():
    """C=1 with no round pipelining IS the serial schedule, exactly."""
    m = round_time_model(1.0, 2.0, 3.0, 4.0, chunks=1,
                         pipeline_rounds=False)
    assert m["pipelined_s"] == m["serial_s"] == 1.0 + 2.0 + 3.0 + 4.0
    assert m["speedup"] == 1.0
    assert m["chunks"] == 1
    assert m["phases_s"] == {"transfer": 1.0, "spatial": 2.0, "a2a": 3.0,
                             "temporal": 4.0}


def test_round_model_monotone_in_chunks():
    """More chunks never slow the round; strictly faster while the
    non-dominant inner phase still has fill/drain to shave."""
    times = [round_time_model(0.5, 1.0, 2.0, 1.0, chunks=c)["pipelined_s"]
             for c in (1, 2, 4, 8, 16)]
    for a, b in zip(times, times[1:]):
        assert b < a                       # comp=2, a2a=2 -> min > 0
    # floor: dominant phase + transfer (no round pipelining here)
    assert times[-1] > 0.5 + max(2.0, 2.0)


def test_round_model_pipeline_rounds_hides_transfer():
    """Round-level pipelining turns transfer+inner into max(transfer,
    inner) — transfer fully hides when compute dominates."""
    kw = dict(t_spatial=2.0, t_a2a=1.0, t_temporal=2.0, chunks=4)
    serial = round_time_model(t_transfer=1.5, pipeline_rounds=False, **kw)
    piped = round_time_model(t_transfer=1.5, pipeline_rounds=True, **kw)
    assert piped["pipelined_s"] == serial["pipelined_s"] - 1.5
    assert piped["speedup"] > serial["speedup"]
    # transfer-bound regime: the round degenerates to the transfer time
    bound = round_time_model(t_transfer=100.0, pipeline_rounds=True, **kw)
    assert bound["pipelined_s"] == 100.0


def test_round_model_never_beats_dominant_phase():
    """The bound is physical: no schedule beats the dominant phase."""
    for c in (1, 2, 4, 64):
        for pr in (False, True):
            m = round_time_model(0.3, 1.0, 5.0, 0.5, chunks=c,
                                 pipeline_rounds=pr)
            assert m["pipelined_s"] >= 5.0
            assert m["pipelined_s"] <= m["serial_s"]


@pytest.mark.parametrize("chunks", [0, -3])
def test_round_model_clamps_chunks(chunks):
    """Nonpositive chunk counts clamp to the serial C=1 schedule (the
    models are report helpers, not validators)."""
    m = round_time_model(1.0, 1.0, 1.0, 1.0, chunks=chunks)
    assert m["chunks"] == 1
    assert m["pipelined_s"] == m["serial_s"]


def test_two_phase_model_consistency():
    """round_time_model with zero transfer+one fused compute phase
    reduces to the original two-phase overlap_time_model."""
    for c in (1, 2, 4):
        two = overlap_time_model(3.0, 2.0, c)
        four = round_time_model(0.0, 3.0, 2.0, 0.0, chunks=c)
        assert four["pipelined_s"] == two["pipelined_s"]
        assert four["serial_s"] == two["serial_s"]
