"""End-to-end distributed training driver (the paper's full stack).

One ``RunConfig`` per schedule drives every production component:
synthetic DTDG + smoothing, graph-diff transfer accounting, snapshot
partitioning over a device mesh (shard_map all-to-alls), blocked
gradient checkpointing, AdamW, async checkpointing, preemption guard,
straggler watchdog — then link-prediction eval; and the same mesh again
ONLINE, with per-shard time-slice delta streams feeding per-device
edge-buffer rings under the snapshot-parallel shard_map.

On this host it runs over the available CPU devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python examples/train_dyngnn_distributed.py
On a pod, the same code runs with plan.mesh = make_production_mesh().
"""

import os
import shutil
import tempfile

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

from repro.core import models
from repro.optim import adamw
from repro.run import (CheckpointSpec, Engine, ExecutionPlan, RunConfig,
                       SyntheticTrace)


def main() -> None:
    n_dev = len(jax.devices())
    p = max(d for d in (1, 2, 4, 8) if d <= n_dev)

    t, n = 32, 512
    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=t,
                              feat_in=2, hidden=6, out_dim=6, window=5,
                              checkpoint_blocks=4)
    data = SyntheticTrace(num_nodes=n, num_steps=t, density=3.0, churn=0.1,
                          smoothing_mode="mproduct", window=5, seed=0)

    # OFFLINE: blocked trainer under the snapshot-partition shard_map
    # (fresh checkpoint dir: a stale one from a previous run would resume
    # past num_steps and leave nothing to train)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_dyngnn_ckpt_")
    engine = Engine(RunConfig(
        model=cfg, data=data,
        plan=ExecutionPlan(mode="eager", shards=p, num_steps=300),
        optimizer=adamw.AdamWConfig(lr=5e-3, warmup_steps=20,
                                    total_steps=300, weight_decay=0.0),
        checkpoint=CheckpointSpec(ckpt_dir, every=100),
        log_every=25))
    mesh = engine.resolve().mesh
    print(f"mesh: {dict(mesh.shape) if mesh is not None else 'single device'}")
    rep = engine.resolve().pipeline.transfer_bytes()
    print(f"host->device transfer with graph-diff: "
          f"{1 / rep['ratio']:.2f}x reduction")
    result = engine.fit()
    print(f"trained {result.state.step} steps; loss "
          f"{result.losses[0]:.4f} -> {result.losses[-1]:.4f}")
    acc = engine.evaluate(result)
    print(f"link-prediction accuracy: {acc:.3f}")

    # Same mesh, ONLINE: per-shard time-slice delta streams feed per-device
    # edge-buffer rings; each checkpoint block trains one snapshot-parallel
    # shard_map round while the next block's deltas prefetch.
    streamed = Engine(RunConfig(
        model=cfg, data=data,
        plan=ExecutionPlan(mode="streamed_mesh", shards=p, num_epochs=2),
        log_every=4))
    s_result = streamed.fit()
    print(f"streamed {s_result.state.step} block rounds on {p} shards; "
          f"loss {s_result.losses[0]:.4f} -> {s_result.losses[-1]:.4f}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
