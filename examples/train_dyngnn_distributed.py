"""End-to-end distributed training driver (the paper's full stack).

Uses every production component: synthetic DTDG + smoothing, graph-diff
transfer accounting, snapshot partitioning over a device mesh (shard_map
all-to-alls), blocked gradient checkpointing, AdamW, async checkpointing,
preemption guard, straggler watchdog — then link-prediction eval.

On this host it runs over the available CPU devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python examples/train_dyngnn_distributed.py
On a pod, the same code runs with mesh = make_production_mesh().
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

from repro.core import models
from repro.data.dyngnn import DTDGPipeline, synthetic_dataset
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.train import trainer


def main() -> None:
    n_dev = len(jax.devices())
    p = max(d for d in (1, 2, 4, 8) if d <= n_dev)
    mesh = make_host_mesh(data=p, model=1)
    print(f"mesh: {dict(mesh.shape)}")

    t, n = 32, 512
    ds = synthetic_dataset(n, t, density=3.0, churn=0.1,
                           smoothing_mode="mproduct", window=5, seed=0)
    pipeline = DTDGPipeline(ds, nb=4)
    rep = pipeline.transfer_bytes()
    print(f"host->device transfer with graph-diff: "
          f"{1 / rep['ratio']:.2f}x reduction")

    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=t,
                              feat_in=2, hidden=6, out_dim=6, window=5,
                              checkpoint_blocks=4)
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=20, total_steps=300,
                                weight_decay=0.0)
    state, losses = trainer.train_dyngnn(
        cfg, pipeline, mesh=mesh, num_steps=300, opt_cfg=opt_cfg,
        ckpt_dir="/tmp/repro_dyngnn_ckpt", ckpt_every=100, log_every=25)
    print(f"trained {state.step} steps; loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}")
    acc = trainer.evaluate_link_prediction(cfg, state.params, pipeline,
                                           ds.snapshots[-1])
    print(f"link-prediction accuracy: {acc:.3f}")

    # Same mesh, ONLINE: per-shard time-slice delta streams feed per-device
    # edge-buffer rings; each checkpoint block trains one snapshot-parallel
    # shard_map round while the next block's deltas prefetch.
    s_state, s_losses = trainer.train_dyngnn_streamed(
        cfg, pipeline, num_epochs=2, mesh=mesh, log_every=4)
    print(f"streamed {s_state.step} block rounds on {p} shards; "
          f"loss {s_losses[0]:.4f} -> {s_losses[-1]:.4f}")


if __name__ == "__main__":
    main()
