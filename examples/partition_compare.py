"""Reproduce the paper's §6.4 comparison in miniature: train the same model
under snapshot partitioning and vertex partitioning, show identical loss
curves (Fig. 6) and the comm-volume law (Table 2).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python examples/partition_compare.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checkpoint as ckpt_exec
from repro.core import dtdg, models, partition
from repro.dist import comm_volume as cv
from repro.graph import generate
from repro.launch.mesh import make_host_mesh


def main() -> None:
    p = min(4, len(jax.devices()))
    mesh = make_host_mesh(data=p, model=1)
    n, t = 128, 16
    snaps = generate.evolving_dynamic_graph(n, t, density=3.0, churn=0.1,
                                            seed=0)
    frames = np.stack([generate.degree_features(s, n) for s in snaps])
    batch = dtdg.build_batch(snaps, frames, n)
    labels = jnp.asarray((frames[:, :, 0] >
                          np.median(frames[:, :, 0])).astype(np.int32))
    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=2)
    params = models.init_params(jax.random.PRNGKey(0), cfg)

    # identical losses under both schemes (paper Fig. 6)
    loss_sp = partition.snapshot_partition_loss(cfg, mesh)
    fr, ed, ew = partition.blockify_batch(batch, 2)
    lab_b = labels.reshape(2, t // 2, n)
    l_sp = jax.jit(lambda p_: loss_sp(p_, fr, ed, ew, lab_b))(params)
    l_ref = ckpt_exec.blocked_node_loss(cfg, params, batch, labels, nb=2)
    print(f"loss  snapshot-partitioned: {float(l_sp):.6f}")
    print(f"loss  single-device ref  : {float(l_ref):.6f}")
    print(f"identical: {np.allclose(float(l_sp), float(l_ref), atol=1e-6)}")

    # comm volume law (Table 2)
    print("\ncomm volume (float units), T=64 N=4096 F=6 L=2:")
    print(f"{'P':>4s} {'snapshot':>12s} {'hypergraph':>12s} "
          f"{'allgather':>12s}")
    snaps_big = generate.evolving_dynamic_graph(4096, 16, 4.0, 0.15, 0)
    owner_edges = np.concatenate(snaps_big)
    for pp in (4, 16, 64):
        v_s = cv.snapshot_partition_volume(64, 4096, 6, 2, pp)
        owner = cv.bfs_partition(owner_edges, 4096, pp)
        v_h = cv.vertex_partition_volume(snaps_big, 4096, 6, 2, pp, owner) \
            * 4  # scale 16 -> 64 steps
        v_a = cv.allgather_vertex_volume(64, 4096, 6, 2, pp)
        print(f"{pp:4d} {v_s:12.3e} {v_h:12.3e} {v_a:12.3e}")
    print("\nsnapshot volume is ~constant in P; vertex volume grows with P "
          "(the paper's central claim).")


if __name__ == "__main__":
    main()
