"""Online dyngnn serving end to end: train offline, then serve the
trained params against a live CTDG event stream.

1. discretize a synthetic CTDG and train with ``repro.run.Engine``;
2. stand up a ``ServeEngine`` with the trained params and an
   ``IngestSpec`` matching the training discretization;
3. push the event stream live (chronological chunks), advance the
   resident state window by window, and answer node-scoring +
   link-prediction queries against the warm on-device cache.

  python examples/serve_dyngnn.py --nodes 64 --windows 16
"""

import argparse

import numpy as np

from repro.core import ctdg
from repro.core.models import DynGNNConfig
from repro.data import dyngnn as dyn_data
from repro.run import (Engine, ExecutionPlan, IngestSpec, InMemoryDTDG,
                       RunConfig, ServeConfig, ServeEngine)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--windows", type=int, default=16)
    ap.add_argument("--events", type=int, default=800)
    args = ap.parse_args()
    n, w = args.nodes, args.windows

    # -- offline: discretize + train --------------------------------------
    stream = ctdg.synthetic_ctdg(n, args.events, seed=0)
    snaps = ctdg.snapshot_events(stream, w)
    ds = dyn_data.dataset_from_snapshots(snaps, n, smoothing_mode="none")
    cfg = DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=w, window=3,
                       checkpoint_blocks=2)
    run = RunConfig(model=cfg, data=InMemoryDTDG(ds),
                    plan=ExecutionPlan(mode="streamed", num_epochs=2),
                    seed=0)
    fit = Engine(run).fit()
    print(f"trained: final loss {fit.losses[-1]:.4f}")

    # -- online: serve the trained params against the live stream ---------
    pipe = dyn_data.DTDGPipeline(ds, nb=2)
    spec = IngestSpec(
        num_windows=w,
        time_range=(float(stream.time.min()), float(stream.time.max())),
        block_size=pipe.bsize, max_edges=pipe.max_edges)
    eng = ServeEngine(ServeConfig(model=cfg, ingest=spec, seed=0),
                      params=fit.state.params)

    ev = stream.sorted()
    chunk = max(len(ev) // 4, 1)
    for lo in range(0, len(ev), chunk):
        sl = slice(lo, lo + chunk)
        eng.ingest(ctdg.EventStream(ev.src[sl], ev.dst[sl], ev.time[sl],
                                    ev.kind[sl], n))
        # advance every window whose events have fully arrived
        arrived = int(spec.window_of(ev.time[sl.stop - 1 if sl.stop
                                             <= len(ev) else -1]))
        while eng.ingester.next_window < min(arrived, w):
            eng.advance()
    eng.advance_all()

    node_scores = eng.query_nodes(np.arange(min(8, n)))
    link_scores = eng.query_links(np.array([[0, 1], [2, 3]]))
    print(f"node scores {node_scores.shape}, link scores "
          f"{link_scores.shape}")
    print(eng.result().summary())


if __name__ == "__main__":
    main()
