"""Quickstart: train a TM-GCN dynamic GNN on a synthetic evolving graph
through the declarative ``repro.run`` Engine API.

Runs in ~30 s on CPU:
  python examples/quickstart.py
"""

from repro.core import models
from repro.run import Engine, ExecutionPlan, RunConfig, SyntheticTrace


def main() -> None:
    # 1. Model: 2-layer GCN + M-product (TM-GCN), feature widths per paper
    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=128, num_steps=16,
                              feat_in=2, hidden=6, out_dim=6, window=3,
                              checkpoint_blocks=2)

    # 2. One declarative run: data spec (an evolving graph, smoothed with
    #    the M-transform, paper §5.4) + execution plan (eager schedule,
    #    single device here; shards=P for snapshot partitioning)
    run = RunConfig(
        model=cfg,
        data=SyntheticTrace(num_nodes=128, num_steps=16, density=3.0,
                            churn=0.1, smoothing_mode="mproduct", window=3),
        plan=ExecutionPlan(mode="eager", num_steps=60),
        seed=0)

    # 3. Train
    engine = Engine(run)
    result = engine.fit()
    rep = result.transfer_report
    print(f"graph-difference transfer: {rep['graph_diff']:,} bytes "
          f"vs naive {rep['naive']:,} ({1 / rep['ratio']:.2f}x less)")
    print(f"loss: {result.losses[0]:.4f} -> {result.losses[-1]:.4f}")

    # 4. Evaluate link prediction on the held-out last snapshot (§6.4)
    acc = engine.evaluate(result)
    print(f"link-prediction accuracy: {acc:.3f}")


if __name__ == "__main__":
    main()
