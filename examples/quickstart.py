"""Quickstart: train a TM-GCN dynamic GNN on a synthetic evolving graph.

Runs in ~30 s on CPU:
  python examples/quickstart.py
"""

import jax

from repro.core import models
from repro.data.dyngnn import DTDGPipeline, synthetic_dataset
from repro.train import trainer


def main() -> None:
    # 1. Data: an evolving graph, smoothed with the M-transform (paper §5.4)
    ds = synthetic_dataset(num_nodes=128, num_steps=16, density=3.0,
                           churn=0.1, smoothing_mode="mproduct", window=3)
    pipeline = DTDGPipeline(ds, nb=2)        # 2 gradient-checkpoint blocks
    rep = pipeline.transfer_bytes()
    print(f"graph-difference transfer: {rep['graph_diff']:,} bytes "
          f"vs naive {rep['naive']:,} ({1 / rep['ratio']:.2f}x less)")

    # 2. Model: 2-layer GCN + M-product (TM-GCN), feature widths per paper
    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=128, num_steps=16,
                              feat_in=2, hidden=6, out_dim=6, window=3,
                              checkpoint_blocks=2)

    # 3. Train (single device here; pass a mesh for snapshot partitioning)
    state, losses = trainer.train_dyngnn(cfg, pipeline, num_steps=60,
                                         log_every=10)
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f}")

    # 4. Evaluate link prediction on the held-out last snapshot (§6.4)
    acc = trainer.evaluate_link_prediction(cfg, state.params, pipeline,
                                           ds.snapshots[-1])
    print(f"link-prediction accuracy: {acc:.3f}")


if __name__ == "__main__":
    main()
