"""Serve a small LM with batched requests: prefill + batched greedy decode
through the KV cache (the serve_step the decode_* dry-run cells lower).

  python examples/serve_lm.py --arch yi-6b --tokens 32
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = registry.get_arch(args.arch).make_smoke_config()
    params = lm.init_lm_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)

    max_len = args.prompt_len + args.tokens
    prefill = jax.jit(lambda p, t: lm.prefill(cfg, p, t, max_len=max_len))
    decode = jax.jit(lambda p, c, t: lm.decode_step(cfg, p, c, t))

    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(args.tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"arch={args.arch} (smoke config) batch={args.batch}")
    for b in range(args.batch):
        print(f"  request {b}: generated {gen[b][:12].tolist()} ...")
    print(f"served {args.batch}x{args.tokens} tokens; cache len "
          f"{int(cache['len'][0])}")


if __name__ == "__main__":
    main()
