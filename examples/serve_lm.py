"""Serve a small LM through the declarative surface: one ServeConfig,
prefill + batched greedy decode through the KV cache behind
``ServeEngine.generate()``.

  python examples/serve_lm.py --arch yi-6b --tokens 32
"""

import argparse

from repro.serve import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    eng = ServeEngine(ServeConfig(
        arch=args.arch, batch_sizes=(args.batch,),
        prompt_len=args.prompt_len, max_tokens=args.tokens))
    gen = eng.generate(batch_size=args.batch)

    print(f"arch={args.arch} (smoke config) batch={args.batch}")
    for b in range(args.batch):
        print(f"  request {b}: generated {gen[b][:12].tolist()} ...")
    print(eng.result().summary())


if __name__ == "__main__":
    main()
