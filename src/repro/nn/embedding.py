"""Sparse embedding substrate for recsys: EmbeddingBag built from
``jnp.take`` + ``jax.ops.segment_sum`` (JAX has no native EmbeddingBag;
this IS part of the system).

Tables are row(vocab)-sharded over the 'model' mesh axis at scale; the
lookup of a sharded table under GSPMD lowers to partial gathers + an
all-reduce — the regular-pattern re-distribution this framework favors
(DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def init_table(key: Array, vocab: int, dim: int,
               dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.01
            ).astype(dtype)


def embedding_lookup(table: Array, ids: Array) -> Array:
    """Plain lookup: ids (...,) int32 -> (..., dim)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: Array, ids: Array, offsets_or_mask: Array,
                  mode: str = "sum") -> Array:
    """Bagged lookup over a padded (B, L) id matrix with a validity mask.

    Equivalent to torch.nn.EmbeddingBag on padded bags:
      out[b] = reduce_{l: mask[b,l]>0} table[ids[b,l]]
    """
    b, l = ids.shape
    emb = jnp.take(table, ids.reshape(-1), axis=0).reshape(b, l, -1)
    mask = offsets_or_mask.astype(emb.dtype)
    if mode == "sum":
        return jnp.sum(emb * mask[..., None], axis=1)
    if mode == "mean":
        cnt = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
        return jnp.sum(emb * mask[..., None], axis=1) / cnt[..., None][:, 0]
    if mode == "max":
        neg = jnp.where(mask[..., None] > 0, emb, -1e30)
        out = jnp.max(neg, axis=1)
        return jnp.where(out <= -1e29, 0.0, out)
    raise ValueError(mode)


def embedding_bag_segment(table: Array, flat_ids: Array, segment_ids: Array,
                          num_bags: int, weights: Array | None = None
                          ) -> Array:
    """Ragged EmbeddingBag: flat ids + segment ids (CSR-style bags)."""
    emb = jnp.take(table, flat_ids, axis=0)
    if weights is not None:
        emb = emb * weights[:, None].astype(emb.dtype)
    return jax.ops.segment_sum(emb, segment_ids, num_segments=num_bags)
