"""Mixture-of-Experts FFN with sort-based top-k dispatch (EP-shardable).

Dispatch is the MegaBlocks/GShard hybrid that works well under GSPMD:

  1. router logits -> top-k experts + softmax combine weights per token,
  2. flatten (token, k) assignments, order by expert id (argsort),
  3. positions within each expert via a cumulative count, clipped to a static
     capacity C = ceil(cf * T * k / E),
  4. gather tokens into the (E, C, d) expert batch   (one scatter),
  5. batched expert GLU-FFN einsum  ("ecd,edf->ecf") — the E axis is what
     expert parallelism shards over the 'model' mesh axis,
  6. scatter back with combine weights (one gather + segment-sum over k).

Everything is static-shape; tokens overflowing an expert's capacity are
dropped (standard capacity-factor semantics), counted in ``aux['dropped']``.
The auxiliary load-balancing loss follows Switch/GShard.

This dispatch -> process -> undispatch structure is the transformer analogue
of the paper's snapshot re-distribution: tokens re-sharded by expert id via
all-to-all, processed locally, and re-sharded back (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import ACTIVATIONS

Array = jax.Array


def init_moe(key: Array, d_model: int, d_ff: int, num_experts: int,
             dtype=jnp.bfloat16) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d_model)

    def mk(k, shape, s):
        return (jax.random.normal(k, shape, dtype=jnp.float32) * s
                ).astype(dtype)

    return {
        "router": mk(k1, (d_model, num_experts), scale).astype(jnp.float32),
        "wi_gate": mk(k2, (num_experts, d_model, d_ff), scale),
        "wi_up": mk(k3, (num_experts, d_model, d_ff), scale),
        "wo": mk(k4, (num_experts, d_ff, d_model), 1.0 / jnp.sqrt(d_ff)),
    }


def moe_apply(params: dict, x: Array, top_k: int,
              capacity_factor: float = 1.25, activation: str = "silu",
              capacity: int | None = None,
              ep_constrain=None) -> tuple[Array, dict]:
    """x: (B, S, d) -> (out (B, S, d), aux dict with load-balance loss).

    ``ep_constrain``: sharding hook for the (E, C, d) expert batch —
    P('model', dp, None) pins experts to EP shards and the capacity dim to
    the data axes, so the dispatch lowers to an all-to-all instead of the
    all-gather GSPMD otherwise picks (§Perf iteration on the MoE cells).
    """
    b, s, d = x.shape
    e = params["router"].shape[1]
    tokens = x.reshape(b * s, d)
    t = b * s
    if capacity is None:
        capacity = int(capacity_factor * t * top_k / e)
        capacity = max(8, -(-capacity // 8) * 8)

    logits = tokens.astype(jnp.float32) @ params["router"]   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)      # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # ---- flatten (T, k) assignments and order by expert ------------------
    flat_expert = expert_idx.reshape(-1)                     # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = (jnp.take(a, order) for a in
                  (flat_expert, flat_token, flat_gate))
    # position of each assignment within its expert
    ones = jnp.ones_like(se)
    csum = jnp.cumsum(ones) - 1
    expert_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jax.ops.segment_sum(ones, se, num_segments=e))[:-1]
         .astype(jnp.int32)])
    pos_in_expert = csum.astype(jnp.int32) - jnp.take(expert_start, se)
    keep = pos_in_expert < capacity

    # ---- gather tokens into the (E, C, d) expert batch --------------------
    slot = jnp.where(keep, se * capacity + pos_in_expert, e * capacity)
    token_for_slot = jnp.zeros((e * capacity + 1,), jnp.int32) \
        .at[slot].set(st.astype(jnp.int32), mode="drop")[:-1]
    slot_filled = jnp.zeros((e * capacity + 1,), jnp.float32) \
        .at[slot].set(1.0, mode="drop")[:-1]
    expert_in = jnp.take(tokens, token_for_slot, axis=0) \
        * slot_filled[:, None].astype(tokens.dtype)
    expert_in = expert_in.reshape(e, capacity, d)
    if ep_constrain is not None:
        expert_in = ep_constrain(expert_in)

    # ---- expert FFNs (E sharded over the 'model' axis = EP) ---------------
    act = ACTIVATIONS[activation]
    gate = act(jnp.einsum("ecd,edf->ecf", expert_in, params["wi_gate"]))
    up = jnp.einsum("ecd,edf->ecf", expert_in, params["wi_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", gate * up, params["wo"])
    if ep_constrain is not None:
        expert_out = ep_constrain(expert_out)
    expert_out = expert_out.reshape(e * capacity, d)

    # ---- combine back ------------------------------------------------------
    contrib = jnp.take(expert_out, jnp.clip(slot, 0, e * capacity - 1),
                       axis=0)
    contrib = contrib * (sg * keep.astype(jnp.float32))[:, None] \
        .astype(contrib.dtype)
    out = jax.ops.segment_sum(contrib, st, num_segments=t)
    out = out.reshape(b, s, d).astype(x.dtype)

    # ---- aux: Switch-style load-balance loss -------------------------------
    frac_tokens = jax.ops.segment_sum(
        jnp.ones_like(flat_expert, dtype=jnp.float32), flat_expert,
        num_segments=e) / (t * top_k)
    frac_probs = probs.mean(axis=0)
    lb_loss = e * jnp.sum(frac_tokens * frac_probs)
    dropped = jnp.sum(1.0 - keep.astype(jnp.float32)) / (t * top_k)
    return out, {"lb_loss": lb_loss, "dropped_frac": dropped}
