"""GQA attention: training (full / query-chunked causal), prefill and decode.

The query-chunked path is a pure-JAX flash-attention analogue (lax.scan over
query blocks with key masking) that bounds the live score tensor to
(chunk x S) — required for the 32k-prefill cells, and the default whenever
S >= CHUNK_THRESHOLD.  The decode path is jnp (GSPMD-shardable over the KV
sequence axis for the 500k cells); the Pallas ``flash_decode`` kernel is the
TPU drop-in validated in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.rope import apply_rope

Array = jax.Array

CHUNK_THRESHOLD = 2048
DEFAULT_Q_CHUNK = 1024
_NEG_INF = -1e30


def init_attention(key: Array, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d_model)

    def mk(k, shape):
        return (jax.random.normal(k, shape, dtype=jnp.float32)
                * scale).astype(dtype)

    return {
        "wq": mk(k1, (d_model, n_heads, head_dim)),
        "wk": mk(k2, (d_model, n_kv_heads, head_dim)),
        "wv": mk(k3, (d_model, n_kv_heads, head_dim)),
        "wo": mk(k4, (n_heads, head_dim, d_model)),
    }


def _repeat_kv(k: Array, groups: int) -> Array:
    """(B, S, KVH, D) -> (B, S, KVH * G, D) by repetition (GQA)."""
    if groups == 1:
        return k
    b, s, kvh, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kvh, groups, d)) \
        .reshape(b, s, kvh * groups, d)


def causal_attention(q: Array, k: Array, v: Array,
                     q_offset: Array | int = 0) -> Array:
    """Full causal softmax attention. q: (B, Sq, H, D); k, v: (B, Sk, KVH, D).

    q_offset: absolute position of q[0] (for chunked calls) — query i may
    attend keys j <= i + q_offset.
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    k = _repeat_kv(k, h // kvh)
    v = _repeat_kv(v, h // kvh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / (d ** 0.5)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = kpos <= qpos                       # (Sq, Sk)
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_causal_attention(q: Array, k: Array, v: Array,
                             q_chunk: int = DEFAULT_Q_CHUNK,
                             unroll: bool = False,
                             chunk_constrain=None) -> Array:
    """Causal attention with the query axis scanned in chunks.

    Live memory per step: (B, H, q_chunk, S) scores instead of (B, H, S, S).
    Exact (not an approximation): each chunk sees the full key prefix.

    ``chunk_constrain``: optional sharding hook applied to each query chunk
    (and inverted on its output) — sequence-parallel attention for archs
    whose head count doesn't divide the TP axis (SSPerf iteration 2): the
    chunk's query rows spread over 'model', K/V stay replicated, so the
    score tile and its FLOPs shard 16-way with no collectives beyond the
    (tiny) output re-shard.
    """
    b, s, h, d = q.shape
    if s % q_chunk != 0 or s == q_chunk:
        return causal_attention(q, k, v)
    n_chunks = s // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    offsets = jnp.arange(n_chunks) * q_chunk

    def step(_, inp):
        q_i, off = inp
        if chunk_constrain is not None:
            q_i = chunk_constrain(q_i, True)
        out = causal_attention(q_i, k, v, q_offset=off)
        if chunk_constrain is not None:
            out = chunk_constrain(out, False)
        return None, out

    if not unroll:
        step = jax.checkpoint(step, prevent_cse=True)
    _, outs = jax.lax.scan(step, None, (qc, offsets), unroll=unroll)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


def attention_apply(params: dict, x: Array, positions: Array,
                    rope_theta: float = 10000.0,
                    q_chunk: int = DEFAULT_Q_CHUNK,
                    unroll: bool = False, chunk_constrain=None) -> Array:
    """Training/prefill attention over hidden states x: (B, S, d_model)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    s = x.shape[1]
    if s > CHUNK_THRESHOLD or chunk_constrain is not None:
        o = chunked_causal_attention(q, k, v, q_chunk, unroll=unroll,
                                     chunk_constrain=chunk_constrain)
    else:
        o = causal_attention(q, k, v)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


def decode_attention_jnp(q: Array, k_cache: Array, v_cache: Array,
                         cache_len: Array) -> Array:
    """One-token attention; q: (B, H, D); caches: (B, S, KVH, D).

    Pure jnp so GSPMD can shard the S axis (context parallelism for
    long_500k): the max/sum reductions over S lower to all-reduces.
    """
    b, h, d = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / (d ** 0.5)
    mask = jnp.arange(k_cache.shape[1])[None, None, None, :] \
        < cache_len[:, None, None, None]
    scores = jnp.where(mask, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def decode_step_attention(params: dict, x: Array, k_cache: Array,
                          v_cache: Array, cache_len: Array,
                          rope_theta: float = 10000.0
                          ) -> tuple[Array, Array, Array]:
    """Single-token decode: x (B, d_model); returns (out, new_k, new_v).

    The new token's K/V are written at position cache_len (per batch row).
    """
    b, d_model = x.shape
    q = jnp.einsum("bd,dhk->bhk", x, params["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, params["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, params["wv"])
    pos = cache_len.astype(jnp.int32)
    q = apply_rope(q[:, None], pos[:, None], rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], rope_theta)[:, 0]

    # Scatter the new K/V into the cache at cache_len.
    b_idx = jnp.arange(b)
    k_cache = k_cache.at[b_idx, pos].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[b_idx, pos].set(v.astype(v_cache.dtype))
    o = decode_attention_jnp(q, k_cache, v_cache, pos + 1)
    out = jnp.einsum("bhk,hkd->bd", o, params["wo"])
    return out, k_cache, v_cache
