"""Transformer substrate: norms, dense projections, gated FFNs.

Pure-functional modules: ``init_*`` build param pytrees (with matching
PartitionSpec trees supplied by ``repro.dist.sharding``); ``*_apply`` are
jittable.  Everything is einsum-based so GSPMD can shard along the annotated
logical axes.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, weight: Array, eps: float = 1e-6,
             plus_one: bool = False) -> Array:
    """RMSNorm; ``plus_one`` uses the (1 + w) parametrization (Gemma)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (xn * w).astype(dtype)


def init_dense(key: Array, d_in: int, d_out: int, dtype=jnp.bfloat16) -> Array:
    scale = 1.0 / jnp.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    return w.astype(dtype)


ACTIVATIONS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def init_glu_ffn(key: Array, d_model: int, d_ff: int,
                 dtype=jnp.bfloat16) -> dict:
    """Gated FFN (SwiGLU / GeGLU — the activation is chosen at apply time)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": init_dense(k1, d_model, d_ff, dtype),
        "wi_up": init_dense(k2, d_model, d_ff, dtype),
        "wo": init_dense(k3, d_ff, d_model, dtype),
    }


def glu_ffn_apply(params: dict, x: Array, activation: str = "silu") -> Array:
    act = ACTIVATIONS[activation]
    gate = act(jnp.einsum("...d,df->...f", x, params["wi_gate"]))
    up = jnp.einsum("...d,df->...f", x, params["wi_up"])
    return jnp.einsum("...f,fd->...d", gate * up, params["wo"])


def init_mlp(key: Array, dims: list[int], dtype=jnp.float32) -> list[dict]:
    """Plain MLP stack (used by the recsys / GNN heads)."""
    layers = []
    for i in range(len(dims) - 1):
        key, k = jax.random.split(key)
        layers.append({
            "w": init_dense(k, dims[i], dims[i + 1], dtype),
            "b": jnp.zeros((dims[i + 1],), dtype=dtype),
        })
    return layers


def mlp_apply(layers: list[dict], x: Array, activation: str = "relu",
              final_activation: bool = False) -> Array:
    act = ACTIVATIONS[activation]
    for i, layer in enumerate(layers):
        x = x @ layer["w"] + layer["b"]
        if i < len(layers) - 1 or final_activation:
            x = act(x)
    return x
