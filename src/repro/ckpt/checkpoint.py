"""Distributed checkpoint save/restore (fault tolerance).

Design (offline-friendly stand-in for orbax/tensorstore, same layout ideas):

  * a checkpoint is a directory ``step_<n>/`` holding one ``.npz`` per pytree
    leaf (host-gathered) + ``manifest.json`` (treedef, shapes, dtypes, step,
    data cursor, mesh shape at save time),
  * ``save`` is ASYNC: arrays are device_get'd synchronously (cheap vs a
    training step) and written by a daemon thread so the step loop never
    blocks on disk,
  * ``restore`` reshards onto the CURRENT mesh: leaves are placed via
    jax.device_put with the target sharding — the checkpoint is mesh-shape
    agnostic, which is what makes elastic re-scaling (repro.ft.elastic)
    work: save on 256 chips, restore on 512 or 64,
  * atomicity: writes go to ``<dir>.tmp`` then os.rename, so a preemption
    mid-save never corrupts the latest complete checkpoint,
  * retention: keep the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

Array = jax.Array


def _flatten_with_paths(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
             for path, _ in leaves_with_paths]
    leaves = [leaf for _, leaf in leaves_with_paths]
    return paths, leaves


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree: Any, extra: dict | None = None,
             blocking: bool = False) -> None:
        self.wait()   # one in-flight save at a time
        paths, leaves = _flatten_with_paths(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        treedef = jax.tree.structure(tree)
        manifest = {
            "step": int(step),
            "paths": paths,
            "treedef": str(treedef),
            "extra": extra or {},
        }

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "leaves.npz",
                     **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if p.is_dir() and not p.name.endswith(".tmp"))

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; reshard onto the current
        mesh via ``shardings`` (same pytree structure, or None = host)."""
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "leaves.npz")
        leaves = [data[f"leaf_{i}"] for i in range(len(manifest["paths"]))]
        treedef = jax.tree.structure(like)
        like_leaves = jax.tree.leaves(like)
        if len(like_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, target structure has "
                f"{len(like_leaves)} — incompatible config")
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))
            leaves = [jax.device_put(a.astype(l.dtype), s)
                      for a, l, s in zip(leaves, like_leaves, sh_leaves,
                                         strict=True)]
        else:
            leaves = [a.astype(l.dtype)
                      for a, l in zip(leaves, like_leaves, strict=True)]
        return jax.tree.unflatten(treedef, leaves), manifest["extra"]
