"""Runtime sanitizers for the invariants ``tools/dynlint`` checks
statically (see ``docs/invariants.md``).

Static analysis catches the patterns it can see; these guards catch the
instances it can't (aliases smuggled through containers, cross-module
call chains) by making the violation FAIL LOUDLY at the moment it
happens instead of silently reading stale memory:

* :class:`DonationGuard` — wraps a jitted function that donates input
  buffers.  On host-CPU backends donation is a no-op (XLA keeps the
  input alive), so a use-after-donation bug trains fine locally and
  corrupts state only on real accelerators.  Under ``REPRO_SANITIZE=1``
  the guard deletes the donated input buffers right after dispatch —
  deletion is deferred by the runtime until in-flight reads complete,
  so legal consumers (e.g. ``SlotStacker``'s already-dispatched copies)
  are unaffected, while any LATER touch of a stale reference raises
  ``RuntimeError: Array has been deleted`` at the exact broken line.

* :class:`ThreadAffinityGuard` — a non-blocking ownership gate for
  resident mutable state (the ``ServeEngine`` carries / warm-``z``
  cache).  Same-thread re-entry is fine (``advance`` flushes the query
  batchers); a SECOND thread entering while the first is still inside
  raises immediately and is counted, instead of two threads interleaving
  donated state-advances.  Always on — it costs one lock acquire.

``REPRO_SANITIZE=1`` is read per construction (not import), so tests can
toggle it; the trainers construct their appliers per epoch.
"""

from __future__ import annotations

import os
import threading
from typing import Callable, Sequence

import jax


def sanitize_enabled() -> bool:
    """True when runtime sanitizers should poison donated buffers."""
    return os.environ.get("REPRO_SANITIZE", "") == "1"


class DonationGuard:
    """Poison donated inputs of a jitted fn so reuse raises immediately.

    ``fn`` must be the jitted callable whose ``donate_argnums`` were
    ``donate_argnums`` — the guard does not re-jit; it only mirrors the
    donation contract onto the Python references.  With ``enabled=None``
    the guard reads ``REPRO_SANITIZE`` once at construction and is a
    zero-overhead passthrough when off.
    """

    def __init__(self, fn: Callable, donate_argnums: Sequence[int],
                 enabled: bool | None = None):
        self.fn = fn
        self.donate_argnums = tuple(donate_argnums)
        self.enabled = sanitize_enabled() if enabled is None else enabled

    def __call__(self, *args):
        out = self.fn(*args)
        if self.enabled:
            for i in self.donate_argnums:
                for leaf in jax.tree_util.tree_leaves(args[i]):
                    if isinstance(leaf, jax.Array) and not leaf.is_deleted():
                        # deferred by the runtime until dispatched reads
                        # of this buffer retire — safe under async dispatch
                        leaf.delete()
        return out


def guard_donated(fn: Callable, donate_argnums: Sequence[int]) -> Callable:
    """``fn`` unchanged when sanitizing is off, guarded when on."""
    if not sanitize_enabled():
        return fn
    return DonationGuard(fn, donate_argnums, enabled=True)


class ThreadAffinityGuard:
    """Reject concurrent entry into a resident-state critical region.

    Re-entrant for the OWNING thread (depth-counted); entry from any
    other thread while held raises ``RuntimeError`` and increments
    ``trips`` — the counter ``ServeResult.guard_trips`` surfaces.
    """

    def __init__(self, name: str):
        self.name = name
        self.trips = 0
        self._owner: int | None = None
        self._depth = 0
        self._mu = threading.Lock()

    def __enter__(self):
        me = threading.get_ident()
        with self._mu:
            if self._owner is None or self._owner == me:
                self._owner = me
                self._depth += 1
                return self
            self.trips += 1
            from repro import obs
            obs.inc("sanitize.guard_trips")
            raise RuntimeError(
                f"{self.name}: concurrent entry from thread {me} while "
                f"thread {self._owner} holds the resident state — "
                "ServeEngine ingest/advance/query must not run "
                "concurrently from multiple threads (serialize callers "
                "or run one engine per thread)")

    def __exit__(self, *exc):
        with self._mu:
            self._depth -= 1
            if self._depth == 0:
                self._owner = None
        return False
