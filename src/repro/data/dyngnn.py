"""Dynamic-graph data pipeline.

Host-side stages (the CPU side of the paper's CPU->GPU boundary):
  1. snapshot generation / loading (ragged numpy edge lists),
  2. smoothing (edge-life / M-transform) — §5.4 preprocessing,
  3. graph-difference delta encoding per checkpoint block (§3.2),
  4. padding + Laplacian normalization -> device-ready DTDG blocks,
  5. label synthesis for vertex classification / link prediction tasks.

``DTDGPipeline.epoch_blocks()`` yields per-block device arrays exactly the
way the blocked trainer consumes them; ``transfer_bytes()`` reports the
graph-difference savings the benchmark records.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import graphdiff, smoothing
from repro.core.dtdg import build_batch
from repro.graph import generate
from repro.stream import encoder as stream_encoder
from repro.stream import sharded as stream_sharded


@dataclass
class DTDGDataset:
    snapshots: list[np.ndarray]
    values: list[np.ndarray] | None
    frames: np.ndarray              # (T, N, F)
    labels: np.ndarray              # (T, N)
    num_nodes: int

    @property
    def num_steps(self) -> int:
        return len(self.snapshots)


def dataset_from_snapshots(snaps: list[np.ndarray], num_nodes: int,
                           smoothing_mode: str = "none", window: int = 5,
                           edge_life: int = 5) -> DTDGDataset:
    """Raw snapshot edge lists -> device-ready DTDG dataset.

    The one post-processing path (smoothing §5.4 -> degree features ->
    synthetic labels) shared by the synthetic generator and the file
    loaders (``repro.run.data.EdgeListDTDG``).

    smoothing_mode: none (CD-GCN) | mproduct (TM-GCN) | edgelife (EvolveGCN).
    """
    values = None
    if smoothing_mode == "mproduct":
        snaps, values = smoothing.m_transform_sparse(snaps, window)
    elif smoothing_mode == "edgelife":
        snaps, values = smoothing.edge_life(snaps, edge_life)
    elif smoothing_mode != "none":
        raise ValueError(f"unknown smoothing_mode {smoothing_mode!r}")
    frames = np.stack([generate.degree_features(s, num_nodes)
                       for s in snaps])
    # synthetic-but-learnable labels: high in-degree (above median) = class 1
    med = np.median(frames[:, :, 0], axis=1, keepdims=True)
    labels = (frames[:, :, 0] > med).astype(np.int32)
    return DTDGDataset(snapshots=snaps, values=values, frames=frames,
                       labels=labels, num_nodes=num_nodes)


def synthetic_dataset(num_nodes: int, num_steps: int, density: float = 3.0,
                      churn: float = 0.1, smoothing_mode: str = "none",
                      window: int = 5, edge_life: int = 5,
                      seed: int = 0) -> DTDGDataset:
    """Evolving synthetic DTDG with degree features and synthetic labels."""
    snaps = generate.evolving_dynamic_graph(num_nodes, num_steps, density,
                                            churn, seed)
    return dataset_from_snapshots(snaps, num_nodes,
                                  smoothing_mode=smoothing_mode,
                                  window=window, edge_life=edge_life)


class DTDGPipeline:
    def __init__(self, ds: DTDGDataset, nb: int, max_edges: int | None = None,
                 use_graph_diff: bool = True):
        self.ds = ds
        self.nb = nb
        self.bsize = ds.num_steps // nb
        loops = ds.num_nodes
        if max_edges is None:
            max_edges = max(s.shape[0] for s in ds.snapshots) + loops
            max_edges = ((max_edges + 127) // 128) * 128
        self.max_edges = max_edges
        self.use_graph_diff = use_graph_diff
        self._batch = None
        # streamed transfer: vectorized encoder, churn-stat-sized pads.
        # Only the byte total is retained — the streaming paths re-encode
        # lazily (host_stream), so holding T padded items here would just
        # duplicate the trace in host memory.
        self.stream_stats = stream_encoder.measure_stats(
            ds.snapshots, ds.num_nodes, self.bsize, max_edges)
        self._stream_bytes = sum(
            item.payload_bytes for item in self.host_stream())

    @property
    def batch(self):
        """Device-ready padded batch (precomputed Laplacian weights,
        §5.5) — built LAZILY on first access: only the eager schedule
        (and evaluation) materializes the full (T, E, ...) tensors on
        device; the streamed and sampled schedules never touch it, so
        an out-of-core run can build the pipeline without allocating a
        device batch that would not fit."""
        if self._batch is None:
            self._batch = build_batch(self.ds.snapshots, self.ds.frames,
                                      self.ds.num_nodes,
                                      max_edges=self.max_edges,
                                      values=self.ds.values)
        return self._batch

    def transfer_bytes(self) -> dict:
        gd = self._stream_bytes
        base = graphdiff.naive_bytes(self.ds.snapshots)
        return {"graph_diff": gd, "naive": base,
                "ratio": gd / max(base, 1)}

    def host_stream(self):
        """Lazy re-encode of the trace (what the prefetch thread drains)."""
        return stream_encoder.iter_encode_stream(
            self.ds.snapshots, self.ds.values, self.ds.num_nodes,
            self.max_edges, self.bsize, self.stream_stats)

    def sharded_streams(self, num_shards: int, wire: str = "none"):
        """Per-shard time-slice streams for snapshot partitioning
        (``wire="int8"`` = the narrow delta format, see stream.wire)."""
        return stream_sharded.encode_time_sliced(
            self.ds.snapshots, self.ds.values, self.ds.num_nodes,
            self.max_edges, self.bsize, num_shards, self.stream_stats,
            wire=wire)

    def blocked_arrays(self):
        """(frames, edges, edge_weights, labels) blocked (nb, bsize, ...)."""
        import jax.numpy as jnp

        def blk(a):
            t = a.shape[0]
            return a.reshape((self.nb, t // self.nb) + a.shape[1:])

        return (blk(self.batch.frames), blk(self.batch.edges),
                blk(self.batch.edge_weights),
                blk(jnp.asarray(self.ds.labels)))
