"""Mid-run recomposition of the distributed stream (the elastic loop).

``train_elastic_streamed`` drives the same per-round protocol as
``stream.distributed.train_distributed_streamed`` but in SEGMENTS of
constant snapshot-parallel width.  At every checkpoint-block boundary it
asks the :class:`~repro.elastic.controller.RescaleController` what width
the next block should train under; when the answer changes it

1. re-commits params + optimizer state onto the new mesh and re-shards
   the temporal carries with one gather/scatter
   (``repro.elastic.reshard``, bytes accounted by
   ``dist.comm_volume.rescale_payload``),
2. re-slices the REMAINING per-shard delta streams for the new width
   from that boundary (``stream.sharded.encode_time_sliced(start_step)``
   — legal because every block slice opens with a self-contained
   ``FullSnapshot``),
3. rebuilds prefetch rings / ``DeltaApplier`` buffers on the new mesh
   (the segment call constructs them per mesh), and
4. records a :class:`RescaleEvent` on the run's ``RescaleReport``.

The hard invariant: rescaling is SCHEDULE, not math.  Each block is one
mean-CE AdamW step over ``win`` snapshots whatever P computes it, and
carries cross boundaries by placement change only — so the loss stream
under any rescale trajectory stays pinned to the serial single-device
reference at block granularity (``tests/test_elastic.py``), pipelined or
not.

Checkpointing rides on the same boundaries: every ``ckpt_every`` rounds
(and on an unabsorbed SIGTERM) the loop saves params/opt/carries plus
the data cursor, and a restored run continues from that cursor — on ANY
legal width, since the checkpoint is mesh-agnostic (``repro.ckpt``).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax

from repro import obs
from repro.core import models as mdl
from repro.ft.straggler import StepTimer
from repro.elastic import reshard
from repro.elastic.controller import (RescaleController, RescaleEvent,
                                      RescaleReport)
from repro.optim import adamw
from repro.stream import distributed as sdist
from repro.stream import encoder as enc
from repro.stream import sharded as stream_sharded
from repro.stream import train_loop as tl


class ElasticRuntime:
    """Caches that survive rescale events and repeated fits.

    Meshes and compiled steps are keyed by width (a width that comes
    back reuses its executable); encoded per-shard streams are keyed by
    width alone and encoded ONCE from block 0 — a from-boundary request
    is served by slicing that encoding (see ``shard_streams``), so only
    the first appearance of a width pays the encode, measured into
    ``RescaleEvent.recompose_s``.
    """

    def __init__(self, cfg, opt_cfg, axis: str = "data",
                 a2a_chunks: int = 1):
        self.cfg, self.opt_cfg, self.axis = cfg, opt_cfg, axis
        self.a2a_chunks = a2a_chunks
        self.meshes: dict = {}
        self.steps: dict = {}
        self.streams: dict = {}

    def mesh(self, p: int):
        if p not in self.meshes:
            from repro.launch.mesh import make_host_mesh
            self.meshes[p] = make_host_mesh(data=p, model=1)
        return self.meshes[p]

    def step(self, p: int):
        if p not in self.steps:
            self.steps[p] = sdist.make_dist_stream_step(
                self.cfg, self.mesh(p), self.opt_cfg, self.axis,
                a2a_chunks=self.a2a_chunks)
        return self.steps[p]

    def shard_streams(self, p: int, start_block: int, snapshots, values,
                      max_edges: int, win: int, stats):
        """Per-shard streams for width ``p`` from ``start_block`` on.

        The from-boundary encoding equals the tail of the from-zero
        encoding (every block slice opens with a self-contained
        ``FullSnapshot``; pinned by ``tests/test_elastic.py``), so a
        boundary request is a LIST SLICE of the cached per-width
        encoding — checkpoint ticks and repeated boundaries cost no
        re-encode and no extra retained memory.
        """
        if p not in self.streams:
            self.streams[p] = stream_sharded.encode_time_sliced(
                snapshots, values, self.cfg.num_nodes, max_edges, win, p,
                stats)
        if start_block == 0:
            return self.streams[p]
        bsl = win // p
        return [s[start_block * bsl:] for s in self.streams[p]]


@dataclass
class ElasticStreamState:
    """What the elastic loop hands back to the Engine worker."""

    params: dict
    opt_state: dict
    losses: list
    report: RescaleReport
    cursor: int             # global rounds completed == resume point
    completed: bool         # False = preempted (checkpointed, resumable)
    carries: object = field(default=None, repr=False)


def validate_widths(widths, win: int, num_nodes: int,
                    num_devices: int) -> None:
    """Every width a rescale policy can ask for must be realizable: fit
    the attached devices, divide the block (each round is sliced over
    the shards) and the vertex axis (N-sharded temporal stage).  The one
    rule set — ``Engine.resolve`` and the elastic loop both call it."""
    for p in widths:
        if p < 1:
            raise ValueError(f"rescale width must be >= 1, got {p}")
        if p > num_devices:
            raise ValueError(f"rescale width {p} exceeds the {num_devices} "
                             "attached devices")
        if win % p:
            raise ValueError(f"rescale width {p} does not divide the "
                             f"checkpoint block size {win}")
        if num_nodes % p:
            raise ValueError(f"rescale width {p} does not divide num_nodes "
                             f"{num_nodes} (vertex-sharded temporal "
                             "stage); pad the vertex axis")


def _ckpt_tree(cfg, params, opt_state, carries):
    # carries is None exactly at epoch boundaries; the restore side
    # ignores the values there, but the pytree structure must match.
    if carries is None:
        carries = mdl.init_carries(cfg, params)
    return {"params": params, "opt": opt_state, "carries": carries}


def train_elastic_streamed(cfg, snapshots, values, frames, labels, *,
                           controller: RescaleController,
                           axis: str = "data",
                           block_size: int | None = None,
                           num_epochs: int = 1, overlap: bool = True,
                           prefetch_depth: int = 2, a2a_chunks: int = 1,
                           pipeline_rounds: bool = False,
                           opt_cfg: adamw.AdamWConfig | None = None,
                           params: dict | None = None, opt_state=None,
                           stats: enc.DeltaStats | None = None,
                           max_edges: int | None = None,
                           runtime: ElasticRuntime | None = None,
                           ckpt=None, ckpt_every: int = 0,
                           start_cursor: int = 0, carries=None,
                           seed: int = 0,
                           log_every: int = 10,
                           log_fn=None) -> ElasticStreamState:
    """Distributed streamed training whose width P may change mid-run.

    Semantics are those of ``train_distributed_streamed`` round for
    round; the controller only decides WHICH mesh computes each block.
    ``start_cursor``/``carries`` resume a checkpointed run (global round
    cursor; carries may come host-gathered from the checkpoint — they
    are re-placed onto the current mesh here).  ``ckpt``/``ckpt_every``
    enable round-granular checkpointing (a ``repro.ckpt.Checkpointer``;
    0 = only on preemption).
    """
    t_steps = len(snapshots)
    win = block_size or max(t_steps // max(cfg.checkpoint_blocks, 1), 1)
    if t_steps % win:
        raise ValueError(f"trace length {t_steps} must be a multiple of "
                         f"block_size {win}")
    rpe = t_steps // win                    # rounds (blocks) per epoch
    total = num_epochs * rpe
    if not 0 <= start_cursor <= total:
        raise ValueError(f"start_cursor {start_cursor} outside the run's "
                         f"{total} rounds")
    max_edges = max_edges or tl.default_max_edges(snapshots)
    if stats is None:
        stats = enc.measure_stats(snapshots, cfg.num_nodes, win, max_edges)
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10, total_steps=num_epochs * t_steps,
        weight_decay=0.0)
    if params is None:
        params = mdl.init_params(jax.random.PRNGKey(seed), cfg)
    if opt_state is None:
        opt_state = adamw.init_state(params)
    rt = runtime or ElasticRuntime(cfg, opt_cfg, axis, a2a_chunks)
    validate_widths(set(controller.widths), win, cfg.num_nodes,
                    len(jax.devices()))

    report = RescaleReport(resumed_from=start_cursor or None)
    losses: list[float] = []
    completed = True
    p = controller.initial_p
    r = start_cursor
    # one EWMA watchdog across every segment (reset at each rescale)
    timer = StepTimer()

    def save(blocking=False):
        if ckpt is not None:
            ckpt.save(r, _ckpt_tree(cfg, params, opt_state, carries),
                      extra={"cursor": r, "p": p,
                             "rounds_per_epoch": rpe},
                      blocking=blocking)

    while r < total:
        epoch, rb = divmod(r, rpe)
        if rb == 0 and r != start_cursor:
            carries = None                  # epoch boundary: fresh carries
        new_p, cause = controller.width_at(r, p)
        if new_p != p:
            with obs.stopwatch("elastic.rescale", cat="elastic", block=r,
                               old_p=p, new_p=new_p, cause=cause) as sw:
                mesh2 = rt.mesh(new_p)
                payload = reshard.rescale_payload_bytes(params, opt_state,
                                                        carries, p, new_p)
                params = reshard.replicate_on(mesh2, params)
                opt_state = reshard.replicate_on(mesh2, opt_state)
                if carries is not None:
                    carries = reshard.reshard_carries(cfg, carries, mesh2,
                                                      axis)
                # stream recompose is part of the same boundary: re-slice
                # the remaining timeline for the new width so the measured
                # recompose time covers re-encode + re-shard
                rt.shard_streams(new_p, rb, snapshots, values, max_edges,
                                 win, stats)
            dt = sw.seconds
            report.events.append(RescaleEvent(
                block=r, old_p=p, new_p=new_p, payload_bytes=payload,
                recompose_s=dt, cause=cause))
            obs.inc("elastic.rescales")
            obs.inc("elastic.payload_bytes", payload)
            # the expected round time changes with the width: restart the
            # EWMA so the watchdog re-seeds on the new mesh's pace
            timer.reset()
            if log_fn is not None:
                log_fn(f"elastic: rescale P {p} -> {new_p} at block {r} "
                       f"({cause}; payload {payload} B, recompose "
                       f"{dt * 1e3:.1f} ms)")
            p = new_p
        elif carries is not None:
            # resume path: host-gathered checkpoint carries need their
            # mesh placement (no-op for carries already on this mesh)
            carries = reshard.reshard_carries(cfg, carries, rt.mesh(p),
                                              axis)

        # segment end: next scripted boundary / epoch end / ckpt tick
        seg_end = (epoch + 1) * rpe
        nxt = controller.next_boundary(r)
        if nxt is not None:
            seg_end = min(seg_end, nxt)
        if ckpt is not None and ckpt_every:
            seg_end = min(seg_end, ((r // ckpt_every) + 1) * ckpt_every)

        bsl = win // p
        streams_full = rt.shard_streams(p, rb, snapshots, values,
                                        max_edges, win, stats)
        seg_streams = [s[:(seg_end - r) * bsl] for s in streams_full]
        report.segments.append(
            (r, p, [sum(i.payload_bytes for i in s) for s in seg_streams]))
        st = sdist.train_distributed_streamed(
            cfg, snapshots, values, frames, labels, mesh=rt.mesh(p),
            axis=axis, block_size=win, num_epochs=1, overlap=overlap,
            prefetch_depth=prefetch_depth, a2a_chunks=a2a_chunks,
            pipeline_rounds=pipeline_rounds, opt_cfg=opt_cfg,
            params=params, opt_state=opt_state, stats=stats,
            max_edges=max_edges, step_fn=rt.step(p), seed=seed,
            shard_streams=seg_streams, start_round=rb, carries=carries,
            stop_fn=(lambda _blk: controller.interrupt())
            if controller.guard is not None else None,
            log_every=log_every, log_fn=log_fn, step_timer=timer)
        params, opt_state, carries = st.params, st.opt_state, st.carries
        losses.extend(st.losses)
        r += len(st.losses)

        if controller.should_stop(p):
            save(blocking=True)
            completed = False
            report.preempted = True
            if log_fn is not None:
                log_fn(f"elastic: preempted at block {r}; "
                       + ("checkpointed, " if ckpt is not None else "")
                       + "exiting cleanly")
            break
        if (ckpt is not None and ckpt_every and r % ckpt_every == 0):
            save()
    if ckpt is not None:
        ckpt.wait()
    return ElasticStreamState(params=params, opt_state=opt_state,
                              losses=losses, report=report, cursor=r,
                              completed=completed, carries=carries)
