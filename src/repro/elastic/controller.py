"""Rescale policy: WHEN the snapshot-parallel width changes, and to what.

The controller is pure decision logic — no meshes, no device state.  It
consumes two event sources and answers one question per checkpoint-block
boundary ("what width should the next block train under?"):

* a scripted ``schedule`` of ``(block, new_p)`` pairs — the deterministic
  source tests, benchmarks, and the launcher's ``--rescale-at`` use.
  ``block`` is the GLOBAL round index (rounds count across epochs; one
  round = one checkpoint block) at which the new width takes effect;
* a :class:`repro.ft.elastic.PreemptionGuard` — when SIGTERM fires and
  ``shrink_to`` is set, the controller absorbs the capacity loss by
  shrinking to that width at the NEXT boundary instead of stopping;
  without ``shrink_to`` the flag tells the training loop to
  checkpoint-and-exit cleanly (classic preemption).

Either way a change is only ever REALIZED at a block boundary.  That
deferral is what makes elasticity cheap here: the per-shard delta
streams open every block slice with a self-contained ``FullSnapshot``,
so no decoder state crosses a boundary, and the only state that has to
move is the block-boundary temporal carries plus (when growing) the
replicated train state — see ``repro.elastic.reshard`` and
``repro.dist.comm_volume.rescale_payload``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.ft.elastic import PreemptionGuard


@dataclass(frozen=True)
class RescaleEvent:
    """One EXECUTED rescale, recorded on the :class:`RescaleReport`."""

    block: int          # global round (= checkpoint-block) boundary
    old_p: int
    new_p: int
    payload_bytes: int  # re-shard bytes (== comm_volume.rescale_payload)
    # wall time actually paid at this boundary: state re-shard + (only
    # the FIRST time a width appears) the per-width stream encode — an
    # amortized cost; later boundaries of the same width slice the
    # runtime's cached encoding for free (ElasticRuntime.shard_streams)
    recompose_s: float
    cause: str = "scheduled"        # "scheduled" | "preemption"


@dataclass
class RescaleReport:
    """What ``Engine.fit`` records about an elastic run.

    ``events`` are the realized rescales in order; ``segments`` the
    ``(start_block, width, per_shard_bytes)`` stream accounting of every
    constant-width stretch (the segment's PLANNED slice payload — a
    preempted segment may stop before streaming its tail); ``preempted``
    is True when the run stopped on SIGTERM (checkpointed, resumable);
    ``resumed_from`` the global round a resumed run continued at (None
    for fresh runs).
    """

    events: list = field(default_factory=list)
    segments: list = field(default_factory=list)
    preempted: bool = False
    resumed_from: int | None = None

    @property
    def widths(self) -> list[int]:
        """Width trajectory: initial width followed by each new_p."""
        if not self.events and not self.segments:
            return []
        first = (self.segments[0][1] if self.segments
                 else self.events[0].old_p)
        return [first] + [e.new_p for e in self.events]


def validate_schedule(schedule) -> tuple:
    """Normalize + validate a scripted resize schedule.

    THE one rule set for ``(block, new_p)`` scripts —
    ``ExecutionPlan.validate`` and ``RescaleController`` both call it,
    so the Engine surface and the direct API can never drift apart.
    Returns the normalized ``((block, new_p), ...)`` tuple.
    """
    events = []
    last = 0
    for entry in schedule:
        try:
            b, p = entry
        except (TypeError, ValueError):
            raise ValueError(
                f"rescale schedule entries must be (block, new_p) "
                f"pairs, got {entry!r}") from None
        b, p = int(b), int(p)
        if b < 1:
            raise ValueError(
                f"rescale boundaries start at block 1 (block 0 is the "
                f"initial width), got {b}")
        if b <= last:
            raise ValueError(
                "rescale boundaries must be strictly increasing, got "
                f"block {b} after {last}")
        if p < 1:
            raise ValueError(f"rescale width must be >= 1, got {p}")
        events.append((b, p))
        last = b
    return tuple(events)


class RescaleController:
    """Decides the snapshot-parallel width at every block boundary."""

    def __init__(self, initial_p: int, schedule=(),
                 guard: PreemptionGuard | None = None,
                 shrink_to: int | None = None):
        if initial_p < 1:
            raise ValueError(f"initial_p must be >= 1, got {initial_p}")
        if shrink_to is not None and shrink_to < 1:
            raise ValueError(f"shrink_to must be >= 1, got {shrink_to}")
        self.initial_p = int(initial_p)
        self.schedule: tuple = validate_schedule(schedule)
        self.guard = guard
        self.shrink_to = shrink_to
        self._shrunk = False

    # ------------------------------------------------------- queries ------

    @property
    def widths(self) -> tuple[int, ...]:
        """Every width this controller can ask for (validation input)."""
        ws = (self.initial_p,) + tuple(p for _, p in self.schedule)
        if self.shrink_to is not None:
            ws += (self.shrink_to,)
        return ws

    def scripted_width(self, block: int) -> int:
        """Width the schedule alone prescribes for ``block``."""
        p = self.initial_p
        for b, new_p in self.schedule:
            if b <= block:
                p = new_p
        return p

    def width_at(self, block: int, current_p: int) -> tuple[int, str]:
        """(width to train block under, cause).  A pending preemption
        shrink is realized here — once, and it then sticks (a lost pod
        does not come back because the script said so).  Absorbing the
        shrink CLEARS the guard's flag so a SECOND SIGTERM re-arms
        ``interrupt``/``should_stop`` — already at the shrink width,
        the only remaining graceful answer is checkpoint-and-exit.
        A shrink only absorbs when it actually SHRINKS: at or above the
        current width it would be a silent no-op, so ``should_stop``
        treats that signal as unabsorbable instead."""
        if (self.guard is not None and self.guard.preempted
                and self.shrink_to is not None and not self._shrunk
                and self.shrink_to < current_p):
            self._shrunk = True
            self.guard.preempted = False
        if self._shrunk:
            return min(self.shrink_to, current_p), "preemption"
        return self.scripted_width(block), "scheduled"

    def next_boundary(self, block: int) -> int | None:
        """Next scripted boundary strictly after ``block`` (None = none)."""
        for b, _ in self.schedule:
            if b > block:
                return b
        return None

    # ------------------------------------------------- interruptions ------

    def interrupt(self) -> bool:
        """True when the running segment should stop at the next block
        boundary: SIGTERM arrived and has not been absorbed yet
        (``width_at`` clears the flag when a shrink absorbs it)."""
        return self.guard is not None and self.guard.preempted

    def should_stop(self, current_p: int | None = None) -> bool:
        """True when the run should checkpoint-and-exit: SIGTERM with no
        shrink width left to absorb it — none configured, the one shrink
        already spent on an earlier signal, or (when ``current_p`` is
        given) a shrink target at/above the current width, which could
        only no-op."""
        if self.guard is None or not self.guard.preempted:
            return False
        if self.shrink_to is None or self._shrunk:
            return True
        return current_p is not None and self.shrink_to >= current_p
