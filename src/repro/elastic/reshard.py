"""One gather/scatter moves a live run onto a new mesh.

The entire data-movement cost of an elastic rescale lives in this
module, and it is O(model state), independent of the trace length:

* **temporal carries** — the only block-boundary activations (paper
  §3.1's ``pi_b``).  They live vertex-sharded on the old mesh; one
  ``jax.device_put`` per leaf onto the new mesh's
  ``stream_carry_specs`` sharding re-lays them out (XLA lowers the
  cross-mesh placement to a single gather/scatter per array);
* **train state** — params + optimizer moments are replicated, so a
  GROWING mesh ships one replica to each newly added device and a
  shrinking mesh moves nothing (survivors already hold replicas).

``rescale_payload_bytes`` is the measured-tree instantiation of the
analytic ``repro.dist.comm_volume.rescale_payload`` — the trainer's
:class:`~repro.elastic.controller.RescaleEvent` records exactly what the
analytic model predicts, so the benchmark rows and the report can never
drift apart.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.dist import comm_volume as cv
from repro.dist import sharding as shardlib


def tree_bytes(tree) -> int:
    """Total bytes of every array leaf in ``tree`` (0 for None)."""
    if tree is None:
        return 0
    return int(sum(x.nbytes for x in jax.tree.leaves(tree)))


def replicate_on(mesh, tree):
    """Commit every leaf of ``tree`` replicated over ``mesh``.

    Used for params/optimizer state at a width change: arrays committed
    to the OLD mesh's devices must be re-committed before the new mesh's
    jitted step may consume them.
    """
    sh = NamedSharding(mesh, PartitionSpec())
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


def reshard_carries(cfg, carries, mesh, axis: str = "data"):
    """Temporal carries -> their stream shardings on ``mesh``.

    Accepts carries committed to any previous mesh OR host arrays (a
    restored checkpoint): either way each leaf lands with the
    vertex-sharded/replicated layout ``dist.sharding.stream_carry_specs``
    prescribes for the snapshot-parallel streamed step.
    """
    shardings = shardlib.named(mesh, shardlib.stream_carry_specs(cfg, axis))
    return jax.tree.map(jax.device_put, carries, shardings)


def rescale_payload_bytes(params, opt_state, carries, old_p: int,
                          new_p: int) -> int:
    """Bytes one P_old -> P_new rescale moves, from the live trees.

    Same quantity as ``comm_volume.rescale_payload`` — this just
    measures ``carry_bytes`` / ``state_bytes`` off the actual pytrees
    instead of taking them as arguments.
    """
    carry_b = tree_bytes(carries)
    state_b = tree_bytes(params) + tree_bytes(opt_state)
    return int(cv.rescale_payload(carry_b, state_b, old_p, new_p))
