"""``repro.elastic`` — rescale the distributed stream mid-run.

The paper's fixed-volume snapshot distribution makes elasticity cheap:
communication stays O(T*N) at ANY snapshot-parallel width P, so changing
P mid-fit only requires re-blocking the timeline at the next
checkpoint-block boundary and moving the boundary state.  This package
turns that observation into a subsystem:

* :class:`~repro.elastic.controller.RescaleController` — consumes resize
  events (a scripted ``(block, new_p)`` schedule and/or a
  ``PreemptionGuard``-driven shrink) and defers every change to the next
  block boundary;
* :mod:`~repro.elastic.reshard` — the one gather/scatter that moves
  carries + train state onto the new mesh, with byte accounting that
  matches ``dist.comm_volume.rescale_payload``;
* :func:`~repro.elastic.train.train_elastic_streamed` — the segment loop
  that re-slices the per-shard delta streams from the boundary
  (``stream.sharded.encode_time_sliced(start_step=...)``), rebuilds the
  prefetch rings on the new mesh, and records every event on a
  :class:`~repro.elastic.controller.RescaleReport`;
* round-granular checkpoint/resume: a run checkpointed at one width
  restores onto any other legal width.

Engine surface: ``ExecutionPlan(rescale=((block, new_p), ...),
rescale_on_preempt=w)`` and ``RunResult.rescale_report`` — see
``docs/run_api.md`` and the "Elasticity" section of
``docs/architecture.md``.  Losses are invariant under any rescale
trajectory (``tests/test_elastic.py`` pins P=4 -> 8 -> 2 against the
serial single-device reference).
"""

from repro.elastic.controller import (RescaleController, RescaleEvent,
                                      RescaleReport)
from repro.elastic.reshard import (replicate_on, rescale_payload_bytes,
                                   reshard_carries, tree_bytes)
from repro.elastic.train import (ElasticRuntime, ElasticStreamState,
                                 train_elastic_streamed, validate_widths)

__all__ = [
    "ElasticRuntime", "ElasticStreamState", "RescaleController",
    "RescaleEvent", "RescaleReport", "replicate_on",
    "rescale_payload_bytes", "reshard_carries", "train_elastic_streamed",
    "tree_bytes", "validate_widths",
]
