"""Pallas TPU kernel: single-token GQA decode attention over a long KV cache.

Serving the LM cells (``decode_32k`` / ``long_500k``) is one new token
attending to S cached entries: entirely memory-bound (read 2*S*D per kv head).
Flash-style blocked streaming keeps the working set in VMEM:

grid (B, KVH, S / S_BLK) with the KV axis innermost (sequential on TPU —
grid steps run in order on the core, so VMEM scratch persists across them):
running max m, denominator l and weighted accumulator acc are carried across
KV blocks; the final block writes acc / l.

The G = Hq / KVH query heads that share one KV head ride together as the
MXU's left operand: scores (G x S_BLK) = q_g @ k_blk^T.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_KV_BLOCK = 512
_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref, l_ref,
            *, kv_block: int, num_kv_blocks: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                        # (G, D)
    k = k_ref[0, :, 0, :]                  # (S_BLK, D)
    v = v_ref[0, :, 0, :]                  # (S_BLK, D)
    valid_len = len_ref[0, 0]              # scalar: #valid cache entries

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale       # (G, S_BLK)
    pos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) \
        + s_idx * kv_block
    scores = jnp.where(pos < valid_len, scores, _NEG_INF)

    m_prev = m_ref[...]                    # (G, 1)
    m_cur = jnp.max(scores, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    correction = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)            # (G, S_BLK)
    l_new = l_ref[...] * correction + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * correction + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kv_block", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 cache_len: jax.Array, kv_block: int = DEFAULT_KV_BLOCK,
                 interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k, v: (B, S, KVH, D); cache_len: (B,) int32 -> (B, Hq, D).

    Hq must be a multiple of KVH (GQA); S a multiple of kv_block.
    """
    b, hq, d = q.shape
    _, s, kvh, _ = k.shape
    if hq % kvh != 0:
        raise ValueError(f"Hq={hq} not a multiple of KVH={kvh}")
    g = hq // kvh
    if s % kv_block != 0:
        raise ValueError(f"S={s} not a multiple of kv_block={kv_block}")
    num_kv_blocks = s // kv_block
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, kvh, g, d)
    lens = jnp.broadcast_to(cache_len.astype(jnp.int32).reshape(b, 1),
                            (b, kvh))

    out = pl.pallas_call(
        functools.partial(_kernel, kv_block=kv_block,
                          num_kv_blocks=num_kv_blocks, scale=scale),
        grid=(b, kvh, num_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, _s: (b_, h_, 0, 0)),
            pl.BlockSpec((1, kv_block, 1, d),
                         lambda b_, h_, s_: (b_, s_, h_, 0)),
            pl.BlockSpec((1, kv_block, 1, d),
                         lambda b_, h_, s_: (b_, s_, h_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, _s: (b_, h_)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, _s: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),   # acc
            pltpu.VMEM((g, 1), jnp.float32),   # running max m
            pltpu.VMEM((g, 1), jnp.float32),   # running denom l
        ],
        interpret=interpret,
    )(qg, k, v, lens)
    return out.reshape(b, hq, d)
