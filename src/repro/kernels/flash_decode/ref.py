"""Pure-jnp oracle for flash decode: masked GQA attention for one token."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     cache_len: jax.Array) -> jax.Array:
    """q: (B, Hq, D); k, v: (B, S, KVH, D); cache_len: (B,) -> (B, Hq, D)."""
    b, hq, d = q.shape
    _, s, kvh, _ = k.shape
    g = hq // kvh
    qg = q.reshape(b, kvh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, kf) / (d ** 0.5)
    mask = jnp.arange(s)[None, None, None, :] < \
        cache_len.astype(jnp.int32)[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(b, hq, d).astype(q.dtype)
