"""Jit'd public wrapper for the flash-decode Pallas kernel."""

from __future__ import annotations

import jax

from repro.kernels.common import resolve_interpret
from repro.kernels.flash_decode import ref as _ref
from repro.kernels.flash_decode.flash_decode import flash_decode

flash_decode_ref = _ref.flash_decode_ref


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cache_len: jax.Array, kv_block: int = 512,
                     use_pallas: bool = True,
                     interpret: bool | None = None) -> jax.Array:
    if use_pallas:
        return flash_decode(q, k, v, cache_len, kv_block=kv_block,
                            interpret=resolve_interpret(interpret))
    return flash_decode_ref(q, k, v, cache_len)
