"""Pure-jnp oracle for the banded-TTM M-product kernel: the dense TTM."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def m_matrix(num_steps: int, window: int, t_offset: int = 0) -> np.ndarray:
    """Dense M restricted to a slice starting at global index t_offset.

    Entries whose source column falls before the slice are dropped (callers
    of the sliced form discard those rows — prefix pattern).
    """
    m = np.zeros((num_steps, num_steps), dtype=np.float32)
    for t in range(num_steps):
        g = t + t_offset + 1
        lo_g = max(1, g - window + 1)
        for kg in range(lo_g, g + 1):
            k = kg - t_offset - 1
            if 0 <= k < num_steps:
                m[t, k] = 1.0 / min(window, g)
    return m


def banded_ttm_ref(x: jax.Array, window: int, t_offset: int = 0) -> jax.Array:
    """Dense-matmul oracle: Y = M @ X over the flattened trailing dims."""
    t = x.shape[0]
    m = jnp.asarray(m_matrix(t, window, t_offset), dtype=x.dtype)
    flat = x.reshape(t, -1)
    return (m @ flat).reshape(x.shape)
