"""Jit'd public wrapper for the banded-TTM Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import resolve_interpret
from repro.kernels.mproduct import ref as _ref
from repro.kernels.mproduct.mproduct import banded_ttm

banded_ttm_ref = _ref.banded_ttm_ref
m_matrix = _ref.m_matrix


def m_product(x: jax.Array, window: int, t_offset: jax.Array | int = 0,
              interpret: bool | None = None) -> jax.Array:
    """TM-GCN temporal op on a (T, N, F) tensor via the Pallas kernel.

    Drop-in for ``repro.core.temporal.m_product`` (use_pallas path).
    ``interpret=None`` resolves from the backend: interpret on CPU only.
    """
    t = x.shape[0]
    flat = x.reshape(t, -1)
    y = banded_ttm(flat, window, t_offset,
                   interpret=resolve_interpret(interpret))
    return y.reshape(x.shape)
