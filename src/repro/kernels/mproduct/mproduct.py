"""Pallas TPU kernel: banded TTM (tensor-times-matrix) for the TM-GCN
M-product (paper §5.3).

Y = M x_1 X with M the (T x T) banded lower-triangular averaging matrix
M[t, k] = 1/min(w, t) on max(1, t-w+1) <= k <= t (1-indexed).  Materializing
M is O(T^2); the band never needs more than w rows of X per output row.

TPU adaptation: grid (T / T_BLK, NF / NF_BLK).  Each step emits a
(T_BLK x NF_BLK) output tile from TWO consecutive input tiles (the current
tile plus its predecessor — the band reaches back at most w-1 <= T_BLK rows),
building the (T_BLK x 2*T_BLK) band weights on the fly from iota comparisons
and contracting on the MXU.  VMEM: 3 tiles — never the T x T matrix.

``t_offset`` (the global index of row 0, needed by blocked checkpointing /
snapshot partitioning, where the op runs on a timeline slice) is a traced
scalar operand: it rides in as a (1, 1) int32 tile so the same compiled
kernel serves every block of the scan.

Constraints: w - 1 <= T_BLK; rows whose band reaches before row 0 while
t_offset > 0 are garbage and must be discarded by the caller (the
``m_product_with_prefix`` pattern prepends the (w-1)-frame prefix and slices
it back off).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(toff_ref, x_prev_ref, x_cur_ref, out_ref, *, window: int,
            t_block: int):
    i = pl.program_id(0)
    t_offset = toff_ref[0, 0]
    x = jnp.concatenate([x_prev_ref[...], x_cur_ref[...]], axis=0)
    # Global 1-indexed timestep of each output row / input column.
    row = jax.lax.broadcasted_iota(jnp.int32, (t_block, 2 * t_block), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t_block, 2 * t_block), 1)
    g = row + i * t_block + t_offset + 1            # output step
    k = col + (i - 1) * t_block + t_offset + 1      # input step
    in_band = (k <= g) & (k > g - window) & (k >= 1)
    denom = jnp.maximum(jnp.minimum(window, g), 1).astype(x.dtype)
    band = jnp.where(in_band, 1.0, 0.0).astype(x.dtype) / denom
    out_ref[...] = jax.lax.dot(band, x,
                               preferred_element_type=jnp.float32
                               ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "t_block", "nf_block",
                                             "interpret"))
def banded_ttm(x: jax.Array, window: int, t_offset: jax.Array | int = 0,
               t_block: int | None = None, nf_block: int = 128,
               interpret: bool = False) -> jax.Array:
    """x: (T, NF) -> (T, NF); Y[t] = mean of x[max(0,t-w+1)..t] (global idx)."""
    t, nf = x.shape
    if t_block is None:
        # large enough for the band; T is padded up to a multiple of it
        t_block = max(8, ((window - 1 + 7) // 8) * 8)
    if window - 1 > t_block:
        raise ValueError(f"window-1={window-1} must be <= t_block={t_block}")
    pad_t = (-t) % t_block
    pad_nf = (-nf) % nf_block
    if pad_t or pad_nf:
        x = jnp.pad(x, ((0, pad_t), (0, pad_nf)))
    t_p, nf_p = x.shape
    toff = jnp.asarray(t_offset, dtype=jnp.int32).reshape(1, 1)
    grid = (t_p // t_block, nf_p // nf_block)
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, t_block=t_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda _i, _j: (0, 0)),
            # predecessor tile (clamped at 0; out-of-band weights are zero)
            pl.BlockSpec((t_block, nf_block),
                         lambda i, j: (jnp.maximum(i - 1, 0), j)),
            pl.BlockSpec((t_block, nf_block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((t_block, nf_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t_p, nf_p), x.dtype),
        interpret=interpret,
    )(toff, x, x)
    return out[:t, :nf]
