"""Shared kernel-wrapper policy helpers."""

from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> interpret exactly where Pallas has no native lowering.

    The CPU backend runs kernels through the interpreter; every real
    accelerator backend (TPU, GPU) must get the compiled kernel — silently
    interpreting there would turn the "Pallas path" into a slow emulation.
    """
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret
