"""Pure-jnp oracle for the segment SpMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bucketed_segment_sum_ref(dst_local: jax.Array, messages: jax.Array,
                             node_block: int) -> jax.Array:
    """(NB, EPB) x (NB, EPB, F) -> (NB, node_block, F) with segment_sum.

    Padded lanes carry dst_local >= node_block and are dropped (one extra
    segment, sliced off).
    """
    def per_block(dst, msg):
        out = jax.ops.segment_sum(msg, dst, num_segments=node_block + 1)
        return out[:node_block]
    return jax.vmap(per_block)(dst_local, messages)


def segment_spmm_ref(x: jax.Array, edges: jax.Array, edge_weights: jax.Array,
                     num_nodes: int) -> jax.Array:
    """End-to-end oracle: A_tilde @ x via plain gather + segment_sum."""
    msgs = jnp.take(x, edges[:, 0], axis=0) \
        * edge_weights[:, None].astype(x.dtype)
    return jax.ops.segment_sum(msgs, edges[:, 1], num_segments=num_nodes)
