"""Jit'd public wrapper for the segment SpMM Pallas kernel.

``segment_spmm(x, edges, w, n)`` == ``ref.segment_spmm_ref`` and is a drop-in
for ``repro.graph.segment.spmm``.  The bucketing (sort by dst + pad each node
block's edge list to a common budget) happens in jnp so it stays inside the
jitted step function; datasets with static topology can pre-bucket once on
host via ``bucket_edges_host``.

Safety properties of the bucketed layout:

* ``interpret`` defaults to ``None`` and resolves from the active backend
  (interpret only on CPU) — real TPU/GPU backends always get the compiled
  kernel, never the silent interpreter emulation.
* A caller-supplied ``edges_per_block`` that is too small for a skewed
  destination distribution would silently drop overflow edges; the wrapper
  now counts weighted overflow lanes and surfaces the count through
  ``checkify.debug_check`` (wrap the jitted caller in
  ``checkify.checkify(..., errors=checkify.all_checks)`` to materialize the
  error).  ``segment_spmm_checked`` is the documented dense-fallback path:
  it prechecks the bucket layout on host and reroutes overflowing calls to
  the XLA segment-sum oracle instead of returning a wrong answer.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import checkify

from repro.kernels.segment_spmm import ref as _ref
from repro.kernels.segment_spmm.segment_spmm import (
    DEFAULT_FEAT_BLOCK, DEFAULT_NODE_BLOCK, bucketed_segment_sum,
    resolve_interpret)


def _pad_feat(x: jax.Array, feat_block: int) -> jax.Array:
    f = x.shape[-1]
    pad = (-f) % feat_block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


@functools.partial(jax.jit, static_argnames=("num_nodes", "node_block"))
def bucket_overflow_count(edges: jax.Array, edge_weights: jax.Array,
                          num_nodes: int, edges_per_block: jax.Array,
                          node_block: int = DEFAULT_NODE_BLOCK) -> jax.Array:
    """Weighted edges that a (node_block, edges_per_block) layout would drop.

    Zero-weight lanes (the padding convention) never count: dropping them is
    lossless.  Returns an int32 scalar; jit-compatible, usable as a host-side
    precheck (``segment_spmm_checked``) or a device-side debug check.
    """
    bucket = edges[:, 1] // node_block
    nb = -(-num_nodes // node_block)
    counts = jax.ops.segment_sum(jnp.ones_like(bucket), bucket,
                                 num_segments=nb)
    order = jnp.argsort(bucket, stable=True)
    bucket_sorted = jnp.take(bucket, order)
    w_sorted = jnp.take(edge_weights, order)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(edges.shape[0]) - jnp.take(starts, bucket_sorted)
    dropped = (rank >= edges_per_block) & (w_sorted != 0)
    return jnp.sum(dropped.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=(
    "num_nodes", "node_block", "feat_block", "edges_per_block", "interpret"))
def segment_spmm(x: jax.Array, edges: jax.Array, edge_weights: jax.Array,
                 num_nodes: int, node_block: int = DEFAULT_NODE_BLOCK,
                 feat_block: int = DEFAULT_FEAT_BLOCK,
                 edges_per_block: int | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """A_tilde @ x with the Pallas kernel (interpret resolved per backend).

    edges: (E, 2); padded lanes must carry weight 0 (they are routed to a
    dump bucket anyway).  Worst-case edges_per_block defaults to E (safe for
    skewed graphs); pass dataset statistics for tight buckets — overflow is
    then detected (never silent): the weighted-overflow count feeds a
    ``checkify.debug_check``, and ``segment_spmm_checked`` documents the
    dense-fallback route.
    """
    interpret = resolve_interpret(interpret)
    e = edges.shape[0]
    f = x.shape[-1]
    nb = -(-num_nodes // node_block)
    epb = edges_per_block or min(e, _round_up(e, 128))
    epb = _round_up(epb, 128)

    # Sort edges by destination block and compute positions within buckets.
    dst = edges[:, 1]
    bucket = dst // node_block
    order = jnp.argsort(bucket, stable=True)
    dst_sorted = jnp.take(dst, order)
    src_sorted = jnp.take(edges[:, 0], order)
    w_sorted = jnp.take(edge_weights, order)
    bucket_sorted = jnp.take(bucket, order)

    # Rank of each edge within its bucket (positions for the padded layout).
    ones = jnp.ones_like(bucket_sorted)
    counts = jax.ops.segment_sum(ones, bucket_sorted, num_segments=nb)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(e) - jnp.take(starts, bucket_sorted)
    valid = rank < epb
    # Overflow = weighted edges beyond the bucket budget.  Detected, not
    # silent: callers wrapping in checkify get the error; everyone else can
    # precheck via bucket_overflow_count / segment_spmm_checked.
    overflow = jnp.sum((~valid & (w_sorted != 0)).astype(jnp.int32))
    checkify.debug_check(
        overflow == 0,
        "segment_spmm: {n} weighted edges overflow edges_per_block="
        f"{epb} (node_block={node_block}); results would drop their "
        "contributions — raise edges_per_block or use "
        "segment_spmm_checked for the dense fallback", n=overflow)

    # Scatter into the (NB, EPB) bucketed layout.
    flat_pos = jnp.where(valid, bucket_sorted * epb + rank, nb * epb)
    dst_local = jnp.full((nb * epb + 1,), node_block, dtype=jnp.int32)
    dst_local = dst_local.at[flat_pos].set(
        (dst_sorted - bucket_sorted * node_block).astype(jnp.int32),
        mode="drop")[:-1].reshape(nb, epb)
    src_b = jnp.zeros((nb * epb + 1,), dtype=jnp.int32)
    src_b = src_b.at[flat_pos].set(src_sorted.astype(jnp.int32),
                                   mode="drop")[:-1].reshape(nb, epb)
    w_b = jnp.zeros((nb * epb + 1,), dtype=edge_weights.dtype)
    w_b = w_b.at[flat_pos].set(w_sorted, mode="drop")[:-1].reshape(nb, epb)

    # Gather + weight OUTSIDE the kernel (XLA handles gathers well on TPU).
    msgs = jnp.take(_pad_feat(x, feat_block), src_b.reshape(-1), axis=0)
    msgs = msgs.reshape(nb, epb, -1) * w_b[..., None].astype(x.dtype)

    out = bucketed_segment_sum(dst_local, msgs, node_block=node_block,
                               feat_block=feat_block, interpret=interpret)
    return out.reshape(nb * node_block, -1)[:num_nodes, :f]


def segment_spmm_checked(x: jax.Array, edges: jax.Array,
                         edge_weights: jax.Array, num_nodes: int,
                         node_block: int = DEFAULT_NODE_BLOCK,
                         feat_block: int = DEFAULT_FEAT_BLOCK,
                         edges_per_block: int | None = None,
                         interpret: bool | None = None) -> jax.Array:
    """Dense-fallback path for tight ``edges_per_block`` budgets.

    Prechecks the bucket layout (one jitted reduction, synced to host); if
    the requested budget would drop weighted edges, warns and reroutes to
    the XLA segment-sum oracle — correct for any degree skew — instead of
    returning a silently wrong aggregate.  Use this wrapper when
    edges_per_block comes from dataset statistics that a live stream might
    exceed; the default (worst-case) budget never overflows.
    """
    if edges_per_block is not None:
        # mirror the kernel wrapper's lane rounding so the precheck sees the
        # same budget the bucketing will actually use
        epb = _round_up(edges_per_block, 128)
        n_over = int(bucket_overflow_count(edges, edge_weights, num_nodes,
                                           jnp.int32(epb),
                                           node_block=node_block))
        if n_over:
            warnings.warn(
                f"segment_spmm: edges_per_block={edges_per_block} drops "
                f"{n_over} weighted edges; falling back to the dense "
                "segment-sum path", stacklevel=2)
            return _ref.segment_spmm_ref(x, edges, edge_weights, num_nodes)
    return segment_spmm(x, edges, edge_weights, num_nodes,
                        node_block=node_block, feat_block=feat_block,
                        edges_per_block=edges_per_block,
                        interpret=interpret)


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def bucket_edges_host(edges: np.ndarray, edge_weights: np.ndarray,
                      num_nodes: int, node_block: int = DEFAULT_NODE_BLOCK):
    """Host-side one-time bucketing for static topologies.

    Returns (dst_local (NB, EPB), src (NB, EPB), w (NB, EPB)) with EPB sized
    to the dataset's max per-block degree sum (rounded to 128).
    """
    nb = -(-num_nodes // node_block)
    bucket = edges[:, 1] // node_block
    counts = np.bincount(bucket, minlength=nb)
    epb = max(int(_round_up(int(counts.max() or 1), 128)), 128)
    dst_local = np.full((nb, epb), node_block, dtype=np.int32)
    src = np.zeros((nb, epb), dtype=np.int32)
    w = np.zeros((nb, epb), dtype=np.float32)
    fill = np.zeros((nb,), dtype=np.int64)
    for i in range(edges.shape[0]):
        b = bucket[i]
        k = fill[b]
        dst_local[b, k] = edges[i, 1] - b * node_block
        src[b, k] = edges[i, 0]
        w[b, k] = edge_weights[i]
        fill[b] += 1
    return dst_local, src, w


# Re-exported oracle for tests/benchmarks.
segment_spmm_ref = _ref.segment_spmm_ref
