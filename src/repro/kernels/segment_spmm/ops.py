"""Jit'd public wrapper for the segment SpMM Pallas kernel.

``segment_spmm(x, edges, w, n)`` == ``ref.segment_spmm_ref`` and is a drop-in
for ``repro.graph.segment.spmm``.  The bucketing (sort by dst + pad each node
block's edge list to a common budget) happens in jnp so it stays inside the
jitted step function; datasets with static topology can pre-bucket once on
host via ``bucket_edges_host``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_spmm import ref as _ref
from repro.kernels.segment_spmm.segment_spmm import (
    DEFAULT_FEAT_BLOCK, DEFAULT_NODE_BLOCK, bucketed_segment_sum)


def _pad_feat(x: jax.Array, feat_block: int) -> jax.Array:
    f = x.shape[-1]
    pad = (-f) % feat_block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


@functools.partial(jax.jit, static_argnames=(
    "num_nodes", "node_block", "feat_block", "edges_per_block", "interpret"))
def segment_spmm(x: jax.Array, edges: jax.Array, edge_weights: jax.Array,
                 num_nodes: int, node_block: int = DEFAULT_NODE_BLOCK,
                 feat_block: int = DEFAULT_FEAT_BLOCK,
                 edges_per_block: int | None = None,
                 interpret: bool = True) -> jax.Array:
    """A_tilde @ x with the Pallas kernel (interpret=True on CPU).

    edges: (E, 2); padded lanes must carry weight 0 (they are routed to a
    dump bucket anyway).  Worst-case edges_per_block defaults to E (safe for
    skewed graphs); pass dataset statistics for tight buckets.
    """
    e = edges.shape[0]
    f = x.shape[-1]
    nb = -(-num_nodes // node_block)
    epb = edges_per_block or min(e, _round_up(e, 128))
    epb = _round_up(epb, 128)

    # Sort edges by destination block and compute positions within buckets.
    dst = edges[:, 1]
    bucket = dst // node_block
    order = jnp.argsort(bucket, stable=True)
    dst_sorted = jnp.take(dst, order)
    src_sorted = jnp.take(edges[:, 0], order)
    w_sorted = jnp.take(edge_weights, order)
    bucket_sorted = jnp.take(bucket, order)

    # Rank of each edge within its bucket (positions for the padded layout).
    ones = jnp.ones_like(bucket_sorted)
    counts = jax.ops.segment_sum(ones, bucket_sorted, num_segments=nb)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(e) - jnp.take(starts, bucket_sorted)
    valid = rank < epb   # overflow edges dropped — caller sizes epb to avoid

    # Scatter into the (NB, EPB) bucketed layout.
    flat_pos = jnp.where(valid, bucket_sorted * epb + rank, nb * epb)
    dst_local = jnp.full((nb * epb + 1,), node_block, dtype=jnp.int32)
    dst_local = dst_local.at[flat_pos].set(
        (dst_sorted - bucket_sorted * node_block).astype(jnp.int32),
        mode="drop")[:-1].reshape(nb, epb)
    src_b = jnp.zeros((nb * epb + 1,), dtype=jnp.int32)
    src_b = src_b.at[flat_pos].set(src_sorted.astype(jnp.int32),
                                   mode="drop")[:-1].reshape(nb, epb)
    w_b = jnp.zeros((nb * epb + 1,), dtype=edge_weights.dtype)
    w_b = w_b.at[flat_pos].set(w_sorted, mode="drop")[:-1].reshape(nb, epb)

    # Gather + weight OUTSIDE the kernel (XLA handles gathers well on TPU).
    msgs = jnp.take(_pad_feat(x, feat_block), src_b.reshape(-1), axis=0)
    msgs = msgs.reshape(nb, epb, -1) * w_b[..., None].astype(x.dtype)

    out = bucketed_segment_sum(dst_local, msgs, node_block=node_block,
                               feat_block=feat_block, interpret=interpret)
    return out.reshape(nb * node_block, -1)[:num_nodes, :f]


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def bucket_edges_host(edges: np.ndarray, edge_weights: np.ndarray,
                      num_nodes: int, node_block: int = DEFAULT_NODE_BLOCK):
    """Host-side one-time bucketing for static topologies.

    Returns (dst_local (NB, EPB), src (NB, EPB), w (NB, EPB)) with EPB sized
    to the dataset's max per-block degree sum (rounded to 128).
    """
    nb = -(-num_nodes // node_block)
    bucket = edges[:, 1] // node_block
    counts = np.bincount(bucket, minlength=nb)
    epb = max(int(_round_up(int(counts.max() or 1), 128)), 128)
    dst_local = np.full((nb, epb), node_block, dtype=np.int32)
    src = np.zeros((nb, epb), dtype=np.int32)
    w = np.zeros((nb, epb), dtype=np.float32)
    fill = np.zeros((nb,), dtype=np.int64)
    for i in range(edges.shape[0]):
        b = bucket[i]
        k = fill[b]
        dst_local[b, k] = edges[i, 1] - b * node_block
        src[b, k] = edges[i, 0]
        w[b, k] = edge_weights[i]
        fill[b] += 1
    return dst_local, src, w


# Re-exported oracle for tests/benchmarks.
segment_spmm_ref = _ref.segment_spmm_ref
