"""Pallas TPU kernel: bucketed segment-sum via one-hot MXU matmuls.

The GCN aggregate ``A_tilde @ X`` is a gather (read x[src]) followed by a
scatter-add (accumulate into dst).  On GPU the paper uses cuSPARSE SpMM; the
TPU has no scatter unit, and XLA lowers segment-sum to a serialized
scatter-add loop.  The TPU-native adaptation: turn the scatter into a
*one-hot matrix product* so it runs on the MXU systolic array.

Data layout (produced by ``ops.bucket_edges`` on host / in jnp):
  * edges sorted by destination and bucketed by destination block:
    ``dst_local``: (NB, EPB) int32 — dst index *within* its node block;
    padded lanes carry ``block_size`` (a dump row sliced off after).
  * ``messages``: (NB, EPB, F) — x[src] * w, gathered OUTSIDE the kernel
    (XLA's dynamic-gather is already TPU-efficient; the scatter is not).

Grid: (NB, F / F_BLK); each step computes

    out[i, :, fb] = OneHot(dst_local[i])^T @ messages[i, :, fb]

an (N_BLK x EPB) @ (EPB x F_BLK) MXU matmul.  VMEM working set:
EPB*F_BLK + EPB*N_BLK + N_BLK*F_BLK floats — all tile-aligned (128 lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret  # noqa: F401 (re-export)

DEFAULT_NODE_BLOCK = 128
DEFAULT_FEAT_BLOCK = 128


def _kernel(dst_ref, msg_ref, out_ref, *, node_block: int):
    # dst_ref: (1, EPB) int32; msg_ref: (1, EPB, FB); out_ref: (1, NB, FB)
    dst = dst_ref[0]                                   # (EPB,)
    msgs = msg_ref[0]                                  # (EPB, FB)
    # One-hot over the node block; padded lanes (dst == node_block or any
    # value >= node_block) match no column and vanish.
    cols = jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], node_block), 1)
    onehot = (dst[:, None] == cols).astype(msgs.dtype)  # (EPB, NB)
    acc = jax.lax.dot_general(
        onehot, msgs,
        dimension_numbers=(((0,), (0,)), ((), ())),     # contract over EPB
        preferred_element_type=jnp.float32)
    out_ref[0] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("node_block", "feat_block",
                                             "interpret"))
def bucketed_segment_sum(dst_local: jax.Array, messages: jax.Array,
                         node_block: int = DEFAULT_NODE_BLOCK,
                         feat_block: int = DEFAULT_FEAT_BLOCK,
                         interpret: bool | None = None) -> jax.Array:
    """(NB, EPB) int32 x (NB, EPB, F) -> (NB, node_block, F)."""
    interpret = resolve_interpret(interpret)
    nb, epb = dst_local.shape
    f = messages.shape[-1]
    if f % feat_block != 0:
        raise ValueError(f"F={f} must be a multiple of feat_block={feat_block}")
    grid = (nb, f // feat_block)
    return pl.pallas_call(
        functools.partial(_kernel, node_block=node_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, epb), lambda i, _j: (i, 0)),
            pl.BlockSpec((1, epb, feat_block), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, node_block, feat_block),
                               lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nb, node_block, f), messages.dtype),
        interpret=interpret,
    )(dst_local, messages)
