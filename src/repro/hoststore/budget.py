"""Per-device graph-tensor budget gating.

``ExecutionPlan.device_budget_bytes`` simulates a device memory cap for
the GRAPH-SHAPED tensors a schedule keeps resident per round (edges +
mask + values + features + labels — the terms that scale with N and E;
params/optimizer/activations are schedule-independent and excluded so
the comparison isolates what sampling changes).

Full-graph schedules must materialize their whole time slice of every
round at full N / full E_max, so their requirement scales with the
graph; the sampled schedule stages O(table_pad + edge_pad) instead.  A
budget between the two is the out-of-core regime: the full schedules
REFUSE (raise :class:`DeviceBudgetError` at fit time, before anything
is allocated), the sampled schedule proceeds — the benchmark's win
condition (``benchmarks/scaling_bench.sampled_smoke``).
"""

from __future__ import annotations

from repro.hoststore.spec import ResolvedSampling

# int32 (src,dst) + f32 mask + f32 values per edge lane
_EDGE_LANE = 8 + 4 + 4


class DeviceBudgetError(RuntimeError):
    """A schedule's resident graph tensors exceed the simulated budget."""

    def __init__(self, mode: str, required: int, budget: int):
        self.mode, self.required, self.budget = mode, required, budget
        super().__init__(
            f"schedule {mode!r} needs {required} bytes of per-device "
            f"graph tensors but plan.device_budget_bytes={budget}; the "
            "full per-snapshot tensors do not fit — use "
            "schedule='sampled' (out-of-core fanout sampling)")


def full_graph_round_bytes(mode: str, *, num_steps: int, win: int,
                           num_shards: int, max_edges: int, num_nodes: int,
                           feat_dim: int) -> int:
    """Per-device resident graph bytes of a full-graph schedule.

    eager holds the whole blocked batch (its time axis sharded on a
    mesh); the streamed schedules hold one round (``win`` steps, over
    ``num_shards`` for the mesh variant) reconstructed at full width.
    """
    per_step = (max_edges * _EDGE_LANE + num_nodes * feat_dim * 4
                + num_nodes * 4)
    if mode == "eager":
        return (num_steps // max(num_shards, 1)) * per_step
    if mode == "streamed":
        return win * per_step
    if mode == "streamed_mesh":
        return (win // num_shards) * per_step
    raise ValueError(f"no budget model for mode {mode!r}")


def sampled_round_bytes(resolved: ResolvedSampling, *, win: int,
                        num_shards: int, feat_dim: int) -> int:
    """Per-device resident graph bytes of one sampled round."""
    per_step = (resolved.edge_pad * _EDGE_LANE
                + resolved.table_pad * feat_dim * 4
                + resolved.table_pad * 4)
    return (win // num_shards) * per_step


def check_budget(mode: str, budget: int | None, *, num_steps: int,
                 win: int, num_shards: int, max_edges: int, num_nodes: int,
                 feat_dim: int,
                 resolved: ResolvedSampling | None = None) -> dict | None:
    """Gate one schedule against the simulated budget.

    Returns ``{"required": ..., "budget": ...}`` (None when no budget is
    set); raises :class:`DeviceBudgetError` when the schedule's resident
    graph tensors do not fit.
    """
    if budget is None:
        return None
    if mode == "sampled":
        if resolved is None:
            raise ValueError("sampled budget check needs the resolved "
                             "sampling shapes")
        required = sampled_round_bytes(resolved, win=win,
                                       num_shards=num_shards,
                                       feat_dim=feat_dim)
    else:
        required = full_graph_round_bytes(
            mode, num_steps=num_steps, win=win, num_shards=num_shards,
            max_edges=max_edges, num_nodes=num_nodes, feat_dim=feat_dim)
    if required > budget:
        raise DeviceBudgetError(mode, required, budget)
    return {"required": required, "budget": budget}
