"""Out-of-core sampled training: host-resident temporal graph store +
fanout-sampled snapshot streaming.

The full-graph schedules bound N by device memory — every round
materializes full per-snapshot tensors on the mesh.  This package keeps
the trace host-resident instead and streams only sampled, static-shape
subgraphs:

* :mod:`~repro.hoststore.store`  — ``TemporalCSRStore``: per-step CSR
  adjacency on host numpy, ingested incrementally from the SAME
  ``IncrementalEncoder`` delta items the device path uses;
* :mod:`~repro.hoststore.sampled` — ``SampledSliceStream``: per-round
  seed batches, ``graph/sampler.py`` fanout expansion in host worker
  threads, fixed-size padded subgraph tensors through the ``prefetch``
  staging machinery with ``NamedSharding`` placement;
* :mod:`~repro.hoststore.carry`  — ``HostCarryStore``: per-node temporal
  state host-resident between rounds, gathered/scattered by table rows;
* :mod:`~repro.hoststore.train`  — ``train_sampled``: the
  ``schedule="sampled"`` driver (the distributed round step on the
  table axis);
* :mod:`~repro.hoststore.budget` — the simulated per-device graph-byte
  budget that full-graph schedules refuse and sampling fits.

See docs/sampling.md for the store layout, the SamplingSpec knobs, and
the full-fanout equivalence argument.
"""

from repro.hoststore.budget import (DeviceBudgetError, check_budget,
                                    full_graph_round_bytes,
                                    sampled_round_bytes)
from repro.hoststore.carry import HostCarryStore
from repro.hoststore.sampled import (SampledSliceStream, SampleReport,
                                     SampleRound, StagedRound, draw_seeds,
                                     sample_round)
from repro.hoststore.spec import ResolvedSampling, SamplingSpec
from repro.hoststore.store import TemporalCSRStore
from repro.hoststore.train import (SampledState, make_sampled_step,
                                   table_config, train_sampled)

__all__ = [
    "DeviceBudgetError", "check_budget", "full_graph_round_bytes",
    "sampled_round_bytes", "HostCarryStore", "SampledSliceStream",
    "SampleReport", "SampleRound", "StagedRound", "draw_seeds",
    "sample_round", "ResolvedSampling", "SamplingSpec",
    "TemporalCSRStore", "SampledState", "make_sampled_step",
    "table_config", "train_sampled",
]
