"""The sampled training schedule (``schedule="sampled"``).

Composes the pieces of this package with the EXISTING distributed round
step: ``make_sampled_step`` is ``stream.distributed.make_dist_stream_step``
instantiated on the round node TABLE (the model config's vertex axis
becomes ``table_pad``) with the seed-restricted loss — same Laplacian
preamble, same ``partition.snapshot_block_body`` (two all-to-alls per
layer over the table axis), same AdamW cadence.  One round per
checkpoint block, like every streamed schedule.

Between rounds the per-node temporal state lives in the
:class:`~repro.hoststore.carry.HostCarryStore`: each round gathers the
rows of its table to the device (stream carry shardings, sized
``table_pad``) and scatters the post-round rows back.  With full fanout
and every vertex a seed this loop is numerically the full-graph
distributed path (pinned <= 1e-5 in tests/test_hoststore.py); with
truncated fanout it is GraphSAGE-style stochastic training whose loss
drift the convergence test bounds.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import models as mdl
from repro.dist import sharding as shardlib
from repro.hoststore.carry import HostCarryStore
from repro.hoststore.sampled import SampledSliceStream, SampleReport
from repro.hoststore.spec import ResolvedSampling, SamplingSpec
from repro.hoststore.store import TemporalCSRStore
from repro.optim import adamw
from repro.stream import distributed as stream_dist
from repro.stream.prefetch import PrefetchIterator


@dataclass
class SampledState:
    params: dict
    opt_state: dict
    losses: list
    report: SampleReport = field(default_factory=SampleReport)


def table_config(cfg: mdl.DynGNNConfig,
                 resolved: ResolvedSampling) -> mdl.DynGNNConfig:
    """The model config the sampled step compiles against: the vertex
    axis is the round node table, everything else unchanged."""
    return dataclasses.replace(cfg, num_nodes=resolved.table_pad)


def make_sampled_step(cfg: mdl.DynGNNConfig, resolved: ResolvedSampling,
                      mesh, opt_cfg: adamw.AdamWConfig,
                      axis: str = shardlib.DATA_AXIS, a2a_chunks: int = 1):
    """Jitted sampled round step — the distributed stream step on the
    table axis with the seed-restricted loss."""
    return stream_dist.make_dist_stream_step(
        table_config(cfg, resolved), mesh, opt_cfg, axis,
        a2a_chunks=a2a_chunks, num_seeds=resolved.num_seeds)


def train_sampled(cfg: mdl.DynGNNConfig, store: TemporalCSRStore,
                  frames: np.ndarray, labels: np.ndarray, *,
                  spec: SamplingSpec, mesh,
                  axis: str = shardlib.DATA_AXIS,
                  block_size: int | None = None, num_epochs: int = 1,
                  overlap: bool = True, prefetch_depth: int = 2,
                  a2a_chunks: int = 1,
                  opt_cfg: adamw.AdamWConfig | None = None,
                  params: dict | None = None, opt_state=None,
                  step_fn=None, carry_store: HostCarryStore | None = None,
                  report: SampleReport | None = None, seed: int = 0,
                  log_every: int = 10, log_fn=None) -> SampledState:
    """Out-of-core sampled training over the host-resident store.

    The device never sees the full graph: per round it receives the
    sampled subgraph tensors (``SampledSliceStream``, prefetch-staged)
    plus the table rows of the host-resident carries, and returns the
    updated rows.  ``step_fn`` / ``carry_store`` / ``report`` let the
    Engine worker cache compilation and state across calls.
    """
    t_steps = store.num_steps
    num_procs = mesh.shape[axis]
    win = block_size or max(t_steps // max(cfg.checkpoint_blocks, 1), 1)
    if win % num_procs:
        raise ValueError(f"block_size {win} must divide into {num_procs} "
                         "shards")
    if t_steps % win:
        raise ValueError(f"trace length {t_steps} must be a multiple of "
                         f"block_size {win}")
    resolved = spec.resolve(cfg.num_nodes, win, num_procs)
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10, total_steps=num_epochs * t_steps,
        weight_decay=0.0)
    if params is None:
        params = mdl.init_params(jax.random.PRNGKey(seed), cfg)
    if opt_state is None:
        opt_state = adamw.init_state(params)
    if step_fn is None:
        step_fn = make_sampled_step(cfg, resolved, mesh, opt_cfg, axis,
                                    a2a_chunks=a2a_chunks)
    if carry_store is None:
        # sized by the GLOBAL cfg (full-N resident rows); gather() pads
        # each round's table rows up to table_pad for the device step
        carry_store = HostCarryStore(cfg, params)
    report = report if report is not None else SampleReport()
    stream = SampledSliceStream(store=store, frames=frames, labels=labels,
                                spec=spec, resolved=resolved, mesh=mesh,
                                win=win, axis=axis)
    carry_shardings = shardlib.named(
        mesh, shardlib.stream_carry_specs(cfg, axis))

    losses: list[float] = []

    def emit(loss_value):
        losses.append(float(loss_value))
        if log_fn is not None and (len(losses) - 1) % log_every == 0:
            log_fn(f"sampled round {len(losses) - 1} loss "
                   f"{losses[-1]:.4f} (P={num_procs}, win={win}, "
                   f"table={resolved.table_pad}, "
                   f"seeds={resolved.num_seeds})")

    for epoch in range(num_epochs):
        carry_store.reset(params)    # epoch-start semantics: fresh state
        host = stream.rounds(epoch)
        if overlap:
            rounds = PrefetchIterator(host, stage_fn=stream.stage_fn(),
                                      depth=prefetch_depth)
        else:
            stage = stream.stage_fn()
            rounds = (stage(x) for x in host)
        try:
            for staged in rounds:
                # carries CANNOT prefetch: round r's gather depends on
                # round r-1's scatter (the host-resident state is the
                # cross-round data dependency)
                with obs.stopwatch("round", cat="round", round=staged.r,
                                   epoch=epoch, schedule="sampled") as sw:
                    host_carries = carry_store.gather(staged.node_ids,
                                                      resolved.table_pad)
                    carries = jax.tree.map(jax.device_put, host_carries,
                                           carry_shardings)
                    staged.staged_bytes += sum(
                        leaf.nbytes
                        for leaf in jax.tree.leaves(host_carries))
                    params, opt_state, new_carries, loss = step_fn(
                        params, opt_state, carries, staged.frames,
                        staged.edges, staged.mask, staged.values,
                        staged.labels, jnp.int32(staged.t0))
                    sw.fence(loss)
                    carry_store.scatter(staged.node_ids, new_carries)
                    emit(loss)
                report.fold(staged)
                report.step_seconds += sw.seconds
        finally:
            if isinstance(rounds, PrefetchIterator):
                rounds.close()
    return SampledState(params=params, opt_state=opt_state, losses=losses,
                        report=report)
