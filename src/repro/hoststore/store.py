"""Host-resident temporal graph store.

``TemporalCSRStore`` keeps the WHOLE trace on host numpy — per-step
in-neighbor CSR adjacency plus edge values — so the device only ever
sees sampled, static-shape subgraphs (``hoststore.sampled``).  Host RAM
is the capacity axis here: a trace whose full per-snapshot tensors blow
the device budget still fits as a few numpy arrays per step.

Ingest is incremental and shares the device path's transfer protocol:
the store consumes the SAME ``FullSnapshot`` / ``SnapshotDelta`` items
the ``IncrementalEncoder`` emits (one encode of the trace, no second
decode), applying each delta to a host mirror with exactly the device
order ``graphdiff.apply_delta`` produces — survivors compacted in
order, adds appended.  The per-step CSR is then built once from the
mirrored edge list, with values re-gathered into CSR order so a sampled
edge's value rides along by CSR position.
"""

from __future__ import annotations

import numpy as np

from repro.core.graphdiff import FullSnapshot, SnapshotDelta
from repro.graph.sampler import CSRGraph
from repro.stream import encoder as enc


class TemporalCSRStore:
    """Per-step host CSR adjacency built from the delta stream.

    ``ingest(item)`` appends one step; ``csr(t)`` / ``values_csr(t)`` /
    ``edges(t)`` read it back.  ``indices``/``values`` are stored in CSR
    (dst-major) order: ``csr(t).indices[k]`` is the source of the k-th
    CSR entry and ``values_csr(t)[k]`` its edge value, so the sampler's
    ``edge_pos`` output indexes both.
    """

    def __init__(self, num_nodes: int):
        self.num_nodes = num_nodes
        self._indptr: list[np.ndarray] = []
        self._indices: list[np.ndarray] = []
        self._values: list[np.ndarray] = []
        # device-order mirror of the CURRENT step (what the next delta's
        # drop positions index) — exactly apply_delta's layout
        self._mirror_edges: np.ndarray | None = None

    # ------------------------------------------------------- ingest -------

    def ingest(self, item: FullSnapshot | SnapshotDelta) -> int:
        """Apply one encoder item; returns the step index it became."""
        if isinstance(item, FullSnapshot):
            edges = np.asarray(item.edges[:item.num_edges])
        elif isinstance(item, SnapshotDelta):
            if self._mirror_edges is None:
                raise ValueError("delta before any FullSnapshot — the "
                                 "stream must open with a full sync")
            prev = self._mirror_edges
            n_drop = int(item.drop_mask.sum())
            drop_pos = np.asarray(item.drop_pos[:n_drop], dtype=np.int64)
            keep = np.ones((prev.shape[0],), dtype=bool)
            keep[drop_pos] = False
            n_add = int(item.add_mask.sum())
            adds = np.asarray(item.add_edges[:n_add])
            edges = np.concatenate([prev[keep], adds], axis=0)
            if edges.shape[0] != item.num_edges:
                raise ValueError(
                    f"delta reconstruction mismatch at step "
                    f"{len(self._indptr)}: {edges.shape[0]} edges vs "
                    f"declared {item.num_edges}")
        else:
            raise TypeError(f"cannot ingest {type(item).__name__}")
        values = np.asarray(item.values[:item.num_edges], dtype=np.float32)
        self._mirror_edges = edges
        self._append_csr(edges, values)
        return len(self._indptr) - 1

    def _append_csr(self, edges: np.ndarray, values: np.ndarray) -> None:
        n = self.num_nodes
        if edges.shape[0]:
            order = np.argsort(edges[:, 1], kind="stable")
            dst_sorted = edges[order, 1].astype(np.int64)
            src_sorted = edges[order, 0].astype(np.int64)
            counts = np.bincount(dst_sorted, minlength=n)
            vals = values[order]
        else:
            src_sorted = np.zeros((0,), dtype=np.int64)
            counts = np.zeros((n,), dtype=np.int64)
            vals = np.zeros((0,), dtype=np.float32)
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        self._indptr.append(indptr)
        self._indices.append(src_sorted)
        self._values.append(vals)

    @classmethod
    def from_stream(cls, items, num_nodes: int) -> "TemporalCSRStore":
        store = cls(num_nodes)
        for item in items:
            store.ingest(item)
        return store

    @classmethod
    def from_snapshots(cls, snapshots, values, num_nodes: int,
                       block_size: int,
                       stats: enc.DeltaStats | None = None
                       ) -> "TemporalCSRStore":
        """Encode-and-ingest: routes through ``iter_encode_stream`` so
        the store sees byte-identical items to the device path."""
        return cls.from_stream(
            enc.iter_encode_stream(snapshots, values, num_nodes,
                                   enc.padded_max_edges(snapshots),
                                   block_size, stats),
            num_nodes)

    # --------------------------------------------------------- reads ------

    @property
    def num_steps(self) -> int:
        return len(self._indptr)

    def csr(self, t: int) -> CSRGraph:
        return CSRGraph(indptr=self._indptr[t], indices=self._indices[t])

    def values_csr(self, t: int) -> np.ndarray:
        """Edge values aligned with ``csr(t).indices``."""
        return self._values[t]

    def edges(self, t: int) -> np.ndarray:
        """(E_t, 2) int64 (src, dst) in CSR order (dst-major)."""
        indptr, src = self._indptr[t], self._indices[t]
        dst = np.repeat(np.arange(self.num_nodes, dtype=np.int64),
                        np.diff(indptr))
        return np.stack([src, dst], axis=1)

    def max_in_degree(self) -> int:
        """Largest in-degree over all steps — the full-fanout threshold."""
        return max(int(np.diff(ip).max()) if ip[-1] else 0
                   for ip in self._indptr)

    @property
    def nbytes(self) -> int:
        """Host bytes the resident trace occupies."""
        return sum(a.nbytes for arrs in (self._indptr, self._indices,
                                         self._values) for a in arrs)
