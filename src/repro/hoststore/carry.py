"""Host-resident per-node temporal state for the sampled schedule.

The full-graph schedules keep temporal carries (LSTM states, TM-GCN
window buffers) device-resident between rounds — O(N) device memory.
Out of core, N is exactly what does not fit, so the carries live here on
host numpy and each round only round-trips the rows of its sampled node
table: ``gather`` lifts table rows to the device (padded, mesh-sharded
by the caller), ``scatter`` writes the post-round rows back.

Nodes absent from a round's table simply keep their previous state —
with full-fanout sampling (every vertex a seed) every row updates every
round and the schedule is numerically the full-graph path.

EvolveGCN is the exception that proves the layout: its carry is a
weight matrix + weight-LSTM state (not per-node, §5.5), so it rides
whole — gathered and scattered as-is.
"""

from __future__ import annotations

import numpy as np

from repro.core import models as mdl


def _node_axis(cfg: mdl.DynGNNConfig) -> int | None:
    """Axis of the node dimension in one layer's carry leaves
    (None = the carry is not per-node and rides whole)."""
    if cfg.model == "cdgcn":
        return 0        # LSTM (h, c), each (N, d)
    if cfg.model == "evolvegcn":
        return None     # (W, (h, c)) — weight-evolution state
    if cfg.model == "tmgcn":
        return 1        # (window-1, N, d)
    raise ValueError(cfg.model)


def _leaves(carry):
    """Flatten one layer's carry into its array leaves (tuples only —
    the carry trees are nested tuples of arrays)."""
    if isinstance(carry, tuple):
        out = []
        for c in carry:
            out.extend(_leaves(c))
        return out
    return [carry]


def _rebuild(template, flat):
    """Inverse of ``_leaves`` against ``template``'s structure."""
    if isinstance(template, tuple):
        parts = []
        for c in template:
            part, flat = _rebuild(c, flat)
            parts.append(part)
        return tuple(parts), flat
    return flat[0], flat[1:]


class HostCarryStore:
    """Full-N temporal carries on host numpy, gathered per round.

    ``reset(params)`` re-derives the epoch-start state from the CURRENT
    params (EvolveGCN's initial weight carry aliases ``params``, exactly
    like ``models.init_carries`` at the top of every epoch).
    """

    def __init__(self, cfg: mdl.DynGNNConfig, params: dict):
        self.cfg = cfg
        self.axis = _node_axis(cfg)
        self._layers: list[list[np.ndarray]] = []
        self._templates: list = []
        self.reset(params)

    def reset(self, params: dict) -> None:
        carries = mdl.init_carries(self.cfg, params)
        self._templates = carries
        # np.array (not asarray): device arrays convert to READ-ONLY
        # views, and scatter() writes these in place
        self._layers = [[np.array(leaf) for leaf in _leaves(c)]
                        for c in carries]

    # ------------------------------------------------------- gather -------

    def gather(self, node_ids: np.ndarray, table_pad: int) -> list:
        """Rows of ``node_ids`` lifted into ``table_pad``-sized host
        arrays (invalid lanes zero), in ``init_carries`` structure.
        The caller ships them with the stream carry shardings."""
        node_ids = np.asarray(node_ids, dtype=np.int64)
        k = node_ids.shape[0]
        ax = self.axis
        out = []
        for template, leaves in zip(self._templates, self._layers,
                                    strict=True):
            if ax is None:
                rows = list(leaves)
            else:
                rows = []
                for leaf in leaves:
                    shape = list(leaf.shape)
                    shape[ax] = table_pad
                    buf = np.zeros(shape, dtype=leaf.dtype)
                    if ax == 0:
                        buf[:k] = leaf[node_ids]
                    else:
                        buf[:, :k] = leaf[:, node_ids]
                    rows.append(buf)
            tree, rest = _rebuild(template, rows)
            if rest:
                raise ValueError("carry leaf mismatch")
            out.append(tree)
        return out

    # ------------------------------------------------------ scatter -------

    def scatter(self, node_ids: np.ndarray, new_carries: list) -> None:
        """Write the first ``len(node_ids)`` table rows of the post-round
        carries back into the resident state (pad lanes discarded)."""
        node_ids = np.asarray(node_ids, dtype=np.int64)
        k = node_ids.shape[0]
        ax = self.axis
        for leaves, new in zip(self._layers, new_carries, strict=True):
            new_leaves = _leaves(new)
            for leaf, fresh in zip(leaves, new_leaves, strict=True):
                fresh = np.asarray(fresh)
                if ax is None:
                    leaf[...] = fresh
                elif ax == 0:
                    leaf[node_ids] = fresh[:k]
                else:
                    leaf[:, node_ids] = fresh[:, :k]

    @property
    def nbytes(self) -> int:
        return sum(leaf.nbytes for leaves in self._layers for leaf in leaves)
