"""SamplingSpec: the declarative knobs of the sampled schedule.

Lives in its own leaf module (stdlib-only) so ``run/plan.py`` can import
and validate it without pulling jax or the host-store machinery into
plan construction.
"""

from __future__ import annotations

from dataclasses import dataclass


def _round_up(v: int, m: int) -> int:
    return max(((v + m - 1) // m) * m, m)


@dataclass(frozen=True)
class ResolvedSampling:
    """Static shapes one sampled round stages (derived once per run).

    ``num_seeds`` seed lanes lead the round node table; ``table_pad``
    (a multiple of the shard count — the temporal all-to-alls run over
    the TABLE axis) is the per-round node budget; ``edge_pad`` the
    per-snapshot budget for the deduplicated union subgraph.
    """

    num_seeds: int
    table_pad: int
    edge_pad: int


@dataclass(frozen=True)
class SamplingSpec:
    """Fanout-sampling knobs of ``schedule="sampled"``.

    * ``batch_nodes`` — seed vertices drawn per round (clamped to N;
      ``batch_nodes >= N`` means every vertex is a seed every round —
      the full-fanout equivalence regime, see docs/sampling.md);
    * ``fanouts`` — per-hop in-neighbor fanout, outermost layer first;
      a fanout >= the max in-degree samples the full neighborhood;
    * ``seed`` — host-sampler PRNG seed (independent of the param-init
      seed: the same model can train over different sample streams);
    * ``table_pad`` / ``max_edges`` — optional static-budget overrides
      for the round node table / per-snapshot union edges.  ``None``
      derives the worst-case closed-neighborhood bound (tight for small
      graphs, loose for big ones — real runs should cap it; overflowing
      a cap degrades to dropped lanes counted on ``SampleReport``);
    * ``workers`` — host sampling threads per round.
    """

    batch_nodes: int
    fanouts: tuple[int, ...] = (10, 10)
    seed: int = 0
    table_pad: int | None = None
    max_edges: int | None = None
    workers: int = 4

    def validate(self) -> None:
        if self.batch_nodes < 1:
            raise ValueError(f"sampling.batch_nodes must be >= 1, got "
                             f"{self.batch_nodes}")
        if not self.fanouts or any(f < 1 for f in self.fanouts):
            raise ValueError(f"sampling.fanouts must be non-empty positive "
                             f"ints, got {self.fanouts!r}")
        if self.workers < 1:
            raise ValueError("sampling.workers must be >= 1")
        if self.table_pad is not None and self.table_pad < 1:
            raise ValueError("sampling.table_pad must be >= 1")
        if self.max_edges is not None and self.max_edges < 1:
            raise ValueError("sampling.max_edges must be >= 1")

    def worst_case_nodes(self, win: int) -> int:
        """Closed-neighborhood bound on the round table: every sampled
        edge of every owned step could introduce a new vertex."""
        b = self.batch_nodes
        per_step = 0
        cap = b
        for f in self.fanouts:
            cap *= f
            per_step += cap
        return b + win * per_step

    def worst_case_edges(self) -> int:
        """Per-step bound on the deduplicated union subgraph."""
        total, cap = 0, self.batch_nodes
        for f in self.fanouts:
            cap *= f
            total += cap
        return total

    def resolve(self, num_nodes: int, win: int,
                num_shards: int) -> ResolvedSampling:
        """Derive the static round shapes for a concrete run.

        The node table is bounded by N (a sample can never exceed the
        vertex set) and padded to a multiple of the shard count so the
        vertex-sharded temporal stage tiles it exactly.
        """
        self.validate()
        num_seeds = min(self.batch_nodes, num_nodes)
        table = self.table_pad
        if table is None:
            table = min(self.worst_case_nodes(win), num_nodes)
        table = max(table, num_seeds)
        table = _round_up(table, num_shards)
        edges = self.max_edges
        if edges is None:
            edges = self.worst_case_edges()
        edges = _round_up(edges, 128)
        return ResolvedSampling(num_seeds=num_seeds, table_pad=table,
                                edge_pad=edges)
