"""Fanout-sampled round streaming against the host store.

One round = one checkpoint block of ``win`` snapshots, exactly like the
full-graph distributed stream — but instead of reconstructing full
snapshots on device, each round:

1. draws a seed batch (one batch per ROUND, shared by all ``win`` steps:
   the temporal stage threads state across the round's time axis, so
   every step must speak the same local node vocabulary);
2. runs ``graph/sampler.py`` fanout expansion per step against the
   store's CSR in host worker threads, takes the DEDUPLICATED UNION of
   the hop blocks as that step's message subgraph (full fanout makes
   the union the full edge set — the equivalence regime);
3. merges the per-step samples into one round node table (seeds first,
   then the remaining sampled vertices in ascending global id),
   re-indexes every step's edges into table-local ids, and gathers
   features / labels / edge values for sampled lanes only;
4. emits fixed-size padded tensors sized by ``ResolvedSampling`` —
   blowing a static budget degrades to dropped lanes counted on
   ``SampleReport``, never a shape change.

The staged payload per round is O(table_pad + edge_pad), independent of
N — the whole point: only sampled subgraphs ever cross the host->device
boundary.  Staging reuses the stream machinery: ``SampledSliceStream``
plugs its ``stage_fn`` into ``prefetch.PrefetchIterator`` with the same
``NamedSharding`` placements (time-sharded over the mesh) the
full-graph round staging uses.

Thread discipline: per-round counters and timings ride ON the round
item through the prefetch queue (the queue's lock is the happens-before
edge); the consumer folds them into the shared ``SampleReport`` on the
main thread — no cross-thread attribute writes at all.
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro import obs
from repro.dist import sharding as shardlib
from repro.graph import sampler as smp
from repro.hoststore.spec import ResolvedSampling, SamplingSpec
from repro.hoststore.store import TemporalCSRStore


@dataclass
class SampleReport:
    """Health/accounting counters of one sampled run (main-thread owned)."""

    rounds: int = 0
    sampled_edges: int = 0        # valid union edges staged
    sampled_nodes: int = 0        # valid table lanes staged
    dropped_nodes: int = 0        # table-budget overflow (degraded lanes)
    dropped_edges: int = 0        # edge-budget overflow (degraded lanes)
    staged_bytes: int = 0         # bytes shipped host->device
    sample_seconds: float = 0.0   # host sampling+merge time
    stage_seconds: float = 0.0    # device_put time
    step_seconds: float = 0.0     # forced device step time (trainer-owned)
    table_fill_max: int = 0       # worst observed table occupancy

    def fold(self, rnd: "StagedRound") -> None:
        self.rounds += 1
        self.sampled_edges += rnd.sampled_edges
        self.sampled_nodes += len(rnd.node_ids)
        self.dropped_nodes += rnd.dropped_nodes
        self.dropped_edges += rnd.dropped_edges
        self.staged_bytes += rnd.staged_bytes
        self.sample_seconds += rnd.sample_s
        self.stage_seconds += rnd.stage_s
        self.table_fill_max = max(self.table_fill_max, len(rnd.node_ids))
        # mirror into the shared namespace (docs/observability.md);
        # fold() runs on the main thread, so the registry sees the same
        # happens-before edge the report does
        obs.inc("sample.rounds")
        obs.inc("sample.dropped_nodes", rnd.dropped_nodes)
        obs.inc("sample.dropped_edges", rnd.dropped_edges)
        obs.inc("sample.staged_bytes", rnd.staged_bytes)


@dataclass
class SampleRound:
    """Host-side product of one round's sampling (numpy, pre-staging)."""

    r: int                      # round index within the epoch
    t0: int                     # global step index of the round's start
    node_ids: np.ndarray        # (k,) int64 global table, seeds first
    frames: np.ndarray          # (win, table_pad, F) f32
    labels: np.ndarray          # (win, table_pad) i32
    edges: np.ndarray           # (win, edge_pad, 2) i32 table-local
    mask: np.ndarray            # (win, edge_pad) f32
    values: np.ndarray          # (win, edge_pad) f32
    sample_s: float = 0.0
    sampled_edges: int = 0
    dropped_nodes: int = 0
    dropped_edges: int = 0


@dataclass
class StagedRound:
    """Device-side round (what the jitted sampled step consumes)."""

    r: int
    t0: int
    node_ids: np.ndarray        # stays host-side (gather/scatter index)
    frames: jax.Array
    labels: jax.Array
    edges: jax.Array
    mask: jax.Array
    values: jax.Array
    sample_s: float = 0.0
    stage_s: float = 0.0
    staged_bytes: int = 0
    sampled_edges: int = 0
    dropped_nodes: int = 0
    dropped_edges: int = 0


def _step_rng(seed: int, epoch: int, t: int) -> np.random.Generator:
    """Per-(stream-seed, epoch, step) generator: sampling is deterministic
    under any worker-thread schedule because no generator is shared."""
    return np.random.default_rng(np.random.SeedSequence([seed, epoch, t]))


def draw_seeds(num_nodes: int, num_seeds: int, seed: int, epoch: int,
               r: int) -> np.ndarray:
    """The round's seed batch.  ``num_seeds >= num_nodes`` pins the
    identity batch (every vertex, ascending) — the equivalence regime."""
    if num_seeds >= num_nodes:
        return np.arange(num_nodes, dtype=np.int64)
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, epoch, 2_000_003 + r]))
    return np.sort(rng.choice(num_nodes, size=num_seeds,
                              replace=False).astype(np.int64))


def _sample_step(store: TemporalCSRStore, t: int, seeds: np.ndarray,
                 spec: SamplingSpec, epoch: int):
    """One step's fanout expansion -> (global nodes, unique global edges,
    values) with sampler-output invariants trimmed to valid lanes."""
    sub = smp.sample_neighbors(store.csr(t), seeds, list(spec.fanouts),
                               _step_rng(spec.seed, epoch, t))
    n_valid = int(sub.node_mask.sum())
    nodes = sub.node_ids[:n_valid]
    gsrc, gdst, pos = [], [], []
    for blk in sub.blocks:
        e = int(blk.edge_mask.sum())
        if not e:
            continue
        gsrc.append(nodes[blk.edges[:e, 0]])
        gdst.append(nodes[blk.edges[:e, 1]])
        pos.append(blk.edge_pos[:e])
    if not gsrc:
        return (nodes, np.zeros((0, 2), dtype=np.int64),
                np.zeros((0,), dtype=np.float32))
    gsrc = np.concatenate(gsrc)
    gdst = np.concatenate(gdst)
    pos = np.concatenate(pos)
    # dedup the hop-block union: an edge sampled at two hops must carry
    # one message, not two (full fanout: union == the full edge set)
    keys = gsrc * np.int64(store.num_nodes) + gdst
    _, first = np.unique(keys, return_index=True)
    edges = np.stack([gsrc[first], gdst[first]], axis=1)
    vals = store.values_csr(t)[pos[first]].astype(np.float32)
    return nodes, edges, vals


def sample_round(store: TemporalCSRStore, frames: np.ndarray,
                 labels: np.ndarray, spec: SamplingSpec,
                 resolved: ResolvedSampling, win: int, r: int, epoch: int,
                 pool: ThreadPoolExecutor) -> SampleRound:
    """Sample one round: per-step expansions in worker threads, merged
    into one table + fixed-size padded tensors.  Runs on the prefetch
    thread; its span/timing rides back on ``SampleRound.sample_s``."""
    with obs.stopwatch("sample.round", cat="sample", round=r,
                       epoch=epoch) as sw:
        rnd = _sample_round_body(store, frames, labels, spec, resolved,
                                 win, r, epoch, pool)
    rnd.sample_s = sw.seconds
    return rnd


def _sample_round_body(store: TemporalCSRStore, frames: np.ndarray,
                       labels: np.ndarray, spec: SamplingSpec,
                       resolved: ResolvedSampling, win: int, r: int,
                       epoch: int, pool: ThreadPoolExecutor) -> SampleRound:
    t0 = r * win
    n = store.num_nodes
    seeds = draw_seeds(n, resolved.num_seeds, spec.seed, epoch, r)
    per_step = list(pool.map(
        lambda t: _sample_step(store, t, seeds, spec, epoch),
        range(t0, t0 + win)))

    # round table: seeds first (loss lanes), then every other sampled
    # vertex ascending — deterministic under any thread schedule
    extra = np.setdiff1d(
        np.unique(np.concatenate([nodes for nodes, _, _ in per_step])),
        seeds, assume_unique=False)
    table = np.concatenate([seeds, extra])
    dropped_nodes = max(0, table.shape[0] - resolved.table_pad)
    table = table[:resolved.table_pad]
    k = table.shape[0]

    # global id -> table-local rank (searchsorted over the sorted view)
    sort_idx = np.argsort(table, kind="stable")
    sorted_ids = table[sort_idx]

    def to_local(ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        p = np.clip(np.searchsorted(sorted_ids, ids), 0, k - 1)
        ok = sorted_ids[p] == ids
        return sort_idx[p].astype(np.int32), ok

    e_pad = resolved.edge_pad
    edges = np.zeros((win, e_pad, 2), dtype=np.int32)
    mask = np.zeros((win, e_pad), dtype=np.float32)
    values = np.zeros((win, e_pad), dtype=np.float32)
    sampled_edges = dropped_edges = 0
    for i, (_, ge, gv) in enumerate(per_step):
        if not ge.shape[0]:
            continue
        lsrc, ok_s = to_local(ge[:, 0])
        ldst, ok_d = to_local(ge[:, 1])
        keep = ok_s & ok_d              # endpoints dropped by table overflow
        lsrc, ldst, gv = lsrc[keep], ldst[keep], gv[keep]
        e = lsrc.shape[0]
        dropped_edges += max(0, e - e_pad)
        e = min(e, e_pad)
        edges[i, :e, 0] = lsrc[:e]
        edges[i, :e, 1] = ldst[:e]
        mask[i, :e] = 1.0
        values[i, :e] = gv[:e]
        sampled_edges += e

    f_sub = np.zeros((win, resolved.table_pad, frames.shape[-1]),
                     dtype=np.float32)
    l_sub = np.zeros((win, resolved.table_pad), dtype=np.int32)
    f_sub[:, :k] = frames[t0:t0 + win][:, table]
    l_sub[:, :k] = labels[t0:t0 + win][:, table]

    return SampleRound(r=r, t0=t0, node_ids=table, frames=f_sub,
                       labels=l_sub, edges=edges, mask=mask, values=values,
                       sampled_edges=sampled_edges,
                       dropped_nodes=dropped_nodes,
                       dropped_edges=dropped_edges)


@dataclass
class SampledSliceStream:
    """The sampled round pipeline stage: host sampling -> prefetch-staged
    device rounds with time-sharded ``NamedSharding`` placement.

    Drives the same producer/consumer protocol as the full-graph round
    stream: ``rounds(epoch)`` is the host iterator the prefetch thread
    drains, ``stage_fn()`` the staging callable it applies."""

    store: TemporalCSRStore
    frames: np.ndarray
    labels: np.ndarray
    spec: SamplingSpec
    resolved: ResolvedSampling
    mesh: object
    win: int
    axis: str = shardlib.DATA_AXIS
    _shardings: dict = field(init=False, default_factory=dict)

    def __post_init__(self):
        b = shardlib.stream_batch_specs(self.axis)
        self._shardings = {k: NamedSharding(self.mesh, b[k])
                           for k in ("frames", "edges", "mask", "values",
                                     "labels")}

    @property
    def rounds_per_epoch(self) -> int:
        return self.store.num_steps // self.win

    def rounds(self, epoch: int):
        """Host iterator of one epoch's ``SampleRound``s (runs on the
        prefetch thread; sampling fans out to ``spec.workers`` threads)."""
        with ThreadPoolExecutor(max_workers=self.spec.workers) as pool:
            for r in range(self.rounds_per_epoch):
                yield sample_round(self.store, self.frames, self.labels,
                                   self.spec, self.resolved, self.win, r,
                                   epoch, pool)

    def stage_fn(self):
        """Round staging for the prefetch thread: every tensor ships with
        its time-sharded placement; timings/bytes ride on the item."""
        sh = self._shardings

        def stage(rnd: SampleRound) -> StagedRound:
            with obs.stopwatch("sample.stage", cat="sample",
                               round=rnd.r) as sw:
                put = jax.device_put
                staged = StagedRound(
                    r=rnd.r, t0=rnd.t0, node_ids=rnd.node_ids,
                    frames=put(rnd.frames, sh["frames"]),
                    labels=put(rnd.labels, sh["labels"]),
                    edges=put(rnd.edges, sh["edges"]),
                    mask=put(rnd.mask, sh["mask"]),
                    values=put(rnd.values, sh["values"]),
                    sample_s=rnd.sample_s, sampled_edges=rnd.sampled_edges,
                    dropped_nodes=rnd.dropped_nodes,
                    dropped_edges=rnd.dropped_edges)
                staged.staged_bytes = (rnd.frames.nbytes + rnd.labels.nbytes
                                       + rnd.edges.nbytes + rnd.mask.nbytes
                                       + rnd.values.nbytes)
            staged.stage_s = sw.seconds
            return staged

        return stage

    def round_graph_bytes(self) -> int:
        """Static bytes one round stages (graph + features + labels)."""
        win, tp, ep = self.win, self.resolved.table_pad, self.resolved.edge_pad
        feat = self.frames.shape[-1]
        return win * (ep * (8 + 4 + 4) + tp * feat * 4 + tp * 4)
