"""int8 gradient compression with error feedback for DP aggregation.

Each shard quantizes (gradient + carried residual) to int8 with a local
absmax scale, dequantizes, and psums the dequantized tensors; the
quantization error is carried into the next step (error feedback), so the
truncation never accumulates bias.  The reduction returns the MEAN over
the axis — a drop-in for the uncompressed ``psum(g)/P`` data-parallel
aggregate.

The wire format modeled is 1 byte/element + one f32 scale per tensor
(4x smaller than f32 all-reduce); on host meshes the psum still runs in
f32, which changes bytes, not math.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

_QMAX = 127.0


def init_residual(grads: Any) -> Any:
    """Zero error-feedback residuals matching the gradient tree (f32)."""
    return jax.tree.map(
        lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def _quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / _QMAX
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(g / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def compressed_psum(grads: Any, axis, residual: Any) -> tuple[Any, Any]:
    """Error-feedback int8 mean-reduction over a mesh ``axis``.

    Returns (reduced_mean_tree, new_residual_tree).  Must be called inside
    ``shard_map``; the residual stays shard-local.
    """
    p = jax.lax.psum(jnp.ones((), jnp.float32), axis)

    def one(g, res):
        g32 = g.astype(jnp.float32) + res
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        new_res = g32 - deq
        red = jax.lax.psum(deq, axis) / p
        return red.astype(g.dtype), new_res

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r, strict=True)]
    red = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_res = jax.tree.unflatten(treedef, [o[1] for o in out])
    return red, new_res
