"""int8 wire compression with error feedback for collectives.

Two users:

- ``compressed_psum`` — error-feedback int8 mean-reduction for DP
  gradient aggregation.  Each shard quantizes (gradient + carried
  residual) to int8 with a local absmax scale, dequantizes, and psums
  the dequantized tensors; the quantization error is carried into the
  next step (error feedback), so the truncation never accumulates bias.
- ``make_quantized_a2a`` — error-feedback int8 all-to-all for the two
  per-layer feature redistributions of the snapshot-partitioned forward
  (``core.partition.snapshot_block_body``).  Each shard quantizes every
  destination piece with its own absmax scale, ships int8 payload plus a
  tiny f32 scale vector, and keeps the untransmitted error as a local
  residual for the next round.  The backward rule is the transposed
  quantized all-to-all (without error feedback — cotangents are not
  reused across rounds), so gradient bytes shrink with activation bytes.

The wire format modeled is 1 byte/element + one f32 scale per piece
(~4x smaller than f32); on host meshes the collectives still run the
dequantized f32 arrays, which changes bytes, not math — byte accounting
lives in ``dist.comm_volume`` and is pinned to the lowered HLO by
``tests/test_compression_drift.py``.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

_QMAX = 127.0

# ExecutionPlan.compression values: "none" keeps today's f32 paths
# bit-exact; "int8_a2a" quantizes the two per-layer feature all-to-alls;
# "int8_all" additionally narrows the host->device delta wire format
# (see stream.wire).
COMPRESSION_MODES = ("none", "int8_a2a", "int8_all")


def validate_mode(compression: str) -> str:
    if compression not in COMPRESSION_MODES:
        raise ValueError(
            f"compression must be one of {COMPRESSION_MODES}, "
            f"got {compression!r}")
    return compression


def compresses_a2a(compression: str) -> bool:
    """Whether this mode quantizes the feature all-to-alls."""
    return validate_mode(compression) != "none"


def wire_mode(compression: str) -> str:
    """The ``stream.wire`` delta format implied by a compression mode."""
    return "int8" if validate_mode(compression) == "int8_all" else "none"


def init_residual(grads: Any) -> Any:
    """Zero error-feedback residuals matching the gradient tree (f32)."""
    return jax.tree.map(
        lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Absmax int8 quantization: ``g ~= q * scale``.

    The scale is clamped to [tiny, finfo.max] so all-zero tensors
    quantize to zeros (not NaN) and ±inf inputs saturate to ±127
    (inf/finite_max is inf, which clips cleanly; inf/inf would be NaN).
    """
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / _QMAX
    scale = jnp.clip(scale, jnp.finfo(jnp.float32).tiny,
                     jnp.finfo(jnp.float32).max)
    q = jnp.clip(jnp.round(g32 / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# kept under the historical name: tests and benchmarks poke it directly
_quantize = quantize


def ef_quantize(g: jax.Array, res: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """One error-feedback step: quantize ``g + res``, return the
    dequantized value actually transmitted and the new residual.

    By construction ``deq == (g + res) - new_res`` exactly, so over K
    steps the transmitted sum telescopes:
    ``sum(deq_k) == sum(g_k) + res_0 - res_K``.
    """
    g32 = g.astype(jnp.float32) + res
    q, scale = quantize(g32)
    deq = dequantize(q, scale)
    return deq, g32 - deq


@partial(jax.jit, static_argnames=("axis",))
def _psum_leaf(g, res, *, axis):
    p = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    deq, new_res = ef_quantize(g, res)
    red = jax.lax.psum(deq, axis) / p
    return red.astype(g.dtype), new_res


def compressed_psum(grads: Any, axis, residual: Any) -> tuple[Any, Any]:
    """Error-feedback int8 mean-reduction over a mesh ``axis``.

    Returns (reduced_mean_tree, new_residual_tree).  Must be called inside
    ``shard_map``; the residual stays shard-local.  One jitted leaf fn
    applied via ``jax.tree.map`` — tracing cost is per unique leaf
    shape/dtype, not per leaf, so deep parameter trees stay cheap.
    """
    out = jax.tree.map(lambda g, r: _psum_leaf(g, r, axis=axis),
                       grads, residual)
    treedef = jax.tree.structure(grads)
    return jax.tree.transpose(treedef, jax.tree.structure((0, 0)), out)


def _split_pieces(y: jax.Array, p: int, split_axis: int) -> list[jax.Array]:
    return jnp.split(y, p, axis=split_axis)


def _quantize_pieces(y32: jax.Array, p: int, split_axis: int
                     ) -> tuple[jax.Array, jax.Array]:
    """Per-destination-piece absmax quantization along ``split_axis``.

    Returns the int8 array (same shape as ``y32``) and a ``(p,)`` f32
    scale vector, one scale per destination shard.
    """
    pieces = _split_pieces(y32, p, split_axis)
    qs, scales = zip(*(quantize(pc) for pc in pieces))
    return (jnp.concatenate(qs, axis=split_axis),
            jnp.stack(list(scales)))


def _dequantize_pieces(q: jax.Array, scales: jax.Array, p: int,
                       piece_axis: int) -> jax.Array:
    """Inverse of ``_quantize_pieces`` with pieces along ``piece_axis``
    (the concat axis after an all-to-all, the split axis before one)."""
    pieces = _split_pieces(q, p, piece_axis)
    return jnp.concatenate(
        [dequantize(pc, scales[i]) for i, pc in enumerate(pieces)],
        axis=piece_axis)


def _a2a_int8(y32: jax.Array, axis, p: int, split_axis: int,
              concat_axis: int) -> tuple[jax.Array, jax.Array]:
    """Quantized tiled all-to-all of an f32 array.

    Returns (dequantized output, what this shard locally transmitted
    after dequantization) — the second value is what error feedback
    subtracts from ``y32`` to form the residual.
    """
    q, scales = _quantize_pieces(y32, p, split_axis)
    sent = _dequantize_pieces(q, scales, p, split_axis)
    q_out = jax.lax.all_to_all(q, axis, split_axis=split_axis,
                               concat_axis=concat_axis, tiled=True)
    s_out = jax.lax.all_to_all(scales.reshape(p, 1), axis, split_axis=0,
                               concat_axis=1, tiled=True).reshape(p)
    return _dequantize_pieces(q_out, s_out, p, concat_axis), sent


def quantized_all_to_all(y: jax.Array, axis, p: int, split_axis: int,
                         concat_axis: int) -> jax.Array:
    """int8 all-to-all without error feedback (used for cotangents)."""
    out, _ = _a2a_int8(y.astype(jnp.float32), axis, p, split_axis,
                       concat_axis)
    return out.astype(y.dtype)


def make_quantized_a2a(axis, p: int, split_axis: int, concat_axis: int):
    """Error-feedback int8 all-to-all: ``(y, res) -> (out, new_res)``.

    Forward ships int8 payload + a (p,) f32 scale vector; the
    untransmitted quantization error lands in ``new_res`` and is added
    back before quantizing the next round (so truncation never
    accumulates bias in the loss stream).  Backward is the TRANSPOSED
    quantized all-to-all of the output cotangent, without error feedback
    — the residual in/out pair is non-differentiable (``new_res`` rides
    the aux output of ``value_and_grad``, whose cotangent is zero).
    """

    def _impl(y, res):
        y32 = y.astype(jnp.float32) + res
        out, sent = _a2a_int8(y32, axis, p, split_axis, concat_axis)
        return out.astype(y.dtype), y32 - sent

    @jax.custom_vjp
    def qa2a(y, res):
        return _impl(y, res)

    def _fwd(y, res):
        return _impl(y, res), jnp.zeros((0,), y.dtype)

    def _bwd(saved, g):
        g_out, _g_res = g  # new_res rides the aux output: cotangent zero
        g_y = quantized_all_to_all(g_out, axis, p, split_axis=concat_axis,
                                   concat_axis=split_axis)
        # the transposed all-to-all restores y's shape; res shares it
        return g_y.astype(saved.dtype), jnp.zeros(g_y.shape, jnp.float32)

    qa2a.defvjp(_fwd, _bwd)
    return qa2a
