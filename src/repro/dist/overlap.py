"""Compute/communication overlap for snapshot partitioning (beyond-paper
§6.5 direction).

The plain schedule serializes [spatial GCN] -> [all-to-all] -> [temporal]
per layer.  Chunking each redistribution into C feature-sliced
all-to-alls exposes independent chains the latency-hiding scheduler can
run concurrently with compute; the math is unchanged (verified exactly in
tests/test_partitioning.py).

``overlap_time_model`` is the standard pipelining bound used by the
benchmark: with C chunks the non-dominant phase hides behind the dominant
one except for one chunk's worth of fill/drain.
"""

from __future__ import annotations

from repro.core import partition as _partition


def overlap_time_model(t_comp: float, t_comm: float, chunks: int) -> dict:
    """Pipelined execution time of two phases split into ``chunks``.

    serial    = t_comp + t_comm
    pipelined = max(phases) + min(phases) / chunks   (fill + steady state)
    """
    chunks = max(int(chunks), 1)
    serial = t_comp + t_comm
    pipelined = max(t_comp, t_comm) + min(t_comp, t_comm) / chunks
    return {"serial_s": serial, "pipelined_s": pipelined,
            "speedup": serial / pipelined if pipelined > 0 else 1.0,
            "chunks": chunks}


def snapshot_partition_forward_overlapped(cfg, mesh, num_chunks: int = 2,
                                          axis: str = "data"):
    """Snapshot-partitioned forward with chunked (overlappable)
    redistributions — identical outputs to the plain schedule."""
    return _partition.snapshot_partition_forward(cfg, mesh, axis=axis,
                                                 a2a_chunks=num_chunks)
