"""Compute/communication overlap for snapshot partitioning (beyond-paper
§6.5 direction).

The plain schedule serializes [spatial GCN] -> [all-to-all] -> [temporal]
per layer.  Chunking each redistribution into C feature-sliced
all-to-alls exposes independent chains the latency-hiding scheduler can
run concurrently with compute; the math is unchanged (verified exactly in
tests/test_partitioning.py).

``overlap_time_model`` is the standard two-phase pipelining bound: with C
chunks the non-dominant phase hides behind the dominant one except for
one chunk's worth of fill/drain.  ``round_time_model`` extends it to the
four phases of one distributed STREAMED round (transfer, spatial, a2a,
temporal) with both the chunked-a2a and the round-level pipelining knob;
``benchmarks/overlap_bench.py`` and ``benchmarks/scaling_bench.py``
report its prediction against the measured pipelined round time.
"""

from __future__ import annotations

from repro.core import partition as _partition


def overlap_time_model(t_comp: float, t_comm: float, chunks: int) -> dict:
    """Pipelined execution time of two phases split into ``chunks``.

    serial    = t_comp + t_comm
    pipelined = max(phases) + min(phases) / chunks   (fill + steady state)
    """
    chunks = max(int(chunks), 1)
    serial = t_comp + t_comm
    pipelined = max(t_comp, t_comm) + min(t_comp, t_comm) / chunks
    return {"serial_s": serial, "pipelined_s": pipelined,
            "speedup": serial / pipelined if pipelined > 0 else 1.0,
            "chunks": chunks}


def round_time_model(t_transfer: float, t_spatial: float, t_a2a: float,
                     t_temporal: float, chunks: int = 1,
                     pipeline_rounds: bool = False,
                     a2a_wire_ratio: float = 1.0) -> dict:
    """Steady-state time of ONE distributed streamed round with C chunks.

    The round has four phases (the serial schedule runs them back to
    back — ``stream.distributed``'s default loop):

      transfer   host->device delta staging + delta-apply reconstruction
      spatial    communication-free GCN stage on the local snapshots
      a2a        the two per-layer fixed-volume all-to-alls
      temporal   temporal stage in the vertex-sharded domain

    Two levels of pipelining, matching the execution knobs:

    * ``chunks=C`` (``a2a_chunks``): within the round, the a2a phase is
      split into C feature-sliced collectives that overlap the adjacent
      compute (spatial + temporal), so the inner round time is the
      standard bound ``max(comp, a2a) + min(comp, a2a) / C``;
    * ``pipeline_rounds``: round r+1's transfer phase runs concurrently
      with round r's compute + collectives, so in steady state the
      per-round time is ``max(transfer, inner)``.

    ``a2a_wire_ratio`` scales the a2a phase for wire compression
    (``ExecutionPlan.compression``): pass the modeled compressed/f32 byte
    ratio — ``alltoall_round_payload(..., compression=...) /
    alltoall_round_payload(...)`` — under the bandwidth-bound assumption
    that redistribution time tracks bytes on the wire.  1.0 (default)
    models the uncompressed round; the serial reference keeps the
    UNCOMPRESSED a2a time so ``speedup`` reports the combined
    pipelining + compression gain against today's serial round.

    Degenerate cases are exact: C=1, no round pipelining, and wire ratio
    1.0 reproduce the serial sum; the model is monotone non-increasing
    in C and in the wire ratio.
    """
    chunks = max(int(chunks), 1)
    if a2a_wire_ratio <= 0:
        raise ValueError(f"a2a_wire_ratio must be > 0, "
                         f"got {a2a_wire_ratio}")
    comp = t_spatial + t_temporal
    serial = t_transfer + comp + t_a2a
    t_a2a_wire = t_a2a * a2a_wire_ratio
    # C=1 degenerates exactly: max + min/1 == comp + t_a2a
    inner = max(comp, t_a2a_wire) + min(comp, t_a2a_wire) / chunks
    pipelined = max(t_transfer, inner) if pipeline_rounds \
        else t_transfer + inner
    return {"serial_s": serial, "pipelined_s": pipelined,
            "inner_s": inner, "a2a_wire_ratio": a2a_wire_ratio,
            "speedup": serial / pipelined if pipelined > 0 else 1.0,
            "chunks": chunks, "pipeline_rounds": pipeline_rounds,
            "phases_s": {"transfer": t_transfer, "spatial": t_spatial,
                         "a2a": t_a2a_wire, "temporal": t_temporal}}


def snapshot_partition_forward_overlapped(cfg, mesh, num_chunks: int = 2,
                                          axis: str = "data"):
    """Snapshot-partitioned forward with chunked (overlappable)
    redistributions — identical outputs to the plain schedule."""
    return _partition.snapshot_partition_forward(cfg, mesh, axis=axis,
                                                 a2a_chunks=num_chunks)
