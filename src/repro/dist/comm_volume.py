"""Analytic communication-volume models (paper §4 / Table 2).

Volumes are counted in FLOAT UNITS actually crossing the network (the
(P-1)/P locality discount of tiled collectives is applied), summed over
all processors — the quantity the paper tabulates.

* ``snapshot_partition_volume`` — the paper's scheme: two all-to-alls per
  GCN layer redistributing the full (T, N, F) activation tensor, so the
  total is O(T*N*F*L) for ANY processor count.  EvolveGCN's temporal op
  acts on the (tiny) layer weights, so its feature path is
  communication-free (§5.5).
* ``allgather_vertex_volume`` — the regular upper bound of vertex
  partitioning: every layer all-gathers the frame, volume grows ~P.
* ``vertex_partition_volume`` — the hypergraph (λ-1 cut) estimate for a
  GIVEN vertex-ownership vector: each (boundary vertex, remote partition)
  pair ships one F-float feature row per layer per snapshot.
* ``bfs_partition`` — BFS-locality ownership standing in for PaToH:
  contiguity-aware equal-size partitions so the cut metric is meaningful.
"""

from __future__ import annotations

from collections import deque

import numpy as np


def snapshot_partition_volume(t: int, n: int, feat: int, layers: int,
                              p: int, model: str = "tmgcn") -> float:
    """Total float units moved per epoch under snapshot partitioning."""
    if model == "evolvegcn":
        # weights-evolve models redistribute nothing on the feature path;
        # only the per-block boundary weight broadcast remains (negligible
        # but nonzero so ratios stay defined).
        return float(layers * feat * feat * max(p - 1, 0))
    if p <= 1:
        return 0.0
    # 2 all-to-alls per layer, each moving (P-1)/P of the (T, N, F) tensor.
    return 2.0 * layers * t * n * feat * (p - 1) / p


def alltoall_round_payload(win: int, n: int, feat: int, layers: int,
                           p: int, bytes_per: float = 4.0,
                           compression: str = "none",
                           a2a_chunks: int = 1) -> float:
    """Bytes crossing the network in ONE streamed round of ``win``
    snapshots under snapshot partitioning: two all-to-alls per GCN layer
    over the (win, N, F) block, each moving the (P-1)/P off-device
    fraction.  Per SNAPSHOT this approaches 2*L*N*F*bytes_per from below
    as P grows — the fixed-volume property the streamed distributed
    trainer inherits (total communication independent of P).

    ``compression`` != "none" models the int8 quantized redistributions
    (``dist.compression.make_quantized_a2a``): one byte per element plus
    one (P,) f32 scale vector per all-to-all per shard — and each of the
    2L redistributions lowers to ``a2a_chunks`` feature-sliced
    all-to-alls, so the scale overhead grows with the chunk count while
    the element payload does not.  The model is pinned element-for-
    element to the lowered HLO in tests/test_compression_drift.py.
    """
    if p <= 1:
        return 0.0
    elems = 2.0 * layers * win * n * feat * (p - 1) / p
    if compression == "none":
        return elems * bytes_per
    # int8 payload + the per-chunk scale a2a: each of the 2L*chunks
    # quantized all-to-alls ships a (P,) f32 scale vector per shard, of
    # which (P-1) entries cross the network; P shards total.
    scale_bytes = 2.0 * layers * a2a_chunks * p * (p - 1) * 4.0
    return elems * 1.0 + scale_bytes


def index_width(max_index: int) -> float:
    """Wire bytes per index under stream.wire narrowing (int16 when the
    largest index fits, int32 otherwise)."""
    return 2.0 if max_index <= 32767 else 4.0


def delta_wire_bytes(drops: float, adds: float, num_edges: float, *,
                     num_nodes: int, max_edges: int,
                     wire: str = "none") -> float:
    """Bytes of one delta payload, mirroring the per-item accounting of
    ``SnapshotDelta.payload_bytes`` (f32 wire) and
    ``stream.wire.QuantizedDelta.payload_bytes`` (int8 wire): drop
    positions index the device edge list, adds carry two node ids, one
    value per valid edge, plus the f32 scale on the quantized wire."""
    if wire == "none":
        return drops * 4.0 + adds * 8.0 + num_edges * 4.0
    if wire != "int8":
        raise ValueError(f"wire must be none|int8, got {wire!r}")
    return (drops * index_width(max_edges - 1)
            + adds * 2.0 * index_width(num_nodes - 1)
            + num_edges * 1.0 + 4.0)


_HLO_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
                    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
                    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}


def hlo_collective_bytes(hlo_text: str, op: str = "all-to-all"
                         ) -> dict[str, dict[str, int]]:
    """Per-shard payload bytes of every ``op`` in a compiled HLO dump,
    keyed by element dtype: ``{"s8": {"ops": 4, "bytes": 1536}, ...}``.

    Parses the RESULT shapes of each op line (tuple-form collectives sum
    their tuple elements — together they carry the whole local payload),
    so measured bytes come from what XLA actually lowered, not from the
    model being checked against it.
    """
    import re
    out: dict[str, dict[str, int]] = {}
    line_re = re.compile(r"= (.*?) " + re.escape(op) + r"(?:-start)?\(")
    shape_re = re.compile(r"(\w+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        m = line_re.search(line)
        if not m:
            continue
        shapes = [(d, dims) for d, dims in shape_re.findall(m.group(1))
                  if d in _HLO_DTYPE_BYTES]
        if not shapes:
            continue
        ent = out.setdefault(shapes[0][0], {"ops": 0, "bytes": 0})
        ent["ops"] += 1
        for dtype, dims in shapes:
            elems = 1
            for d in dims.split(","):
                if d:
                    elems *= int(d)
            out.setdefault(dtype, {"ops": 0, "bytes": 0})
            out[dtype]["bytes"] += elems * _HLO_DTYPE_BYTES[dtype]
    return out


def streamed_shard_volume(num_steps: int, p: int, block_size: int,
                          bytes_full: float, bytes_delta: float) -> float:
    """Analytic per-shard host->device stream bytes under the time-sliced
    delta streams (stream/sharded.py): each shard opens every round
    (= checkpoint block) with one self-contained full snapshot — the
    per-shard analogue of the block-boundary rule — and ships deltas for
    the rest of its ``num_steps/P`` owned slice.

    Under time-axis weak scaling (T and block_size grown with P, per-shard
    work fixed) this is CONSTANT in P; on a fixed trace it shrinks ~1/P.
    """
    owned = num_steps / p
    fulls = num_steps / block_size          # one slice start per block
    return fulls * bytes_full + max(owned - fulls, 0.0) * bytes_delta


def rescale_payload(carry_bytes: float, state_bytes: float, old_p: int,
                    new_p: int) -> float:
    """Bytes crossing the links at ONE elastic rescale P_old -> P_new
    (``repro.elastic``): the vertex-sharded temporal carries are re-laid
    out over the new mesh (one gather/scatter of the full carry tree),
    and — only when the mesh GROWS — the replicated train state (params +
    optimizer) is shipped once to each newly added device.  Shrinking
    moves no replicas: the surviving devices already hold them.

    The total is O(model state + block-boundary carries), independent of
    T and of the stream volume — the reason elasticity is cheap under
    fixed-volume snapshot partitioning: changing P re-blocks the
    timeline and re-slices the delta streams, but the O(T*N) transfer
    volume itself is the same at any P, so only boundary state moves.
    """
    if old_p < 1 or new_p < 1:
        raise ValueError(f"processor counts must be >= 1, got "
                         f"{old_p} -> {new_p}")
    if old_p == new_p:
        return 0.0
    return float(carry_bytes) + max(new_p - old_p, 0) * float(state_bytes)


def allgather_vertex_volume(t: int, n: int, feat: int, layers: int,
                            p: int) -> float:
    """Regular-pattern vertex baseline: per layer & snapshot every
    processor receives the (P-1)/P remote rows of the (N, F) frame."""
    if p <= 1:
        return 0.0
    return float(layers) * t * p * (n * (p - 1) / p) * feat


def bfs_partition(edges: np.ndarray, num_nodes: int, p: int) -> np.ndarray:
    """Equal-size BFS-locality vertex partitioning (PaToH stand-in).

    Grows partition 0..p-1 by BFS from unassigned seed vertices so each
    owns ``ceil(N/P)`` vertices; neighbours tend to share an owner, which
    is all the cut model needs.  Returns owner (N,) int32.
    """
    cap = -(-num_nodes // p)
    adj: list[list[int]] = [[] for _ in range(num_nodes)]
    for u, v in np.asarray(edges, dtype=np.int64):
        adj[u].append(int(v))
        adj[v].append(int(u))
    owner = np.full((num_nodes,), -1, dtype=np.int32)
    sizes = np.zeros((p,), dtype=np.int64)
    cur = 0
    for seed in range(num_nodes):
        if owner[seed] >= 0:
            continue
        q = deque([seed])
        while q:
            u = q.popleft()
            if owner[u] >= 0:
                continue
            while sizes[cur] >= cap and cur < p - 1:
                cur += 1
            owner[u] = cur
            sizes[cur] += 1
            for w in adj[u]:
                if owner[w] < 0:
                    q.append(w)
    return owner


def vertex_partition_volume(snapshots: list[np.ndarray], _n: int, feat: int,
                            layers: int, p: int,
                            owner: np.ndarray) -> float:
    """Hypergraph-style volume: λ-1 cut of the given ownership, per layer
    and snapshot, F floats per (vertex, remote partition) pair."""
    owner = np.asarray(owner)
    pairs = 0
    for snap in snapshots:
        e = np.asarray(snap, dtype=np.int64)
        if e.shape[0] == 0:
            continue
        src_own = owner[e[:, 0]]
        dst_own = owner[e[:, 1]]
        cut = src_own != dst_own
        if not cut.any():
            continue
        # distinct (src vertex, dst partition) pairs = rows shipped
        key = e[cut, 0] * p + dst_own[cut]
        pairs += np.unique(key).shape[0]
    return float(layers) * feat * pairs
