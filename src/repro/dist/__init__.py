"""Distribution utilities: communication-volume models, compute/comm
overlap, gradient compression, and sharding-spec helpers."""
