"""PartitionSpec trees + helpers for the production cells.

Conventions (see launch/steps.py):
  * TP over the 'model' axis, DP over ('pod', 'data') — ``dp_axes`` returns
    whichever of those exist on the mesh, pod-major.
  * A dimension is only sharded when it divides the axis size; otherwise it
    stays replicated (the callers layer smarter fallbacks on top, e.g. the
    GQA head specs in steps.py).
  * ``named`` turns a PartitionSpec tree into a NamedSharding tree;
    PartitionSpec is a tuple subclass, so every tree op here passes
    ``is_leaf`` to stop the flattener from recursing into the specs.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_DP_NAMES = ("pod", "data")


def dp_axes(mesh: Mesh) -> tuple:
    """Data-parallel axis names present on the mesh, pod-major."""
    return tuple(a for a in _DP_NAMES if a in mesh.axis_names)


def _is_spec(x) -> bool:
    return isinstance(x, P)


def named(mesh: Mesh, specs: Any) -> Any:
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=_is_spec)


def replicate_specs(tree: Any) -> Any:
    """Fully-replicated spec tree matching ``tree``'s structure."""
    return jax.tree.map(lambda _: P(), tree)


def opt_state_specs(p_specs: Any) -> dict:
    """AdamW state specs: m/v/master mirror the param specs."""
    return {"m": p_specs, "v": p_specs, "master": p_specs, "step": P()}


# ------------------------------------------------------------------ LM ------

def _model_if_divisible(dim: int, mesh: Mesh):
    m = mesh.shape.get("model", 1)
    return "model" if m > 1 and dim % m == 0 else None


def lm_param_specs(cfg, mesh: Mesh, mode: str = "tp") -> dict:
    """Megatron-style TP specs for the stacked-layer LM param tree.

    Attention gets a baseline head-sharded spec; launch/steps.py replaces
    ``specs["layers"]["attn"]`` with the GQA-aware variant.
    """
    del mode  # one strategy here; steps.py layers variants on top
    ff = _model_if_divisible(cfg.d_ff, mesh)
    vocab = _model_if_divisible(cfg.padded_vocab, mesh)
    heads = _model_if_divisible(cfg.num_heads, mesh)
    kv = _model_if_divisible(cfg.num_kv_heads, mesh)
    attn = {"wq": P(None, None, heads, None),
            "wk": P(None, None, kv, None),
            "wv": P(None, None, kv, None),
            "wo": P(None, heads, None, None)}
    if cfg.is_moe:
        ep = _model_if_divisible(cfg.moe_experts, mesh)
        ffn = {"router": P(),
               "wi_gate": P(None, ep, None, None if ep else ff),
               "wi_up": P(None, ep, None, None if ep else ff),
               "wo": P(None, ep, None if ep else ff, None)}
    else:
        ffn = {"wi_gate": P(None, None, ff),
               "wi_up": P(None, None, ff),
               "wo": P(None, ff, None)}
    return {
        "embed": P(vocab, None),
        "layers": {"attn": attn, "ffn": ffn, "ln1": P(), "ln2": P()},
        "final_norm": P(),
        "out": P(None, vocab),
    }


def lm_batch_specs(mesh: Mesh) -> P:
    """(B, S) token batches: batch over DP, sequence replicated."""
    return P(dp_axes(mesh), None)


def lm_activation_constrainer(mesh: Mesh):
    """Rank-agnostic activation constraint: leading (batch) dim over DP.

    The returned callable carries an ``ep`` attribute (expert-parallel
    constrainer) that MoE layers probe via getattr; None = no EP here.
    """
    dp = dp_axes(mesh)

    def constrain(x):
        if not dp or x.ndim == 0:
            return x
        spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    constrain.ep = None
    return constrain


# -------------------------------------------------------------- recsys ------

def din_param_specs(mesh: Mesh, cfg=None) -> dict:
    """DIN: the huge embedding tables vocab-sharded over 'model', the small
    MLP towers replicated.  Structure is derived from the config so the
    spec tree always matches ``din.init_params``."""
    from repro.models import din as din_mod
    cfg = cfg or din_mod.DINConfig()
    abstract = jax.eval_shape(
        lambda: din_mod.init_params(jax.random.PRNGKey(0), cfg))
    specs = replicate_specs(abstract)
    table = P("model", None) if mesh.shape.get("model", 1) > 1 else P(None,
                                                                      None)
    for k in ("item_table", "cate_table", "user_table"):
        specs[k] = table
    return specs
