"""PartitionSpec trees + helpers for the production cells.

Conventions (see launch/steps.py):
  * TP over the 'model' axis, DP over ('pod', 'data') — ``dp_axes`` returns
    whichever of those exist on the mesh, pod-major.
  * A dimension is only sharded when it divides the axis size; otherwise it
    stays replicated (the callers layer smarter fallbacks on top, e.g. the
    GQA head specs in steps.py).
  * ``named`` turns a PartitionSpec tree into a NamedSharding tree;
    PartitionSpec is a tuple subclass, so every tree op here passes
    ``is_leaf`` to stop the flattener from recursing into the specs.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Canonical mesh-axis names.  Every shard_map / PartitionSpec /
# collective call in src/ must spell axes through these constants
# (dynlint's shard-axes pass enforces it): an axis-name typo then fails
# at import time instead of silently replicating a dimension.
POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"

_DP_NAMES = (POD_AXIS, DATA_AXIS)


def dp_axes(mesh: Mesh) -> tuple:
    """Data-parallel axis names present on the mesh, pod-major."""
    return tuple(a for a in _DP_NAMES if a in mesh.axis_names)


def _is_spec(x) -> bool:
    return isinstance(x, P)


def named(mesh: Mesh, specs: Any) -> Any:
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=_is_spec)


def replicate_specs(tree: Any) -> Any:
    """Fully-replicated spec tree matching ``tree``'s structure."""
    return jax.tree.map(lambda _: P(), tree)


def opt_state_specs(p_specs: Any) -> dict:
    """AdamW state specs: m/v/master mirror the param specs."""
    return {"m": p_specs, "v": p_specs, "master": p_specs, "step": P()}


# -------------------------------------------------- streamed dyn-GNN --------

def stream_batch_specs(axis="data") -> dict:
    """Specs for one streamed round under snapshot partitioning.

    Every array is (win, ...) with the TIME axis sharded: shard s owns its
    contiguous ``win/P`` reconstructed snapshots (Fig. 3b layout, one
    checkpoint block per round).
    """
    return {
        "frames": P(axis, None, None),    # (win, N, F)
        "edges": P(axis, None, None),     # (win, E, 2)
        "mask": P(axis, None),            # (win, E)
        "values": P(axis, None),          # (win, E)
        "labels": P(axis, None),          # (win, N)
    }


def stream_carry_specs(cfg, axis="data") -> list:
    """PartitionSpec tree mirroring ``models.init_carries`` for the
    snapshot-parallel streamed trainer.

    The temporal stage runs in the N-sharded domain (after the first
    all-to-all), so feature-RNN carries are vertex-sharded; EvolveGCN's
    weight-LSTM carry is tiny and evolved redundantly on every shard
    (§5.5), hence replicated.
    """
    specs: list = []
    for _ in range(cfg.num_layers):
        if cfg.model == "cdgcn":
            specs.append((P(axis, None), P(axis, None)))      # LSTM (h, c)
        elif cfg.model == "evolvegcn":
            specs.append((P(), (P(), P())))                   # (W, (h, c))
        elif cfg.model == "tmgcn":
            specs.append(P(None, axis, None))                 # (w-1, N, d)
        else:
            raise ValueError(cfg.model)
    return specs


def stream_comm_residual_specs(cfg, axis="data") -> list:
    """Specs for the per-layer error-feedback residuals of the quantized
    all-to-alls (``partition.a2a_payload_dims`` gives the widths).

    Each layer carries a ``(res_t2n, res_n2t)`` pair in the PRE-a2a
    layout of its redistribution: the T->N residual lives in the
    time-sharded domain (win, N, f_t2n), the N->T residual in the
    vertex-sharded domain (win, N, f_n2t).  EvolveGCN has no
    redistributions, hence no residuals.
    """
    if cfg.model == "evolvegcn":
        return []
    return [(P(axis, None, None), P(None, axis, None))
            for _ in range(cfg.num_layers)]


def shard_devices(mesh: Mesh, axis: str = "data") -> list:
    """One representative device per shard along ``axis`` (which must be
    the leading mesh axis): the placement target for per-shard delta
    streams and edge-buffer rings."""
    if mesh.axis_names[0] != axis:
        raise ValueError(f"stream sharding expects {axis!r} leading the "
                         f"mesh, got axes {mesh.axis_names}")
    import numpy as np
    devs = np.asarray(mesh.devices).reshape(mesh.shape[axis], -1)
    if devs.shape[1] != 1:
        raise ValueError(
            "per-shard delta streams need a pure snapshot-parallel mesh "
            f"(every non-{axis!r} axis of size 1); got {dict(mesh.shape)}")
    return [devs[s, 0] for s in range(mesh.shape[axis])]


# ------------------------------------------------------------------ LM ------

def _model_if_divisible(dim: int, mesh: Mesh):
    m = mesh.shape.get(MODEL_AXIS, 1)
    return MODEL_AXIS if m > 1 and dim % m == 0 else None


def lm_param_specs(cfg, mesh: Mesh, mode: str = "tp") -> dict:
    """Megatron-style TP specs for the stacked-layer LM param tree.

    Attention gets a baseline head-sharded spec; launch/steps.py replaces
    ``specs["layers"]["attn"]`` with the GQA-aware variant.
    """
    del mode  # one strategy here; steps.py layers variants on top
    ff = _model_if_divisible(cfg.d_ff, mesh)
    vocab = _model_if_divisible(cfg.padded_vocab, mesh)
    heads = _model_if_divisible(cfg.num_heads, mesh)
    kv = _model_if_divisible(cfg.num_kv_heads, mesh)
    attn = {"wq": P(None, None, heads, None),
            "wk": P(None, None, kv, None),
            "wv": P(None, None, kv, None),
            "wo": P(None, heads, None, None)}
    if cfg.is_moe:
        ep = _model_if_divisible(cfg.moe_experts, mesh)
        ffn = {"router": P(),
               "wi_gate": P(None, ep, None, None if ep else ff),
               "wi_up": P(None, ep, None, None if ep else ff),
               "wo": P(None, ep, None if ep else ff, None)}
    else:
        ffn = {"wi_gate": P(None, None, ff),
               "wi_up": P(None, None, ff),
               "wo": P(None, ff, None)}
    return {
        "embed": P(vocab, None),
        "layers": {"attn": attn, "ffn": ffn, "ln1": P(), "ln2": P()},
        "final_norm": P(),
        "out": P(None, vocab),
    }


def lm_batch_specs(mesh: Mesh) -> P:
    """(B, S) token batches: batch over DP, sequence replicated."""
    return P(dp_axes(mesh), None)


def lm_activation_constrainer(mesh: Mesh):
    """Rank-agnostic activation constraint: leading (batch) dim over DP.

    The returned callable carries an ``ep`` attribute (expert-parallel
    constrainer) that MoE layers probe via getattr; None = no EP here.
    """
    dp = dp_axes(mesh)

    def constrain(x):
        if not dp or x.ndim == 0:
            return x
        spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    constrain.ep = None
    return constrain


# -------------------------------------------------------------- recsys ------

def din_param_specs(mesh: Mesh, cfg=None) -> dict:
    """DIN: the huge embedding tables vocab-sharded over 'model', the small
    MLP towers replicated.  Structure is derived from the config so the
    spec tree always matches ``din.init_params``."""
    from repro.models import din as din_mod
    cfg = cfg or din_mod.DINConfig()
    abstract = jax.eval_shape(lambda: din_mod.init_params(
        # shape-only trace: the key never produces values
        jax.random.PRNGKey(0), cfg))  # dynlint: allow[prng]
    specs = replicate_specs(abstract)
    table = (P(MODEL_AXIS, None)
             if mesh.shape.get(MODEL_AXIS, 1) > 1 else P(None, None))
    for k in ("item_table", "cate_table", "user_table"):
        specs[k] = table
    return specs
