"""Vectorized host-side graph-diff encoder.

Replaces the reference encoder's per-edge python dict alignment
(``core.graphdiff.encode_stream``) with ``np.searchsorted`` set algebra:

* membership (drop/add selection) via one sort of each key array,
* value alignment of the new device ordering via a stable argsort +
  searchsorted gather — no python-level per-edge work at all.

It also sizes the drop/add pads from DATASET STATISTICS (the actual max
churn over the trace, rounded up) instead of ``max_edges``: real traces
churn a few percent of edges per step, so stats-sized pads shrink the
staged host buffers and the per-delta ``device_put`` by ~1/churn.

Output is bit-identical to the reference encoder (same drop positions,
same device-order survivors+adds, same aligned values) — only the pad
lengths differ, which ``apply_delta`` is agnostic to.  Verified in
tests/test_stream.py.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Iterator

import numpy as np

from repro import obs
from repro.core.graphdiff import FullSnapshot, SnapshotDelta, _edge_key
from repro.stream import wire as wirelib


class ChurnOverflowError(ValueError):
    """Measured churn at one step exceeds the stats-sized delta pads."""

    def __init__(self, drops: int, adds: int, drop_pad: int, add_pad: int):
        self.drops, self.adds = drops, adds
        self.drop_pad, self.add_pad = drop_pad, add_pad
        super().__init__(
            f"churn ({drops} drops / {adds} adds) exceeds stats pad "
            f"({drop_pad}/{add_pad}); re-measure stats")


@dataclass
class StreamReport:
    """Mutable per-stream health counters (shared with the caller).

    ``resyncs`` counts delta steps that overflowed the stats pads and were
    downgraded to FullSnapshot resyncs — a long-running stream whose live
    churn drifts past the measured trace statistics degrades (extra full
    payloads) instead of crashing mid-training.
    """
    resyncs: int = 0
    worst_drops: int = 0
    worst_adds: int = 0
    resync_steps: list = field(default_factory=list)

    def note_overflow(self, step: int, err: ChurnOverflowError) -> None:
        self.resyncs += 1
        self.worst_drops = max(self.worst_drops, err.drops)
        self.worst_adds = max(self.worst_adds, err.adds)
        self.resync_steps.append(step)
        # mirror into the shared namespace (docs/observability.md)
        obs.inc("stream.resyncs")


@dataclass(frozen=True)
class DeltaStats:
    """Pad sizing derived from one pass over the trace's key sets."""
    max_edges: int
    max_drops: int
    max_adds: int

    @property
    def churn_pad(self) -> int:
        return max(self.max_drops, self.max_adds)


def _round_up(v: int, m: int) -> int:
    return max(((v + m - 1) // m) * m, m)


def padded_max_edges(snapshots, multiple: int = 128) -> int:
    """Trace-wide E_max rounded up to the device lane multiple — the one
    edge-pad sizing rule shared by the trainer, benchmarks, and tests."""
    return _round_up(max(s.shape[0] for s in snapshots), multiple)


def measure_stats(snapshots: list[np.ndarray], num_nodes: int,
                  block_size: int, max_edges: int,
                  pad_multiple: int = 64) -> DeltaStats:
    """Max drop/add counts over the trace (delta steps only), padded up.

    Counts are set-cardinalities of consecutive snapshot key sets, so one
    vectorized pass suffices — no device-order simulation needed.
    """
    max_d = max_a = 0
    prev_keys: np.ndarray | None = None
    for i, snap in enumerate(snapshots):
        keys = np.sort(_edge_key(snap, num_nodes))
        if i % block_size != 0 and prev_keys is not None:
            common = np.intersect1d(prev_keys, keys,
                                    assume_unique=False).shape[0]
            max_d = max(max_d, prev_keys.shape[0] - common)
            max_a = max(max_a, keys.shape[0] - common)
        prev_keys = keys
    pad = min(_round_up(max(max_d, max_a, 1), pad_multiple), max_edges)
    return DeltaStats(max_edges=max_edges, max_drops=pad, max_adds=pad)


@dataclass
class _DeviceMirror:
    """Host mirror of the device buffer between delta steps.

    Carrying keys forward kills the two redundant sorts of the naive
    formulation: the device keys in device order are concat(kept, added)
    from last step, and the SORTED device keys are exactly the previous
    snapshot's sorted keys (same set).
    """
    edges: np.ndarray        # (E_dev, 2) device-order edge list
    keys: np.ndarray         # (E_dev,) int64 keys, device order
    keys_sorted: np.ndarray  # (E_dev,) int64 keys, ascending


def _delta_step(dev: _DeviceMirror, snap: np.ndarray, vals: np.ndarray,
                num_nodes: int, max_edges: int, drop_pad: int,
                add_pad: int) -> tuple[SnapshotDelta, _DeviceMirror]:
    """One vectorized delta against the current device ordering."""
    pk = dev.keys
    ck = _edge_key(snap, num_nodes)
    ck_order = np.argsort(ck, kind="stable")
    ck_sorted = ck[ck_order]
    # prev edges still present in the current snapshot (+ where, for the
    # value alignment below)
    pos = np.searchsorted(ck_sorted, pk)
    np.minimum(pos, max(ck_sorted.shape[0] - 1, 0), out=pos)
    keep_sel = (ck_sorted[pos] == pk) if ck_sorted.size else \
        np.zeros(pk.shape, dtype=bool)
    # current edges not present in the previous snapshot
    cpos = np.searchsorted(dev.keys_sorted, ck)
    np.minimum(cpos, max(dev.keys_sorted.shape[0] - 1, 0), out=cpos)
    add_sel = (dev.keys_sorted[cpos] != ck) if dev.keys_sorted.size else \
        np.ones(ck.shape, dtype=bool)

    drop_pos = np.nonzero(~keep_sel)[0].astype(np.int32)
    adds = snap[add_sel]
    if drop_pos.shape[0] > drop_pad or adds.shape[0] > add_pad:
        raise ChurnOverflowError(drop_pos.shape[0], adds.shape[0],
                                 drop_pad, add_pad)

    dp = np.zeros((drop_pad,), dtype=np.int32)
    dm = np.zeros((drop_pad,), dtype=np.float32)
    dp[:drop_pos.shape[0]] = drop_pos
    dm[:drop_pos.shape[0]] = 1.0
    ae = np.zeros((add_pad, 2), dtype=np.int32)
    am = np.zeros((add_pad,), dtype=np.float32)
    ae[:adds.shape[0]] = adds
    am[:adds.shape[0]] = 1.0

    # New device order: survivors (device order) then adds.  Values align
    # without another search: a survivor's key sits at ck_sorted[pos], i.e.
    # original snapshot position ck_order[pos]; adds map directly.
    new_dev = np.concatenate([dev.edges[keep_sel], adds], axis=0)
    v_valid = np.concatenate([vals[ck_order[pos[keep_sel]]], vals[add_sel]])
    v = np.zeros((max_edges,), dtype=np.float32)
    v[:v_valid.shape[0]] = v_valid
    new_keys = np.concatenate([pk[keep_sel], ck[add_sel]])
    mirror = _DeviceMirror(edges=new_dev, keys=new_keys,
                           keys_sorted=ck_sorted)
    return SnapshotDelta(drop_pos=dp, drop_mask=dm, add_edges=ae,
                         add_mask=am, values=v,
                         num_edges=snap.shape[0]), mirror


def _full_step(snap: np.ndarray, vals: np.ndarray,
               max_edges: int) -> FullSnapshot:
    e = np.zeros((max_edges, 2), dtype=np.int32)
    m = np.zeros((max_edges,), dtype=np.float32)
    v = np.zeros((max_edges,), dtype=np.float32)
    e[:snap.shape[0]] = snap
    m[:snap.shape[0]] = 1.0
    v[:snap.shape[0]] = vals
    return FullSnapshot(edges=e, mask=m, values=v, num_edges=snap.shape[0])


class IncrementalEncoder:
    """The delta encoder as an online consumer: one snapshot at a time.

    Holds the device-mirror state (``_DeviceMirror``) between calls so a
    LIVE stream — snapshots that materialize window by window, e.g. from
    the CTDG ingester (``repro.serve.ingest``) — encodes without ever
    materializing the trace.  The offline ``iter_encode_stream`` is a
    thin loop over this class, so online and offline encodings of the
    same snapshot sequence are the same code path (and therefore
    byte-identical — the property ``tests/test_serve.py`` pins).

    ``on_overflow`` governs steps whose measured churn exceeds the
    sized pads (always possible online, where pads come from a config or
    from a different trace's statistics):

    * ``"resync"`` (default) — ship that step as a FullSnapshot resync
      (the decoder treats it like a block boundary), warn once, and count
      it on ``report``; long-running streams degrade instead of crashing.
    * ``"raise"`` — propagate :class:`ChurnOverflowError` (strict mode
      for offline encoding where stats are authoritative).

    ``wire="int8"`` emits deltas on the narrow ``stream.wire`` format
    (:class:`~repro.stream.wire.QuantizedDelta`: int16/int32 indices,
    int8 masks, absmax-int8 values).  Full snapshots — block boundaries
    AND overflow resyncs — always stay on the lossless f32 format, so
    value quantization error never survives a re-base.
    """

    def __init__(self, num_nodes: int, max_edges: int, block_size: int,
                 drop_pad: int, add_pad: int, on_overflow: str = "resync",
                 report: StreamReport | None = None, wire: str = "none"):
        if on_overflow not in ("resync", "raise"):
            raise ValueError(f"on_overflow must be resync|raise, "
                             f"got {on_overflow!r}")
        self.num_nodes = num_nodes
        self.max_edges = max_edges
        self.block_size = block_size
        self.drop_pad = drop_pad
        self.add_pad = add_pad
        self.on_overflow = on_overflow
        self.report = report
        self.wire = wirelib.validate_wire(wire)
        self.step = 0
        self._dev: _DeviceMirror | None = None
        self._warned = False

    def _full_resync(self, snap, vals):
        keys = _edge_key(snap, self.num_nodes)
        self._dev = _DeviceMirror(edges=snap.copy(), keys=keys,
                                  keys_sorted=np.sort(keys))
        return _full_step(snap, vals, self.max_edges)

    def encode(self, snap: np.ndarray, vals: np.ndarray | None = None
               ) -> FullSnapshot | SnapshotDelta:
        """Encode the next snapshot against the mirrored device state."""
        if vals is None:
            vals = np.ones((snap.shape[0],), dtype=np.float32)
        i, self.step = self.step, self.step + 1
        if i % self.block_size == 0:
            return self._full_resync(snap, vals)
        try:
            item, self._dev = _delta_step(
                self._dev, snap, vals, self.num_nodes, self.max_edges,
                self.drop_pad, self.add_pad)
            if self.wire != "none":
                item = wirelib.quantize_delta(item, self.num_nodes,
                                              self.max_edges)
            return item
        except ChurnOverflowError as err:
            if self.on_overflow == "raise":
                raise
            if self.report is not None:
                self.report.note_overflow(i, err)
            if not self._warned:
                # once per stream: a long-drifted stream can resync on
                # many steps and must not flood stderr — the report
                # carries the per-step detail
                warnings.warn(
                    f"delta stream step {i}: {err}; emitting "
                    "FullSnapshot resync (further overflows counted "
                    "on StreamReport, not warned)", stacklevel=2)
                self._warned = True
            return self._full_resync(snap, vals)


def iter_encode_stream(snapshots: list[np.ndarray],
                       values: list[np.ndarray] | None,
                       num_nodes: int, max_edges: int, block_size: int,
                       stats: DeltaStats | None = None,
                       on_overflow: str = "resync",
                       report: StreamReport | None = None,
                       wire: str = "none") -> Iterator:
    """Lazily encode the trace (the form the prefetch thread consumes).

    A loop over :class:`IncrementalEncoder` (which documents the
    ``on_overflow`` and ``wire`` modes) with stats-sized delta pads
    measured from the trace when not provided.
    """
    if stats is None:
        stats = measure_stats(snapshots, num_nodes, block_size, max_edges)
    inc = IncrementalEncoder(num_nodes, max_edges, block_size,
                             stats.max_drops, stats.max_adds,
                             on_overflow=on_overflow, report=report,
                             wire=wire)
    for i, snap in enumerate(snapshots):
        yield inc.encode(snap, values[i] if values is not None else None)


def encode_stream_fast(snapshots: list[np.ndarray],
                       values: list[np.ndarray] | None,
                       num_nodes: int, max_edges: int, block_size: int,
                       stats: DeltaStats | None = None,
                       on_overflow: str = "resync",
                       report: StreamReport | None = None,
                       wire: str = "none") -> list:
    """Drop-in replacement for ``core.graphdiff.encode_stream``."""
    return list(iter_encode_stream(snapshots, values, num_nodes, max_edges,
                                   block_size, stats, on_overflow, report,
                                   wire=wire))
