"""Shard-aware streaming for snapshot partitioning (paper §4.2).

Under snapshot partitioning, processor s of P owns a contiguous slice of
``bsize/P`` steps inside every checkpoint block.  Broadcasting the global
delta stream would ship every delta to every device; instead each shard
receives ONLY its own time-slices, encoded self-contained: the first step
of each slice ships full (the device holds nothing to diff against at a
slice boundary — the per-shard analogue of §6.2's block-boundary rule),
and the rest ship as deltas sized by the same trace statistics.

The per-shard payload therefore scales 1/P with the shard count (up to
the extra slice-boundary full snapshots), which is what
``benchmarks/graphdiff_bench.py`` reports.
"""

from __future__ import annotations

import numpy as np

from repro.core.graphdiff import FullSnapshot, SnapshotDelta
from repro.stream import encoder as enc


def shard_slice_steps(num_steps: int, block_size: int, num_shards: int,
                      shard: int) -> list[int]:
    """Global step indices owned by ``shard`` (contiguous per block)."""
    if block_size % num_shards != 0:
        raise ValueError(f"block_size {block_size} must divide into "
                         f"{num_shards} shards")
    bsl = block_size // num_shards
    steps: list[int] = []
    for b0 in range(0, num_steps, block_size):
        start = b0 + shard * bsl
        steps.extend(range(start, min(start + bsl, num_steps)))
    return steps


def encode_time_sliced(snapshots: list[np.ndarray],
                       values: list[np.ndarray] | None,
                       num_nodes: int, max_edges: int, block_size: int,
                       num_shards: int,
                       stats: enc.DeltaStats | None = None,
                       start_step: int = 0, wire: str = "none"
                       ) -> list[list[FullSnapshot | SnapshotDelta]]:
    """Per-shard streams: ``out[s][i]`` transfers shard s's i-th owned step.

    Each shard's sub-sequence is encoded with block boundaries at its
    slice starts (block size ``bsize/P``), so every slice is decodable
    from an empty device buffer.  Deltas within a slice reuse the global
    stats pads — churn between consecutive owned steps equals global
    consecutive-step churn because slices are contiguous.

    ``start_step`` (a checkpoint-block boundary) starts the streams
    mid-timeline: the elastic rescale subsystem (``repro.elastic``)
    re-slices the remaining trace for a NEW shard count from the next
    block boundary.  This is legal at exactly block granularity because
    every slice opens with a self-contained ``FullSnapshot`` — no shard
    ever needs decoder state from before the boundary, so the re-sliced
    tail is identical to the tail of a from-zero encoding.

    ``wire="int8"`` puts every delta on the narrow ``stream.wire``
    format (slice-boundary fulls stay lossless f32 — see
    ``IncrementalEncoder``).
    """
    if start_step % block_size:
        raise ValueError(f"start_step {start_step} must be a checkpoint-"
                         f"block boundary (multiple of {block_size})")
    if start_step:
        snapshots = snapshots[start_step:]
        values = values[start_step:] if values is not None else None
    bsl = block_size // num_shards
    if stats is None:
        stats = enc.measure_stats(snapshots, num_nodes, block_size,
                                  max_edges)
    out = []
    for s in range(num_shards):
        steps = shard_slice_steps(len(snapshots), block_size, num_shards, s)
        snaps_s = [snapshots[t] for t in steps]
        vals_s = [values[t] for t in steps] if values is not None else None
        out.append(enc.encode_stream_fast(snaps_s, vals_s, num_nodes,
                                          max_edges, bsl, stats,
                                          wire=wire))
    return out


def sharded_stream_bytes(shard_streams: list[list]) -> int:
    """Total bytes crossing the host->device links, all shards summed."""
    return sum(item.payload_bytes for s in shard_streams for item in s)
