"""Per-snapshot streaming training over the delta stream.

The regime the transfer pipeline exists for: snapshots arrive one delta at
a time, the device reconstructs the padded edge list (``apply_delta``),
recomputes the Laplacian weights from the reconstructed topology
(degree-derived — only index deltas + raw values cross the link, §5.5),
and runs one online train step per snapshot, threading the models'
temporal carries across steps.

Two drivers share every jitted computation and consume the items in the
same order, so their loss streams are BIT-IDENTICAL:

* ``overlap=False`` — the synchronous reference: encode, transfer, and
  compute strictly interleaved on one thread;
* ``overlap=True``  — encode + ``device_put`` run on the prefetch thread,
  ``depth`` deltas ahead of the compute stream.

The overlap path's win is measured in ``benchmarks/overlap_bench.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import models as mdl
from repro.graph import segment
from repro.optim import adamw
from repro.stream import encoder as enc
from repro.stream.prefetch import DeltaApplier, PrefetchIterator, stage_item


@dataclass
class StreamTrainState:
    params: dict
    opt_state: dict
    losses: list


def make_stream_train_step(cfg: mdl.DynGNNConfig,
                           opt_cfg: adamw.AdamWConfig):
    """Jitted per-snapshot step: reconstructed (edges, mask, values) ->
    Laplacian weights on device -> one-layer-stack forward over the
    length-1 timeline slice -> CE loss -> AdamW update."""
    n = cfg.num_nodes
    loop_edges = jnp.stack(
        [jnp.arange(n, dtype=jnp.int32)] * 2, axis=1)   # device-resident
    loop_ones = jnp.ones((n,), dtype=jnp.float32)

    @jax.jit
    def step(params, opt_state, carries, frame, edges, mask, values,
             labels, t_offset):
        e_full = jnp.concatenate([edges, loop_edges], axis=0)
        m_full = jnp.concatenate([mask, loop_ones], axis=0)
        v_full = jnp.concatenate([values, loop_ones], axis=0)
        w_full = segment.gcn_edge_weights(e_full, n, m_full, v_full)

        def loss_fn(p):
            z, new_carries = mdl.forward_slice(
                cfg, p, frame[None], e_full[None], w_full[None], carries,
                t_offset)
            logits = mdl.classify(p, z[0])
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, labels[..., None],
                                       axis=-1)[..., 0]
            return jnp.mean(nll), new_carries

        (loss, new_carries), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params2, opt2 = adamw.apply_updates(opt_cfg, params, grads,
                                            opt_state)
        return params2, opt2, new_carries, loss

    return step


def host_stream(snapshots, values, frames, labels, num_nodes: int,
                max_edges: int, block_size: int,
                stats: enc.DeltaStats | None = None):
    """Host iterator of (delta item, frame_t, labels_t) per step."""
    it = enc.iter_encode_stream(snapshots, values, num_nodes, max_edges,
                                block_size, stats)
    for t, item in enumerate(it):
        yield (item, np.asarray(frames[t]), np.asarray(labels[t]))


def default_max_edges(snapshots) -> int:
    return enc.padded_max_edges(snapshots)


def train_streamed(cfg: mdl.DynGNNConfig, snapshots, values, frames,
                   labels, *, block_size: int | None = None,
                   num_epochs: int = 1, overlap: bool = True,
                   prefetch_depth: int = 2,
                   opt_cfg: adamw.AdamWConfig | None = None,
                   params: dict | None = None, opt_state=None,
                   stats: enc.DeltaStats | None = None,
                   max_edges: int | None = None,
                   log_every: int = 10,
                   log_fn=None) -> StreamTrainState:
    """Stream the trace through per-snapshot training.

    Identical-loss guarantee: for fixed inputs the returned loss sequence
    does not depend on ``overlap`` / ``prefetch_depth`` — prefetching moves
    work between threads, never across the data dependency order.
    """
    t_steps = len(snapshots)
    block_size = block_size or max(t_steps // max(cfg.checkpoint_blocks, 1),
                                   1)
    max_edges = max_edges or default_max_edges(snapshots)
    if stats is None:
        stats = enc.measure_stats(snapshots, cfg.num_nodes, block_size,
                                  max_edges)
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10, total_steps=num_epochs * t_steps,
        weight_decay=0.0)
    if params is None:
        params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    if opt_state is None:
        opt_state = adamw.init_state(params)
    step_fn = make_stream_train_step(cfg, opt_cfg)
    mk_host = partial(host_stream, snapshots, values, frames, labels,
                      cfg.num_nodes, max_edges, block_size, stats)

    losses: list[float] = []
    for _ in range(num_epochs):
        if overlap:
            items = PrefetchIterator(mk_host(), depth=prefetch_depth)
        else:
            items = (stage_item(x) for x in mk_host())
        applier = DeltaApplier(max_edges)
        carries = mdl.init_carries(cfg, params)
        try:
            for t, (item, frame, lab) in enumerate(items):
                edges, mask, vals = applier.consume(item)
                params, opt_state, carries, loss = step_fn(
                    params, opt_state, carries, frame, edges, mask, vals,
                    lab, jnp.int32(t))
                losses.append(float(loss))
                if log_fn is not None and (len(losses) - 1) % log_every == 0:
                    log_fn(f"stream step {len(losses) - 1} "
                           f"loss {losses[-1]:.4f}")
        finally:
            # unblock + retire the prefetch worker if the step raised
            if isinstance(items, PrefetchIterator):
                items.close()
    return StreamTrainState(params=params, opt_state=opt_state,
                            losses=losses)
