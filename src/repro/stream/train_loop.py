"""Per-snapshot streaming training over the delta stream.

The regime the transfer pipeline exists for: snapshots arrive one delta at
a time, the device reconstructs the padded edge list (``apply_delta``),
recomputes the Laplacian weights from the reconstructed topology
(degree-derived — only index deltas + raw values cross the link, §5.5),
and runs one online train step per snapshot, threading the models'
temporal carries across steps.

Two drivers share every jitted computation and consume the items in the
same order, so their loss streams are BIT-IDENTICAL:

* ``overlap=False`` — the synchronous reference: encode, transfer, and
  compute strictly interleaved on one thread;
* ``overlap=True``  — encode + ``device_put`` run on the prefetch thread,
  ``depth`` deltas ahead of the compute stream.

The overlap path's win is measured in ``benchmarks/overlap_bench.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import models as mdl
from repro.graph import segment
from repro.optim import adamw
from repro.stream import encoder as enc
from repro.stream.prefetch import (DeltaApplier, PrefetchIterator,
                                   SlotStacker, stage_item)


@dataclass
class StreamTrainState:
    params: dict
    opt_state: dict
    losses: list


def advance_slice(cfg: mdl.DynGNNConfig, params: dict, carries: list,
                  frames, edges, mask, values,
                  t_offset) -> tuple[jax.Array, list]:
    """The STATE-ADVANCE step: one time-window of reconstructed snapshots
    rolls the temporal carries forward and yields the window's embeddings.

    frames (k, N, F), edges (k, E, 2), mask/values (k, E) -> (z (k, N, F'),
    new carries).  This is the forward math every consumer of the delta
    stream shares — the per-snapshot/slice TRAINING steps below wrap it in
    a loss + AdamW update, the online SERVING engine
    (``repro.serve.state.make_advance_step``) jits it alone with donated
    carries.  Keeping it single-sourced is what pins served scores to the
    offline training reference."""
    e_full, w_full = slice_weights_with_loops(
        cfg.num_nodes, *make_self_loops(cfg.num_nodes), edges, mask, values)
    return mdl.forward_slice(cfg, params, frames, e_full, w_full, carries,
                             t_offset)


def make_stream_train_step(cfg: mdl.DynGNNConfig,
                           opt_cfg: adamw.AdamWConfig):
    """Jitted per-snapshot step: reconstructed (edges, mask, values) ->
    Laplacian weights on device -> one-layer-stack forward over the
    length-1 timeline slice (``advance_slice``) -> CE loss -> AdamW
    update."""

    @jax.jit
    def step(params, opt_state, carries, frame, edges, mask, values,
             labels, t_offset):
        def loss_fn(p):
            z, new_carries = advance_slice(cfg, p, carries, frame[None],
                                           edges[None], mask[None],
                                           values[None], t_offset)
            return jnp.mean(slice_nll(p, z[0], labels)), new_carries

        (loss, new_carries), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params2, opt2 = adamw.apply_updates(opt_cfg, params, grads,
                                            opt_state)
        return params2, opt2, new_carries, loss

    return step


def make_self_loops(n: int) -> tuple[jax.Array, jax.Array]:
    """Device-resident self-loop edge list + unit mask/values for N nodes."""
    return (jnp.stack([jnp.arange(n, dtype=jnp.int32)] * 2, axis=1),
            jnp.ones((n,), dtype=jnp.float32))


def slice_weights_with_loops(n: int, loop_edges, loop_ones, edges, mask,
                             values) -> tuple[jax.Array, jax.Array]:
    """Append self-loops to a (k, E, 2) slice of reconstructed snapshots
    and recompute the per-step Laplacian weights on device.

    The ONE implementation of the streamed loss preamble — the
    single-device slice step and the sharded block step (where ``edges``
    is each shard's local time slice) both call it, so the <=1e-5 pinned
    equivalence can't drift apart edit by edit.
    """
    k = edges.shape[0]
    le = jnp.broadcast_to(loop_edges[None], (k,) + loop_edges.shape)
    lo = jnp.broadcast_to(loop_ones[None], (k,) + loop_ones.shape)
    e_full = jnp.concatenate([edges, le], axis=1)
    m_full = jnp.concatenate([mask, lo], axis=1)
    v_full = jnp.concatenate([values, lo], axis=1)
    w_full = jax.vmap(
        lambda e, m, v: segment.gcn_edge_weights(e, n, m, v))(
        e_full, m_full, v_full)
    return e_full, w_full


def slice_nll(params: dict, z, labels) -> jax.Array:
    """Per-(t, u) CE against the shared classifier (float32 softmax)."""
    logits = mdl.classify(params, z)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def make_stream_slice_step(cfg: mdl.DynGNNConfig,
                           opt_cfg: adamw.AdamWConfig):
    """Jitted multi-snapshot step over a contiguous timeline slice.

    Same math as ``make_stream_train_step`` generalized to ``k`` stacked
    reconstructed snapshots: per-step Laplacian weights on device, one
    ``forward_slice`` over the k-length timeline, mean CE, one AdamW
    update.  This is the single-device reference the snapshot-parallel
    distributed streamed trainer (``repro.stream.distributed``) must match:
    there the identical slice is computed with the time axis sharded and
    the temporal stage reached through two all-to-alls.
    """

    @jax.jit
    def step(params, opt_state, carries, frames, edges, mask, values,
             labels, t_offset):
        def loss_fn(p):
            z, new_carries = advance_slice(cfg, p, carries, frames, edges,
                                           mask, values, t_offset)
            return jnp.mean(slice_nll(p, z, labels)), new_carries

        (loss, new_carries), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params2, opt2 = adamw.apply_updates(opt_cfg, params, grads,
                                            opt_state)
        return params2, opt2, new_carries, loss

    return step


def host_stream(snapshots, values, frames, labels, num_nodes: int,
                max_edges: int, block_size: int,
                stats: enc.DeltaStats | None = None,
                report: enc.StreamReport | None = None):
    """Host iterator of (delta item, frame_t, labels_t) per step."""
    it = enc.iter_encode_stream(snapshots, values, num_nodes, max_edges,
                                block_size, stats, report=report)
    for t, item in enumerate(it):
        yield (item, np.asarray(frames[t]), np.asarray(labels[t]))


def default_max_edges(snapshots) -> int:
    return enc.padded_max_edges(snapshots)


def round_host_stream(step_iter, slice_len: int):
    """Group the per-step host stream into slices of ``slice_len``:
    yields (items tuple, frames (k, N, F), labels (k, N)) per round."""
    items, frs, labs = [], [], []
    for item, fr, lab in step_iter:
        items.append(item)
        frs.append(fr)
        labs.append(lab)
        if len(items) == slice_len:
            yield tuple(items), np.stack(frs), np.stack(labs)
            items, frs, labs = [], [], []
    if items:
        raise ValueError(f"trace length not divisible by slice_len="
                         f"{slice_len} ({len(items)} steps left over)")


def train_streamed(cfg: mdl.DynGNNConfig, snapshots, values, frames,
                   labels, *, block_size: int | None = None,
                   num_epochs: int = 1, overlap: bool = True,
                   prefetch_depth: int = 2,
                   opt_cfg: adamw.AdamWConfig | None = None,
                   params: dict | None = None, opt_state=None,
                   stats: enc.DeltaStats | None = None,
                   max_edges: int | None = None,
                   slice_len: int | None = None,
                   report: enc.StreamReport | None = None,
                   step_fn=None,
                   seed: int = 0,
                   log_every: int = 10,
                   log_fn=None) -> StreamTrainState:
    """Stream the trace through per-snapshot training.

    Identical-loss guarantee: for fixed inputs the returned loss sequence
    does not depend on ``overlap`` / ``prefetch_depth`` — prefetching moves
    work between threads, never across the data dependency order.

    ``slice_len`` > 1 switches to slice-granularity online updates: each
    round reconstructs ``slice_len`` consecutive snapshots from the delta
    stream and takes ONE AdamW step on their mean CE (the single-device
    reference semantics of the distributed streamed trainer, which shards
    exactly this slice over its mesh).  ``slice_len`` in (None, 1) keeps
    the per-snapshot schedule unchanged.

    ``step_fn`` lets callers that invoke this in a loop (the Engine's
    streamed worker, benchmark epochs) reuse one compiled step instead of
    re-tracing per call; it must come from ``make_stream_train_step``
    (or ``make_stream_slice_step`` when sliced) with matching
    (cfg, opt_cfg).
    """
    t_steps = len(snapshots)
    block_size = block_size or max(t_steps // max(cfg.checkpoint_blocks, 1),
                                   1)
    max_edges = max_edges or default_max_edges(snapshots)
    if stats is None:
        stats = enc.measure_stats(snapshots, cfg.num_nodes, block_size,
                                  max_edges)
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10, total_steps=num_epochs * t_steps,
        weight_decay=0.0)
    if params is None:
        params = mdl.init_params(jax.random.PRNGKey(seed), cfg)
    if opt_state is None:
        opt_state = adamw.init_state(params)
    sliced = slice_len is not None and slice_len > 1
    if step_fn is None:
        step_fn = (make_stream_slice_step(cfg, opt_cfg) if sliced
                   else make_stream_train_step(cfg, opt_cfg))
    mk_host = partial(host_stream, snapshots, values, frames, labels,
                      cfg.num_nodes, max_edges, block_size, stats, report)
    if sliced and t_steps % slice_len:
        raise ValueError(f"slice_len {slice_len} must divide the trace "
                         f"length {t_steps}")

    losses: list[float] = []
    for _ in range(num_epochs):
        host = round_host_stream(mk_host(), slice_len) if sliced \
            else mk_host()
        if overlap:
            items = PrefetchIterator(host, depth=prefetch_depth)
        else:
            items = (stage_item(x) for x in host)
        applier = DeltaApplier(max_edges)
        carries = mdl.init_carries(cfg, params)
        try:
            if sliced:
                stacker = SlotStacker(slice_len)
                for r, (slice_items, frame_b, lab_b) in enumerate(items):
                    for j, item in enumerate(slice_items):
                        edges, mask, vals = applier.consume(item)
                        stacker.put(j, edges, mask, vals)
                    e_b, m_b, v_b = stacker.arrays()
                    params, opt_state, carries, loss = step_fn(
                        params, opt_state, carries, frame_b, e_b, m_b,
                        v_b, lab_b, jnp.int32(r * slice_len))
                    losses.append(float(loss))
                    if log_fn is not None \
                            and (len(losses) - 1) % log_every == 0:
                        log_fn(f"stream slice {len(losses) - 1} "
                               f"loss {losses[-1]:.4f}")
            else:
                for t, (item, frame, lab) in enumerate(items):
                    edges, mask, vals = applier.consume(item)
                    params, opt_state, carries, loss = step_fn(
                        params, opt_state, carries, frame, edges, mask,
                        vals, lab, jnp.int32(t))
                    losses.append(float(loss))
                    if log_fn is not None \
                            and (len(losses) - 1) % log_every == 0:
                        log_fn(f"stream step {len(losses) - 1} "
                               f"loss {losses[-1]:.4f}")
        finally:
            # unblock + retire the prefetch worker if the step raised
            if isinstance(items, PrefetchIterator):
                items.close()
    return StreamTrainState(params=params, opt_state=opt_state,
                            losses=losses)
