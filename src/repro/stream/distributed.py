"""Distributed streamed training: per-shard delta streams under the
fixed-volume snapshot distribution (paper §3.2 x §4.2, composed).

This is where the two transfer subsystems finally meet the compute
distribution the paper benchmarks:

* ``stream/sharded.py`` cuts the delta stream into self-contained
  time-slice streams — shard s receives ONLY the deltas of the snapshots
  it owns (payload ~1/P per device);
* each shard feeds its own ``DeltaApplier`` edge-buffer ring, pinned to
  its device, reconstructing its slice of every round on device;
* the prefetch thread stages each shard's next round with its
  per-device / NamedSharding placement while the current round trains;
* one round = one checkpoint block of ``win`` snapshots: the jitted train
  step runs the snapshot-parallel ``shard_map``
  (``core.partition.snapshot_block_body``) over the assembled
  time-sharded arrays, so the GCN stage is communication-free and the
  temporal stage crosses shards through the paper's two fixed-volume
  all-to-alls per layer.

Loss semantics match ``train_loop.train_streamed(slice_len=win)`` exactly
(same slice, same mean CE, same AdamW cadence); the equivalence is pinned
to <= 1e-5 relative in ``tests/test_dist_stream.py``.

Two further schedule knobs pipeline the round itself (losses unchanged —
the pinned tests cover every combination; see docs/architecture.md for
the round diagram):

* ``a2a_chunks=C`` chunks each of the two per-layer redistributions into
  C feature-sliced all-to-alls (``partition.snapshot_block_body``), so
  chunk c's transfer can overlap chunk c-1's consumer compute;
* ``pipeline_rounds=True`` double-buffers the per-shard edge rings and
  keeps ONE round in flight: round r+1's delta-apply + staging is
  dispatched before round r's loss is forced to the host, so the
  reconstruction work runs concurrently with round r's temporal-stage
  collectives.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro import obs
from repro.compat import shard_map
from repro.core import models as mdl
from repro.core import partition
from repro.dist import compression as compression_lib
from repro.dist import sharding as shardlib
from repro.ft.straggler import StepTimer
from repro.optim import adamw
from repro.stream import encoder as enc
from repro.stream import sharded as stream_sharded
from repro.stream import train_loop as tl
from repro.stream.prefetch import (DeltaApplier, PrefetchIterator,
                                   SlotStacker, stage_item)

P = partition.P


@dataclass
class DistStreamState:
    params: dict
    opt_state: dict
    losses: list
    per_shard_bytes: list = field(default_factory=list)
    carries: object = None          # final temporal carries (mesh-sharded)
    step_timer: object = None       # the run's StepTimer (EWMA watchdog)


def make_dist_stream_step(cfg: mdl.DynGNNConfig, mesh,
                          opt_cfg: adamw.AdamWConfig, axis: str = "data",
                          a2a_chunks: int = 1,
                          num_seeds: int | None = None,
                          compression: str = "none"):
    """Jitted per-round step: time-sharded reconstructed snapshots ->
    Laplacian weights on each shard -> snapshot-parallel block body
    (2 all-to-alls per layer) -> replicated mean CE -> AdamW update.

    Carries thread across rounds OUTSIDE the shard_map: feature-RNN
    carries stay vertex-sharded on the mesh between calls (they live in
    the N-sharded domain the temporal stage runs in), EvolveGCN's weight
    carry stays replicated.

    ``a2a_chunks=C`` splits each redistribution into C feature-sliced
    all-to-alls (the §6.5 overlap schedule) — math-identical, so the
    loss stream is pinned to the C=1 reference.

    ``num_seeds`` is the sampled schedule's loss restriction
    (``repro.hoststore``): the vertex axis is then a round-local node
    TABLE whose first ``num_seeds`` lanes are the seed batch, and only
    those lanes carry loss (mean over seeds).  ``None`` (full-graph
    schedules) keeps the all-vertices mean.

    ``compression`` != "none" quantizes the redistributions to int8 with
    per-shard error feedback (``dist.compression``).  The step then takes
    the residual tree as a 4th argument (after carries, see
    ``init_comm_residuals``) and returns it updated:
    ``(params, opt_state, carries, comm_res, loss)``.  With "none" the
    signature and jaxpr are exactly today's — bit-identical losses.
    """
    if a2a_chunks < 1:
        raise ValueError(f"a2a_chunks must be >= 1, got {a2a_chunks}")
    compression_lib.validate_mode(compression)
    num_procs = mesh.shape[axis]
    n = cfg.num_nodes
    if n % num_procs:
        raise ValueError(f"num_nodes {n} must divide over {num_procs} "
                         f"snapshot shards (vertex-sharded temporal stage)")
    if num_seeds is not None and not 1 <= num_seeds <= n:
        raise ValueError(f"num_seeds {num_seeds} must lie in [1, {n}]")
    loop_edges, loop_ones = tl.make_self_loops(n)
    carry_specs = shardlib.stream_carry_specs(cfg, axis)
    b = shardlib.stream_batch_specs(axis)

    def _loss_tail(nll, bsl):
        if num_seeds is None:
            total = jax.lax.psum(jnp.sum(nll), axis)
            count = jnp.asarray(bsl * num_procs * n, jnp.float32)
        else:
            seed_mask = (jnp.arange(n) < num_seeds).astype(nll.dtype)
            total = jax.lax.psum(jnp.sum(nll * seed_mask[None, :]), axis)
            count = jnp.asarray(bsl * num_procs * num_seeds, jnp.float32)
        return total / count

    if compression == "none":
        def sharded_loss(params, carries, frames, edges, mask, values,
                         labels, t0):
            # local: frames (win/P, N, F); edges (win/P, E, 2);
            # labels (win/P, N)
            bsl = frames.shape[0]
            # same preamble as the single-device slice step, on the local
            # slice (per-snapshot Laplacian weights: no collectives)
            e_full, w_full = tl.slice_weights_with_loops(
                n, loop_edges, loop_ones, edges, mask, values)
            new_carries, h = partition.snapshot_block_body(
                cfg, params, axis, num_procs, carries,
                (frames, e_full, w_full, t0), a2a_chunks=a2a_chunks)
            nll = tl.slice_nll(params, h, labels)
            return _loss_tail(nll, bsl), new_carries

        loss_fn = shard_map(
            sharded_loss, mesh=mesh,
            in_specs=(P(), carry_specs, b["frames"], b["edges"], b["mask"],
                      b["values"], b["labels"], P()),
            out_specs=(P(), carry_specs),
            check_vma=False)

        @jax.jit
        def step(params, opt_state, carries, frames, edges, mask, values,
                 labels, t0):
            (loss, new_carries), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, carries, frames, edges, mask,
                                       values, labels, t0)
            params2, opt2 = adamw.apply_updates(opt_cfg, params, grads,
                                                opt_state)
            return params2, opt2, new_carries, loss

        return step

    res_specs = shardlib.stream_comm_residual_specs(cfg, axis)

    def sharded_loss_q(params, carries, comm_res, frames, edges, mask,
                       values, labels, t0):
        bsl = frames.shape[0]
        e_full, w_full = tl.slice_weights_with_loops(
            n, loop_edges, loop_ones, edges, mask, values)
        new_carries, h, new_res = partition.snapshot_block_body(
            cfg, params, axis, num_procs, carries,
            (frames, e_full, w_full, t0), a2a_chunks=a2a_chunks,
            compression=compression, comm_residuals=comm_res)
        nll = tl.slice_nll(params, h, labels)
        # new_res rides the aux output: value_and_grad gives it a zero
        # cotangent, matching the non-differentiable residual carry.
        return _loss_tail(nll, bsl), (new_carries, new_res)

    loss_fn = shard_map(
        sharded_loss_q, mesh=mesh,
        in_specs=(P(), carry_specs, res_specs, b["frames"], b["edges"],
                  b["mask"], b["values"], b["labels"], P()),
        out_specs=(P(), (carry_specs, res_specs)),
        check_vma=False)

    @jax.jit
    def step(params, opt_state, carries, comm_res, frames, edges, mask,
             values, labels, t0):
        (loss, (new_carries, new_res)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, carries, comm_res, frames,
                                   edges, mask, values, labels, t0)
        params2, opt2 = adamw.apply_updates(opt_cfg, params, grads,
                                            opt_state)
        return params2, opt2, new_carries, new_res, loss

    return step


def init_sharded_carries(cfg: mdl.DynGNNConfig, params: dict, mesh,
                         axis: str = "data"):
    """Zero carries (full N) placed with their stream shardings."""
    carries = mdl.init_carries(cfg, params)
    shardings = shardlib.named(mesh, shardlib.stream_carry_specs(cfg, axis))
    return jax.tree.map(jax.device_put, carries, shardings)


def init_comm_residuals(cfg: mdl.DynGNNConfig, win: int, mesh,
                        axis: str = "data"):
    """Zero error-feedback residuals for the quantized redistributions,
    placed with their stream shardings: one ``(res_t2n, res_n2t)`` pair
    per layer in the PRE-all-to-all layouts (empty for EvolveGCN)."""
    res = [(jnp.zeros((win, cfg.num_nodes, f1), jnp.float32),
            jnp.zeros((win, cfg.num_nodes, f2), jnp.float32))
           for f1, f2 in partition.a2a_payload_dims(cfg)]
    shardings = shardlib.named(
        mesh, shardlib.stream_comm_residual_specs(cfg, axis))
    return jax.tree.map(jax.device_put, res, shardings)


def lowered_step_hlo(cfg: mdl.DynGNNConfig, mesh, *, win: int,
                     max_edges: int, axis: str = "data",
                     a2a_chunks: int = 1, compression: str = "none",
                     opt_cfg: adamw.AdamWConfig | None = None) -> str:
    """Compiled HLO text of one round step over zero-valued inputs.

    Shared by the structural byte-accounting tests and
    ``benchmarks/scaling_bench.compressed_round`` so both measure the
    SAME lowering (``dist.comm_volume.hlo_collective_bytes`` parses it).
    """
    opt_cfg = opt_cfg or adamw.AdamWConfig(lr=1e-2, warmup_steps=1,
                                           total_steps=1)
    step = make_dist_stream_step(cfg, mesh, opt_cfg, axis,
                                 a2a_chunks=a2a_chunks,
                                 compression=compression)
    # shape-only trace: the key never reaches training
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)  # dynlint: allow[prng]
    opt_state = adamw.init_state(params)
    carries = init_sharded_carries(cfg, params, mesh, axis)
    n = cfg.num_nodes
    args = [params, opt_state, carries]
    if compression != "none":
        args.append(init_comm_residuals(cfg, win, mesh, axis))
    args += [jnp.zeros((win, n, cfg.feat_in)),
             jnp.zeros((win, max_edges, 2), jnp.int32),
             jnp.zeros((win, max_edges)), jnp.zeros((win, max_edges)),
             jnp.zeros((win, n), jnp.int32), jnp.int32(0)]
    return step.lower(*args).compile().as_text()


def dist_round_stream(shard_streams, frames, labels, win: int, bsl: int,
                      start_round: int = 0):
    """Host iterator of one round's payloads: (per-shard delta items,
    frames (win, N, F), labels (win, N)).

    ``start_round`` resumes mid-epoch: the given ``shard_streams`` begin
    at that round's checkpoint-block boundary (see
    ``sharded.encode_time_sliced(start_step=...)``), while frames/labels
    stay globally indexed.
    """
    num_shards = len(shard_streams)
    rounds = len(shard_streams[0]) // bsl
    for r in range(rounds):
        items = tuple(
            tuple(shard_streams[s][r * bsl + j] for j in range(bsl))
            for s in range(num_shards))
        t0 = (start_round + r) * win
        yield (items, np.asarray(frames[t0:t0 + win]),
               np.asarray(labels[t0:t0 + win]))


def make_round_stage_fn(mesh, axis: str = "data"):
    """Round staging for the prefetch thread: each shard's delta items go
    to that shard's device; frames/labels ship with their time-sharded
    ``NamedSharding`` placements directly."""
    devices = shardlib.shard_devices(mesh, axis)
    b = shardlib.stream_batch_specs(axis)
    fr_sh = NamedSharding(mesh, b["frames"])
    lab_sh = NamedSharding(mesh, b["labels"])

    def stage(round_item):
        items, fr, lab = round_item
        staged = tuple(
            tuple(stage_item(it, devices[s]) for it in shard_items)
            for s, shard_items in enumerate(items))
        return staged, jax.device_put(fr, fr_sh), jax.device_put(lab,
                                                                 lab_sh)

    return stage


def consume_round(items, appliers, stackers):
    """Drive one round's staged per-shard delta items through the shard
    rings: ``appliers[s]`` applies shard s's deltas, ``stackers[s]``
    copies each reconstructed slot out of the donated ring.  Returns the
    per-shard ``(edges, mask, values)`` blocks, dispatch-only (nothing
    blocks on device execution).

    This is THE per-round reconstruction protocol — the trainer below
    and the benchmarks that time the transfer phase
    (``benchmarks/overlap_bench.pipelined_round``,
    ``benchmarks/scaling_bench._round_transfer_time``) all call it, so
    the measured phase can never drift from what the trainer overlaps.
    """
    blocks = []
    for s, shard_items in enumerate(items):
        for j, item in enumerate(shard_items):
            e, m, v = appliers[s].consume(item)
            stackers[s].put(j, e, m, v)
        blocks.append(stackers[s].arrays())
    return blocks


def _assemble(mesh, spec, shard_blocks, global_shape):
    """Per-shard device blocks -> one global time-sharded jax.Array
    (zero host round-trip: the blocks already live on their devices)."""
    return jax.make_array_from_single_device_arrays(
        global_shape, NamedSharding(mesh, spec), list(shard_blocks))


def _dist_phase_probe(cfg, opt_cfg, params, opt_state, fr_g, assembled,
                      lab_g, t0) -> tuple[float, float]:
    """One-time comp-reference measurement for derived phase spans.

    The round step is one fused jit, so the spatial / a2a / temporal
    phases cannot be fenced individually inside it.  Mirror the
    methodology of ``benchmarks/overlap_bench.pipelined_round``: compile
    the SAME step on a single-shard mesh (where the two all-to-alls
    degenerate to local copies) and time it on this round's actual data
    — that is the round's communication-free compute reference.  Per
    round, ``a2a = step - comp_ref`` and the remaining compute splits
    between the spatial and temporal stages by their analytic flop
    ratio (the same split the overlap benchmark feeds
    ``round_time_model``).  Returns ``(comp_ref_s, f_spatial)``.
    """
    from repro.launch.mesh import make_host_mesh
    mesh1 = make_host_mesh(data=1)
    step1 = make_dist_stream_step(cfg, mesh1, opt_cfg)
    host = [np.asarray(x) for x in (fr_g, *assembled, lab_g)]
    params_h = jax.tree.map(np.asarray, params)
    opt_h = jax.tree.map(np.asarray, opt_state)
    carries1 = init_sharded_carries(cfg, params_h, mesh1)
    trc = obs.get_tracer()

    def run():
        out = step1(params_h, opt_h, carries1, *host, jnp.int32(t0))
        jax.block_until_ready(out[-1])

    run()                                        # compile + warm
    best = None
    for _ in range(2):
        with trc.stopwatch("round.probe", cat="probe") as sw:
            run()
        best = sw.seconds if best is None else min(best, sw.seconds)
    mask = np.asarray(assembled[1])
    e_mean = float(mask.sum()) / mask.shape[0]
    feat = cfg.hidden
    fl_spatial = 2 * e_mean * 2 * feat + 2 * cfg.num_nodes * feat * feat
    fl_temporal = 2 * cfg.window * cfg.num_nodes * feat * feat
    f_sp = fl_spatial / (fl_spatial + fl_temporal)
    return best, f_sp


def _emit_phase_spans(trc, gr: int, step_span, comp_ref: float,
                      f_sp: float) -> None:
    """Derived spatial/a2a/temporal child spans inside one measured
    ``round.step`` span (marked ``derived`` — see docs/observability.md)."""
    step_s = step_span.dur_s
    a2a_s = max(step_s - comp_ref, 0.0)
    comp_s = step_s - a2a_s
    sp_s = f_sp * comp_s
    t0 = step_span.start_s
    trc.add_span("round.spatial", t0, sp_s, cat="phase.derived",
                 round=gr, derived=True)
    trc.add_span("round.a2a", t0 + sp_s, a2a_s, cat="phase.derived",
                 round=gr, derived=True)
    trc.add_span("round.temporal", t0 + sp_s + a2a_s, comp_s - sp_s,
                 cat="phase.derived", round=gr, derived=True)


def train_distributed_streamed(cfg: mdl.DynGNNConfig, snapshots, values,
                               frames, labels, *, mesh, axis: str = "data",
                               block_size: int | None = None,
                               num_epochs: int = 1, overlap: bool = True,
                               prefetch_depth: int = 2,
                               a2a_chunks: int = 1,
                               pipeline_rounds: bool = False,
                               compression: str = "none",
                               opt_cfg: adamw.AdamWConfig | None = None,
                               params: dict | None = None, opt_state=None,
                               stats: enc.DeltaStats | None = None,
                               max_edges: int | None = None,
                               step_fn=None, shard_streams=None,
                               start_round: int = 0, carries=None,
                               stop_fn=None, seed: int = 0,
                               log_every: int = 10,
                               log_fn=None,
                               step_timer: StepTimer | None = None
                               ) -> DistStreamState:
    """Stream the trace through snapshot-parallel distributed training.

    One round per checkpoint block (``win = block_size`` snapshots): shard
    s receives only its ``win/P`` owned deltas (1/P transfer volume),
    reconstructs them into its slice of the time-sharded block, and the
    round's single train step crosses shards exclusively through the two
    fixed-volume all-to-alls per layer.  ``overlap=True`` stages round
    r+1's per-shard deltas while round r trains; both schedules produce
    identical losses.

    ``a2a_chunks`` / ``pipeline_rounds`` are the chunked-round pipelining
    knobs (see the module docstring): pure schedule changes whose loss
    streams are pinned to the serial (C=1, unpipelined) reference.  With
    ``pipeline_rounds=True`` each shard alternates between two
    ``DeltaApplier`` rings, so round r+1's delta-applies never wait on
    the retirement of buffers round r's assembly still reads, and the
    host forces round r's loss only after round r+1 is fully dispatched.

    ``step_fn`` / ``shard_streams`` let callers that invoke this in a loop
    (benchmark epochs, repeated timing runs) reuse one compiled step and
    one encoded stream set instead of re-tracing and re-encoding per call;
    both must come from ``make_dist_stream_step`` /
    ``sharded.encode_time_sliced`` with matching (cfg, mesh, block,
    a2a_chunks) args.

    ``compression`` ("none" | "int8_a2a" | "int8_all") turns on int8
    error-feedback quantization of the per-layer all-to-alls; "int8_all"
    additionally encodes the per-shard delta streams on the narrow
    ``stream.wire`` format (quantized edge values + int16 indices where
    num_nodes/max_edges allow).  "none" is bit-identical to the
    uncompressed trainer; the compressed loss streams are drift-bounded
    by ``tests/test_compression_drift.py``.  A caller-provided
    ``step_fn``/``shard_streams`` must have been built with the same
    compression mode.

    ``start_round`` / ``carries`` / ``stop_fn`` are the resumable-from-
    block entry the elastic rescale subsystem (``repro.elastic``) drives
    segments through: run the rounds of ONE epoch from checkpoint-block
    boundary ``start_round`` with explicit initial ``carries`` (None =
    fresh zeros, the epoch-start semantics), and stop cleanly at the
    next boundary when ``stop_fn(global_round)`` returns True (SIGTERM,
    scheduled resize).  The final carries ride back on
    ``DistStreamState.carries`` so the caller can re-shard them onto a
    different mesh and continue — these knobs never change the losses of
    the rounds that do run.

    Every round is observed through ``repro.obs`` (one wall-clock
    ``round`` stopwatch per round feeding the ``step_timer`` EWMA
    watchdog — pass one to share it across elastic segments).  When the
    global tracer is enabled the loop additionally records fenced
    ``round.transfer`` / ``round.step`` spans plus the derived
    spatial/a2a/temporal phase spans from the one-time comp-reference
    probe (``_dist_phase_probe``); fencing serializes the schedule, so
    traced runs measure the serial round (docs/observability.md).
    """
    t_steps = len(snapshots)
    num_procs = mesh.shape[axis]
    compression_lib.validate_mode(compression)
    use_comp = compression_lib.compresses_a2a(compression)
    win = block_size or max(t_steps // max(cfg.checkpoint_blocks, 1), 1)
    if win % num_procs:
        raise ValueError(f"block_size {win} must divide into {num_procs} "
                         "shards")
    if t_steps % win:
        raise ValueError(f"trace length {t_steps} must be a multiple of "
                         f"block_size {win}")
    if (start_round or carries is not None) and num_epochs != 1:
        raise ValueError(
            "start_round/carries resume one epoch segment; run with "
            "num_epochs=1 and loop epochs in the caller (repro.elastic)")
    bsl = win // num_procs
    max_edges = max_edges or tl.default_max_edges(snapshots)
    if stats is None and shard_streams is None:
        stats = enc.measure_stats(snapshots, cfg.num_nodes, win, max_edges)
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10, total_steps=num_epochs * t_steps,
        weight_decay=0.0)
    if params is None:
        params = mdl.init_params(jax.random.PRNGKey(seed), cfg)
    if opt_state is None:
        opt_state = adamw.init_state(params)

    # Per-shard self-contained time-slice streams (encoded once, replayed
    # every epoch): shard s's stream opens each round with a FullSnapshot
    # (slice boundary — it holds nothing to diff against) and deltas after.
    if shard_streams is None:
        shard_streams = stream_sharded.encode_time_sliced(
            snapshots, values, cfg.num_nodes, max_edges, win, num_procs,
            stats, start_step=start_round * win,
            wire=compression_lib.wire_mode(compression))
    per_shard_bytes = [sum(i.payload_bytes for i in s)
                       for s in shard_streams]

    devices = shardlib.shard_devices(mesh, axis)
    b = shardlib.stream_batch_specs(axis)
    if step_fn is None:
        step_fn = make_dist_stream_step(cfg, mesh, opt_cfg, axis,
                                        a2a_chunks=a2a_chunks,
                                        compression=compression)
    stage_fn = make_round_stage_fn(mesh, axis)
    e_pad = max_edges
    # pipeline_rounds double-buffers the per-shard rings: round r uses
    # buffer r%2, so round r+1's delta-applies (and their donations) are
    # fully independent of the ring round r's assembly was built from.
    nbuf = 2 if pipeline_rounds else 1

    def reconstruct_round(r, items, appliers, stackers):
        """Per-shard delta-apply + slot stacking -> assembled global
        (edges, mask, values) for one round, on round r's ring buffer."""
        buf = r % nbuf
        blocks = consume_round(items, [a[buf] for a in appliers],
                               [st[buf] for st in stackers])
        return (_assemble(mesh, b["edges"], (e for e, _, _ in blocks),
                          (win, e_pad, 2)),
                _assemble(mesh, b["mask"], (m for _, m, _ in blocks),
                          (win, e_pad)),
                _assemble(mesh, b["values"], (v for _, _, v in blocks),
                          (win, e_pad)))

    def emit(loss_value):
        losses.append(float(loss_value))
        if log_fn is not None and (len(losses) - 1) % log_every == 0:
            log_fn(f"dist stream round {len(losses) - 1} "
                   f"loss {losses[-1]:.4f} "
                   f"(P={num_procs}, win={win}, C={a2a_chunks}, "
                   f"pipelined={pipeline_rounds})")

    losses: list[float] = []
    initial_carries = carries
    stopped = False
    timer = step_timer if step_timer is not None else StepTimer()
    trc = obs.get_tracer()
    # derived phase spans need fenced (execution-timed) measurements and
    # the comp-reference probe; both are opt-in via the tracer config
    derive_phases = trc.enabled and trc.phases and trc.fencing
    probe: tuple[float, float] | None = None      # (comp_ref_s, f_spatial)
    obs.inc("stream.payload_bytes", sum(per_shard_bytes))
    # span round index: monotonic across epochs (the model-time index
    # ``gr`` deliberately restarts each epoch, which would collide trace
    # rounds and calibration keys)
    ridx = start_round
    for _ in range(num_epochs):
        host = dist_round_stream(shard_streams, frames, labels, win, bsl,
                                 start_round=start_round)
        if overlap:
            rounds = PrefetchIterator(host, stage_fn=stage_fn,
                                      depth=prefetch_depth)
        else:
            rounds = (stage_fn(x) for x in host)
        appliers = [[DeltaApplier(e_pad, device=d) for _ in range(nbuf)]
                    for d in devices]
        stackers = [[SlotStacker(bsl) for _ in range(nbuf)]
                    for _ in devices]
        carries = (initial_carries if initial_carries is not None
                   else init_sharded_carries(cfg, params, mesh, axis))
        initial_carries = None           # later epochs start fresh
        # error-feedback residuals restart at zero with the carries: they
        # are an optimization state of the quantizer, not model state
        comm_res = (init_comm_residuals(cfg, win, mesh, axis)
                    if use_comp else None)
        in_flight = None        # round r-1's device loss (pipeline_rounds)
        try:
            for r, (items, fr_g, lab_g) in enumerate(rounds):
                gr = start_round + r
                with trc.stopwatch("round", cat="round", round=ridx,
                                   p=num_procs, win=win) as round_sw:
                    with trc.span("round.transfer", round=ridx) as tr_sp:
                        assembled = reconstruct_round(r, items, appliers,
                                                      stackers)
                        tr_sp.fence(assembled)
                    with trc.span("round.step", round=ridx) as st_sp:
                        if use_comp:
                            params, opt_state, carries, comm_res, loss = \
                                step_fn(params, opt_state, carries,
                                        comm_res, fr_g, *assembled, lab_g,
                                        jnp.int32(gr * win))
                        else:
                            params, opt_state, carries, loss = step_fn(
                                params, opt_state, carries, fr_g,
                                *assembled, lab_g, jnp.int32(gr * win))
                        st_sp.fence(loss)
                    if pipeline_rounds:
                        # force the PREVIOUS round only now: round r's
                        # delta-applies and step are already dispatched,
                        # so they execute while the host blocks on loss
                        # r-1.
                        if in_flight is not None:
                            emit(in_flight)
                        in_flight = loss
                    else:
                        emit(loss)
                obs.inc("stream.rounds")
                timer.observe(round_sw.seconds)  # counts straggler.flags
                if derive_phases:
                    if probe is None:
                        probe = _dist_phase_probe(
                            cfg, opt_cfg, params, opt_state, fr_g,
                            assembled, lab_g, gr * win)
                    _emit_phase_spans(trc, ridx, st_sp, *probe)
                ridx += 1
                if stop_fn is not None and stop_fn(gr):
                    stopped = True
                    break
            if in_flight is not None:   # drain the pipelined epoch tail
                emit(in_flight)
        finally:
            if isinstance(rounds, PrefetchIterator):
                rounds.close()
        if stopped:
            break
    return DistStreamState(params=params, opt_state=opt_state,
                           losses=losses, per_shard_bytes=per_shard_bytes,
                           carries=carries, step_timer=timer)
