"""Compact host->device wire formats for the delta stream.

A ``SnapshotDelta`` ships f32 drop/add masks, int32 indices, and an f32
value lane per device slot — conservative widths for payloads that are
churn-sized and low-precision by nature.  :func:`quantize_delta` narrows
the delta to the int8/int16 wire:

* drop positions index the previous device edge list — int16 when
  ``max_edges`` fits, int32 otherwise;
* added edges carry node ids — int16 when ``num_nodes`` fits;
* drop/add masks are 0/1 — int8;
* edge values are absmax-int8 quantized with ONE f32 scale per delta
  (the only lossy lane; traces with unit weights quantize exactly since
  ``127/127 * absmax == absmax``).

``FullSnapshot`` items (block boundaries and churn-overflow resyncs) are
deliberately left on the f32 format: they are the lossless escape hatch
that re-bases the device state, so wire drift can never compound across
block boundaries.  The device-side decode (widen + apply) lives in
``stream.prefetch``; byte accounting in ``dist.comm_volume``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.graphdiff import SnapshotDelta

WIRE_MODES = ("none", "int8")

_QMAX = 127.0
_INT16_MAX = 32767


def validate_wire(wire: str) -> str:
    if wire not in WIRE_MODES:
        raise ValueError(f"wire must be one of {WIRE_MODES}, got {wire!r}")
    return wire


def index_dtype(max_index: int) -> np.dtype:
    """Narrowest signed integer dtype holding indices up to
    ``max_index`` inclusive."""
    return np.dtype(np.int16 if max_index <= _INT16_MAX else np.int32)


def quantize_values(v: np.ndarray) -> tuple[np.ndarray, np.float32]:
    """Host-side absmax int8 quantization: ``v ~= q * scale``.

    Mirrors ``dist.compression.quantize``: the scale is clamped to
    [tiny, finfo.max] so all-zero lanes stay zero and ±inf saturates.
    """
    v32 = np.asarray(v, dtype=np.float32)
    absmax = float(np.max(np.abs(v32))) if v32.size else 0.0
    scale = np.float32(np.clip(absmax / _QMAX,
                               np.finfo(np.float32).tiny,
                               np.finfo(np.float32).max))
    q = np.clip(np.rint(v32 / scale), -_QMAX, _QMAX).astype(np.int8)
    return q, scale


def dequantize_values(q: np.ndarray, scale) -> np.ndarray:
    return q.astype(np.float32) * np.float32(scale)


@dataclass
class QuantizedDelta:
    """A ``SnapshotDelta`` on the narrow wire (same pad lengths, same
    decode semantics after widening — see ``prefetch.DeltaApplier``)."""
    drop_pos: np.ndarray      # (drop_pad,) int16/int32 device positions
    drop_mask: np.ndarray     # (drop_pad,) int8 0/1
    add_edges: np.ndarray     # (add_pad, 2) int16/int32 node ids
    add_mask: np.ndarray      # (add_pad,) int8 0/1
    values_q: np.ndarray      # (max_edges,) int8
    values_scale: np.float32  # one scale per delta
    num_edges: int

    @property
    def payload_bytes(self) -> int:
        """Valid-lane wire bytes, same counting convention as
        ``SnapshotDelta.payload_bytes`` (d*4 + a*8 + E*4 there):
        narrowed indices, one byte per valid value, one f32 scale."""
        d = int(np.sum(self.drop_mask))
        a = int(np.sum(self.add_mask))
        return (d * self.drop_pos.dtype.itemsize
                + a * 2 * self.add_edges.dtype.itemsize
                + self.num_edges * 1 + 4)


def quantize_delta(delta: SnapshotDelta, num_nodes: int,
                   max_edges: int) -> QuantizedDelta:
    """Narrow one delta to the int8/int16 wire format."""
    q, scale = quantize_values(delta.values)
    return QuantizedDelta(
        drop_pos=np.asarray(delta.drop_pos,
                            dtype=index_dtype(max_edges - 1)),
        drop_mask=np.asarray(delta.drop_mask, dtype=np.int8),
        add_edges=np.asarray(delta.add_edges,
                             dtype=index_dtype(num_nodes - 1)),
        add_mask=np.asarray(delta.add_mask, dtype=np.int8),
        values_q=q, values_scale=scale,
        num_edges=delta.num_edges)
