"""Streamed graph-diff snapshot transfer (paper §3.2, made asynchronous).

The subsystem has three pieces:

* ``encoder``    — vectorized host delta encoder (searchsorted key
  alignment; drop/add pads sized from dataset statistics, not E_max);
* ``prefetch``   — background-thread encode + ``jax.device_put`` lookahead
  overlapping delta k+1's transfer with step k's compute, and the
  device-resident edge-buffer ring the deltas are applied into;
* ``sharded``    — per-shard time-slice streams for snapshot partitioning;
* ``distributed``— the composition: per-shard streams feeding per-device
  edge-buffer rings under the snapshot-parallel shard_map train step
  (2 fixed-volume all-to-alls per layer, GCN stage communication-free).

``core.graphdiff`` keeps the synchronous reference encoder/decoder the
tests diff against; ``train_loop`` drives per-snapshot streaming training
through both the synchronous and the overlapped path (identical math) and
the slice-granularity single-device reference the distributed trainer is
pinned against.
"""

from repro.stream.encoder import (ChurnOverflowError, DeltaStats,
                                  StreamReport, encode_stream_fast,
                                  iter_encode_stream, measure_stats,
                                  padded_max_edges)
from repro.stream.prefetch import (DeltaApplier, PrefetchIterator,
                                   SlotStacker)
from repro.stream.sharded import encode_time_sliced, shard_slice_steps

__all__ = [
    "ChurnOverflowError", "DeltaStats", "StreamReport",
    "encode_stream_fast", "iter_encode_stream", "measure_stats",
    "padded_max_edges", "DeltaApplier", "PrefetchIterator", "SlotStacker",
    "encode_time_sliced", "shard_slice_steps",
]
