"""Asynchronous host->device delta streaming.

``PrefetchIterator`` runs the host encoder on a background thread and
issues ``jax.device_put`` there too, keeping up to ``depth`` staged items
ahead of the consumer: while the device executes ``apply_delta`` + the
train step for delta k, delta k+1 is being encoded and transferred.  The
numpy encode and the device execution overlap because both release the
GIL for their heavy parts.

``DeltaApplier`` owns the device-resident edge-buffer ring: ``apply_delta``
is jitted with donated input buffers, so the reconstructed snapshot is
written into the slot of the buffer being retired rather than a fresh
allocation — the stream runs in O(ring) device memory regardless of T.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterable, Iterator

import jax
import jax.numpy as jnp

from repro import obs, sanitize
from repro.core import graphdiff
from repro.core.graphdiff import FullSnapshot, SnapshotDelta
from repro.stream.wire import QuantizedDelta

_SENTINEL = object()


class PrefetchIterator:
    """Stage items of ``host_iter`` on a background thread.

    ``stage_fn`` (default ``jax.device_put``-based staging of stream items)
    runs on the worker; the bounded queue applies backpressure so at most
    ``depth`` staged items exist at once.  Exceptions on the worker are
    re-raised at the consumer's next ``__next__``; the iterator stays
    terminated (StopIteration) afterwards.  ``close()`` (also via the
    context-manager protocol) unblocks and retires the worker when the
    consumer abandons the stream early, releasing the staged buffers.
    """

    # _err is written by the worker and read by the consumer WITHOUT a
    # lock: the write happens-before the sentinel put, and the consumer
    # reads it only after get() returned that sentinel — the queue's
    # internal lock is the synchronization edge (dynlint: locks pass).
    _thread_owned = ("_err",)

    def __init__(self, host_iter: Iterable, stage_fn: Callable | None = None,
                 depth: int = 2):
        if depth < 1:
            raise ValueError("prefetch depth must be >= 1")
        self._stage = stage_fn if stage_fn is not None else stage_item
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None
        self._stop = threading.Event()
        self._done = False
        self._thread = threading.Thread(
            target=self._worker, args=(iter(host_iter),), daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that still observes close(); False = shut down."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self, it: Iterator) -> None:
        trc = obs.get_tracer()
        try:
            for item in it:
                if self._stop.is_set():
                    return
                # staging span lives on the worker thread's trace track,
                # so overlap with the consumer's round spans is visible
                with trc.span("prefetch.stage", cat="prefetch"):
                    staged = self._stage(item)
                obs.inc("prefetch.items")
                if not self._put(staged):
                    return
        except BaseException as e:  # noqa: BLE001 — re-raised on consumer
            self._err = e
        finally:
            self._put(_SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        with obs.span("prefetch.wait", cat="prefetch"):
            item = self._q.get()
        if item is _SENTINEL:
            self._done = True
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self) -> None:
        """Retire the worker and drop staged items (idempotent)."""
        self._stop.set()
        self._done = True
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=1.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def stage_item(item: Any, device=None) -> Any:
    """Ship one stream item's arrays to device (tuples recurse).

    ``device`` may be a concrete ``jax.Device`` (per-shard staging: the
    distributed streamed trainer pins each shard's delta to its own device)
    or a ``Sharding`` — anything ``jax.device_put`` accepts.  ``None`` keeps
    the single-device default placement.
    """
    put = (jax.device_put if device is None
           else (lambda x: jax.device_put(x, device)))
    if isinstance(item, tuple):
        return tuple(stage_item(x, device) for x in item)
    if isinstance(item, FullSnapshot):
        return FullSnapshot(edges=put(item.edges),
                            mask=put(item.mask),
                            values=put(item.values),
                            num_edges=item.num_edges)
    if isinstance(item, SnapshotDelta):
        return SnapshotDelta(drop_pos=put(item.drop_pos),
                             drop_mask=put(item.drop_mask),
                             add_edges=put(item.add_edges),
                             add_mask=put(item.add_mask),
                             values=put(item.values),
                             num_edges=item.num_edges)
    if isinstance(item, QuantizedDelta):
        # the narrow dtypes cross the host->device link as-is; widening
        # happens on device inside the decode jit (DeltaApplier)
        return QuantizedDelta(drop_pos=put(item.drop_pos),
                              drop_mask=put(item.drop_mask),
                              add_edges=put(item.add_edges),
                              add_mask=put(item.add_mask),
                              values_q=put(item.values_q),
                              values_scale=item.values_scale,
                              num_edges=item.num_edges)
    return put(item)


# One jitted apply_delta per donation mode, SHARED by every DeltaApplier:
# a fresh jax.jit wrapper per ring would re-trace/re-compile per instance,
# which the distributed trainer would pay P (double-buffered: 2P) times
# per epoch.  Device placement still follows the committed inputs.
_APPLY_DONATING = jax.jit(graphdiff.apply_delta, donate_argnums=(0, 1))
_APPLY_PLAIN = jax.jit(graphdiff.apply_delta)


def _decode_apply(prev_edges, prev_mask, drop_pos, drop_mask, add_edges,
                  add_mask):
    """Widen a QuantizedDelta's narrow wire dtypes on device, then apply
    — one fused jit so the decode costs no extra device round."""
    return graphdiff.apply_delta(
        prev_edges, prev_mask, drop_pos.astype(jnp.int32),
        drop_mask.astype(jnp.float32), add_edges.astype(jnp.int32),
        add_mask.astype(jnp.float32))


_DECODE_DONATING = jax.jit(_decode_apply, donate_argnums=(0, 1))
_DECODE_PLAIN = jax.jit(_decode_apply)
# scale rides as an ARRAY argument: a python-float scale would bake a new
# constant (and a recompile) into the jit per delta
_DEQUANT = jax.jit(lambda q, scale: q.astype(jnp.float32) * scale)


class DeltaApplier:
    """Device-resident (edges, mask) buffer ring.

    ``consume`` turns a staged stream item into the current snapshot's
    device buffers: full snapshots swap in directly; deltas run the jitted
    ``apply_delta`` with the previous buffers DONATED, so XLA writes the
    new snapshot into the retiring slot (a 2-deep ring realized through
    input/output aliasing — no per-step allocation).
    """

    def __init__(self, max_edges: int, donate: bool = True, device=None):
        self.edges = jnp.zeros((max_edges, 2), dtype=jnp.int32)
        self.mask = jnp.zeros((max_edges,), dtype=jnp.float32)
        if device is not None:
            # Pin the ring to one shard's device: with committed inputs the
            # jitted apply (and every donation) stays on that device, so P
            # shard rings run truly independent per-device streams.
            self.edges = jax.device_put(self.edges, device)
            self.mask = jax.device_put(self.mask, device)
        self._apply = (sanitize.guard_donated(_APPLY_DONATING, (0, 1))
                       if donate else _APPLY_PLAIN)
        self._decode = (sanitize.guard_donated(_DECODE_DONATING, (0, 1))
                        if donate else _DECODE_PLAIN)

    def consume(self, item) -> tuple[jax.Array, jax.Array, jax.Array]:
        """-> (edges, mask, values) device arrays for this step.

        Accepts FullSnapshot, SnapshotDelta, and the narrow-wire
        QuantizedDelta (widened + dequantized on device).
        """
        if isinstance(item, FullSnapshot):
            self.edges = jnp.asarray(item.edges)
            self.mask = jnp.asarray(item.mask)
            values = jnp.asarray(item.values)
        elif isinstance(item, QuantizedDelta):
            self.edges, self.mask = self._decode(
                self.edges, self.mask, jnp.asarray(item.drop_pos),
                jnp.asarray(item.drop_mask), jnp.asarray(item.add_edges),
                jnp.asarray(item.add_mask))
            values = _DEQUANT(jnp.asarray(item.values_q),
                              jnp.asarray(item.values_scale))
        else:
            self.edges, self.mask = self._apply(
                self.edges, self.mask, jnp.asarray(item.drop_pos),
                jnp.asarray(item.drop_mask), jnp.asarray(item.add_edges),
                jnp.asarray(item.add_mask))
            values = jnp.asarray(item.values)
        # The documented ring contract (SlotStacker): these aliases are
        # donated by the NEXT consume — callers copy before then.  Under
        # REPRO_SANITIZE=1 a stale read raises instead of going silent.
        return self.edges, self.mask, values  # dynlint: allow[donation]


class SlotStacker:
    """Per-shard slot staging for blockwise streaming.

    The distributed trainer reconstructs ``slots`` consecutive snapshots on
    each shard before one sharded train step consumes them all.  The
    applier's ring DONATES its buffers on the next ``consume``, so each
    reconstructed snapshot must be copied out first: ``put(j, ...)``
    dispatches one O(E) copy per buffer (device program order guarantees
    the read happens before the next apply retires the ring slot), and
    ``arrays()`` stacks the slots into fresh (slots, E, ...) blocks once
    per round — O(slots * E) total, and nothing the assembled global
    array aliases is ever donated.
    """

    def __init__(self, slots: int):
        self._slots: list = [None] * slots

    _copy = staticmethod(jax.jit(jnp.copy))

    def put(self, j: int, edges, mask, values) -> None:
        self._slots[j] = (self._copy(edges), self._copy(mask),
                          self._copy(values))

    def arrays(self):
        """-> (edges (slots, E, 2), mask (slots, E), values (slots, E))."""
        es, ms, vs = zip(*self._slots, strict=True)
        return jnp.stack(es), jnp.stack(ms), jnp.stack(vs)
