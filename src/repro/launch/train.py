"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Selects any registered architecture, builds its mesh + train step through
the same cell machinery the dry-run validates, and runs real steps on the
attached devices (host CPU here; a pod in production — the code path is
identical, only the mesh differs).

For the paper's dynamic-GNN archs this drives the full stack (snapshot
partitioning + graph-diff pipeline + checkpointing); for the assigned LM /
GNN / recsys archs it runs their reduced (smoke) configs by default since
the full configs need a pod.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np


def _finish_trace(path: str | None, result=None) -> None:
    """Export the session trace (``--trace``) and, for mesh runs,
    print the model-vs-measured calibration summary."""
    if not path:
        return
    from repro import obs
    trc = obs.get_tracer()
    out = obs.export_trace(path)
    dropped = f" ({trc.dropped} spans dropped)" if trc.dropped else ""
    print(f"trace: {len(trc.spans())} spans -> {out}{dropped}")
    if result is not None and result.per_shard_bytes is not None:
        # int8 wire formats quarter the a2a bytes the model predicts
        ratio = 0.25 if result.compression != "none" else 1.0
        rep = obs.calibration_report(
            trc.spans(), chunks=result.a2a_chunks,
            pipeline_rounds=result.pipeline_rounds, a2a_wire_ratio=ratio)
        print(rep.summary())


def _parse_rescale(spec: str) -> tuple[int, int]:
    """'BLOCK:P' -> (block, new_p) for the plan's rescale schedule."""
    try:
        block, p = spec.split(":")
        return int(block), int(p)
    except ValueError:
        raise SystemExit(
            f"--rescale-at expects BLOCK:P (e.g. 2:8), got {spec!r}"
        ) from None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--data-parallel", type=int, default=0,
                    help="0 = all available devices")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (pod-scale) config instead of smoke")
    ap.add_argument("--stream", action="store_true",
                    help="dyngnn only: per-snapshot streaming training "
                         "over the async graph-diff delta stream")
    ap.add_argument("--no-overlap", action="store_true",
                    help="with --stream: synchronous reference schedule "
                         "(no prefetch/transfer overlap)")
    ap.add_argument("--epochs", type=int, default=1,
                    help="with --stream: passes over the trace")
    ap.add_argument("--mesh", type=int, default=0,
                    help="with --stream: snapshot-parallel shards; each "
                         "device gets only its own time-slice delta "
                         "stream and blocks train under shard_map "
                         "(0 = single-device streaming)")
    ap.add_argument("--a2a-chunks", type=int, default=1,
                    help="mesh schedules: split each all-to-all "
                         "redistribution into this many feature-sliced "
                         "chunks the scheduler can overlap with compute "
                         "(losses unchanged)")
    ap.add_argument("--pipeline-rounds", action="store_true",
                    help="with --stream --mesh: dispatch round r+1's "
                         "delta-apply/staging before forcing round r's "
                         "loss (double-buffered edge rings; losses "
                         "unchanged)")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8_a2a", "int8_all"],
                    help="with --stream --mesh: quantized wire formats — "
                         "int8_a2a = error-feedback int8 all-to-alls, "
                         "int8_all = also the narrow host->device delta "
                         "wire (drift-bounded, not bit-exact)")
    ap.add_argument("--rescale-at", action="append", default=[],
                    metavar="BLOCK:P",
                    help="with --stream --mesh: elastically rescale the "
                         "snapshot-parallel width to P at global round "
                         "BLOCK (repeatable; realized at the "
                         "checkpoint-block boundary; losses unchanged)")
    ap.add_argument("--rescale-on-preempt", type=int, default=0,
                    metavar="P",
                    help="with --stream --mesh: absorb SIGTERM by "
                         "shrinking to width P at the next block "
                         "boundary instead of stopping")
    ap.add_argument("--sampled", action="store_true",
                    help="dyngnn only: out-of-core sampled training — "
                         "host-resident temporal store, fanout-sampled "
                         "rounds (docs/sampling.md); combine with --mesh")
    ap.add_argument("--sample-batch", type=int, default=0, metavar="B",
                    help="with --sampled: seed vertices per round "
                         "(default num_nodes // 4)")
    ap.add_argument("--fanout", default="10,10", metavar="K1,K2,...",
                    help="with --sampled: per-hop in-neighbor fanouts")
    ap.add_argument("--device-budget", type=int, default=0, metavar="BYTES",
                    help="dyngnn only: simulated per-device cap on "
                         "round-resident graph tensors; over-budget "
                         "schedules refuse with DeviceBudgetError")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="enable the repro.obs tracer and export a "
                         "Perfetto-loadable Chrome trace of the run "
                         "(phase spans + counters; .jsonl for one event "
                         "per line); mesh runs also print the "
                         "round_time_model calibration residuals")
    args = ap.parse_args()
    if args.trace:
        from repro import obs
        obs.configure(enabled=True)
    if args.sampled and args.stream:
        raise SystemExit("--sampled is its own schedule; drop --stream")
    if (args.sample_batch or args.fanout != "10,10") and not args.sampled:
        # same fail-loudly rule as the rescale flags: a typo'd command
        # must not silently run a different schedule
        raise SystemExit("--sample-batch/--fanout configure the sampled "
                         "schedule; they require --sampled")
    if (args.rescale_at or args.rescale_on_preempt) and not args.stream:
        # fail loudly, never drop the flags: the eager branch has no
        # rescale plumbing, so a typo'd command would otherwise run a
        # plain fixed-width schedule without a word
        raise SystemExit("--rescale-at/--rescale-on-preempt recompose the "
                         "distributed stream; they require "
                         "--stream --mesh P")

    from repro.configs import registry
    from repro.launch.mesh import make_host_mesh

    arch = registry.get_arch(args.arch)
    n_dev = len(jax.devices())
    dp = args.data_parallel or max(d for d in (1, 2, 4, 8, 16) if
                                   d <= n_dev)

    if arch.family == "dyngnn":
        from repro.run import (CheckpointSpec, DeviceBudgetError, Engine,
                               ExecutionPlan, RunConfig, SamplingSpec,
                               SyntheticTrace)
        cfg = (arch.make_config() if args.full_config
               else arch.make_smoke_config())
        smooth = {"tmgcn": "mproduct", "evolvegcn": "edgelife",
                  "cdgcn": "none"}[cfg.model]
        data = SyntheticTrace(num_nodes=cfg.num_nodes,
                              num_steps=cfg.num_steps, density=3.0,
                              churn=0.1, smoothing_mode=smooth,
                              window=cfg.window)
        budget = args.device_budget or None
        if args.sampled:
            try:
                fanouts = tuple(int(k) for k in args.fanout.split(","))
            except ValueError:
                raise SystemExit(f"bad --fanout {args.fanout!r}; expected "
                                 "K1,K2,...") from None
            spec = SamplingSpec(
                batch_nodes=args.sample_batch or max(cfg.num_nodes // 4, 1),
                fanouts=fanouts)
            plan = ExecutionPlan(mode="sampled", shards=max(args.mesh, 1),
                                 num_epochs=args.epochs,
                                 overlap=not args.no_overlap,
                                 a2a_chunks=args.a2a_chunks,
                                 compression=args.compression,
                                 sampling=spec, device_budget_bytes=budget)
            ckpt = None
            if args.ckpt_dir:
                print("note: --ckpt-dir is ignored with --sampled "
                      "(checkpointing is wired for the eager and "
                      "streamed --mesh schedules)")
        elif args.stream:
            # non-divisible num_nodes auto-pads inside the plan (logged);
            # the pipelining/rescale flags pass through VERBATIM so a
            # combination the plan cannot honor (e.g. --a2a-chunks or
            # --rescale-at without --mesh) fails loudly below instead of
            # silently running a no-op
            plan = ExecutionPlan(
                mode="streamed_mesh" if args.mesh > 1 else "streamed",
                shards=max(args.mesh, 1), num_epochs=args.epochs,
                overlap=not args.no_overlap,
                a2a_chunks=args.a2a_chunks,
                pipeline_rounds=args.pipeline_rounds,
                compression=args.compression,
                rescale=tuple(_parse_rescale(s) for s in args.rescale_at),
                rescale_on_preempt=args.rescale_on_preempt,
                device_budget_bytes=budget)
            ckpt = None
            if args.ckpt_dir:
                if plan.mode == "streamed_mesh":
                    # round-granular mesh-agnostic checkpoints: SIGTERM
                    # saves the data cursor; a rerun resumes it, on any
                    # legal --mesh width
                    ckpt = CheckpointSpec(args.ckpt_dir)
                else:
                    print("note: --ckpt-dir is ignored with single-device "
                          "--stream (checkpointing is wired for the eager "
                          "and streamed --mesh schedules)")
        else:
            plan = ExecutionPlan(mode="eager", shards=dp,
                                 num_steps=args.steps,
                                 a2a_chunks=args.a2a_chunks,
                                 pipeline_rounds=args.pipeline_rounds,
                                 compression=args.compression,
                                 device_budget_bytes=budget)
            ckpt = (CheckpointSpec(args.ckpt_dir)
                    if args.ckpt_dir else None)
        try:
            # surface plan/config contradictions (e.g. a trace length the
            # shards cannot slice, a bad --a2a-chunks) as a one-line CLI
            # error, not a traceback
            engine = Engine(RunConfig(model=cfg, data=data, plan=plan,
                                      checkpoint=ckpt))
            engine.resolve()
        except ValueError as e:
            raise SystemExit(f"invalid run configuration: {e}") from None
        try:
            result = engine.fit()
        except DeviceBudgetError as e:
            # the budget gate refusing IS the answer the flag asks for —
            # report it as a one-line CLI outcome, not a traceback
            raise SystemExit(f"refused: {e}") from None
        _finish_trace(args.trace, result)
        rep = result.transfer_report
        if args.sampled:
            final = (f"{result.losses[-1]:.4f}" if result.losses else "n/a")
            srep = result.sample_report
            budget_txt = (f", budget {result.budget_report['required']}"
                          f"/{result.budget_report['budget']} B"
                          if result.budget_report else "")
            print(f"sampled {srep.rounds} rounds on "
                  f"{max(args.mesh, 1)} shards, final loss {final}, "
                  f"staged {srep.staged_bytes} B, sampled edges "
                  f"{srep.sampled_edges} (dropped {srep.dropped_edges} "
                  f"edges / {srep.dropped_nodes} nodes){budget_txt}")
            return
        if args.stream:
            final = (f"{result.losses[-1]:.4f}" if result.losses else "n/a")
            if plan.mode == "streamed_mesh":
                rsc = result.rescale_report
                if rsc is not None and (rsc.events or rsc.preempted
                                        or rsc.resumed_from is not None):
                    # elastic summary: the width trajectory, not a single
                    # per-device figure (each segment has its own P)
                    evs = ", ".join(
                        f"{e.old_p}->{e.new_p}@block{e.block}"
                        f" ({e.cause}, {e.payload_bytes} B)"
                        for e in rsc.events) or "none realized"
                    if not rsc.preempted:
                        state_txt = "completed"
                    elif ckpt is not None:
                        state_txt = "preempted+checkpointed"
                    else:       # no --ckpt-dir: progress was NOT saved
                        state_txt = "preempted (no checkpoint configured)"
                    print(f"streamed {result.state.step} block rounds "
                          f"elastically ({state_txt}), final loss "
                          f"{final}, rescales: {evs}")
                    return
                # report what actually crossed the links: the per-shard
                # time-sliced streams (extra slice-boundary fulls), not
                # the single-device global stream
                per_dev = result.per_shard_bytes
                comp_txt = (f", compression {result.compression}"
                            if result.compression != "none" else "")
                print(f"streamed {result.state.step} block rounds on "
                      f"{args.mesh} shards, final loss {final}, "
                      f"per-device stream {max(per_dev)} B (total "
                      f"{sum(per_dev) / max(rep['naive'], 1):.3f} of "
                      f"naive){comp_txt}")
            else:
                print(f"streamed {result.state.step} snapshot steps, "
                      f"final loss {final}, transfer ratio "
                      f"{rep['ratio']:.3f} vs naive")
            return
        acc = engine.evaluate(result)
        # a checkpoint resume at/past --steps trains zero new steps
        final = f"{result.losses[-1]:.4f}" if result.losses else "n/a"
        print(f"done: {result.state.step} steps, final loss "
              f"{final}, link-pred acc {acc:.3f}")
        return

    # LM / GNN / recsys: drive one cell's train step repeatedly
    from repro.launch import steps as steps_mod
    mesh = make_host_mesh(data=dp, model=max(n_dev // dp, 1))
    shape_name = {"lm": "train_4k", "gnn": "molecule",
                  "recsys": "train_batch"}[arch.family]
    override = {"lm": {"seq_len": 128, "global_batch": 2 * dp},
                "gnn": {"n_nodes": 16, "n_edges": 32, "batch": 2 * dp,
                        "d_feat": 8, "num_classes": 2},
                "recsys": {"batch": 16 * dp}}[arch.family]
    cell = steps_mod.build_cell(args.arch, shape_name, mesh,
                                smoke=not args.full_config,
                                shape_override=None if args.full_config
                                else override)
    rng = np.random.default_rng(0)
    import jax.numpy as jnp

    def concretize(a):
        if a.dtype in (jnp.int32, jnp.int64):
            return jnp.asarray(rng.integers(0, 2, a.shape), a.dtype)
        return jnp.asarray(rng.normal(0, 0.1, a.shape), a.dtype)

    args_c = list(jax.tree.map(concretize, cell.abstract_inputs))
    with mesh:
        step = jax.jit(cell.step, in_shardings=cell.in_shardings,
                       out_shardings=cell.out_shardings)
        for i in range(args.steps):
            out = step(*args_c)
            params, opt_state, loss = out
            args_c[0], args_c[1] = params, opt_state
            if i % max(args.steps // 10, 1) == 0:
                print(f"step {i} loss {float(loss):.4f}")
    _finish_trace(args.trace)
    print("done")


if __name__ == "__main__":
    main()
