"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Drives the serve_step path (prefill + batched decode through a KV cache)
for the LM architectures, or batched CTR scoring for DIN — the same step
functions the decode/serve dry-run cells validate at pod scale.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--requests", type=int, default=3,
                    help="number of batched request waves")
    args = ap.parse_args()

    from repro.configs import registry
    arch = registry.get_arch(args.arch)

    if arch.family == "recsys":
        from repro.models import din as din_mod
        cfg = arch.make_smoke_config()
        params = din_mod.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        fwd = jax.jit(din_mod.forward)
        for wave in range(args.requests):
            b = args.batch
            batch = {
                "user_id": jnp.asarray(
                    rng.integers(0, cfg.user_vocab, (b,)), jnp.int32),
                "hist_items": jnp.asarray(
                    rng.integers(0, cfg.item_vocab, (b, cfg.seq_len)),
                    jnp.int32),
                "hist_cates": jnp.asarray(
                    rng.integers(0, cfg.cate_vocab, (b, cfg.seq_len)),
                    jnp.int32),
                "hist_mask": jnp.ones((b, cfg.seq_len), jnp.float32),
                "target_item": jnp.asarray(
                    rng.integers(0, cfg.item_vocab, (b,)), jnp.int32),
                "target_cate": jnp.asarray(
                    rng.integers(0, cfg.cate_vocab, (b,)), jnp.int32),
            }
            t0 = time.perf_counter()
            logits = jax.block_until_ready(fwd(params, batch))
            print(f"wave {wave}: scored {b} requests in "
                  f"{(time.perf_counter() - t0) * 1e3:.1f} ms")
        return

    from repro.models import lm
    cfg = arch.make_smoke_config()
    params = lm.init_lm_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.tokens
    prefill = jax.jit(lambda p, t: lm.prefill(cfg, p, t, max_len=max_len))
    decode = jax.jit(lambda p, c, t: lm.decode_step(cfg, p, c, t))
    for wave in range(args.requests):
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32)
        t0 = time.perf_counter()
        logits, cache = prefill(params, prompts)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        n_gen = 1
        for _ in range(args.tokens - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            n_gen += 1
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        print(f"wave {wave}: {args.batch} x {n_gen} tokens in {dt:.2f} s "
              f"({args.batch * n_gen / dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
