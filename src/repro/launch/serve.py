"""DEPRECATED serving launcher — use ``repro.serve``.

``python -m repro.launch.serve`` remains as a thin shim over the
declarative surface::

    from repro.serve import ServeConfig, ServeEngine
    eng = ServeEngine(ServeConfig(arch="yi-6b", prompt_len=32,
                                  max_tokens=64, batch_sizes=(8,)))
    eng.generate()

See README "Migrating to repro.serve" for the flag mapping and
``docs/serve_api.md`` for the full surface (including the dyngnn online
path, which this legacy CLI never had).
"""

from __future__ import annotations

import argparse
import warnings


def main(argv: list[str] | None = None) -> None:
    warnings.warn(
        "repro.launch.serve is deprecated: build a repro.serve.ServeConfig "
        "and use ServeEngine instead (see README 'Migrating to "
        "repro.serve')", DeprecationWarning, stacklevel=2)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--requests", type=int, default=3,
                    help="number of batched request waves")
    args = ap.parse_args(argv)

    from repro.serve import ServeConfig, ServeEngine
    eng = ServeEngine(ServeConfig(
        arch=args.arch, batch_sizes=(args.batch,),
        prompt_len=args.prompt_len, max_tokens=args.tokens))
    for wave in range(args.requests):
        if eng.family == "recsys":
            eng.score(batch_size=args.batch)
        else:
            eng.generate(batch_size=args.batch)
        r = eng.result()
        print(f"wave {wave}: {r.summary()}")


if __name__ == "__main__":
    main()
