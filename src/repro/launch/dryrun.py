import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline inputs.

MUST be the first jax import site: the XLA_FLAGS line above precedes every
other import so jax sees 512 host devices.

For each cell and mesh:
  * jax.jit(step, in_shardings, out_shardings).lower(*abstract).compile()
  * record memory_analysis() (per-device bytes — proves fit),
  * cost_analysis() (HLO flops / bytes accessed),
  * collective bytes parsed from the optimized HLO (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute operand sizes),
  * derived roofline terms for TPU v5e (EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
Results cached in results/dryrun/<mesh>/<arch>__<shape>.json.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.launch.mesh import make_production_mesh

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (~)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "s16": 2, "u16": 2,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:[%\w.\-]+) = \(?([a-z0-9\[\]{}, ]+?)\)? (all-gather|"
    r"all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64|c64|"
                       r"s16|u16)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective in the optimized HLO.

    Counted per collective kind; shapes are per-PARTICIPANT (SPMD module),
    i.e. bytes moved per device per step (the roofline denominator uses
    per-chip link bandwidth, so per-device volume is the right numerator).
    """
    out: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        kind = m.group(2)
        b = _shape_bytes(m.group(1))
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def roofline(cost: dict, coll: dict, _num_chips: int, _meta: dict) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    # cost_analysis of the SPMD module is per-device already
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll.get("total", 0) / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    return {**terms, "dominant": dominant,
            "hlo_flops_per_device": flops,
            "hlo_bytes_per_device": bytes_accessed,
            "collective_bytes_per_device": coll.get("total", 0)}


def _compile_cell(cell):
    jitted = jax.jit(cell.step, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate)
    return jitted.lower(*cell.abstract_inputs).compile()


def _cost_and_coll(compiled) -> tuple[dict, dict]:
    cost_list = compiled.cost_analysis()
    cost = cost_list if isinstance(cost_list, dict) else \
        (cost_list[0] if cost_list else {})
    coll = collective_bytes(compiled.as_text())
    return cost, coll


def _two_point_lm_cost(arch_id, shape_name, mesh, num_layers) -> tuple:
    """XLA cost_analysis counts while(scan) bodies ONCE, ignoring the trip
    count (calibrated in EXPERIMENTS.md §Methodology).  For the LM family we
    recover exact totals from two auxiliary fully-unrolled lowers:

        aux_k = head_cost + k * layer_cost    (k = 1, 2)
        total = aux_1 + (L - 1) * (aux_2 - aux_1)

    Applies to flops, bytes and collective volume alike.
    """
    from repro.launch import steps as steps_mod
    aux = []
    for k in (1, 2):
        cell = steps_mod.build_cell(
            arch_id, shape_name, mesh,
            config_override={"num_layers": k, "layer_unroll": k,
                             "unroll_chunks": True, "remat": False})
        compiled = _compile_cell(cell)
        aux.append(_cost_and_coll(compiled))
    (c1, k1), (c2, k2) = aux

    def extrapolate(a1, a2):
        # GSPMD may legally pick different layouts for the 1- vs 2-layer
        # module; guard against a negative per-layer delta by falling back
        # to scaling the 2-layer module.
        delta = a2 - a1
        if delta < 0 or (a1 > 0 and delta > 4 * a1):
            return a2 * num_layers / 2.0
        return a1 + (num_layers - 1) * delta

    flops = extrapolate(float(c1.get("flops", 0)), float(c2.get("flops", 0)))
    byts = extrapolate(float(c1.get("bytes accessed", 0)),
                       float(c2.get("bytes accessed", 0)))
    coll = extrapolate(float(k1.get("total", 0)), float(k2.get("total", 0)))
    # remat recompute: the real train step reruns each layer's forward in
    # backward (remat=True); aux modules disable remat (fwd+bwd ~= 3x fwd),
    # so add one forward recompute ~= +1/3 of layer compute.
    return ({"flops": flops, "bytes accessed": byts},
            {"total": coll},
            {"aux1": {"flops": c1.get("flops"), "coll": k1.get("total", 0)},
             "aux2": {"flops": c2.get("flops"), "coll": k2.get("total", 0)}})


def _dyngnn_analytic(cell, cfg, num_chips) -> tuple[dict, dict]:
    """Analytic per-device roofline inputs for the paper's workload (the
    model is three dense ops + SpMM; formulas in EXPERIMENTS.md)."""
    meta = cell.meta
    n, t, e = meta["nodes"], meta["steps"], meta["edges_per_snap"]
    p = num_chips
    dims = cfg.layer_dims()
    fwd_flops = 0.0
    for (d_in, d_gcn, d_out) in dims:
        fwd_flops += t * (2.0 * e * d_in + 2.0 * n * d_in * d_gcn)  # SpMM+W
        if cfg.model == "cdgcn":
            fwd_flops += t * 2.0 * n * (d_in + d_gcn + d_out) * 4 * d_out
        elif cfg.model == "tmgcn":
            fwd_flops += t * n * d_out * 2.0
    fwd_flops += t * 2.0 * n * dims[-1][2] * cfg.num_classes
    flops = 4.0 * fwd_flops / p        # fwd + bwd(2x) + remat rerun(1x)
    act_bytes = 4.0 * t * n * sum(d for (_, _, d) in dims) / p
    edge_bytes = t * e * 12.0 / p
    byts = 3.0 * (act_bytes + edge_bytes) + 2 * act_bytes
    # collectives: the OPTIMIZED execution ships bf16 payloads (2 bytes)
    # and fuses the final-layer loss vertex-sharded, eliding one of the 2L
    # redistributions; x2 for fwd+bwd.  Gradient all-reduce is tiny.
    if cfg.model == "evolvegcn":
        legs = 0
    else:
        legs = 2 * cfg.num_layers - 1
    avg_w = sum(d for (_, _, d) in dims) / max(len(dims), 1)
    a2a = 2 * legs * (t / p) * n * avg_w * 2.0
    coll = a2a * (p - 1) / p
    return ({"flops": flops, "bytes accessed": byts}, {"total": coll})


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: Path | None = None, verbose: bool = True) -> dict:
    from repro.configs import registry
    from repro.launch import steps as steps_mod

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_dir = out_dir or (RESULTS_DIR / mesh_name)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_file = out_dir / f"{arch_id}__{shape_name}.json"

    mesh = make_production_mesh(multi_pod=multi_pod)
    num_chips = 512 if multi_pod else 256
    t0 = time.time()
    record: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                    "status": "error"}
    try:
        arch = registry.get_arch(arch_id)
        cell = steps_mod.build_cell(arch_id, shape_name, mesh)
        with mesh:
            compiled = _compile_cell(cell)
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        mem_rec = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem_rec[k] = getattr(mem, k, None)
        cost_raw, coll_raw = _cost_and_coll(compiled)
        cost = {k: cost_raw.get(k) for k in
                ("flops", "bytes accessed", "transcendentals")
                if k in cost_raw}
        coll = coll_raw
        correction = "none"
        extra = {}
        if arch.family == "lm":
            with mesh:
                cost_c, coll_c, extra = _two_point_lm_cost(
                    arch_id, shape_name, mesh,
                    arch.make_config().num_layers)
            cost, coll = cost_c, {**coll_raw, "total": coll_c["total"]}
            correction = "two_point_unrolled"
        elif arch.family == "dyngnn":
            cost, coll_a = _dyngnn_analytic(cell, arch.make_config(),
                                            num_chips)
            coll = {**coll_raw, "total": coll_a["total"]}
            correction = "analytic"
        rl = roofline(cost, coll, num_chips, cell.meta or {})
        record.update({
            "status": "ok",
            "compile_s": round(t_compile, 1),
            "memory": mem_rec,
            "cost": cost,
            "cost_raw_hlo": {k: cost_raw.get(k) for k in
                             ("flops", "bytes accessed") if k in cost_raw},
            "collectives": coll,
            "cost_correction": correction,
            "correction_detail": extra,
            "roofline": rl,
            "meta": cell.meta,
        })
    except Exception as exc:  # noqa: BLE001 — record and continue
        record["error"] = f"{type(exc).__name__}: {exc}"
        record["traceback"] = traceback.format_exc()[-3000:]
    record["total_s"] = round(time.time() - t0, 1)
    out_file.write_text(json.dumps(record, indent=2))
    if verbose:
        status = record["status"]
        extra = (f"dominant={record['roofline']['dominant']}"
                 if status == "ok" else record.get("error", ""))
        print(f"[{mesh_name}] {arch_id} x {shape_name}: {status} "
              f"({record['total_s']}s) {extra}", flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-cached", action="store_true")
    args = ap.parse_args()

    from repro.launch import steps as steps_mod

    if args.all:
        cells = steps_mod.all_cells()
    else:
        if not (args.arch and args.shape):
            raise SystemExit("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    for arch_id, shape_name in cells:
        out_file = RESULTS_DIR / mesh_name / f"{arch_id}__{shape_name}.json"
        if args.skip_cached and out_file.exists():
            rec = json.loads(out_file.read_text())
            if rec.get("status") == "ok":
                print(f"[{mesh_name}] {arch_id} x {shape_name}: cached ok",
                      flush=True)
                continue
        run_cell(arch_id, shape_name, args.multi_pod)


if __name__ == "__main__":
    main()
