"""Step builders + abstract input specs for every (arch x shape) cell.

``build_cell(arch_id, shape_name, mesh)`` returns a ``Cell`` with everything
the dry-run / trainer needs:

  * ``step``          — the python callable to jit (train_step or serve_step)
  * ``in_shardings`` / ``out_shardings``
  * ``abstract_inputs`` — ShapeDtypeStructs (weak-type-correct, shardable, no
    allocation) for ``jax.jit(...).lower(...)``
  * ``donate``        — argnums donated (params / opt state / caches)

Conventions: train cells lower a FULL training step (loss + grads + AdamW
update, optimizer state included so memory analysis reflects reality);
decode/recsys-serve cells lower a serve_step.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.dist import sharding as shd
from repro.models import din as din_mod
from repro.models import lm as lm_mod
from repro.optim import adamw

Array = jax.Array

# TP axis name, from the canonical mesh-axis constants (dynlint:
# shard-axes pass rejects raw string literals in specs/collectives).
MODEL = shd.MODEL_AXIS


@dataclass
class Cell:
    arch_id: str
    shape_name: str
    step: Callable
    abstract_inputs: tuple
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()
    meta: dict | None = None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _abstract_tree(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _dp_size(mesh: Mesh) -> int:
    out = 1
    for a in shd.dp_axes(mesh):
        out *= mesh.shape[a]
    return out


# ============================================================ LM cells ======

def _lm_head_specs(cfg, mesh: Mesh, mode: str = "gqa_tp"):
    """TP specs for attention weights.

    'gqa_tp' (default, §Perf iteration 1): shard the QUERY heads over
    'model' and replicate KV heads when they don't divide the axis (GQA has
    few of them and they're small) — attention then computes entirely
    locally per head group, with one output psum per layer.

    'naive_tp' (the recorded baseline): falls back to sharding the head_dim
    (contraction) axis when head counts don't divide — which makes QK^T emit
    FULL-head partial scores plus an all-reduce per layer (the pathology
    measured in EXPERIMENTS.md §Perf, kept reproducible here).
    """
    m = mesh.shape[MODEL]
    heads_ok = cfg.num_heads % m == 0
    kv_ok = cfg.num_kv_heads % m == 0
    if mode == "naive_tp":
        if heads_ok and kv_ok:
            return {"wq": P(None, None, MODEL, None),
                    "wk": P(None, None, MODEL, None),
                    "wv": P(None, None, MODEL, None),
                    "wo": P(None, MODEL, None, None)}
        assert cfg.head_dim % m == 0
        return {"wq": P(None, None, None, MODEL),
                "wk": P(None, None, None, MODEL),
                "wv": P(None, None, None, MODEL),
                "wo": P(None, None, MODEL, None)}
    if heads_ok:
        kv = MODEL if kv_ok else None
        return {"wq": P(None, None, MODEL, None),
                "wk": P(None, None, kv, None),
                "wv": P(None, None, kv, None),
                "wo": P(None, MODEL, None, None)}
    # heads don't divide (minicpm's 36): replicate attention weights; the
    # attention itself is sequence-sharded (§Perf iteration 2).
    return {"wq": P(None, None, None, None),
            "wk": P(None, None, None, None),
            "wv": P(None, None, None, None),
            "wo": P(None, None, None, None)}


def lm_param_specs(cfg, mesh: Mesh, mode: str = "gqa_tp") -> dict:
    specs = shd.lm_param_specs(cfg, mesh, mode="tp")
    specs["layers"]["attn"] = _lm_head_specs(cfg, mesh, mode)
    return specs


def _fsdp_opt_specs(a_params, p_specs, mesh: Mesh) -> dict:
    """ZeRO-style optimizer-state sharding (§Perf iteration 5): m/v/master
    additionally shard their largest unsharded dim over the data axes, so
    fp32 optimizer memory scales 1/(dp*tp).  XLA turns the gradient
    all-reduce into reduce-scatter + post-update param all-gather."""
    dp = shd.dp_axes(mesh)
    dp_n = _dp_size(mesh)

    def leaf_spec(a, spec: P) -> P:
        parts = list(spec) + [None] * (len(a.shape) - len(spec))
        best, best_dim = None, -1
        for i, (s, p_) in enumerate(zip(a.shape, parts, strict=True)):
            if p_ is None and s % dp_n == 0 and s > best_dim:
                best, best_dim = i, s
        if best is None:
            return spec
        parts[best] = dp
        return P(*parts)

    flat_a = jax.tree.leaves(a_params)
    flat_s = jax.tree.leaves(p_specs, is_leaf=lambda x: isinstance(x, P))
    flat_2d = [leaf_spec(a, s) for a, s in zip(flat_a, flat_s, strict=True)]
    treedef = jax.tree.structure(p_specs,
                                 is_leaf=lambda x: isinstance(x, P))
    shard2d = jax.tree.unflatten(treedef, flat_2d)
    return {"m": shard2d, "v": shard2d, "master": shard2d, "step": P()}


def _chunk_constrainer(cfg, mesh: Mesh):
    """Sequence-parallel attention hook for archs whose head count does
    not divide the model axis (SSPerf iteration 2, minicpm): shard each
    query chunk's rows over 'model' (inward), un-shard its output."""
    if cfg.num_heads % mesh.shape[MODEL] == 0:
        return None
    dp = shd.dp_axes(mesh)
    inward = NamedSharding(mesh, P(dp, MODEL, None, None))
    outward = NamedSharding(mesh, P(dp, None, None, None))

    def constrain(x, to_sharded):
        return jax.lax.with_sharding_constraint(
            x, inward if to_sharded else outward)

    return constrain


def _lm_train_cell(arch, shape, mesh: Mesh, cfg) -> Cell:
    opt_cfg = adamw.AdamWConfig(schedule=cfg.lr_schedule)
    constrain = shd.lm_activation_constrainer(mesh)
    chunk_con = _chunk_constrainer(cfg, mesh)
    p_specs = lm_param_specs(cfg, mesh)
    b_spec = shd.lm_batch_specs(mesh)

    def train_step(params, opt_state, tokens, targets):
        loss, grads = jax.value_and_grad(
            lambda p: lm_mod.lm_loss(cfg, p, tokens, targets, constrain,
                                     chunk_constrain=chunk_con)
        )(params)
        params, opt_state = adamw.apply_updates(opt_cfg, params, grads,
                                                opt_state)
        return params, opt_state, loss

    a_params = _abstract_tree(
        lambda: lm_mod.init_lm_params(
            jax.random.PRNGKey(0), cfg))  # dynlint: allow[prng] shape-only
    a_opt = _abstract_tree(adamw.init_state, a_params)
    o_specs = _fsdp_opt_specs(a_params, p_specs, mesh)
    b, s = shape.dims["global_batch"], shape.dims["seq_len"]
    a_tok = _sds((b, s), jnp.int32)
    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name, step=train_step,
        abstract_inputs=(a_params, a_opt, a_tok, a_tok),
        in_shardings=(shd.named(mesh, p_specs), shd.named(mesh, o_specs),
                      NamedSharding(mesh, b_spec),
                      NamedSharding(mesh, b_spec)),
        out_shardings=(shd.named(mesh, p_specs), shd.named(mesh, o_specs),
                       NamedSharding(mesh, P())),
        donate=(0, 1),
        meta={"tokens": b * s})


def _lm_kv_specs(cfg, mesh: Mesh, seq_shard: bool):
    m = mesh.shape[MODEL]
    dp = shd.dp_axes(mesh)
    if seq_shard:
        # context parallelism: KV sequence over every axis (batch = 1)
        axes = (*dp, MODEL)
        return {"k": P(None, None, axes, None, None),
                "v": P(None, None, axes, None, None), "len": P()}
    if cfg.num_kv_heads % m == 0:
        return {"k": P(None, dp, None, MODEL, None),
                "v": P(None, dp, None, MODEL, None), "len": P(dp)}
    # few KV heads (yi): split the cache sequence over 'model' instead
    return {"k": P(None, dp, MODEL, None, None),
            "v": P(None, dp, MODEL, None, None), "len": P(dp)}


def _lm_decode_cell(arch, shape, mesh: Mesh, cfg) -> Cell:
    b = shape.dims["global_batch"]
    s = shape.dims["seq_len"]
    seq_shard = bool(shape.dims.get("kv_seq_shard", False))
    p_specs = lm_param_specs(cfg, mesh)
    kv_specs = _lm_kv_specs(cfg, mesh, seq_shard)
    constrain = shd.lm_activation_constrainer(mesh)

    def serve_step(params, cache, token):
        return lm_mod.decode_step(cfg, params, cache, token, constrain)

    a_params = _abstract_tree(
        lambda: lm_mod.init_lm_params(
            jax.random.PRNGKey(0), cfg))  # dynlint: allow[prng] shape-only
    a_cache = _abstract_tree(
        lambda: lm_mod.init_kv_cache(cfg, b, s))
    tok_spec = P(shd.dp_axes(mesh)) if b >= _dp_size(mesh) else P()
    a_tok = _sds((b,), jnp.int32)
    logits_spec = P(shd.dp_axes(mesh), MODEL) if b >= _dp_size(mesh) \
        else P(None, MODEL)
    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name, step=serve_step,
        abstract_inputs=(a_params, a_cache, a_tok),
        in_shardings=(shd.named(mesh, p_specs), shd.named(mesh, kv_specs),
                      NamedSharding(mesh, tok_spec)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       shd.named(mesh, kv_specs)),
        donate=(1,),
        meta={"tokens": b, "kv_len": s})


def _lm_prefill_cell(arch, shape, mesh: Mesh, cfg) -> Cell:
    b = shape.dims["global_batch"]
    s = shape.dims["seq_len"]
    p_specs = lm_param_specs(cfg, mesh)
    kv_specs = _lm_kv_specs(cfg, mesh, seq_shard=False)
    constrain = shd.lm_activation_constrainer(mesh)

    chunk_con = _chunk_constrainer(cfg, mesh)

    def serve_step(params, tokens):
        return lm_mod.prefill(cfg, params, tokens, max_len=s,
                              constrain=constrain,
                              chunk_constrain=chunk_con)

    a_params = _abstract_tree(
        lambda: lm_mod.init_lm_params(
            jax.random.PRNGKey(0), cfg))  # dynlint: allow[prng] shape-only
    a_tok = _sds((b, s), jnp.int32)
    logits_spec = P(shd.dp_axes(mesh), MODEL)
    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name, step=serve_step,
        abstract_inputs=(a_params, a_tok),
        in_shardings=(shd.named(mesh, p_specs),
                      NamedSharding(mesh, shd.lm_batch_specs(mesh))),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       shd.named(mesh, kv_specs)),
        meta={"tokens": b * s})


# =========================================================== GNN cells ======

def _gnn_forward_fn(arch_id: str, cfg):
    from repro.models.gnn import equiformer_v2, gatedgcn, pna, schnet
    if arch_id == "gatedgcn":
        return lambda p, b: gatedgcn.logits(p, b)
    if arch_id == "pna":
        return lambda p, b: pna.logits(p, b)
    if arch_id == "schnet":
        return lambda p, b: schnet.logits(p, b, cfg.cutoff)
    if arch_id == "equiformer-v2":
        return lambda p, b: equiformer_v2.logits(
            p, b, l_max=cfg.l_max, m_max=cfg.m_max, n_heads=cfg.n_heads,
            n_rbf=cfg.n_rbf, cutoff=cfg.cutoff)
    raise KeyError(arch_id)


def _gnn_init_fn(arch_id: str, cfg, d_in: int, num_classes: int):
    from repro.models.gnn import equiformer_v2, gatedgcn, pna, schnet
    # abstract-eval only: build_cell traces these inits for shapes; the
    # fixed key keeps the dry-run deterministic and never trains
    key = jax.random.PRNGKey(0)  # dynlint: allow[prng]
    if arch_id == "gatedgcn":
        return lambda: gatedgcn.init_params(key, d_in, cfg.d_hidden,
                                            cfg.n_layers, num_classes)
    if arch_id == "pna":
        return lambda: pna.init_params(key, d_in, cfg.d_hidden,
                                       cfg.n_layers, num_classes)
    if arch_id == "schnet":
        return lambda: schnet.init_params(key, d_in, cfg.d_hidden,
                                          cfg.n_interactions, cfg.n_rbf,
                                          num_classes)
    if arch_id == "equiformer-v2":
        return lambda: equiformer_v2.init_params(
            key, d_in, cfg.d_hidden, cfg.n_layers, cfg.l_max, cfg.m_max,
            cfg.n_heads, cfg.n_rbf, num_classes)
    raise KeyError(arch_id)


def _needs_positions(arch_id: str) -> bool:
    return arch_id in ("schnet", "equiformer-v2")


def _gnn_full_graph_cell(arch, shape, mesh: Mesh, cfg) -> Cell:
    from repro.models.gnn.common import GraphBatch, node_ce_loss
    d = shape.dims
    dp = shd.dp_axes(mesh)
    dp_n = _dp_size(mesh)
    n = _round_up(d["n_nodes"], dp_n)
    e = _round_up(d["n_edges"], dp_n * 128)
    d_in, n_cls = d["d_feat"], d["num_classes"]
    fwd = _gnn_forward_fn(arch.arch_id, cfg)
    init = _gnn_init_fn(arch.arch_id, cfg, d_in, n_cls)
    opt_cfg = adamw.AdamWConfig()
    with_pos = _needs_positions(arch.arch_id)
    # the big irreps arch keeps node tensors row-sharded; others replicate
    node_spec = P(dp) if arch.arch_id == "equiformer-v2" else P()

    def train_step(params, opt_state, edges, emask, feats, pos, labels,
                   nmask):
        batch = GraphBatch(edges=edges, edge_mask=emask, node_feat=feats,
                           node_mask=nmask, positions=pos, graph_id=None,
                           num_graphs=1, labels=labels)

        def loss_fn(p):
            return node_ce_loss(fwd(p, batch), labels, nmask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw.apply_updates(opt_cfg, params, grads,
                                                opt_state)
        return params, opt_state, loss

    a_params = _abstract_tree(init)
    a_opt = _abstract_tree(adamw.init_state, a_params)
    dt = jnp.float32
    abstract = (a_params, a_opt, _sds((e, 2), jnp.int32), _sds((e,), dt),
                _sds((n, d_in), dt), _sds((n, 3), dt),
                _sds((n,), jnp.int32), _sds((n,), dt))
    p_specs = shd.replicate_specs(a_params)
    o_specs = shd.replicate_specs(a_opt)
    in_sh = (shd.named(mesh, p_specs), shd.named(mesh, o_specs),
             NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P(dp)),
             NamedSharding(mesh, node_spec), NamedSharding(mesh, node_spec),
             NamedSharding(mesh, node_spec), NamedSharding(mesh, node_spec))
    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name, step=train_step,
        abstract_inputs=abstract, in_shardings=in_sh,
        out_shardings=(shd.named(mesh, p_specs), shd.named(mesh, o_specs),
                       NamedSharding(mesh, P())),
        donate=(0, 1),
        meta={"edges": e, "nodes": n})


def _gnn_replica_cell(arch, shape, mesh: Mesh, cfg, *, minibatch: bool
                      ) -> Cell:
    """minibatch_lg / molecule: one independent subgraph per DP replica,
    vmapped over the leading replica axis."""
    from repro.models.gnn.common import GraphBatch, node_ce_loss
    d = shape.dims
    dp = shd.dp_axes(mesh)
    r = _dp_size(mesh)
    if minibatch:
        seeds = max(d["batch_nodes"] // r, 1)
        e_sub = 0
        cap = seeds
        for f in d["fanouts"]:
            cap *= f
            e_sub += cap
        n_sub = seeds + e_sub
        d_in, n_cls = d["d_feat"], d["num_classes"]
        graph_level = False
    else:
        graphs_per = max(d["batch"] // r, 1)
        n_sub = graphs_per * d["n_nodes"]
        e_sub = graphs_per * d["n_edges"]
        d_in, n_cls = d["d_feat"], d["num_classes"]
        graph_level = True
        seeds = graphs_per

    fwd = _gnn_forward_fn(arch.arch_id, cfg)
    init = _gnn_init_fn(arch.arch_id, cfg, d_in, n_cls)
    opt_cfg = adamw.AdamWConfig()

    def per_replica_loss(params, edges, emask, feats, pos, labels, nmask,
                         gid):
        batch = GraphBatch(edges=edges, edge_mask=emask, node_feat=feats,
                           node_mask=nmask, positions=pos,
                           graph_id=gid if graph_level else None,
                           num_graphs=seeds if graph_level else 1,
                           labels=labels)
        logits = fwd(params, batch)
        if graph_level:
            mask = jnp.ones((seeds,), jnp.float32)
            return node_ce_loss(logits, labels, mask)
        # minibatch: loss on seed nodes only (first `seeds` rows)
        return node_ce_loss(logits[:seeds], labels[:seeds], nmask[:seeds])

    def train_step(params, opt_state, edges, emask, feats, pos, labels,
                   nmask, gid):
        def loss_fn(p):
            losses = jax.vmap(per_replica_loss,
                              in_axes=(None, 0, 0, 0, 0, 0, 0, 0))(
                p, edges, emask, feats, pos, labels, nmask, gid)
            return jnp.mean(losses)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw.apply_updates(opt_cfg, params, grads,
                                                opt_state)
        return params, opt_state, loss

    a_params = _abstract_tree(init)
    a_opt = _abstract_tree(adamw.init_state, a_params)
    dt = jnp.float32
    lab_n = seeds if graph_level else n_sub
    abstract = (a_params, a_opt,
                _sds((r, e_sub, 2), jnp.int32), _sds((r, e_sub), dt),
                _sds((r, n_sub, d_in), dt), _sds((r, n_sub, 3), dt),
                _sds((r, lab_n), jnp.int32), _sds((r, n_sub), dt),
                _sds((r, n_sub), jnp.int32))
    p_specs = shd.replicate_specs(a_params)
    o_specs = shd.replicate_specs(a_opt)
    rspec = lambda *rest: NamedSharding(mesh, P(dp, *rest))
    in_sh = (shd.named(mesh, p_specs), shd.named(mesh, o_specs),
             rspec(None, None), rspec(None), rspec(None, None),
             rspec(None, None), rspec(None), rspec(None), rspec(None))
    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name, step=train_step,
        abstract_inputs=abstract, in_shardings=in_sh,
        out_shardings=(shd.named(mesh, p_specs), shd.named(mesh, o_specs),
                       NamedSharding(mesh, P())),
        donate=(0, 1),
        meta={"replicas": r, "edges_per_replica": e_sub,
              "nodes_per_replica": n_sub})


# ======================================================== recsys cells ======

def _din_batch_abstract(cfg, batch: int):
    return {
        "user_id": _sds((batch,), jnp.int32),
        "hist_items": _sds((batch, cfg.seq_len), jnp.int32),
        "hist_cates": _sds((batch, cfg.seq_len), jnp.int32),
        "hist_mask": _sds((batch, cfg.seq_len), jnp.float32),
        "target_item": _sds((batch,), jnp.int32),
        "target_cate": _sds((batch,), jnp.int32),
    }


def _din_batch_specs(mesh: Mesh, sharded: bool):
    dp = shd.dp_axes(mesh)
    s1 = P(dp) if sharded else P()
    s2 = P(dp, None) if sharded else P(None, None)
    return {"user_id": s1, "hist_items": s2, "hist_cates": s2,
            "hist_mask": s2, "target_item": s1, "target_cate": s1}


def _din_cell(arch, shape, mesh: Mesh, cfg) -> Cell:
    batch = shape.dims.get("batch", 1)
    kind = shape.kind
    p_specs = shd.din_param_specs(mesh)
    a_params = _abstract_tree(
        lambda: din_mod.init_params(
            jax.random.PRNGKey(0), cfg))  # dynlint: allow[prng] shape-only
    dp = shd.dp_axes(mesh)
    sharded = batch >= _dp_size(mesh)

    if kind == "recsys_train":
        opt_cfg = adamw.AdamWConfig()

        def train_step(params, opt_state, batch_in, labels):
            loss, grads = jax.value_and_grad(
                lambda p: din_mod.ctr_loss(p, batch_in, labels))(params)
            params, opt_state = adamw.apply_updates(opt_cfg, params, grads,
                                                    opt_state)
            return params, opt_state, loss

        a_opt = _abstract_tree(adamw.init_state, a_params)
        o_specs = shd.opt_state_specs(p_specs)
        abstract = (a_params, a_opt, _din_batch_abstract(cfg, batch),
                    _sds((batch,), jnp.int32))
        in_sh = (shd.named(mesh, p_specs), shd.named(mesh, o_specs),
                 shd.named(mesh, _din_batch_specs(mesh, sharded)),
                 NamedSharding(mesh, P(dp)))
        return Cell(arch_id=arch.arch_id, shape_name=shape.name,
                    step=train_step, abstract_inputs=abstract,
                    in_shardings=in_sh,
                    out_shardings=(shd.named(mesh, p_specs),
                                   shd.named(mesh, o_specs),
                                   NamedSharding(mesh, P())),
                    donate=(0, 1), meta={"batch": batch})

    if kind == "recsys_serve":
        def serve_step(params, batch_in):
            return din_mod.forward(params, batch_in)

        abstract = (a_params, _din_batch_abstract(cfg, batch))
        out_spec = P(dp, None) if sharded else P(None, None)
        return Cell(arch_id=arch.arch_id, shape_name=shape.name,
                    step=serve_step, abstract_inputs=abstract,
                    in_shardings=(shd.named(mesh, p_specs),
                                  shd.named(mesh,
                                            _din_batch_specs(mesh, sharded))),
                    out_shardings=NamedSharding(mesh, out_spec),
                    meta={"batch": batch})

    # retrieval: one user, n_candidates scored, candidates DP-sharded
    n_cand = shape.dims["n_candidates"]

    def retrieval_step(params, batch_in, cand_items, cand_cates):
        return din_mod.score_candidates(params, batch_in, cand_items,
                                        cand_cates)

    abstract = (a_params, _din_batch_abstract(cfg, 1),
                _sds((n_cand,), jnp.int32), _sds((n_cand,), jnp.int32))
    return Cell(arch_id=arch.arch_id, shape_name=shape.name,
                step=retrieval_step, abstract_inputs=abstract,
                in_shardings=(shd.named(mesh, p_specs),
                              shd.named(mesh, _din_batch_specs(mesh, False)),
                              NamedSharding(mesh, P(dp)),
                              NamedSharding(mesh, P(dp))),
                out_shardings=NamedSharding(mesh, P(dp)),
                meta={"candidates": n_cand})


# ===================================================== dynamic-GNN cells ====

def _dyngnn_cell(arch, shape, mesh: Mesh, cfg) -> Cell:
    """The paper's workload: snapshot-partitioned, checkpointed train step."""
    import dataclasses

    from repro.core import partition

    d = shape.dims
    n = d["n_nodes"]
    t = d["n_steps"]
    e_pad = _round_up(d["edges_per_snap"] + n, 1024)
    dp = shd.dp_axes(mesh)
    dp_n = _dp_size(mesh)
    cfg = dataclasses.replace(cfg, num_nodes=n, num_steps=t)
    nb = cfg.checkpoint_blocks
    bsize = t // nb
    assert bsize % dp_n == 0 and n % dp_n == 0

    from repro.core import models as dyn_models
    opt_cfg = adamw.AdamWConfig()
    # optimized execution (SSPerf iteration on the paper's workload):
    # bf16 redistribution payloads + final-layer loss fused in the
    # vertex-sharded domain (one all-to-all elided per block)
    loss_sharded = partition.snapshot_partition_loss(
        cfg, mesh, axis=dp, comm_dtype=jnp.bfloat16, fuse_final=True)

    def train_step(params, opt_state, frames, edges, ew, labels):
        loss, grads = jax.value_and_grad(
            lambda p: loss_sharded(p, frames, edges, ew, labels))(params)
        params, opt_state = adamw.apply_updates(opt_cfg, params, grads,
                                                opt_state)
        return params, opt_state, loss

    a_params = _abstract_tree(
        lambda: dyn_models.init_params(
            jax.random.PRNGKey(0), cfg))  # dynlint: allow[prng] shape-only
    a_opt = _abstract_tree(adamw.init_state, a_params)
    f32 = jnp.float32
    abstract = (a_params, a_opt,
                _sds((nb, bsize, n, cfg.feat_in), f32),
                _sds((nb, bsize, e_pad, 2), jnp.int32),
                _sds((nb, bsize, e_pad), f32),
                _sds((nb, bsize, n), jnp.int32))
    p_specs = shd.replicate_specs(a_params)
    o_specs = shd.replicate_specs(a_opt)
    blk = NamedSharding(mesh, P(None, dp))
    # fused-loss layout: labels vertex-sharded (except evolvegcn)
    lab_sh = NamedSharding(mesh, P(None, None, dp)) \
        if cfg.model != "evolvegcn" else blk
    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name, step=train_step,
        abstract_inputs=abstract,
        in_shardings=(shd.named(mesh, p_specs), shd.named(mesh, o_specs),
                      blk, blk, blk, lab_sh),
        out_shardings=(shd.named(mesh, p_specs), shd.named(mesh, o_specs),
                       NamedSharding(mesh, P())),
        donate=(0, 1),
        meta={"edges_per_snap": e_pad, "nodes": n, "steps": t})


# ============================================================= dispatch =====

def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               smoke: bool = False,
               shape_override: dict | None = None,
               config_override: dict | None = None) -> Cell:
    arch = registry.get_arch(arch_id)
    shape = arch.shapes[shape_name]
    if shape_override:
        shape = registry.ShapeSpec(shape.name, shape.kind,
                                   {**shape.dims, **shape_override})
    cfg = arch.make_smoke_config() if smoke else arch.make_config()
    if config_override:
        import dataclasses
        cfg = dataclasses.replace(cfg, **config_override)
    if arch.family == "lm":
        if shape.kind == "train":
            return _lm_train_cell(arch, shape, mesh, cfg)
        if shape.kind == "prefill":
            return _lm_prefill_cell(arch, shape, mesh, cfg)
        if shape.kind == "decode":
            return _lm_decode_cell(arch, shape, mesh, cfg)
    if arch.family == "gnn":
        if shape.kind == "full_graph":
            return _gnn_full_graph_cell(arch, shape, mesh, cfg)
        if shape.kind == "minibatch":
            return _gnn_replica_cell(arch, shape, mesh, cfg, minibatch=True)
        if shape.kind == "molecule":
            return _gnn_replica_cell(arch, shape, mesh, cfg, minibatch=False)
    if arch.family == "recsys":
        return _din_cell(arch, shape, mesh, cfg)
    if arch.family == "dyngnn":
        return _dyngnn_cell(arch, shape, mesh, cfg)
    raise KeyError((arch_id, shape_name))


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch x shape) pairs + the paper's own cells."""
    out = []
    for arch_id, arch in registry.all_archs().items():
        for shape_name in arch.shapes:
            out.append((arch_id, shape_name))
    return out
