"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE first jax use.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh_compat
from repro.dist.sharding import DATA_AXIS, MODEL_AXIS, POD_AXIS


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ((POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod
            else (DATA_AXIS, MODEL_AXIS))
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data * model} devices, "
                         f"have {n}")
    return make_mesh_compat((data, model), (DATA_AXIS, MODEL_AXIS))


def mesh_device_count(mesh) -> int:
    out = 1
    for s in mesh.shape.values():
        out *= s
    return out
