"""``repro.run`` — the declarative training API.

One composable surface over the paper's pipeline (graph-diff transfer ->
snapshot-partitioned shard_map training):

    from repro.run import (Engine, ExecutionPlan, RunConfig,
                           SyntheticTrace)

    run = RunConfig(
        model=DynGNNConfig(model="tmgcn", num_nodes=128, num_steps=16),
        data=SyntheticTrace(num_nodes=128, num_steps=16,
                            smoothing_mode="mproduct", window=3),
        plan=ExecutionPlan(mode="streamed", num_epochs=2),
        seed=0)
    result = Engine(run).fit()        # -> RunResult(state, losses, ...)

The legacy entrypoints (``trainer.train_dyngnn`` /
``trainer.train_dyngnn_streamed``) remain as deprecation shims that
construct a ``RunConfig`` and call the Engine.

The ONLINE half of the surface is re-exported here too:
``ServeConfig -> ServeEngine`` (from ``repro.serve``) mirrors
``RunConfig -> Engine.fit()`` for inference against resident temporal
state — ``Engine.fit()`` trains the params, ``ServeEngine`` serves
them (``docs/serve_api.md``).

Full reference with runnable examples: ``docs/run_api.md`` (executed by
CI, so it cannot drift from this package); subsystem map and the
pipelined-round data flow: ``docs/architecture.md``.  The
``ExecutionPlan`` overlap knobs (``overlap`` / ``prefetch_depth`` /
``a2a_chunks`` / ``pipeline_rounds``) are pure schedule knobs — they
never change losses; so is the elastic rescale policy (``rescale`` /
``rescale_on_preempt`` — the snapshot-parallel width changes at
checkpoint-block boundaries, executed by ``repro.elastic`` and recorded
on ``RunResult.rescale_report``).
"""

from repro.elastic.controller import RescaleEvent, RescaleReport
from repro.hoststore import DeviceBudgetError, SampleReport, SamplingSpec
from repro.run.config import (CheckpointSpec, ResolvedRun, RunConfig,
                              RunResult)
from repro.run.data import (DataSource, EdgeListDTDG, InMemoryDTDG,
                            SyntheticTrace, pad_dataset, read_edgelist,
                            write_edgelist)
from repro.run.engine import Engine
from repro.run.plan import ExecutionPlan
# The serving counterpart of the training surface:
# ServeConfig -> ServeEngine mirrors RunConfig -> Engine.fit()
# (resident-state online inference; see docs/serve_api.md).
from repro.serve import IngestSpec, ServeConfig, ServeEngine, ServeResult

__all__ = [
    "CheckpointSpec", "DataSource", "DeviceBudgetError", "EdgeListDTDG",
    "Engine", "ExecutionPlan", "InMemoryDTDG", "IngestSpec",
    "RescaleEvent", "RescaleReport", "ResolvedRun", "RunConfig",
    "RunResult", "SampleReport", "SamplingSpec", "ServeConfig",
    "ServeEngine", "ServeResult", "SyntheticTrace", "pad_dataset",
    "read_edgelist", "write_edgelist",
]
