"""``repro.run`` — the declarative training API.

One composable surface over the paper's pipeline (graph-diff transfer ->
snapshot-partitioned shard_map training):

    from repro.run import (Engine, ExecutionPlan, RunConfig,
                           SyntheticTrace)

    run = RunConfig(
        model=DynGNNConfig(model="tmgcn", num_nodes=128, num_steps=16),
        data=SyntheticTrace(num_nodes=128, num_steps=16,
                            smoothing_mode="mproduct", window=3),
        plan=ExecutionPlan(mode="streamed", num_epochs=2),
        seed=0)
    result = Engine(run).fit()        # -> RunResult(state, losses, ...)

The legacy entrypoints (``trainer.train_dyngnn`` /
``trainer.train_dyngnn_streamed``) remain as deprecation shims that
construct a ``RunConfig`` and call the Engine.
"""

from repro.run.config import (CheckpointSpec, ResolvedRun, RunConfig,
                              RunResult)
from repro.run.data import (DataSource, EdgeListDTDG, InMemoryDTDG,
                            SyntheticTrace, pad_dataset, read_edgelist,
                            write_edgelist)
from repro.run.engine import Engine
from repro.run.plan import ExecutionPlan

__all__ = [
    "CheckpointSpec", "DataSource", "EdgeListDTDG", "Engine",
    "ExecutionPlan", "InMemoryDTDG", "ResolvedRun", "RunConfig",
    "RunResult", "SyntheticTrace", "pad_dataset", "read_edgelist",
    "write_edgelist",
]
