"""The Engine: one way to train.

``Engine(RunConfig).fit()`` replaces the four diverging entrypoints
(``trainer.train_dyngnn``, ``trainer.train_dyngnn_streamed``,
``stream.train_loop.train_streamed``,
``stream.distributed.train_distributed_streamed``):

    run = RunConfig(model=cfg,
                    data=SyntheticTrace(num_nodes=128, num_steps=16),
                    plan=ExecutionPlan(mode="streamed_mesh", shards=4))
    result = Engine(run).fit()       # -> RunResult

``resolve()`` is the one place mesh construction, vertex-axis padding,
timeline re-blocking, and pipeline building happen; ``fit()`` dispatches
the resolved bundle to the private workers; ``evaluate()`` runs the
paper's link-prediction protocol on the trained params; ``resume()`` is
an explicit restart from the configured checkpoint.
"""

from __future__ import annotations

import dataclasses

from repro import obs
from repro.data.dyngnn import DTDGPipeline
from repro.run import workers
from repro.run.config import ResolvedRun, RunConfig, RunResult
from repro.run.data import pad_dataset
from repro.train.trainer import TrainState


class Engine:
    """Declarative training driver for the dynamic-GNN workload."""

    def __init__(self, config: RunConfig):
        config.plan.validate()
        self.config = config
        self._resolved: ResolvedRun | None = None
        self._last: RunResult | None = None

    # ------------------------------------------------------ resolve -------

    def resolve(self) -> ResolvedRun:
        """Build (once) the bundle the workers consume."""
        if self._resolved is not None:
            return self._resolved
        c = self.config
        plan = c.plan
        if (c.checkpoint is not None
                and plan.mode not in ("eager", "streamed_mesh")):
            raise ValueError(
                "RunConfig.checkpoint is only wired for plan.mode='eager' "
                f"and 'streamed_mesh' (got {plan.mode!r}); the "
                "single-device streamed schedule does not checkpoint yet "
                "— drop the CheckpointSpec or switch modes")
        if c.checkpoint is not None and plan.compression != "none":
            raise ValueError(
                "RunConfig.checkpoint routes streamed_mesh through the "
                "elastic segment loop, which does not thread the "
                "error-feedback residuals of plan.compression="
                f"{plan.compression!r}; drop the CheckpointSpec or use "
                "compression='none'")

        nominal = c.data.num_nodes
        ds = None
        if nominal is None:               # e.g. edge-list file: read to learn
            ds = c.data.build()
            nominal = ds.num_nodes
        n = plan.padded_num_nodes(nominal, log_fn=c.log_fn)
        if ds is None:
            ds = c.data.build(num_nodes=n if n != nominal else None)
        elif n != nominal:                # already built: pad, don't rebuild
            ds = pad_dataset(ds, n)

        nb = plan.resolved_blocks(ds.num_steps, c.model.checkpoint_blocks,
                                  log_fn=c.log_fn)
        if plan.is_elastic:
            # every width the rescale policy can switch to must slice the
            # resolved block and the (possibly lcm-padded) vertex axis —
            # fail at resolve time, not three segments into the run
            import jax as _jax
            from repro.elastic.train import validate_widths
            validate_widths(plan.rescale_widths, win=ds.num_steps // nb,
                            num_nodes=ds.num_nodes,
                            num_devices=len(_jax.devices()))
        cfg = c.model
        if (cfg.num_nodes != ds.num_nodes or cfg.num_steps != ds.num_steps
                or cfg.checkpoint_blocks != nb):
            cfg = dataclasses.replace(cfg, num_nodes=ds.num_nodes,
                                      num_steps=ds.num_steps,
                                      checkpoint_blocks=nb)

        pipe = getattr(c.data, "pipeline", None)
        if pipe is None or pipe.ds is not ds or pipe.nb != nb:
            pipe = DTDGPipeline(ds, nb=nb)

        self._resolved = ResolvedRun(
            config=c, cfg=cfg, ds=ds, pipeline=pipe,
            mesh=plan.build_mesh(), plan=plan, opt_cfg=c.optimizer,
            seed=c.seed, checkpoint=c.checkpoint, log_every=c.log_every,
            log_fn=c.log_fn,
            padded_from=nominal if n != nominal else None)
        return self._resolved

    # ---------------------------------------------------------- fit -------

    def fit(self) -> RunResult:
        rr = self.resolve()
        worker = {"eager": workers.fit_eager,
                  "streamed": workers.fit_streamed,
                  "streamed_mesh": workers.fit_streamed_mesh,
                  "sampled": workers.fit_sampled}[rr.plan.mode]
        # scope the obs registry / span stream to this fit: the delta of
        # everything the worker increments and records becomes
        # RunResult.metrics (mirrors ServeEngine.result())
        base = obs.metrics_snapshot()
        trc = obs.get_tracer()
        spans0 = trc.recorded
        self._last = worker(rr)
        self._last.metrics = obs.metrics().delta(base)
        self._last.metrics["spans"] = trc.summary(trc.spans_since(spans0))
        return self._last

    def resume(self) -> RunResult:
        """Explicit restart from the configured checkpoint directory.

        streamed_mesh checkpoints are mesh-agnostic: the resuming plan
        may use a DIFFERENT snapshot-parallel width than the one the
        checkpoint was written at — the worker re-shards the restored
        carries onto the current mesh and re-slices the remaining delta
        streams from the saved cursor (``repro.elastic``).
        """
        rr = self.resolve()
        if rr.checkpoint is None:
            raise ValueError("resume() needs RunConfig.checkpoint")
        if rr.plan.mode not in ("eager", "streamed_mesh"):
            raise NotImplementedError("checkpoint resume is only wired for "
                                      "the eager and streamed_mesh "
                                      "schedules")
        from repro.ckpt.checkpoint import Checkpointer
        if Checkpointer(rr.checkpoint.directory).latest_step() is None:
            raise FileNotFoundError(
                f"no checkpoint under {rr.checkpoint.directory}")
        return self.fit()

    # ----------------------------------------------------- evaluate -------

    def evaluate(self, state: TrainState | RunResult | None = None,
                 test_snapshot=None, theta: float = 0.1,
                 seed: int = 0) -> float:
        """Link-prediction accuracy (paper §6.4) of trained params on the
        held-out ``test_snapshot`` (default: the trace's last snapshot)."""
        rr = self.resolve()
        if state is None:
            if self._last is None:
                raise ValueError("evaluate() before fit(): pass a state")
            state = self._last
        if isinstance(state, RunResult):
            state = state.state
        snap = rr.ds.snapshots[-1] if test_snapshot is None else test_snapshot
        from repro.train import trainer
        return trainer.evaluate_link_prediction(
            rr.cfg, state.params, rr.pipeline, snap, theta=theta, seed=seed)
