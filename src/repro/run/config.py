"""Run configuration: the one declarative description of a training run.

``RunConfig`` separates the three concerns the legacy entrypoints mixed
into 10+ positional-and-keyword arguments:

* ``model`` — the architecture config (``DynGNNConfig``).  Its
  ``num_nodes`` / ``num_steps`` are resolved against the data source
  (the data is authoritative; the plan may pad the vertex axis);
* ``data``  — a :class:`repro.run.data.DataSource`;
* ``plan``  — a :class:`repro.run.plan.ExecutionPlan`;

plus the optimizer, checkpoint, logging, and — at last — the PRNG
``seed`` that ``trainer.py`` used to hard-code as ``PRNGKey(0)``.

``Engine.resolve()`` turns a ``RunConfig`` into a ``ResolvedRun``: the
single bundle the private training workers consume instead of the old
positional-array plumbing.  ``Engine.fit()`` returns a ``RunResult``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.models import DynGNNConfig
from repro.data.dyngnn import DTDGDataset, DTDGPipeline
from repro.elastic.controller import RescaleReport
from repro.optim.adamw import AdamWConfig
from repro.run.data import DataSource
from repro.run.plan import ExecutionPlan
from repro.stream.encoder import StreamReport
from repro.train.trainer import TrainState


@dataclass(frozen=True)
class CheckpointSpec:
    """Where/how often to checkpoint.

    ``every`` counts eager steps on the eager schedule and rounds
    (= checkpoint blocks) on the streamed_mesh schedule; streamed_mesh
    checkpoints are mesh-agnostic, so a run saved at one width resumes
    onto any legal width (``repro.elastic``).
    """

    directory: str
    every: int = 50


@dataclass(frozen=True)
class RunConfig:
    model: DynGNNConfig
    data: DataSource
    plan: ExecutionPlan = ExecutionPlan()
    optimizer: AdamWConfig | None = None      # None = schedule default
    checkpoint: CheckpointSpec | None = None
    seed: int = 0                             # param-init PRNG seed
    log_every: int = 10
    log_fn: Callable[[str], None] = print


@dataclass
class ResolvedRun:
    """Everything a training worker needs, resolved once.

    The workers (``repro.run.workers``) take exactly this bundle — no
    re-plumbing of ``(snapshots, values, frames, labels, block_size,
    stats, max_edges, ...)`` per entrypoint.  ``cache`` holds compiled
    step functions and encoded shard streams so repeated ``fit()`` calls
    (benchmark epochs) do not re-trace or re-encode.
    """

    config: RunConfig
    cfg: DynGNNConfig               # model config w/ resolved N and T
    ds: DTDGDataset
    pipeline: DTDGPipeline
    mesh: Any                       # None for single-device schedules
    plan: ExecutionPlan
    opt_cfg: AdamWConfig | None
    seed: int
    checkpoint: CheckpointSpec | None
    log_every: int
    log_fn: Callable[[str], None]
    padded_from: int | None = None  # original num_nodes if auto-padded
    cache: dict = field(default_factory=dict)


@dataclass
class RunResult:
    """What ``Engine.fit()`` returns.

    ``losses`` is the per-step (eager / streamed) or per-round
    (streamed_mesh) loss stream; ``stream_report`` carries the encoder
    health counters of the streamed schedule (None otherwise);
    ``transfer_report`` is the graph-diff byte accounting
    (``DTDGPipeline.transfer_bytes()``); ``per_shard_bytes`` the
    per-device stream payloads of the streamed_mesh schedule.
    ``a2a_chunks`` / ``pipeline_rounds`` echo the overlap knobs the run
    actually executed with (pure schedule knobs — two results that
    differ only here carry identical ``losses``).  ``compression`` echoes
    the wire-compression mode (NOT a pure schedule knob: quantized runs
    drift within the bound pinned by tests/test_compression_drift.py;
    ``"none"`` stays bit-identical).  ``rescale_report``
    records the elastic events of a rescaled/checkpointed streamed_mesh
    run (realized width changes, per-segment stream bytes, preemption /
    resume cursors); rescaling is also pure schedule — the losses match
    the fixed-width run.  ``sample_report`` carries the sampled
    schedule's host-sampling accounting (staged bytes, dropped lanes,
    phase timings — ``repro.hoststore.SampleReport``); ``budget_report``
    echoes the ``device_budget_bytes`` gate the run passed
    (``{"required", "budget"}``, None when no budget was set).
    ``metrics`` is the ``repro.obs`` registry delta scoped to this fit
    (counters/gauges namespaced per ``docs/observability.md``) plus a
    per-name summary of the spans the fit recorded under ``"spans"``.
    """

    state: TrainState
    losses: list[float]
    stream_report: StreamReport | None = None
    transfer_report: dict | None = None
    per_shard_bytes: list[int] | None = None
    a2a_chunks: int = 1
    pipeline_rounds: bool = False
    compression: str = "none"
    rescale_report: RescaleReport | None = None
    sample_report: Any = None       # hoststore.SampleReport (sampled mode)
    budget_report: dict | None = None
    metrics: dict | None = None     # obs counter delta + span summary
