"""Private training workers behind ``Engine.fit()``.

Each worker consumes one :class:`repro.run.config.ResolvedRun` bundle
and drives the corresponding training loop:

* ``fit_eager``         — the blocked offline trainer (single-device or
  snapshot-partition shard_map), with async checkpointing, preemption
  guard, and straggler timing — the loop that used to live inside
  ``trainer.train_dyngnn``;
* ``fit_streamed``      — per-snapshot online training over the
  graph-diff delta stream (``repro.stream.train_loop``);
* ``fit_streamed_mesh`` — per-shard delta streams + snapshot-parallel
  shard_map (``repro.stream.distributed``); when the plan is elastic
  (``rescale`` / ``rescale_on_preempt``) or a checkpoint is configured
  it routes through ``repro.elastic.train_elastic_streamed`` — the
  segment loop that can change the snapshot-parallel width at
  checkpoint-block boundaries and checkpoint/resume the data cursor;
* ``fit_sampled``       — out-of-core sampled training
  (``repro.hoststore``): the trace stays host-resident in a
  ``TemporalCSRStore`` and only fanout-sampled subgraph tensors stream
  to the mesh.

Every worker first gates against ``plan.device_budget_bytes``
(``_budget_gate``) BEFORE allocating device graph tensors: full-graph
schedules refuse a graph whose resident tensors exceed the budget
(``DeviceBudgetError`` names the sampled schedule as the way out).

These are the ONLY call sites of the stream training loops outside the
deprecation shims; everything user-facing goes through the Engine.
Compiled steps and encoded shard streams are cached on the bundle so
repeated ``fit()`` calls (benchmark epochs, resume) reuse them.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.ckpt.checkpoint import Checkpointer
from repro.core import models as dyn_models
from repro.dist import compression as compression_lib
from repro.ft.elastic import PreemptionGuard
from repro.ft.straggler import StepTimer
from repro import hoststore
from repro.hoststore import budget as hostbudget
from repro.optim import adamw
from repro.run.config import ResolvedRun, RunResult
from repro.stream import distributed as stream_dist
from repro.stream import encoder as stream_enc
from repro.stream import train_loop as stream_train
from repro.train import trainer


def _init(rr: ResolvedRun):
    params = dyn_models.init_params(jax.random.PRNGKey(rr.seed), rr.cfg)
    return params, adamw.init_state(params)


def _budget_gate(rr: ResolvedRun, resolved=None) -> dict | None:
    """Gate the schedule against ``plan.device_budget_bytes`` BEFORE any
    device graph tensor is allocated (raises ``DeviceBudgetError`` when
    the resident graph tensors do not fit)."""
    plan = rr.plan
    return hostbudget.check_budget(
        plan.mode, plan.device_budget_bytes,
        num_steps=rr.ds.num_steps, win=rr.pipeline.bsize,
        num_shards=plan.num_shards, max_edges=rr.pipeline.max_edges,
        num_nodes=rr.ds.num_nodes,
        feat_dim=rr.ds.frames.shape[-1], resolved=resolved)


def fit_eager(rr: ResolvedRun) -> RunResult:
    plan = rr.plan
    budget = _budget_gate(rr)
    num_steps = plan.num_steps
    opt_cfg = rr.opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10, total_steps=num_steps, weight_decay=0.0)
    params, opt_state = _init(rr)
    start_step = 0
    ckpt = Checkpointer(rr.checkpoint.directory) if rr.checkpoint else None
    if ckpt and ckpt.latest_step() is not None:
        s = ckpt.latest_step()
        (params, opt_state), extra = ckpt.restore(s, (params, opt_state))
        start_step = extra.get("train_step", s)
        rr.log_fn(f"resumed from checkpoint step {start_step}")

    frames, edges, ew, labels = rr.pipeline.blocked_arrays()
    step_fn = rr.cache.get("eager_step")
    if rr.mesh is not None:
        if step_fn is None:
            step_fn = trainer.make_dyngnn_train_step(
                rr.cfg, rr.mesh, opt_cfg, a2a_chunks=plan.a2a_chunks)
            rr.cache["eager_step"] = step_fn
        args = (frames, edges, ew, labels)
    else:
        if step_fn is None:
            step_fn = trainer.make_single_device_train_step(rr.cfg, opt_cfg)
            rr.cache["eager_step"] = step_fn
        lab = labels.reshape((-1,) + labels.shape[2:])
        args = (rr.pipeline.batch, lab)

    timer = StepTimer()
    losses: list[float] = []
    with PreemptionGuard() as guard:
        for step in range(start_step, num_steps):
            with timer:
                params, opt_state, loss = step_fn(params, opt_state, *args)
            losses.append(float(loss))
            if step % rr.log_every == 0:
                rr.log_fn(f"step {step} loss {float(loss):.4f}")
            if ckpt and (step + 1) % rr.checkpoint.every == 0:
                ckpt.save(step + 1, (params, opt_state),
                          extra={"train_step": step + 1})
            if guard.preempted:
                rr.log_fn(f"preempted at step {step}; checkpointing and "
                          "exiting cleanly")
                if ckpt:
                    ckpt.save(step + 1, (params, opt_state),
                              extra={"train_step": step + 1},
                              blocking=True)
                break
    if ckpt:
        ckpt.wait()
    state = trainer.TrainState(
        params=params, opt_state=opt_state,
        step=min(num_steps, start_step + len(losses)))
    return RunResult(state=state, losses=losses,
                     transfer_report=rr.pipeline.transfer_bytes(),
                     a2a_chunks=plan.a2a_chunks, budget_report=budget)


def fit_streamed(rr: ResolvedRun) -> RunResult:
    plan, ds, pipe = rr.plan, rr.ds, rr.pipeline
    budget = _budget_gate(rr)
    opt_cfg = rr.opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10,
        total_steps=plan.num_epochs * ds.num_steps, weight_decay=0.0)
    params, opt_state = _init(rr)
    step_fn = rr.cache.get("stream_step")
    if step_fn is None:
        step_fn = stream_train.make_stream_train_step(rr.cfg, opt_cfg)
        rr.cache["stream_step"] = step_fn
    report = stream_enc.StreamReport()
    st = stream_train.train_streamed(
        rr.cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
        np.asarray(ds.labels), block_size=pipe.bsize,
        num_epochs=plan.num_epochs, overlap=plan.overlap,
        prefetch_depth=plan.prefetch_depth, opt_cfg=opt_cfg,
        params=params, opt_state=opt_state, stats=pipe.stream_stats,
        max_edges=pipe.max_edges, report=report, step_fn=step_fn,
        log_every=rr.log_every, log_fn=rr.log_fn)
    state = trainer.TrainState(params=st.params, opt_state=st.opt_state,
                               step=len(st.losses))
    return RunResult(state=state, losses=st.losses, stream_report=report,
                     transfer_report=pipe.transfer_bytes(),
                     budget_report=budget)


def fit_streamed_mesh(rr: ResolvedRun) -> RunResult:
    plan, ds, pipe = rr.plan, rr.ds, rr.pipeline
    budget = _budget_gate(rr)
    opt_cfg = rr.opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10,
        total_steps=plan.num_epochs * ds.num_steps, weight_decay=0.0)
    if plan.is_elastic or rr.checkpoint is not None:
        return _fit_streamed_mesh_elastic(rr, opt_cfg, budget)
    params, opt_state = _init(rr)
    step_fn = rr.cache.get("dist_step")
    if step_fn is None:
        step_fn = stream_dist.make_dist_stream_step(
            rr.cfg, rr.mesh, opt_cfg, plan.mesh_axis,
            a2a_chunks=plan.a2a_chunks, compression=plan.compression)
        rr.cache["dist_step"] = step_fn
    shard_streams = rr.cache.get("shard_streams")
    if shard_streams is None:
        shard_streams = pipe.sharded_streams(
            plan.num_shards, wire=compression_lib.wire_mode(plan.compression))
        rr.cache["shard_streams"] = shard_streams
    st = stream_dist.train_distributed_streamed(
        rr.cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
        np.asarray(ds.labels), mesh=rr.mesh, axis=plan.mesh_axis,
        block_size=pipe.bsize, num_epochs=plan.num_epochs,
        overlap=plan.overlap, prefetch_depth=plan.prefetch_depth,
        a2a_chunks=plan.a2a_chunks, pipeline_rounds=plan.pipeline_rounds,
        compression=plan.compression,
        opt_cfg=opt_cfg, params=params, opt_state=opt_state,
        stats=pipe.stream_stats, max_edges=pipe.max_edges,
        step_fn=step_fn, shard_streams=shard_streams,
        log_every=rr.log_every, log_fn=rr.log_fn)
    state = trainer.TrainState(params=st.params, opt_state=st.opt_state,
                               step=len(st.losses))
    return RunResult(state=state, losses=st.losses,
                     transfer_report=pipe.transfer_bytes(),
                     per_shard_bytes=st.per_shard_bytes,
                     a2a_chunks=plan.a2a_chunks,
                     pipeline_rounds=plan.pipeline_rounds,
                     compression=plan.compression,
                     budget_report=budget)


def _fit_streamed_mesh_elastic(rr: ResolvedRun, opt_cfg: adamw.AdamWConfig,
                               budget: dict | None = None) -> RunResult:
    """Elastic / checkpointed variant of the streamed_mesh schedule.

    Same round protocol, driven in constant-width segments by
    ``repro.elastic.train_elastic_streamed``: scripted rescales and
    SIGTERM shrinks recompose the stream at block boundaries, and a
    configured ``CheckpointSpec`` enables round-granular save + resume
    (onto any legal width — the checkpoint is mesh-agnostic).
    """
    from repro import elastic as el

    plan, ds, pipe = rr.plan, rr.ds, rr.pipeline
    params, opt_state = _init(rr)
    rt = rr.cache.get("elastic_runtime")
    if rt is None or rt.a2a_chunks != plan.a2a_chunks:
        rt = el.ElasticRuntime(rr.cfg, opt_cfg, plan.mesh_axis,
                               a2a_chunks=plan.a2a_chunks)
        rt.meshes.setdefault(plan.num_shards, rr.mesh)
        rr.cache["elastic_runtime"] = rt

    ckpt = Checkpointer(rr.checkpoint.directory) if rr.checkpoint else None
    rpe = ds.num_steps // pipe.bsize
    start, carries = 0, None
    if ckpt is not None and ckpt.latest_step() is not None:
        like = {"params": params, "opt": opt_state,
                "carries": dyn_models.init_carries(rr.cfg, params)}
        tree, extra = ckpt.restore(ckpt.latest_step(), like)
        start = int(extra.get("cursor", 0))
        saved_rpe = int(extra.get("rounds_per_epoch", rpe))
        if saved_rpe != rpe:
            # the cursor counts rounds of the ORIGINAL block size; under
            # a plan that re-blocks the timeline it would land mid-block
            # and silently skip (or repeat) snapshots
            raise ValueError(
                f"checkpoint under {rr.checkpoint.directory} was written "
                f"with {saved_rpe} rounds per epoch but this plan blocks "
                f"the timeline into {rpe}; resume with a shard width that "
                "preserves the checkpoint block size")
        params, opt_state = tree["params"], tree["opt"]
        # carries only matter mid-epoch; at an epoch boundary the loop
        # re-initializes them (the uninterrupted-run semantics)
        carries = tree["carries"] if start % rpe else None
        rr.log_fn(f"resumed streamed_mesh run at round {start} "
                  f"(checkpoint written at P={extra.get('p', '?')}, "
                  f"resuming on P={plan.num_shards})")

    # scripted boundaries BEFORE the resume cursor are history — realized
    # (and recorded) by the run that wrote the checkpoint; replaying them
    # would double-count the payload.  A boundary AT the cursor is still
    # pending: events realize at the top of the iteration for their
    # block, and checkpoints are written with cursor == segment end,
    # i.e. before that iteration ran.
    schedule = tuple((b, p) for b, p in plan.rescale if int(b) >= start)
    with PreemptionGuard() as guard:
        controller = el.RescaleController(
            initial_p=plan.num_shards, schedule=schedule, guard=guard,
            shrink_to=plan.rescale_on_preempt or None)
        st = el.train_elastic_streamed(
            rr.cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
            np.asarray(ds.labels), controller=controller,
            axis=plan.mesh_axis, block_size=pipe.bsize,
            num_epochs=plan.num_epochs, overlap=plan.overlap,
            prefetch_depth=plan.prefetch_depth,
            a2a_chunks=plan.a2a_chunks,
            pipeline_rounds=plan.pipeline_rounds, opt_cfg=opt_cfg,
            params=params, opt_state=opt_state, stats=pipe.stream_stats,
            max_edges=pipe.max_edges, runtime=rt, ckpt=ckpt,
            ckpt_every=(rr.checkpoint.every if rr.checkpoint else 0),
            start_cursor=start, carries=carries, log_every=rr.log_every,
            log_fn=rr.log_fn)

    # a COMPLETED run that never changed width has one well-defined
    # per-shard byte accounting: the first epoch's segments sum to
    # exactly the encoded stream (epochs replay the same streams, so —
    # like the fixed-width path — the stream is counted once, not per
    # epoch).  Rescaled, resumed, or preempted runs report per-segment
    # PLANNED payloads on the rescale_report instead (a preempted
    # segment's tail never actually streamed).
    per_shard = None
    if (st.completed and not st.report.events
            and st.report.resumed_from is None and st.report.segments):
        first_epoch = [seg for seg in st.report.segments if seg[0] < rpe]
        per_shard = [sum(seg[2][s] for seg in first_epoch)
                     for s in range(len(first_epoch[0][2]))]
    state = trainer.TrainState(params=st.params, opt_state=st.opt_state,
                               step=st.cursor)
    return RunResult(state=state, losses=st.losses,
                     transfer_report=pipe.transfer_bytes(),
                     per_shard_bytes=per_shard,
                     a2a_chunks=plan.a2a_chunks,
                     pipeline_rounds=plan.pipeline_rounds,
                     rescale_report=st.report,
                     budget_report=budget)


def fit_sampled(rr: ResolvedRun) -> RunResult:
    """Out-of-core sampled schedule: host-resident store + fanout-sampled
    subgraph streaming (``repro.hoststore.train_sampled``)."""
    plan, ds, pipe = rr.plan, rr.ds, rr.pipeline
    spec = plan.sampling
    resolved = spec.resolve(ds.num_nodes, pipe.bsize, plan.num_shards)
    budget = _budget_gate(rr, resolved)
    opt_cfg = rr.opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10,
        total_steps=plan.num_epochs * ds.num_steps, weight_decay=0.0)
    params, opt_state = _init(rr)
    store = rr.cache.get("host_store")
    if store is None:
        # SAME delta items as the device path: the store ingests the
        # pipeline's IncrementalEncoder stream, no second decode
        store = hoststore.TemporalCSRStore.from_stream(
            pipe.host_stream(), ds.num_nodes)
        rr.cache["host_store"] = store
    step_fn = rr.cache.get("sampled_step")
    if step_fn is None:
        step_fn = hoststore.make_sampled_step(
            rr.cfg, resolved, rr.mesh, opt_cfg, plan.mesh_axis,
            a2a_chunks=plan.a2a_chunks)
        rr.cache["sampled_step"] = step_fn
    st = hoststore.train_sampled(
        rr.cfg, store, np.asarray(ds.frames), np.asarray(ds.labels),
        spec=spec, mesh=rr.mesh, axis=plan.mesh_axis,
        block_size=pipe.bsize, num_epochs=plan.num_epochs,
        overlap=plan.overlap, prefetch_depth=plan.prefetch_depth,
        a2a_chunks=plan.a2a_chunks, opt_cfg=opt_cfg, params=params,
        opt_state=opt_state, step_fn=step_fn, seed=rr.seed,
        log_every=rr.log_every, log_fn=rr.log_fn)
    state = trainer.TrainState(params=st.params, opt_state=st.opt_state,
                               step=len(st.losses))
    return RunResult(state=state, losses=st.losses,
                     transfer_report=pipe.transfer_bytes(),
                     a2a_chunks=plan.a2a_chunks,
                     sample_report=st.report, budget_report=budget)
