"""Execution plans: the *how* of a training run.

An :class:`ExecutionPlan` owns everything the fractured entrypoints used
to hard-code in ``if args.stream / if mesh is not None`` branches:

* the schedule — ``eager`` (blocked offline trainer), ``streamed``
  (per-snapshot online training over the graph-diff delta stream), or
  ``streamed_mesh`` (per-shard delta streams + snapshot-parallel
  shard_map);
* mesh construction (or injection of a prebuilt mesh);
* the overlap/prefetch knobs of the streamed paths;
* the divisibility rules of the distributed paths — instead of dying
  with ``SystemExit`` the plan auto-pads ``num_nodes`` up to the next
  multiple of the mesh and re-blocks the timeline
  (``repro.ft.elastic.dyngnn_elastic_blocks``) when the checkpoint block
  does not divide over the shards, logging both adjustments;
* the elastic rescale policy (``rescale`` / ``rescale_on_preempt``) —
  WHEN the snapshot-parallel width changes mid-run; executed by
  ``repro.elastic`` at checkpoint-block boundaries;
* the out-of-core sampled schedule (``sampled``): the trace stays
  host-resident (``repro.hoststore``) and only fanout-sampled subgraphs
  stream to the mesh — ``sampling`` holds the :class:`SamplingSpec`,
  ``device_budget_bytes`` the simulated per-device graph-tensor budget
  every schedule is gated against (full-graph schedules refuse a graph
  that does not fit; sampling is how to train it anyway).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

from repro.hoststore.spec import SamplingSpec

MODES = ("eager", "streamed", "streamed_mesh", "sampled")
COMPRESSIONS = ("none", "int8_a2a", "int8_all")


@dataclass(frozen=True)
class ExecutionPlan:
    """Declarative execution spec, independent of model and data.

    ``shards`` is the snapshot-parallel width (data axis of the mesh);
    ``mesh`` may inject a prebuilt mesh instead (``shards`` is then
    ignored and read off the mesh).  ``num_steps`` drives the eager
    schedule, ``num_epochs`` the streamed ones.

    Overlap / pipelining knobs (all pure schedule knobs — they never
    change losses; see docs/run_api.md "Overlap & pipelining"):

    * ``overlap`` / ``prefetch_depth`` — host->device transfer overlap of
      the stream subsystem (background-thread encode + device_put);
    * ``a2a_chunks`` — chunk every shard_map redistribution into that
      many feature-sliced all-to-alls so the scheduler can overlap chunk
      c's transfer with chunk c-1's consumer compute (mesh schedules
      only; math-identical to the unchunked collective);
    * ``pipeline_rounds`` — streamed_mesh only: double-buffer the
      per-shard edge rings and dispatch round r+1's delta-apply +
      staging while round r's temporal-stage collectives execute
      (one round in flight; losses pinned to the serial schedule).

    Wire compression (streamed_mesh; NOT loss-pinned — drift is bounded
    by the numerics tier, tests/test_compression_drift.py):

    * ``compression`` — ``"int8_a2a"`` quantizes the two per-layer
      feature all-to-alls to int8 with per-shard error feedback
      (``repro.dist.compression``); ``"int8_all"`` additionally narrows
      the host->device delta wire format (``repro.stream.wire``).
      ``"none"`` (default) is bit-identical to the uncompressed trainer.

    Elastic rescale policy (streamed_mesh; executed by ``repro.elastic``,
    also pure schedule — losses stay pinned to the serial reference):

    * ``rescale`` — scripted ``((block, new_p), ...)`` events: the
      snapshot-parallel width changes to ``new_p`` at global round
      (= checkpoint-block) boundary ``block``;
    * ``rescale_on_preempt`` — shrink-to width: a SIGTERM mid-fit is
      absorbed by rescaling down to this width at the next boundary
      instead of stopping (0 = off; with a ``checkpoint`` configured and
      this off, SIGTERM checkpoints the cursor and exits cleanly).
    """

    mode: str = "eager"             # eager|streamed|streamed_mesh|sampled
    shards: int = 1
    mesh: Any = None                # optional prebuilt Mesh (tests/shims)
    mesh_axis: str = "data"
    num_steps: int = 100            # eager schedule length
    num_epochs: int = 1             # streamed passes over the trace
    overlap: bool = True
    prefetch_depth: int = 2
    a2a_chunks: int = 1             # chunked all-to-alls (mesh schedules)
    pipeline_rounds: bool = False   # round-level pipelining (streamed_mesh)
    compression: str = "none"       # wire compression (streamed_mesh)
    auto_pad: bool = True
    rescale: tuple = ()             # ((block, new_p), ...) resize script
    rescale_on_preempt: int = 0     # SIGTERM shrink-to width (0 = off)
    sampling: SamplingSpec | None = None    # sampled-schedule knobs
    device_budget_bytes: int | None = None  # simulated per-device budget

    def validate(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"plan.mode must be one of {MODES}, "
                             f"got {self.mode!r}")
        if self.mode == "sampled" and self.sampling is None:
            raise ValueError("mode='sampled' needs plan.sampling="
                             "SamplingSpec(batch_nodes, fanouts, ...)")
        if self.sampling is not None:
            if self.mode != "sampled":
                raise ValueError("plan.sampling configures the sampled "
                                 "schedule; it requires mode='sampled' "
                                 f"(got {self.mode!r})")
            self.sampling.validate()
        if (self.device_budget_bytes is not None
                and self.device_budget_bytes < 1):
            raise ValueError("plan.device_budget_bytes must be >= 1 "
                             "bytes (None = unlimited)")
        if self.shards < 1:
            raise ValueError(f"plan.shards must be >= 1, got {self.shards}")
        if self.prefetch_depth < 1:
            raise ValueError("plan.prefetch_depth must be >= 1")
        if self.a2a_chunks < 1:
            raise ValueError(f"plan.a2a_chunks must be >= 1, "
                             f"got {self.a2a_chunks}")
        if self.mode == "streamed" and (self.shards > 1
                                        or self.mesh is not None):
            raise ValueError("mode='streamed' is single-device; use "
                             "mode='streamed_mesh' for snapshot-parallel "
                             "streaming")
        if self.a2a_chunks > 1 and not self.wants_mesh:
            raise ValueError("plan.a2a_chunks chunks the shard_map "
                             "all-to-alls; this plan runs without a mesh "
                             f"(mode={self.mode!r}, shards="
                             f"{self.num_shards}) so there are none — "
                             "use a mesh schedule")
        if self.pipeline_rounds and self.mode != "streamed_mesh":
            raise ValueError("plan.pipeline_rounds pipelines the "
                             "distributed streamed round loop; it requires "
                             "mode='streamed_mesh'")
        if self.compression not in COMPRESSIONS:
            raise ValueError(f"plan.compression must be one of "
                             f"{COMPRESSIONS}, got {self.compression!r}")
        if self.compression != "none":
            if self.mode != "streamed_mesh":
                raise ValueError(
                    "plan.compression quantizes the distributed stream's "
                    "wire formats (shard_map all-to-alls + host->device "
                    "deltas); it requires mode='streamed_mesh' "
                    f"(got {self.mode!r})")
            if self.is_elastic:
                raise ValueError(
                    "plan.compression is not wired through the elastic "
                    "segment loop (error-feedback residuals would need "
                    "re-sharding at every rescale boundary); drop "
                    "rescale/rescale_on_preempt or use compression='none'")
        if self.rescale_on_preempt < 0:
            raise ValueError("plan.rescale_on_preempt is a shrink-to "
                             "width (0 = off); it cannot be negative")
        if ((self.rescale or self.rescale_on_preempt)
                and self.mode != "streamed_mesh"):
            raise ValueError("plan.rescale/rescale_on_preempt recompose "
                             "the distributed stream at checkpoint-block "
                             "boundaries; they require "
                             "mode='streamed_mesh'")
        if self.rescale:
            # the one schedule rule set, shared with RescaleController
            from repro.elastic.controller import validate_schedule
            validate_schedule(self.rescale)

    @property
    def rescale_widths(self) -> tuple:
        """Every width the elastic policy can switch to."""
        ws = tuple(int(p) for _, p in self.rescale)
        if self.rescale_on_preempt:
            ws += (self.rescale_on_preempt,)
        return ws

    @property
    def is_elastic(self) -> bool:
        """True when this plan can change width mid-run."""
        return bool(self.rescale) or self.rescale_on_preempt > 0

    @property
    def num_shards(self) -> int:
        if self.mesh is not None:
            return int(self.mesh.shape[self.mesh_axis])
        return self.shards

    @property
    def wants_mesh(self) -> bool:
        """True when this plan trains under a shard_map mesh."""
        return (self.mode in ("streamed_mesh", "sampled")
                or (self.mode == "eager" and self.num_shards > 1))

    def build_mesh(self):
        """The plan's mesh (prebuilt or constructed), or None."""
        if self.mesh is not None:
            return self.mesh
        if not self.wants_mesh:
            return None
        from repro.launch.mesh import make_host_mesh
        return make_host_mesh(data=self.num_shards, model=1)

    # ---------------------------------------------- divisibility ----------

    def padded_num_nodes(self, num_nodes: int,
                         log_fn: Callable[[str], None] | None = None) -> int:
        """``num_nodes`` rounded up to the next multiple of the mesh.

        The vertex-sharded temporal stage needs N % P == 0; rather than
        refusing to run (the old launcher raised ``SystemExit``) the plan
        pads the vertex axis with isolated nodes and logs the padding.
        An elastic plan pads to the lcm of EVERY width its rescale policy
        can switch to, so the vertex axis stays divisible mid-run.
        """
        p = self.num_shards
        for w in self.rescale_widths:
            p = math.lcm(p, w)
        if self.mode == "sampled":
            # the temporal stage runs over the round node TABLE, which
            # SamplingSpec.resolve pads to the mesh — the global vertex
            # axis never has to divide (that's the point of sampling)
            return num_nodes
        if not self.wants_mesh or num_nodes % p == 0:
            return num_nodes
        if not self.auto_pad:
            raise ValueError(f"num_nodes {num_nodes} must divide over "
                             f"{p} shards (set plan.auto_pad=True to pad)")
        padded = ((num_nodes + p - 1) // p) * p
        if log_fn is not None:
            log_fn(f"plan: auto-padding num_nodes {num_nodes} -> {padded} "
                   f"(next multiple of {p} shards)")
        return padded

    def resolved_blocks(self, num_steps: int, checkpoint_blocks: int,
                        log_fn: Callable[[str], None] | None = None) -> int:
        """Checkpoint-block count adjusted for the streamed mesh.

        ``streamed_mesh`` and ``sampled`` need ``bsize % P == 0`` and
        ``T % bsize == 0`` (each round is one block, sliced over the
        shards).  When the
        requested blocking violates that, re-block via
        ``repro.ft.elastic.dyngnn_elastic_blocks`` (largest legal block
        <= the requested one) and log the adjustment.
        """
        if self.mode not in ("streamed_mesh", "sampled"):
            return checkpoint_blocks
        p = self.num_shards
        nb = max(checkpoint_blocks, 1)
        bsize = num_steps // nb
        if bsize >= 1 and num_steps % bsize == 0 and bsize % p == 0:
            return nb
        if num_steps % p:
            raise ValueError(
                f"trace length {num_steps} cannot be sliced over {p} "
                "snapshot shards (num_steps % shards != 0)")
        from repro.ft.elastic import dyngnn_elastic_blocks
        nb2, bsize2 = dyngnn_elastic_blocks(num_steps, p, max(bsize, p))
        if log_fn is not None:
            log_fn(f"plan: re-blocking timeline for {p} shards: "
                   f"checkpoint_blocks {checkpoint_blocks} -> {nb2} "
                   f"(block size {bsize2})")
        return nb2
