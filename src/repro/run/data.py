"""Data sources: the *what* of a training run.

A :class:`DataSource` yields a ``DTDGDataset`` — the Engine asks it to
build (optionally at a padded ``num_nodes``, see
``ExecutionPlan.padded_num_nodes``) and owns nothing else.  Three
implementations cover the current workloads:

* :class:`SyntheticTrace` — the evolving synthetic DTDG generator
  (``repro.data.dyngnn.synthetic_dataset``) as a declarative spec;
* :class:`EdgeListDTDG` — timestamped edge-list files (``.tsv`` /
  ``.npz``) loaded into a ``DTDGDataset``: the on-ramp for the paper's
  epinions/flickr/youtube traces, which ship in exactly this form;
* :class:`InMemoryDTDG` — wrap an already-built dataset (and optionally
  its pipeline) — what the legacy-entrypoint shims use.

``write_edgelist`` is the matching writer, used by the round-trip tests
and for exporting synthetic traces.
"""

from __future__ import annotations

import re
import zipfile
from dataclasses import dataclass
from pathlib import Path
from typing import Protocol, runtime_checkable

import numpy as np

from repro.data.dyngnn import (DTDGDataset, DTDGPipeline,
                               dataset_from_snapshots, synthetic_dataset)


@runtime_checkable
class DataSource(Protocol):
    """Anything that can build a ``DTDGDataset`` on demand.

    ``num_nodes`` is the source's nominal vertex count (None when only
    known after reading, e.g. an edge-list file); ``build(num_nodes=n)``
    must honor an override >= the nominal count (vertex-axis padding).
    """

    num_nodes: int | None

    def build(self, num_nodes: int | None = None) -> DTDGDataset:
        ...


def pad_dataset(ds: DTDGDataset, num_nodes: int) -> DTDGDataset:
    """Append isolated vertices (zero features, class-0 labels) up to
    ``num_nodes`` — the padding contract of ``ExecutionPlan``'s
    vertex-axis auto-pad.  The edge lists (and therefore the trained
    graph) are untouched."""
    if num_nodes == ds.num_nodes:
        return ds
    if num_nodes < ds.num_nodes:
        raise ValueError(f"cannot shrink dataset from {ds.num_nodes} to "
                         f"{num_nodes} nodes")
    t = ds.frames.shape[0]
    extra = num_nodes - ds.num_nodes
    frames = np.concatenate(
        [ds.frames, np.zeros((t, extra, ds.frames.shape[2]),
                             dtype=ds.frames.dtype)], axis=1)
    labels = np.concatenate(
        [ds.labels, np.zeros((t, extra), dtype=ds.labels.dtype)], axis=1)
    return DTDGDataset(snapshots=ds.snapshots, values=ds.values,
                       frames=frames, labels=labels, num_nodes=num_nodes)


@dataclass(frozen=True)
class SyntheticTrace:
    """Spec for ``repro.data.dyngnn.synthetic_dataset``.

    A ``num_nodes`` override pads the NOMINAL trace with isolated
    vertices (same graph, same labels) — it never regenerates a
    different random graph.
    """

    num_nodes: int
    num_steps: int
    density: float = 3.0
    churn: float = 0.1
    smoothing_mode: str = "none"    # none | mproduct | edgelife
    window: int = 5
    edge_life: int = 5
    seed: int = 0

    def build(self, num_nodes: int | None = None) -> DTDGDataset:
        ds = synthetic_dataset(
            self.num_nodes, self.num_steps, density=self.density,
            churn=self.churn, smoothing_mode=self.smoothing_mode,
            window=self.window, edge_life=self.edge_life, seed=self.seed)
        if num_nodes is not None:
            ds = pad_dataset(ds, num_nodes)
        return ds


@dataclass(frozen=True)
class EdgeListDTDG:
    """Timestamped edge-list loader: ``(src, dst, t)`` rows -> DTDG.

    Formats (selected by extension):

    * ``.npz`` — arrays ``src``, ``dst``, ``t`` (or one ``edges`` array
      of shape (E, 3));
    * anything else — whitespace/tab-separated text, one ``src dst t``
      row per edge, ``#`` comments allowed.

    Snapshot ``k`` holds the file-order edges with ``t == t_min + k``
    (timestamps are treated as consecutive integer bins; empty bins make
    empty snapshots).  Smoothing / features / labels are derived exactly
    as for the synthetic traces (``dataset_from_snapshots``), so a
    written-then-loaded trace trains bit-identically to its in-memory
    original.

    ``chunk_edges`` switches the read out-of-core: text files stream
    line-by-line in ``chunk_edges``-row chunks and ``.npz`` members are
    memory-mapped straight out of the archive (``_npz_memmaps``) — the
    monolithic ``(E, 3)`` int64 row table is never materialized, only
    the per-snapshot int32 edge lists.  The binned result is identical
    to the in-memory read (round-trip tested).
    """

    path: str
    num_nodes: int | None = None
    smoothing_mode: str = "none"
    window: int = 5
    edge_life: int = 5
    chunk_edges: int | None = None  # out-of-core read: rows per chunk

    def build(self, num_nodes: int | None = None) -> DTDGDataset:
        snaps, n_seen = read_edgelist(self.path,
                                      chunk_edges=self.chunk_edges)
        nominal = self.num_nodes or n_seen
        if nominal < n_seen:
            raise ValueError(f"num_nodes={nominal} but {self.path} "
                             f"references node ids up to {n_seen - 1}")
        # labels/features derive from the NOMINAL node count; a padding
        # override appends isolated vertices afterwards so pad nodes can
        # never shift the label median of the real ones
        ds = dataset_from_snapshots(
            snaps, nominal, smoothing_mode=self.smoothing_mode,
            window=self.window, edge_life=self.edge_life)
        if num_nodes is not None:
            ds = pad_dataset(ds, num_nodes)
        return ds


@dataclass
class InMemoryDTDG:
    """Wrap an existing ``DTDGDataset`` (and optionally its pipeline).

    Padding appends isolated vertices (zero features, class-0 labels);
    the edge lists are untouched, so an unpadded build is the original
    dataset object and any attached pipeline can be reused as-is.
    """

    ds: DTDGDataset
    pipeline: DTDGPipeline | None = None

    @property
    def num_nodes(self) -> int:
        return self.ds.num_nodes

    def build(self, num_nodes: int | None = None) -> DTDGDataset:
        if num_nodes is None:
            return self.ds
        return pad_dataset(self.ds, num_nodes)


# ------------------------------------------------ edge-list file I/O -------

def _tsv_num_steps(path: Path) -> int | None:
    """``num_steps=K`` from the header comment, if the file carries one."""
    with open(path) as f:
        first = f.readline()
    if first.startswith("#"):
        m = re.search(r"num_steps=(\d+)", first)
        if m:
            return int(m.group(1))
    return None


def read_edgelist(path: str | Path,
                  chunk_edges: int | None = None
                  ) -> tuple[list[np.ndarray], int]:
    """(snapshots, min num_nodes) from a timestamped edge-list file.

    Files written by ``write_edgelist`` carry a ``num_steps`` marker
    (npz key / tsv header comment) so that empty snapshots — including
    leading/trailing ones — round-trip exactly.  External files without
    the marker are binned over ``[t.min(), t.max()]``: empty bins inside
    that span become empty snapshots, but empty bins outside it are
    unknowable and dropped.

    ``chunk_edges`` enables the out-of-core read path (chunked text
    scan / zip-member memmap) — same snapshots, bounded peak memory.
    """
    path = Path(path)
    if chunk_edges is not None:
        return _read_edgelist_chunked(path, chunk_edges)
    num_steps = None
    if path.suffix == ".npz":
        with np.load(path) as z:
            if "edges" in z:
                rows = np.asarray(z["edges"], dtype=np.int64)
                src, dst, t = rows[:, 0], rows[:, 1], rows[:, 2]
            else:
                src = np.asarray(z["src"], dtype=np.int64)
                dst = np.asarray(z["dst"], dtype=np.int64)
                t = np.asarray(z["t"], dtype=np.int64)
            if "num_steps" in z:
                num_steps = int(z["num_steps"])
    else:
        rows = np.loadtxt(path, dtype=np.int64, comments="#", ndmin=2)
        if rows.shape[1] != 3:
            raise ValueError(f"{path}: expected 'src dst t' rows, got "
                             f"{rows.shape[1]} columns")
        src, dst, t = rows[:, 0], rows[:, 1], rows[:, 2]
        num_steps = _tsv_num_steps(path)
    if src.shape[0] == 0:
        raise ValueError(f"{path}: empty edge list")
    if src.min() < 0 or dst.min() < 0:
        raise ValueError(f"{path}: negative node ids")
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    if num_steps is not None:
        if t.min() < 0 or t.max() >= num_steps:
            raise ValueError(f"{path}: timestamps outside the declared "
                             f"num_steps={num_steps}")
        bins = range(0, num_steps)
    else:
        bins = range(int(t.min()), int(t.max()) + 1)
    snaps = [edges[t == v] for v in bins]
    return snaps, int(max(src.max(), dst.max())) + 1


def write_edgelist(path: str | Path,
                   snapshots: list[np.ndarray]) -> None:
    """Write snapshots as a timestamped edge list (exact inverse of
    ``read_edgelist`` up to the edge dtype: a ``num_steps`` marker keeps
    empty snapshots, snapshot k is stamped ``t=k`` in row order)."""
    path = Path(path)
    num_steps = len(snapshots)
    src = np.concatenate([np.asarray(s[:, 0], dtype=np.int64)
                          for s in snapshots])
    dst = np.concatenate([np.asarray(s[:, 1], dtype=np.int64)
                          for s in snapshots])
    t = np.concatenate([np.full((s.shape[0],), i, dtype=np.int64)
                        for i, s in enumerate(snapshots)])
    if path.suffix == ".npz":
        np.savez(path, src=src, dst=dst, t=t,
                 num_steps=np.int64(num_steps))
        return
    rows = np.stack([src, dst, t], axis=1)
    np.savetxt(path, rows, fmt="%d", delimiter="\t",
               header=f"src\tdst\tt\tnum_steps={num_steps}")


# --------------------------------------------- out-of-core read path -------

def _npz_memmaps(path: Path) -> dict[str, np.ndarray] | None:
    """Zero-copy ``np.memmap`` views of an UNCOMPRESSED npz's members.

    ``np.load(..., mmap_mode="r")`` silently ignores the mmap request
    for ``.npz`` archives (it only ever mmaps bare ``.npy`` files), so
    this locates each stored member's ``.npy`` payload inside the zip —
    local file header at ``ZipInfo.header_offset``, then the npy header
    — and maps the data region of the ARCHIVE file directly.  Returns
    None when any member is deflated (no contiguous bytes to map; the
    caller falls back to a regular load).
    """
    out: dict[str, np.ndarray] = {}
    with zipfile.ZipFile(path) as z, open(path, "rb") as raw:
        for zi in z.infolist():
            if zi.compress_type != zipfile.ZIP_STORED:
                return None
            # local header: 30 fixed bytes + name + extra (the extra
            # field can differ from the central directory's, so read it)
            raw.seek(zi.header_offset)
            hdr = raw.read(30)
            if hdr[:4] != b"PK\x03\x04":
                return None
            name_len = int.from_bytes(hdr[26:28], "little")
            extra_len = int.from_bytes(hdr[28:30], "little")
            raw.seek(zi.header_offset + 30 + name_len + extra_len)
            version = np.lib.format.read_magic(raw)
            if version == (1, 0):
                shape, fortran, dtype = np.lib.format.read_array_header_1_0(
                    raw)
            elif version == (2, 0):
                shape, fortran, dtype = np.lib.format.read_array_header_2_0(
                    raw)
            else:
                return None
            name = zi.filename
            if name.endswith(".npy"):
                name = name[:-4]
            out[name] = np.memmap(path, dtype=dtype, mode="r",
                                  offset=raw.tell(), shape=shape,
                                  order="F" if fortran else "C")
    return out


def _iter_tsv_chunks(path: Path, chunk_edges: int):
    """Yield ``(<=chunk_edges, 3)`` int64 row blocks from a text edge
    list without ever holding the whole table."""
    buf: list[tuple[int, int, int]] = []
    with open(path) as f:
        for line in f:
            s = line.strip()
            if not s or s.startswith("#"):
                continue
            parts = s.split()
            if len(parts) != 3:
                raise ValueError(f"{path}: expected 'src dst t' rows, "
                                 f"got {len(parts)} columns")
            buf.append((int(parts[0]), int(parts[1]), int(parts[2])))
            if len(buf) >= chunk_edges:
                yield np.asarray(buf, dtype=np.int64)
                buf = []
    if buf:
        yield np.asarray(buf, dtype=np.int64)


def _iter_array_chunks(src, dst, t, chunk_edges: int):
    """Yield row blocks from (possibly memory-mapped) column arrays —
    each chunk is the only region pulled into memory."""
    n = src.shape[0]
    for lo in range(0, n, chunk_edges):
        hi = min(lo + chunk_edges, n)
        yield np.stack([np.asarray(src[lo:hi], dtype=np.int64),
                        np.asarray(dst[lo:hi], dtype=np.int64),
                        np.asarray(t[lo:hi], dtype=np.int64)], axis=1)


def _read_edgelist_chunked(path: Path, chunk_edges: int
                           ) -> tuple[list[np.ndarray], int]:
    """Out-of-core ``read_edgelist``: same snapshots, bounded memory."""
    if chunk_edges < 1:
        raise ValueError(f"chunk_edges must be >= 1, got {chunk_edges}")
    num_steps = None
    if path.suffix == ".npz":
        arrs = _npz_memmaps(path)
        if arrs is None:    # deflated archive: no mappable bytes
            with np.load(path) as z:
                arrs = {k: z[k] for k in z.files}
        if "edges" in arrs:
            rows = arrs["edges"]
            src, dst, t = rows[:, 0], rows[:, 1], rows[:, 2]
        else:
            src, dst, t = arrs["src"], arrs["dst"], arrs["t"]
        if "num_steps" in arrs:
            num_steps = int(np.asarray(arrs["num_steps"]))
        chunks = _iter_array_chunks(src, dst, t, chunk_edges)
    else:
        num_steps = _tsv_num_steps(path)
        chunks = _iter_tsv_chunks(path, chunk_edges)

    # bin incrementally: per chunk, file-order edge runs per timestamp;
    # concatenating runs in chunk order preserves file order per bin
    parts: dict[int, list[np.ndarray]] = {}
    total, n_seen = 0, 0
    t_lo = t_hi = None
    for rows in chunks:
        if rows.shape[0] == 0:
            continue
        s, d, tt = rows[:, 0], rows[:, 1], rows[:, 2]
        if s.min() < 0 or d.min() < 0:
            raise ValueError(f"{path}: negative node ids")
        total += rows.shape[0]
        n_seen = max(n_seen, int(s.max()) + 1, int(d.max()) + 1)
        lo, hi = int(tt.min()), int(tt.max())
        t_lo = lo if t_lo is None else min(t_lo, lo)
        t_hi = hi if t_hi is None else max(t_hi, hi)
        edges = np.stack([s, d], axis=1).astype(np.int32)
        for v in np.unique(tt):
            parts.setdefault(int(v), []).append(edges[tt == v])
    if total == 0:
        raise ValueError(f"{path}: empty edge list")
    if num_steps is not None:
        if t_lo < 0 or t_hi >= num_steps:
            raise ValueError(f"{path}: timestamps outside the declared "
                             f"num_steps={num_steps}")
        bins = range(0, num_steps)
    else:
        bins = range(t_lo, t_hi + 1)
    empty = np.zeros((0, 2), dtype=np.int32)
    snaps = [np.concatenate(parts[v], axis=0) if v in parts else empty
             for v in bins]
    return snaps, n_seen
