"""Data sources: the *what* of a training run.

A :class:`DataSource` yields a ``DTDGDataset`` — the Engine asks it to
build (optionally at a padded ``num_nodes``, see
``ExecutionPlan.padded_num_nodes``) and owns nothing else.  Three
implementations cover the current workloads:

* :class:`SyntheticTrace` — the evolving synthetic DTDG generator
  (``repro.data.dyngnn.synthetic_dataset``) as a declarative spec;
* :class:`EdgeListDTDG` — timestamped edge-list files (``.tsv`` /
  ``.npz``) loaded into a ``DTDGDataset``: the on-ramp for the paper's
  epinions/flickr/youtube traces, which ship in exactly this form;
* :class:`InMemoryDTDG` — wrap an already-built dataset (and optionally
  its pipeline) — what the legacy-entrypoint shims use.

``write_edgelist`` is the matching writer, used by the round-trip tests
and for exporting synthetic traces.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from pathlib import Path
from typing import Protocol, runtime_checkable

import numpy as np

from repro.data.dyngnn import (DTDGDataset, DTDGPipeline,
                               dataset_from_snapshots, synthetic_dataset)


@runtime_checkable
class DataSource(Protocol):
    """Anything that can build a ``DTDGDataset`` on demand.

    ``num_nodes`` is the source's nominal vertex count (None when only
    known after reading, e.g. an edge-list file); ``build(num_nodes=n)``
    must honor an override >= the nominal count (vertex-axis padding).
    """

    num_nodes: int | None

    def build(self, num_nodes: int | None = None) -> DTDGDataset:
        ...


def pad_dataset(ds: DTDGDataset, num_nodes: int) -> DTDGDataset:
    """Append isolated vertices (zero features, class-0 labels) up to
    ``num_nodes`` — the padding contract of ``ExecutionPlan``'s
    vertex-axis auto-pad.  The edge lists (and therefore the trained
    graph) are untouched."""
    if num_nodes == ds.num_nodes:
        return ds
    if num_nodes < ds.num_nodes:
        raise ValueError(f"cannot shrink dataset from {ds.num_nodes} to "
                         f"{num_nodes} nodes")
    t = ds.frames.shape[0]
    extra = num_nodes - ds.num_nodes
    frames = np.concatenate(
        [ds.frames, np.zeros((t, extra, ds.frames.shape[2]),
                             dtype=ds.frames.dtype)], axis=1)
    labels = np.concatenate(
        [ds.labels, np.zeros((t, extra), dtype=ds.labels.dtype)], axis=1)
    return DTDGDataset(snapshots=ds.snapshots, values=ds.values,
                       frames=frames, labels=labels, num_nodes=num_nodes)


@dataclass(frozen=True)
class SyntheticTrace:
    """Spec for ``repro.data.dyngnn.synthetic_dataset``.

    A ``num_nodes`` override pads the NOMINAL trace with isolated
    vertices (same graph, same labels) — it never regenerates a
    different random graph.
    """

    num_nodes: int
    num_steps: int
    density: float = 3.0
    churn: float = 0.1
    smoothing_mode: str = "none"    # none | mproduct | edgelife
    window: int = 5
    edge_life: int = 5
    seed: int = 0

    def build(self, num_nodes: int | None = None) -> DTDGDataset:
        ds = synthetic_dataset(
            self.num_nodes, self.num_steps, density=self.density,
            churn=self.churn, smoothing_mode=self.smoothing_mode,
            window=self.window, edge_life=self.edge_life, seed=self.seed)
        if num_nodes is not None:
            ds = pad_dataset(ds, num_nodes)
        return ds


@dataclass(frozen=True)
class EdgeListDTDG:
    """Timestamped edge-list loader: ``(src, dst, t)`` rows -> DTDG.

    Formats (selected by extension):

    * ``.npz`` — arrays ``src``, ``dst``, ``t`` (or one ``edges`` array
      of shape (E, 3));
    * anything else — whitespace/tab-separated text, one ``src dst t``
      row per edge, ``#`` comments allowed.

    Snapshot ``k`` holds the file-order edges with ``t == t_min + k``
    (timestamps are treated as consecutive integer bins; empty bins make
    empty snapshots).  Smoothing / features / labels are derived exactly
    as for the synthetic traces (``dataset_from_snapshots``), so a
    written-then-loaded trace trains bit-identically to its in-memory
    original.
    """

    path: str
    num_nodes: int | None = None
    smoothing_mode: str = "none"
    window: int = 5
    edge_life: int = 5

    def build(self, num_nodes: int | None = None) -> DTDGDataset:
        snaps, n_seen = read_edgelist(self.path)
        nominal = self.num_nodes or n_seen
        if nominal < n_seen:
            raise ValueError(f"num_nodes={nominal} but {self.path} "
                             f"references node ids up to {n_seen - 1}")
        # labels/features derive from the NOMINAL node count; a padding
        # override appends isolated vertices afterwards so pad nodes can
        # never shift the label median of the real ones
        ds = dataset_from_snapshots(
            snaps, nominal, smoothing_mode=self.smoothing_mode,
            window=self.window, edge_life=self.edge_life)
        if num_nodes is not None:
            ds = pad_dataset(ds, num_nodes)
        return ds


@dataclass
class InMemoryDTDG:
    """Wrap an existing ``DTDGDataset`` (and optionally its pipeline).

    Padding appends isolated vertices (zero features, class-0 labels);
    the edge lists are untouched, so an unpadded build is the original
    dataset object and any attached pipeline can be reused as-is.
    """

    ds: DTDGDataset
    pipeline: DTDGPipeline | None = None

    @property
    def num_nodes(self) -> int:
        return self.ds.num_nodes

    def build(self, num_nodes: int | None = None) -> DTDGDataset:
        if num_nodes is None:
            return self.ds
        return pad_dataset(self.ds, num_nodes)


# ------------------------------------------------ edge-list file I/O -------

def _tsv_num_steps(path: Path) -> int | None:
    """``num_steps=K`` from the header comment, if the file carries one."""
    with open(path) as f:
        first = f.readline()
    if first.startswith("#"):
        m = re.search(r"num_steps=(\d+)", first)
        if m:
            return int(m.group(1))
    return None


def read_edgelist(path: str | Path) -> tuple[list[np.ndarray], int]:
    """(snapshots, min num_nodes) from a timestamped edge-list file.

    Files written by ``write_edgelist`` carry a ``num_steps`` marker
    (npz key / tsv header comment) so that empty snapshots — including
    leading/trailing ones — round-trip exactly.  External files without
    the marker are binned over ``[t.min(), t.max()]``: empty bins inside
    that span become empty snapshots, but empty bins outside it are
    unknowable and dropped.
    """
    path = Path(path)
    num_steps = None
    if path.suffix == ".npz":
        with np.load(path) as z:
            if "edges" in z:
                rows = np.asarray(z["edges"], dtype=np.int64)
                src, dst, t = rows[:, 0], rows[:, 1], rows[:, 2]
            else:
                src = np.asarray(z["src"], dtype=np.int64)
                dst = np.asarray(z["dst"], dtype=np.int64)
                t = np.asarray(z["t"], dtype=np.int64)
            if "num_steps" in z:
                num_steps = int(z["num_steps"])
    else:
        rows = np.loadtxt(path, dtype=np.int64, comments="#", ndmin=2)
        if rows.shape[1] != 3:
            raise ValueError(f"{path}: expected 'src dst t' rows, got "
                             f"{rows.shape[1]} columns")
        src, dst, t = rows[:, 0], rows[:, 1], rows[:, 2]
        num_steps = _tsv_num_steps(path)
    if src.shape[0] == 0:
        raise ValueError(f"{path}: empty edge list")
    if src.min() < 0 or dst.min() < 0:
        raise ValueError(f"{path}: negative node ids")
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    if num_steps is not None:
        if t.min() < 0 or t.max() >= num_steps:
            raise ValueError(f"{path}: timestamps outside the declared "
                             f"num_steps={num_steps}")
        bins = range(0, num_steps)
    else:
        bins = range(int(t.min()), int(t.max()) + 1)
    snaps = [edges[t == v] for v in bins]
    return snaps, int(max(src.max(), dst.max())) + 1


def write_edgelist(path: str | Path,
                   snapshots: list[np.ndarray]) -> None:
    """Write snapshots as a timestamped edge list (exact inverse of
    ``read_edgelist`` up to the edge dtype: a ``num_steps`` marker keeps
    empty snapshots, snapshot k is stamped ``t=k`` in row order)."""
    path = Path(path)
    num_steps = len(snapshots)
    src = np.concatenate([np.asarray(s[:, 0], dtype=np.int64)
                          for s in snapshots])
    dst = np.concatenate([np.asarray(s[:, 1], dtype=np.int64)
                          for s in snapshots])
    t = np.concatenate([np.full((s.shape[0],), i, dtype=np.int64)
                        for i, s in enumerate(snapshots)])
    if path.suffix == ".npz":
        np.savez(path, src=src, dst=dst, t=t,
                 num_steps=np.int64(num_steps))
        return
    rows = np.stack([src, dst, t], axis=1)
    np.savetxt(path, rows, fmt="%d", delimiter="\t",
               header=f"src\tdst\tt\tnum_steps={num_steps}")
