"""Discrete-Time Dynamic Graph (DTDG) batch containers.

A DTDG (§2.1 of the paper) is a sequence of T snapshots over a fixed vertex
set of size N plus a feature frame per step.  On device everything is a static
padded tensor:

  edges        (T, E_max, 2) int32 — (src, dst) per snapshot, padded
  edge_weights (T, E_max)    f32   — Laplacian-normalized (mask folded in)
  edge_mask    (T, E_max)    f32
  frames       (T, N, F)           — input features X

The host-side representation is a list of numpy edge arrays (ragged), which is
what the graph-difference transfer encoder consumes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import pad as padlib
from repro.graph import segment

Array = jax.Array


@dataclass
class DTDGBatch:
    edges: Any          # (T, E, 2) int32
    edge_weights: Any   # (T, E) f32 — normalized, mask folded in
    edge_mask: Any      # (T, E) f32
    frames: Any         # (T, N, F)
    num_nodes: int

    @property
    def num_steps(self) -> int:
        return self.edges.shape[0]

    def tree_flatten(self):
        return ((self.edges, self.edge_weights, self.edge_mask, self.frames),
                self.num_nodes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, num_nodes=aux)


jax.tree_util.register_pytree_node(
    DTDGBatch, DTDGBatch.tree_flatten, DTDGBatch.tree_unflatten)


def build_batch(snapshots: list[np.ndarray], frames: np.ndarray,
                num_nodes: int, max_edges: int | None = None,
                add_self_loops: bool = True,
                values: list[np.ndarray] | None = None) -> DTDGBatch:
    """Pad host snapshots into a device-ready DTDG batch.

    Laplacian normalization (Eq. 1) is pre-computed here per snapshot — it
    depends only on the topology, mirroring the paper's pre-computation of the
    first-layer spatial aggregate (§5.5).
    """
    t_steps = len(snapshots)
    if max_edges is None:
        max_edges = max(s.shape[0] + (num_nodes if add_self_loops else 0)
                        for s in snapshots)
        max_edges = padlib.round_up(max_edges, 128)

    e_arr = np.zeros((t_steps, max_edges, 2), dtype=np.int32)
    w_arr = np.zeros((t_steps, max_edges), dtype=np.float32)
    m_arr = np.zeros((t_steps, max_edges), dtype=np.float32)
    for t, snap in enumerate(snapshots):
        vals = values[t] if values is not None else None
        if add_self_loops:
            snap, vals = padlib.add_self_loops(snap, num_nodes, vals)
        e, v, m = padlib.pad_edges(snap, max_edges, vals)
        e_arr[t] = e
        m_arr[t] = m
        w_arr[t] = np.asarray(
            segment.gcn_edge_weights(jnp.asarray(e), num_nodes,
                                     jnp.asarray(m), jnp.asarray(v)))
    return DTDGBatch(edges=jnp.asarray(e_arr), edge_weights=jnp.asarray(w_arr),
                     edge_mask=jnp.asarray(m_arr), frames=jnp.asarray(frames),
                     num_nodes=num_nodes)
