"""GCN spatial module (Kipf-Welling, Eq. 2) over padded snapshots.

The sparse-dense aggregate ``A_tilde @ X`` is the compute hot spot; it is
served either by the XLA-native segment-sum path or by the Pallas TPU kernel
(``repro.kernels.segment_spmm``), selected with ``use_pallas``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.graph import segment

Array = jax.Array


def init_gcn_params(key: Array, f_in: int, f_out: int,
                    dtype=jnp.float32) -> dict:
    scale = 1.0 / jnp.sqrt(f_in)
    return {
        "w": (jax.random.uniform(key, (f_in, f_out), dtype=jnp.float32,
                                 minval=-scale, maxval=scale)).astype(dtype),
        "b": jnp.zeros((f_out,), dtype=dtype),
    }


def spatial_aggregate(x: Array, edges: Array, edge_weights: Array,
                      num_nodes: int, use_pallas: bool = False,
                      interpret: bool | None = None) -> Array:
    """``A_tilde @ X`` for one snapshot. x: (N, F) -> (N, F).

    ``interpret=None`` lets the kernel wrapper resolve from the backend
    (interpret only on CPU); pass an explicit bool to force either mode.
    """
    if use_pallas:
        from repro.kernels.segment_spmm import ops as spmm_ops
        return spmm_ops.segment_spmm(x, edges, edge_weights, num_nodes,
                                     interpret=interpret)
    return segment.spmm(x, edges, edge_weights, num_nodes)


def gcn_apply(params: dict, x: Array, edges: Array, edge_weights: Array,
              num_nodes: int, *, activation: Callable = jax.nn.relu,
              concat_skip: bool = False, use_pallas: bool = False,
              interpret: bool | None = None,
              pre_aggregated: bool = False) -> Array:
    """One GCN op on one snapshot.

    concat_skip implements CD-GCN's skip connection (§5.1):
        Y0 = A_tilde X;  Y1 = Y0 W;  Y = act(concat(Y0, Y1))  (F + F' wide)
    pre_aggregated: x already equals A_tilde @ X (the paper's first-layer
    pre-computation, §5.5) — skip the sparse product.
    """
    y0 = x if pre_aggregated else spatial_aggregate(
        x, edges, edge_weights, num_nodes, use_pallas, interpret)
    y1 = y0 @ params["w"] + params["b"]
    if concat_skip:
        return activation(jnp.concatenate([y0, y1], axis=-1))
    return activation(y1)
