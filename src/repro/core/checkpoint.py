"""Timeline-blocked gradient checkpointing (paper §3.1).

The timeline [1..T] is split into ``nb`` blocks of ``bsize = T/nb`` steps.
During the forward pass only the *carries* pi_b (RNN state at the block
boundary + last w-1 windowed activations) are stored; during backprop each
block's forward is re-run.  In JAX this is precisely ``lax.scan`` over blocks
with ``jax.checkpoint`` (remat) on the block body: XLA stores the scan carries
(= pi_b) and rematerializes block-internal activations, giving the paper's
memory profile (intra-block activations for ONE block + nb carries) with the
identical recompute schedule.

Gradients are bit-identical to the non-blocked forward (tested in
``tests/test_checkpoint.py``) because the computation graph is the same, only
the storage schedule changes.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import models as mdl
from repro.core.dtdg import DTDGBatch

Array = jax.Array


def _blockify(arr: Array, nb: int) -> Array:
    t = arr.shape[0]
    if t % nb != 0:
        raise ValueError(f"T={t} not divisible by nb={nb}")
    return arr.reshape((nb, t // nb) + arr.shape[1:])


def blocked_forward(cfg: mdl.DynGNNConfig, params: dict, batch: DTDGBatch,
                    nb: int | None = None) -> Array:
    """Embeddings (T, N, out_dim) with blocked checkpointing."""
    nb = nb if nb is not None else cfg.checkpoint_blocks
    t_steps = batch.num_steps
    bsize = t_steps // nb
    x = _blockify(batch.frames, nb)
    edges = _blockify(batch.edges, nb)
    ew = _blockify(batch.edge_weights, nb)
    t0s = jnp.arange(nb, dtype=jnp.int32) * bsize
    carries = mdl.init_carries(cfg, params, dtype=batch.frames.dtype)

    def block_step(carries, blk):
        x_b, e_b, w_b, t0 = blk
        z, new_carries = mdl.forward_slice(cfg, params, x_b, e_b, w_b,
                                           carries, t0)
        return new_carries, z

    # prevent_cse is required for remat-in-scan to actually drop residuals.
    body = jax.checkpoint(block_step, prevent_cse=True)
    _, zs = jax.lax.scan(body, carries, (x, edges, ew, t0s))
    return zs.reshape((t_steps,) + zs.shape[2:])


def blocked_node_loss(cfg: mdl.DynGNNConfig, params: dict, batch: DTDGBatch,
                      labels: Array, nb: int | None = None) -> Array:
    z = blocked_forward(cfg, params, batch, nb)
    logits = mdl.classify(params, z)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def activation_memory_estimate(cfg: mdl.DynGNNConfig, num_edges: int,
                               nb: int, bytes_per_el: int = 4) -> dict:
    """Analytic per-device activation memory model (paper §3.1 balance).

    intra-block  ~ bsize * (E * (2 idx + w) + N * sum(layer widths))
    checkpoints  ~ nb * |pi|  (RNN state + (w-1)-frame prefix per layer)
    Used by benchmarks/checkpoint_bench.py to reproduce the nb trade-off.
    """
    t, n = cfg.num_steps, cfg.num_nodes
    bsize = t // nb
    widths = [d for (_, _, d) in cfg.layer_dims()]
    act_width = sum(widths) + cfg.feat_in
    intra = bsize * (num_edges * (2 * 4 + bytes_per_el)
                     + n * act_width * bytes_per_el)
    pi_width = 0
    for (_, _, d) in cfg.layer_dims():
        if cfg.model == "cdgcn":
            pi_width += 2 * d                      # (h, c)
        elif cfg.model == "tmgcn":
            pi_width += (cfg.window - 1) * d       # frame prefix
        else:                                      # evolvegcn: tiny
            pi_width += 0
    ckpt = nb * n * pi_width * bytes_per_el
    return {"intra_block": intra, "checkpoint": ckpt,
            "total": intra + ckpt, "bsize": bsize}
