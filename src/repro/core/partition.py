"""Data-distribution schemes for dynamic-GNN training (paper §4).

* ``snapshot_*``  — the paper's contribution (§4.2): shard the TIME axis; the
  GCN stage is communication-free, the temporal stage is reached through an
  all-to-all that re-shards T-major -> N-major and a second all-to-all back.
  Fixed O(T*N) volume per layer, for any P.
* ``vertex_*``    — the baseline (§4.1): shard the VERTEX axis; temporal stage
  is local but the GCN needs remote neighbor features.  Our regular-pattern
  implementation gathers the full frame (the dense upper bound of the
  hypergraph scheme); the analytic hypergraph volume is estimated separately
  in ``repro.dist.comm_volume``.
* ``hybrid``      — §6.5: snapshot groups x intra-snapshot sharding for
  snapshots too large for one device (used by the big static-graph cells).

All are written with ``shard_map`` so every collective is explicit and
auditable — the compiled HLO contains exactly the two all-to-alls per layer
that the paper counts.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import models as mdl
from repro.dist import compression as compression_lib
from repro.core import temporal
from repro.core.dtdg import DTDGBatch

Array = jax.Array


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


# ------------------------------------------------- snapshot partitioning ----

def _sp_block_body(cfg: mdl.DynGNNConfig, params: dict, axis,
                   num_procs: int, carries: list, blk,
                   comm_dtype=None, fused_labels: bool = False,
                   a2a_chunks: int = 1, compression: str = "none",
                   comm_residuals: list | None = None):
    """One checkpoint block under snapshot partitioning (Fig. 3b).

    Local shapes: x (bsize/P, N, F); temporal carries are vertex-sharded
    (N/P rows).  Returns T-sharded block output (bsize/P, N, out).

    Beyond-paper options (§Perf iteration on the paper's own workload):
      * ``comm_dtype`` — cast all-to-all payloads (e.g. bf16): halves the
        redistribution volume; compute stays in the working dtype.
      * ``fused_labels`` — blk carries labels in the VERTEX-sharded layout
        (bsize, N/P); the final layer's loss is computed there and the last
        N->T redistribution is skipped entirely (the classifier is
        per-(t, u), so the loss decomposes over vertex shards).  Removes
        1 of the 2L all-to-alls per block.
      * ``compression`` != "none" — int8 error-feedback quantization of
        both redistributions (dist.compression.make_quantized_a2a).
        ``comm_residuals`` must then carry one (res_t2n, res_n2t) pair
        per layer in the PRE-a2a layouts (see ``a2a_payload_dims``), and
        the body returns ``(new_carries, h, new_comm_residuals)``.
    """
    if fused_labels:
        x_b, e_b, w_b, t0, labels_b = blk
    else:
        x_b, e_b, w_b, t0 = blk
        labels_b = None
    compression_lib.validate_mode(compression)
    compress = compression_lib.compresses_a2a(compression)
    if compress:
        if comm_dtype is not None or fused_labels:
            raise ValueError(
                "compression composes with a2a_chunks only, not with "
                "comm_dtype/fused_labels")
        if comm_residuals is None:
            raise ValueError(
                "compression != 'none' requires comm_residuals "
                "(init_comm_residuals)")
    p_idx = jax.lax.axis_index(axis)
    bsl = x_b.shape[0]                      # bsize / P local steps
    evolve = cfg.model == "evolvegcn"

    def _feature_cuts(width):
        return [width * c // a2a_chunks for c in range(1, a2a_chunks)]

    def a2a(y, split_axis, concat_axis):
        orig = y.dtype
        if comm_dtype is not None:
            y = y.astype(comm_dtype)
        if a2a_chunks > 1:
            # §6.5 overlap schedule: C independent all-to-alls over feature
            # slices, so the scheduler can run chunk c's redistribution
            # concurrently with chunk c-1's consumer compute.
            pieces = [jax.lax.all_to_all(p, axis, split_axis=split_axis,
                                         concat_axis=concat_axis, tiled=True)
                      for p in jnp.split(y, _feature_cuts(y.shape[-1]),
                                         axis=-1)]
            y = jnp.concatenate(pieces, axis=-1)
        else:
            y = jax.lax.all_to_all(y, axis, split_axis=split_axis,
                                   concat_axis=concat_axis, tiled=True)
        return y.astype(orig)

    def a2a_q(y, res, split_axis, concat_axis):
        # int8 redistribution with per-shard error feedback; chunking
        # slices payload AND residual with the same feature cuts so each
        # chunk keeps its own absmax scales.
        qa = compression_lib.make_quantized_a2a(axis, num_procs,
                                                split_axis, concat_axis)
        if a2a_chunks > 1:
            cuts = _feature_cuts(y.shape[-1])
            outs = [qa(yp, rp)
                    for yp, rp in zip(jnp.split(y, cuts, axis=-1),
                                      jnp.split(res, cuts, axis=-1))]
            return (jnp.concatenate([o for o, _ in outs], axis=-1),
                    jnp.concatenate([r for _, r in outs], axis=-1))
        return qa(y, res)

    h = x_b
    new_carries = []
    new_comm_res = []
    loss_contrib = None
    for l in range(cfg.num_layers):
        last = l == cfg.num_layers - 1
        lp = params["layers"][l]
        # --- spatial stage: communication-free (whole snapshots local) -----
        if evolve:
            # every processor redundantly evolves the block's weights from the
            # carried block-boundary state (weights are tiny — §5.5), then
            # slices its own bsl steps.
            w_prev, st = carries[l]
            ws, w_last, st_last = temporal.evolve_weights_from(
                lp["evolve"], w_prev, st, bsl * num_procs)
            ws_local = jax.lax.dynamic_slice_in_dim(ws, p_idx * bsl, bsl, 0)

            def per_step(xt, et, wt, w_t):
                y0 = mdl.gcnlib.spatial_aggregate(xt, et, wt, xt.shape[0],
                                                  cfg.use_pallas)
                return jax.nn.relu(y0 @ w_t)

            h = jax.vmap(per_step)(h, e_b, w_b, ws_local)
            new_carries.append((w_last, st_last))
            # EvolveGCN's temporal op acts on weights -> feature path needs
            # NO redistribution (the model is communication-free, §5.5).
            continue

        h, _ = mdl.spatial_stage(cfg, lp, l, h, e_b, w_b, None, t0)
        # --- redistribution 1: T-sharded -> N-sharded (all-to-all) ---------
        if compress:
            res_t2n, res_n2t = comm_residuals[l]
            h, nr1 = a2a_q(h, res_t2n, split_axis=1, concat_axis=0)
        else:
            h = a2a(h, split_axis=1, concat_axis=0)
        # --- temporal stage: full block timeline, local vertices -----------
        h, c_tm = mdl.temporal_stage(cfg, lp, l, h, carries[l], t0)
        new_carries.append(c_tm)
        if last and labels_b is not None:
            # fused loss in the vertex-sharded domain; no final a2a
            logits = mdl.classify(params, h)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, labels_b[..., None],
                                       axis=-1)[..., 0]
            loss_contrib = jnp.sum(nll)
            return new_carries, loss_contrib
        # --- redistribution 2: N-sharded -> T-sharded ----------------------
        if compress:
            h, nr2 = a2a_q(h, res_n2t, split_axis=0, concat_axis=1)
            new_comm_res.append((nr1, nr2))
        else:
            h = a2a(h, split_axis=0, concat_axis=1)
    if compress:
        # evolvegcn redistributes nothing, so new_comm_res is [] there
        return new_carries, h, new_comm_res
    return new_carries, h


# Public alias: one checkpoint block of the sharded layer stack (carries in,
# carries out).  The streamed distributed trainer (repro.stream.distributed)
# reuses it directly so the online path shares every collective with the
# offline shard_map path above.
snapshot_block_body = _sp_block_body


def a2a_payload_dims(cfg: mdl.DynGNNConfig) -> list[tuple[int, int]]:
    """Per-layer feature widths ``(f_t2n, f_n2t)`` of the two
    redistributions in ``snapshot_block_body``.

    The T->N payload is the spatial-stage output (cdgcn concatenates the
    aggregate with the GCN transform, so it is ``d_in + d_gcn`` wide);
    the N->T payload is the temporal-stage output.  EvolveGCN
    redistributes nothing (§5.5) — empty list.
    """
    if cfg.model == "evolvegcn":
        return []
    return [(d_in + d_gcn if cfg.model == "cdgcn" else d_out, d_out)
            for d_in, d_gcn, d_out in cfg.layer_dims()]


def snapshot_partition_forward(cfg: mdl.DynGNNConfig, mesh: Mesh,
                               axis="data", a2a_chunks: int = 1):
    """Build the sharded forward fn: (params, batch) -> Z (T-sharded).

    Block layout: arrays are (nb, bsize, ...) with the *bsize* axis sharded,
    so each processor owns contiguous steps within each block (Fig. 3b).
    ``a2a_chunks > 1`` chunks every redistribution into that many
    feature-sliced all-to-alls (the §6.5 overlap schedule; math-identical).
    """
    num_procs = _axis_size(mesh, axis)
    nb = cfg.checkpoint_blocks

    def fn(params, frames, edges, ew):
        # local: frames (nb, bsize/P, N, F)
        bsl = frames.shape[1]
        n_local = cfg.num_nodes // num_procs
        carries = mdl.init_carries(cfg, params, num_local_nodes=n_local,
                                   dtype=frames.dtype)
        t0s = jnp.arange(nb, dtype=jnp.int32) * (bsl * num_procs)
        body = jax.checkpoint(
            partial(_sp_block_body, cfg, params, axis, num_procs,
                    a2a_chunks=a2a_chunks),
            prevent_cse=True)
        _, zs = jax.lax.scan(body, carries, (frames, edges, ew, t0s))
        return zs                     # (nb, bsize/P, N, out) local

    spec_b = P(None, axis)          # (nb, bsize<split>, ...)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(), spec_b, spec_b, spec_b),
        out_specs=spec_b,
        check_vma=False)


def snapshot_partition_loss(cfg: mdl.DynGNNConfig, mesh: Mesh, axis="data",
                            comm_dtype=None, fuse_final: bool = False,
                            a2a_chunks: int = 1):
    """Sharded scalar loss: mean CE over all (t, u).

    fuse_final (beyond-paper): labels ride VERTEX-sharded (nb, bsize, N/P)
    and the final N->T all-to-all is elided; comm_dtype casts the remaining
    redistributions (see _sp_block_body); a2a_chunks splits every
    redistribution into that many feature-sliced all-to-alls (the §6.5
    overlap schedule; math-identical).  All default off = the
    paper-faithful execution.
    """
    num_procs = _axis_size(mesh, axis)
    nb = cfg.checkpoint_blocks
    fuse = fuse_final and cfg.model != "evolvegcn"

    def fn(params, frames, edges, ew, labels):
        bsl = frames.shape[1]
        n_local = cfg.num_nodes // num_procs
        carries = mdl.init_carries(cfg, params, num_local_nodes=n_local,
                                   dtype=frames.dtype)
        t0s = jnp.arange(nb, dtype=jnp.int32) * (bsl * num_procs)
        body = jax.checkpoint(
            partial(_sp_block_body, cfg, params, axis, num_procs,
                    comm_dtype=comm_dtype, fused_labels=fuse,
                    a2a_chunks=a2a_chunks),
            prevent_cse=True)
        if fuse:
            _, nll_sums = jax.lax.scan(
                body, carries, (frames, edges, ew, t0s, labels))
            total = jax.lax.psum(jnp.sum(nll_sums), axis)
            count = jnp.asarray(nb * bsl * num_procs * cfg.num_nodes,
                                jnp.float32)
            return total / count
        _, zs = jax.lax.scan(body, carries, (frames, edges, ew, t0s))
        z = zs.reshape((nb * bsl,) + zs.shape[2:])     # (T/P, N, F')
        logits = mdl.classify(params, z)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        lab = labels.reshape((nb * bsl,) + labels.shape[2:])
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        total = jax.lax.psum(jnp.sum(nll), axis)
        count = jax.lax.psum(jnp.asarray(nll.size, jnp.float32), axis)
        return total / count

    spec_b = P(None, axis)
    label_spec = P(None, None, axis) if fuse else spec_b
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(), spec_b, spec_b, spec_b, label_spec),
        out_specs=P(),
        check_vma=False)


def blockify_batch(batch: DTDGBatch, nb: int) -> tuple:
    """Host-side reshape of a DTDG batch to (nb, bsize, ...) arrays."""
    def blk(a):
        t = a.shape[0]
        return a.reshape((nb, t // nb) + a.shape[1:])
    return (blk(batch.frames), blk(batch.edges), blk(batch.edge_weights))


# --------------------------------------------------- vertex partitioning ----

def vertex_partition_forward(cfg: mdl.DynGNNConfig, mesh: Mesh, axis="data"):
    """Baseline §4.1: vertices sharded; GCN gathers remote features.

    Edges are pre-partitioned by destination shard on the host (each device
    receives the edges whose dst it owns, with GLOBAL src ids and LOCAL dst
    ids).  Per snapshot the device all-gathers the frame (the regular-pattern
    upper bound of vertex partitioning — volume grows ~P, unlike snapshots).
    The temporal stage is local, as in the paper.
    """
    num_procs = _axis_size(mesh, axis)

    def fn(params, frames, edges, ew):
        # local: frames (T, N/P, F); edges (T, E/P, 2) [src global, dst local]
        n_local = frames.shape[1]
        evolve = cfg.model == "evolvegcn"
        carries = mdl.init_carries(cfg, params, num_local_nodes=n_local,
                                   dtype=frames.dtype)
        h = frames
        new_carries = []
        for l in range(cfg.num_layers):
            lp = params["layers"][l]
            # all-gather the frame so every src row is addressable: this is
            # the irregular-neighbor-exchange, upper-bounded regularly.
            h_full = jax.lax.all_gather(h, axis, axis=1, tiled=True)

            def agg(xt_full, et, wt):
                msgs = jnp.take(xt_full, et[:, 0], axis=0) \
                    * wt[:, None].astype(xt_full.dtype)
                return jax.ops.segment_sum(msgs, et[:, 1],
                                           num_segments=n_local)

            if evolve:
                w_prev, st = carries[l]
                ws, w_last, st_last = temporal.evolve_weights_from(
                    lp["evolve"], w_prev, st, h.shape[0])
                y0 = jax.vmap(agg)(h_full, edges, ew)
                h = jax.nn.relu(jnp.einsum("tnf,tfg->tng", y0, ws))
                new_carries.append((w_last, st_last))
                continue
            y0 = jax.vmap(agg)(h_full, edges, ew)
            if cfg.model == "cdgcn":
                y1 = y0 @ lp["gcn"]["w"] + lp["gcn"]["b"]
                h2 = jax.nn.relu(jnp.concatenate([y0, y1], axis=-1))
            else:
                h2 = jax.nn.relu(y0 @ lp["gcn"]["w"] + lp["gcn"]["b"])
            h, c_tm = mdl.temporal_stage(cfg, lp, l, h2, carries[l], 0)
            new_carries.append(c_tm)
        return h

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(), P(None, axis), P(None, axis), P(None, axis)),
        out_specs=P(None, axis),
        check_vma=False)


def partition_edges_by_dst(edges_padded, masks, num_nodes: int,
                           num_procs: int, max_local_edges: int):
    """Host-side dst-shard edge partitioning for the vertex baseline.

    Returns (T, P, E_loc, 2) with src GLOBAL / dst LOCAL ids and the matching
    mask, ready to be fed shard-wise.
    """
    import numpy as np
    t_steps = edges_padded.shape[0]
    n_per = num_nodes // num_procs
    out_e = np.zeros((t_steps, num_procs, max_local_edges, 2), dtype=np.int32)
    out_w = np.zeros((t_steps, num_procs, max_local_edges), dtype=np.float32)
    for t in range(t_steps):
        e = np.asarray(edges_padded[t])
        m = np.asarray(masks[t]) > 0
        e = e[m]
        w = np.asarray(masks[t])[m]
        owner = e[:, 1] // n_per
        for p in range(num_procs):
            sel = e[owner == p]
            wsel = w[owner == p]
            k = min(sel.shape[0], max_local_edges)
            out_e[t, p, :k, 0] = sel[:k, 0]
            out_e[t, p, :k, 1] = sel[:k, 1] % n_per
            out_w[t, p, :k] = wsel[:k]
    return out_e, out_w


# -------------------------------------------------------------- hybrid ------

def hybrid_spmm(x: Array, edges: Array, edge_weights: Array,
                num_nodes: int, model_axis="model") -> Array:
    """§6.5 hybrid partitioning: intra-snapshot edge sharding.

    Called under shard_map with edges sharded over ``model_axis`` and x
    replicated within the group: each shard computes a partial segment-sum
    over its edge slice; a psum over the group completes the aggregate.
    Enables snapshots too large for one device (AMLSim-Large experiment).
    """
    msgs = jnp.take(x, edges[:, 0], axis=0) \
        * edge_weights[:, None].astype(x.dtype)
    partial_sum = jax.ops.segment_sum(msgs, edges[:, 1],
                                      num_segments=num_nodes)
    return jax.lax.psum(partial_sum, model_axis)
