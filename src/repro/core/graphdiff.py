"""Graph-difference based host->device snapshot transfer (paper §3.2).

Real dynamic graphs evolve slowly, so consecutive snapshots share most of
their topology.  Instead of shipping every snapshot as a full (indices,
values) sparse body, we ship, per step:

  * the positions (within the previous snapshot's edge list) of edges that
    DISAPPEAR  (A_i^ext  -> a drop list),
  * the new edges that APPEAR (A_{i+1}^ext),
  * all values of the new snapshot (values rarely overlap, per the paper).

TPU adaptation: the scarce link is host RAM -> HBM (the infeed), playing the
role of the paper's PCIe CPU->GPU link.  The *encoder* runs on host numpy in
the data pipeline; the *decoder* (reconstruction of the padded edge list from
the previous device-resident buffer plus the delta) runs on device in jitted
JAX so the reconstructed snapshot never round-trips through the host.

Bytes accounting is exact and is what `benchmarks/graphdiff_bench.py` reports
against the naive full-transfer baseline (paper Fig. 4).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _edge_key(edges: np.ndarray, num_nodes: int) -> np.ndarray:
    return edges[:, 0].astype(np.int64) * num_nodes \
        + edges[:, 1].astype(np.int64)


@dataclass
class SnapshotDelta:
    """Host-side delta between consecutive snapshots (padded, static shapes)."""
    drop_pos: np.ndarray    # (D_max,) int32 positions into prev edge list
    drop_mask: np.ndarray   # (D_max,) f32
    add_edges: np.ndarray   # (A_max, 2) int32
    add_mask: np.ndarray    # (A_max,) f32
    values: np.ndarray      # (E_max,) f32 — values of the new snapshot
    num_edges: int          # valid edge count of the new snapshot

    @property
    def payload_bytes(self) -> int:
        """Bytes actually shipped (valid lanes only, like the paper counts)."""
        d = int(self.drop_mask.sum())
        a = int(self.add_mask.sum())
        return d * 4 + a * 8 + self.num_edges * 4


@dataclass
class FullSnapshot:
    edges: np.ndarray   # (E_max, 2)
    mask: np.ndarray    # (E_max,)
    values: np.ndarray  # (E_max,)
    num_edges: int

    @property
    def payload_bytes(self) -> int:
        return self.num_edges * 8 + self.num_edges * 4


def encode_stream(snapshots: list[np.ndarray],
                  values: list[np.ndarray] | None,
                  num_nodes: int, max_edges: int,
                  block_size: int) -> list[FullSnapshot | SnapshotDelta]:
    """Encode a snapshot sequence for blocked transfer.

    The first snapshot of each checkpoint block is shipped in full (the GPU
    holds nothing to diff against at a block boundary — §6.2's
    (bsize-1)/bsize benefit ratio); subsequent snapshots ship as deltas.
    Padded static shapes: drops/adds padded to max_edges (callers may size
    tighter from dataset statistics).
    """
    out: list[FullSnapshot | SnapshotDelta] = []
    # The encoder mirrors the DEVICE-side edge ordering: after a delta is
    # applied on device, the buffer holds survivors (previous device order,
    # compacted) followed by the added edges.  Drop positions must index THIS
    # ordering, not the original snapshot file order.
    device_edges: np.ndarray | None = None
    for i, snap in enumerate(snapshots):
        vals = (values[i] if values is not None
                else np.ones((snap.shape[0],), dtype=np.float32))
        if i % block_size == 0:
            e = np.zeros((max_edges, 2), dtype=np.int32)
            m = np.zeros((max_edges,), dtype=np.float32)
            v = np.zeros((max_edges,), dtype=np.float32)
            e[:snap.shape[0]] = snap
            m[:snap.shape[0]] = 1.0
            v[:snap.shape[0]] = vals
            out.append(FullSnapshot(edges=e, mask=m, values=v,
                                    num_edges=snap.shape[0]))
            device_edges = snap.copy()
        else:
            prev = device_edges
            pk = _edge_key(prev, num_nodes)
            ck = _edge_key(snap, num_nodes)
            drop_sel = ~np.isin(pk, ck)
            add_sel = ~np.isin(ck, pk)
            drop_pos = np.nonzero(drop_sel)[0].astype(np.int32)
            adds = snap[add_sel]
            dp = np.zeros((max_edges,), dtype=np.int32)
            dm = np.zeros((max_edges,), dtype=np.float32)
            dp[:drop_pos.shape[0]] = drop_pos
            dm[:drop_pos.shape[0]] = 1.0
            ae = np.zeros((max_edges, 2), dtype=np.int32)
            am = np.zeros((max_edges,), dtype=np.float32)
            ae[:adds.shape[0]] = adds
            am[:adds.shape[0]] = 1.0
            # New device order: survivors (device order) then adds.
            device_edges = np.concatenate([prev[~drop_sel], adds], axis=0)
            v = np.zeros((max_edges,), dtype=np.float32)
            cur_lookup = {int(k): float(val) for k, val in zip(ck, vals, strict=True)}
            new_keys = _edge_key(device_edges, num_nodes)
            v[:new_keys.shape[0]] = np.asarray(
                [cur_lookup[int(k)] for k in new_keys], dtype=np.float32)
            out.append(SnapshotDelta(drop_pos=dp, drop_mask=dm, add_edges=ae,
                                     add_mask=am, values=v,
                                     num_edges=snap.shape[0]))
    return out


def apply_delta(prev_edges: Array, prev_mask: Array, drop_pos: Array,
                drop_mask: Array, add_edges: Array, add_mask: Array
                ) -> tuple[Array, Array]:
    """Device-side reconstruction of the next snapshot's padded edge list.

    1. Invalidate dropped positions in the previous buffer.
    2. Compact surviving edges to the front (stable argsort on validity).
    3. Append the added edges after the survivors.

    All shapes static (E_max); runs inside jit.
    """
    e_max = prev_edges.shape[0]
    keep = prev_mask
    keep = keep * (1.0 - jnp.zeros_like(prev_mask)
                   .at[drop_pos].add(drop_mask, mode="drop"))
    keep = jnp.clip(keep, 0.0, 1.0)
    # Stable compaction: order by (not kept), preserving original order.
    order = jnp.argsort(1.0 - keep, stable=True)
    survivors = jnp.take(prev_edges, order, axis=0)
    surv_mask = jnp.take(keep, order)
    n_surv = jnp.sum(surv_mask).astype(jnp.int32)
    # Place added edges right after the survivors.
    add_count = jnp.cumsum(add_mask.astype(jnp.int32)) - 1
    tgt = jnp.where(add_mask > 0, n_surv + add_count, e_max)  # e_max = drop
    new_edges = survivors * surv_mask[:, None].astype(prev_edges.dtype)
    new_edges = new_edges.at[tgt].set(add_edges, mode="drop")
    new_mask = surv_mask.at[tgt].set(add_mask, mode="drop")
    return new_edges, new_mask


def decode_stream(stream: list[FullSnapshot | SnapshotDelta],
                  max_edges: int) -> list[tuple[np.ndarray, np.ndarray]]:
    """Replay a stream on device; returns [(edges, mask)] per step (testing)."""
    apply_jit = jax.jit(apply_delta)
    out = []
    prev_e = jnp.zeros((max_edges, 2), dtype=jnp.int32)
    prev_m = jnp.zeros((max_edges,), dtype=jnp.float32)
    for item in stream:
        if isinstance(item, FullSnapshot):
            prev_e = jnp.asarray(item.edges)
            prev_m = jnp.asarray(item.mask)
        else:
            prev_e, prev_m = apply_jit(prev_e, prev_m,
                                       jnp.asarray(item.drop_pos),
                                       jnp.asarray(item.drop_mask),
                                       jnp.asarray(item.add_edges),
                                       jnp.asarray(item.add_mask))
        out.append((np.asarray(prev_e), np.asarray(prev_m)))
    return out


def stream_bytes(stream: list[FullSnapshot | SnapshotDelta]) -> int:
    return sum(s.payload_bytes for s in stream)


def naive_bytes(snapshots: list[np.ndarray]) -> int:
    """Baseline: full (indices, values) per snapshot (paper's `Base`)."""
    return sum(s.shape[0] * 12 for s in snapshots)
