"""Input-graph smoothing (paper §5.4): edge-life and M-transform.

Both are *host-side preprocessing* (the paper runs them once before training)
operating on ragged numpy edge lists, producing denser snapshots whose
consecutive-overlap the graph-difference transfer then exploits.
"""

from __future__ import annotations

import numpy as np


def _merge(edge_sets: list[np.ndarray],
           weights: list[float]) -> tuple[np.ndarray, np.ndarray]:
    """Union of weighted edge lists with accumulation of duplicate weights."""
    all_edges = np.concatenate(edge_sets, axis=0)
    all_w = np.concatenate([np.full((e.shape[0],), w, dtype=np.float32)
                            for e, w in zip(edge_sets, weights, strict=True)])
    # Dedup on (src, dst), summing weights.
    key = all_edges[:, 0].astype(np.int64) * (all_edges.max() + 1 if
                                              all_edges.size else 1) \
        + all_edges[:, 1].astype(np.int64)
    uniq, inv = np.unique(key, return_inverse=True)
    w = np.zeros(uniq.shape[0], dtype=np.float32)
    np.add.at(w, inv, all_w)
    # First occurrence of each unique key.
    first = np.full(uniq.shape[0], np.iinfo(np.int64).max, dtype=np.int64)
    np.minimum.at(first, inv, np.arange(all_edges.shape[0]))
    return all_edges[first].astype(np.int32), w


def edge_life(snapshots: list[np.ndarray], life: int
              ) -> tuple[list[np.ndarray], list[np.ndarray]]:
    """A_t <- A_t + sum_{i=t-l+1}^{t-1} A_i (EvolveGCN smoothing).

    Returns (edges, values) per snapshot; carried edges keep weight 1 per
    appearance (duplicates accumulate), matching the paper's formulation.
    """
    out_e, out_v = [], []
    for t in range(len(snapshots)):
        lo = max(0, t - life + 1)
        window = snapshots[lo:t + 1]
        e, v = _merge(window, [1.0] * len(window))
        out_e.append(e)
        out_v.append(v)
    return out_e, out_v


def m_transform_matrix(num_steps: int, window: int) -> np.ndarray:
    """The banded lower-triangular M of TM-GCN (§5.3), 1-indexed per paper:
    M[t, k] = 1 / min(w, t) for max(1, t - w + 1) <= k <= t."""
    m = np.zeros((num_steps, num_steps), dtype=np.float32)
    for t in range(1, num_steps + 1):
        lo = max(1, t - window + 1)
        for k in range(lo, t + 1):
            m[t - 1, k - 1] = 1.0 / min(window, t)
    return m


def m_transform_sparse(snapshots: list[np.ndarray], window: int
                       ) -> tuple[list[np.ndarray], list[np.ndarray]]:
    """Apply the M-transform along the time mode of the sparse tensor A.

    hat(A)_t = sum_k M[t, k] A_k — a weighted union of the last w snapshots.
    """
    t_steps = len(snapshots)
    m = m_transform_matrix(t_steps, window)
    out_e, out_v = [], []
    for t in range(t_steps):
        ks = np.nonzero(m[t])[0]
        e, v = _merge([snapshots[k] for k in ks], [float(m[t, k]) for k in ks])
        out_e.append(e)
        out_v.append(v)
    return out_e, out_v
