"""CTDG -> DTDG bridging (paper §7 future-work item iii).

Continuous-Time Dynamic Graphs arrive as timestamped event streams
(edge insertions/deletions).  The paper's entire machinery is DTDG-based;
this module discretizes a CTDG into the snapshot sequence the rest of the
framework consumes — including the two discretization policies used in
practice:

  * ``snapshot_events``  — G_t = edges alive at the end of window t
    (insertions minus deletions), the exact-state view;
  * ``window_events``    — G_t = edges *observed* during window t
    (interaction graphs, e.g. transactions), the view the paper's
    epinions/AMLSim datasets use.

Because consecutive windows share most alive edges, the output plugs
directly into the graph-difference transfer encoder with high overlap.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class EventStream:
    """Timestamped edge events: kind +1 = insert, -1 = delete."""
    src: np.ndarray          # (M,) int
    dst: np.ndarray          # (M,) int
    time: np.ndarray         # (M,) float, non-decreasing not required
    kind: np.ndarray         # (M,) int8 in {+1, -1}
    num_nodes: int

    def sorted(self) -> "EventStream":
        order = np.argsort(self.time, kind="stable")
        return EventStream(self.src[order], self.dst[order],
                           self.time[order], self.kind[order],
                           self.num_nodes)


def _edge_key(src, dst, n):
    return src.astype(np.int64) * n + dst.astype(np.int64)


def snapshot_events(stream: EventStream, num_steps: int
                    ) -> list[np.ndarray]:
    """Alive-edge snapshots at the end of each of ``num_steps`` uniform
    windows over the stream's time range."""
    ev = stream.sorted()
    t0, t1 = float(ev.time.min()), float(ev.time.max())
    bounds = np.linspace(t0, t1, num_steps + 1)[1:]
    alive: dict[int, int] = {}
    out: list[np.ndarray] = []
    i, m = 0, ev.time.shape[0]
    n = stream.num_nodes
    keys = _edge_key(ev.src, ev.dst, n)
    for b in bounds:
        while i < m and ev.time[i] <= b:
            k = int(keys[i])
            if ev.kind[i] > 0:
                alive[k] = alive.get(k, 0) + 1
            else:
                c = alive.get(k, 0) - 1
                if c <= 0:
                    alive.pop(k, None)
                else:
                    alive[k] = c
            i += 1
        ks = np.fromiter(alive.keys(), dtype=np.int64,
                         count=len(alive))
        snap = np.stack([ks // n, ks % n], axis=1).astype(np.int32) \
            if ks.size else np.zeros((0, 2), np.int32)
        out.append(snap)
    return out


def window_events(stream: EventStream, num_steps: int) -> list[np.ndarray]:
    """Interaction snapshots: unique edges observed within each window."""
    ev = stream.sorted()
    t0, t1 = float(ev.time.min()), float(ev.time.max())
    edges_at = np.clip(((ev.time - t0) / max(t1 - t0, 1e-12)
                        * num_steps).astype(np.int64), 0, num_steps - 1)
    out = []
    for t in range(num_steps):
        sel = (edges_at == t) & (ev.kind > 0)
        e = np.stack([ev.src[sel], ev.dst[sel]], axis=1).astype(np.int32)
        out.append(np.unique(e, axis=0) if e.size
                   else np.zeros((0, 2), np.int32))
    return out


def synthetic_ctdg(num_nodes: int, num_events: int, delete_frac: float = 0.2,
                   seed: int = 0) -> EventStream:
    """Synthetic event stream with slow churn (inserts then deletions of
    previously-inserted edges)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_nodes, num_events)
    dst = rng.integers(0, num_nodes, num_events)
    time = np.sort(rng.uniform(0, 1, num_events))
    kind = np.ones(num_events, np.int8)
    n_del = int(num_events * delete_frac)
    if n_del:
        del_idx = rng.choice(num_events // 2, n_del, replace=False)
        pos = rng.integers(num_events // 2, num_events, n_del)
        kind[pos] = -1
        src[pos] = src[del_idx]
        dst[pos] = dst[del_idx]
    return EventStream(src.astype(np.int32), dst.astype(np.int32),
                       time, kind, num_nodes)
