"""CTDG -> DTDG bridging (paper §7 future-work item iii).

Continuous-Time Dynamic Graphs arrive as timestamped event streams
(edge insertions/deletions).  The paper's entire machinery is DTDG-based;
this module discretizes a CTDG into the snapshot sequence the rest of the
framework consumes — including the two discretization policies used in
practice:

  * ``snapshot_events``  — G_t = edges alive at the end of window t
    (insertions minus deletions), the exact-state view;
  * ``window_events``    — G_t = edges *observed* during window t
    (interaction graphs, e.g. transactions), the view the paper's
    epinions/AMLSim datasets use.

Because consecutive windows share most alive edges, the output plugs
directly into the graph-difference transfer encoder with high overlap.

The window assignment rules and the alive-edge bookkeeping are factored
out (``uniform_bounds`` / ``snapshot_window_index`` /
``interaction_window_index`` / ``AliveSet``) so the ONLINE ingester
(``repro.serve.ingest``) consumes events through literally the same code
paths — a live stream discretizes onto exactly the windows the offline
functions would produce, which is what pins online serving to the
offline reference.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

POLICIES = ("snapshot", "window")


@dataclass
class EventStream:
    """Timestamped edge events: kind +1 = insert, -1 = delete."""
    src: np.ndarray          # (M,) int
    dst: np.ndarray          # (M,) int
    time: np.ndarray         # (M,) float, non-decreasing not required
    kind: np.ndarray         # (M,) int8 in {+1, -1}
    num_nodes: int

    def __len__(self) -> int:
        return int(self.src.shape[0])

    def sorted(self) -> "EventStream":
        order = np.argsort(self.time, kind="stable")
        return EventStream(self.src[order], self.dst[order],
                           self.time[order], self.kind[order],
                           self.num_nodes)

    def validate(self, require_sorted: bool = False,
                 check_deletes: bool = True) -> "EventStream":
        """Reject malformed streams with a clear message (returns self).

        Checks: matching array lengths, non-empty, node ids within
        ``[0, num_nodes)``, kinds in {+1, -1}, finite timestamps, and —
        with ``check_deletes`` — that no edge is deleted more times than
        it was inserted up to that point (delete-before-insert), in
        stable time order.  ``require_sorted`` additionally demands
        non-decreasing timestamps (the contract of live ingest pushes;
        the offline discretizers sort for you).  Silently feeding any of
        these through the discretizers would produce wrong windows, so
        they raise here instead.
        """
        m = len(self)
        for name in ("dst", "time", "kind"):
            a = getattr(self, name)
            if a.shape[0] != m:
                raise ValueError(
                    f"EventStream.{name} has {a.shape[0]} events but src "
                    f"has {m}; all event arrays must align")
        if m == 0:
            raise ValueError("EventStream is empty: nothing to discretize")
        if self.num_nodes <= 0:
            raise ValueError(f"EventStream.num_nodes must be positive, "
                             f"got {self.num_nodes}")
        for name in ("src", "dst"):
            a = getattr(self, name)
            if a.min() < 0 or a.max() >= self.num_nodes:
                bad = int(a[(a < 0) | (a >= self.num_nodes)][0])
                raise ValueError(
                    f"EventStream.{name} contains node id {bad} outside "
                    f"[0, {self.num_nodes}); fix the ids or num_nodes")
        if not np.isin(self.kind, (-1, 1)).all():
            bad = self.kind[~np.isin(self.kind, (-1, 1))][0]
            raise ValueError(f"EventStream.kind must be +1 (insert) or -1 "
                             f"(delete), got {int(bad)}")
        if not np.isfinite(self.time).all():
            raise ValueError("EventStream.time contains non-finite "
                             "timestamps")
        if require_sorted and np.any(np.diff(self.time) < 0):
            i = int(np.nonzero(np.diff(self.time) < 0)[0][0])
            raise ValueError(
                f"EventStream.time must be non-decreasing: event {i + 1} "
                f"(t={float(self.time[i + 1])}) precedes event {i} "
                f"(t={float(self.time[i])})")
        if check_deletes:
            self._check_delete_before_insert()
        return self

    def _check_delete_before_insert(self) -> None:
        """Per-edge running insert-minus-delete count must never go
        negative (vectorized: group events by edge key, keeping stable
        time order inside each group, and cumsum the kinds)."""
        order = np.argsort(self.time, kind="stable")
        keys = _edge_key(self.src[order], self.dst[order], self.num_nodes)
        grp = np.argsort(keys, kind="stable")     # stable: time order kept
        counts = np.cumsum(self.kind[order][grp].astype(np.int64))
        k_sorted = keys[grp]
        starts = np.nonzero(np.r_[True, k_sorted[1:] != k_sorted[:-1]])[0]
        sizes = np.diff(np.r_[starts, k_sorted.shape[0]])
        base = np.repeat(np.r_[0, counts[starts[1:] - 1]], sizes)
        running = counts - base
        if running.min() < 0:
            i = int(order[grp[np.nonzero(running < 0)[0][0]]])
            raise ValueError(
                f"EventStream deletes edge ({int(self.src[i])}, "
                f"{int(self.dst[i])}) at t={float(self.time[i])} before "
                "inserting it (or more times than it was inserted); "
                "delete events must follow a matching insert")


def _edge_key(src, dst, n):
    return src.astype(np.int64) * n + dst.astype(np.int64)


# ------------------------------------------------ window assignment ---------

def uniform_bounds(t0: float, t1: float, num_steps: int) -> np.ndarray:
    """End-bound of each of ``num_steps`` uniform windows over [t0, t1]."""
    return np.linspace(t0, t1, num_steps + 1)[1:]


def snapshot_window_index(time: np.ndarray, bounds: np.ndarray
                          ) -> np.ndarray:
    """Window owning each event under the alive-edge (snapshot) policy:
    the first window whose end bound is >= the event time (events beyond
    the last bound land past the final window and are never consumed —
    identical to the reference consumption loop)."""
    return np.searchsorted(bounds, time, side="left")


def interaction_window_index(time: np.ndarray, t0: float, t1: float,
                             num_steps: int) -> np.ndarray:
    """Window owning each event under the interaction (window) policy —
    the exact binning formula of ``window_events``."""
    return np.clip(((np.asarray(time) - t0) / max(t1 - t0, 1e-12)
                    * num_steps).astype(np.int64), 0, num_steps - 1)


class AliveSet:
    """Incremental alive-edge bookkeeping with reference-stable order.

    Holds the insert-minus-delete count per edge key; ``snapshot()``
    materializes the alive edge list in key *insertion* order — the same
    dict-order contract ``snapshot_events`` has always had, so feeding
    the same events through ``apply`` online or offline yields
    byte-identical snapshots.
    """

    def __init__(self, num_nodes: int):
        self.num_nodes = num_nodes
        self._alive: dict[int, int] = {}

    def apply(self, src: np.ndarray, dst: np.ndarray,
              kind: np.ndarray, strict: bool = False) -> None:
        """Apply events (already in stable time order).

        ``strict`` raises on a delete of an edge that is not currently
        alive — the running analogue of ``validate(check_deletes=True)``
        for live ingest, where no single push sees the whole history.
        """
        keys = _edge_key(np.asarray(src), np.asarray(dst), self.num_nodes)
        alive = self._alive
        n = self.num_nodes
        for k, s in zip(keys.tolist(), np.asarray(kind).tolist(),
                        strict=True):
            if s > 0:
                alive[k] = alive.get(k, 0) + 1
            else:
                c = alive.get(k, 0) - 1
                if c < 0 and strict:
                    raise ValueError(
                        f"delete of edge ({k // n}, {k % n}) which is not "
                        "alive (delete-before-insert across the ingested "
                        "stream)")
                if c <= 0:
                    alive.pop(k, None)
                else:
                    alive[k] = c

    def snapshot(self) -> np.ndarray:
        """(E, 2) int32 alive edge list, key-insertion order."""
        n = self.num_nodes
        ks = np.fromiter(self._alive.keys(), dtype=np.int64,
                         count=len(self._alive))
        if not ks.size:
            return np.zeros((0, 2), np.int32)
        return np.stack([ks // n, ks % n], axis=1).astype(np.int32)


def _validated(stream: EventStream, num_steps: int) -> EventStream:
    if num_steps < 1:
        raise ValueError(f"num_steps must be >= 1, got {num_steps}")
    return stream.validate().sorted()


def snapshot_events(stream: EventStream, num_steps: int
                    ) -> list[np.ndarray]:
    """Alive-edge snapshots at the end of each of ``num_steps`` uniform
    windows over the stream's time range."""
    ev = _validated(stream, num_steps)
    bounds = uniform_bounds(float(ev.time[0]), float(ev.time[-1]),
                            num_steps)
    win = snapshot_window_index(ev.time, bounds)
    alive = AliveSet(stream.num_nodes)
    out: list[np.ndarray] = []
    for t in range(num_steps):
        sel = win == t
        alive.apply(ev.src[sel], ev.dst[sel], ev.kind[sel])
        out.append(alive.snapshot())
    return out


def window_events(stream: EventStream, num_steps: int) -> list[np.ndarray]:
    """Interaction snapshots: unique edges observed within each window."""
    ev = _validated(stream, num_steps)
    t0, t1 = float(ev.time[0]), float(ev.time[-1])
    edges_at = interaction_window_index(ev.time, t0, t1, num_steps)
    out = []
    for t in range(num_steps):
        sel = (edges_at == t) & (ev.kind > 0)
        e = np.stack([ev.src[sel], ev.dst[sel]], axis=1).astype(np.int32)
        out.append(np.unique(e, axis=0) if e.size
                   else np.zeros((0, 2), np.int32))
    return out


def synthetic_ctdg(num_nodes: int, num_events: int, delete_frac: float = 0.2,
                   seed: int = 0) -> EventStream:
    """Synthetic event stream with slow churn (inserts then deletions of
    previously-inserted edges)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_nodes, num_events)
    dst = rng.integers(0, num_nodes, num_events)
    time = np.sort(rng.uniform(0, 1, num_events))
    kind = np.ones(num_events, np.int8)
    n_del = min(int(num_events * delete_frac), num_events // 2)
    if n_del:
        # distinct delete positions (replace=False: a repeated position
        # would overwrite itself into a double-delete of a once-inserted
        # edge, which validate() rightly rejects)
        del_idx = rng.choice(num_events // 2, n_del, replace=False)
        pos = rng.choice(np.arange(num_events // 2, num_events), n_del,
                         replace=False)
        kind[pos] = -1
        src[pos] = src[del_idx]
        dst[pos] = dst[del_idx]
    return EventStream(src.astype(np.int32), dst.astype(np.int32),
                       time, kind, num_nodes)
