"""Temporal (RNN) modules of the dynamic-GNN framework (paper §2.2, §5).

Three variants, one per representative model:

* ``lstm_scan``      — LSTM over the timeline per vertex (CD-GCN).
* ``m_product``      — parameter-free banded temporal averaging (TM-GCN);
                       optionally served by the Pallas banded-TTM kernel.
* ``weight_lstm``    — LSTM over the GCN *weight matrices* (EvolveGCN / EGCN-O).

All operate on (T, N, F) feature tensors; vertex independence is what the
snapshot-partitioning scheme exploits (the all-to-all re-shards T-major to
N-major before these run).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------- LSTM ------

def init_lstm_params(key: Array, f_in: int, hidden: int,
                     dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(hidden)
    wx = jax.random.uniform(k1, (f_in, 4 * hidden), minval=-scale,
                            maxval=scale, dtype=jnp.float32)
    wh = jax.random.uniform(k2, (hidden, 4 * hidden), minval=-scale,
                            maxval=scale, dtype=jnp.float32)
    return {"wx": wx.astype(dtype), "wh": wh.astype(dtype),
            "b": jnp.zeros((4 * hidden,), dtype=dtype)}


def lstm_cell(params: dict, state: tuple[Array, Array],
              x: Array) -> tuple[tuple[Array, Array], Array]:
    """Standard LSTM cell; x: (..., F), state (h, c): (..., H)."""
    h, c = state
    gates = x @ params["wx"] + h @ params["wh"] + params["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return (h_new, c_new), h_new


def lstm_zero_state(batch_shape: tuple[int, ...], hidden: int,
                    dtype=jnp.float32) -> tuple[Array, Array]:
    z = jnp.zeros(batch_shape + (hidden,), dtype=dtype)
    return (z, z)


def lstm_scan(params: dict, x: Array,
              init_state: tuple[Array, Array] | None = None
              ) -> tuple[Array, tuple[Array, Array]]:
    """LSTM along axis 0 of x: (T, N, F) -> (T, N, H); returns final state.

    The returned final state is the per-block boundary data pi_b of the
    gradient-checkpoint scheme (§3.1).
    """
    hidden = params["wh"].shape[0]
    if init_state is None:
        init_state = lstm_zero_state(x.shape[1:-1], hidden, x.dtype)

    def step(state, xt):
        new_state, y = lstm_cell(params, state, xt)
        return new_state, y

    final_state, ys = jax.lax.scan(step, init_state, x)
    return ys, final_state


# ----------------------------------------------------------- M-product ------

def m_product(x: Array, window: int, t_offset: Array | int = 0,
              use_pallas: bool = False) -> Array:
    """TM-GCN temporal op: Y = M x_1 X with the banded averaging M (§5.3).

    Y_t = (1 / min(w, t)) * sum_{k=max(1, t-w+1)}^{t} X_k   (1-indexed t).

    ``t_offset``: global index of x[0] — under blocked checkpointing /
    snapshot partitioning this op runs on a timeline slice, and the
    normalization 1/min(w, t) depends on the *global* timestep.
    The window prefix (last w-1 frames before the slice) must be prepended by
    the caller; here we only need the offset for correct weighting.
    """
    if use_pallas:
        from repro.kernels.mproduct import ops as mp_ops
        return mp_ops.m_product(x, window, t_offset)
    t = x.shape[0]
    # cumulative sums along time with a zero row in front: cs[t] = sum_{<t} x
    cs = jnp.concatenate([jnp.zeros_like(x[:1]), jnp.cumsum(x, axis=0)],
                         axis=0)
    idx = jnp.arange(t)
    glob = idx + t_offset + 1  # 1-indexed global timestep
    lo = jnp.maximum(glob - window, t_offset * jnp.ones_like(glob)) - t_offset
    hi = idx + 1
    total = jnp.take(cs, hi, axis=0) - jnp.take(cs, lo, axis=0)
    denom = jnp.minimum(window, glob).astype(x.dtype)
    return total / denom.reshape((t,) + (1,) * (x.ndim - 1))


def m_product_with_prefix(x: Array, prefix: Array, window: int,
                          t_offset: Array | int,
                          use_pallas: bool = False) -> Array:
    """M-product over a timeline slice given the (w-1)-frame prefix carry.

    prefix: (w-1, N, F) — the last w-1 frames before x[0] (zeros at t=0).
    Returns Y for the slice only: (T_slice, N, F).
    """
    w1 = prefix.shape[0]
    full = jnp.concatenate([prefix, x], axis=0)
    y = m_product(full, window, t_offset=jnp.asarray(t_offset) - w1,
                  use_pallas=use_pallas)
    return y[w1:]


# -------------------------------------------------------- EvolveGCN ---------

def init_weight_lstm_params(key: Array, f_in: int, f_out: int,
                            dtype=jnp.float32) -> dict:
    """EGCN-O: the GCN weight W_t (f_in x f_out) is evolved by an LSTM whose
    'batch' is the f_out columns and feature size is f_in."""
    p = init_lstm_params(key, f_in, f_in, dtype)
    k2 = jax.random.fold_in(key, 17)
    scale = 1.0 / jnp.sqrt(f_in)
    w0 = jax.random.uniform(k2, (f_in, f_out), minval=-scale, maxval=scale,
                            dtype=jnp.float32).astype(dtype)
    return {"lstm": p, "w0": w0}


def evolve_weights(params: dict, num_steps: int) -> Array:
    """Produce (T, f_in, f_out) evolved GCN weights: W_t = LSTM(W_{t-1}).

    Replicated on every processor (weights are tiny — §5.5), which keeps the
    EvolveGCN feature path fully communication-free under snapshot
    partitioning.
    """
    f_in, f_out = params["w0"].shape
    lstm = params["lstm"]

    def step(carry, _):
        w_prev, state = carry
        # columns of W are the batch: (f_out, f_in) input to the cell
        new_state, h = lstm_cell(lstm, state, w_prev.T)
        w_new = h.T  # (f_in, f_out)
        return (w_new, new_state), w_new

    init = (params["w0"],
            lstm_zero_state((f_out,), f_in, params["w0"].dtype))
    _, ws = jax.lax.scan(step, init, None, length=num_steps)
    return ws


def evolve_weights_from(params: dict, w_prev: Array,
                        state: tuple[Array, Array], num_steps: int
                        ) -> tuple[Array, Array, tuple[Array, Array]]:
    """Blocked variant: continue evolving from carried (w, state) — pi_b."""
    lstm = params["lstm"]

    def step(carry, _):
        w_c, st = carry
        new_state, h = lstm_cell(lstm, st, w_c.T)
        w_new = h.T
        return (w_new, new_state), w_new

    (w_last, st_last), ws = jax.lax.scan(step, (w_prev, state), None,
                                         length=num_steps)
    return ws, w_last, st_last
