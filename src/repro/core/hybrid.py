"""Hybrid partitioning (paper §6.5): snapshot groups x intra-snapshot
vertex sharding — for datasets whose individual snapshots are too large for
one device (AMLSim-Large: 2.2-3.2 B nnz, 44-64 GB per §6.5), or when
T < P would leave processors idle.

Mesh mapping: the 'data' axis carries snapshot groups (the paper's scheme),
the 'model' axis shards vertices WITHIN each snapshot:

  * features live vertex-sharded: local x is (T/Pd, N/Pm, F);
  * the GCN aggregate uses the blockwise pattern the paper cites ([23],
    Tripathy et al.): all-gather the frame over 'model', aggregate the
    local dst-edge shard, reduce-scatter back to vertex shards;
  * the temporal stage re-shards T-major -> N-major over 'data' exactly as
    in plain snapshot partitioning, except the vertex axis is already
    'model'-sharded, so each device ends with N/(Pd*Pm) timelines;
  * volume: O(T*N) over 'data' (unchanged — the paper's law) plus
    O(T/Pd * N) over 'model' for the intra-snapshot exchange.

Exactness vs the single-device reference is tested in
tests/test_hybrid.py.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import models as mdl
from repro.core import temporal

Array = jax.Array


def hybrid_forward(cfg: mdl.DynGNNConfig, mesh: Mesh,
                   data_axis: str = "data", model_axis: str = "model"):
    """Builds fn(params, frames, edges, ew) -> Z.

    Input layouts (global):
      frames (T, N, F)   sharded P(data, model, None)
      edges  (T, E, 2)   sharded P(data, model_edges, None) — edge shards
                         pre-partitioned by DST so each model shard owns
                         edges whose dst is local (dst ids LOCAL, src GLOBAL)
      ew     (T, E)      same sharding as edges' first two axes
    Output Z (T, N, F') sharded P(data, model, None).
    """
    pd = mesh.shape[data_axis]
    pm = mesh.shape[model_axis]

    def fn(params, frames, edges, ew):
        t_loc, n_loc, _ = frames.shape       # (T/Pd, N/Pm, F)
        h = frames
        for l in range(cfg.num_layers):
            lp = params["layers"][l]

            # ---- spatial stage: blockwise intra-snapshot SpMM ------------
            def per_snapshot(x_loc, e_loc, w_loc):
                x_full = jax.lax.all_gather(x_loc, model_axis, axis=0,
                                            tiled=True)      # (N, F)
                msgs = jnp.take(x_full, e_loc[:, 0], axis=0) \
                    * w_loc[:, None].astype(x_full.dtype)
                return jax.ops.segment_sum(msgs, e_loc[:, 1],
                                           num_segments=n_loc)

            y0 = jax.vmap(per_snapshot)(h, edges, ew)   # (T/Pd, N/Pm, F)
            if cfg.model == "cdgcn":
                y1 = y0 @ lp["gcn"]["w"] + lp["gcn"]["b"]
                y = jax.nn.relu(jnp.concatenate([y0, y1], axis=-1))
            else:
                y = jax.nn.relu(y0 @ lp["gcn"]["w"] + lp["gcn"]["b"])

            # ---- temporal stage: T-major -> N-major over 'data' ----------
            y = jax.lax.all_to_all(y, data_axis, split_axis=1,
                                   concat_axis=0, tiled=True)
            # (T, N/(Pd*Pm), F')
            carry = mdl.init_layer_carry(cfg, params, l,
                                         num_local_nodes=y.shape[1],
                                         dtype=y.dtype)
            z, _ = mdl.temporal_stage(cfg, lp, l, y, carry, 0)
            h = jax.lax.all_to_all(z, data_axis, split_axis=0,
                                   concat_axis=1, tiled=True)
        return h

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(), P(data_axis, model_axis, None),
                  P(data_axis, model_axis, None),
                  P(data_axis, model_axis)),
        out_specs=P(data_axis, model_axis, None),
        check_vma=False)


def partition_edges_for_hybrid(edges_padded, weights, masks,
                               num_nodes: int, pm: int,
                               max_local_edges: int):
    """Host-side: per snapshot, split edges into Pm dst-shards (dst LOCAL,
    src GLOBAL), stacked along the edge axis so spec P(data, model) shards
    correctly.  Returns (T, Pm*E_loc, 2) edges and matching weights."""
    import numpy as np
    t_steps = edges_padded.shape[0]
    n_per = num_nodes // pm
    out_e = np.zeros((t_steps, pm, max_local_edges, 2), dtype=np.int32)
    out_w = np.zeros((t_steps, pm, max_local_edges), dtype=np.float32)
    for t in range(t_steps):
        e = np.asarray(edges_padded[t])
        m = np.asarray(masks[t]) > 0
        ev = e[m]
        wv = np.asarray(weights[t])[m]
        owner = ev[:, 1] // n_per
        for p in range(pm):
            sel = ev[owner == p]
            ws = wv[owner == p]
            k = min(sel.shape[0], max_local_edges)
            out_e[t, p, :k, 0] = sel[:k, 0]
            out_e[t, p, :k, 1] = sel[:k, 1] % n_per
            out_w[t, p, :k] = ws[:k]
    return (out_e.reshape(t_steps, pm * max_local_edges, 2),
            out_w.reshape(t_steps, pm * max_local_edges))
