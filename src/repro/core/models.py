"""The three representative dynamic-GNN models (paper §5).

Every model is expressed as a stack of (GCN, RNN) layer pairs with an explicit
*temporal carry* per layer:

    carry_in -(layer forward over a timeline slice)-> (outputs, carry_out)

The carry is exactly the paper's pi_b block-boundary data (§3.1): the RNN
state at the slice boundary plus the last (w-1) activations for windowed
temporal ops.  Single-device forward = one slice covering all T steps;
blocked gradient checkpointing (``repro.core.checkpoint``) scans over slices;
snapshot partitioning (``repro.core.partition``) inserts the two all-to-all
re-distributions around the temporal stage of the same layer functions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import gcn as gcnlib
from repro.core import temporal
from repro.core.dtdg import DTDGBatch

Array = jax.Array


@dataclass(frozen=True)
class DynGNNConfig:
    model: str = "tmgcn"            # cdgcn | evolvegcn | tmgcn
    num_nodes: int = 1024
    num_steps: int = 16
    feat_in: int = 2                # paper: in/out degree features
    hidden: int = 6                 # paper: intermediate feature length 6
    out_dim: int = 6                # embedding length F'
    num_layers: int = 2
    window: int = 5                 # M-product / RNN window w
    num_classes: int = 2
    # execution knobs
    checkpoint_blocks: int = 1      # nb (1 = no checkpointing)
    use_pallas: bool = False
    precompute_first_agg: bool = False  # paper §5.5 first-layer SpMM reuse
    param_dtype: Any = jnp.float32

    def layer_dims(self) -> list[tuple[int, int, int]]:
        """[(d_in, d_gcn, d_out_of_layer)] per layer."""
        dims = []
        d = self.feat_in
        for l in range(self.num_layers):
            d_gcn = self.hidden
            if self.model == "cdgcn":
                d_layer_out = (self.out_dim if l == self.num_layers - 1
                               else self.hidden)
            else:
                d_layer_out = (self.out_dim if l == self.num_layers - 1
                               else self.hidden)
            dims.append((d, d_gcn, d_layer_out))
            d = d_layer_out
        return dims


# ------------------------------------------------------------- init ---------

def init_params(key: Array, cfg: DynGNNConfig) -> dict:
    params: dict = {"layers": []}
    for _l, (d_in, d_gcn, d_out) in enumerate(cfg.layer_dims()):
        key, k1, k2 = jax.random.split(key, 3)
        layer: dict = {}
        if cfg.model == "cdgcn":
            layer["gcn"] = gcnlib.init_gcn_params(k1, d_in, d_gcn,
                                                  cfg.param_dtype)
            # concat skip makes the LSTM input (d_in + d_gcn)-wide
            layer["lstm"] = temporal.init_lstm_params(
                k2, d_in + d_gcn, d_out, cfg.param_dtype)
        elif cfg.model == "evolvegcn":
            layer["evolve"] = temporal.init_weight_lstm_params(
                k1, d_in, d_out, cfg.param_dtype)
        elif cfg.model == "tmgcn":
            layer["gcn"] = gcnlib.init_gcn_params(k1, d_in, d_out,
                                                  cfg.param_dtype)
        else:
            raise ValueError(cfg.model)
        params["layers"].append(layer)
    key, kc = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(cfg.out_dim)
    params["classifier"] = {
        "u": jax.random.uniform(kc, (cfg.out_dim, cfg.num_classes),
                                minval=-scale, maxval=scale,
                                dtype=jnp.float32).astype(cfg.param_dtype),
        "b": jnp.zeros((cfg.num_classes,), dtype=cfg.param_dtype),
    }
    return params


def init_layer_carry(cfg: DynGNNConfig, params: dict, layer: int,
                     num_local_nodes: int | None = None,
                     dtype=jnp.float32) -> Any:
    """Zero temporal carry (pi_0) for one layer.

    num_local_nodes: under snapshot partitioning the RNN stage is vertex-
    sharded, so carries are sized N/P locally.
    """
    n = num_local_nodes if num_local_nodes is not None else cfg.num_nodes
    d_in, d_gcn, d_out = cfg.layer_dims()[layer]
    if cfg.model == "cdgcn":
        return temporal.lstm_zero_state((n,), d_out, dtype)
    if cfg.model == "evolvegcn":
        p = params["layers"][layer]["evolve"]
        w0 = p["w0"]
        f_in, f_out = w0.shape
        return (w0, temporal.lstm_zero_state((f_out,), f_in, dtype))
    if cfg.model == "tmgcn":
        return jnp.zeros((cfg.window - 1, n, d_out), dtype=dtype)
    raise ValueError(cfg.model)


def init_carries(cfg: DynGNNConfig, params: dict,
                 num_local_nodes: int | None = None,
                 dtype=jnp.float32) -> list:
    return [init_layer_carry(cfg, params, l, num_local_nodes, dtype)
            for l in range(cfg.num_layers)]


# ---------------------------------------------------- layer-slice steps -----

def spatial_stage(cfg: DynGNNConfig, layer_params: dict, _layer: int,
                  x: Array, edges: Array, edge_weights: Array,
                  carry: Any, _t_offset: Array | int) -> tuple[Array, Any]:
    """The per-snapshot (communication-free) stage of one layer.

    x: (Ts, N, d_in) slice; edges: (Ts, E, 2); returns (Ts, N, d_mid).
    EvolveGCN folds the whole layer here (its LSTM runs over weights, which
    is also per-processor local — §5.5); returns the updated weight carry.
    """
    num_nodes = x.shape[1]
    if cfg.model == "evolvegcn":
        w_prev, state = carry
        ws, w_last, st_last = temporal.evolve_weights_from(
            layer_params["evolve"], w_prev, state, x.shape[0])

        def per_step(xt, et, wt, w_t):
            y0 = gcnlib.spatial_aggregate(xt, et, wt, num_nodes,
                                          cfg.use_pallas)
            return jax.nn.relu(y0 @ w_t)

        y = jax.vmap(per_step)(x, edges, edge_weights, ws)
        return y, (w_last, st_last)

    concat_skip = cfg.model == "cdgcn"

    def per_step(xt, et, wt):
        return gcnlib.gcn_apply(
            layer_params["gcn"], xt, et, wt, num_nodes,
            concat_skip=concat_skip, use_pallas=cfg.use_pallas,
            activation=(lambda v: v) if cfg.model == "tmgcn"
            else jax.nn.relu)

    y = jax.vmap(per_step)(x, edges, edge_weights)
    if cfg.model == "tmgcn":
        y = jax.nn.relu(y)
    return y, carry


def temporal_stage(cfg: DynGNNConfig, layer_params: dict, _layer: int,
                   y: Array, carry: Any,
                   t_offset: Array | int) -> tuple[Array, Any]:
    """The per-vertex timeline stage of one layer. y: (Ts, Nloc, d_mid)."""
    if cfg.model == "cdgcn":
        z, new_state = temporal.lstm_scan(layer_params["lstm"], y,
                                          init_state=carry)
        return z, new_state
    if cfg.model == "evolvegcn":
        return y, carry  # already folded into the spatial stage
    if cfg.model == "tmgcn":
        z = temporal.m_product_with_prefix(y, carry, cfg.window, t_offset,
                                           use_pallas=cfg.use_pallas)
        new_prefix = jnp.concatenate([carry, y], axis=0)[-(cfg.window - 1):] \
            if cfg.window > 1 else carry
        return z, new_prefix
    raise ValueError(cfg.model)


def forward_slice(cfg: DynGNNConfig, params: dict, x: Array, edges: Array,
                  edge_weights: Array, carries: list,
                  t_offset: Array | int) -> tuple[Array, list]:
    """Full model over a contiguous timeline slice (single-device path)."""
    # Each layer owns one carry: the weight-LSTM state for EvolveGCN (used by
    # the spatial stage), the feature-RNN state / window prefix otherwise
    # (used by the temporal stage).
    evolve = cfg.model == "evolvegcn"
    new_carries = []
    h = x
    for l in range(cfg.num_layers):
        lp = params["layers"][l]
        h, c_sp = spatial_stage(cfg, lp, l, h, edges, edge_weights,
                                carries[l] if evolve else None, t_offset)
        h, c_tm = temporal_stage(cfg, lp, l, h,
                                 None if evolve else carries[l], t_offset)
        new_carries.append(c_sp if evolve else c_tm)
    return h, new_carries


# --------------------------------------------------------- full model -------

def forward(cfg: DynGNNConfig, params: dict, batch: DTDGBatch) -> Array:
    """Embeddings Z: (T, N, out_dim) — plain (non-blocked) forward."""
    carries = init_carries(cfg, params, dtype=batch.frames.dtype)
    z, _ = forward_slice(cfg, params, batch.frames, batch.edges,
                         batch.edge_weights, carries, 0)
    return z


def classify(params: dict, z: Array) -> Array:
    """Per-(t, u) logits via the shared projection U (§2.2)."""
    return z @ params["classifier"]["u"] + params["classifier"]["b"]


def node_loss(cfg: DynGNNConfig, params: dict, batch: DTDGBatch,
              labels: Array, label_mask: Array | None = None) -> Array:
    """Cross-entropy vertex classification over all (t, u)."""
    z = forward(cfg, params, batch)
    logits = classify(params, z)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if label_mask is not None:
        return jnp.sum(nll * label_mask) / jnp.maximum(label_mask.sum(), 1.0)
    return jnp.mean(nll)


def link_logits(params: dict, z_t: Array, pairs: Array) -> Array:
    """Link prediction head (§6.4): concat endpoint embeddings -> FC layer.

    z_t: (N, F'); pairs: (B, 2). The classifier U doubles as the FC layer by
    applying it to each endpoint and summing (equivalent to a (2F' x C) FC on
    the concatenation).
    """
    zu = jnp.take(z_t, pairs[:, 0], axis=0)
    zv = jnp.take(z_t, pairs[:, 1], axis=0)
    u = params["classifier"]["u"]
    b = params["classifier"]["b"]
    return zu @ u + zv @ u + b
