"""The state-advance / query split of online dyngnn serving.

Training fuses "roll the temporal state forward" and "read scores out"
into one loss step; serving needs them apart:

* the STATE-ADVANCE step runs once per closed time window — apply the
  window's edge delta (the ``DeltaApplier`` ring reconstructs the padded
  edge list on device), recompute the Laplacian weights from the
  reconstructed topology, run the layer stack over the length-1 timeline
  slice, and roll the per-layer temporal carries forward.  It is jitted
  with the carries DONATED: the rolled state overwrites the retiring
  buffers, so resident state stays O(state) regardless of how long the
  stream runs.  The math is ``stream.train_loop.advance_slice`` — the
  same function the training steps differentiate through, which is what
  pins served scores to the offline reference;

* the QUERY steps are pure reads against the resident embeddings
  ``z_t``: gather the requested rows, apply the classifier (node
  scoring) or the link head (link prediction).  They are jitted per
  static micro-batch bucket, so live traffic never recompiles.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import sanitize
from repro.core import models as mdl
from repro.stream.train_loop import advance_slice


def make_advance_step(cfg: mdl.DynGNNConfig):
    """Jitted, carry-donating state advance for one serve window.

    (params, carries, frame (N, F), edges (E, 2), mask (E,), values (E,),
    t_offset) -> (z_t (N, F'), new carries).  ``z_t`` is the warm-state
    cache the query steps read; the donated carries make the temporal
    state truly resident (rolled in place, never reallocated).  Under
    ``REPRO_SANITIZE=1`` the retired carries are poisoned after each
    call, so a stale alias (the PR-6 ``init_carries`` param-aliasing bug
    class) raises instead of silently reusing donated memory.
    """

    @partial(jax.jit, donate_argnums=(1,))
    def advance(params, carries, frame, edges, mask, values, t_offset):
        z, new_carries = advance_slice(cfg, params, carries, frame[None],
                                       edges[None], mask[None],
                                       values[None], t_offset)
        return z[0], new_carries

    return sanitize.guard_donated(advance, (1,))


def make_node_query_step():
    """Jitted batched node-scoring read: (params, z (N, F'), ids (B,))
    -> per-class logits (B, C).  B is a static bucket size — callers pad."""

    @jax.jit
    def query(params, z, ids):
        return mdl.classify(params, jnp.take(z, ids, axis=0))

    return query


def make_link_query_step():
    """Jitted batched link-prediction read: (params, z (N, F'),
    pairs (B, 2)) -> logits (B, C) via the paper's §6.4 link head."""

    @jax.jit
    def query(params, z, pairs):
        return mdl.link_logits(params, z, pairs)

    return query


def fresh_carries(cfg: mdl.DynGNNConfig, params: dict) -> list:
    """Donation-safe initial carries.

    ``init_carries`` aliases EvolveGCN's initial weight carry to the
    param tensor itself; a donating advance step would then hand the
    param buffer to XLA for reuse.  Serving therefore deep-copies the
    zero state once at session start."""
    return jax.tree_util.tree_map(jnp.array, mdl.init_carries(cfg, params))
