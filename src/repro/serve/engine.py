"""``ServeEngine`` — the declarative online-inference counterpart of
``repro.run.Engine``.

``ServeEngine(ServeConfig).__init__`` resolves the model from the arch
registry (or an explicit config object), builds the family's serving
path once, and then answers requests against RESIDENT state:

* dyngnn — the tentpole path.  Live CTDG events stream in through
  :class:`~repro.serve.ingest.OnlineIngester`; each closed window's
  delta item flows through the same ``DeltaApplier`` ring the trainer
  uses, one donated jitted state-advance rolls the temporal carries
  forward, and the window's node embeddings ``z_t`` stay cached on
  device (the warm-state cache).  Queries — node scoring or link
  prediction — are micro-batched reads against that cache: no
  re-encoding, no model re-run.  After window t the served scores equal
  the offline ``Engine.fit``-then-evaluate forward on the equivalent
  DTDG to <=1e-5 (pinned in ``tests/test_serve.py``).
* lm — prefill + greedy KV-cache decode (the path the legacy
  ``repro.launch.serve`` drove), now behind ``generate()``.
* recsys — batched DIN CTR scoring behind ``score()``.

All families share the ``ServeResult`` counters (latency percentiles,
events/s ingest, resyncs).  Full reference: ``docs/serve_api.md``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, sanitize
from repro.core import models as mdl
from repro.serve.batching import QueryBatcher
from repro.serve.config import IngestSpec, ServeConfig, ServeResult
from repro.serve.ingest import OnlineIngester
from repro.serve.state import (fresh_carries, make_advance_step,
                               make_link_query_step, make_node_query_step)
from repro.stream.encoder import StreamReport
from repro.stream.prefetch import DeltaApplier, stage_item


def _resolve(config: ServeConfig):
    """-> (family, model config) from the registry and/or explicit model."""
    if config.model is not None:
        m = config.model
        if isinstance(m, mdl.DynGNNConfig):
            return "dyngnn", m
        kind = type(m).__name__
        if kind == "LMConfig":
            return "lm", m
        if kind == "DINConfig":
            return "recsys", m
        raise ValueError(f"cannot serve a model config of type {kind}; "
                         "expected DynGNNConfig, LMConfig, or DINConfig")
    from repro.configs import registry
    arch = registry.get_arch(config.arch)
    if arch.family == "gnn":
        raise ValueError(
            f"arch '{config.arch}' is a static-graph gnn; online serving "
            "supports the dyngnn, lm, and recsys families")
    return arch.family, arch.make_smoke_config()


class ServeEngine:
    """One serving session: resolved model + resident state + counters.

    ``params`` (optionally with trained values, e.g.
    ``Engine.fit().state.params``) defaults to a seed-keyed fresh init —
    the same seed plumbing as ``RunConfig``.
    """

    def __init__(self, config: ServeConfig, params: dict | None = None,
                 keep_history: bool = False):
        config.validate()
        self.config = config
        self.family, self.model = _resolve(config)
        self.report = StreamReport()
        self._result = ServeResult(family=self.family, arch=config.arch)
        # scope the shared registry to this session: result() reports
        # the delta against this baseline as ServeResult.metrics
        self._metrics_base = obs.metrics_snapshot()
        self._spans_base = obs.get_tracer().recorded
        key = jax.random.PRNGKey(config.seed)
        self._rng = np.random.default_rng(config.seed)
        if self.family == "dyngnn":
            self._init_dyngnn(key, params, keep_history)
        elif self.family == "lm":
            self._init_lm(key, params)
        else:
            self._init_recsys(key, params)

    def _family_guard(self, method: str, *families: str) -> None:
        if self.family not in families:
            raise ValueError(
                f"{method}() serves the {'/'.join(families)} family; this "
                f"engine is serving family={self.family!r}")

    # ------------------------------------------------------------ dyngnn ---
    def _init_dyngnn(self, key, params, keep_history) -> None:
        cfg = self.model
        if self.config.ingest is None:
            raise ValueError(
                "dyngnn serving needs ServeConfig.ingest (an IngestSpec "
                "describing the live event-stream discretization)")
        # NB: the §5.4 smoothing transforms (mproduct/edgelife) read
        # FUTURE windows and are data-pipeline preprocessing — a live
        # stream serves the raw alive-edge snapshots (the offline
        # smoothing_mode="none" data path).
        self.params = params if params is not None \
            else mdl.init_params(key, cfg)
        # Resident state (carries, warm z) is single-owner by design:
        # every method touching it enters this guard, so concurrent
        # callers get an immediate RuntimeError (counted on ServeResult)
        # instead of interleaved donated state-advances.
        self._guard = sanitize.ThreadAffinityGuard("ServeEngine")
        self.carries = fresh_carries(cfg, self.params)
        self.ingester = OnlineIngester(self.config.ingest, cfg.num_nodes,
                                       report=self.report,
                                       keep_history=keep_history)
        self.applier = DeltaApplier(self.config.ingest.max_edges)
        self._advance = make_advance_step(cfg)
        node_step, link_step = make_node_query_step(), make_link_query_step()
        self.z: jax.Array | None = None     # warm-state cache (N, F')
        self._node_batcher = QueryBatcher(
            lambda ids: np.asarray(node_step(
                self.params, self._warm_z(),
                jax.device_put(ids.astype(np.int32)))),
            self.config.batch_sizes, self.config.queue_depth)
        self._link_batcher = QueryBatcher(
            lambda pairs: np.asarray(link_step(
                self.params, self._warm_z(),
                jax.device_put(pairs.astype(np.int32)))),
            self.config.batch_sizes, self.config.queue_depth)

    def _warm_z(self) -> jax.Array:
        if self.z is None:
            raise ValueError("no resident state yet: ingest events and "
                             "advance() at least one window before querying")
        return self.z

    def ingest(self, stream) -> int:
        """Push live CTDG events into the open-window buffer."""
        self._family_guard("ingest", "dyngnn")
        with self._guard:
            with obs.stopwatch("serve.ingest", cat="serve") as sw:
                n = self.ingester.push(stream)
            self._result.ingest_seconds += sw.seconds
            self._result.events_ingested = n
            # push() returns the running total -> gauge, not counter
            obs.gauge("serve.events_ingested", n)
            return n

    def advance(self, windows: int = 1) -> jax.Array:
        """Close ``windows`` time windows and roll the resident state.

        Each window: encode the delta on host, stage it, reconstruct the
        padded edge list on device (donated ring), one jitted
        state-advance (donated carries), refresh the warm ``z`` cache.
        Any queries still queued against the OLD state are flushed first
        — the cache is never invalidated under a pending request.
        """
        self._family_guard("advance", "dyngnn")
        with self._guard:
            self._node_batcher.flush()
            self._link_batcher.flush()
            with obs.stopwatch("serve.advance", cat="serve",
                               windows=windows) as sw:
                for _ in range(windows):
                    t_idx = self.ingester.next_window
                    with obs.span("serve.window", cat="serve", t=t_idx):
                        item, frame = self.ingester.close_window()
                        item, frame = stage_item((item, frame))
                        edges, mask, vals = self.applier.consume(item)
                        self.z, self.carries = self._advance(
                            self.params, self.carries, frame, edges, mask,
                            vals, jnp.int32(t_idx))
                    obs.inc("serve.windows_advanced")
                jax.block_until_ready(self.z)
            self._result.ingest_seconds += sw.seconds
            self._result.windows_advanced = self.ingester.next_window
            self._result.resyncs = self.report.resyncs
            return self.z

    def advance_all(self) -> jax.Array:
        """Close every remaining configured window (bounded specs)."""
        spec = self.config.ingest
        if not spec.num_windows:
            raise ValueError("advance_all() needs a bounded IngestSpec "
                             "(num_windows set); open-ended streams "
                             "advance(1) as windows elapse")
        return self.advance(spec.num_windows - self.ingester.next_window)

    def submit_nodes(self, ids):
        """Queue a node-scoring request (micro-batched; see flush())."""
        self._family_guard("submit_nodes", "dyngnn")
        with self._guard:
            self._warm_z()
            return self._node_batcher.submit(np.asarray(ids))

    def submit_links(self, pairs):
        """Queue a link-prediction request for (src, dst) pairs."""
        self._family_guard("submit_links", "dyngnn")
        with self._guard:
            self._warm_z()
            return self._link_batcher.submit(np.asarray(pairs))

    def flush(self) -> None:
        """Score everything queued (both query types)."""
        self._family_guard("flush", "dyngnn")
        with self._guard:
            self._node_batcher.flush()
            self._link_batcher.flush()

    def query_nodes(self, ids) -> np.ndarray:
        """Synchronous node scores (B, C) against resident state."""
        self._family_guard("query_nodes", "dyngnn")
        with self._guard:
            self._warm_z()
            return self._node_batcher.query(np.asarray(ids))

    def query_links(self, pairs) -> np.ndarray:
        """Synchronous link logits (B, C) against resident state."""
        self._family_guard("query_links", "dyngnn")
        with self._guard:
            self._warm_z()
            return self._link_batcher.query(np.asarray(pairs))

    def cold_query_nodes(self, ids) -> np.ndarray:
        """The no-resident-state baseline: re-encode the WHOLE ingested
        history, re-run the model over every window, then score.

        Needs ``keep_history=True``.  This is what each query would cost
        without the warm cache — the denominator of the >=2x speedup
        ``benchmarks/serve_bench.py`` demonstrates."""
        self._family_guard("cold_query_nodes", "dyngnn")
        cfg = self.model
        applier = DeltaApplier(self.config.ingest.max_edges)
        carries = fresh_carries(cfg, self.params)
        advance = make_advance_step(cfg)
        z = None
        for t, (item, frame) in enumerate(self.ingester.replay()):
            item, frame = stage_item((item, frame))
            edges, mask, vals = applier.consume(item)
            z, carries = advance(self.params, carries, frame, edges, mask,
                                 vals, jnp.int32(t))
        if z is None:
            raise ValueError("no windows closed yet")
        ids = jnp.asarray(np.asarray(ids).astype(np.int32))
        return np.asarray(mdl.classify(self.params,
                                       jnp.take(z, ids, axis=0)))

    # ---------------------------------------------------------------- lm ---
    def _init_lm(self, key, params) -> None:
        from repro.models import lm
        cfg = self.model
        self.params = params if params is not None \
            else lm.init_lm_params(key, cfg)
        max_len = self.config.prompt_len + self.config.max_tokens
        self._prefill = jax.jit(
            lambda p, t: lm.prefill(cfg, p, t, max_len=max_len))
        self._decode = jax.jit(lambda p, c, t: lm.decode_step(cfg, p, c, t))

    def generate(self, prompts=None, batch_size: int | None = None
                 ) -> np.ndarray:
        """Prefill + greedy decode one request wave -> generated tokens
        (B, max_tokens).  ``prompts`` defaults to a synthetic
        (batch_size, prompt_len) wave from the seeded generator."""
        self._family_guard("generate", "lm")
        cfg, sc = self.model, self.config
        if prompts is None:
            b = batch_size or sc.batch_sizes[-1]
            prompts = self._rng.integers(0, cfg.vocab_size,
                                         (b, sc.prompt_len))
        prompts = jnp.asarray(np.asarray(prompts), jnp.int32)
        with obs.stopwatch("serve.generate", cat="serve",
                           batch=int(prompts.shape[0])) as sw:
            logits, cache = self._prefill(self.params, prompts)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out = [tok]
            for _ in range(sc.max_tokens - 1):
                logits, cache = self._decode(self.params, cache, tok)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                out.append(tok)
            tokens = np.asarray(jax.block_until_ready(
                jnp.stack(out, axis=1)))
        dt = sw.seconds
        r = self._result
        r.queries += int(prompts.shape[0])
        r.query_batches += 1
        r.tokens_generated += tokens.size
        r.query_seconds += dt
        r.query_latencies_ms.append(dt * 1e3)
        obs.inc("serve.queries", int(prompts.shape[0]))
        obs.inc("serve.tokens_generated", tokens.size)
        return tokens

    # ------------------------------------------------------------ recsys ---
    def _init_recsys(self, key, params) -> None:
        from repro.models import din
        self.params = params if params is not None \
            else din.init_params(key, self.model)
        self._fwd = jax.jit(din.forward)
        self._din = din

    def synthetic_requests(self, batch_size: int) -> dict:
        """One synthetic CTR request batch from the seeded generator."""
        cfg, rng = self.model, self._rng
        b, s = batch_size, cfg.seq_len
        ints = rng.integers
        return {
            "user_id": jnp.asarray(ints(0, cfg.user_vocab, (b,)),
                                   jnp.int32),
            "hist_items": jnp.asarray(ints(0, cfg.item_vocab, (b, s)),
                                      jnp.int32),
            "hist_cates": jnp.asarray(ints(0, cfg.cate_vocab, (b, s)),
                                      jnp.int32),
            "hist_mask": jnp.ones((b, s), jnp.float32),
            "target_item": jnp.asarray(ints(0, cfg.item_vocab, (b,)),
                                       jnp.int32),
            "target_cate": jnp.asarray(ints(0, cfg.cate_vocab, (b,)),
                                       jnp.int32),
        }

    def score(self, batch: dict | None = None,
              batch_size: int | None = None) -> np.ndarray:
        """Batched CTR scores for one request wave."""
        self._family_guard("score", "recsys")
        if batch is None:
            batch = self.synthetic_requests(
                batch_size or self.config.batch_sizes[-1])
        with obs.stopwatch("serve.score", cat="serve") as sw:
            scores = np.asarray(jax.block_until_ready(
                self._fwd(self.params, batch)))
        dt = sw.seconds
        r = self._result
        r.queries += int(scores.shape[0])
        r.query_batches += 1
        r.query_seconds += dt
        r.query_latencies_ms.append(dt * 1e3)
        obs.inc("serve.queries", int(scores.shape[0]))
        return scores

    # ------------------------------------------------------------ result ---
    def result(self) -> ServeResult:
        """Session counters so far (flushes pending dyngnn queries)."""
        r = self._result
        if self.family == "dyngnn":
            with self._guard:
                self._node_batcher.flush()
                self._link_batcher.flush()
            r.guard_trips = self._guard.trips
            r.queries = (self._node_batcher.stats.queries
                         + self._link_batcher.stats.queries)
            r.query_batches = (self._node_batcher.stats.batches
                               + self._link_batcher.stats.batches)
            r.query_seconds = (self._node_batcher.stats.seconds
                               + self._link_batcher.stats.seconds)
            r.query_latencies_ms = (self._node_batcher.stats.latencies_ms
                                    + self._link_batcher.stats.latencies_ms)
            r.events_ingested = self.ingester.events_ingested
            r.resyncs = self.report.resyncs
        trc = obs.get_tracer()
        r.metrics = obs.metrics().delta(self._metrics_base)
        r.metrics["spans"] = trc.summary(
            trc.spans_since(self._spans_base))
        return r


def serve(config: ServeConfig, params: dict | None = None,
          **kwargs) -> ServeEngine:
    """Sugar mirroring ``repro.run``'s declarative style:
    ``serve(ServeConfig(arch=...))`` -> ready engine."""
    return ServeEngine(config, params=params, **kwargs)
