"""``repro.serve`` — online inference against resident temporal state.

The serving counterpart of ``repro.run``: a declarative
``ServeConfig -> ServeEngine`` surface over the paper's streaming
machinery.  For dyngnn, live CTDG events ingest incrementally
(``OnlineIngester`` -> the graph-diff delta stream), one donated jitted
state-advance per closed window rolls the temporal carries forward, and
queries are micro-batched reads against the warm on-device embedding
cache.  The lm and recsys serve paths (formerly ``repro.launch.serve``)
live behind the same surface.

    from repro.serve import IngestSpec, ServeConfig, ServeEngine

    eng = ServeEngine(ServeConfig(
        arch="paper_dyngnn",
        ingest=IngestSpec(num_windows=16, time_range=(0.0, 1.0))))
    eng.ingest(events)                 # live CTDG pushes
    eng.advance()                      # close a window, roll state
    scores = eng.query_nodes([3, 17])  # read resident state

Full reference: ``docs/serve_api.md`` (CI-executed).
"""

from repro.serve.batching import PendingQuery, QueryBatcher
from repro.serve.config import IngestSpec, ServeConfig, ServeResult
from repro.serve.engine import ServeEngine, serve
from repro.serve.ingest import LateEventError, OnlineIngester
from repro.serve.state import (fresh_carries, make_advance_step,
                               make_link_query_step, make_node_query_step)

__all__ = [
    "IngestSpec", "LateEventError", "OnlineIngester", "PendingQuery",
    "QueryBatcher", "ServeConfig", "ServeEngine", "ServeResult",
    "fresh_carries", "make_advance_step", "make_link_query_step",
    "make_node_query_step", "serve",
]
