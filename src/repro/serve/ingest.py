"""Live CTDG ingest: event pushes -> per-window delta-stream items.

The online half of the ``core.ctdg`` bridge.  Offline, the whole event
trace exists up front and ``snapshot_events`` / ``window_events``
materialize every snapshot at once; online, events arrive in pushes and
windows close one at a time.  :class:`OnlineIngester` therefore runs the
SAME primitives incrementally:

* window binning via ``IngestSpec.window_of`` — the exact offline
  formulas (``snapshot_window_index`` / ``interaction_window_index``),
  so a live stream discretizes onto the windows the offline bridge
  would produce;
* alive-edge bookkeeping via :class:`~repro.core.ctdg.AliveSet` — the
  same insertion-ordered structure, applied window by window (window
  index is monotone in sorted time, so per-window application preserves
  the offline global order and the snapshots are byte-identical);
* delta encoding via :class:`~repro.stream.encoder.IncrementalEncoder`
  — the object ``iter_encode_stream`` itself loops over, so online and
  offline encodings of the same snapshots are one code path.

Nothing is ever materialized for the full trace: the ingester holds the
not-yet-closed event buffer, the alive set, and the encoder's device
mirror — O(current graph + open-window events), independent of stream
length.
"""

from __future__ import annotations

import numpy as np

from repro.core.ctdg import AliveSet, EventStream
from repro.core.graphdiff import FullSnapshot, SnapshotDelta
from repro.graph import generate
from repro.serve.config import IngestSpec
from repro.stream.encoder import IncrementalEncoder, StreamReport


class LateEventError(ValueError):
    """A pushed event belongs to an already-closed window."""

    def __init__(self, time: float, window: int, next_window: int):
        self.time, self.window, self.next_window = time, window, next_window
        super().__init__(
            f"event at t={time} belongs to window {window}, which already "
            f"closed (next open window is {next_window}); late events "
            "cannot be applied retroactively — widen the windows or "
            "buffer upstream")


class OnlineIngester:
    """Consume CTDG event pushes; emit one delta item per closed window.

    ``push(stream)`` buffers validated events (each push must be
    time-sorted and may not reach back into a closed window).
    ``close_window()`` binds the next window: it takes the buffered
    events the policy assigns to it, rolls the alive set forward
    (snapshot policy; strict — a delete of a never-inserted edge raises)
    or collects the window's unique observed insertions (window policy),
    and returns ``(item, frame)`` — the encoded delta-stream item the
    :class:`~repro.stream.prefetch.DeltaApplier` consumes plus the
    window's degree-feature frame.

    ``keep_history=True`` additionally records each closed window's raw
    snapshot — the replay source for cold-path comparisons
    (``benchmarks/serve_bench.py``) and for late-joining consumers.
    """

    def __init__(self, spec: IngestSpec, num_nodes: int,
                 report: StreamReport | None = None,
                 keep_history: bool = False):
        spec.validate()
        if num_nodes < 1:
            raise ValueError(f"num_nodes must be >= 1, got {num_nodes}")
        self.spec = spec
        self.num_nodes = num_nodes
        self.report = report if report is not None else StreamReport()
        self.next_window = 0
        self.events_ingested = 0
        self._alive = AliveSet(num_nodes)
        self._enc = IncrementalEncoder(
            num_nodes, spec.max_edges, spec.block_size,
            spec.drop_add_pad, spec.drop_add_pad,
            on_overflow="resync", report=self.report)
        # open-event buffer: one (src, dst, time, kind) tuple per push,
        # concatenated lazily at window close
        self._buf: list[tuple[np.ndarray, ...]] = []
        self.history: list[np.ndarray] | None = [] if keep_history else None

    # ------------------------------------------------------------- ingest --
    def push(self, stream: EventStream) -> int:
        """Buffer one push of events; returns events accepted so far.

        Per-push validation only (sortedness, ids, kinds, finite times) —
        delete-before-insert is inherently a cross-push property online,
        so it is enforced where the history lives: strictly, by the
        alive set, at window close.
        """
        if stream.num_nodes != self.num_nodes:
            raise ValueError(
                f"push has num_nodes={stream.num_nodes} but the ingester "
                f"serves {self.num_nodes} nodes")
        stream.validate(require_sorted=True, check_deletes=False)
        win = self.spec.window_of(stream.time)
        if win.min() < self.next_window:
            i = int(np.nonzero(win < self.next_window)[0][0])
            raise LateEventError(float(stream.time[i]), int(win[i]),
                                 self.next_window)
        self._buf.append((np.asarray(stream.src), np.asarray(stream.dst),
                          np.asarray(stream.time), np.asarray(stream.kind)))
        self.events_ingested += len(stream)
        return self.events_ingested

    @property
    def buffered_events(self) -> int:
        return sum(s.shape[0] for s, _, _, _ in self._buf)

    # ------------------------------------------------------ window close ---
    def _take_window(self, k: int) -> tuple[np.ndarray, ...]:
        """Pop window k's events from the buffer, in stable time order."""
        if not self._buf:
            return (np.zeros(0, np.int32),) * 2 + (np.zeros(0),
                                                   np.zeros(0, np.int8))
        src = np.concatenate([b[0] for b in self._buf])
        dst = np.concatenate([b[1] for b in self._buf])
        time = np.concatenate([b[2] for b in self._buf])
        kind = np.concatenate([b[3] for b in self._buf])
        order = np.argsort(time, kind="stable")
        src, dst, time, kind = (src[order], dst[order], time[order],
                                kind[order])
        win = self.spec.window_of(time)
        sel = win == k
        keep = win > k
        self._buf = [(src[keep], dst[keep], time[keep], kind[keep])] \
            if keep.any() else []
        return src[sel], dst[sel], time[sel], kind[sel]

    def close_window(self) -> tuple[FullSnapshot | SnapshotDelta,
                                    np.ndarray]:
        """Bind the next window -> (encoded stream item, frame (N, 2))."""
        k = self.next_window
        if self.spec.num_windows and k >= self.spec.num_windows:
            raise ValueError(f"all {self.spec.num_windows} windows already "
                             "closed")
        src, dst, _, kind = self._take_window(k)
        if self.spec.policy == "snapshot":
            self._alive.apply(src, dst, kind, strict=True)
            snap = self._alive.snapshot()
        else:
            ins = kind > 0
            e = np.stack([src[ins], dst[ins]], axis=1).astype(np.int32)
            snap = np.unique(e, axis=0) if e.size \
                else np.zeros((0, 2), np.int32)
        if snap.shape[0] > self.spec.max_edges:
            raise ValueError(
                f"window {k} has {snap.shape[0]} alive edges, over the "
                f"configured max_edges={self.spec.max_edges}; serving "
                "bounds device memory up front — raise max_edges")
        self.next_window = k + 1
        if self.history is not None:
            self.history.append(snap)
        frame = generate.degree_features(snap, self.num_nodes)
        return self._enc.encode(snap), frame

    def replay(self):
        """Re-encode the kept history from scratch (fresh encoder) —
        the cold path: what serving would cost without resident state."""
        if self.history is None:
            raise ValueError("replay needs keep_history=True")
        enc = IncrementalEncoder(
            self.num_nodes, self.spec.max_edges, self.spec.block_size,
            self.spec.drop_add_pad, self.spec.drop_add_pad,
            on_overflow="resync")
        for snap in self.history:
            yield enc.encode(snap), generate.degree_features(
                snap, self.num_nodes)
