"""Request micro-batching over static padded batch shapes.

Live query traffic arrives in ragged sizes; jitted query steps want
static shapes.  :class:`QueryBatcher` bridges the two:

* requests land on a BOUNDED queue (``queue_depth`` — backpressure: a
  submit into a full queue flushes the batch first, so pending work can
  never grow without limit);
* ``flush()`` drains the queue, concatenates the rows, and runs them in
  chunks padded up to the smallest configured bucket that fits (largest
  bucket per chunk) — one compiled query step per bucket size, ever,
  regardless of traffic pattern;
* per-request latency is measured submit -> scores-on-host and recorded
  for the session's :class:`~repro.serve.config.ServeResult`.

The run function owns the actual compute: it receives one padded
``(bucket, ...)`` array and must return host scores for those rows
(blocking until ready — the latency numbers are honest).
"""

from __future__ import annotations

import queue
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro import obs


@dataclass
class PendingQuery:
    """One submitted request: ``rows`` in, ``scores`` out after a flush."""
    rows: np.ndarray
    submitted_at: float
    scores: np.ndarray | None = None

    @property
    def done(self) -> bool:
        return self.scores is not None


@dataclass
class BatcherStats:
    queries: int = 0          # individual requests
    rows: int = 0             # total rows scored (pre-padding)
    batches: int = 0          # padded device batches launched
    seconds: float = 0.0      # wall time inside flush()
    latencies_ms: list[float] = field(default_factory=list)


class QueryBatcher:
    """Bounded-queue micro-batcher in front of one padded query step."""

    def __init__(self, run_fn: Callable[[np.ndarray], np.ndarray],
                 batch_sizes: tuple[int, ...], queue_depth: int):
        if not batch_sizes or list(batch_sizes) != sorted(batch_sizes):
            raise ValueError(f"batch_sizes must be ascending and "
                             f"non-empty, got {batch_sizes}")
        self.run_fn = run_fn
        self.buckets = tuple(int(b) for b in batch_sizes)
        self._q: queue.Queue = queue.Queue(maxsize=queue_depth)
        self.stats = BatcherStats()

    def bucket_for(self, n: int) -> int:
        """Smallest configured bucket >= n (chunking caps n at max)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def submit(self, rows) -> PendingQuery:
        """Enqueue one request; flushes first if the queue is full."""
        rows = np.asarray(rows)
        if rows.shape[0] == 0:
            raise ValueError("empty query")
        # span-clock timestamp: latency shares the tracer's clock, so
        # submit -> flush waits line up with spans in an exported trace
        p = PendingQuery(rows=rows, submitted_at=obs.now_s())
        try:
            self._q.put_nowait(p)
        except queue.Full:
            self.flush()
            self._q.put_nowait(p)
        return p

    def _drain(self) -> list[PendingQuery]:
        out = []
        while True:
            try:
                out.append(self._q.get_nowait())
            except queue.Empty:
                return out

    def flush(self) -> list[PendingQuery]:
        """Score everything queued; returns the completed requests."""
        pending = self._drain()
        if not pending:
            return []
        with obs.stopwatch("serve.query.flush", cat="serve",
                           queries=len(pending)) as sw:
            rows = np.concatenate([p.rows for p in pending], axis=0)
            cap = self.buckets[-1]
            chunks = []
            for lo in range(0, rows.shape[0], cap):
                chunk = rows[lo:lo + cap]
                b = self.bucket_for(chunk.shape[0])
                padded = np.zeros((b,) + chunk.shape[1:], dtype=chunk.dtype)
                padded[:chunk.shape[0]] = chunk
                chunks.append(
                    np.asarray(self.run_fn(padded))[:chunk.shape[0]])
                self.stats.batches += 1
            scores = np.concatenate(chunks, axis=0)
        done = sw.start_s + sw.seconds       # flush end, on the span clock
        off = 0
        for p in pending:
            n = p.rows.shape[0]
            p.scores = scores[off:off + n]
            off += n
            self.stats.latencies_ms.append((done - p.submitted_at) * 1e3)
        self.stats.queries += len(pending)
        self.stats.rows += rows.shape[0]
        self.stats.seconds += sw.seconds
        obs.inc("serve.queries", len(pending))
        obs.inc("serve.query_rows", int(rows.shape[0]))
        return pending

    def query(self, rows) -> np.ndarray:
        """Synchronous convenience: submit + flush -> this request's
        scores (anything else queued rides along in the same flush)."""
        p = self.submit(rows)
        self.flush()
        return p.scores
