"""Serve configuration: the one declarative description of a serving run.

``ServeConfig -> ServeEngine`` mirrors the training surface
(``RunConfig -> Engine.fit()``): the config separates

* the MODEL — an arch id from the registry (``arch="paper_dyngnn"``,
  ``"yi-6b"``, ``"din"``) and/or an explicit config object (``model=``,
  which wins; for dyngnn a :class:`repro.core.models.DynGNNConfig`);
* the INGEST discretization (:class:`IngestSpec`, dyngnn only) — how the
  live CTDG event stream bins into time windows and how the delta
  encoder pads its payloads;
* the QUERY path — static padded micro-batch buckets and the bounded
  request queue.

``ServeEngine`` answers queries against resident temporal state;
``ServeResult`` carries the latency / throughput / ingest counters.
Full reference: ``docs/serve_api.md`` (CI-executed).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.ctdg import (POLICIES, interaction_window_index,
                             snapshot_window_index, uniform_bounds)


@dataclass(frozen=True)
class IngestSpec:
    """How a live CTDG event stream discretizes into serve windows.

    * ``policy`` — ``"snapshot"`` (alive-edge view, ``snapshot_events``
      semantics) or ``"window"`` (interaction view, ``window_events``
      semantics); the online binning uses the exact offline formulas so
      a served stream discretizes onto the same windows the offline
      bridge would produce.
    * window geometry — either ``time_range=(t0, t1)`` split uniformly
      into ``num_windows`` (the offline-equivalent mode; the window
      policy requires it), or an open-ended ``window_span`` starting at
      ``t_start`` (live mode: window k covers
      ``(t_start + k*span, t_start + (k+1)*span]``).
    * ``block_size`` — full-snapshot resync cadence of the delta
      encoder (every ``block_size``-th window ships full — the online
      analogue of the offline checkpoint-block boundary rule);
    * ``max_edges`` — device edge-buffer capacity (serving must bound
      memory up front: a window whose graph exceeds it fails loudly);
    * ``churn_pad`` — drop/add delta pad size (None = ``max_edges``,
      always safe; size it from measured churn stats to shrink the
      per-window ingest payload).  Overflowing churn degrades to a
      FullSnapshot resync, counted on the report.
    """

    num_windows: int = 0                    # 0 = open-ended (span mode)
    policy: str = "snapshot"
    time_range: tuple[float, float] | None = None
    window_span: float | None = None
    t_start: float = 0.0
    block_size: int = 8
    max_edges: int = 4096
    churn_pad: int | None = None

    def validate(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"ingest.policy must be one of {POLICIES}, "
                             f"got {self.policy!r}")
        if (self.time_range is None) == (self.window_span is None):
            raise ValueError(
                "IngestSpec needs exactly one window geometry: either "
                "time_range=(t0, t1) with num_windows, or an open-ended "
                "window_span")
        if self.time_range is not None:
            t0, t1 = self.time_range
            if not (np.isfinite(t0) and np.isfinite(t1)) or t1 <= t0:
                raise ValueError(f"ingest.time_range must be a finite "
                                 f"(t0, t1) with t1 > t0, got "
                                 f"{self.time_range}")
            if self.num_windows < 1:
                raise ValueError("ingest.num_windows must be >= 1 when "
                                 "time_range is set")
        else:
            if self.window_span <= 0:
                raise ValueError(f"ingest.window_span must be positive, "
                                 f"got {self.window_span}")
            if self.policy == "window":
                raise ValueError(
                    "ingest.policy='window' bins with the offline "
                    "interaction formula, which needs the full "
                    "time_range — open-ended window_span only supports "
                    "policy='snapshot'")
        if self.block_size < 1:
            raise ValueError("ingest.block_size must be >= 1")
        if self.max_edges < 1:
            raise ValueError("ingest.max_edges must be >= 1")
        if self.churn_pad is not None and not (
                1 <= self.churn_pad <= self.max_edges):
            raise ValueError(f"ingest.churn_pad must be in "
                             f"[1, max_edges={self.max_edges}], got "
                             f"{self.churn_pad}")

    @property
    def drop_add_pad(self) -> int:
        return self.churn_pad if self.churn_pad is not None \
            else self.max_edges

    def bound(self, k: int) -> float:
        """End time of window k."""
        if self.time_range is not None:
            t0, t1 = self.time_range
            return float(uniform_bounds(t0, t1, self.num_windows)[k])
        return self.t_start + (k + 1) * self.window_span

    def window_of(self, time: np.ndarray) -> np.ndarray:
        """Window index owning each event time (policy-exact binning)."""
        time = np.asarray(time)
        if self.time_range is not None:
            t0, t1 = self.time_range
            if self.policy == "window":
                return interaction_window_index(time, t0, t1,
                                                self.num_windows)
            bounds = uniform_bounds(t0, t1, self.num_windows)
            return snapshot_window_index(time, bounds)
        idx = np.ceil((time - self.t_start) / self.window_span) - 1
        return np.maximum(idx, 0).astype(np.int64)


@dataclass(frozen=True)
class ServeConfig:
    """Declarative serving spec (see module docstring).

    ``batch_sizes`` are the STATIC padded query-batch shapes: every
    micro-batch pads up to the smallest bucket that fits (one compiled
    query step per bucket — no shape-churn recompiles under live
    traffic).  ``queue_depth`` bounds the pending-request queue
    (backpressure: a submit into a full queue flushes first).  ``seed``
    drives param init when no trained state is supplied, and the
    synthetic request generators of the lm/recsys families.
    """

    arch: str | None = None
    model: Any = None                       # explicit config object (wins)
    ingest: IngestSpec | None = None        # dyngnn family only
    batch_sizes: tuple[int, ...] = (1, 8, 64)
    queue_depth: int = 64
    warm_cache: bool = True
    seed: int = 0
    # lm-family knobs (prefill + greedy decode)
    prompt_len: int = 32
    max_tokens: int = 64

    def validate(self) -> None:
        if self.arch is None and self.model is None:
            raise ValueError("ServeConfig needs an arch id or an explicit "
                             "model config")
        if not self.batch_sizes or any(b < 1 for b in self.batch_sizes):
            raise ValueError(f"ServeConfig.batch_sizes must be positive, "
                             f"got {self.batch_sizes}")
        if tuple(sorted(self.batch_sizes)) != tuple(self.batch_sizes):
            raise ValueError(f"ServeConfig.batch_sizes must be ascending, "
                             f"got {self.batch_sizes}")
        if self.queue_depth < 1:
            raise ValueError("ServeConfig.queue_depth must be >= 1")
        if self.prompt_len < 1 or self.max_tokens < 1:
            raise ValueError("ServeConfig.prompt_len/max_tokens must be "
                             ">= 1")
        if self.ingest is not None:
            self.ingest.validate()


@dataclass
class ServeResult:
    """Counters of a serving session (returned by ``ServeEngine.result()``).

    Latency percentiles are per REQUEST (submit -> scores on host),
    including queueing and micro-batch padding; ``events_per_s`` counts
    ingested events over the wall time spent in ingest + state advance.
    """

    family: str
    arch: str | None = None
    events_ingested: int = 0
    windows_advanced: int = 0
    resyncs: int = 0                        # delta-pad overflow resyncs
    queries: int = 0
    query_batches: int = 0
    tokens_generated: int = 0               # lm family
    guard_trips: int = 0                    # rejected concurrent entries
    ingest_seconds: float = 0.0
    query_seconds: float = 0.0
    query_latencies_ms: list[float] = field(default_factory=list)
    # repro.obs registry delta scoped to this session (counters/gauges
    # namespaced per docs/observability.md) + per-name span summary
    metrics: dict | None = None

    def latency_ms(self, pct: float) -> float:
        if not self.query_latencies_ms:
            return float("nan")
        return float(np.percentile(self.query_latencies_ms, pct))

    @property
    def p50_ms(self) -> float:
        return self.latency_ms(50)

    @property
    def p95_ms(self) -> float:
        return self.latency_ms(95)

    @property
    def events_per_s(self) -> float:
        if self.ingest_seconds <= 0:
            return float("nan")
        return self.events_ingested / self.ingest_seconds

    @property
    def queries_per_s(self) -> float:
        if self.query_seconds <= 0:
            return float("nan")
        return self.queries / self.query_seconds

    def summary(self) -> str:
        parts = [f"family={self.family}"]
        if self.arch:
            parts.append(f"arch={self.arch}")
        if self.events_ingested:
            parts.append(f"ingested {self.events_ingested} events over "
                         f"{self.windows_advanced} windows "
                         f"({self.events_per_s:.0f} ev/s, "
                         f"{self.resyncs} resyncs)")
        if self.queries:
            parts.append(f"{self.queries} queries in "
                         f"{self.query_batches} batches "
                         f"(p50 {self.p50_ms:.2f} ms, "
                         f"p95 {self.p95_ms:.2f} ms)")
        if self.tokens_generated:
            parts.append(f"{self.tokens_generated} tokens")
        if self.guard_trips:
            parts.append(f"{self.guard_trips} concurrent entries rejected")
        return "; ".join(parts)
