"""AdamW with master-weight mixed precision, clipping, and LR schedules
(cosine; WSD — warmup-stable-decay — for MiniCPM).

Pure-pytree (no optax dependency): state mirrors the param tree, so the same
PartitionSpecs shard the optimizer state (m, v, fp32 master) as the params —
the layout the dry-run memory analysis accounts for.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | wsd | constant
    stable_frac: float = 0.8          # WSD: fraction of steps at peak LR
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        frac = jnp.asarray(1.0)
    elif cfg.schedule == "cosine":
        t = jnp.clip((s - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) \
            * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        # warmup -> stable plateau -> linear decay (MiniCPM, arXiv:2404.06395)
        stable_end = cfg.warmup_steps + cfg.stable_frac * \
            (cfg.total_steps - cfg.warmup_steps)
        decay_t = jnp.clip((s - stable_end)
                           / jnp.maximum(cfg.total_steps - stable_end, 1),
                           0.0, 1.0)
        frac = 1.0 - (1.0 - cfg.min_lr_frac) * decay_t
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * frac


def init_state(params: Any) -> dict:
    zeros32 = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return {"m": zeros32, "v": jax.tree.map(jnp.copy, zeros32),
            "master": master, "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: dict) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.asarray(1.0)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        master_new = master - lr * delta
        return master_new.astype(p.dtype), m_new, v_new, master_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_ma = jax.tree.leaves(state["master"])
    new = [upd(*xs) for xs in zip(flat_p, flat_g, flat_m, flat_v, flat_ma,
                                  strict=True)]
    params_new = jax.tree.unflatten(treedef, [n[0] for n in new])
    state_new = {
        "m": jax.tree.unflatten(treedef, [n[1] for n in new]),
        "v": jax.tree.unflatten(treedef, [n[2] for n in new]),
        "master": jax.tree.unflatten(treedef, [n[3] for n in new]),
        "step": step,
    }
    return params_new, state_new
