"""Decoder-only transformer LM family (yi-6b / gemma-7b / minicpm-2b /
olmoe-1b-7b / moonshot-v1-16b-a3b).

Structure: pre-RMSNorm blocks of GQA attention + gated FFN (dense GLU or
MoE), RoPE positions, untied output head.  Layer parameters are STACKED on a
leading L axis and the forward is a ``lax.scan`` over layers: the HLO is one
layer's graph regardless of depth, which keeps 256/512-device dry-run
compiles tractable and is the idiomatic production pattern (MaxText does the
same).  ``jax.checkpoint`` on the block body implements activation remat.

Sharding is annotated via ``with_sharding_constraint`` with specs from
``repro.dist.sharding`` (TP over 'model', DP over ('pod','data'), EP for MoE
experts, optional KV-sequence context parallelism for long decode).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn import attention as attn
from repro.nn import layers as nnl
from repro.nn import moe as moelib

Array = jax.Array


@dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 32000
    activation: str = "silu"         # silu = SwiGLU, gelu = GeGLU (gemma)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    rms_plus_one: bool = False       # gemma (1 + w) RMSNorm
    embed_scale: bool = False        # gemma sqrt(d_model) embedding scale
    # MoE (0 experts = dense)
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # execution
    dtype: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = attn.DEFAULT_Q_CHUNK
    # cost-extraction mode: fully unroll layer/chunk scans so XLA's
    # cost_analysis (which counts while bodies ONCE) sees every iteration
    layer_unroll: int = 1
    unroll_chunks: bool = False
    # two-level layer remat (sqrt-checkpointing — the paper's SS3.1 timeline
    # blocking applied to the LAYER axis): save one carry per group of
    # ``layer_block`` layers instead of per layer; inner layers re-nest
    # jax.checkpoint.  0 = flat per-layer remat.
    layer_block: int = 8
    # chunk the CE loss over the sequence so (B, S, V) f32 logits are never
    # materialized (SSPerf iteration 6); 0 = unchunked
    loss_chunk: int = 1024
    # schedule hint (minicpm uses WSD)
    lr_schedule: str = "cosine"

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 256) * 256

    def param_count(self) -> int:
        d, l = self.d_model, self.num_layers
        attn_p = d * self.head_dim * (2 * self.num_heads
                                      + 2 * self.num_kv_heads)
        if self.is_moe:
            ffn_p = self.moe_experts * 3 * d * self.d_ff + d * self.moe_experts
        else:
            ffn_p = 3 * d * self.d_ff
        embed = 2 * self.padded_vocab * d
        return l * (attn_p + ffn_p + 2 * d) + embed + d

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, l = self.d_model, self.num_layers
        attn_p = d * self.head_dim * (2 * self.num_heads
                                      + 2 * self.num_kv_heads)
        ffn_p = self.moe_top_k * 3 * d * self.d_ff
        embed = 2 * self.padded_vocab * d
        return l * (attn_p + ffn_p + 2 * d) + embed + d


# ------------------------------------------------------------- params -------

def init_lm_params(key: Array, cfg: LMConfig) -> dict:
    keys = jax.random.split(key, 8)
    l = cfg.num_layers

    def stack(init_fn, k):
        ks = jax.random.split(k, l)
        return jax.vmap(init_fn)(ks)

    def attn_init(k):
        return attn.init_attention(k, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim, cfg.dtype)

    if cfg.is_moe:
        def ffn_init(k):
            return moelib.init_moe(k, cfg.d_model, cfg.d_ff,
                                   cfg.moe_experts, cfg.dtype)
    else:
        def ffn_init(k):
            return nnl.init_glu_ffn(k, cfg.d_model, cfg.d_ff, cfg.dtype)

    vp = cfg.padded_vocab
    embed = (jax.random.normal(keys[0], (vp, cfg.d_model), jnp.float32)
             * 0.02).astype(cfg.dtype)
    out_w = (jax.random.normal(keys[1], (cfg.d_model, vp), jnp.float32)
             * 0.02).astype(cfg.dtype)
    return {
        "embed": embed,
        "layers": {
            "attn": stack(attn_init, keys[2]),
            "ffn": stack(ffn_init, keys[3]),
            "ln1": jnp.zeros((l, cfg.d_model), cfg.dtype)
            if cfg.rms_plus_one else jnp.ones((l, cfg.d_model), cfg.dtype),
            "ln2": jnp.zeros((l, cfg.d_model), cfg.dtype)
            if cfg.rms_plus_one else jnp.ones((l, cfg.d_model), cfg.dtype),
        },
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype)
        if cfg.rms_plus_one else jnp.ones((cfg.d_model,), cfg.dtype),
        "out": out_w,
    }


# ------------------------------------------------------------ forward -------

def _block(cfg: LMConfig, lp: dict, x: Array, positions: Array,
           constrain, chunk_constrain=None) -> tuple[Array, Array]:
    """One transformer block; returns (x, moe_aux_loss)."""
    h = nnl.rms_norm(x, lp["ln1"], cfg.norm_eps, cfg.rms_plus_one)
    a = attn.attention_apply(lp["attn"], h, positions, cfg.rope_theta,
                             cfg.q_chunk, unroll=cfg.unroll_chunks,
                             chunk_constrain=chunk_constrain)
    x = constrain(x + a)
    h = nnl.rms_norm(x, lp["ln2"], cfg.norm_eps, cfg.rms_plus_one)
    if cfg.is_moe:
        f, aux = moelib.moe_apply(lp["ffn"], h, cfg.moe_top_k,
                                  cfg.moe_capacity_factor, cfg.activation,
                                  ep_constrain=getattr(constrain,
                                                       "ep", None))
        lb = aux["lb_loss"]
    else:
        f = nnl.glu_ffn_apply(lp["ffn"], h, cfg.activation)
        lb = jnp.zeros((), jnp.float32)
    return constrain(x + f), lb


def forward(cfg: LMConfig, params: dict, tokens: Array,
            constrain=lambda x: x,
            return_hidden: bool = False,
            chunk_constrain=None) -> tuple[Array, Array]:
    """tokens (B, S) int32 -> (logits (B, S, Vp) f32, moe aux loss); with
    return_hidden=True returns final hidden states instead of logits."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = constrain(x)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def layer_step(carry, lp):
        x, lb_sum = carry
        x, lb = _block(cfg, lp, x, positions, constrain, chunk_constrain)
        return (x, lb_sum + lb), None

    init = (x, jnp.zeros((), jnp.float32))
    lb_grouping = (cfg.remat and cfg.layer_unroll == 1
                   and 1 < cfg.layer_block < cfg.num_layers
                   and cfg.num_layers % cfg.layer_block == 0)
    if lb_grouping:
        g = cfg.num_layers // cfg.layer_block
        grouped = jax.tree.map(
            lambda a: a.reshape((g, cfg.layer_block) + a.shape[1:]),
            params["layers"])
        inner = jax.checkpoint(layer_step, prevent_cse=True)

        def group_step(carry, glp):
            c2, _ = jax.lax.scan(inner, carry, glp)
            return c2, None

        body = jax.checkpoint(group_step, prevent_cse=True)
        (x, lb_sum), _ = jax.lax.scan(body, init, grouped)
    else:
        step = jax.checkpoint(layer_step, prevent_cse=True) if cfg.remat \
            else layer_step
        (x, lb_sum), _ = jax.lax.scan(step, init, params["layers"],
                                      unroll=cfg.layer_unroll)
    x = nnl.rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.rms_plus_one)
    if return_hidden:
        return x, lb_sum
    logits = jnp.einsum("bsd,dv->bsv", x, params["out"]).astype(jnp.float32)
    return logits, lb_sum


def lm_loss(cfg: LMConfig, params: dict, tokens: Array, targets: Array,
            constrain=lambda x: x, chunk_constrain=None) -> Array:
    """Next-token CE + MoE load-balance aux.

    The head + CE run seq-chunked under remat (cfg.loss_chunk) so the
    (B, S, Vp) f32 logits tensor never exists in full.
    """
    b, s_len = tokens.shape
    hidden, lb = forward(cfg, params, tokens, constrain,
                         return_hidden=True,
                         chunk_constrain=chunk_constrain)
    mask_all = (targets >= 0) & (targets < cfg.vocab_size)

    def chunk_nll(x_c, tgt_c, m_c):
        logits = jnp.einsum("bsd,dv->bsv", x_c,
                            params["out"]).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt_c[..., None], axis=-1)[..., 0]
        return jnp.sum(jnp.where(m_c, nll, 0.0))

    c = cfg.loss_chunk
    if c and s_len % c == 0 and s_len > c:
        n_chunks = s_len // c
        xc = hidden.reshape(b, n_chunks, c, -1).transpose(1, 0, 2, 3)
        tc = targets.reshape(b, n_chunks, c).transpose(1, 0, 2)
        mc = mask_all.reshape(b, n_chunks, c).transpose(1, 0, 2)

        def step(acc, inp):
            return acc + jax.checkpoint(chunk_nll)(*inp), None

        total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32),
                                (xc, tc, mc))
    else:
        total = chunk_nll(hidden, targets, mask_all)
    ce = total / jnp.maximum(mask_all.sum(), 1)
    return ce + cfg.aux_loss_weight * lb / cfg.num_layers


# -------------------------------------------------------------- decode ------

def init_kv_cache(cfg: LMConfig, batch: int, max_len: int,
                  dtype=None) -> dict:
    dtype = dtype or cfg.dtype
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


def decode_step(cfg: LMConfig, params: dict, cache: dict, token: Array,
                constrain=lambda x: x) -> tuple[Array, dict]:
    """One decoding step. token: (B,) int32 -> (logits (B, Vp), new cache)."""
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = constrain(x)
    cache_len = cache["len"]

    def layer_step(x, lp_kv):
        lp, k_c, v_c = lp_kv
        h = nnl.rms_norm(x, lp["ln1"], cfg.norm_eps, cfg.rms_plus_one)
        a, k_new, v_new = attn.decode_step_attention(
            lp["attn"], h, k_c, v_c, cache_len, cfg.rope_theta)
        x = x + a
        h = nnl.rms_norm(x, lp["ln2"], cfg.norm_eps, cfg.rms_plus_one)
        if cfg.is_moe:
            f, _ = moelib.moe_apply(lp["ffn"], h[:, None, :], cfg.moe_top_k,
                                    cfg.moe_capacity_factor, cfg.activation)
            f = f[:, 0, :]
        else:
            f = nnl.glu_ffn_apply(lp["ffn"], h, cfg.activation)
        return constrain(x + f), (k_new, v_new)

    x, (k_all, v_all) = jax.lax.scan(
        layer_step, x, (params["layers"], cache["k"], cache["v"]),
        unroll=cfg.layer_unroll)
    x = nnl.rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.rms_plus_one)
    logits = (x @ params["out"]).astype(jnp.float32)
    new_cache = {"k": k_all, "v": v_all, "len": cache_len + 1}
    return logits, new_cache


def prefill(cfg: LMConfig, params: dict, tokens: Array, max_len: int,
            constrain=lambda x: x, chunk_constrain=None) -> tuple[Array, dict]:
    """Prefill the KV cache from a full prompt; returns last-token logits.

    Runs the training forward per layer but also emits K/V; tokens (B, S).
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = constrain(x)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def layer_step(x, lp):
        h = nnl.rms_norm(x, lp["ln1"], cfg.norm_eps, cfg.rms_plus_one)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
        from repro.nn.rope import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if s > attn.CHUNK_THRESHOLD or chunk_constrain is not None:
            o = attn.chunked_causal_attention(
                q, k, v, cfg.q_chunk, unroll=cfg.unroll_chunks,
                chunk_constrain=chunk_constrain)
        else:
            o = attn.causal_attention(q, k, v)
        a = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        x = constrain(x + a)
        h = nnl.rms_norm(x, lp["ln2"], cfg.norm_eps, cfg.rms_plus_one)
        if cfg.is_moe:
            f, _ = moelib.moe_apply(lp["ffn"], h, cfg.moe_top_k,
                                    cfg.moe_capacity_factor, cfg.activation,
                                    ep_constrain=getattr(constrain,
                                                         "ep", None))
        else:
            f = nnl.glu_ffn_apply(lp["ffn"], h, cfg.activation)
        kv = (jnp.pad(k, ((0, 0), (0, max_len - s), (0, 0), (0, 0))),
              jnp.pad(v, ((0, 0), (0, max_len - s), (0, 0), (0, 0))))
        return constrain(x + f), kv

    body = jax.checkpoint(layer_step, prevent_cse=True) if cfg.remat \
        else layer_step
    x, (k_all, v_all) = jax.lax.scan(body, x, params["layers"],
                                     unroll=cfg.layer_unroll)
    x = nnl.rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.rms_plus_one)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["out"]) \
        .astype(jnp.float32)
    cache = {"k": k_all, "v": v_all,
             "len": jnp.full((b,), s, jnp.int32)}
    return logits, cache
