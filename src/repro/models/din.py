"""DIN — Deep Interest Network (arXiv:1706.06978).

Config: embed_dim=18, user-history seq_len=100, attention MLP 80-40,
final MLP 200-80, target attention interaction.

Structure: sparse id features -> embeddings; the user's behaviour history
(item ids + category ids) is pooled by TARGET ATTENTION — a small MLP scores
each history item against the candidate ad:

    a_l = MLP([h_l, t, h_l - t, h_l * t])      (80 -> 40 -> 1)
    u   = sum_l a_l * h_l                      (no softmax, per the paper)

then concat(user emb, pooled interest, target emb, context) -> MLP -> CTR
logit.  The embedding lookup (huge tables) is the hot path; tables are
vocab-sharded over 'model' at scale.

``score_candidates`` serves the retrieval_cand shape: one user history scored
against N candidates by broadcasting the user tensors — batched einsums, not
a loop.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.nn.embedding import embedding_bag, init_table
from repro.nn.layers import init_mlp, mlp_apply

Array = jax.Array


@dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_hidden: tuple = (80, 40)
    mlp_hidden: tuple = (200, 80)
    item_vocab: int = 1_000_000
    cate_vocab: int = 10_000
    user_vocab: int = 1_000_000
    num_classes: int = 2


def init_params(key: Array, cfg: DINConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.embed_dim
    # history/target features are (item, category) pairs -> 2d wide
    pair = 2 * d
    attn_dims = [4 * pair, *cfg.attn_hidden, 1]
    mlp_in = d + pair + pair          # user + pooled interest + target
    mlp_dims = [mlp_in, *cfg.mlp_hidden, cfg.num_classes]
    return {
        "item_table": init_table(ks[0], cfg.item_vocab, d, dtype),
        "cate_table": init_table(ks[1], cfg.cate_vocab, d, dtype),
        "user_table": init_table(ks[2], cfg.user_vocab, d, dtype),
        "attn_mlp": init_mlp(ks[3], attn_dims, dtype),
        "mlp": init_mlp(ks[4], mlp_dims, dtype),
    }


def _pair_embed(params: dict, item_ids: Array, cate_ids: Array) -> Array:
    it = jnp.take(params["item_table"], item_ids, axis=0)
    ct = jnp.take(params["cate_table"], cate_ids, axis=0)
    return jnp.concatenate([it, ct], axis=-1)


def target_attention(params: dict, hist: Array, hist_mask: Array,
                     target: Array) -> Array:
    """hist (B, L, P); target (B, P) -> pooled interest (B, P)."""
    t = target[:, None, :] * jnp.ones_like(hist)
    feat = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    scores = mlp_apply(params["attn_mlp"], feat, activation="relu")[..., 0]
    scores = scores * hist_mask.astype(scores.dtype)       # (B, L)
    return jnp.einsum("bl,blp->bp", scores, hist)


def forward(params: dict, batch: dict) -> Array:
    """batch: user_id (B,), hist_items/hist_cates (B, L), hist_mask (B, L),
    target_item/target_cate (B,) -> logits (B, C)."""
    hist = _pair_embed(params, batch["hist_items"], batch["hist_cates"])
    target = _pair_embed(params, batch["target_item"], batch["target_cate"])
    user = jnp.take(params["user_table"], batch["user_id"], axis=0)
    interest = target_attention(params, hist, batch["hist_mask"], target)
    x = jnp.concatenate([user, interest, target], axis=-1)
    return mlp_apply(params["mlp"], x, activation="relu")


def ctr_loss(params: dict, batch: dict, labels: Array) -> Array:
    logits = forward(params, batch)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def score_candidates(params: dict, batch: dict, cand_items: Array,
                     cand_cates: Array) -> Array:
    """Retrieval scoring: ONE user vs N candidates (retrieval_cand shape).

    batch: single-user history (1, L); cand_*: (N,).  The history embedding
    and user embedding are computed once; the per-candidate target attention
    broadcasts over N via einsums (no loop).  Returns (N,) CTR scores.
    """
    hist = _pair_embed(params, batch["hist_items"], batch["hist_cates"])
    hist = hist[0]                                        # (L, P)
    mask = batch["hist_mask"][0]                          # (L,)
    user = jnp.take(params["user_table"], batch["user_id"], axis=0)[0]
    targets = _pair_embed(params, cand_items, cand_cates)  # (N, P)

    t = targets[:, None, :] * jnp.ones_like(hist)[None]    # (N, L, P)
    h = jnp.broadcast_to(hist[None], t.shape)
    feat = jnp.concatenate([h, t, h - t, h * t], axis=-1)
    scores = mlp_apply(params["attn_mlp"], feat, activation="relu")[..., 0]
    scores = scores * mask[None, :].astype(scores.dtype)    # (N, L)
    interest = jnp.einsum("nl,lp->np", scores, hist)
    x = jnp.concatenate([jnp.broadcast_to(user[None], (t.shape[0],
                                                       user.shape[0])),
                         interest, targets], axis=-1)
    logits = mlp_apply(params["mlp"], x, activation="relu")
    return jax.nn.softmax(logits, axis=-1)[:, 1]
