"""SO(3) representation machinery for eSCN-style equivariant convolutions.

The eSCN trick (arXiv:2302.03655, used by EquiformerV2 arXiv:2306.12059):
rotate each edge's features so the edge direction aligns with the z-axis;
in that frame the SH of the edge direction is nonzero only at m=0, so the
full Clebsch-Gordan tensor product collapses to independent per-m linear
maps (SO(2) convolutions) — O(L^3) instead of O(L^6).

This module provides real Wigner-D matrices D^l(alpha, beta, gamma) for
l <= L_MAX, evaluated per edge inside jit:

  * Wigner small-d via the explicit factorial sum (coefficients precomputed
    as numpy tables at import, evaluation = powers of cos/sin half-angle),
  * complex D = e^{-i m' alpha} d^l_{m'm}(beta) e^{-i m gamma},
  * real basis change D_real = U D U^dagger (standard real-SH unitary U).

Conventions: z-y-z Euler angles, active rotations; real SH ordering
m = -l..l within each l block; the full feature vector stacks blocks
l = 0..l_max (dim = (l_max+1)^2).
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

L_MAX_SUPPORTED = 8


def irreps_dim(l_max: int) -> int:
    return (l_max + 1) ** 2


def block_slices(l_max: int) -> list[slice]:
    out, off = [], 0
    for l in range(l_max + 1):
        out.append(slice(off, off + 2 * l + 1))
        off += 2 * l + 1
    return out


@lru_cache(maxsize=None)
def _wigner_d_tables(l: int):
    """Coefficient tables for d^l_{m'm}(beta) = sum_k c * cos^p * sin^q.

    Returns (rows, cols, cos_pow, sin_pow, coeff) flat numpy arrays.
    """
    rows, cols, cps, sps, cfs = [], [], [], [], []
    for mp in range(-l, l + 1):
        for m in range(-l, l + 1):
            pref = math.sqrt(math.factorial(l + mp) * math.factorial(l - mp)
                             * math.factorial(l + m) * math.factorial(l - m))
            k_lo = max(0, m - mp)
            k_hi = min(l + m, l - mp)
            for k in range(k_lo, k_hi + 1):
                denom = (math.factorial(l + m - k) * math.factorial(k)
                         * math.factorial(l - k - mp)
                         * math.factorial(k - m + mp))
                c = ((-1) ** (k - m + mp)) * pref / denom
                rows.append(mp + l)
                cols.append(m + l)
                cps.append(2 * l + m - mp - 2 * k)
                sps.append(2 * k + mp - m)
                cfs.append(c)
    return (np.asarray(rows, np.int32), np.asarray(cols, np.int32),
            np.asarray(cps, np.int32), np.asarray(sps, np.int32),
            np.asarray(cfs, np.float64))


@lru_cache(maxsize=None)
def _real_u_matrix(l: int) -> np.ndarray:
    """Unitary U with Y_real = U Y_complex (complex m ordered -l..l)."""
    dim = 2 * l + 1
    u = np.zeros((dim, dim), dtype=np.complex128)
    s2 = 1.0 / math.sqrt(2.0)
    for m in range(-l, l + 1):
        i = m + l
        if m < 0:
            # sign fixed so that the l=1 block in (y, z, x) ordering equals
            # the coordinate rotation matrix (validated in tests)
            u[i, m + l] = -1j * s2
            u[i, -m + l] = 1j * s2 * ((-1) ** m)
        elif m == 0:
            u[i, l] = 1.0
        else:
            u[i, -m + l] = s2
            u[i, m + l] = s2 * ((-1) ** m)
    return u


def wigner_d_real(l: int, alpha: Array, beta: Array, gamma: Array) -> Array:
    """Real Wigner-D matrices for one l; angles (...,) -> (..., 2l+1, 2l+1)."""
    rows, cols, cps, sps, cfs = _wigner_d_tables(l)
    c = jnp.cos(beta / 2.0)
    s = jnp.sin(beta / 2.0)
    # powers 0..2l gathered from a table of stacked powers
    pows_c = jnp.stack([c ** p for p in range(2 * l + 1)], axis=-1)
    pows_s = jnp.stack([s ** p for p in range(2 * l + 1)], axis=-1)
    terms = (jnp.asarray(cfs, jnp.float32)
             * jnp.take(pows_c, jnp.asarray(cps), axis=-1)
             * jnp.take(pows_s, jnp.asarray(sps), axis=-1))
    dim = 2 * l + 1
    flat = jnp.asarray(rows, jnp.int32) * dim + jnp.asarray(cols, jnp.int32)
    small_d = jax.ops.segment_sum(
        jnp.moveaxis(terms, -1, 0), flat, num_segments=dim * dim)
    small_d = jnp.moveaxis(small_d, 0, -1).reshape(beta.shape + (dim, dim))
    m_range = jnp.arange(-l, l + 1, dtype=jnp.float32)
    e_alpha = jnp.exp(-1j * m_range * alpha[..., None])      # (..., dim)
    e_gamma = jnp.exp(-1j * m_range * gamma[..., None])
    d_complex = (e_alpha[..., :, None] * small_d.astype(jnp.complex64)
                 * e_gamma[..., None, :])
    u = jnp.asarray(_real_u_matrix(l), jnp.complex64)
    d_real = jnp.einsum("ij,...jk,lk->...il", u, d_complex, u.conj())
    return jnp.real(d_real).astype(jnp.float32)


def wigner_d_real_stack(l_max: int, alpha: Array, beta: Array,
                        gamma: Array) -> list[Array]:
    """Per-l list of real Wigner-D matrices (block-diagonal factors)."""
    return [wigner_d_real(l, alpha, beta, gamma) for l in range(l_max + 1)]


def edge_rotation_angles(vec: Array) -> tuple[Array, Array, Array]:
    """Euler angles (alpha=0, beta, gamma) rotating edge direction -> z-axis.

    For unit r with polar angle theta and azimuth phi, R = Ry(-theta) Rz(-phi)
    maps r to z; as z-y-z Euler (Rz(a) Ry(b) Rz(g)): a = 0, b = -theta,
    g = -phi.
    """
    r = vec / jnp.maximum(jnp.linalg.norm(vec, axis=-1, keepdims=True), 1e-9)
    theta = jnp.arccos(jnp.clip(r[..., 2], -1.0, 1.0))
    phi = jnp.arctan2(r[..., 1], r[..., 0])
    zeros = jnp.zeros_like(theta)
    return zeros, -theta, -phi


def rotate_features(feats: Array, d_blocks: list[Array],
                    l_max: int, inverse: bool = False) -> Array:
    """Apply block-diagonal Wigner-D to stacked irreps features.

    feats: (E, dim, C); d_blocks[l]: (E, 2l+1, 2l+1).
    """
    out = []
    for l, sl in enumerate(block_slices(l_max)):
        d = d_blocks[l]
        if inverse:
            d = jnp.swapaxes(d, -1, -2)   # orthogonal: inverse = transpose
        out.append(jnp.einsum("eij,ejc->eic", d, feats[:, sl, :]))
    return jnp.concatenate(out, axis=1)
