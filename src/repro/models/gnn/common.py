"""Shared static-graph batch container + heads for the assigned GNN archs.

These archs plug into the dynamic-GNN framework as spatial modules (the
DESIGN.md arch-applicability mapping); standalone static-graph training uses
this container: one padded edge list + node features (+ 3D positions for the
molecular archs) + an optional graph-id vector for batched small graphs
(disjoint union, the `molecule` shape).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclass
class GraphBatch:
    edges: Any              # (E, 2) int32
    edge_mask: Any          # (E,) f32
    node_feat: Any          # (N, F) f32
    node_mask: Any          # (N,) f32
    positions: Any = None   # (N, 3) f32 or None
    graph_id: Any = None    # (N,) int32 for batched graphs, else None
    num_graphs: int = 1
    labels: Any = None      # (N,) or (num_graphs,) int32

    def tree_flatten(self):
        return ((self.edges, self.edge_mask, self.node_feat, self.node_mask,
                 self.positions, self.graph_id, self.labels),
                self.num_graphs)

    @classmethod
    def tree_unflatten(cls, aux, children):
        e, em, nf, nm, pos, gid, lab = children
        return cls(edges=e, edge_mask=em, node_feat=nf, node_mask=nm,
                   positions=pos, graph_id=gid, num_graphs=aux, labels=lab)


jax.tree_util.register_pytree_node(
    GraphBatch, GraphBatch.tree_flatten, GraphBatch.tree_unflatten)


def batch_molecules(n_graphs: int, nodes_per: int, edges_per: int,
                    feat_dim: int, seed: int = 0,
                    with_positions: bool = True) -> GraphBatch:
    """Disjoint union of random small graphs (the `molecule` shape)."""
    rng = np.random.default_rng(seed)
    n_total = n_graphs * nodes_per
    e_total = n_graphs * edges_per
    edges = np.zeros((e_total, 2), dtype=np.int32)
    for g in range(n_graphs):
        base = g * nodes_per
        src = rng.integers(0, nodes_per, size=(edges_per,))
        # no self-loops: zero-length edge vectors have no edge frame
        # (breaks the eSCN rotation); radius graphs never contain them.
        off = rng.integers(1, nodes_per, size=(edges_per,))
        dst = (src + off) % nodes_per
        edges[g * edges_per:(g + 1) * edges_per] = \
            np.stack([src, dst], axis=1) + base
    feat = rng.normal(size=(n_total, feat_dim)).astype(np.float32)
    pos = rng.uniform(0, 5, size=(n_total, 3)).astype(np.float32) \
        if with_positions else None
    gid = np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per)
    labels = rng.integers(0, 2, size=(n_graphs,)).astype(np.int32)
    return GraphBatch(edges=jnp.asarray(edges),
                      edge_mask=jnp.ones((e_total,), jnp.float32),
                      node_feat=jnp.asarray(feat),
                      node_mask=jnp.ones((n_total,), jnp.float32),
                      positions=jnp.asarray(pos) if pos is not None else None,
                      graph_id=jnp.asarray(gid), num_graphs=n_graphs,
                      labels=jnp.asarray(labels))


def graph_readout(x: Array, graph_id: Array, num_graphs: int,
                  node_mask: Array) -> Array:
    """Masked mean pooling per graph: (N, F) -> (G, F)."""
    xm = x * node_mask[:, None].astype(x.dtype)
    sums = jax.ops.segment_sum(xm, graph_id, num_segments=num_graphs)
    cnt = jax.ops.segment_sum(node_mask, graph_id, num_segments=num_graphs)
    return sums / jnp.maximum(cnt, 1.0)[:, None].astype(x.dtype)


def node_ce_loss(logits: Array, labels: Array, mask: Array) -> Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
