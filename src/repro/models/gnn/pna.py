"""PNA — Principal Neighbourhood Aggregation (arXiv:2004.05718).

Config: 4 layers, d_hidden=75, aggregators {mean, max, min, std} x scalers
{identity, amplification, attenuation} -> 12 aggregate views concatenated,
then a linear post-transform, residual connection.

Scalers use log-degree: S_amp = log(d+1)/delta, S_att = delta/log(d+1), with
delta the mean log-degree of the training graph (computed from the batch).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph import segment
from repro.models.gnn.common import GraphBatch, graph_readout
from repro.nn.layers import init_dense

Array = jax.Array

N_AGG = 4
N_SCALE = 3


def init_params(key: Array, d_in: int, d_hidden: int, n_layers: int,
                num_classes: int, dtype=jnp.float32) -> dict:
    key, k_in, k_out = jax.random.split(key, 3)
    layers = []
    for _ in range(n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        layers.append({
            # pre-transform on (h_i || h_j), post-transform on 12 views
            "pre": init_dense(k1, 2 * d_hidden, d_hidden, dtype),
            "post": init_dense(k2, N_AGG * N_SCALE * d_hidden, d_hidden,
                               dtype),
            "b": jnp.zeros((d_hidden,), dtype),
        })
    return {
        "embed": init_dense(k_in, d_in, d_hidden, dtype),
        "layers": layers,
        "out": init_dense(k_out, d_hidden, num_classes, dtype),
    }


def forward(params: dict, batch: GraphBatch) -> Array:
    edges, emask = batch.edges, batch.edge_mask
    n = batch.node_feat.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    deg = segment.in_degree(edges, n, emask)
    log_deg = jnp.log(deg + 1.0)
    delta = jnp.maximum(jnp.sum(log_deg * batch.node_mask)
                        / jnp.maximum(batch.node_mask.sum(), 1.0), 1e-3)
    s_amp = (log_deg / delta)[:, None]
    s_att = (delta / jnp.maximum(log_deg, 1e-3))[:, None]

    h = batch.node_feat @ params["embed"]

    def layer(lp, h):
        h_src = jnp.take(h, src, axis=0)
        h_dst = jnp.take(h, dst, axis=0)
        msg = jax.nn.relu(jnp.concatenate([h_dst, h_src], -1) @ lp["pre"])
        aggs = [
            segment.scatter_mean(msg, dst, n, emask),
            segment.scatter_max(msg, dst, n, emask),
            segment.scatter_min(msg, dst, n, emask),
            segment.scatter_std(msg, dst, n, emask),
        ]
        views = []
        for a in aggs:
            views.extend([a, a * s_amp.astype(a.dtype),
                          a * s_att.astype(a.dtype)])
        return h + jax.nn.relu(jnp.concatenate(views, -1) @ lp["post"]
                               + lp["b"])

    layer = jax.checkpoint(layer, prevent_cse=True)
    for lp in params["layers"]:
        h = layer(lp, h)
    return h


def logits(params: dict, batch: GraphBatch) -> Array:
    h = forward(params, batch)
    if batch.graph_id is not None:
        h = graph_readout(h, batch.graph_id, batch.num_graphs,
                          batch.node_mask)
    return h @ params["out"]
