"""SchNet (arXiv:1706.08566): continuous-filter convolutions over 3D
positions.  Config: 3 interaction blocks, d_hidden=64, 300 RBF centers,
cutoff 10 A.

    interaction:  x_j -> W1 x_j ;  filter = MLP(rbf(d_ij)) (ssp act)
                  m_i = sum_j (W1 x_j) * filter(d_ij)
                  x_i += W3 ssp(W2 m_i)

ssp = shifted softplus.  Edge list = radius graph (precomputed on host /
supplied by the shape); distances computed on device from positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphBatch, graph_readout
from repro.nn.layers import init_dense

Array = jax.Array


def ssp(x: Array) -> Array:
    """Shifted softplus: log(0.5 e^x + 0.5)."""
    return jax.nn.softplus(x) - jnp.log(2.0)


def rbf_expand(dist: Array, n_rbf: int, cutoff: float) -> Array:
    """Gaussian radial basis on [0, cutoff]: (E,) -> (E, n_rbf)."""
    centers = jnp.linspace(0.0, cutoff, n_rbf, dtype=dist.dtype)
    gamma = 1.0 / ((cutoff / n_rbf) ** 2)
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def init_params(key: Array, d_in: int, d_hidden: int, n_interactions: int,
                n_rbf: int, num_classes: int, dtype=jnp.float32) -> dict:
    key, k_in, k_o1, k_o2 = jax.random.split(key, 4)
    blocks = []
    for _ in range(n_interactions):
        key, *ks = jax.random.split(key, 6)
        blocks.append({
            "w1": init_dense(ks[0], d_hidden, d_hidden, dtype),
            "filt1": init_dense(ks[1], n_rbf, d_hidden, dtype),
            "filt1_b": jnp.zeros((d_hidden,), dtype),
            "filt2": init_dense(ks[2], d_hidden, d_hidden, dtype),
            "filt2_b": jnp.zeros((d_hidden,), dtype),
            "w2": init_dense(ks[3], d_hidden, d_hidden, dtype),
            "w2_b": jnp.zeros((d_hidden,), dtype),
            "w3": init_dense(ks[4], d_hidden, d_hidden, dtype),
            "w3_b": jnp.zeros((d_hidden,), dtype),
        })
    return {
        "embed": init_dense(k_in, d_in, d_hidden, dtype),
        "blocks": blocks,
        "out1": init_dense(k_o1, d_hidden, d_hidden // 2, dtype),
        "out2": init_dense(k_o2, d_hidden // 2, num_classes, dtype),
    }


def forward(params: dict, batch: GraphBatch, cutoff: float = 10.0) -> Array:
    edges, emask = batch.edges, batch.edge_mask
    n = batch.node_feat.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    pos = batch.positions
    diff = jnp.take(pos, src, axis=0) - jnp.take(pos, dst, axis=0)
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    n_rbf = params["blocks"][0]["filt1"].shape[0]
    rbf = rbf_expand(dist, n_rbf, cutoff)
    # smooth cosine cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cutoff, 0, 1)) + 1.0)
    w_edge = (env * emask)[:, None]

    x = batch.node_feat @ params["embed"]

    def block(bp, x):
        filt = ssp(rbf @ bp["filt1"] + bp["filt1_b"])
        filt = ssp(filt @ bp["filt2"] + bp["filt2_b"]) * w_edge
        msgs = jnp.take(x @ bp["w1"], src, axis=0) * filt
        m = jax.ops.segment_sum(msgs, dst, num_segments=n)
        return x + (ssp(m @ bp["w2"] + bp["w2_b"]) @ bp["w3"] + bp["w3_b"])

    block = jax.checkpoint(block, prevent_cse=True)
    for bp in params["blocks"]:
        x = block(bp, x)
    return x


def logits(params: dict, batch: GraphBatch, cutoff: float = 10.0) -> Array:
    h = forward(params, batch, cutoff)
    h = ssp(h @ params["out1"])
    if batch.graph_id is not None:
        h = graph_readout(h, batch.graph_id, batch.num_graphs,
                          batch.node_mask)
    return h @ params["out2"]
