"""EquiformerV2-style equivariant graph attention via eSCN SO(2) convolutions
(arXiv:2306.12059 / eSCN arXiv:2302.03655).

Config: 12 layers, C=128 channels, l_max=6, m_max=2, 8 heads.

Per layer:
  1. equivariant norm (per-l RMS over the (2l+1)-vector, per-channel scale),
  2. per edge: rotate (src || dst) irreps into the edge frame (Wigner-D from
     ``so3``), run SO(2) convolutions — per-m linear maps over (l, channel);
     the m=0 block additionally sees the radial basis of the edge length,
  3. attention: per-head logits from invariant (l=0) features + rbf,
     segment-softmax over destinations,
  4. rotate messages back, aggregate, per-l output projection, residual,
  5. equivariant FFN: per-l channel mixes, l=0 SiLU, l>0 gated by invariant
     sigmoid gates, residual.

Simplifications vs the released model (documented in DESIGN.md):
LayerNorm variant is RMS-style; attention logits come from input invariants
rather than the m=0 message content; no S2-grid activation resampling.
Equivariance is exact and tested (rotation invariance of l=0 outputs).

Memory: the per-edge message tensor is (E, (l_max+1)^2, C); for large graphs
``edge_chunk`` streams edges through a ``lax.map`` accumulation so the live
working set is (chunk, dim, C).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gnn import so3
from repro.models.gnn.common import GraphBatch, graph_readout
from repro.models.gnn.schnet import rbf_expand
from repro.nn.layers import init_dense

Array = jax.Array


def _ls_with_m(l_max: int, m: int) -> list[int]:
    return list(range(m, l_max + 1))


def init_params(key: Array, d_in: int, channels: int, n_layers: int,
                l_max: int, m_max: int, n_heads: int, n_rbf: int,
                num_classes: int, dtype=jnp.float32) -> dict:
    c = channels
    key, k_e, k_o1, k_o2 = jax.random.split(key, 4)
    layers = []
    for _ in range(n_layers):
        key, *ks = jax.random.split(key, 10)
        so2 = {}
        # m = 0: (l_max+1) l's, input 2C per l + rbf, output C per l
        d0_in = (l_max + 1) * 2 * c + n_rbf
        d0_out = (l_max + 1) * c
        so2["w0"] = init_dense(ks[0], d0_in, d0_out, dtype)
        for m in range(1, m_max + 1):
            n_l = l_max + 1 - m
            so2[f"w{m}_r"] = init_dense(jax.random.fold_in(ks[1], m),
                                        n_l * 2 * c, n_l * c, dtype)
            so2[f"w{m}_i"] = init_dense(jax.random.fold_in(ks[2], m),
                                        n_l * 2 * c, n_l * c, dtype)
        layers.append({
            "norm_scale": jnp.ones((l_max + 1, c), dtype),
            "so2": so2,
            "att_w1": init_dense(ks[3], 2 * c + n_rbf, c, dtype),
            "att_w2": init_dense(ks[4], c, n_heads, dtype),
            "proj": (jax.random.normal(ks[5], (l_max + 1, c, c),
                                       jnp.float32) / jnp.sqrt(c)
                     ).astype(dtype),
            "ffn_norm_scale": jnp.ones((l_max + 1, c), dtype),
            "ffn_in": (jax.random.normal(ks[6], (l_max + 1, c, 2 * c),
                                         jnp.float32) / jnp.sqrt(c)
                       ).astype(dtype),
            "ffn_gate": init_dense(ks[7], c, 2 * c, dtype),
            "ffn_out": (jax.random.normal(ks[8], (l_max + 1, 2 * c, c),
                                          jnp.float32) / jnp.sqrt(2 * c)
                        ).astype(dtype),
        })
    return {
        "embed": init_dense(k_e, d_in, c, dtype),
        "layers": layers,
        "out1": init_dense(k_o1, c, c, dtype),
        "out2": init_dense(k_o2, c, num_classes, dtype),
    }


def _equiv_norm(x: Array, scale: Array, l_max: int,
                eps: float = 1e-6) -> Array:
    """Per-l RMS norm over the (2l+1) vector dims and channels."""
    outs = []
    for l, sl in enumerate(so3.block_slices(l_max)):
        blk = x[:, sl, :]
        rms = jnp.sqrt(jnp.mean(jnp.sum(blk * blk, axis=1), axis=-1,
                                keepdims=True) + eps)
        outs.append(blk / rms[:, None, :] * scale[l][None, None, :])
    return jnp.concatenate(outs, axis=1)


def _so2_conv(so2: dict, feats: Array, rbf: Array, l_max: int,
              m_max: int, channels: int) -> Array:
    """SO(2) convolution in the edge-aligned frame.

    feats: (E, dim, 2C) — concatenated rotated (src, dst) features.
    Returns messages (E, dim, C); orders |m| > m_max are zero (truncation).
    """
    e = feats.shape[0]
    c = channels
    sls = so3.block_slices(l_max)

    # m = 0 components of each l live at offset l within the block.
    x0 = jnp.stack([feats[:, sls[l].start + l, :]
                    for l in range(l_max + 1)], axis=1)   # (E, L+1, 2C)
    x0 = jnp.concatenate([x0.reshape(e, -1), rbf.astype(feats.dtype)],
                         axis=-1)
    y0 = (x0 @ so2["w0"]).reshape(e, l_max + 1, c)

    y_pm: dict[int, tuple] = {}
    for m in range(1, m_max + 1):
        ls = _ls_with_m(l_max, m)
        xp = jnp.stack([feats[:, sls[l].start + l + m, :] for l in ls],
                       axis=1).reshape(e, -1)     # +m components (E, nl*2C)
        xm = jnp.stack([feats[:, sls[l].start + l - m, :] for l in ls],
                       axis=1).reshape(e, -1)     # -m components
        wr, wi = so2[f"w{m}_r"], so2[f"w{m}_i"]
        y_pm[m] = ((xp @ wr - xm @ wi).reshape(e, len(ls), c),
                   (xp @ wi + xm @ wr).reshape(e, len(ls), c))

    # Assemble each l block by pure concatenation along the m axis
    # (m = -l..l): scatter-free — the .at[].set chain this replaces forced
    # XLA to hold a dozen full-size (E, dim, C) buffers live at once.
    blocks = []
    for l in range(l_max + 1):
        cols = []
        if l > m_max:
            cols.append(jnp.zeros((e, l - m_max, c), feats.dtype))
        for m in range(min(l, m_max), 0, -1):        # m = -min(l,mmax)..-1
            cols.append(y_pm[m][1][:, l - m, None, :])
        cols.append(y0[:, l, None, :])               # m = 0
        for m in range(1, min(l, m_max) + 1):        # m = +1..+min(l,mmax)
            cols.append(y_pm[m][0][:, l - m, None, :])
        if l > m_max:
            cols.append(jnp.zeros((e, l - m_max, c), feats.dtype))
        blocks.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(blocks, axis=1)


def forward(params: dict, batch: GraphBatch, *, l_max: int = 6,
            m_max: int = 2, n_heads: int = 8, n_rbf: int = 16,
            cutoff: float = 10.0,
            edge_chunk: int | None = None) -> Array:  # noqa: ARG001
    """Returns invariant (l=0) node features (N, C)."""
    edges, emask = batch.edges, batch.edge_mask
    n = batch.node_feat.shape[0]
    c = params["embed"].shape[1]
    dim = so3.irreps_dim(l_max)
    src, dst = edges[:, 0], edges[:, 1]

    vec = jnp.take(batch.positions, src, axis=0) \
        - jnp.take(batch.positions, dst, axis=0)
    dist = jnp.sqrt(jnp.sum(vec * vec, axis=-1) + 1e-12)
    # Degenerate (zero-length) edges have no edge frame — mask them out.
    emask = emask * (dist > 1e-6).astype(emask.dtype)
    rbf = rbf_expand(dist, n_rbf, cutoff) * emask[:, None]
    al, be, ga = so3.edge_rotation_angles(vec)
    d_blocks = so3.wigner_d_real_stack(l_max, al, be, ga)

    # initial features: invariant l=0 channels from input node features
    x = jnp.zeros((n, dim, c), batch.node_feat.dtype)
    x = x.at[:, 0, :].set(batch.node_feat @ params["embed"])

    heads = n_heads
    ch = c // heads

    def layer_body(lp, x):
        xn = _equiv_norm(x, lp["norm_scale"], l_max)
        # attention logits from invariant inputs + rbf (cheap tensors only)
        inv = jnp.concatenate([jnp.take(xn[:, 0, :], dst, axis=0),
                               jnp.take(xn[:, 0, :], src, axis=0),
                               rbf.astype(x.dtype)], axis=-1)
        logits = jax.nn.silu(inv @ lp["att_w1"]) @ lp["att_w2"]  # (E, H)
        from repro.graph.segment import scatter_softmax
        alpha = scatter_softmax(logits.astype(jnp.float32), dst, n, emask)

        # rotate (src, dst) into the edge frame
        f_src = so3.rotate_features(jnp.take(xn, src, axis=0), d_blocks,
                                    l_max)
        f_dst = so3.rotate_features(jnp.take(xn, dst, axis=0), d_blocks,
                                    l_max)
        feats = jnp.concatenate([f_src, f_dst], axis=-1)   # (E, dim, 2C)
        msg = _so2_conv(lp["so2"], feats, rbf, l_max, m_max, c)
        msg = so3.rotate_features(msg, d_blocks, l_max, inverse=True)
        # per-head attention weights
        w = jnp.repeat(alpha, ch, axis=-1).astype(msg.dtype)  # (E, C)
        msg = msg * w[:, None, :] * emask[:, None, None].astype(msg.dtype)
        agg = jax.ops.segment_sum(msg, dst, num_segments=n)
        # per-l output projection + residual
        upd = []
        for l, sl in enumerate(so3.block_slices(l_max)):
            upd.append(jnp.einsum("nic,cd->nid", agg[:, sl, :],
                                  lp["proj"][l]))
        x = x + jnp.concatenate(upd, axis=1)

        # FFN
        xf = _equiv_norm(x, lp["ffn_norm_scale"], l_max)
        gates = jax.nn.sigmoid(xf[:, 0, :] @ lp["ffn_gate"])   # (N, 2C)
        outs = []
        for l, sl in enumerate(so3.block_slices(l_max)):
            h = jnp.einsum("nic,cf->nif", xf[:, sl, :], lp["ffn_in"][l])
            if l == 0:
                h = jax.nn.silu(h)
            else:
                h = h * gates[:, None, :]
            outs.append(jnp.einsum("nif,fc->nic", h, lp["ffn_out"][l]))
        return x + jnp.concatenate(outs, axis=1)

    # per-layer remat: the (E, dim, C) rotated-message tensors dominate
    # memory; keep one layer's worth live.
    layer_body = jax.checkpoint(layer_body, prevent_cse=True)
    for lp in params["layers"]:
        x = layer_body(lp, x)
    return x[:, 0, :]   # invariant readout


def logits(params: dict, batch: GraphBatch, **kw) -> Array:
    h = forward(params, batch, **kw)
    h = jax.nn.silu(h @ params["out1"])
    if batch.graph_id is not None:
        h = graph_readout(h, batch.graph_id, batch.num_graphs,
                          batch.node_mask)
    return h @ params["out2"]
