"""GatedGCN (Bresson & Laurent, arXiv:1711.07553; benchmarking-gnns config:
16 layers, d_hidden=70, gated aggregation, residual, LayerNorm).

    e_ij' = A h_i + B h_j + C e_ij
    eta_ij = sigma(e_ij') / (sum_{j'} sigma(e_ij') + eps)
    h_i'  = h_i + ReLU(LN(U h_i + sum_j eta_ij * (V h_j)))
    e_ij  = e_ij + ReLU(LN(e_ij'))

(LayerNorm replaces the original BatchNorm: BN's cross-device batch statistics
are exactly the irregular communication this framework avoids; noted in
DESIGN.md.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph import segment
from repro.models.gnn.common import GraphBatch, graph_readout
from repro.nn.layers import init_dense

Array = jax.Array


def layer_norm(x: Array, w: Array, b: Array, eps: float = 1e-5) -> Array:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w + b


def init_params(key: Array, d_in: int, d_hidden: int, n_layers: int,
                num_classes: int, dtype=jnp.float32) -> dict:
    key, k_in, k_e, k_out = jax.random.split(key, 4)
    layers = []
    for _ in range(n_layers):
        key, *ks = jax.random.split(key, 6)
        layers.append({
            "A": init_dense(ks[0], d_hidden, d_hidden, dtype),
            "B": init_dense(ks[1], d_hidden, d_hidden, dtype),
            "C": init_dense(ks[2], d_hidden, d_hidden, dtype),
            "U": init_dense(ks[3], d_hidden, d_hidden, dtype),
            "V": init_dense(ks[4], d_hidden, d_hidden, dtype),
            "ln_h_w": jnp.ones((d_hidden,), dtype),
            "ln_h_b": jnp.zeros((d_hidden,), dtype),
            "ln_e_w": jnp.ones((d_hidden,), dtype),
            "ln_e_b": jnp.zeros((d_hidden,), dtype),
        })
    return {
        "embed_h": init_dense(k_in, d_in, d_hidden, dtype),
        "embed_e": jnp.zeros((1, d_hidden), dtype),  # no input edge feats
        "layers": layers,
        "out": init_dense(k_out, d_hidden, num_classes, dtype),
    }


def forward(params: dict, batch: GraphBatch, remat: bool = True) -> Array:
    """Node embeddings (N, d_hidden) -> logits via params['out'] by caller.

    ``remat``: per-layer activation checkpointing — the (E, d) edge
    intermediates dominate memory on dense graphs (ogb_products), so only
    one layer's worth stays live.
    """
    edges, emask = batch.edges, batch.edge_mask
    n = batch.node_feat.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    h = batch.node_feat @ params["embed_h"]
    e = jnp.broadcast_to(params["embed_e"], (edges.shape[0],
                                             params["embed_e"].shape[1]))

    def layer(lp, h, e):
        h_src = jnp.take(h, src, axis=0)
        h_dst = jnp.take(h, dst, axis=0)
        e_hat = h_dst @ lp["A"] + h_src @ lp["B"] + e @ lp["C"]
        gate = jax.nn.sigmoid(e_hat) * emask[:, None]
        denom = jax.ops.segment_sum(gate, dst, num_segments=n)
        denom_e = jnp.take(denom, dst, axis=0) + 1e-6
        eta = gate / denom_e
        msgs = eta * (h_src @ lp["V"])
        agg = jax.ops.segment_sum(msgs, dst, num_segments=n)
        h = h + jax.nn.relu(layer_norm(h @ lp["U"] + agg,
                                       lp["ln_h_w"], lp["ln_h_b"]))
        e = e + jax.nn.relu(layer_norm(e_hat, lp["ln_e_w"], lp["ln_e_b"]))
        return h, e

    if remat:
        layer = jax.checkpoint(layer, prevent_cse=True)
    for lp in params["layers"]:
        h, e = layer(lp, h, e)
    return h


def logits(params: dict, batch: GraphBatch) -> Array:
    h = forward(params, batch)
    if batch.graph_id is not None:
        h = graph_readout(h, batch.graph_id, batch.num_graphs,
                          batch.node_mask)
    return h @ params["out"]
