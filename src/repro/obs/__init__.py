"""repro.obs — unified tracing + metrics (docs/observability.md).

One process-global :class:`Tracer` (disabled by default: ``span()`` is
a true no-op) and one :class:`MetricsRegistry` shared by every
instrumented layer.  Module-level helpers delegate to the globals so
hot paths write ``obs.span("round.transfer")`` / ``obs.inc(...)``
without threading handles through every call signature.

>>> from repro import obs
>>> tracer = obs.configure(enabled=True)      # start tracing
>>> with obs.span("round", round=0):
...     pass
>>> obs.export_trace("trace.json")            # open in ui.perfetto.dev
"""

from __future__ import annotations

from typing import Any

from repro.obs.calibrate import (PHASES, CalibrationReport, CalibrationRow,
                                 calibration_report, phase_durations)
from repro.obs.export import (chrome_trace_events, export_trace, load_trace,
                              validate_trace)
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.trace import NULL_SPAN, Span, Stopwatch, Tracer

__all__ = [
    "Tracer", "Span", "Stopwatch", "NULL_SPAN",
    "MetricsRegistry", "REGISTRY",
    "configure", "get_tracer", "set_tracer", "enabled",
    "span", "stopwatch", "add_span", "now_s", "span_summary",
    "metrics", "inc", "gauge", "metrics_snapshot",
    "chrome_trace_events", "export_trace", "load_trace", "validate_trace",
    "PHASES", "CalibrationRow", "CalibrationReport",
    "calibration_report", "phase_durations",
]

_tracer = Tracer(enabled=False)


def configure(enabled: bool = True, capacity: int = 65536,
              fence: bool = True, phases: bool = True) -> Tracer:
    """Install (and return) a fresh global tracer."""
    global _tracer
    _tracer = Tracer(enabled=enabled, capacity=capacity, fence=fence,
                     phases=phases)
    return _tracer


def get_tracer() -> Tracer:
    return _tracer


def set_tracer(tracer: Tracer) -> Tracer:
    global _tracer
    _tracer = tracer
    return tracer


def enabled() -> bool:
    return _tracer.enabled


def span(name: str, cat: str = "phase", **attrs: Any):
    """Pure span on the global tracer (no-op when disabled)."""
    # inlined fast path: the disabled branch must not repack **attrs
    # through Tracer.span — this helper sits inside hot loops
    t = _tracer
    if not t.enabled:
        return NULL_SPAN
    return Span(t, name, cat, attrs)


def stopwatch(name: str, cat: str = "phase", **attrs: Any) -> Stopwatch:
    """Always-measuring stopwatch on the global tracer."""
    return _tracer.stopwatch(name, cat=cat, **attrs)


def add_span(name: str, start_s: float, dur_s: float, cat: str = "derived",
             **attrs: Any) -> None:
    _tracer.add_span(name, start_s, dur_s, cat=cat, **attrs)


def now_s() -> float:
    """Seconds on the span clock (always available)."""
    return _tracer.now_s()


def span_summary(spans=None) -> dict[str, dict]:
    return _tracer.summary(spans)


def metrics() -> MetricsRegistry:
    return REGISTRY


def inc(name: str, value: float = 1) -> None:
    REGISTRY.inc(name, value)


def gauge(name: str, value: float) -> None:
    REGISTRY.gauge(name, value)


def metrics_snapshot() -> dict[str, Any]:
    return REGISTRY.snapshot()
