"""Model-vs-measured calibration for the distributed round.

``dist/overlap.round_time_model`` predicts one round from four phase
times (transfer / spatial / a2a / temporal).  A traced ``streamed_mesh``
run *measures* those same phases per round (``round.transfer`` is fenced
wall time; spatial / a2a / temporal come from the comp-ref probe in
``stream/distributed.py``).  ``calibration_report`` joins the two:

* feed each round's measured phases through the model and compare the
  prediction against the measured ``round`` span (the residual tells
  you how much round time the four-phase model fails to explain —
  Python-side reconstruction, dispatch, logging);
* compare each round's phases against the cross-round median baseline
  (per-phase residuals locate *which* phase a straggler round lost
  time in — the signal ROADMAP's policy-driven elasticity needs).

A fenced trace serializes the schedule, so the prediction uses the
model's ``serial_s`` by default; pass ``schedule="pipelined"`` only for
traces captured without fencing (dispatch-timed, not execution-timed).

Works on live ``Tracer`` spans or on a trace file round-tripped through
``obs.export`` — both reduce to (name, dur, round-attr) triples.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable

from repro.dist.overlap import round_time_model

__all__ = ["PHASES", "CalibrationRow", "CalibrationReport",
           "phase_durations", "calibration_report"]

#: The four model phases, in schedule order.  Span names are
#: ``round.<phase>``; the enclosing measured round span is ``round``.
PHASES = ("transfer", "spatial", "a2a", "temporal")


@dataclass
class CalibrationRow:
    """One round's measured phases joined against the model."""
    round: int
    measured_s: dict[str, float]          # phase -> measured seconds
    measured_round_s: float               # the enclosing `round` span
    predicted_s: float                    # model on this round's phases
    residual_s: float                     # measured_round - predicted
    phase_residual_s: dict[str, float]    # phase - cross-round median

    @property
    def rel_residual(self) -> float:
        return self.residual_s / self.predicted_s if self.predicted_s else 0.0


@dataclass
class CalibrationReport:
    """Per-round predicted-vs-measured residuals + baseline medians."""
    rows: list[CalibrationRow]
    baseline_s: dict[str, float]          # median phase times
    schedule: str = "serial"
    chunks: int = 1
    pipeline_rounds: bool = False
    a2a_wire_ratio: float = 1.0
    extra: dict[str, Any] = field(default_factory=dict)

    def summary(self) -> str:
        lines = [f"calibration ({self.schedule} model, C={self.chunks}, "
                 f"pipelined={self.pipeline_rounds}): "
                 f"{len(self.rows)} rounds"]
        base = " ".join(f"{p}={self.baseline_s.get(p, 0.0) * 1e3:.2f}ms"
                        for p in PHASES)
        lines.append(f"  baseline medians: {base}")
        for row in self.rows:
            lines.append(
                f"  round {row.round}: measured={row.measured_round_s * 1e3:.2f}ms "
                f"predicted={row.predicted_s * 1e3:.2f}ms "
                f"residual={row.residual_s * 1e3:+.2f}ms "
                f"({row.rel_residual * 100:+.1f}%)")
        return "\n".join(lines)


def _median(vals: list[float]) -> float:
    if not vals:
        return 0.0
    vals = sorted(vals)
    n = len(vals)
    mid = n // 2
    return vals[mid] if n % 2 else 0.5 * (vals[mid - 1] + vals[mid])


def _as_triples(source: Iterable[Any]) -> list[tuple[str, float, int | None]]:
    """Spans or chrome-trace event dicts -> (name, dur_s, round)."""
    out = []
    for item in source:
        if isinstance(item, dict):
            if item.get("ph") != "X":
                continue
            name = item.get("name", "")
            dur_s = float(item.get("dur", 0.0)) * 1e-6
            rnd = item.get("args", {}).get("round")
        else:
            name = item.name
            dur_s = item.dur_s
            rnd = item.attrs.get("round")
        out.append((name, dur_s, rnd))
    return out


def phase_durations(source: Iterable[Any]) -> dict[int, dict[str, float]]:
    """Group phase + round spans by round index:
    ``{round: {"transfer": s, ..., "round": s}}``."""
    per_round: dict[int, dict[str, float]] = {}
    for name, dur_s, rnd in _as_triples(source):
        if rnd is None:
            continue
        if name == "round":
            per_round.setdefault(int(rnd), {})["round"] = dur_s
        elif name.startswith("round."):
            phase = name.split(".", 1)[1]
            if phase in PHASES:
                per_round.setdefault(int(rnd), {})[phase] = dur_s
    return per_round


def calibration_report(source: Iterable[Any], chunks: int = 1,
                       pipeline_rounds: bool = False,
                       a2a_wire_ratio: float = 1.0,
                       schedule: str = "serial") -> CalibrationReport:
    """Join measured round spans against ``round_time_model``.

    ``source`` — tracer spans (``Tracer.spans()``) or loaded trace
    events (``obs.load_trace(path)[0]``).  Rounds missing any of the
    four phases are skipped (counted in ``report.extra["skipped"]``).
    """
    if schedule not in ("serial", "pipelined"):
        raise ValueError(f"schedule must be serial|pipelined, "
                         f"got {schedule!r}")
    per_round = phase_durations(source)
    complete = {r: ph for r, ph in per_round.items()
                if all(p in ph for p in PHASES) and "round" in ph}
    baseline = {p: _median([ph[p] for ph in complete.values()])
                for p in PHASES}
    rows: list[CalibrationRow] = []
    for r in sorted(complete):
        ph = complete[r]
        model = round_time_model(
            ph["transfer"], ph["spatial"], ph["a2a"], ph["temporal"],
            chunks=chunks, pipeline_rounds=pipeline_rounds,
            a2a_wire_ratio=a2a_wire_ratio)
        predicted = model["serial_s"] if schedule == "serial" \
            else model["pipelined_s"]
        measured = ph["round"]
        rows.append(CalibrationRow(
            round=r,
            measured_s={p: ph[p] for p in PHASES},
            measured_round_s=measured,
            predicted_s=predicted,
            residual_s=measured - predicted,
            phase_residual_s={p: ph[p] - baseline[p] for p in PHASES}))
    return CalibrationReport(
        rows=rows, baseline_s=baseline, schedule=schedule, chunks=chunks,
        pipeline_rounds=pipeline_rounds, a2a_wire_ratio=a2a_wire_ratio,
        extra={"skipped": len(per_round) - len(complete)})
