"""Counter/gauge registry: one dotted namespace for the repo's counters.

Absorbs the ad-hoc tallies that previously lived on per-subsystem report
objects (encoder resyncs, dropped sample lanes, compressed payload
bytes, guard trips, straggler flags) behind a single thread-safe
registry.  The legacy report fields stay populated — the registry is the
*shared* view, keyed by a stable dotted namespace:

======================  ================================================
``stream.resyncs``       encoder stats-pad overflows -> full-frame resync
``stream.rounds``        distributed rounds consumed
``stream.payload_bytes`` wire bytes moved by the distributed stream
``prefetch.items``       items staged by prefetch worker threads
``sample.*``             fanout-sampler drops / staged bytes / rounds
``serve.*``              ingest events, advances, queries, tokens
``sanitize.guard_trips`` ThreadAffinityGuard rejections
``elastic.*``            rescale events / payload bytes
``straggler.flags``      StepTimer EWMA outlier flags
======================  ================================================

Counters are monotonic within a process; use ``snapshot()`` +
``delta(before)`` to scope them to one run (that is how
``RunResult.metrics`` / ``ServeResult.metrics`` are produced).
"""

from __future__ import annotations

import threading
from typing import Any

__all__ = ["MetricsRegistry", "REGISTRY"]


class MetricsRegistry:
    """Thread-safe counters (monotonic adds) + gauges (last value)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}

    # ------------------------------------------------------------ write

    def inc(self, name: str, value: float = 1) -> None:
        """Add ``value`` to counter ``name`` (created at 0)."""
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def gauge(self, name: str, value: float) -> None:
        """Set gauge ``name`` to its latest ``value``."""
        with self._lock:
            self._gauges[name] = value

    # ------------------------------------------------------------- read

    def get(self, name: str, default: float = 0) -> float:
        with self._lock:
            if name in self._counters:
                return self._counters[name]
            return self._gauges.get(name, default)

    def snapshot(self) -> dict[str, Any]:
        """Deep copy: ``{"counters": {...}, "gauges": {...}}``."""
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges)}

    def delta(self, before: dict[str, Any]) -> dict[str, Any]:
        """Counters since a ``snapshot()`` (zero-delta keys omitted);
        gauges are last-value, not differenced."""
        now = self.snapshot()
        base = before.get("counters", {})
        counters = {k: v - base.get(k, 0)
                    for k, v in now["counters"].items()
                    if v != base.get(k, 0)}
        return {"counters": counters, "gauges": now["gauges"]}

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()


#: Process-global registry — the one namespace every subsystem feeds.
REGISTRY = MetricsRegistry()
