"""Thread-safe phase tracer: nested spans on one monotonic clock.

Two primitives, with deliberately different disabled-path contracts:

* ``Tracer.span(name)`` — a *pure* span.  When the tracer is disabled it
  returns a shared null object and performs **zero clock reads**; hot
  loops can leave spans inline at no cost (the <2% overhead bound is
  asserted by ``benchmarks/obs_bench.py``).
* ``Tracer.stopwatch(name)`` — an *always-on* measurement.  It reads the
  clock whether or not tracing is enabled (its ``.seconds`` feeds the
  legacy report fields: ``SampleReport.stage_seconds``,
  ``ServeResult.ingest_seconds``, ``RescaleEvent.recompose_s``, …) and
  additionally records a span when tracing is on.  This is the migration
  target for the ad-hoc ``time.perf_counter()`` pairs that used to live
  in ``src/`` (now a dynlint violation outside ``obs/`` and ``ft/``).

Spans are stored in a bounded ring (``collections.deque(maxlen=…)``);
once full, the oldest spans are evicted and counted in
``Tracer.dropped``.  All timestamps come from ``time.perf_counter_ns``
relative to the tracer's epoch, so spans from every thread share one
clock.  Device work is asynchronous under jax — with ``fence=True``
(the default for an enabled tracer) a span exit calls
``jax.block_until_ready`` on whatever the span registered via
``Span.fence(obj)``, so device phases measure *execution*, not
dispatch.  Fencing serializes the dispatch pipeline — a traced run
measures a serial schedule (the observer effect the calibration report
accounts for by comparing against ``round_time_model``'s ``serial_s``).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Iterator

__all__ = ["Span", "Stopwatch", "Tracer", "NULL_SPAN"]


class Span:
    """One timed region.  Use as a context manager; ``fence(obj)``
    registers jax arrays to block on at exit (only honoured when the
    owning tracer fences)."""

    __slots__ = ("name", "cat", "tid", "thread_name", "start_s", "dur_s",
                 "attrs", "_fence_obj", "_tracer", "_t0_ns")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: dict[str, Any]):
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self.tid = threading.get_ident()
        self.thread_name = threading.current_thread().name
        self.start_s = 0.0
        self.dur_s = 0.0
        self._fence_obj: Any = None
        self._tracer = tracer
        self._t0_ns = 0

    def fence(self, obj: Any) -> Any:
        """Register ``obj`` (pytree of jax arrays) to block on at span
        exit; returns ``obj`` so call sites can fence inline."""
        self._fence_obj = obj
        return obj

    def __enter__(self) -> "Span":
        self._t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        tr = self._tracer
        if self._fence_obj is not None and tr.fencing:
            import jax
            jax.block_until_ready(self._fence_obj)
            self._fence_obj = None
        end_ns = time.perf_counter_ns()
        self.start_s = (self._t0_ns - tr._epoch_ns) * 1e-9
        self.dur_s = (end_ns - self._t0_ns) * 1e-9
        tr._record(self)

    # convenience for symmetric reading with Stopwatch
    @property
    def seconds(self) -> float:
        return self.dur_s


class _NullSpan:
    """Shared no-op span: the disabled-tracer fast path.  No clock
    reads, no allocation per use."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def fence(self, obj: Any) -> Any:
        return obj

    name = ""
    cat = ""
    start_s = 0.0
    dur_s = 0.0
    seconds = 0.0
    attrs: dict[str, Any] = {}


NULL_SPAN = _NullSpan()


class Stopwatch:
    """Always-times context manager.  ``.seconds`` is valid after exit
    regardless of tracer state; a span is recorded only when tracing."""

    __slots__ = ("name", "cat", "attrs", "seconds", "start_s", "_tracer",
                 "_t0_ns", "_fence_obj")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: dict[str, Any]):
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self.seconds = 0.0
        self.start_s = 0.0
        self._tracer = tracer
        self._t0_ns = 0
        self._fence_obj: Any = None

    def fence(self, obj: Any) -> Any:
        """Like ``Span.fence`` — only honoured when the tracer fences,
        so an untraced run keeps its async dispatch schedule."""
        self._fence_obj = obj
        return obj

    def __enter__(self) -> "Stopwatch":
        self._t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        tr = self._tracer
        if self._fence_obj is not None and tr.enabled and tr.fencing:
            import jax
            jax.block_until_ready(self._fence_obj)
            self._fence_obj = None
        end_ns = time.perf_counter_ns()
        self.start_s = (self._t0_ns - tr._epoch_ns) * 1e-9
        self.seconds = (end_ns - self._t0_ns) * 1e-9
        if tr.enabled:
            sp = Span(tr, self.name, self.cat, self.attrs)
            sp.start_s = self.start_s
            sp.dur_s = self.seconds
            tr._record(sp)


class Tracer:
    """Bounded-ring span recorder shared by every instrumented layer.

    ``enabled=False`` (the default) is a true no-op for ``span()``:
    one attribute read and the shared ``NULL_SPAN`` — nothing else.
    """

    def __init__(self, enabled: bool = False, capacity: int = 65536,
                 fence: bool = True, phases: bool = True):
        self.enabled = bool(enabled)
        self.fencing = bool(fence)
        # derive per-round spatial/a2a/temporal spans from the comp-ref
        # probe in the distributed trainer (see stream/distributed.py)
        self.phases = bool(phases)
        self.capacity = int(capacity)
        self.recorded = 0          # total spans ever recorded
        self._spans: deque[Span] = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._epoch_ns = time.perf_counter_ns()

    # ------------------------------------------------------------ record

    def span(self, name: str, cat: str = "phase", **attrs: Any):
        """Pure span: no-op (no clock read) when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, cat, attrs)

    def stopwatch(self, name: str, cat: str = "phase",
                  **attrs: Any) -> Stopwatch:
        """Always-measuring stopwatch (span recorded only if enabled)."""
        return Stopwatch(self, name, cat, attrs)

    def add_span(self, name: str, start_s: float, dur_s: float,
                 cat: str = "derived", tid: int | None = None,
                 **attrs: Any) -> None:
        """Inject a span with explicit timing (derived phases, replayed
        measurements).  No-op when disabled."""
        if not self.enabled:
            return
        sp = Span(self, name, cat, attrs)
        sp.start_s = float(start_s)
        sp.dur_s = float(dur_s)
        if tid is not None:
            sp.tid = tid
        self._record(sp)

    def _record(self, span: Span) -> None:
        with self._lock:
            self._spans.append(span)
            self.recorded += 1

    # ------------------------------------------------------------- query

    def now_s(self) -> float:
        """Seconds since the tracer epoch — the span clock.  Use this
        (not raw perf_counter) for latency bookkeeping outside spans."""
        return (time.perf_counter_ns() - self._epoch_ns) * 1e-9

    def spans(self) -> list[Span]:
        """Snapshot of the ring (oldest first)."""
        with self._lock:
            return list(self._spans)

    def spans_since(self, recorded_before: int) -> list[Span]:
        """Spans recorded after a ``tracer.recorded`` checkpoint (up to
        ring capacity — older ones may have been evicted)."""
        with self._lock:
            n = min(self.recorded - recorded_before, len(self._spans))
            if n <= 0:
                return []
            return list(self._spans)[-n:]

    @property
    def dropped(self) -> int:
        """Spans evicted from the ring (recorded but no longer stored)."""
        with self._lock:
            return self.recorded - len(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.recorded = 0

    def summary(self, spans: list[Span] | None = None) -> dict[str, dict]:
        """Per-name aggregate: count / total_s / mean_s / max_s."""
        out: dict[str, dict] = {}
        for sp in (self.spans() if spans is None else spans):
            agg = out.setdefault(sp.name, {"count": 0, "total_s": 0.0,
                                           "max_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += sp.dur_s
            agg["max_s"] = max(agg["max_s"], sp.dur_s)
        for agg in out.values():
            agg["mean_s"] = agg["total_s"] / agg["count"]
        return out

    def __iter__(self) -> Iterator[Span]:
        return iter(self.spans())
