"""Chrome-trace / Perfetto export for tracer spans.

Emits the Chrome Trace Event JSON format (the ``traceEvents`` array of
complete ``"ph": "X"`` events) that both ``chrome://tracing`` and
https://ui.perfetto.dev open directly.  Two file shapes:

* ``*.json``  — one object: ``{"traceEvents": [...], "displayTimeUnit":
  "ms", "repro": {metadata}}``.  Perfetto ignores the extra ``repro``
  key, which carries the metrics snapshot and export provenance.
* ``*.jsonl`` — one event per line (streaming-friendly; Perfetto accepts
  a bare JSON array, so ``load_trace`` reassembles it).

Spans nest by containment on each thread track — Perfetto stacks
duration events that lie inside each other on the same ``tid``, so the
tracer does not store parent links.  ``validate_trace`` is the schema
gate CI's trace-smoke step runs (via ``tools/check_trace.py``).
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Any, Iterable

from repro.obs.trace import Span, Tracer

__all__ = ["chrome_trace_events", "export_trace", "load_trace",
           "validate_trace"]

_REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


def chrome_trace_events(spans: Iterable[Span],
                        metrics: dict | None = None) -> list[dict]:
    """Spans -> Chrome trace events (µs timestamps, ``ph: "X"``)."""
    pid = os.getpid()
    events: list[dict] = []
    threads: dict[int, str] = {}
    for sp in spans:
        threads.setdefault(sp.tid, getattr(sp, "thread_name", "") or
                           f"thread-{sp.tid}")
        ev = {"name": sp.name, "cat": sp.cat or "phase", "ph": "X",
              "ts": sp.start_s * 1e6, "dur": sp.dur_s * 1e6,
              "pid": pid, "tid": sp.tid}
        if sp.attrs:
            ev["args"] = {k: v for k, v in sp.attrs.items()}
        events.append(ev)
    meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": "repro"}}]
    for tid, tname in sorted(threads.items()):
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": tid, "args": {"name": tname}})
    if metrics:
        for name, val in sorted(metrics.get("counters", {}).items()):
            meta.append({"name": name, "ph": "C", "pid": pid, "tid": 0,
                         "ts": 0, "args": {"value": val}})
    return meta + events


def export_trace(path: str | os.PathLike, tracer: Tracer | None = None,
                 spans: Iterable[Span] | None = None,
                 metrics: dict | None = None) -> Path:
    """Write spans as a Perfetto-loadable trace; returns the path.

    ``.jsonl`` suffix -> one event per line; anything else -> a single
    ``{"traceEvents": ...}`` object.
    """
    if spans is None:
        if tracer is None:
            from repro import obs
            tracer = obs.get_tracer()
        spans = tracer.spans()
    if metrics is None:
        from repro.obs.metrics import REGISTRY
        metrics = REGISTRY.snapshot()
    events = chrome_trace_events(spans, metrics=metrics)
    meta = {"format": "chrome-trace", "clock": "perf_counter",
            "exported_unix_s": time.time(),
            "dropped_spans": tracer.dropped if tracer is not None else 0,
            "metrics": metrics}
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    if path.suffix == ".jsonl":
        with path.open("w") as fh:
            fh.write(json.dumps({"repro_meta": meta}) + "\n")
            for ev in events:
                fh.write(json.dumps(ev) + "\n")
    else:
        with path.open("w") as fh:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms",
                       "repro": meta}, fh, indent=1)
    return path


def load_trace(path: str | os.PathLike) -> tuple[list[dict], dict]:
    """Read a trace written by ``export_trace`` -> (events, meta)."""
    path = Path(path)
    if path.suffix == ".jsonl":
        events, meta = [], {}
        with path.open() as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                if "repro_meta" in obj:
                    meta = obj["repro_meta"]
                else:
                    events.append(obj)
        return events, meta
    doc = json.loads(path.read_text())
    return doc.get("traceEvents", []), doc.get("repro", {})


def validate_trace(events: list[dict]) -> list[str]:
    """Schema check -> list of problems (empty = valid Chrome trace).

    Checks what Perfetto actually needs: required keys per event, the
    ``ph`` code, numeric non-negative timestamps, and ``dur`` on every
    complete event.
    """
    problems: list[str] = []
    if not events:
        return ["trace contains no events"]
    for i, ev in enumerate(events):
        missing = [k for k in _REQUIRED_KEYS
                   if k not in ev and not (k == "ts" and ev.get("ph") == "M")]
        if missing:
            problems.append(f"event {i}: missing keys {missing}")
            continue
        ph = ev["ph"]
        if ph not in ("X", "M", "C", "B", "E", "i"):
            problems.append(f"event {i}: unknown ph {ph!r}")
        if ph == "X":
            if "dur" not in ev:
                problems.append(f"event {i}: complete event missing dur")
            elif not (isinstance(ev["dur"], (int, float))
                      and ev["dur"] >= 0):
                problems.append(f"event {i}: bad dur {ev['dur']!r}")
            if not (isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0):
                problems.append(f"event {i}: bad ts {ev['ts']!r}")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"event {i}: args must be an object")
    return problems
