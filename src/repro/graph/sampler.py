"""Host-side layered neighbor sampler (GraphSAGE-style fanout sampling).

The ``minibatch_lg`` shape (232 965 nodes / 114.6 M edges, batch_nodes=1024,
fanout 15-10) requires a *real* sampler: uniform fanout sampling from a CSR
adjacency, producing fixed-size padded subgraph tensors that the jitted train
step consumes.  Sampling runs on host numpy (data-pipeline stage); the device
only ever sees static shapes.

Output layout per layer l (hop l from the seeds):
  * edges[l]: (batch * prod(fanouts[:l+1]), 2) int32 (src, dst) pairs indexed
    into the *local* node table,
  * node_ids: (num_sampled,) global ids of every sampled node (seeds first),
  * masks for padded lanes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray   # (N + 1,)
    indices: np.ndarray  # (nnz,)

    @property
    def num_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @staticmethod
    def from_edges(edges: np.ndarray, num_nodes: int) -> "CSRGraph":
        # CSR over incoming edges: row = dst, entries = srcs (we aggregate
        # messages into dst, so sampling expands the in-neighborhood).
        order = np.argsort(edges[:, 1], kind="stable")
        dst_sorted = edges[order, 1]
        src_sorted = edges[order, 0]
        counts = np.bincount(dst_sorted, minlength=num_nodes)
        indptr = np.zeros(num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr=indptr, indices=src_sorted.astype(np.int64))


@dataclass
class SampledBlock:
    """One hop of a layered sample, in local (renumbered) ids."""
    edges: np.ndarray       # (E_pad, 2) int32 local (src, dst)
    edge_mask: np.ndarray   # (E_pad,) float32
    edge_pos: np.ndarray | None = None  # (E_pad,) int64 CSR positions


@dataclass
class SampledSubgraph:
    node_ids: np.ndarray        # (N_pad,) int64 global ids, seeds first
    node_mask: np.ndarray       # (N_pad,) float32
    num_seeds: int
    blocks: list[SampledBlock]  # outermost hop first

    @property
    def num_nodes(self) -> int:
        return self.node_ids.shape[0]


def sample_neighbors(graph: CSRGraph, seeds: np.ndarray, fanouts: list[int],
                     rng: np.random.Generator) -> SampledSubgraph:
    """Layered uniform sampling with static padded output shapes."""
    seeds = np.asarray(seeds, dtype=np.int64)
    b = seeds.shape[0]

    # Global-id -> local-id table built incrementally; seeds occupy [0, b).
    local: dict[int, int] = {int(g): i for i, g in enumerate(seeds)}
    order: list[int] = list(map(int, seeds))

    frontier = seeds
    raw_blocks: list[np.ndarray] = []
    max_edges_per_layer: list[int] = []
    cap = b
    for f in fanouts:
        cap *= f
        max_edges_per_layer.append(cap)

    raw_pos: list[np.ndarray] = []
    for fanout in fanouts:
        srcs, dsts, poss = [], [], []
        for v in frontier:
            lo, hi = graph.indptr[v], graph.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            k = min(fanout, deg)
            picks = rng.choice(deg, size=k, replace=False) + lo
            for p, s in zip(picks, graph.indices[picks], strict=True):
                s = int(s)
                if s not in local:
                    local[s] = len(order)
                    order.append(s)
                srcs.append(local[s])
                dsts.append(local[int(v)])
                poss.append(int(p))
        edges = (np.stack([np.asarray(srcs, dtype=np.int32),
                           np.asarray(dsts, dtype=np.int32)], axis=1)
                 if srcs else np.zeros((0, 2), dtype=np.int32))
        raw_blocks.append(edges)
        raw_pos.append(np.asarray(poss, dtype=np.int64))
        frontier = np.asarray([order[i] for i in
                               np.unique(edges[:, 0])] if edges.size else [],
                              dtype=np.int64)

    # Static padded shapes: nodes padded to the worst-case closed neighborhood
    # (every sampled edge could introduce a new node).
    n_pad = b + sum(max_edges_per_layer)
    node_ids = np.zeros((n_pad,), dtype=np.int64)
    node_mask = np.zeros((n_pad,), dtype=np.float32)
    node_ids[:len(order)] = np.asarray(order, dtype=np.int64)
    node_mask[:len(order)] = 1.0

    blocks = []
    for edges, pos, cap in zip(raw_blocks, raw_pos, max_edges_per_layer,
                               strict=True):
        e_pad = np.zeros((cap, 2), dtype=np.int32)
        m = np.zeros((cap,), dtype=np.float32)
        p_pad = np.zeros((cap,), dtype=np.int64)
        e = min(edges.shape[0], cap)
        e_pad[:e] = edges[:e]
        m[:e] = 1.0
        p_pad[:e] = pos[:e]
        blocks.append(SampledBlock(edges=e_pad, edge_mask=m, edge_pos=p_pad))

    return SampledSubgraph(node_ids=node_ids, node_mask=node_mask,
                           num_seeds=b, blocks=blocks)


def flat_edges(sub: SampledSubgraph) -> tuple[np.ndarray, np.ndarray]:
    """Union of all hop blocks as one padded edge list (for flat GNN stacks)."""
    edges = np.concatenate([blk.edges for blk in sub.blocks], axis=0)
    mask = np.concatenate([blk.edge_mask for blk in sub.blocks], axis=0)
    return edges, mask
