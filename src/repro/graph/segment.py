"""Segment-reduction message-passing primitives.

JAX has no CSR/CSC sparse (BCOO only), so all graph aggregation in this
framework is expressed as edge-index gather -> segment reduction, which lowers
to TPU-friendly dynamic-gather + scatter-add HLO.  These ops ARE the SpMM layer
of the paper (the GCN convolution ``A_tilde @ X``) and are shared by every GNN
architecture in ``repro.models.gnn``.

Conventions
-----------
* ``edges``: int32 array of shape (E, 2) with columns (src, dst).
* Padding: invalid edges point at a *dump row* ``num_nodes`` (one extra row is
  allocated by callers where needed) or carry a zero in ``edge_mask`` /
  zero weight; reductions below always take an optional mask and zero the
  contribution of padded lanes, so results never depend on pad contents.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_NEG_INF = -1e30


def gather_src(x: Array, edges: Array) -> Array:
    """Features of the source endpoint of every edge: (E, F)."""
    return jnp.take(x, edges[:, 0], axis=0)


def gather_dst(x: Array, edges: Array) -> Array:
    """Features of the destination endpoint of every edge: (E, F)."""
    return jnp.take(x, edges[:, 1], axis=0)


def _masked(messages: Array, edge_mask: Array | None) -> Array:
    if edge_mask is None:
        return messages
    m = edge_mask.astype(messages.dtype)
    return messages * m.reshape(m.shape + (1,) * (messages.ndim - 1))


def scatter_sum(messages: Array, dst: Array, num_nodes: int,
                edge_mask: Array | None = None) -> Array:
    """Sum messages (E, ...) into per-node buckets (num_nodes, ...)."""
    return jax.ops.segment_sum(_masked(messages, edge_mask), dst,
                               num_segments=num_nodes)


def scatter_mean(messages: Array, dst: Array, num_nodes: int,
                 edge_mask: Array | None = None) -> Array:
    total = scatter_sum(messages, dst, num_nodes, edge_mask)
    ones = jnp.ones(messages.shape[:1], dtype=messages.dtype)
    cnt = jax.ops.segment_sum(_masked(ones, edge_mask), dst,
                              num_segments=num_nodes)
    cnt = jnp.maximum(cnt, 1.0)
    return total / cnt.reshape(cnt.shape + (1,) * (total.ndim - 1))


def scatter_max(messages: Array, dst: Array, num_nodes: int,
                edge_mask: Array | None = None) -> Array:
    if edge_mask is not None:
        m = edge_mask.reshape(edge_mask.shape + (1,) * (messages.ndim - 1))
        messages = jnp.where(m > 0, messages, _NEG_INF)
    out = jax.ops.segment_max(messages, dst, num_segments=num_nodes)
    # Nodes with no (valid) in-edges get -inf from segment_max; zero them.
    return jnp.where(out <= _NEG_INF / 2, 0.0, out)


def scatter_min(messages: Array, dst: Array, num_nodes: int,
                edge_mask: Array | None = None) -> Array:
    return -scatter_max(-messages, dst, num_nodes, edge_mask)


def scatter_std(messages: Array, dst: Array, num_nodes: int,
                edge_mask: Array | None = None, eps: float = 1e-5) -> Array:
    """Per-node population std of incoming messages (PNA aggregator)."""
    mean = scatter_mean(messages, dst, num_nodes, edge_mask)
    mean_sq = scatter_mean(messages * messages, dst, num_nodes, edge_mask)
    var = jnp.maximum(mean_sq - mean * mean, 0.0)
    return jnp.sqrt(var + eps)


def scatter_softmax(logits: Array, dst: Array, num_nodes: int,
                    edge_mask: Array | None = None) -> Array:
    """Numerically-stable per-destination softmax over edges (GAT-style)."""
    if edge_mask is not None:
        m = edge_mask.reshape(edge_mask.shape + (1,) * (logits.ndim - 1))
        logits = jnp.where(m > 0, logits, _NEG_INF)
    node_max = jax.ops.segment_max(logits, dst, num_segments=num_nodes)
    node_max = jnp.where(node_max <= _NEG_INF / 2, 0.0, node_max)
    shifted = logits - jnp.take(node_max, dst, axis=0)
    expd = jnp.exp(shifted)
    if edge_mask is not None:
        m = edge_mask.reshape(edge_mask.shape + (1,) * (expd.ndim - 1))
        expd = expd * m.astype(expd.dtype)
    denom = jax.ops.segment_sum(expd, dst, num_segments=num_nodes)
    denom = jnp.maximum(denom, 1e-16)
    return expd / jnp.take(denom, dst, axis=0)


def in_degree(edges: Array, num_nodes: int,
              edge_mask: Array | None = None) -> Array:
    ones = jnp.ones(edges.shape[:1], dtype=jnp.float32)
    if edge_mask is not None:
        ones = ones * edge_mask.astype(jnp.float32)
    return jax.ops.segment_sum(ones, edges[:, 1], num_segments=num_nodes)


def out_degree(edges: Array, num_nodes: int,
               edge_mask: Array | None = None) -> Array:
    ones = jnp.ones(edges.shape[:1], dtype=jnp.float32)
    if edge_mask is not None:
        ones = ones * edge_mask.astype(jnp.float32)
    return jax.ops.segment_sum(ones, edges[:, 0], num_segments=num_nodes)


def gcn_edge_weights(edges: Array, num_nodes: int,
                     edge_mask: Array | None = None,
                     edge_values: Array | None = None) -> Array:
    """Symmetric-normalized Laplacian edge weights (Eq. 1 of the paper).

    w(u, v) = val(u, v) / sqrt((1 + deg_u) (1 + deg_v)); the "+1" is the
    identity (self-loop) term of ``A + I``.  Self-loops themselves must be
    appended by the caller (``repro.graph.pad.add_self_loops``).
    """
    deg_in = in_degree(edges, num_nodes, edge_mask)
    deg_out = out_degree(edges, num_nodes, edge_mask)
    # Kipf-Welling uses the undirected degree; for directed snapshots we follow
    # the paper and use in/out degree on the respective endpoint.
    inv_sqrt_in = jax.lax.rsqrt(1.0 + deg_in)
    inv_sqrt_out = jax.lax.rsqrt(1.0 + deg_out)
    w = (jnp.take(inv_sqrt_out, edges[:, 0])
         * jnp.take(inv_sqrt_in, edges[:, 1]))
    if edge_values is not None:
        w = w * edge_values
    if edge_mask is not None:
        w = w * edge_mask.astype(w.dtype)
    return w


def spmm(x: Array, edges: Array, edge_weights: Array, num_nodes: int) -> Array:
    """Sparse-dense product ``A_tilde @ x`` via gather + weighted scatter-add.

    ``edge_weights`` already folds in the Laplacian normalization and the edge
    mask (padded edges carry weight zero), which keeps this inner loop free of
    extra masking work.
    """
    msgs = gather_src(x, edges) * edge_weights[:, None].astype(x.dtype)
    return jax.ops.segment_sum(msgs, edges[:, 1], num_segments=num_nodes)
