"""Static-shape padding utilities.

XLA requires static shapes; real snapshots have varying edge counts.  We pad
edge lists to a fixed ``max_edges`` and carry a mask.  Padded edges point at
node 0 but always carry weight 0 / mask 0 so they contribute nothing.
"""

from __future__ import annotations

import numpy as np


def pad_edges(edges: np.ndarray, max_edges: int,
              values: np.ndarray | None = None):
    """Pad an (E, 2) int array to (max_edges, 2); returns (edges, values, mask).

    Raises if E > max_edges: callers size max_edges from the dataset.
    """
    e = edges.shape[0]
    if e > max_edges:
        raise ValueError(f"edge count {e} exceeds max_edges {max_edges}")
    out = np.zeros((max_edges, 2), dtype=np.int32)
    out[:e] = edges
    mask = np.zeros((max_edges,), dtype=np.float32)
    mask[:e] = 1.0
    if values is None:
        values = np.ones((e,), dtype=np.float32)
    vals = np.zeros((max_edges,), dtype=np.float32)
    vals[:e] = values
    return out, vals, mask


def add_self_loops(edges: np.ndarray, num_nodes: int,
                   values: np.ndarray | None = None):
    """Append one self-loop per node (the ``A + I`` of Eq. 1)."""
    loops = np.stack([np.arange(num_nodes, dtype=np.int32)] * 2, axis=1)
    out = np.concatenate([edges.astype(np.int32), loops], axis=0)
    if values is not None:
        out_vals = np.concatenate(
            [values, np.ones((num_nodes,), dtype=values.dtype)])
        return out, out_vals
    return out, None


def round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple
