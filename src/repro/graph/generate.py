"""Synthetic graph generators.

Two generators mirror the paper's experimental setup:

* ``random_dynamic_graph`` — the weak-scaling generator of §6.3: each snapshot
  is drawn independently with ``m = N * density`` random edges.
* ``evolving_dynamic_graph`` — real DTDG datasets evolve slowly (§3.2); this
  generator makes that controllable: snapshot t+1 keeps a (1 - churn) fraction
  of snapshot t's edges and resamples the rest, so the expected topology
  overlap between consecutive snapshots is exactly ``1 - churn``.  Used to
  evaluate the graph-difference transfer technique across overlap regimes.

Both return plain numpy edge lists (list of (E_t, 2) int32 arrays): the dynamic
graph lives on the *host* (that is the point of the paper's transfer
optimization) and is shipped block-by-block to the device.
"""

from __future__ import annotations

import numpy as np


def _random_edges(rng: np.random.Generator, n: int, m: int) -> np.ndarray:
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    edges = np.stack([src, dst], axis=1)
    return np.unique(edges, axis=0).astype(np.int32)


def random_dynamic_graph(num_nodes: int, num_steps: int, density: float,
                         seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    m = int(num_nodes * density)
    return [_random_edges(rng, num_nodes, m) for _ in range(num_steps)]


def evolving_dynamic_graph(num_nodes: int, num_steps: int, density: float,
                           churn: float = 0.1, seed: int = 0
                           ) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    m = int(num_nodes * density)
    snaps = [_random_edges(rng, num_nodes, m)]
    for _ in range(1, num_steps):
        prev = snaps[-1]
        keep = rng.random(prev.shape[0]) >= churn
        kept = prev[keep]
        fresh = _random_edges(rng, num_nodes, max(m - kept.shape[0], 0))
        nxt = np.unique(np.concatenate([kept, fresh], axis=0), axis=0)
        snaps.append(nxt.astype(np.int32))
    return snaps


def random_static_graph(num_nodes: int, num_edges: int,
                        seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return _random_edges(rng, num_nodes, num_edges)


def random_positions(num_nodes: int, box: float = 10.0,
                     seed: int = 0) -> np.ndarray:
    """Synthetic 3D coordinates for molecular archs on non-molecular shapes."""
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, box, size=(num_nodes, 3)).astype(np.float32)


def random_features(num_nodes: int, dim: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1, size=(num_nodes, dim)).astype(np.float32)


def degree_features(edges: np.ndarray, num_nodes: int) -> np.ndarray:
    """(in-degree, out-degree) input features, as used by the paper (§6.1)."""
    f = np.zeros((num_nodes, 2), dtype=np.float32)
    np.add.at(f[:, 0], edges[:, 1], 1.0)
    np.add.at(f[:, 1], edges[:, 0], 1.0)
    return f
