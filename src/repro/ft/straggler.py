"""Straggler mitigation.

Synchronous SPMD training runs at the speed of the slowest chip.  Two
mechanisms, both host-side (they orchestrate, not compute):

  * ``StepTimer`` — EWMA step-time watchdog; flags a step as straggling when
    it exceeds mean + k*std.  At scale the launcher uses consecutive flags to
    trigger (a) input-pipeline rebalancing or (b) checkpoint + exclusion of
    the slow host via elastic re-mesh (repro.ft.elastic).
  * ``BackupShardSchedule`` — speculative backup execution plan for the
    paper's snapshot partitioning: because the snapshot axis is perfectly
    regular, a backup worker can mirror the k slowest workers' shards cheaply
    (shard reassignment is a cursor change, not a data-layout change).  This
    regularity is exactly the §4.2 advantage; hypergraph partitions would
    need a full re-partition.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field


class StepTimer:
    """EWMA step-time watchdog.

    Keeps exponentially weighted moving estimates of the step-time mean
    and variance with smoothing factor ``alpha = 2 / (window + 1)`` (the
    span convention, so ``window`` keeps its old meaning: roughly how
    many recent steps dominate the estimate).  A step is flagged when
    ``dt > mean + threshold_std * sqrt(var)`` once ``min_steps``
    observations have seeded the estimate; the estimate is updated
    *after* the check so an outlier cannot mask itself.  The incremental
    variance update is the standard EW form::

        diff  = dt - mean
        mean += alpha * diff
        var   = (1 - alpha) * (var + alpha * diff**2)

    ``times`` still holds the last ``window`` raw durations — the
    ``BackupShardSchedule`` planner wants the raw tail, not the
    smoothed moments.
    """

    def __init__(self, window: int = 50, threshold_std: float = 3.0,
                 min_steps: int = 10):
        self.window = window
        self.threshold_std = threshold_std
        self.min_steps = min_steps
        self.alpha = 2.0 / (window + 1)
        self.mean = 0.0
        self.var = 0.0
        self.times: deque[float] = deque(maxlen=window)
        self._t0: float | None = None
        self.flagged_steps: list[int] = []
        self.step_idx = 0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        self.observe(dt)
        return False

    def observe(self, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.step_idx += 1
        flag = False
        if self.step_idx > self.min_steps:
            std = max(self.var ** 0.5, 1e-9)
            if dt > self.mean + self.threshold_std * std:
                flag = True
                self.flagged_steps.append(self.step_idx)
                from repro import obs      # lazy: flag path only
                obs.inc("straggler.flags")
        if self.step_idx == 1:
            self.mean = dt
            self.var = 0.0
        else:
            diff = dt - self.mean
            incr = self.alpha * diff
            self.mean += incr
            self.var = (1.0 - self.alpha) * (self.var + diff * incr)
        self.times.append(dt)
        return flag

    def reset(self) -> None:
        """Forget all state — e.g. after an elastic re-mesh changes the
        expected step time."""
        self.mean = 0.0
        self.var = 0.0
        self.times.clear()
        self.flagged_steps.clear()
        self.step_idx = 0
        self._t0 = None

    @property
    def straggler_rate(self) -> float:
        return len(self.flagged_steps) / max(self.step_idx, 1)


@dataclass
class BackupShardSchedule:
    """Assign backup workers to the slowest primaries (snapshot shards)."""
    num_workers: int
    num_backups: int
    assignments: dict = field(default_factory=dict)

    def plan(self, step_times: list[float]) -> dict[int, int]:
        """worker -> backup mapping for the k slowest workers."""
        order = sorted(range(self.num_workers),
                       key=lambda w: -step_times[w])
        slowest = order[:self.num_backups]
        self.assignments = {w: self.num_workers + i
                            for i, w in enumerate(slowest)}
        return self.assignments

    def shard_for(self, worker: int, bsize_local: int) -> tuple[int, int]:
        """Snapshot-shard cursor (start, len) — identical for the backup,
        which is the point: re-assignment is O(1) metadata."""
        return worker * bsize_local, bsize_local
