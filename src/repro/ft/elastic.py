"""Elastic scaling + preemption handling.

At 1000+ nodes, pod loss and re-provisioning are routine.  The framework's
answer (exercised in tests with host devices):

  * **Checkpoint-mediated re-mesh.**  Checkpoints are mesh-agnostic
    (host-gathered leaves, repro.ckpt).  ``remesh_plan`` picks the new
    (data, model) factorization for a changed chip count; restore places
    leaves with the new shardings.  Model code never changes — all sharding
    is expressed against logical axis names (repro.dist.sharding).
  * **Snapshot-axis elasticity (paper-specific).**  Snapshot partitioning
    needs bsize % P == 0; ``dyngnn_elastic_blocks`` re-blocks the timeline
    (adjusts nb) for a new P, preserving the gradient-checkpoint semantics —
    the communication volume stays O(T*N) at any P, which is exactly the
    paper's argument for why elasticity is cheap under this scheme.
  * **Preemption.**  ``PreemptionGuard`` converts SIGTERM into a flag the
    train loop polls; on preemption it saves a final checkpoint and exits
    cleanly (restart resumes from the data cursor in ckpt extra).
"""

from __future__ import annotations

import signal
from dataclasses import dataclass


@dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.data * self.model


def remesh_plan(num_chips: int, model_parallel: int = 16,
                min_model: int = 1) -> MeshPlan:
    """Choose (data, model) for a new chip count.

    Keeps model-parallel degree if it divides the chip count; otherwise
    falls back to the largest power-of-two divisor <= requested (TP degree
    must divide head/ff dims, which are powers of two in all our configs).
    """
    m = min(model_parallel, num_chips)
    while m > min_model and num_chips % m != 0:
        m //= 2
    return MeshPlan(data=num_chips // m, model=m)


def dyngnn_elastic_blocks(num_steps: int, num_procs: int,
                          target_bsize: int) -> tuple[int, int]:
    """(nb, bsize) for a new processor count: bsize must be a multiple of P
    and divide T; prefer the largest bsize <= target (fewer blocks = less
    recompute + better GD benefit ratio (bsize-P)/bsize, §6.2)."""
    best = None
    for nb in range(1, num_steps + 1):
        if num_steps % nb:
            continue
        bsize = num_steps // nb
        if bsize % num_procs:
            continue
        if bsize <= target_bsize:
            best = (nb, bsize)
            break
    if best is None:
        # fall back to bsize == P (minimum legal block)
        nb = num_steps // num_procs
        return nb, num_procs
    return best


class PreemptionGuard:
    """SIGTERM -> graceful checkpoint-and-exit flag."""

    def __init__(self):
        self.preempted = False
        self._orig = None

    def __enter__(self):
        def handler(signum, frame):
            self.preempted = True

        self._orig = signal.signal(signal.SIGTERM, handler)
        return self

    def __exit__(self, *exc):
        signal.signal(signal.SIGTERM, self._orig)
        return False
