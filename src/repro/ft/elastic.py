"""Elastic scaling + preemption handling.

At 1000+ nodes, pod loss and re-provisioning are routine.  The framework's
answer (exercised in tests with host devices):

  * **Checkpoint-mediated re-mesh.**  Checkpoints are mesh-agnostic
    (host-gathered leaves, repro.ckpt).  ``remesh_plan`` picks the new
    (data, model) factorization for a changed chip count; restore places
    leaves with the new shardings.  Model code never changes — all sharding
    is expressed against logical axis names (repro.dist.sharding).
  * **Snapshot-axis elasticity (paper-specific).**  Snapshot partitioning
    needs bsize % P == 0; ``dyngnn_elastic_blocks`` re-blocks the timeline
    (adjusts nb) for a new P, preserving the gradient-checkpoint semantics —
    the communication volume stays O(T*N) at any P, which is exactly the
    paper's argument for why elasticity is cheap under this scheme.
  * **Preemption.**  ``PreemptionGuard`` converts SIGTERM into a flag the
    train loop polls; on preemption it saves a final checkpoint and exits
    cleanly (restart resumes from the data cursor in ckpt extra).

The live mid-run recomposition built on these pieces — changing the
snapshot-parallel width P without restarting — lives in ``repro.elastic``.
"""

from __future__ import annotations

import signal
from dataclasses import dataclass


@dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.data * self.model


def remesh_plan(num_chips: int, model_parallel: int = 16,
                min_model: int = 1) -> MeshPlan:
    """Choose (data, model) for a new chip count.

    Keeps model-parallel degree if it divides the chip count; otherwise
    falls back to the largest power-of-two divisor <= requested (TP degree
    must divide head/ff dims, which are powers of two in all our configs).
    """
    m = min(model_parallel, num_chips)
    while m > min_model and num_chips % m != 0:
        m //= 2
    return MeshPlan(data=num_chips // m, model=m)


def dyngnn_elastic_blocks(num_steps: int, num_procs: int,
                          target_bsize: int) -> tuple[int, int]:
    """(nb, bsize) for a new processor count: bsize must be a multiple of P
    and divide T; prefer the largest bsize <= target (fewer blocks = less
    recompute + better GD benefit ratio (bsize-P)/bsize, §6.2).

    Raises when no legal blocking exists (``num_steps % num_procs != 0``):
    every returned ``(nb, bsize)`` satisfies ``nb * bsize == num_steps``,
    so callers never receive a blocking that does not tile the timeline —
    pad the trace or change P instead.
    """
    if num_steps < 1 or num_procs < 1:
        raise ValueError(f"num_steps ({num_steps}) and num_procs "
                         f"({num_procs}) must be >= 1")
    if num_steps % num_procs:
        raise ValueError(
            f"timeline of {num_steps} steps cannot be tiled into blocks "
            f"divisible by {num_procs} processors (num_steps % num_procs "
            "!= 0); pad the trace or pick a P that divides it")
    best = None
    for nb in range(1, num_steps + 1):
        if num_steps % nb:
            continue
        bsize = num_steps // nb
        if bsize % num_procs:
            continue
        if bsize <= target_bsize:
            best = (nb, bsize)
            break
    if best is None:
        # fall back to bsize == P (minimum legal block; tiles exactly
        # because num_procs divides num_steps)
        nb = num_steps // num_procs
        return nb, num_procs
    return best


def _chainable(prev) -> bool:
    """A previous handler worth forwarding to: a real Python callable,
    not the SIG_DFL/SIG_IGN sentinels and not the default SIGINT handler
    (chaining that one would re-raise KeyboardInterrupt — exactly the
    hard kill the guard exists to absorb)."""
    return (callable(prev)
            and prev not in (signal.SIG_DFL, signal.SIG_IGN,
                             signal.default_int_handler))


class PreemptionGuard:
    """SIGTERM (and optionally SIGINT) -> graceful checkpoint-and-exit flag.

    Composes instead of clobbering: a previously installed handler still
    runs after the flag is set, so nested guards all observe the signal
    and wrapping launchers keep their own cleanup hooks.  ``__exit__``
    restores exactly the handlers it replaced, so nested guards unwind
    in LIFO order.
    """

    def __init__(self, catch_sigint: bool = False):
        self.preempted = False
        self._signals = (signal.SIGTERM,) + (
            (signal.SIGINT,) if catch_sigint else ())
        self._orig: dict = {}

    def __enter__(self):
        for sig in self._signals:
            prev = signal.getsignal(sig)

            def handler(signum, frame, _prev=prev):
                self.preempted = True
                if _chainable(_prev):
                    _prev(signum, frame)

            self._orig[sig] = signal.signal(sig, handler)
        return self

    def __exit__(self, *exc):
        for sig, orig in self._orig.items():
            signal.signal(sig, orig)
        self._orig.clear()
        return False
