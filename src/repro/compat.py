"""Version-compat shims over the moving parts of the jax API.

The repo targets the jax that ships in the pinned image (see
requirements.txt) but must keep importing on neighbouring versions:

* ``shard_map`` graduated from ``jax.experimental.shard_map`` to
  ``jax.shard_map`` and renamed ``check_rep`` -> ``check_vma`` along the
  way.  Everything in the repo imports the symbol from HERE and always
  passes the new-style ``check_vma`` keyword; the shim translates.
* ``jax.sharding.AxisType`` (explicit/auto axis types) does not exist on
  older jax; ``make_mesh_compat`` drops the ``axis_types`` argument when
  the installed jax cannot accept it.
"""

from __future__ import annotations

import jax

# --------------------------------------------------------------- shard_map --

try:  # jax >= 0.5-ish
    _shard_map_impl = jax.shard_map
    _NEW_API = True
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _NEW_API = False


def shard_map(f, mesh=None, in_specs=None, out_specs=None,
              check_vma: bool | None = None, **kwargs):
    """``jax.shard_map`` with the new-style signature on every jax.

    Callers always use the modern keyword names; on old jax the
    ``check_vma`` flag is forwarded as ``check_rep`` (same meaning:
    verify per-shard replication invariants).
    """
    if _NEW_API:
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **kwargs)
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


# ---------------------------------------------------------------- AxisType --

try:
    from jax.sharding import AxisType  # noqa: F401
    HAS_AXIS_TYPES = True
except ImportError:
    AxisType = None
    HAS_AXIS_TYPES = False


def auto_axis_types(n: int):
    """(AxisType.Auto,) * n on new jax, None (= omit the kwarg) on old."""
    if not HAS_AXIS_TYPES:
        return None
    return (AxisType.Auto,) * n


def make_mesh_compat(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where supported."""
    types = auto_axis_types(len(axis_names))
    if types is None:
        return jax.make_mesh(shape, axis_names)
    return jax.make_mesh(shape, axis_names, axis_types=types)
