"""Architecture registry: ``--arch <id>`` selects one of the assigned
configs; each arch carries its own input-shape set (40 cells total) plus a
reduced smoke config for CPU tests.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str              # train | prefill | decode | full_graph |
    #                        minibatch | molecule | recsys_train |
    #                        recsys_serve | retrieval
    dims: dict


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str            # lm | gnn | recsys | dyngnn
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: dict
    notes: str = ""


_REGISTRY: dict[str, ArchSpec] = {}

ARCH_MODULES = [
    "repro.configs.yi_6b",
    "repro.configs.gemma_7b",
    "repro.configs.minicpm_2b",
    "repro.configs.olmoe_1b_7b",
    "repro.configs.moonshot_v1_16b_a3b",
    "repro.configs.gatedgcn",
    "repro.configs.pna",
    "repro.configs.schnet",
    "repro.configs.equiformer_v2",
    "repro.configs.din",
    "repro.configs.paper_dyngnn",
]


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    if not _REGISTRY:
        load_all()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; have "
                       f"{sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_archs() -> dict[str, ArchSpec]:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


def load_all() -> None:
    for mod in ARCH_MODULES:
        importlib.import_module(mod)


# ---- shared shape sets ------------------------------------------------------

def lm_shapes() -> dict:
    return {
        "train_4k": ShapeSpec("train_4k", "train",
                              {"seq_len": 4096, "global_batch": 256}),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                 {"seq_len": 32768, "global_batch": 32}),
        "decode_32k": ShapeSpec("decode_32k", "decode",
                                {"seq_len": 32768, "global_batch": 128}),
        "long_500k": ShapeSpec("long_500k", "decode",
                               {"seq_len": 524288, "global_batch": 1,
                                "kv_seq_shard": True}),
    }


def gnn_shapes() -> dict:
    return {
        "full_graph_sm": ShapeSpec(
            "full_graph_sm", "full_graph",
            {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
             "num_classes": 7}),
        "minibatch_lg": ShapeSpec(
            "minibatch_lg", "minibatch",
            {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
             "fanouts": (15, 10), "d_feat": 602, "num_classes": 41}),
        "ogb_products": ShapeSpec(
            "ogb_products", "full_graph",
            {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
             "num_classes": 47}),
        "molecule": ShapeSpec(
            "molecule", "molecule",
            {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16,
             "num_classes": 2}),
    }


def recsys_shapes() -> dict:
    return {
        "train_batch": ShapeSpec("train_batch", "recsys_train",
                                 {"batch": 65536}),
        "serve_p99": ShapeSpec("serve_p99", "recsys_serve", {"batch": 512}),
        "serve_bulk": ShapeSpec("serve_bulk", "recsys_serve",
                                {"batch": 262144}),
        "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                    {"batch": 1,
                                     "n_candidates": 1_000_000}),
    }
