"""Gemma-7B [arXiv:2403.08295]: 28L, d=3072, 16H (kv=16), head_dim=256,
GeGLU ff=24576, vocab=256000, (1+w)-RMSNorm, sqrt(d) embedding scale."""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.lm import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="gemma-7b", num_layers=28, d_model=3072,
                    num_heads=16, num_kv_heads=16, head_dim=256, d_ff=24576,
                    vocab_size=256000, activation="gelu",
                    rms_plus_one=True, embed_scale=True,
                    dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(name="gemma-7b-smoke", num_layers=2, d_model=96,
                    num_heads=2, num_kv_heads=2, head_dim=48, d_ff=384,
                    vocab_size=512, activation="gelu", rms_plus_one=True,
                    embed_scale=True, dtype=jnp.float32)


register(ArchSpec(arch_id="gemma-7b", family="lm", make_config=make_config,
                  make_smoke_config=make_smoke_config, shapes=lm_shapes()))
