"""MiniCPM-2B [arXiv:2404.06395]: 40L, d=2304, 36H (kv=36), ff=5760,
vocab=122753 (padded to 122880), WSD LR schedule."""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.lm import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="minicpm-2b", num_layers=40, d_model=2304,
                    num_heads=36, num_kv_heads=36, head_dim=64, d_ff=5760,
                    vocab_size=122753, activation="silu",
                    lr_schedule="wsd", dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(name="minicpm-2b-smoke", num_layers=2, d_model=96,
                    num_heads=4, num_kv_heads=4, head_dim=24, d_ff=240,
                    vocab_size=512, activation="silu", lr_schedule="wsd",
                    dtype=jnp.float32)


register(ArchSpec(arch_id="minicpm-2b", family="lm",
                  make_config=make_config,
                  make_smoke_config=make_smoke_config, shapes=lm_shapes()))
