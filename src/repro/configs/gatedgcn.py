"""GatedGCN [arXiv:2003.00982 benchmark config]: 16L, d_hidden=70."""

from dataclasses import dataclass

from repro.configs.registry import ArchSpec, gnn_shapes, register


@dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    kind: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70


def make_config():
    return GatedGCNConfig()


def make_smoke_config():
    return GatedGCNConfig(name="gatedgcn-smoke", n_layers=3, d_hidden=16)


register(ArchSpec(arch_id="gatedgcn", family="gnn", make_config=make_config,
                  make_smoke_config=make_smoke_config, shapes=gnn_shapes()))
