"""EquiformerV2 [arXiv:2306.12059]: 12L, d_hidden=128, l_max=6, m_max=2,
8 heads, SO(2)-eSCN equivariant graph attention."""

from dataclasses import dataclass

from repro.configs.registry import ArchSpec, gnn_shapes, register


@dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    kind: str = "equiformer_v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 16
    cutoff: float = 10.0


def make_config():
    return EquiformerV2Config()


def make_smoke_config():
    return EquiformerV2Config(name="equiformer-v2-smoke", n_layers=2,
                              d_hidden=16, l_max=3, m_max=2, n_heads=4,
                              n_rbf=8)


register(ArchSpec(arch_id="equiformer-v2", family="gnn",
                  make_config=make_config,
                  make_smoke_config=make_smoke_config, shapes=gnn_shapes()))
