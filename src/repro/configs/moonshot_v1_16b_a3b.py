"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: 48L, d=2048,
16H (kv=16), MoE 64 experts top-6, expert ff=1408, vocab=163840."""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.lm import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="moonshot-v1-16b-a3b", num_layers=48, d_model=2048,
                    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1408,
                    vocab_size=163840, activation="silu", moe_experts=64,
                    moe_top_k=6, dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(name="moonshot-smoke", num_layers=2, d_model=64,
                    num_heads=2, num_kv_heads=2, head_dim=32, d_ff=96,
                    vocab_size=512, activation="silu", moe_experts=8,
                    moe_top_k=2, dtype=jnp.float32)


register(ArchSpec(arch_id="moonshot-v1-16b-a3b", family="lm",
                  make_config=make_config,
                  make_smoke_config=make_smoke_config, shapes=lm_shapes()))
