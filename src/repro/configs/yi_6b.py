"""Yi-6B [arXiv:2403.04652]: llama-arch GQA, 32L, d=4096, 32H/4KV, ff=11008,
vocab=64000."""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.lm import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="yi-6b", num_layers=32, d_model=4096, num_heads=32,
                    num_kv_heads=4, head_dim=128, d_ff=11008,
                    vocab_size=64000, activation="silu",
                    rope_theta=5_000_000.0, dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(name="yi-6b-smoke", num_layers=2, d_model=128,
                    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=344,
                    vocab_size=512, activation="silu", dtype=jnp.float32)


register(ArchSpec(arch_id="yi-6b", family="lm", make_config=make_config,
                  make_smoke_config=make_smoke_config, shapes=lm_shapes()))
