"""PNA [arXiv:2004.05718]: 4L, d_hidden=75, mean/max/min/std aggregators,
identity/amplification/attenuation scalers."""

from dataclasses import dataclass

from repro.configs.registry import ArchSpec, gnn_shapes, register


@dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    kind: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75


def make_config():
    return PNAConfig()


def make_smoke_config():
    return PNAConfig(name="pna-smoke", n_layers=2, d_hidden=12)


register(ArchSpec(arch_id="pna", family="gnn", make_config=make_config,
                  make_smoke_config=make_smoke_config, shapes=gnn_shapes()))
