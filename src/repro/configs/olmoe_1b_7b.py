"""OLMoE-1B-7B [arXiv:2409.02060]: 16L, d=2048, 16H (kv=16), MoE 64 experts
top-8, expert ff=1024, vocab=50304."""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.lm import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="olmoe-1b-7b", num_layers=16, d_model=2048,
                    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1024,
                    vocab_size=50304, activation="silu", moe_experts=64,
                    moe_top_k=8, dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(name="olmoe-smoke", num_layers=2, d_model=64,
                    num_heads=2, num_kv_heads=2, head_dim=32, d_ff=64,
                    vocab_size=512, activation="silu", moe_experts=8,
                    moe_top_k=2, dtype=jnp.float32)


register(ArchSpec(arch_id="olmoe-1b-7b", family="lm",
                  make_config=make_config,
                  make_smoke_config=make_smoke_config, shapes=lm_shapes()))
