"""The paper's own dynamic-GNN configs (TM-GCN / CD-GCN / EvolveGCN on
epinions/flickr/youtube/AMLSim-scale DTDGs) as selectable archs.

Full configs mirror Table 1 scales (vertex/timestep counts); smoke configs
run on CPU.  Shapes: one `dtdg_train` cell per dataset scale.
"""

from repro.configs.registry import ArchSpec, ShapeSpec, register
from repro.core.models import DynGNNConfig

_DATASETS = {
    # name: (N, T, smoothed edges per snapshot).  N and T are rounded from
    # Table 1 to multiples of 32 resp. 128 so the production meshes divide
    # the vertex and timestep axes evenly (noted in DESIGN.md).
    "epinions": (755_200, 512, 2_097_152),
    "flickr": (2_300_000, 128, 7_340_032),
    "youtube": (3_200_000, 256, 3_342_336),
    "amlsim": (1_000_000, 256, 4_194_304),
    "weak_scale": (1_048_576, 256, 3_145_728),   # weak-scaling generator
}


def _shapes():
    return {
        f"dtdg_{k}": ShapeSpec(
            f"dtdg_{k}", "dtdg_train",
            {"n_nodes": n, "n_steps": t, "edges_per_snap": e})
        for k, (n, t, e) in _DATASETS.items()
    }


def _mk(model: str):
    def make_config():
        return DynGNNConfig(model=model, feat_in=2, hidden=6, out_dim=6,
                            num_layers=2, window=5, num_classes=2,
                            checkpoint_blocks=4)

    def make_smoke_config():
        return DynGNNConfig(model=model, num_nodes=64, num_steps=16,
                            feat_in=2, hidden=6, out_dim=6, num_layers=2,
                            window=3, num_classes=2, checkpoint_blocks=2)

    return make_config, make_smoke_config


for _model in ("tmgcn", "cdgcn", "evolvegcn"):
    _mc, _ms = _mk(_model)
    register(ArchSpec(arch_id=_model, family="dyngnn", make_config=_mc,
                      make_smoke_config=_ms, shapes=_shapes()))

# canonical alias for the paper's workload (the CI end-to-end job and the
# README drive `--arch paper_dyngnn`); TM-GCN is the paper's headline model
_mc, _ms = _mk("tmgcn")
register(ArchSpec(arch_id="paper_dyngnn", family="dyngnn", make_config=_mc,
                  make_smoke_config=_ms, shapes=_shapes(),
                  notes="alias of tmgcn (paper headline config)"))
