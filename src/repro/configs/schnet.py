"""SchNet [arXiv:1706.08566]: 3 interactions, d_hidden=64, 300 RBF,
cutoff 10 A."""

from dataclasses import dataclass

from repro.configs.registry import ArchSpec, gnn_shapes, register


@dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    kind: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0


def make_config():
    return SchNetConfig()


def make_smoke_config():
    return SchNetConfig(name="schnet-smoke", n_interactions=2, d_hidden=16,
                        n_rbf=20)


register(ArchSpec(arch_id="schnet", family="gnn", make_config=make_config,
                  make_smoke_config=make_smoke_config, shapes=gnn_shapes()))
