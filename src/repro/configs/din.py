"""DIN [arXiv:1706.06978]: embed_dim=18, hist seq_len=100, attn MLP 80-40,
MLP 200-80, target attention."""

from repro.configs.registry import ArchSpec, recsys_shapes, register
from repro.models.din import DINConfig


def make_config() -> DINConfig:
    return DINConfig()


def make_smoke_config() -> DINConfig:
    return DINConfig(name="din-smoke", embed_dim=8, seq_len=10,
                     attn_hidden=(16, 8), mlp_hidden=(32, 16),
                     item_vocab=1000, cate_vocab=100, user_vocab=1000)


register(ArchSpec(arch_id="din", family="recsys", make_config=make_config,
                  make_smoke_config=make_smoke_config,
                  shapes=recsys_shapes()))
