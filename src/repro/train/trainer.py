"""Training driver for the paper's dynamic-GNN workload.

Composes the full production stack:
  data pipeline (graph-diff streaming) -> snapshot-partitioned, blocked-
  checkpoint train step (shard_map) -> AdamW -> async checkpointing ->
  preemption guard -> straggler watchdog.

Single-host it runs on however many host devices exist (tests/examples);
the identical code drives a pod — only the mesh changes.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import Checkpointer
from repro.core import models as dyn_models
from repro.core import partition
from repro.data.dyngnn import DTDGPipeline
from repro.ft.elastic import PreemptionGuard
from repro.ft.straggler import StepTimer
from repro.optim import adamw


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


def make_dyngnn_train_step(cfg: dyn_models.DynGNNConfig, mesh,
                           opt_cfg: adamw.AdamWConfig, axis="data"):
    loss_fn = partition.snapshot_partition_loss(cfg, mesh, axis=axis)

    @jax.jit
    def train_step(params, opt_state, frames, edges, ew, labels):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, frames, edges, ew, labels))(params)
        params, opt_state = adamw.apply_updates(opt_cfg, params, grads,
                                                opt_state)
        return params, opt_state, loss

    return train_step


def make_single_device_train_step(cfg: dyn_models.DynGNNConfig,
                                  opt_cfg: adamw.AdamWConfig):
    from repro.core import checkpoint as ckpt_exec

    @jax.jit
    def train_step(params, opt_state, batch, labels):
        loss, grads = jax.value_and_grad(
            lambda p: ckpt_exec.blocked_node_loss(cfg, p, batch, labels)
        )(params)
        params, opt_state = adamw.apply_updates(opt_cfg, params, grads,
                                                opt_state)
        return params, opt_state, loss

    return train_step


def train_dyngnn(cfg: dyn_models.DynGNNConfig, pipeline: DTDGPipeline,
                 mesh=None, num_steps: int = 100,
                 opt_cfg: adamw.AdamWConfig | None = None,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 log_every: int = 10,
                 log_fn: Callable[[str], None] = print) -> TrainState:
    """Train; returns final state.  Resumes from ckpt_dir if one exists."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=1e-2, warmup_steps=10, total_steps=num_steps, weight_decay=0.0)
    params = dyn_models.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init_state(params)
    start_step = 0
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        s = ckpt.latest_step()
        (params, opt_state), extra = ckpt.restore(
            s, (params, opt_state))
        start_step = extra.get("train_step", s)
        log_fn(f"resumed from checkpoint step {start_step}")

    nb = cfg.checkpoint_blocks
    frames, edges, ew, labels = pipeline.blocked_arrays()
    if mesh is not None:
        step_fn = make_dyngnn_train_step(cfg, mesh, opt_cfg)
        args = (frames, edges, ew, labels)
    else:
        step_fn = make_single_device_train_step(cfg, opt_cfg)
        lab = labels.reshape((-1,) + labels.shape[2:])
        args = (pipeline.batch, lab)

    timer = StepTimer()
    losses = []
    with PreemptionGuard() as guard:
        for step in range(start_step, num_steps):
            with timer:
                params, opt_state, loss = step_fn(params, opt_state, *args)
            losses.append(float(loss))
            if step % log_every == 0:
                log_fn(f"step {step} loss {float(loss):.4f}")
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state),
                          extra={"train_step": step + 1})
            if guard.preempted:
                log_fn(f"preempted at step {step}; checkpointing and "
                       "exiting cleanly")
                if ckpt:
                    ckpt.save(step + 1, (params, opt_state),
                              extra={"train_step": step + 1},
                              blocking=True)
                break
    if ckpt:
        ckpt.wait()
    return TrainState(params=params, opt_state=opt_state,
                      step=min(num_steps, start_step + len(losses))), losses


def train_dyngnn_streamed(cfg: dyn_models.DynGNNConfig,
                          pipeline: DTDGPipeline, num_epochs: int = 1,
                          overlap: bool = True, prefetch_depth: int = 2,
                          opt_cfg: adamw.AdamWConfig | None = None,
                          mesh=None, log_every: int = 10,
                          log_fn: Callable[[str], None] = print):
    """Streaming training over the graph-diff delta stream.

    Transfers ride the ``repro.stream`` subsystem: vectorized host encode
    + prefetched ``device_put`` of delta k+1 overlapped with the jitted
    ``apply_delta`` + train step of delta k (overlap=False forces the
    synchronous reference schedule — identical losses, no overlap).

    ``mesh=None`` runs the single-device per-snapshot loop.  With a mesh,
    the trainer goes snapshot-parallel: per-shard time-slice delta streams
    (1/P transfer volume each) feed per-device edge-buffer rings, and each
    checkpoint block trains under the snapshot-partition shard_map — the
    temporal stage crosses shards through two fixed-volume all-to-alls per
    layer while the GCN stage stays communication-free.
    """
    ds = pipeline.ds
    if mesh is not None:
        from repro.stream import distributed as stream_dist
        state = stream_dist.train_distributed_streamed(
            cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
            np.asarray(ds.labels), mesh=mesh, block_size=pipeline.bsize,
            num_epochs=num_epochs, overlap=overlap,
            prefetch_depth=prefetch_depth, opt_cfg=opt_cfg,
            stats=pipeline.stream_stats, max_edges=pipeline.max_edges,
            log_every=log_every, log_fn=log_fn)
        return TrainState(params=state.params, opt_state=state.opt_state,
                          step=len(state.losses)), state.losses
    from repro.stream import train_loop as stream_train
    state = stream_train.train_streamed(
        cfg, ds.snapshots, ds.values, np.asarray(ds.frames),
        np.asarray(ds.labels), block_size=pipeline.bsize,
        num_epochs=num_epochs, overlap=overlap,
        prefetch_depth=prefetch_depth, opt_cfg=opt_cfg,
        stats=pipeline.stream_stats, max_edges=pipeline.max_edges,
        log_every=log_every, log_fn=log_fn)
    return TrainState(params=state.params, opt_state=state.opt_state,
                      step=len(state.losses)), state.losses


def evaluate_link_prediction(cfg, params, pipeline: DTDGPipeline,
                             test_snapshot: np.ndarray, theta: float = 0.1,
                             seed: int = 0) -> float:
    """Paper §6.4 link-prediction protocol: embeddings at step T classify
    edges of snapshot T+1 against random negative pairs."""
    from repro.core import checkpoint as ckpt_exec
    rng = np.random.default_rng(seed)
    z = ckpt_exec.blocked_forward(cfg, params, pipeline.batch,
                                  nb=cfg.checkpoint_blocks)
    z_last = z[-1]
    m = max(1, int(theta * test_snapshot.shape[0]))
    pos = test_snapshot[rng.choice(test_snapshot.shape[0], m,
                                   replace=False)]
    neg = rng.integers(0, pipeline.ds.num_nodes, size=(m, 2))
    pairs = jnp.asarray(np.concatenate([pos, neg], axis=0).astype(np.int32))
    labels = np.concatenate([np.ones(m), np.zeros(m)])
    logits = dyn_models.link_logits(params, z_last, pairs)
    pred = np.asarray(jnp.argmax(logits, axis=-1))
    return float((pred == labels).mean())
