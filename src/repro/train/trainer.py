"""Training driver for the paper's dynamic-GNN workload.

The declarative ``repro.run`` Engine API is now the one way to train:

    from repro.run import Engine, ExecutionPlan, RunConfig, SyntheticTrace
    result = Engine(RunConfig(model=cfg, data=..., plan=...)).fit()

This module keeps three things:

* the jitted train-step factories (``make_dyngnn_train_step`` /
  ``make_single_device_train_step``) the Engine's eager worker compiles;
* ``evaluate_link_prediction`` (paper §6.4), which ``Engine.evaluate``
  wraps;
* the legacy entrypoints ``train_dyngnn`` / ``train_dyngnn_streamed`` as
  DEPRECATED shims: each constructs a ``RunConfig``, warns, and
  delegates to the Engine.  See README "Migrating to repro.run".
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import models as dyn_models
from repro.core import partition
from repro.data.dyngnn import DTDGPipeline
from repro.optim import adamw


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


def make_dyngnn_train_step(cfg: dyn_models.DynGNNConfig, mesh,
                           opt_cfg: adamw.AdamWConfig, axis="data",
                           a2a_chunks: int = 1):
    """Jitted eager train step under the snapshot-partition shard_map.

    ``a2a_chunks`` chunks the per-layer redistributions into that many
    feature-sliced all-to-alls (overlap schedule; math-identical).
    """
    loss_fn = partition.snapshot_partition_loss(cfg, mesh, axis=axis,
                                                a2a_chunks=a2a_chunks)

    @jax.jit
    def train_step(params, opt_state, frames, edges, ew, labels):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, frames, edges, ew, labels))(params)
        params, opt_state = adamw.apply_updates(opt_cfg, params, grads,
                                                opt_state)
        return params, opt_state, loss

    return train_step


def make_single_device_train_step(cfg: dyn_models.DynGNNConfig,
                                  opt_cfg: adamw.AdamWConfig):
    from repro.core import checkpoint as ckpt_exec

    @jax.jit
    def train_step(params, opt_state, batch, labels):
        loss, grads = jax.value_and_grad(
            lambda p: ckpt_exec.blocked_node_loss(cfg, p, batch, labels)
        )(params)
        params, opt_state = adamw.apply_updates(opt_cfg, params, grads,
                                                opt_state)
        return params, opt_state, loss

    return train_step


# ------------------------------------------------- deprecated shims --------

def _warn_deprecated(name: str) -> None:
    warnings.warn(
        f"{name}() is deprecated: build a repro.run.RunConfig and call "
        "Engine.fit() instead (see README 'Migrating to repro.run')",
        DeprecationWarning, stacklevel=3)


def train_dyngnn(cfg: dyn_models.DynGNNConfig, pipeline: DTDGPipeline,
                 mesh=None, num_steps: int = 100,
                 opt_cfg: adamw.AdamWConfig | None = None,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 log_every: int = 10,
                 log_fn: Callable[[str], None] = print
                 ) -> tuple[TrainState, list[float]]:
    """DEPRECATED eager entrypoint; use ``repro.run.Engine``.

    Returns ``(final TrainState, per-step losses)`` — the annotation the
    old signature lied about.  Resumes from ``ckpt_dir`` if one exists.
    """
    _warn_deprecated("train_dyngnn")
    from repro import run as run_api
    plan = run_api.ExecutionPlan(mode="eager", mesh=mesh,
                                 num_steps=num_steps)
    rc = run_api.RunConfig(
        model=cfg,
        data=run_api.InMemoryDTDG(pipeline.ds, pipeline=pipeline),
        plan=plan, optimizer=opt_cfg,
        checkpoint=(run_api.CheckpointSpec(ckpt_dir, every=ckpt_every)
                    if ckpt_dir else None),
        log_every=log_every, log_fn=log_fn)
    res = run_api.Engine(rc).fit()
    return res.state, res.losses


def train_dyngnn_streamed(cfg: dyn_models.DynGNNConfig,
                          pipeline: DTDGPipeline, num_epochs: int = 1,
                          overlap: bool = True, prefetch_depth: int = 2,
                          opt_cfg: adamw.AdamWConfig | None = None,
                          mesh=None, log_every: int = 10,
                          log_fn: Callable[[str], None] = print
                          ) -> tuple[TrainState, list[float]]:
    """DEPRECATED streaming entrypoint; use ``repro.run.Engine``.

    Returns ``(final TrainState, per-step losses)``.  ``mesh=None`` maps
    to ``ExecutionPlan(mode="streamed")`` (single-device per-snapshot
    loop); a mesh maps to ``mode="streamed_mesh"`` (per-shard time-slice
    delta streams + snapshot-parallel shard_map).
    """
    _warn_deprecated("train_dyngnn_streamed")
    from repro import run as run_api
    plan = run_api.ExecutionPlan(
        mode="streamed" if mesh is None else "streamed_mesh", mesh=mesh,
        num_epochs=num_epochs, overlap=overlap,
        prefetch_depth=prefetch_depth)
    rc = run_api.RunConfig(
        model=cfg,
        data=run_api.InMemoryDTDG(pipeline.ds, pipeline=pipeline),
        plan=plan, optimizer=opt_cfg, log_every=log_every, log_fn=log_fn)
    res = run_api.Engine(rc).fit()
    return res.state, res.losses


def evaluate_link_prediction(cfg, params, pipeline: DTDGPipeline,
                             test_snapshot: np.ndarray, theta: float = 0.1,
                             seed: int = 0) -> float:
    """Paper §6.4 link-prediction protocol: embeddings at step T classify
    edges of snapshot T+1 against random negative pairs."""
    from repro.core import checkpoint as ckpt_exec
    rng = np.random.default_rng(seed)
    z = ckpt_exec.blocked_forward(cfg, params, pipeline.batch,
                                  nb=cfg.checkpoint_blocks)
    z_last = z[-1]
    m = max(1, int(theta * test_snapshot.shape[0]))
    pos = test_snapshot[rng.choice(test_snapshot.shape[0], m,
                                   replace=False)]
    neg = rng.integers(0, pipeline.ds.num_nodes, size=(m, 2))
    pairs = jnp.asarray(np.concatenate([pos, neg], axis=0).astype(np.int32))
    labels = np.concatenate([np.ones(m), np.zeros(m)])
    logits = dyn_models.link_logits(params, z_last, pairs)
    pred = np.asarray(jnp.argmax(logits, axis=-1))
    return float((pred == labels).mean())
