"""Online serving benchmark: query latency against resident state and
live-ingest throughput.

Three measurements per query batch size b in {1, 8, 64}:

* ``serve_warm_query_b{b}``  — p50/p95 latency of a node-scoring query
  against the warm on-device state (the serving steady state: one
  gather + classifier head, no re-encoding);
* ``serve_cold_query_b{b}``  — the same query WITHOUT resident state:
  re-encode the whole ingested history and re-run the model over every
  window, then score (what each query would cost with no warm cache).
  The warm path must be >=2x faster — asserted, not just reported;
* ``serve_ingest``           — events/s through push -> window close ->
  delta encode -> staged transfer -> donated state advance.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import record


def run(n: int = 512, windows: int = 32, events: int = 6000,
        batches: tuple[int, ...] = (1, 8, 64), iters: int = 8,
        warm_cold_factor: float = 2.0) -> None:
    from repro.core import ctdg
    from repro.core.models import DynGNNConfig
    from repro.serve import IngestSpec, ServeConfig, ServeEngine

    stream = ctdg.synthetic_ctdg(n, events, seed=0).sorted()
    cfg = DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=windows,
                       window=3, checkpoint_blocks=2)
    spec = IngestSpec(
        num_windows=windows,
        time_range=(float(stream.time.min()), float(stream.time.max())),
        block_size=max(windows // 2, 1), max_edges=4096)
    eng = ServeEngine(ServeConfig(model=cfg, ingest=spec,
                                  batch_sizes=batches),
                      keep_history=True)

    t0 = time.perf_counter()
    eng.ingest(stream)
    eng.advance_all()
    ingest_s = time.perf_counter() - t0
    record("serve_ingest", ingest_s / windows * 1e6,
           f"events_per_s={events / ingest_s:.0f};windows={windows}")

    rng = np.random.default_rng(0)
    for b in batches:
        ids = rng.integers(0, n, (b,))
        eng.query_nodes(ids)                      # compile the bucket
        warm = []
        for _ in range(iters):
            t0 = time.perf_counter()
            eng.query_nodes(ids)
            warm.append(time.perf_counter() - t0)
        eng.cold_query_nodes(ids)                 # compile the cold path
        cold = []
        for _ in range(max(iters // 2, 2)):
            t0 = time.perf_counter()
            eng.cold_query_nodes(ids)
            cold.append(time.perf_counter() - t0)
        p50 = np.percentile(warm, 50) * 1e6
        p95 = np.percentile(warm, 95) * 1e6
        cold_p50 = np.percentile(cold, 50) * 1e6
        speedup = cold_p50 / p50
        record(f"serve_warm_query_b{b}", p50,
               f"p95_us={p95:.1f};speedup_vs_cold={speedup:.1f}x")
        record(f"serve_cold_query_b{b}", cold_p50, "")
        # resident state is the point of the serving engine: a warm
        # query must beat re-encoding the history by a wide margin
        assert speedup >= warm_cold_factor, (
            f"warm query (b={b}) only {speedup:.2f}x faster than cold "
            f"re-encode; expected >={warm_cold_factor}x")

    r = eng.result()
    record("serve_session", r.p50_ms * 1e3,
           f"queries={r.queries};resyncs={r.resyncs}")


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
