"""Paper Fig. 5 (strong scaling) + Fig. 7 (weak scaling).

Two layers of evidence, since no pod is attached:
  * MEASURED: the actual shard_map train step on 1/2/4/8 host devices
    (same code path as the pod run) — wall-clock speedup + identical loss.
  * MODELED: the paper's 128-GPU setting via the analytic communication
    model (volume from repro.dist.comm_volume, bandwidth = intra-node vs
    inter-node split exactly as §6.3 describes: intra volume 1/K, inter
    (K-1)/K for K = P/8 nodes).
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import models
from repro.data.dyngnn import DTDGPipeline, synthetic_dataset
from repro.dist import comm_volume as cv
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.train import trainer

GPU_FLOPS = 14e12           # V100 fp32
PCIE_BW = 12e9              # CPU->GPU
INTRA_BW = 150e9            # NVLink-class aggregate per node
INTER_BW = 12.5e9           # 100 Gb EDR IB per node


def modeled_strong_scaling(model: str = "tmgcn", n: int = 1_000_000,
                           t: int = 256, epn: float = 4.2e6,
                           feat: int = 6, layers: int = 2) -> None:
    """Per-epoch time model on the paper's 16-node x 8-GPU system."""
    base_t = None
    for p in (1, 2, 4, 8, 16, 32, 64, 128):
        flops = 4.0 * t * (2 * epn * feat + 2 * n * feat * feat) * layers
        t_comp = flops / (p * GPU_FLOPS)
        t_xfer = (t / p) * epn * 12.0 / PCIE_BW * 2    # fwd + rerun
        vol_units = cv.snapshot_partition_volume(t, n, feat, layers, p,
                                                 model)
        vol_bytes = vol_units * 4.0
        k = max(p // 8, 1)
        if p <= 8:
            t_comm = vol_bytes / INTRA_BW
        else:
            inter = vol_bytes * (k - 1) / k
            t_comm = inter / (k * INTER_BW)
        total = t_comp + t_xfer + t_comm
        if base_t is None:
            base_t = total
        record(f"strong_scaling_model/{model}/P{p}", total * 1e6,
               f"speedup={base_t / total:.1f} comp={t_comp:.3f} "
               f"xfer={t_xfer:.3f} comm={t_comm:.3f}")


def measured_strong_scaling(model: str = "tmgcn") -> None:
    n_dev = len(jax.devices())
    n, t = 256, 16
    smooth = {"tmgcn": "mproduct", "cdgcn": "none",
              "evolvegcn": "edgelife"}[model]
    ds = synthetic_dataset(n, t, density=3.0, churn=0.1,
                           smoothing_mode=smooth, seed=0)
    pipe = DTDGPipeline(ds, nb=2)
    cfg = models.DynGNNConfig(model=model, num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=2)
    opt_cfg = adamw.AdamWConfig(lr=1e-2, total_steps=100)
    frames, edges, ew, labels = pipe.blocked_arrays()
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init_state(params)
    base = None
    p = 1
    while p <= n_dev:
        mesh = make_host_mesh(data=p, model=1)
        step = trainer.make_dyngnn_train_step(cfg, mesh, opt_cfg)
        us = time_fn(step, params, opt_state, frames, edges, ew, labels,
                     warmup=2, iters=3)
        if base is None:
            base = us
        record(f"strong_scaling_measured/{model}/P{p}", us,
               f"speedup={base / us:.2f}")
        p *= 2


def modeled_weak_scaling(model: str = "tmgcn") -> None:
    """Fig. 7 setting: T=256, f=3, N doubling from 2^14 with P."""
    t, f_den, feat, layers = 256, 3.0, 6, 2
    base_thr = None
    for i, p in enumerate((1, 2, 4, 8, 16, 32, 64, 128)):
        n = 2 ** 14 * p
        epn = n * f_den * (5 if model != "cdgcn" else 1)   # smoothing x5
        flops = 4.0 * t * (2 * epn * feat + 2 * n * feat * feat) * layers
        t_comp = flops / (p * GPU_FLOPS)
        t_xfer = (t / p) * epn * 12.0 / PCIE_BW * 2
        vol_bytes = cv.snapshot_partition_volume(t, n, feat, layers, p,
                                                 model) * 4
        k = max(p // 8, 1)
        t_comm = (vol_bytes / INTRA_BW if p <= 8
                  else vol_bytes * (k - 1) / k / (k * INTER_BW))
        total = t_comp + t_xfer + t_comm
        thr = t * epn / total
        if base_thr is None:
            base_thr = thr
        record(f"weak_scaling_model/{model}/P{p}", total * 1e6,
               f"edges_per_s={thr:.2e} scaled_speedup={thr / base_thr:.1f}")


def run() -> None:
    for m in ("tmgcn", "cdgcn", "evolvegcn"):
        modeled_strong_scaling(m)
    measured_strong_scaling("tmgcn")
    for m in ("tmgcn", "evolvegcn"):
        modeled_weak_scaling(m)


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
