"""Paper Fig. 5 (strong scaling) + Fig. 7 (weak scaling) + the streamed
distributed composition (PR 2).

Three layers of evidence, since no pod is attached:
  * MEASURED: the actual shard_map train step on 1/2/4/8 host devices
    (same code path as the pod run) — wall-clock speedup + identical loss.
  * MEASURED: per-device streamed transfer bytes + per-round all-to-all
    payloads of the distributed streamed trainer as the simulated mesh
    grows 1 -> 8 (time-axis weak scaling: per-device stream volume stays
    CONSTANT within +-10%, total redistribution volume stays fixed), plus
    the pipelined chunked round (``a2a_chunks=4, pipeline_rounds=True``)
    measured against ``dist.overlap.round_time_model``'s prediction.
  * MODELED: the paper's 128-GPU setting via the analytic communication
    model (volume from repro.dist.comm_volume, bandwidth = intra-node vs
    inter-node split exactly as §6.3 describes: intra volume 1/K, inter
    (K-1)/K for K = P/8 nodes).
  * MEASURED: the out-of-core win condition (``sampled_smoke``) — a
    simulated device budget every full-graph schedule refuses, trained
    by ``mode="sampled"`` with staged bytes below the full-graph epoch.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import models
from repro.data.dyngnn import synthetic_dataset
from repro.dist import comm_volume as cv
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.run import Engine, ExecutionPlan, InMemoryDTDG, RunConfig

_SILENT = lambda _msg: None  # noqa: E731  — benchmark output is CSV rows

GPU_FLOPS = 14e12           # V100 fp32
PCIE_BW = 12e9              # CPU->GPU
INTRA_BW = 150e9            # NVLink-class aggregate per node
INTER_BW = 12.5e9           # 100 Gb EDR IB per node


def modeled_strong_scaling(model: str = "tmgcn", n: int = 1_000_000,
                           t: int = 256, epn: float = 4.2e6,
                           feat: int = 6, layers: int = 2) -> None:
    """Per-epoch time model on the paper's 16-node x 8-GPU system."""
    base_t = None
    for p in (1, 2, 4, 8, 16, 32, 64, 128):
        flops = 4.0 * t * (2 * epn * feat + 2 * n * feat * feat) * layers
        t_comp = flops / (p * GPU_FLOPS)
        t_xfer = (t / p) * epn * 12.0 / PCIE_BW * 2    # fwd + rerun
        vol_units = cv.snapshot_partition_volume(t, n, feat, layers, p,
                                                 model)
        vol_bytes = vol_units * 4.0
        k = max(p // 8, 1)
        if p <= 8:
            t_comm = vol_bytes / INTRA_BW
        else:
            inter = vol_bytes * (k - 1) / k
            t_comm = inter / (k * INTER_BW)
        total = t_comp + t_xfer + t_comm
        if base_t is None:
            base_t = total
        record(f"strong_scaling_model/{model}/P{p}", total * 1e6,
               f"speedup={base_t / total:.1f} comp={t_comp:.3f} "
               f"xfer={t_xfer:.3f} comm={t_comm:.3f}")


def measured_strong_scaling(model: str = "tmgcn",
                            steps_per_fit: int = 16) -> None:
    """Engine.fit() wall-time per step as the mesh grows 1 -> n_dev.

    Repeated ``fit()`` calls on one Engine reuse the compiled shard_map
    step (``ResolvedRun.cache``), so warmup pays the trace/compile.
    Each timed fit still re-runs the (P-independent) per-run setup —
    param/optimizer init, blocked-array reshapes — so ``steps_per_fit``
    is sized to amortize that overhead below the per-step signal.
    """
    n_dev = len(jax.devices())
    n, t = 256, 16
    smooth = {"tmgcn": "mproduct", "cdgcn": "none",
              "evolvegcn": "edgelife"}[model]
    ds = synthetic_dataset(n, t, density=3.0, churn=0.1,
                           smoothing_mode=smooth, seed=0)
    cfg = models.DynGNNConfig(model=model, num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=2)
    opt_cfg = adamw.AdamWConfig(lr=1e-2, total_steps=100)
    base = None
    p = 1
    while p <= n_dev:
        # inject the mesh so P=1 also runs the shard_map step (comparable
        # code path at every P, as before)
        engine = Engine(RunConfig(
            model=cfg, data=InMemoryDTDG(ds),
            plan=ExecutionPlan(mode="eager",
                               mesh=make_host_mesh(data=p, model=1),
                               num_steps=steps_per_fit),
            optimizer=opt_cfg, log_fn=_SILENT))
        us = time_fn(lambda: engine.fit().losses[-1],
                     warmup=2, iters=3) / steps_per_fit
        if base is None:
            base = us
        record(f"strong_scaling_measured/{model}/P{p}", us,
               f"speedup={base / us:.2f}")
        p *= 2


def _round_transfer_time(mesh, streams, ds, max_edges: int, win: int,
                         p: int, iters: int = 2) -> float:
    """Measured transfer phase of one distributed round: stage the
    per-shard delta items, delta-apply them on their devices, stack the
    slots (the work ``pipeline_rounds=True`` overlaps with the previous
    round's collectives)."""
    import time as _time

    from repro.dist import sharding as shardlib
    from repro.stream import distributed as sd
    from repro.stream.prefetch import DeltaApplier, SlotStacker

    frames, labels = np.asarray(ds.frames), np.asarray(ds.labels)
    bsl = win // p
    stage = sd.make_round_stage_fn(mesh)
    devices = shardlib.shard_devices(mesh)
    first = next(sd.dist_round_stream(streams, frames, labels, win, bsl))

    appliers = [DeltaApplier(max_edges, device=d) for d in devices]
    stackers = [SlotStacker(bsl) for _ in devices]

    def once():
        # ring construction happens once per epoch in the trainer, so it
        # stays outside the per-round transfer timing (each slice opens
        # with a FullSnapshot — the rings stay valid across repetitions)
        items, _, _ = stage(first)
        jax.block_until_ready(sd.consume_round(items, appliers, stackers))

    once()                                   # compile apply_delta
    best = float("inf")
    for _ in range(iters):
        t0 = _time.perf_counter()
        once()
        best = min(best, _time.perf_counter() - t0)
    return best


def streamed_scaling(model: str = "tmgcn", n: int = 128, t0: int = 8,
                     bsl0: int = 2) -> None:
    """The PR-2 composition: per-shard delta streams + snapshot-parallel
    shard_map, measured as the simulated mesh grows 1 -> 8 devices.

    Time-axis weak scaling (the paper's regime): the trace grows with the
    mesh (T = t0*P snapshots, round size win = bsl0*P) so each shard's
    owned slice stays t0 steps.  Reported per P:
      * measured per-device stream bytes (mean over shards) — expected
        CONSTANT within +-10% of the P=1 baseline (each device keeps
        receiving one slice-boundary full per round + its own deltas);
      * the analytic model of the same quantity (cv.streamed_shard_volume);
      * per-snapshot all-to-all payload (cv.alltoall_round_payload / win) —
        bounded by 2*L*N*F*4 bytes for ANY P (fixed total communication);
      * wall time per distributed streamed round where the host has the
        devices to run it.
    """
    from repro.core.graphdiff import FullSnapshot
    from repro.data.dyngnn import DTDGPipeline

    n_dev = len(jax.devices())
    smooth = {"tmgcn": "mproduct", "cdgcn": "none",
              "evolvegcn": "edgelife"}[model]
    layers, feat = 2, 6
    base_per_dev = None
    for p in (1, 2, 4, 8):
        t = t0 * p
        win = bsl0 * p
        ds = synthetic_dataset(n, t, density=3.0, churn=0.1,
                               smoothing_mode=smooth, seed=0)
        # ONE stream set serves both the byte report and the timed Engine
        # run below (the pipeline is what the Engine resolves, so the
        # reported bytes are exactly what the timed run transfers)
        pipe = DTDGPipeline(ds, nb=t // win)
        streams = pipe.sharded_streams(p)
        per_dev = [sum(i.payload_bytes for i in s) for s in streams]
        mean_b = float(np.mean(per_dev))
        if base_per_dev is None:
            base_per_dev = mean_b
        ratio = mean_b / base_per_dev
        # analytic model from the trace's mean payload sizes
        items = [i for s in streams for i in s]
        fulls = [i.payload_bytes for i in items
                 if isinstance(i, FullSnapshot)]
        deltas = [i.payload_bytes for i in items
                  if not isinstance(i, FullSnapshot)]
        model_b = cv.streamed_shard_volume(
            t, p, win, float(np.mean(fulls)),
            float(np.mean(deltas)) if deltas else 0.0)
        a2a_per_snap = cv.alltoall_round_payload(win, n, feat, layers,
                                                 p) / win
        record(f"streamed_scaling/{model}/P{p}/per_device_bytes", mean_b,
               f"vs_P1={ratio:.3f} within10pct={abs(ratio - 1) <= 0.1} "
               f"modeled={model_b:.0f} spread="
               f"{(max(per_dev) - min(per_dev)) / max(mean_b, 1):.3f}")
        record(f"streamed_scaling/{model}/P{p}/a2a_bytes_per_snapshot",
               a2a_per_snap,
               f"bound={2 * layers * n * feat * 4} "
               f"total_fixed={cv.snapshot_partition_volume(t, n, feat, layers, p) * 4 / max(t, 1):.0f}")
        if p <= n_dev:
            cfg = models.DynGNNConfig(model=model, num_nodes=n,
                                      num_steps=t, window=3,
                                      checkpoint_blocks=t // win)
            # the Engine hoists the compiled step + encoded shard streams
            # into ResolvedRun.cache: warmup compiles/encodes once, timed
            # iterations measure the stream->reconstruct->shard_map round
            opt_cfg = adamw.AdamWConfig(lr=1e-2, total_steps=100)
            engine = Engine(RunConfig(
                model=cfg, data=InMemoryDTDG(ds, pipeline=pipe),
                plan=ExecutionPlan(mode="streamed_mesh", shards=p,
                                   num_epochs=1),
                optimizer=opt_cfg, log_fn=_SILENT))
            # seed the cache with the streams reported above (no re-encode)
            engine.resolve().cache["shard_streams"] = streams

            us = time_fn(lambda: engine.fit().losses[-1],
                         warmup=1, iters=2)
            rounds = t // win
            record(f"streamed_scaling/{model}/P{p}/epoch_wall",
                   us, f"rounds={rounds} us_per_round={us / rounds:.0f}")

            # pipelined chunked round: measured (a2a_chunks=4 +
            # pipeline_rounds) vs round_time_model's ROUND-LEVEL
            # prediction.  The phase decomposition comes from the
            # synchronous schedule (overlap=False — the default epoch
            # above already hides transfer behind compute, so deriving
            # phases from it would double-count), and the model is
            # called with chunks=1 because only transfer vs step is
            # measured here: the a2a/compute split (where the chunk knob
            # bites) is benchmarked in overlap_bench.pipelined_round.
            from repro.dist import overlap as ovl
            sync = Engine(RunConfig(
                model=cfg, data=InMemoryDTDG(ds, pipeline=pipe),
                plan=ExecutionPlan(mode="streamed_mesh", shards=p,
                                   num_epochs=1, overlap=False),
                optimizer=opt_cfg, log_fn=_SILENT))
            sync.resolve().cache["shard_streams"] = streams
            us_sync = time_fn(lambda: sync.fit().losses[-1],
                              warmup=1, iters=2)
            piped = Engine(RunConfig(
                model=cfg, data=InMemoryDTDG(ds, pipeline=pipe),
                plan=ExecutionPlan(mode="streamed_mesh", shards=p,
                                   num_epochs=1, a2a_chunks=4,
                                   pipeline_rounds=True),
                optimizer=opt_cfg, log_fn=_SILENT))
            piped.resolve().cache["shard_streams"] = streams
            us_pipe = time_fn(lambda: piped.fit().losses[-1],
                              warmup=1, iters=2)
            t_transfer = _round_transfer_time(
                piped.resolve().mesh, streams, ds, pipe.max_edges, win, p)
            t_step = max(us_sync / rounds * 1e-6 - t_transfer, 1e-9)
            m = ovl.round_time_model(t_transfer, t_step, 0.0, 0.0,
                                     chunks=1, pipeline_rounds=True)
            record(f"streamed_scaling/{model}/P{p}/pipelined_round",
                   us_pipe / rounds,
                   f"predicted={m['pipelined_s'] * 1e6:.0f}us "
                   f"serial_sync={us_sync / rounds:.0f}us "
                   f"serial_overlap={us / rounds:.0f}us "
                   f"model_speedup={m['speedup']:.2f} measured_speedup="
                   f"{us_sync / max(us_pipe, 1e-9):.2f}")


def rescale_smoke(model: str = "tmgcn", n: int = 64, t: int = 16) -> None:
    """Elastic rescale cost row: re-shard payload bytes + measured
    time-to-recompose at one P_old -> P_new block boundary.

    The payload (carries + grown replicas, ``cv.rescale_payload``) is
    O(model state); the recompose time covers the state re-shard AND the
    re-slice of the remaining per-shard delta streams — both paid once
    per realized event, never per round.  Needs >= 2 host devices
    (records a skipped row otherwise).
    """
    from repro.data.dyngnn import DTDGPipeline

    n_dev = len(jax.devices())
    nb = 2
    win = t // nb
    # largest grow target that slices the block and fits the devices
    candidates = [p for p in (2, 4, 8) if p <= n_dev and win % p == 0]
    if n_dev < 2 or not candidates:
        record(f"rescale_smoke/{model}/skipped", 0.0,
               f"no width in (2,4,8) divides win={win} on {n_dev} "
               "devices")
        return
    p1 = max(candidates)
    p0 = p1 // 2
    smooth = {"tmgcn": "mproduct", "cdgcn": "none",
              "evolvegcn": "edgelife"}[model]
    ds = synthetic_dataset(n, t, density=3.0, churn=0.1,
                           smoothing_mode=smooth, seed=0)
    pipe = DTDGPipeline(ds, nb=nb)
    cfg = models.DynGNNConfig(model=model, num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=nb)
    engine = Engine(RunConfig(
        model=cfg, data=InMemoryDTDG(ds, pipeline=pipe),
        plan=ExecutionPlan(mode="streamed_mesh", shards=p0, num_epochs=1,
                           rescale=((1, p1),)),
        optimizer=adamw.AdamWConfig(lr=1e-2, total_steps=100),
        log_fn=_SILENT))
    # one COLD fit: the recompose cost of a new (width, boundary) pair is
    # exactly what the elastic runtime pays at the boundary (repeat fits
    # would hit the stream/step caches and report ~0)
    res = engine.fit()
    ev = res.rescale_report.events[0]
    grew = max(p1 - p0, 0)
    record(f"rescale_smoke/{model}/P{p0}->P{p1}/recompose",
           ev.recompose_s * 1e6,
           f"payload_bytes={ev.payload_bytes} block={ev.block} "
           f"grew_replicas={grew} rounds={len(res.losses)}")


def compressed_round(model: str = "tmgcn", n: int = 96, t: int = 16) -> None:
    """Quantized wire smoke rows: measured (compiled-HLO) all-to-all
    bytes of one round step under ``compression="int8_a2a"`` vs the f32
    lowering — asserted <= 0.3x, scales included — the analytic model's
    ratio next to it, plus the measured per-shard stream bytes and loss
    drift of a short ``int8_all`` run against the uncompressed engine run
    on the same trace.  Needs >= 4 host devices AND a window of >= 2
    snapshots per shard so the delta wire has actual deltas to narrow
    (records a skipped row otherwise).
    """
    from repro.core import partition
    from repro.data.dyngnn import DTDGPipeline
    from repro.stream import distributed as stream_dist

    n_dev = len(jax.devices())
    nb = 2
    win = t // nb
    p = 4
    if n_dev < p or win // p < 2:
        record(f"compressed_round/{model}/skipped", 0.0,
               f"needs {p} devices (have {n_dev}) and win//P >= 2 "
               f"(win={win})")
        return
    smooth = {"tmgcn": "mproduct", "cdgcn": "none",
              "evolvegcn": "edgelife"}[model]
    ds = synthetic_dataset(n, t, density=3.0, churn=0.1,
                           smoothing_mode=smooth, seed=0)
    pipe = DTDGPipeline(ds, nb=nb)
    cfg = models.DynGNNConfig(model=model, num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=nb)
    mesh = make_host_mesh(data=p, model=1)

    def hlo_bytes(compression):
        hlo = stream_dist.lowered_step_hlo(
            cfg, mesh, win=win, max_edges=pipe.max_edges,
            compression=compression)
        return cv.hlo_collective_bytes(hlo)

    f32 = hlo_bytes("none")["f32"]["bytes"]
    q = hlo_bytes("int8_a2a")
    q_total = q["s8"]["bytes"] + q.get("f32", {"bytes": 0})["bytes"]
    measured = q_total / f32
    assert measured <= 0.3, (
        f"compressed a2a bytes {q_total} > 0.3x f32 {f32}")
    dims = partition.a2a_payload_dims(cfg)
    feat = dims[0][0]
    modeled = (cv.alltoall_round_payload(win, n, feat, len(dims), p,
                                         compression="int8_a2a")
               / cv.alltoall_round_payload(win, n, feat, len(dims), p))
    record(f"compressed_round/{model}/P{p}/a2a_bytes_ratio",
           measured * 1e6,
           f"measured={measured:.3f} modeled={modeled:.3f} "
           f"s8={q['s8']['bytes']} f32={f32}")

    def fit(compression):
        engine = Engine(RunConfig(
            model=cfg, data=InMemoryDTDG(ds, pipeline=pipe),
            plan=ExecutionPlan(mode="streamed_mesh", shards=p,
                               num_epochs=1, compression=compression),
            optimizer=adamw.AdamWConfig(lr=1e-2, total_steps=100),
            log_fn=_SILENT))
        return engine.fit()

    ref = fit("none")
    got = fit("int8_all")
    drift = max(abs(a - b) for a, b in zip(got.losses, ref.losses))
    wire = sum(got.per_shard_bytes) / sum(ref.per_shard_bytes)
    record(f"compressed_round/{model}/P{p}/stream_bytes_ratio",
           wire * 1e6,
           f"int8_wire={sum(got.per_shard_bytes)} "
           f"f32_wire={sum(ref.per_shard_bytes)} loss_drift={drift:.2e}")


def sampled_smoke(model: str = "cdgcn", n: int = 384, t: int = 8,
                  density: float = 3.0) -> None:
    """Out-of-core win condition: a simulated per-device budget that
    EVERY full-graph schedule refuses (``DeviceBudgetError``) trains
    under ``mode="sampled"``.

    Rows: per-mode refusal margins; sampled staged bytes vs the bytes
    the full-graph stream would stage over the same epoch (must be
    smaller — that is out-of-core); host-sample edge throughput; and
    the per-round sample / stage / step phase split off the
    ``SampleReport``.
    """
    from repro import hoststore as hs
    from repro.data.dyngnn import DTDGPipeline

    n_dev = len(jax.devices())
    nb = 2
    win = t // nb
    p = max(pp for pp in (1, 2, 4, 8) if pp <= n_dev and win % pp == 0)
    smooth = {"tmgcn": "mproduct", "cdgcn": "none",
              "evolvegcn": "edgelife"}[model]
    ds = synthetic_dataset(n, t, density=density, churn=0.1,
                           smoothing_mode=smooth, seed=0)
    pipe = DTDGPipeline(ds, nb=nb)
    feat = int(np.asarray(ds.frames).shape[-1])
    cfg = models.DynGNNConfig(model=model, num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=nb)
    # truncated budgets: the table holds ~N/3 vertices, the edge pad a
    # quarter of the full-graph max — the out-of-core regime, not the
    # full-fanout equivalence regime
    spec = hs.SamplingSpec(batch_nodes=max(n // 8, 16), fanouts=(4, 4),
                           seed=0, table_pad=max(n // 3, 32),
                           max_edges=max(pipe.max_edges // 4, 128))
    budget = hs.sampled_round_bytes(spec.resolve(n, win, p), win=win,
                                    num_shards=p, feat_dim=feat)

    data = InMemoryDTDG(ds, pipeline=pipe)
    opt_cfg = adamw.AdamWConfig(lr=1e-2, total_steps=100)
    for mode, shards in (("eager", 1), ("streamed", 1),
                         ("streamed_mesh", p)):
        try:
            Engine(RunConfig(
                model=cfg, data=data,
                plan=ExecutionPlan(mode=mode, shards=shards,
                                   device_budget_bytes=budget),
                optimizer=opt_cfg, log_fn=_SILENT)).fit()
            raise AssertionError(
                f"full-graph mode {mode!r} fit budget {budget}")
        except hs.DeviceBudgetError as e:
            record(f"sampled_smoke/{model}/refused/{mode}",
                   float(e.required),
                   f"budget={budget} over={e.required / budget:.1f}x")

    engine = Engine(RunConfig(
        model=cfg, data=data,
        plan=ExecutionPlan(mode="sampled", shards=p, num_epochs=1,
                           sampling=spec, device_budget_bytes=budget),
        optimizer=opt_cfg, log_fn=_SILENT))
    res = engine.fit()
    rep = res.sample_report
    # the mesh-total graph bytes the full-graph stream stages for the
    # same epoch (win * per_step per round, P-independent)
    full_epoch = (t // win) * hs.full_graph_round_bytes(
        "streamed", num_steps=t, win=win, num_shards=1,
        max_edges=pipe.max_edges, num_nodes=n, feat_dim=feat)
    assert rep.staged_bytes < full_epoch, (rep.staged_bytes, full_epoch)
    record(f"sampled_smoke/{model}/P{p}/staged_bytes",
           float(rep.staged_bytes),
           f"full_graph_epoch={full_epoch} "
           f"ratio={rep.staged_bytes / full_epoch:.3f} "
           f"dropped_edges={rep.dropped_edges} "
           f"dropped_nodes={rep.dropped_nodes} "
           f"table_fill_max={rep.table_fill_max}")
    record(f"sampled_smoke/{model}/P{p}/host_sample_throughput",
           rep.sample_seconds / max(rep.rounds, 1) * 1e6,
           f"edges_per_s={rep.sampled_edges / max(rep.sample_seconds, 1e-9):.2e} "
           f"sampled_edges={rep.sampled_edges}")
    record(f"sampled_smoke/{model}/P{p}/round_phases",
           (rep.sample_seconds + rep.stage_seconds + rep.step_seconds)
           / max(rep.rounds, 1) * 1e6,
           f"sample_us={rep.sample_seconds / max(rep.rounds, 1) * 1e6:.0f} "
           f"stage_us={rep.stage_seconds / max(rep.rounds, 1) * 1e6:.0f} "
           f"step_us={rep.step_seconds / max(rep.rounds, 1) * 1e6:.0f} "
           f"rounds={rep.rounds} loss_last={res.losses[-1]:.4f}")


def modeled_weak_scaling(model: str = "tmgcn") -> None:
    """Fig. 7 setting: T=256, f=3, N doubling from 2^14 with P."""
    t, f_den, feat, layers = 256, 3.0, 6, 2
    base_thr = None
    for p in (1, 2, 4, 8, 16, 32, 64, 128):
        n = 2 ** 14 * p
        epn = n * f_den * (5 if model != "cdgcn" else 1)   # smoothing x5
        flops = 4.0 * t * (2 * epn * feat + 2 * n * feat * feat) * layers
        t_comp = flops / (p * GPU_FLOPS)
        t_xfer = (t / p) * epn * 12.0 / PCIE_BW * 2
        vol_bytes = cv.snapshot_partition_volume(t, n, feat, layers, p,
                                                 model) * 4
        k = max(p // 8, 1)
        t_comm = (vol_bytes / INTRA_BW if p <= 8
                  else vol_bytes * (k - 1) / k / (k * INTER_BW))
        total = t_comp + t_xfer + t_comm
        thr = t * epn / total
        if base_thr is None:
            base_thr = thr
        record(f"weak_scaling_model/{model}/P{p}", total * 1e6,
               f"edges_per_s={thr:.2e} scaled_speedup={thr / base_thr:.1f}")


def run() -> None:
    for m in ("tmgcn", "cdgcn", "evolvegcn"):
        modeled_strong_scaling(m)
    measured_strong_scaling("tmgcn")
    streamed_scaling("tmgcn")
    rescale_smoke("tmgcn")
    compressed_round("tmgcn")
    sampled_smoke("cdgcn")
    for m in ("tmgcn", "evolvegcn"):
        modeled_weak_scaling(m)


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
