"""Paper §3.1/§6.2: gradient-checkpoint memory/time trade-off vs nb.

MEASURED memory: XLA's compiled memory_analysis (temp bytes) of the real
train step at each nb — the ground truth the paper tunes by hand; plus the
analytic two-component model (intra-block vs checkpoint data)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import checkpoint as ckpt_exec
from repro.core import models
from repro.run import Engine, ExecutionPlan, RunConfig, SyntheticTrace


def run(model: str = "tmgcn", n: int = 512, t: int = 32) -> None:
    # data + pipeline resolved through the Engine (the nb sweep below
    # varies the blocking of the SAME device batch, so resolve once)
    resolved = Engine(RunConfig(
        model=models.DynGNNConfig(model=model, num_nodes=n, num_steps=t,
                                  window=3, checkpoint_blocks=1),
        data=SyntheticTrace(num_nodes=n, num_steps=t, density=3.0,
                            churn=0.1, smoothing_mode="none", seed=0),
        plan=ExecutionPlan(mode="eager", num_steps=1),
        log_fn=lambda _msg: None)).resolve()
    ds, pipe = resolved.ds, resolved.pipeline
    labels = jnp.asarray(ds.labels)
    num_edges = int(np.mean([s.shape[0] for s in ds.snapshots]))
    for nb in (1, 2, 4, 8):
        cfg = models.DynGNNConfig(model=model, num_nodes=n, num_steps=t,
                                  window=3, checkpoint_blocks=nb)
        params = models.init_params(jax.random.PRNGKey(0), cfg)

        def loss(p, nb=nb):
            return ckpt_exec.blocked_node_loss(cfg, p, pipe.batch, labels,
                                               nb=nb)

        grad_fn = jax.jit(jax.grad(loss))
        compiled = grad_fn.lower(params).compile()
        mem = compiled.memory_analysis()
        temp = getattr(mem, "temp_size_in_bytes", 0)
        est = ckpt_exec.activation_memory_estimate(cfg, num_edges, nb)
        us = time_fn(grad_fn, params, warmup=1, iters=3)
        record(f"checkpoint/{model}/nb{nb}", us,
               f"xla_temp_bytes={temp} model_intra={est['intra_block']} "
               f"model_ckpt={est['checkpoint']}")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
