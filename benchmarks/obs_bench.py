"""Tracer overhead benchmark — the <2% disabled-overhead contract.

``repro.obs`` promises that an UNTRACED run pays (almost) nothing for
being instrumentable: with the tracer disabled, ``obs.span()`` returns
the shared null span without reading a clock or taking a lock.  This
section measures

* the WORK UNIT — one matmul of the smallest size any instrumented
  region in this repo actually wraps (the real regions — stream rounds,
  prefetch staging, sampler rounds — are milliseconds; ``dim=192`` is
  ~100x smaller, i.e. conservative),
* the SPAN COST — a span-per-iteration loop with no work inside, so
  the per-span cost is measured directly instead of as the difference
  of two noisy loop timings,

and FAILS the section (``RuntimeError`` -> non-zero exit) if
``span_cost / unit_time`` exceeds 2% with the tracer disabled.  The
enabled-tracer cost is reported alongside for scale (not asserted — a
traced run buys the data with the overhead).  Min-of-``reps`` per
measurement: scheduler noise can only inflate a timing, never deflate
it, so the min is the honest estimate.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import record
from repro import obs

MAX_DISABLED_OVERHEAD = 0.02


def _min_of(reps: int, fn) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(units: int = 2000, reps: int = 5, dim: int = 192) -> None:
    rng = np.random.default_rng(0)
    a = rng.standard_normal((dim, dim)).astype(np.float32)
    b = rng.standard_normal((dim, dim)).astype(np.float32)

    def work_loop() -> None:
        for _ in range(units):
            np.dot(a, b)

    def span_loop() -> None:
        for i in range(units):
            with obs.span("bench.unit", i=i):
                pass

    prev = obs.get_tracer()
    try:
        obs.configure(enabled=False)
        work_loop(); span_loop()                # warm caches / allocator
        unit_s = _min_of(reps, work_loop) / units
        off_s = _min_of(reps, span_loop) / units

        obs.configure(enabled=True, fence=False, capacity=2 * units)
        span_loop()
        on_s = _min_of(reps, span_loop) / units
    finally:
        obs.set_tracer(prev)

    overhead = off_s / unit_s
    record("obs_work_unit", unit_s * 1e6, f"dim={dim}")
    record("obs_disabled_span", off_s * 1e6,
           f"overhead={overhead:.2%}_budget={MAX_DISABLED_OVERHEAD:.0%}")
    record("obs_enabled_span", on_s * 1e6,
           f"overhead={on_s / unit_s:.2%}_fence=off")
    if overhead >= MAX_DISABLED_OVERHEAD:
        raise RuntimeError(
            f"disabled span costs {off_s * 1e9:.0f} ns = {overhead:.2%} "
            f"of a {unit_s * 1e6:.1f} us work unit — the no-op span "
            f"contract allows <{MAX_DISABLED_OVERHEAD:.0%}")
