"""Paper Table 2: snapshot vs vertex(hypergraph) partitioning — comm volume
(analytic, with BFS-locality standing in for PaToH) and measured step time
of both executable implementations on host devices."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import dtdg, models, partition
from repro.dist import comm_volume as cv
from repro.graph import generate
from repro.launch.mesh import make_host_mesh


def volume_table(model: str = "tmgcn") -> None:
    """Comm volume (units = floats) for snapshot vs hypergraph-style vertex
    partitioning at P = 4/16/64 on an AMLSim-like synthetic graph."""
    n, t, feat, layers = 4096, 64, 6, 2
    density = 8.0 if model != "cdgcn" else 3.0   # smoothing densifies
    snaps = generate.evolving_dynamic_graph(n, t, density, churn=0.15,
                                            seed=0)
    owner_edges = np.concatenate(snaps)
    for p in (4, 16, 64):
        v_snap = cv.snapshot_partition_volume(t, n, feat, layers, p, model)
        owner = cv.bfs_partition(owner_edges, n, p)
        v_hyper = cv.vertex_partition_volume(snaps, n, feat, layers, p,
                                             owner)
        record(f"partition_volume/{model}/P{p}", 0.0,
               f"snapshot={v_snap:.3e} hypergraph={v_hyper:.3e} "
               f"ratio={v_hyper / max(v_snap, 1):.2f}")


def measured_times(model: str = "tmgcn") -> None:
    n_dev = len(jax.devices())
    p = min(4, n_dev)
    mesh = make_host_mesh(data=p, model=1)
    n, t = 256, 16
    snaps = generate.evolving_dynamic_graph(n, t, density=3.0, churn=0.1,
                                            seed=0)
    frames = np.stack([generate.degree_features(s, n) for s in snaps])
    batch = dtdg.build_batch(snaps, frames, n)
    cfg = models.DynGNNConfig(model=model, num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=2)
    params = models.init_params(jax.random.PRNGKey(0), cfg)

    fwd_sp = jax.jit(partition.snapshot_partition_forward(cfg, mesh))
    fr, ed, ew = partition.blockify_batch(batch, 2)
    us_sp = time_fn(fwd_sp, params, fr, ed, ew, warmup=2, iters=3)
    record(f"partition_time/{model}/snapshot/P{p}", us_sp, "")

    import dataclasses
    cfg_vp = dataclasses.replace(cfg, checkpoint_blocks=1)
    fwd_vp = jax.jit(partition.vertex_partition_forward(cfg_vp, mesh))
    edges_p, w_p = partition.partition_edges_by_dst(
        batch.edges, batch.edge_mask, n, p,
        max_local_edges=batch.edges.shape[1])
    w_full = np.asarray(batch.edge_weights)
    ew_p = np.zeros_like(w_p)
    for ti in range(t):
        e = np.asarray(batch.edges[ti])
        m = np.asarray(batch.edge_mask[ti]) > 0
        ew_t = w_full[ti][m]
        own = e[m][:, 1] // (n // p)
        for pi in range(p):
            sel = ew_t[own == pi]
            ew_p[ti, pi, :sel.shape[0]] = sel
    e_stack = jnp.asarray(edges_p).reshape(t, p * edges_p.shape[2], 2)
    w_stack = jnp.asarray(ew_p).reshape(t, p * ew_p.shape[2])
    us_vp = time_fn(fwd_vp, params, batch.frames, e_stack, w_stack,
                    warmup=2, iters=3)
    record(f"partition_time/{model}/vertex/P{p}", us_vp,
           f"snapshot_speedup={us_vp / us_sp:.2f}")


def run() -> None:
    for m in ("tmgcn", "cdgcn", "evolvegcn"):
        volume_table(m)
    measured_times("tmgcn")
    measured_times("cdgcn")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
