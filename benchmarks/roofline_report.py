"""Aggregate results/dryrun JSONs into the EXPERIMENTS.md roofline table."""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

HBM_PER_CHIP = 16 * 2 ** 30


def model_flops(rec: dict) -> float | None:
    """6*N*D (dense) / 6*N_active*D (MoE) for LM train; 2*N*D for serve."""
    from repro.configs import registry
    try:
        arch = registry.get_arch(rec["arch"])
    except KeyError:
        return None
    if arch.family != "lm":
        return None
    cfg = arch.make_config()
    toks = rec.get("meta", {}).get("tokens", 0)
    n_par = cfg.active_param_count()
    if rec["shape"].startswith("train"):
        return 6.0 * n_par * toks
    return 2.0 * n_par * toks


def rows(mesh_dir: Path) -> list[dict]:
    out = []
    chips = 512 if "2x16" in mesh_dir.name else 256
    for f in sorted(mesh_dir.glob("*.json")):
        r = json.loads(f.read_text())
        row = {"arch": r["arch"], "shape": r["shape"], "mesh": mesh_dir.name,
               "status": r["status"]}
        if r["status"] == "ok":
            rl = r["roofline"]
            row.update({
                "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
                "collective_s": rl["collective_s"],
                "dominant": rl["dominant"],
                "temp_gb": (r["memory"].get("temp_size_in_bytes") or 0)
                / 2 ** 30,
                "fits_hbm": ((r["memory"].get("temp_size_in_bytes") or 0)
                             + (r["memory"].get("argument_size_in_bytes")
                                or 0)) < HBM_PER_CHIP,
            })
            mf = model_flops(r)
            if mf:
                row["model_flops_global"] = mf
                hlo_global = rl["hlo_flops_per_device"] * chips
                row["useful_flops_frac"] = mf / max(hlo_global, 1)
        else:
            row["error"] = r.get("error", "")[:120]
        out.append(row)
    return out


def main() -> None:
    for mesh_dir in sorted(RESULTS.glob("pod*")):
        print(f"\n=== {mesh_dir.name} ===")
        print(f"{'arch':26s}{'shape':16s}{'dom':13s}{'comp_s':>9s}"
              f"{'mem_s':>9s}{'coll_s':>9s}{'temp_GB':>9s}{'fit':>5s}"
              f"{'useful':>8s}")
        for row in rows(mesh_dir):
            if row["status"] != "ok":
                print(f"{row['arch']:26s}{row['shape']:16s}ERROR "
                      f"{row.get('error', '')}")
                continue
            uf = row.get("useful_flops_frac")
            print(f"{row['arch']:26s}{row['shape']:16s}"
                  f"{row['dominant'].replace('_s', ''):13s}"
                  f"{row['compute_s']:9.3f}{row['memory_s']:9.3f}"
                  f"{row['collective_s']:9.3f}{row['temp_gb']:9.1f}"
                  f"{str(row['fits_hbm'])[:1]:>5s}"
                  f"{uf if uf is None else round(uf, 2)!s:>8s}")


if __name__ == "__main__":
    main()
