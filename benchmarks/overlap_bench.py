"""Beyond-paper (§6.5): compute/communication overlap benefit model + HLO
structural verification that the chunked schedule exposes overlap, plus
the MEASURED host->device streaming overlap: per-snapshot training with
the prefetched delta stream vs the synchronous reference schedule."""

from __future__ import annotations

import time

import jax

from benchmarks.common import record
from repro.core import models, partition
from repro.dist import overlap
from repro.launch.mesh import make_host_mesh


def stream_overlap(n: int = 4096, t: int = 64, density: float = 6.0,
                   churn: float = 0.15, iters: int = 3) -> None:
    """Measured streamed-transfer pipeline: per-step wall time of
    encode -> device_put -> apply_delta -> on-device Laplacian weights,
    synchronous vs prefetch-overlapped (identical computations; the
    prefetch thread hides encode + transfer of delta k+1 behind step k's
    device work).  Loss-identity of the full streamed TRAINING loop under
    overlap is asserted in tests/test_stream.py."""
    import numpy as np

    import jax.numpy as jnp
    from repro.graph import generate, segment
    from repro.stream import encoder as stream_encoder
    from repro.stream.prefetch import (DeltaApplier, PrefetchIterator,
                                       stage_item)

    snaps = generate.evolving_dynamic_graph(n, t, density, churn, seed=0)
    rng = np.random.default_rng(0)
    values = [rng.uniform(0.5, 1.5, s.shape[0]).astype(np.float32)
              for s in snaps]
    max_edges = stream_encoder.padded_max_edges(snaps)
    stats = stream_encoder.measure_stats(snaps, n, 8, max_edges)
    loops = jnp.stack([jnp.arange(n, dtype=jnp.int32)] * 2, axis=1)
    ones = jnp.ones((n,), jnp.float32)

    @jax.jit
    def reconstruct_weights(e, m, v):
        ef = jnp.concatenate([e, loops])
        mf = jnp.concatenate([m, ones])
        vf = jnp.concatenate([v, ones])
        return segment.gcn_edge_weights(ef, n, mf, vf)

    def pipeline(overlap_on: bool) -> float:
        it = stream_encoder.iter_encode_stream(snaps, values, n, max_edges,
                                               8, stats)
        items = PrefetchIterator(it, depth=3) if overlap_on \
            else (stage_item(x) for x in it)
        applier = DeltaApplier(max_edges)
        acc = 0.0
        for item in items:
            e, m, v = applier.consume(item)
            acc += float(reconstruct_weights(e, m, v).sum())  # step sync
        return acc

    pipeline(False)  # compile
    times = {}
    for name, ov in (("sync", False), ("prefetch", True)):
        best = min(_timed(pipeline, ov) for _ in range(iters))
        times[name] = best / t
    record("stream_overlap/sync_step", times["sync"] * 1e6,
           f"T={t} N={n} E_max={max_edges}")
    record("stream_overlap/prefetch_step", times["prefetch"] * 1e6,
           f"step_time_reduction="
           f"{(1 - times['prefetch'] / times['sync']) * 100:.1f}%")


def _timed(fn, *a) -> float:
    t0 = time.perf_counter()
    fn(*a)
    return time.perf_counter() - t0


def run(smoke: bool = False) -> None:
    if smoke:
        stream_overlap(n=512, t=16, iters=1)
    else:
        stream_overlap()
    # analytic: amlsim-scale per-block GCN vs a2a times on v5e
    flops_gcn = 4.2e6 * 2 * 6 * 2 * 64        # E*2F * layers * bsize
    t_gcn = flops_gcn / 197e12 * 50           # sparse ops run ~2% MXU util
    vol = 64 * 1_000_000 * 6 * 4 / 32         # bsize*N*F bytes / P
    t_a2a = vol / 50e9
    for c in (1, 2, 4, 8):
        m = overlap.overlap_time_model(t_gcn, t_a2a, c)
        record(f"overlap_model/chunks{c}", m["pipelined_s"] * 1e6,
               f"speedup={m['speedup']:.3f}")
    # HLO structure on host mesh (needs >= 4 devices; under the default
    # single-device bench run the structural check lives in
    # tests/test_partitioning.py::test_overlapped_hlo_has_multiple_all_to_alls)
    if len(jax.devices()) < 4:
        record("overlap_hlo/all_to_all_ops", 0.0,
               "skipped: single-device run (covered by tests)")
        return
    import jax.numpy as jnp
    import numpy as np
    from repro.core import dtdg
    from repro.graph import generate
    mesh = make_host_mesh(data=4, model=1)
    n, t = 64, 16
    snaps = generate.evolving_dynamic_graph(n, t, 2.0, 0.1, 0)
    frames = np.stack([generate.degree_features(s, n) for s in snaps])
    batch = dtdg.build_batch(snaps, frames, n)
    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=2)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    fr, ed, ew = partition.blockify_batch(batch, 2)
    plain = jax.jit(partition.snapshot_partition_forward(cfg, mesh)) \
        .lower(params, fr, ed, ew).compile().as_text()
    over = jax.jit(overlap.snapshot_partition_forward_overlapped(
        cfg, mesh, num_chunks=2)).lower(params, fr, ed, ew).compile() \
        .as_text()
    record("overlap_hlo/all_to_all_ops", 0.0,
           f"plain={plain.count('all-to-all')} "
           f"chunked={over.count('all-to-all')}")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
