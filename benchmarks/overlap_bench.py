"""Beyond-paper (§6.5): compute/communication overlap benefit model + HLO
structural verification that the chunked schedule exposes overlap, plus
two MEASURED overlap pipelines:

* ``stream_overlap`` — host->device streaming: per-snapshot delta
  encode/transfer prefetched behind device compute vs the synchronous
  reference schedule (the single-device half of the story; the Engine
  API exposes it as ``ExecutionPlan(overlap=True, prefetch_depth=...)``);
* ``pipelined_round`` — the distributed streamed round on P=1..8 host
  devices: serial (delta-apply -> assemble -> shard_map step) vs the
  chunked-round pipeline (``a2a_chunks=C, pipeline_rounds=True``, i.e.
  ``ExecutionPlan``'s knobs), with ``dist.overlap.round_time_model``'s
  prediction reported next to the measured round time.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import record
from repro.core import models, partition
from repro.dist import overlap
from repro.launch.mesh import make_host_mesh


def stream_overlap(n: int = 4096, t: int = 64, density: float = 6.0,
                   churn: float = 0.15, iters: int = 3) -> None:
    """Measured streamed-transfer pipeline: per-step wall time of
    encode -> device_put -> apply_delta -> on-device Laplacian weights,
    synchronous vs prefetch-overlapped (identical computations; the
    prefetch thread hides encode + transfer of delta k+1 behind step k's
    device work).  Loss-identity of the full streamed TRAINING loop under
    overlap is asserted in tests/test_stream.py."""
    import numpy as np

    import jax.numpy as jnp
    from repro.graph import generate, segment
    from repro.stream import encoder as stream_encoder
    from repro.stream.prefetch import (DeltaApplier, PrefetchIterator,
                                       stage_item)

    snaps = generate.evolving_dynamic_graph(n, t, density, churn, seed=0)
    rng = np.random.default_rng(0)
    values = [rng.uniform(0.5, 1.5, s.shape[0]).astype(np.float32)
              for s in snaps]
    max_edges = stream_encoder.padded_max_edges(snaps)
    stats = stream_encoder.measure_stats(snaps, n, 8, max_edges)
    loops = jnp.stack([jnp.arange(n, dtype=jnp.int32)] * 2, axis=1)
    ones = jnp.ones((n,), jnp.float32)

    @jax.jit
    def reconstruct_weights(e, m, v):
        ef = jnp.concatenate([e, loops])
        mf = jnp.concatenate([m, ones])
        vf = jnp.concatenate([v, ones])
        return segment.gcn_edge_weights(ef, n, mf, vf)

    def pipeline(overlap_on: bool) -> float:
        it = stream_encoder.iter_encode_stream(snaps, values, n, max_edges,
                                               8, stats)
        items = PrefetchIterator(it, depth=3) if overlap_on \
            else (stage_item(x) for x in it)
        applier = DeltaApplier(max_edges)
        acc = 0.0
        for item in items:
            e, m, v = applier.consume(item)
            acc += float(reconstruct_weights(e, m, v).sum())  # step sync
        return acc

    pipeline(False)  # compile
    times = {}
    for name, ov in (("sync", False), ("prefetch", True)):
        best = min(_timed(pipeline, ov) for _ in range(iters))
        times[name] = best / t
    record("stream_overlap/sync_step", times["sync"] * 1e6,
           f"T={t} N={n} E_max={max_edges}")
    record("stream_overlap/prefetch_step", times["prefetch"] * 1e6,
           f"step_time_reduction="
           f"{(1 - times['prefetch'] / times['sync']) * 100:.1f}%")


def _timed(fn, *a) -> float:
    t0 = time.perf_counter()
    fn(*a)
    return time.perf_counter() - t0


def pipelined_round(n: int = 128, t: int = 16, win: int = 8,
                    chunks: int = 4, iters: int = 3) -> None:
    """Distributed streamed round, serial vs chunked-round pipeline, on
    P=1..8 host devices: predicted (``round_time_model``) vs measured.

    Phase estimates feeding the model, all from this host:
      * transfer  — measured: stage + delta-apply + assemble one round;
      * compute   — the P=1 serial step (its all-to-alls are degenerate),
        split into spatial/temporal by analytic flops (only their sum
        enters the pipelining bound);
      * a2a       — measured step time at P minus the P=1 compute
        reference (host devices share the cores, so fixed-trace compute
        wall time is ~P-independent).
    """
    import numpy as np

    from repro.data.dyngnn import synthetic_dataset
    from repro.optim import adamw
    from repro.stream import distributed as sd
    from repro.stream import encoder as enc
    from repro.stream import sharded as stream_sharded

    n_dev = len(jax.devices())
    ds = synthetic_dataset(n, t, density=3.0, churn=0.1,
                           smoothing_mode="mproduct", seed=0)
    frames, labels = np.asarray(ds.frames), np.asarray(ds.labels)
    rounds = t // win
    max_edges = enc.padded_max_edges(ds.snapshots)
    e_mean = float(np.mean([s.shape[0] for s in ds.snapshots]))
    comp_ref = None
    for p in (1, 2, 4, 8):
        if p > n_dev or n % p or win % p:
            continue
        cfg = models.DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=t,
                                  window=3, checkpoint_blocks=rounds)
        mesh = make_host_mesh(data=p, model=1)
        opt_cfg = adamw.AdamWConfig(lr=1e-2, total_steps=100)
        serial_step = sd.make_dist_stream_step(cfg, mesh, opt_cfg)
        pipe_step = sd.make_dist_stream_step(cfg, mesh, opt_cfg,
                                             a2a_chunks=chunks)
        streams = stream_sharded.encode_time_sliced(
            ds.snapshots, ds.values, n, max_edges, win, p)

        def epoch(step_fn, c, pipelined):
            st = sd.train_distributed_streamed(
                cfg, ds.snapshots, ds.values, frames, labels, mesh=mesh,
                num_epochs=1, a2a_chunks=c, pipeline_rounds=pipelined,
                opt_cfg=opt_cfg, step_fn=step_fn, shard_streams=streams)
            return st.losses[-1]

        epoch(serial_step, 1, False)            # compile
        epoch(pipe_step, chunks, True)          # compile
        t_serial = min(_timed(epoch, serial_step, 1, False)
                       for _ in range(iters)) / rounds
        t_pipe = min(_timed(epoch, pipe_step, chunks, True)
                     for _ in range(iters)) / rounds

        # transfer phase: stage + reconstruct + assemble one round, forced
        stage = sd.make_round_stage_fn(mesh)
        from repro.dist import sharding as shardlib
        devices = shardlib.shard_devices(mesh)
        bsl = win // p
        host_rounds = list(sd.dist_round_stream(streams, frames, labels,
                                                win, bsl))

        from repro.stream.prefetch import DeltaApplier, SlotStacker
        appliers = [DeltaApplier(max_edges, device=d) for d in devices]
        stackers = [SlotStacker(bsl) for _ in devices]

        def transfer_once():
            # appliers/stackers live outside: the trainer builds them once
            # per epoch, so ring construction is not part of the per-round
            # transfer phase (each slice opens with a FullSnapshot, so the
            # rings stay valid across repetitions)
            items, _, _ = stage(host_rounds[0])
            jax.block_until_ready(
                sd.consume_round(items, appliers, stackers))

        transfer_once()                          # compile apply_delta
        t_transfer = min(_timed(transfer_once) for _ in range(iters))
        t_step = max(t_serial - t_transfer, 1e-9)
        if comp_ref is None:
            comp_ref = t_step                    # P=1: degenerate a2a
        t_comp = min(comp_ref, t_step)
        t_a2a = max(t_step - comp_ref, 0.0)
        feat = cfg.hidden
        fl_spatial = 2 * e_mean * 2 * feat + 2 * n * feat * feat
        fl_temporal = 2 * cfg.window * n * feat * feat
        f_sp = fl_spatial / (fl_spatial + fl_temporal)
        m = overlap.round_time_model(t_transfer, f_sp * t_comp, t_a2a,
                                     (1 - f_sp) * t_comp, chunks=chunks,
                                     pipeline_rounds=True)
        record(f"pipelined_round/P{p}", t_pipe * 1e6,
               f"predicted={m['pipelined_s'] * 1e6:.0f}us "
               f"serial_measured={t_serial * 1e6:.0f}us "
               f"model_speedup={m['speedup']:.2f} "
               f"measured_speedup={t_serial / max(t_pipe, 1e-9):.2f} "
               f"C={chunks} phases(us)=transfer:{t_transfer * 1e6:.0f},"
               f"a2a:{t_a2a * 1e6:.0f},comp:{t_comp * 1e6:.0f}")


def run(smoke: bool = False) -> None:
    if smoke:
        stream_overlap(n=512, t=16, iters=1)
        pipelined_round(n=64, t=8, win=4, iters=1)
    else:
        stream_overlap()
        pipelined_round()
    # analytic: amlsim-scale per-block GCN vs a2a times on v5e
    flops_gcn = 4.2e6 * 2 * 6 * 2 * 64        # E*2F * layers * bsize
    t_gcn = flops_gcn / 197e12 * 50           # sparse ops run ~2% MXU util
    vol = 64 * 1_000_000 * 6 * 4 / 32         # bsize*N*F bytes / P
    t_a2a = vol / 50e9
    t_xfer = 64 * 4.2e6 / 32 * 12.0 / 12e9    # per-shard deltas over PCIe
    for c in (1, 2, 4, 8):
        m = overlap.overlap_time_model(t_gcn, t_a2a, c)
        record(f"overlap_model/chunks{c}", m["pipelined_s"] * 1e6,
               f"speedup={m['speedup']:.3f}")
        rm = overlap.round_time_model(t_xfer, t_gcn * 0.7, t_a2a,
                                      t_gcn * 0.3, chunks=c,
                                      pipeline_rounds=True)
        record(f"round_model/chunks{c}", rm["pipelined_s"] * 1e6,
               f"serial={rm['serial_s'] * 1e6:.1f}us "
               f"speedup={rm['speedup']:.3f}")
    # HLO structure on host mesh (needs >= 4 devices; under the default
    # single-device bench run the structural check lives in
    # tests/test_partitioning.py::test_overlapped_hlo_has_multiple_all_to_alls)
    if len(jax.devices()) < 4:
        record("overlap_hlo/all_to_all_ops", 0.0,
               "skipped: single-device run (covered by tests)")
        return
    import jax.numpy as jnp
    import numpy as np
    from repro.core import dtdg
    from repro.graph import generate
    mesh = make_host_mesh(data=4, model=1)
    n, t = 64, 16
    snaps = generate.evolving_dynamic_graph(n, t, 2.0, 0.1, 0)
    frames = np.stack([generate.degree_features(s, n) for s in snaps])
    batch = dtdg.build_batch(snaps, frames, n)
    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=2)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    fr, ed, ew = partition.blockify_batch(batch, 2)
    plain = jax.jit(partition.snapshot_partition_forward(cfg, mesh)) \
        .lower(params, fr, ed, ew).compile().as_text()
    over = jax.jit(overlap.snapshot_partition_forward_overlapped(
        cfg, mesh, num_chunks=2)).lower(params, fr, ed, ew).compile() \
        .as_text()
    record("overlap_hlo/all_to_all_ops", 0.0,
           f"plain={plain.count('all-to-all')} "
           f"chunked={over.count('all-to-all')}")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
