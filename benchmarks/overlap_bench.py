"""Beyond-paper (§6.5): compute/communication overlap benefit model + HLO
structural verification that the chunked schedule exposes overlap."""

from __future__ import annotations

import jax

from benchmarks.common import record
from repro.core import models, partition
from repro.dist import overlap
from repro.launch.mesh import make_host_mesh


def run() -> None:
    # analytic: amlsim-scale per-block GCN vs a2a times on v5e
    flops_gcn = 4.2e6 * 2 * 6 * 2 * 64        # E*2F * layers * bsize
    t_gcn = flops_gcn / 197e12 * 50           # sparse ops run ~2% MXU util
    vol = 64 * 1_000_000 * 6 * 4 / 32         # bsize*N*F bytes / P
    t_a2a = vol / 50e9
    for c in (1, 2, 4, 8):
        m = overlap.overlap_time_model(t_gcn, t_a2a, c)
        record(f"overlap_model/chunks{c}", m["pipelined_s"] * 1e6,
               f"speedup={m['speedup']:.3f}")
    # HLO structure on host mesh (needs >= 4 devices; under the default
    # single-device bench run the structural check lives in
    # tests/test_partitioning.py::test_overlapped_hlo_has_multiple_all_to_alls)
    if len(jax.devices()) < 4:
        record("overlap_hlo/all_to_all_ops", 0.0,
               "skipped: single-device run (covered by tests)")
        return
    import jax.numpy as jnp
    import numpy as np
    from repro.core import dtdg
    from repro.graph import generate
    mesh = make_host_mesh(data=4, model=1)
    n, t = 64, 16
    snaps = generate.evolving_dynamic_graph(n, t, 2.0, 0.1, 0)
    frames = np.stack([generate.degree_features(s, n) for s in snaps])
    batch = dtdg.build_batch(snaps, frames, n)
    cfg = models.DynGNNConfig(model="tmgcn", num_nodes=n, num_steps=t,
                              window=3, checkpoint_blocks=2)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    fr, ed, ew = partition.blockify_batch(batch, 2)
    plain = jax.jit(partition.snapshot_partition_forward(cfg, mesh)) \
        .lower(params, fr, ed, ew).compile().as_text()
    over = jax.jit(overlap.snapshot_partition_forward_overlapped(
        cfg, mesh, num_chunks=2)).lower(params, fr, ed, ew).compile() \
        .as_text()
    record("overlap_hlo/all_to_all_ops", 0.0,
           f"plain={plain.count('all-to-all')} "
           f"chunked={over.count('all-to-all')}")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
